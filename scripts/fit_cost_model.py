"""Refit the autotuner's analytical cost model from sidecar observations.

Every ``policy="sweep"`` autotune run appends ``(features, tiling,
measured_us)`` rows to the sidecar (``$REPRO_TUNE_DATA``, default
``~/.cache/repro/autotune_data.json``).  This script turns that data back
into coefficients:

* ``--sweep`` first runs a representative sweep grid (three shapes per
  kernel spanning small/wide/tall problems, synthetic include banks for
  the schedule kernels spanning low/high sharing) so the sidecar has
  fresh same-machine rows to fit from.
* It then fits :class:`repro.kernels.cost_model.CostModel` per backend
  mode and prints a ``DEFAULT_COEFFS``-shaped dict.  Paste the output
  into ``kernels/cost_model.py`` to re-baseline the shipped defaults, or
  just leave the rows in the sidecar — ``get_model`` refits from them
  automatically on every process start.
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core import packetizer
from repro.kernels import autotune, cost_model, ops

# (B, C, W, K) dense-inference problems
DENSE_SHAPES = ((64, 128, 8, 4), (128, 256, 16, 8), (64, 512, 32, 10))
# (B, C, W, L, K) training problems (train sweeps are the slow ones);
# the kernel packs literals itself so W must equal ceil(L/32).  Shapes
# must be big enough that the candidate grid does NOT clip-collapse to
# one tiling, or the fit never reaches MIN_FIT_ROWS distinct rows.
TRAIN_SHAPES = ((256, 512, 16, 512, 8), (128, 384, 10, 320, 4))
# (B, K, U, Wa, density, groups): include-bank generators for the
# schedule kernels — `groups` rows sharing a base pattern controls
# partial-term sharing, so the grid spans the factorize decision boundary
SCHED_SHAPES = (
    (64, 4, 128, 8, 0.04, 128),    # low sharing: every row independent
    (128, 8, 256, 16, 0.02, 16),   # high sharing: 16 shared bases
    (64, 10, 384, 24, 0.08, 48),
)


def synth_include(U: int, Wa: int, density: float, groups: int,
                  seed: int = 0) -> np.ndarray:
    """Random packed include bank with tunable row-sharing structure."""
    rng = np.random.default_rng(seed)
    L = Wa * 32
    base = rng.random((groups, L)) < density * 0.6
    bits = np.empty((U, L), np.uint8)
    for r in range(U):
        bits[r] = base[r % groups] | (rng.random(L) < density * 0.4)
    return packetizer.pack_bits_np(bits)


def run_sweeps(interpret: bool, reps: int | None) -> None:
    for B, C, W, K in DENSE_SHAPES:
        autotune.tune("fused_infer", B=B, C=C, W=W, K=K,
                      interpret=interpret, policy="sweep",
                      reps=reps, refresh=True)
        print(f"swept fused_infer B{B} C{C} W{W} K{K}")
    for B, C, W, L, K in TRAIN_SHAPES:
        autotune.tune("fused_train", B=B, C=C, W=W, L=L, K=K,
                      interpret=interpret, policy="sweep",
                      reps=reps, refresh=True)
        print(f"swept fused_train B{B} C{C} W{W} L{L} K{K}")
    for i, (B, K, U, Wa, dens, groups) in enumerate(SCHED_SHAPES):
        iw = synth_include(U, Wa, dens, groups, seed=i)
        for kernel in ("sparse_infer", "term_infer"):
            autotune.tune(kernel, B=B, K=K, include_words=iw,
                          interpret=interpret, policy="sweep",
                          reps=reps, refresh=True)
            print(f"swept {kernel} B{B} U{U} W{Wa} dens{dens}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweep", action="store_true",
                    help="run the representative sweep grid first")
    ap.add_argument("--interpret", action="store_true", default=None,
                    help="force interpret mode (default: auto-dispatch)")
    ap.add_argument("--reps", type=int, default=None)
    args = ap.parse_args(argv)

    interpret = ops.kernel_dispatch(None, args.interpret)[1]
    if args.sweep:
        run_sweeps(interpret, args.reps)

    obs = cost_model.load_observations()
    mode = autotune._mode_backend(interpret)
    print(f"\n{len(obs)} sidecar rows at {cost_model.data_path()}; "
          f"fitting mode {mode!r}")
    fitted = cost_model.CostModel().fit(obs, mode)
    print("DEFAULT_COEFFS = " + json.dumps(
        {k: {n: round(v, 3) for n, v in theta.items()}
         for k, theta in fitted.coeffs.items()}, indent=4))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
