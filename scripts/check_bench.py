"""Bench regression gate: fresh bench_smoke output vs committed baselines.

    python scripts/check_bench.py \
        --pair BENCH_fused_infer.json:fresh_infer.json \
        --pair BENCH_fused_train.json:fresh_train.json \
        --pair BENCH_sparse_infer.json:fresh_sparse.json \
        [--factor 2.0]

For each baseline:fresh pair, compares the LEAD row (the first
``*_fused_*`` / ``*_sparse_*`` / ``*_mesh_*`` row — bench modules emit the
lead shape first) and exits non-zero when the fresh time exceeds
``factor`` x the committed baseline.  Serve-gateway reports
(``BENCH_serve.json``, ``benchmark == "serve_gateway"``) gate their lead
row on BOTH axes: fresh p99 latency above ``factor`` x baseline OR
achieved req/s below baseline / ``factor`` fails.  Online-update reports
(``BENCH_online.json``, ``benchmark == "online_update"``) apply the same
two-axis rule to the hot-swap pause (``swap_pause_p99_ms``) and the
steady-state ``req_per_s`` under online updating.  Anytime reports
(``BENCH_anytime.json``, ``benchmark == "anytime"``) gate two-axis as
well: the exact-early-exit row's latency against ``factor`` x baseline,
and every budgeted quality tier's accuracy against its committed
baseline minus an absolute tolerance (the accuracy-vs-latency frontier
must not silently collapse).  The committed ``BENCH_*.json`` files
are the cross-PR perf trajectory; this gate turns them from "diffable
artifact" into an enforced floor — a PR that makes the kernels >2x slower
in interpret mode fails CI instead of silently regressing the trajectory.

Comparisons are only meaningful between like runs: when backend or
interpret-mode metadata differs between baseline and fresh (e.g. a TPU
runner checking against a CPU-interpret baseline), the pair is reported as
``skipped`` and does not fail the gate.  Missing/unparseable fresh files DO
fail — a bench that crashed must not pass — and so does a committed
baseline that is unparseable or parses without a lead row (a broken
trajectory file must be refreshed, not silently exempted from the gate
forever).  Only a missing baseline FILE skips: that is the expected state
of a brand-new benchmark's first PR.

Known limitation: same-backend hardware skew (a CI runner class slower
than the machine that recorded the baseline) is indistinguishable from a
code regression here.  The default factor is deliberately generous (2x
catches "the fused path stopped being fused"-sized regressions, not noise)
and CI pins the runner class; if the runner class changes, refresh the
committed baselines in the same PR or raise ``--factor``.

No third-party deps (stdlib only) so the gate runs before pip installs
anything beyond the test stack.
"""

from __future__ import annotations

import argparse
import json
import sys


def lead_fused_row(report: dict) -> dict | None:
    """First fused / sparse-schedule / factorized / sharded-mesh row —
    bench modules emit the lead shape first, so this is the shape the
    gate tracks."""
    for row in report.get("rows", []):
        name = row.get("name", "")
        if ("_fused_" in name or "_mesh_" in name or "_sparse_" in name
                or "_factorized_" in name):
            return row
    return None


def lead_serve_row(report: dict) -> dict | None:
    """First serving-gateway row: carries BOTH ``p99_ms`` (latency) and
    ``req_per_s`` (throughput) — benchmarks/serve_gateway.py emits the
    open-loop Poisson shape first."""
    for row in report.get("rows", []):
        if "p99_ms" in row and "req_per_s" in row:
            return row
    return None


def _check_serve(baseline_path, fresh_path, base, fresh, factor) -> str:
    """Serve-gateway rule: p99 latency may not grow AND achieved
    throughput may not shrink by more than ``factor``."""
    b_row = lead_serve_row(base)
    f_row = lead_serve_row(fresh)
    if b_row is None:
        raise RegressionError(
            f"{baseline_path}: committed serve baseline has no "
            "p99_ms/req_per_s lead row — refresh the BENCH file")
    if f_row is None:
        raise RegressionError(
            f"{fresh_path}: no serve row — the gateway bench did not run")
    b_p99, f_p99 = float(b_row["p99_ms"]), float(f_row["p99_ms"])
    b_rps, f_rps = float(b_row["req_per_s"]), float(f_row["req_per_s"])
    verdict = (f"lead {b_row['name']}: p99 {b_p99:.2f}->{f_p99:.2f} ms, "
               f"req/s {b_rps:.0f}->{f_rps:.0f}")
    if f_p99 > factor * b_p99:
        raise RegressionError(
            f"{verdict} — p99 exceeds the {factor:.1f}x regression gate")
    if b_rps > 0 and f_rps < b_rps / factor:
        raise RegressionError(
            f"{verdict} — throughput collapsed past the "
            f"{factor:.1f}x regression gate")
    return f"ok: {verdict}"


def lead_online_row(report: dict) -> dict | None:
    """First online-update row: carries BOTH ``swap_pause_p99_ms`` (the
    serving pause a hot-swap imposes) and ``req_per_s`` (steady-state
    throughput under online updating) — benchmarks/online_update.py emits
    the immediate-policy shape first."""
    for row in report.get("rows", []):
        if "swap_pause_p99_ms" in row and "req_per_s" in row:
            return row
    return None


def _check_online(baseline_path, fresh_path, base, fresh, factor) -> str:
    """Online-update rule (mirrors the serve-gateway rule): the hot-swap
    pause may not grow AND steady-state throughput may not shrink by more
    than ``factor``."""
    b_row = lead_online_row(base)
    f_row = lead_online_row(fresh)
    if b_row is None:
        raise RegressionError(
            f"{baseline_path}: committed online baseline has no "
            "swap_pause_p99_ms/req_per_s lead row — refresh the BENCH file")
    if f_row is None:
        raise RegressionError(
            f"{fresh_path}: no online row — the online bench did not run")
    b_pause = float(b_row["swap_pause_p99_ms"])
    f_pause = float(f_row["swap_pause_p99_ms"])
    b_rps, f_rps = float(b_row["req_per_s"]), float(f_row["req_per_s"])
    verdict = (f"lead {b_row['name']}: swap pause p99 "
               f"{b_pause:.2f}->{f_pause:.2f} ms, "
               f"req/s {b_rps:.0f}->{f_rps:.0f}")
    if b_pause > 0 and f_pause > factor * b_pause:
        raise RegressionError(
            f"{verdict} — swap pause exceeds the {factor:.1f}x "
            "regression gate")
    if b_rps > 0 and f_rps < b_rps / factor:
        raise RegressionError(
            f"{verdict} — throughput collapsed past the "
            f"{factor:.1f}x regression gate")
    return f"ok: {verdict}"


def lead_anytime_row(report: dict) -> dict | None:
    """The exact-early-exit row of an anytime report: the gated scalar is
    its ``us_per_call`` (argmax-identical answers, so latency is the whole
    story for the exact mode)."""
    for row in report.get("rows", []):
        if "exact_ee" in row.get("name", "") and "us_per_call" in row:
            return row
    return None


# absolute accuracy tolerance for the budgeted tiers: a quality level may
# not lose more than this vs its committed baseline (accuracy is already
# in [0, 1], so a relative factor would be meaningless near 1.0)
ANYTIME_ACC_TOL = 0.02


def _check_anytime(baseline_path, fresh_path, base, fresh, factor) -> str:
    """Anytime rule, two-axis: the exact-early-exit row's latency may not
    grow past ``factor`` x baseline, and EACH budgeted quality tier's
    accuracy may not drop more than ``ANYTIME_ACC_TOL`` below its
    committed baseline (the frontier must not silently collapse)."""
    b_row = lead_anytime_row(base)
    f_row = lead_anytime_row(fresh)
    if b_row is None:
        raise RegressionError(
            f"{baseline_path}: committed anytime baseline has no exact_ee "
            "row — refresh the BENCH file")
    if f_row is None:
        raise RegressionError(
            f"{fresh_path}: no exact_ee row — the anytime bench did not run")
    b_us, f_us = float(b_row["us_per_call"]), float(f_row["us_per_call"])
    verdict = f"lead {b_row['name']}: {b_us:.0f}us -> {f_us:.0f}us"
    if f_us > factor * b_us:
        raise RegressionError(
            f"{verdict} — exact early-exit latency exceeds the "
            f"{factor:.1f}x regression gate")
    base_acc = {r["name"]: float(r["accuracy"]) for r in base.get("rows", [])
                if int(r.get("level", 0)) > 0 and "accuracy" in r}
    fresh_acc = {r["name"]: float(r["accuracy"]) for r in fresh.get("rows", [])
                 if int(r.get("level", 0)) > 0 and "accuracy" in r}
    if not fresh_acc:
        raise RegressionError(
            f"{fresh_path}: no budgeted quality rows — the frontier is gone")
    drops = []
    for name, b_acc in base_acc.items():
        f_acc = fresh_acc.get(name)
        if f_acc is None:
            drops.append(f"{name}: row missing from fresh report")
        elif f_acc < b_acc - ANYTIME_ACC_TOL:
            drops.append(f"{name}: accuracy {b_acc:.4f} -> {f_acc:.4f}")
    if drops:
        raise RegressionError(
            f"{verdict}; quality-tier accuracy regressed past the "
            f"{ANYTIME_ACC_TOL} tolerance: " + "; ".join(drops))
    return (f"ok: {verdict}; {len(fresh_acc)} quality tiers within "
            f"{ANYTIME_ACC_TOL} of baseline accuracy")


def lead_predict_row(report: dict) -> dict | None:
    """First predict-policy row of an autotune_cost report — carries
    ``regret`` (vs the full swept optimum) and ``timing_runs``."""
    for row in report.get("rows", []):
        if "predict" in row.get("name", "") and "regret" in row:
            return row
    return None


# predict-policy regret ceiling: the cold-start tiling must be within 10%
# of the full-sweep optimum (the PR's acceptance bar), with zero timing
# runs.  Absolute, not baseline-relative — regret is already a ratio.
_PREDICT_REGRET_MAX = 0.10


def _check_autotune(baseline_path, fresh_path, base, fresh) -> str:
    """Autotune-cost rule: fresh predict regret over the absolute ceiling
    fails, as does a 'predict' row that spent timing runs (the zero-run
    promise is the whole point of the policy)."""
    if lead_predict_row(base) is None:
        raise RegressionError(
            f"{baseline_path}: committed autotune baseline has no "
            "predict row with regret — refresh the BENCH file")
    f_row = lead_predict_row(fresh)
    if f_row is None:
        raise RegressionError(
            f"{fresh_path}: no predict row — the autotune bench did not run")
    regret = float(f_row["regret"])
    runs = int(f_row.get("timing_runs", -1))
    verdict = (f"lead {f_row['name']}: regret {regret:.3f}, "
               f"timing_runs {runs}")
    if runs != 0:
        raise RegressionError(
            f"{verdict} — predict policy must not issue timing runs")
    if regret > _PREDICT_REGRET_MAX:
        raise RegressionError(
            f"{verdict} — exceeds the {_PREDICT_REGRET_MAX:.0%} "
            "cold-start regret ceiling")
    return f"ok: {verdict}"


def check_pair(baseline_path: str, fresh_path: str, factor: float) -> str:
    """Returns 'ok' | 'skipped: ...' | raises RegressionError."""
    try:
        with open(baseline_path) as f:
            base = json.load(f)
    except FileNotFoundError as e:
        # a brand-new benchmark's first PR has no committed baseline yet
        return f"skipped: no baseline ({e})"
    except (OSError, ValueError) as e:
        # a baseline that EXISTS but cannot be read or parsed (permissions,
        # truncation, merge conflict markers) must fail like a missing lead
        # row — otherwise the gate is silently bypassed on every future PR
        raise RegressionError(
            f"committed baseline {baseline_path!r} unreadable: {e}")
    try:
        with open(fresh_path) as f:
            fresh = json.load(f)
    except (OSError, ValueError) as e:
        raise RegressionError(f"fresh report {fresh_path!r} unreadable: {e}")

    for key in ("backend", "interpret_mode"):
        if base.get(key) != fresh.get(key):
            return (f"skipped: {key} mismatch "
                    f"(baseline {base.get(key)!r} vs fresh {fresh.get(key)!r})")

    if base.get("benchmark") == "serve_gateway":
        return _check_serve(baseline_path, fresh_path, base, fresh, factor)

    if base.get("benchmark") == "online_update":
        return _check_online(baseline_path, fresh_path, base, fresh, factor)

    if base.get("benchmark") == "autotune_cost":
        return _check_autotune(baseline_path, fresh_path, base, fresh)

    if base.get("benchmark") == "anytime":
        return _check_anytime(baseline_path, fresh_path, base, fresh, factor)

    b_row = lead_fused_row(base)
    f_row = lead_fused_row(fresh)
    if b_row is None:
        # a COMMITTED baseline with no lead row is a broken trajectory
        # file (e.g. a bench refactor dropped the fused rows) — fail
        # loudly instead of silently skipping the gate forever
        raise RegressionError(
            f"{baseline_path}: committed baseline has no lead "
            "fused/sparse/mesh row — refresh the BENCH file")
    if f_row is None:
        raise RegressionError(
            f"{fresh_path}: no fused row — the fused bench did not run")
    b_us, f_us = float(b_row["us_per_call"]), float(f_row["us_per_call"])
    ratio = f_us / b_us if b_us > 0 else float("inf")
    verdict = (f"lead {b_row['name']}: baseline {b_us:.0f}us, "
               f"fresh {f_us:.0f}us ({ratio:.2f}x)")
    if f_us > factor * b_us:
        raise RegressionError(
            f"{verdict} — exceeds the {factor:.1f}x regression gate")
    return f"ok: {verdict}"


class RegressionError(Exception):
    pass


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pair", action="append", required=True,
                    metavar="BASELINE:FRESH",
                    help="baseline json : fresh json (repeatable)")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="fail when fresh > factor x baseline (default 2.0; "
                         "generous because CI containers are noisy)")
    args = ap.parse_args(argv)

    failures = 0
    for pair in args.pair:
        baseline_path, _, fresh_path = pair.partition(":")
        if not fresh_path:
            print(f"FAIL {pair}: expected BASELINE:FRESH")
            failures += 1
            continue
        try:
            msg = check_pair(baseline_path, fresh_path, args.factor)
            print(f"{'SKIP' if msg.startswith('skipped') else 'PASS'} "
                  f"{baseline_path}: {msg}")
        except RegressionError as e:
            print(f"FAIL {baseline_path}: {e}")
            failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
