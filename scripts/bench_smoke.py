"""Fast fused-vs-unfused inference microbenchmark -> BENCH_fused_infer.json.

    PYTHONPATH=src python scripts/bench_smoke.py [--full] [--reps N] [--no-autotune]

A CI-sized smoke of the fused single-pass TM inference kernel
(src/repro/kernels/fused_infer.py) against the legacy two-kernel pipeline
and the jnp oracle on identical shapes.  Appends nothing: each run rewrites
``BENCH_fused_infer.json`` with fresh numbers + backend metadata, so the
perf trajectory of the fused kernel is a per-PR diffable artifact.

The fused row runs at the block tiling chosen by the autotuner's cached
sweep (kernels/autotune.py); ``--no-autotune`` pins the kernel defaults.
"""

from __future__ import annotations

import argparse
import os
import sys

# runnable as `python scripts/bench_smoke.py` — put the repo root (the
# `benchmarks` package) on the path alongside PYTHONPATH=src
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="run every benchmark shape, not just the smoke one")
    ap.add_argument("--reps", type=int, default=8)
    ap.add_argument("--out", default="BENCH_fused_infer.json")
    ap.add_argument("--no-autotune", action="store_true",
                    help="use default fused block sizes instead of the "
                         "cached autotuner sweep")
    args = ap.parse_args()

    from benchmarks import fused_infer

    rows = fused_infer.run(fast=not args.full, reps=args.reps,
                           autotune=not args.no_autotune)
    fused_infer.write_report(rows, args.out)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
