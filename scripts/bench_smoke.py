"""Fast fused-kernel microbenchmarks -> BENCH_fused_infer.json +
BENCH_fused_train.json + BENCH_sparse_infer.json + BENCH_term_infer.json.

    PYTHONPATH=src python scripts/bench_smoke.py [--full] [--reps N]
        [--no-autotune] [--only {infer,train,sparse,term}]

A CI-sized smoke of the fused single-pass TM kernels against their legacy
pipelines and the jnp oracles on identical shapes:

  * inference (src/repro/kernels/fused_infer.py) vs the two-kernel
    clause_eval -> class_sum pipeline -> ``BENCH_fused_infer.json``
  * training (src/repro/kernels/fused_train.py: clause fire -> feedback ->
    TA delta in one pallas_call) vs the three-dispatch pipeline ->
    ``BENCH_fused_train.json``
  * block-sparse compiled-schedule inference on a TRAINED artifact
    (src/repro/kernels/sparse_infer.py) vs the dense fused kernel vs the
    uncompiled bank -> ``BENCH_sparse_infer.json``
  * shared-term FACTORIZED inference on a trained thermometer artifact
    (src/repro/kernels/term_infer.py: unique AND terms evaluated once)
    vs the flat sparse schedule vs the dense kernel, plus a synthetic
    sharing sweep -> ``BENCH_term_infer.json``

Appends nothing: each run rewrites the report files with fresh numbers +
backend metadata, so the perf trajectory of the fused kernels is a per-PR
diffable artifact.

The fused rows run at the block tilings chosen by the autotuner's cached
sweeps (kernels/autotune.py); ``--no-autotune`` pins the kernel defaults.
"""

from __future__ import annotations

import argparse
import os
import sys

# runnable as `python scripts/bench_smoke.py` — put the repo root (the
# `benchmarks` package) on the path alongside PYTHONPATH=src
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="run every benchmark shape, not just the smoke one")
    ap.add_argument("--reps", type=int, default=8)
    ap.add_argument("--train-reps", type=int, default=3,
                    help="rounds for the (heavier) training benchmark")
    ap.add_argument("--out", default="BENCH_fused_infer.json")
    ap.add_argument("--out-train", default="BENCH_fused_train.json")
    ap.add_argument("--out-sparse", default="BENCH_sparse_infer.json")
    ap.add_argument("--out-term", default="BENCH_term_infer.json")
    ap.add_argument("--no-autotune", action="store_true",
                    help="use default fused block sizes instead of the "
                         "cached autotuner sweep")
    ap.add_argument("--only", choices=("infer", "train", "sparse", "term"),
                    default=None,
                    help="run just one of the four benchmarks")
    args = ap.parse_args()

    from benchmarks import fused_infer, fused_train, sparse_infer, term_infer

    rows = []
    if args.only in (None, "infer"):
        infer_rows = fused_infer.run(fast=not args.full, reps=args.reps,
                                     autotune=not args.no_autotune)
        fused_infer.write_report(infer_rows, args.out)
        rows += infer_rows
    if args.only in (None, "train"):
        train_rows = fused_train.run(fast=not args.full, reps=args.train_reps,
                                     autotune=not args.no_autotune)
        fused_train.write_report(train_rows, args.out_train)
        rows += train_rows
    if args.only in (None, "sparse"):
        sparse_rows = sparse_infer.run(fast=not args.full, reps=args.reps,
                                       autotune=not args.no_autotune)
        sparse_infer.write_report(sparse_rows, args.out_sparse)
        rows += sparse_rows
    if args.only in (None, "term"):
        term_rows = term_infer.run(fast=not args.full, reps=args.reps,
                                   autotune=not args.no_autotune)
        term_infer.write_report(term_rows, args.out_term)
        rows += term_rows

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    if args.only in (None, "infer"):
        print(f"wrote {args.out}")
    if args.only in (None, "train"):
        print(f"wrote {args.out_train}")
    if args.only in (None, "sparse"):
        print(f"wrote {args.out_sparse}")
    if args.only in (None, "term"):
        print(f"wrote {args.out_term}")


if __name__ == "__main__":
    main()
