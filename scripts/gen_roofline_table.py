"""Emit the EXPERIMENTS.md roofline table from dryrun_results.jsonl."""

import json
import sys


def main(path="dryrun_results.jsonl"):
    cells = {}
    for line in open(path):
        r = json.loads(line)
        cells[(r["arch"], r["shape"], r["mesh"])] = r

    print("| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s) | "
          "bottleneck | useful | temp GB | args GB |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for (arch, shape, mesh), r in sorted(cells.items()):
        if r["status"] == "skip":
            reason = "long_500k: full-attn skip" if "full-attention" in r.get("skipped", "") \
                else "dp layout: >10B skip"
            print(f"| {arch} | {shape} | {mesh} | — | — | — | *{reason}* | | | |")
            continue
        if r["status"] != "ok":
            print(f"| {arch} | {shape} | {mesh} | FAIL | | | | | | |")
            continue
        print(
            f"| {arch} | {shape} | {mesh} "
            f"| {r['t_comp']:.3g} | {r['t_mem']:.3g} | {r['t_coll']:.3g} "
            f"| **{r['bottleneck'][:4]}** | {r['useful_flops_ratio']:.2f} "
            f"| {r['temp_bytes'] / 1e9:.1f} | {r['arg_bytes'] / 1e9:.2f} |"
        )


if __name__ == "__main__":
    main(*sys.argv[1:])
