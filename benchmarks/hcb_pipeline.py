"""Paper Fig. 7 analog: the HCB chain schedule and its latency model.

Fig. 7 shows the initiation interval: packets stream through HCBs; the class
sum waits for the last partial clause; subsequent datapoints pipeline at the
packet rate.  Here the HCB chain is the word-axis grid of the clause_eval
kernel; this benchmark measures the partial-clause schedule empirically by
sweeping the word-block size (packets per HCB) and reports per-block cost —
the structural analog of the paper's packets-per-datapoint latency curve.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packetizer
from repro.kernels import ops


def run(B: int = 512, C: int = 512, W: int = 32) -> list:
    rng = np.random.default_rng(0)
    lit = jnp.asarray(rng.integers(0, 2**32, (B, W), dtype=np.uint32))
    inc_bits = (rng.random((C, W * 32)) < 0.03).astype(np.uint8)
    inc = jnp.asarray(packetizer.pack_bits_np(inc_bits))

    rows = []
    for block_w in (1, 4, 16, 32):
        fn = jax.jit(lambda l, i: ops.clause_fire(
            l, i, use_kernel=True, interpret=True, block_w=block_w))
        fn(lit, inc).block_until_ready()
        t0 = time.perf_counter()
        out = fn(lit, inc)
        out.block_until_ready()
        dt = time.perf_counter() - t0
        n_hcbs = (W + block_w - 1) // block_w
        rows.append((
            f"fig7_hcb_blockw{block_w}",
            dt * 1e6,
            f"hcb_stages={n_hcbs};packets_per_stage={block_w};"
            f"us_per_datapoint={dt / B * 1e6:.3f}",
        ))
    return rows
