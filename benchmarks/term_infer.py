"""Shared-term factorized inference benchmark (perf trajectory tracker).

MATADOR's Fig. 5 logic absorption collapses the AND terms that overlapping
clauses share to a single gate; ``CompileStats.partial_term_sharing``
measures that opportunity and the PR-5 factorized schedule
(``kernels/term_infer.py``) exploits it: each unique (word,
include-pattern) term is evaluated ONCE per sample slab, clauses chain
term ids.  This benchmark times the same compiled artifact through three
engines on the same request stream:

  * ``factorized`` — the two-stage term-table kernel [the lead row]
  * ``sparse``     — kernels/sparse_infer.py: the flat bit-chain schedule
    (PR 4; the kernel the factorized path must beat)
  * ``dense``      — kernels/fused_infer.py at the autotuner's best dense
    tiling (streams every literal word per clause block)

The lead artifact is TRAINED at the repo's edge-XL lead shape — B=512
requests x C=4096 clauses over 4096 boolean features (W=256 literal
words) — on word-aligned 32-level THERMOMETER features (the paper's
booleanization: 128 continuous features x 32 unary levels = one packed
word per feature), so converged clauses hold multi-bit threshold runs and
the deduped bank's term sharing clears the factorized-serving threshold.
Requests are IN-DISTRIBUTION (drawn from the training generator, fresh
seed): a serving bucket fires real clauses, so neither kernel rides its
dead-slab early-exit the way an all-random stream would let it.

A synthetic sharing SWEEP rides along: fixed-shape clause banks whose
(word, value) terms are drawn from pools of decreasing size, so the
sharing fraction rises while total chain work stays constant — the
factorized speedup must GROW along these rows (the sparse kernel's time
is flat by construction).

Engines are timed in isolated per-engine loops (``_time_isolated`` —
see benchmarks/sparse_infer.py for why rotation misleads here) and
written to ``BENCH_term_infer.json`` by ``write_report`` — the cross-PR
perf trajectory file gated by scripts/check_bench.py.  On this CPU
container the kernels run in Pallas interpret mode; the factorized-vs-
sparse ratio is the tracked quantity.
"""

from __future__ import annotations

import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.sparse_infer import _time_isolated
from repro.core import compiler, packetizer, tm
from repro.data.booleanize import thermometer_encode
from repro.kernels import autotune as _autotune
from repro.kernels import ops, sparse_infer, term_infer

# lead shape: B x (n_cont x therm_bits) features, K classes, cpc
# clauses/class -> C=4096 clauses over F=4096 booleans (W=256 words)
LEAD = dict(B=512, n_cont=128, therm_bits=32, K=8, cpc=512)
# converged-model regime: enough steps at a high threshold that clauses
# fill in their thermometer runs (young models are 1-bit-per-word and
# under-represent a deployed artifact's sharing)
_TRAIN_SAMPLES = 2048
_TRAIN_EPOCHS = 7
_TRAIN_BATCH = 64
_NOISE = 0.15

# sharing sweep: same bank shape, term pool shrinks -> sharing rises
_SWEEP_U = 2048
_SWEEP_WORDS = 128           # active words per clause
_SWEEP_PC = 3                # bits per synthetic term
_SWEEP_SHARES = (0.0, 0.5, 0.9)


def _thermo_batch(n, *, seed, protos):
    rng = np.random.default_rng(seed)
    K, n_cont = protos.shape
    y = rng.integers(0, K, n).astype(np.int32)
    Xc = protos[y] * 1.0 + rng.normal(size=(n, n_cont)) * _NOISE
    return thermometer_encode(Xc, LEAD["therm_bits"]), y


def _train_artifact(seed: int = 0):
    """Train a TM on word-aligned thermometer features (matmul engine) and
    compile it; returns (cfg, protos, compiled)."""
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(LEAD["K"], LEAD["n_cont"]))
    X, y = _thermo_batch(_TRAIN_SAMPLES, seed=seed + 1, protos=protos)
    cfg = tm.TMConfig(n_features=X.shape[1], n_classes=LEAD["K"],
                      clauses_per_class=LEAD["cpc"], threshold=200, s=30.0)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    ta = tm.init(cfg, jax.random.PRNGKey(seed)).ta_state
    step = jax.jit(
        lambda t_, x, yy, s: ops.tm_train_step_matmul(cfg, t_, x, yy, s)[0]
    )
    k = 0
    n_batches = _TRAIN_SAMPLES // _TRAIN_BATCH
    for _ in range(_TRAIN_EPOCHS):
        for i in range(n_batches):
            sl = slice(i * _TRAIN_BATCH, (i + 1) * _TRAIN_BATCH)
            ta = step(ta, Xj[sl], yj[sl], jnp.uint32(k))
            k += 1
    ta.block_until_ready()
    return cfg, protos, compiler.compile_tm(cfg, ta)


def _synthetic_bank(share: float, *, Wa: int = 128, seed: int = 0):
    """(include_words, votes) with a CONTROLLED term-sharing fraction.

    Every clause activates ``_SWEEP_WORDS`` distinct words; each active
    word's include pattern is drawn from a per-word pool of
    ``_SWEEP_PC``-bit values sized so that
    ``1 - n_unique_terms / n_refs ~= share``.  Chain length, word count,
    and bit count per clause are identical across the sweep — only the
    sharing changes, so the sparse kernel's work is flat and any
    factorized trend is attributable to sharing alone.
    """
    rng = np.random.default_rng(seed)
    U = _SWEEP_U
    iw = np.zeros((U, Wa), np.uint32)
    # column-major assignment so every word serves refs_w = U*W/Wa refs:
    # the first u_w refs get DISTINCT values (u_w = refs_w * (1-share)),
    # the rest reuse them — realized sharing hits the target exactly
    # instead of depending on pool-collision luck
    refs_of_word = [[] for _ in range(Wa)]
    for c in range(U):
        for w in rng.choice(Wa, _SWEEP_WORDS, replace=False):
            refs_of_word[w].append(c)
    for w in range(Wa):
        refs = refs_of_word[w]
        u_w = max(1, round(len(refs) * (1.0 - share)))
        vals = set()
        while len(vals) < u_w:
            bits = rng.choice(32, _SWEEP_PC, replace=False)
            vals.add(int(sum(1 << b for b in bits)))
        vals = np.array(sorted(vals), np.uint32)
        for i, c in enumerate(refs):
            iw[c, w] = vals[i] if i < u_w else vals[rng.integers(u_w)]
    votes = rng.integers(-2, 3, (U, 8), dtype=np.int32)
    return iw, votes


def _biased_literals(B: int, Wa: int, *, p: float = 0.95, seed: int = 1):
    """Packed literal words with high bit density, so synthetic chains
    survive several tiles (an all-random stream kills every clause in the
    first tile and both kernels just ride their early-exits)."""
    rng = np.random.default_rng(seed)
    bits = (rng.random((B, Wa * 32)) < p).astype(np.uint8)
    return jnp.asarray(packetizer.pack_bits_np(bits))


def run(fast: bool = True, reps: int = 5, autotune: bool = True) -> list:
    _, interpret = ops.kernel_dispatch(True, None)
    rows = []

    # -- lead row: the trained thermometer artifact --------------------
    cfg, protos, comp = _train_artifact()
    Xr, _ = _thermo_batch(LEAD["B"], seed=777, protos=protos)
    lit = jnp.asarray(packetizer.pack_literals(jnp.asarray(Xr)))
    # both schedule kernels are tuned ON THE MEASURED STREAM (word-
    # compacted, as run_compiled serves it): a uniform-random sweep lets
    # trained chains die in their first tile and crowns tilings that lose
    # on live traffic — best-vs-best on the same bucket keeps the
    # comparison honest
    lit_rep = np.asarray(lit[:, comp.word_ids])

    fblocks = (
        _autotune.autotune_term_infer_blocks(
            LEAD["B"], comp.n_classes, comp.include_words,
            interpret=interpret, lit_words=lit_rep)
        if autotune else {}
    )
    sblocks = (
        _autotune.autotune_sparse_infer_blocks(
            LEAD["B"], comp.n_classes, comp.include_words,
            interpret=interpret, lit_words=lit_rep)
        if autotune else {}
    )
    dblocks = (
        _autotune.autotune_fused_blocks(
            LEAD["B"], comp.n_unique, comp.n_words_active, comp.n_classes,
            interpret=interpret)
        if autotune else {}
    )

    def compiled_fwd(engine, **blk):
        jitted = jax.jit(lambda l: compiler.run_compiled(
            comp, l, engine=engine, interpret=interpret, **blk,
        ))
        return lambda: jitted(lit)

    t = _time_isolated(
        dict(
            factorized=compiled_fwd("factorized", **fblocks),
            sparse=compiled_fwd("sparse", **sblocks),
            dense=compiled_fwd("dense", **dblocks),
        ),
        reps,
    )
    fsched = comp.factorized_schedule(
        fblocks.get("block_c"), fblocks.get("block_j"),
        fblocks.get("block_t"), fblocks.get("term_w"))
    W = comp.stats.n_words_dense
    tag = f"b{LEAD['B']}_c{cfg.n_clauses_total}_w{W}_k{comp.n_classes}"
    fblk = ";".join(f"{k}={v}" for k, v in sorted(fblocks.items()))
    rows.append((
        f"terminfer_factorized_{tag}", t["factorized"] * 1e6,
        f"speedup_vs_sparse={t['sparse'] / t['factorized']:.2f}x;"
        f"partial_term_sharing={comp.stats.partial_term_sharing:.4f};"
        f"realized_term_sharing={fsched.realized_term_sharing:.4f};"
        f"n_terms={fsched.n_terms};n_term_refs={fsched.n_term_refs}"
        + (f";{fblk}" if fblk else ""),
    ))
    rows.append((
        f"terminfer_sparse_{tag}", t["sparse"] * 1e6,
        "flat_bit_chain_schedule;" + ";".join(
            f"{k}={v}" for k, v in sorted(sblocks.items())),
    ))
    rows.append((
        f"terminfer_dense_{tag}", t["dense"] * 1e6,
        f"compiled_dense_fused;speedup_factorized="
        f"{t['dense'] / t['factorized']:.2f}x",
    ))

    # -- sharing sweep: speedup must GROW with the sharing fraction ----
    # tilings are PINNED across the sweep (and term_w pinned above the
    # synthetic popcount so no term splits): every row runs identical
    # chain work through identical grids, so the trend is attributable to
    # the sharing fraction alone — sparse time is flat by construction.
    # fast (CI) mode keeps only the gated lead rows above; the committed
    # BENCH file's sweep rows come from a full run.
    for share in () if fast else _SWEEP_SHARES:
        iw, votes = _synthetic_bank(share)
        slit = _biased_literals(LEAD["B"], iw.shape[1])
        vts = jnp.asarray(votes)
        fs = term_infer.build_factorized_schedule_cached(
            iw, block_c=1024, block_j=128, block_t=32768, term_w=4)
        ss = sparse_infer.build_schedule_cached(
            iw, block_c=2048, block_j=128)

        def fact_fwd():
            jitted = jax.jit(lambda l: term_infer.factorized_tm_forward(
                l, vts, fs, block_s=16, interpret=interpret))
            return lambda: jitted(slit)

        def sparse_fwd():
            jitted = jax.jit(lambda l: sparse_infer.sparse_tm_forward(
                l, vts, ss, block_s=16, interpret=interpret))
            return lambda: jitted(slit)

        ts = _time_isolated(dict(factorized=fact_fwd(),
                                 sparse=sparse_fwd()), reps)
        rows.append((
            f"terminfer_sweep_share{int(share * 100):02d}",
            ts["factorized"] * 1e6,
            f"speedup_vs_sparse={ts['sparse'] / ts['factorized']:.2f}x;"
            f"realized_term_sharing={fs.realized_term_sharing:.4f};"
            f"n_terms={fs.n_terms};sparse_us={ts['sparse'] * 1e6:.0f}",
        ))
    return rows


def write_report(rows: list, path: str = "BENCH_term_infer.json") -> None:
    _, interpret = ops.kernel_dispatch(True, None)
    report = dict(
        benchmark="term_infer",
        backend=jax.default_backend(),
        interpret_mode=bool(interpret),
        jax_version=jax.__version__,
        platform=platform.platform(),
        autotune_cache=_autotune.cache_path(),
        rows=[dict(name=n, us_per_call=us, derived=d) for n, us, d in rows],
    )
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
