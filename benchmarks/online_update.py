"""Online-learning service benchmark -> BENCH_online.json.

    PYTHONPATH=src python -m benchmarks.online_update [--out BENCH_online.json]

Drives the full online-update loop of ``runtime/online.py`` under live
gateway load: labeled feedback streams into a live automata bank beside
the serving artifact, include-bit drift arms incremental recompiles, and
promotions hot-swap the zoo entry atomically while an open-loop Poisson
request stream keeps arriving.  Reported per row:

  * ``req_per_s``      — steady-state answered throughput UNDER online
                         updating (training, drift checks, rebuilds, and
                         swaps all share the machine with serving).
  * ``swap_pause_p99_ms`` [the gated scalar, also ``us_per_call``] — p99
                         wall-time of the first bucket served after each
                         promotion: the pause a hot-swap actually imposes
                         on the request stream (rebound engines re-trace
                         here).  The zero-drop invariant is asserted, so
                         this pause is a LATENCY cost, never a loss.
  * ``p99_ms``         — end-to-end request p99 across the whole run.
  * ``drift_to_promotion_ms`` (derived) — p50 latency from the drift
                         threshold crossing to the committed swap.

The lead ``online_steady_*`` row runs ``swap_policy="immediate"`` (every
rebuild promotes — the swap machinery is exercised maximally); the second
row runs the shadow-canary pipeline with a mirrored-bucket agreement
verdict before each swap.  scripts/check_bench.py gates the lead row on
BOTH ``swap_pause_p99_ms`` and ``req_per_s`` (pause regression or
throughput collapse >2x fails), mirroring the serve-gateway rule.
"""

from __future__ import annotations

import argparse
import asyncio
import itertools
import json
import platform
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.matador_tm import TM_CONFIGS
from repro.core import compiler, packetizer, tm, train
from repro.data import make_boolean_classification
from repro.kernels import ops
from repro.runtime.gateway import Gateway
from repro.runtime.online import OnlineConfig, OnlineUpdater
from repro.runtime.zoo import ArtifactZoo

TENANT = "t0"
BUCKET = 64


def _build(arch: str = "tm-tiny"):
    config = TM_CONFIGS[arch]
    X, y = make_boolean_classification(
        512, config.n_features, config.n_classes, seed=0)
    state = tm.init(config, jax.random.PRNGKey(0))
    state = train.fit(config, state, jnp.asarray(X), jnp.asarray(y),
                      epochs=1, batch_size=64, rng=jax.random.PRNGKey(1))
    return config, state, compiler.compile_tm(config, state.ta_state), X, y


async def _open_loop(gw, xp, rate: float, n: int, futs: list) -> None:
    rng = np.random.default_rng(0)
    gaps = rng.exponential(1.0 / rate, size=n)
    t_next = time.perf_counter()
    for j in range(n):
        t_next += gaps[j]
        delay = t_next - time.perf_counter()
        if delay > 0:
            await asyncio.sleep(delay)
        futs.append(gw.offer(TENANT, xp[j % len(xp)]))


def _percentile(xs, q):
    if not xs:
        return 0.0
    return float(np.percentile(np.asarray(xs, np.float64), q))


def run_policy(policy: str, *, rate: float, n: int,
               drift_threshold: float = 0.02,
               canary_agreement: float = 0.75) -> dict:
    """One full online-serving run under ``swap_policy=policy``.

    The canary row lowers the agreement bar: the 1-epoch bench bank still
    moves fast, and the row measures the canary PIPELINE cost, not the
    verdict policy (a production bar belongs in serve.py's flags).
    """
    # feedback pool = the TRAINING distribution (continued learning of the
    # same task): the bank keeps refining, so drift crosses and candidates
    # stay canary-agreeable — a distribution SHIFT canary-failure drill
    # lives in tests/test_online.py, not in a gated throughput number
    config, state, compiled, Xf, yf = _build()
    xp = np.asarray(packetizer.pack_literals(jnp.asarray(Xf)))
    W = xp.shape[1]

    current = {"compiled": compiled}
    swap_pauses: list = []
    post_swap = threading.Event()     # armed by on_promote, consumed by
    counter = itertools.count()       # the next bucket's wall-time record

    def build_engine(name):
        art = current["compiled"]
        if name == "dense":
            return jax.jit(lambda xw: compiler.run_compiled(
                art, xw, engine="dense", interpret=True).argmax(-1))
        return jax.jit(lambda xw: compiler.run_compiled(
            art, xw, engine="oracle").argmax(-1))

    levels = ["dense", "oracle"]
    ladder = ops.EngineLadder(
        [(nm, (lambda n2=nm: build_engine(n2))) for nm in levels])
    ladder.run(lambda: jnp.zeros((BUCKET, W), jnp.uint32),
               bucket="warm", count=False)

    def run_rows(rows):
        i = next(counter)
        t_b = time.perf_counter()
        padded = np.zeros((BUCKET, W), np.uint32)
        padded[:len(rows)] = rows
        out = ladder.run(lambda: jnp.asarray(padded), bucket=i)
        preds = np.asarray(out)[:len(rows)]
        if post_swap.is_set():
            post_swap.clear()
            swap_pauses.append(time.perf_counter() - t_b)
        return preds

    def _nbytes(c):
        return int(c.include_words.nbytes + c.word_ids.nbytes
                   + c.votes.nbytes)

    def make_obj(c):
        return {"compiled": c, "run": run_rows}, _nbytes(c)

    zoo = ArtifactZoo(lambda tenant: make_obj(current["compiled"]),
                      max_entries=1)
    runner = zoo.runner(lambda obj, rows: obj["run"](rows))

    def canary_serve(obj, rows):
        fn = obj.get("_canary_fn")
        if fn is None:
            c = obj["compiled"]
            fn = obj["_canary_fn"] = jax.jit(
                lambda xw: compiler.run_compiled(
                    c, xw, engine="oracle").argmax(-1))
        padded = np.zeros((BUCKET, W), np.uint32)
        padded[:len(rows)] = rows
        return np.asarray(fn(jnp.asarray(padded)))[:len(rows)]

    def on_promote(cand):
        current["compiled"] = cand
        ladder.rebind(
            [(nm, (lambda n2=nm: build_engine(n2))) for nm in levels])
        post_swap.set()

    upd = OnlineUpdater(
        config, state.ta_state, compiled,
        cfg=OnlineConfig(drift_threshold=drift_threshold,
                         swap_policy=policy, canary_frac=0.5, canary_min=2,
                         canary_agreement=canary_agreement),
        zoo=zoo, tenant=TENANT, make_obj=make_obj, serve_fn=canary_serve,
        deployed_obj={"compiled": compiled, "run": run_rows},
        deployed_nbytes=_nbytes(compiled), on_promote=on_promote)

    stop_online = threading.Event()

    def online_loop():
        feed = iter(range(n))
        while not stop_online.is_set():
            progressed = False
            for _ in range(upd.cfg.batch_size):
                j = next(feed, None)
                if j is None:
                    break
                upd.ingest(Xf[j % len(Xf)], int(yf[j % len(yf)]))
                progressed = True
            progressed = upd.step() or progressed
            if not progressed:
                time.sleep(0.001)

    async def go():
        gw = await Gateway(runner, bucket=BUCKET, max_wait=0.005,
                           mirror=upd.mirror).start()
        th = threading.Thread(target=online_loop, daemon=True)
        th.start()
        t0 = time.perf_counter()
        futs: list = []
        await _open_loop(gw, xp, rate, n, futs)
        health = await gw.drain()
        wall = time.perf_counter() - t0
        stop_online.set()
        th.join(timeout=10)
        await asyncio.gather(*futs)
        return health, wall

    health, wall = asyncio.run(go())
    oh = upd.health()
    assert health["unaccounted"] == 0, health
    assert oh["promotions"] >= 1, (
        f"online bench made no promotions (drift {oh['drift']:.3f}) — "
        "the swap-pause row would be vacuous", oh)
    pause_p99 = _percentile(swap_pauses, 99) * 1e3
    d2p_p50 = _percentile(oh["drift_to_promotion_ms"], 50)
    return dict(
        name=f"online_steady_{policy}_r{int(rate)}_b{BUCKET}",
        us_per_call=pause_p99 * 1e3,
        swap_pause_p99_ms=pause_p99,
        p99_ms=health["latency_ms"]["p99"] or 0.0,
        req_per_s=health["answered"] / wall if wall > 0 else 0.0,
        derived=(f"promotions={oh['promotions']};"
                 f"incremental={oh['incremental_rebuilds']};"
                 f"full={oh['full_rebuilds']};"
                 f"canary_passes={oh['canary']['passes']};"
                 f"canary_failures={oh['canary']['failures']};"
                 f"drift_to_promotion_p50_ms={d2p_p50:.2f};"
                 f"swaps={zoo.health()['swaps']};"
                 f"answered={health['answered']};"
                 f"mirrored={health['mirrored']}"),
    )


def run(rate: float = 1200.0, n: int = 1200) -> list:
    rows = [run_policy("immediate", rate=rate, n=n)]
    rows.append(run_policy("canary", rate=rate, n=n))
    return rows


def write_report(rows: list, path: str = "BENCH_online.json") -> None:
    report = dict(
        benchmark="online_update",
        backend=jax.default_backend(),
        interpret_mode=True,           # the dense ladder level interprets
        jax_version=jax.__version__,
        platform=platform.platform(),
        rows=rows,
    )
    with open(path, "w") as f:
        json.dump(report, f, indent=1)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_online.json")
    ap.add_argument("--rate", type=float, default=1200.0,
                    help="open-loop Poisson offered rate (req/s)")
    ap.add_argument("--requests", type=int, default=1200)
    args = ap.parse_args()
    rows = run(rate=args.rate, n=args.requests)
    write_report(rows, args.out)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.3f},"
              f"swap_pause_p99_ms={r['swap_pause_p99_ms']:.2f};"
              f"req_per_s={r['req_per_s']:.0f};{r['derived']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
