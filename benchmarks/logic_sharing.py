"""Paper Fig. 8 analog: resource savings from logic sharing / compaction.

Fig. 8 compares LUT / Slice-Register counts with the optimizations on
("LUT-opt") vs DON'T-TOUCH pragmas ("LUT-dt").  Here the optimizations are
the compiler passes (clause dedup + dead-word elimination + chain-schedule
emission) and "resources" are the quantities that cost silicon time on TPU:
clause rows evaluated, literal words streamed, bytes moved per batch — and,
since the schedule landed, MEASURED inference time: each row times its
artifact through the kernel path on the same request stream (previously
``us_per_call`` was a 0.0 placeholder).

Rows per dataset:
  * ``fig8_opt_fact_*``   — compiled artifact, shared-term FACTORIZED
    schedule (each unique AND term evaluated once — the Fig. 5 logic
    absorption, realized); derived stats report the REALIZED term sharing
    (1 - terms evaluated / terms pre-factorization) next to the
    ``partial_term_sharing`` opportunity the compiler measured
  * ``fig8_opt_*``        — compiled artifact, block-sparse chain schedule
  * ``fig8_opt_dense_*``  — same artifact, dense fused kernel
  * ``fig8_dont_touch_*`` — DON'T-TOUCH artifact (no dedup / word elim /
    clustering), dense fused kernel — the unoptimized netlist analog
  * ``fig8_savings_*``    — us saved per call by the full compile pipeline
    (dont_touch minus opt), plus the clause/word reduction ratios
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.sparse_infer import _time_isolated
from repro.core import compiler, packetizer, tm, train
from repro.data import paper_dataset
from repro.kernels import ops

_BENCH_BATCH = 256
_REPS = 5


def run(dataset: str = "mnist") -> list:
    X, y, _, _ = paper_dataset(dataset, n_train=3000, n_test=8)
    cfg = tm.TMConfig(n_features=X.shape[1], n_classes=int(y.max()) + 1,
                      clauses_per_class=40, threshold=40, s=8.0)
    st = tm.init(cfg, jax.random.PRNGKey(0))
    st = train.fit(cfg, st, jnp.asarray(X), jnp.asarray(y), epochs=6,
                   batch_size=50, rng=jax.random.PRNGKey(1))

    opt = compiler.compile_tm(cfg, st.ta_state)                # "LUT-opt"
    dt = compiler.compile_tm(cfg, st.ta_state, dedup=False,
                             prune_words=False, cluster=False)

    _, interpret = ops.kernel_dispatch(True, None)
    rng = np.random.default_rng(2)
    lit = packetizer.pack_literals(jnp.asarray(
        rng.integers(0, 2, (_BENCH_BATCH, cfg.n_features), dtype=np.uint8)
    ))

    def fwd(artifact, engine):
        jitted = jax.jit(lambda l: compiler.run_compiled(
            artifact, l, engine=engine, interpret=interpret,
        ))
        return lambda: jitted(lit)

    t = _time_isolated(dict(
        opt_fact=fwd(opt, "factorized"),
        opt_sparse=fwd(opt, "sparse"),
        opt_dense=fwd(opt, "dense"),
        dont_touch=fwd(dt, "dense"),
    ), _REPS)

    def stats_str(c):
        sched = c.default_schedule
        fsched = c.default_factorized_schedule
        # realized term sharing: terms the factorized schedule actually
        # evaluates vs the per-clause term references a flat executor
        # pays — reported NEXT TO the compiler's opportunity stat
        return (
            f"clauses={c.n_unique};words={c.n_words_active};"
            f"model_bytes={c.include_words.nbytes};"
            f"sparsity={c.stats.include_sparsity:.4f};"
            f"clause_sharing={c.stats.clause_sharing:.4f};"
            f"partial_term_sharing={c.stats.partial_term_sharing:.4f};"
            f"realized_term_sharing={fsched.realized_term_sharing:.4f};"
            f"terms_evaluated={fsched.n_terms};"
            f"terms_prefactor={fsched.n_term_refs};"
            f"tile_sparsity={sched.tile_sparsity:.4f}"
        )

    rows = [
        (f"fig8_opt_fact_{dataset}", t["opt_fact"] * 1e6,
         stats_str(opt)
         + f";speedup_vs_sparse={t['opt_sparse'] / t['opt_fact']:.2f}x"
         + f";speedup_vs_dont_touch={t['dont_touch'] / t['opt_fact']:.2f}x"),
        (f"fig8_opt_{dataset}", t["opt_sparse"] * 1e6,
         stats_str(opt)
         + f";speedup_vs_dont_touch={t['dont_touch'] / t['opt_sparse']:.2f}x"),
        (f"fig8_opt_dense_{dataset}", t["opt_dense"] * 1e6, stats_str(opt)),
        (f"fig8_dont_touch_{dataset}", t["dont_touch"] * 1e6, stats_str(dt)),
    ]
    saved_clauses = 1 - opt.n_unique / max(dt.n_unique, 1)
    saved_words = 1 - opt.n_words_active / max(dt.n_words_active, 1)
    rows.append((
        f"fig8_savings_{dataset}",
        (t["dont_touch"] - t["opt_sparse"]) * 1e6,
        f"us_saved_per_call;clause_reduction={saved_clauses:.2%};"
        f"word_reduction={saved_words:.2%}",
    ))
    return rows
