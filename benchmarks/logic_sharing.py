"""Paper Fig. 8 analog: resource savings from logic sharing / compaction.

Fig. 8 compares LUT / Slice-Register counts with the optimizations on
("LUT-opt") vs DON'T-TOUCH pragmas ("LUT-dt").  Here the optimizations are
the compiler passes (clause dedup + dead-word elimination) and "resources"
are the quantities that cost silicon time on TPU: clause rows evaluated,
literal words streamed, and bytes moved per batch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import compiler, tm, train
from repro.data import paper_dataset


def run(dataset: str = "mnist") -> list:
    X, y, _, _ = paper_dataset(dataset, n_train=3000, n_test=8)
    cfg = tm.TMConfig(n_features=X.shape[1], n_classes=int(y.max()) + 1,
                      clauses_per_class=40, threshold=40, s=8.0)
    st = tm.init(cfg, jax.random.PRNGKey(0))
    st = train.fit(cfg, st, jnp.asarray(X), jnp.asarray(y), epochs=6,
                   batch_size=50, rng=jax.random.PRNGKey(1))

    opt = compiler.compile_tm(cfg, st.ta_state)                # "LUT-opt"
    dt = compiler.compile_tm(cfg, st.ta_state, dedup=False, prune_words=False)

    rows = []
    for name, c in (("opt", opt), ("dont_touch", dt)):
        bytes_batch = c.include_words.nbytes
        rows.append((
            f"fig8_{name}_{dataset}",
            0.0,
            f"clauses={c.n_unique};words={c.n_words_active};"
            f"model_bytes={bytes_batch};sparsity={c.stats.include_sparsity:.4f};"
            f"clause_sharing={c.stats.clause_sharing:.4f};"
            f"partial_term_sharing={c.stats.partial_term_sharing:.4f}",
        ))
    saved_clauses = 1 - opt.n_unique / max(dt.n_unique, 1)
    saved_words = 1 - opt.n_words_active / max(dt.n_words_active, 1)
    rows.append((
        f"fig8_savings_{dataset}",
        0.0,
        f"clause_reduction={saved_clauses:.2%};word_reduction={saved_words:.2%}",
    ))
    return rows
