"""Fused vs unfused TM *training-step* microbenchmark (perf trajectory).

Times three execution engines on identical problem shapes, mirroring
``benchmarks/fused_infer.py`` for the training hot loop:

  * ``fused``    — kernels/fused_train.py single-pass kernel (clause fire +
    feedback plan + TA delta in one ``pallas_call``, fed by one fused-
    inference pass for class sums; the (B, C) fire/ftype matrices never
    exist in HBM), at the block tiling picked by kernels/autotune.py's
    cached training-shape sweep
  * ``unfused``  — the legacy three-dispatch pipeline (clause_eval kernel,
    XLA feedback plan, ta_update kernel, fire and ftype materialized
    between them)
  * ``oracle``   — the pure-jnp XLA path (the off-TPU default engine),
    batch-chunked so its (chunk, C, L) random field stays bounded

All three engines are bit-identical on the delta (tests/test_fused_train
.py); only speed differs.  Engines are timed interleaved (alternating
calls, min over rounds) so container noise hits all rows equally.
``write_report`` persists the rows to ``BENCH_fused_train.json`` so the
fused training kernel's perf trajectory is tracked across PRs.  On this
CPU container the kernel paths run in Pallas interpret mode; the
fused-vs-unfused ratio is still meaningful (same interpreter, two launches
vs three + HBM intermediates + a dense hash field where the fused kernel
exploits feedback sparsity), and on TPU the HBM-traffic win is larger.
"""

from __future__ import annotations

import json
import platform

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.fused_infer import _time_interleaved
from repro.core import tm
from repro.kernels import autotune as _autotune
from repro.kernels import ops

# (B, n_features, n_classes, clauses_per_class): the lead shape is the
# 512 x 4096-clause training cell — where the (B, C) HBM intermediates and
# the dense (B, C, L) hash sweep of the unfused pipeline actually cost.
SHAPES = [
    (512, 128, 8, 512),    # C = 4096, L = 256, W = 8
    (256, 128, 8, 64),     # C = 512: small-bank regime
]

_ORACLE_CHUNK = 128   # bounds the oracle's (chunk, C, L) random field


def run(fast: bool = True, reps: int = 3, autotune: bool = True) -> list:
    _, interpret = ops.kernel_dispatch(True, None)
    rng = np.random.default_rng(0)
    rows = []
    for B, F, K, cpc in SHAPES[:1] if fast else SHAPES:
        cfg = tm.TMConfig(n_features=F, n_classes=K, clauses_per_class=cpc,
                          threshold=40, s=8.0)
        C, L = cfg.n_clauses_total, cfg.n_literals
        W = (L + 31) // 32
        ta = jnp.asarray(rng.integers(-64, 64, (C, L), dtype=np.int8))
        X = jnp.asarray(rng.integers(0, 2, (B, F), dtype=np.uint8))
        y = jnp.asarray(rng.integers(0, K, B, dtype=np.int32))
        seed = jnp.uint32(3)

        blocks = (
            _autotune.autotune_fused_train_blocks(
                B, C, W, L, K, interpret=interpret)
            if autotune else None
        )

        def step(**kwargs):
            # inputs stay jit arguments (not closure constants) so XLA
            # cannot constant-fold the timed computation away; the delta
            # output forces the whole pipeline.
            jitted = jax.jit(lambda t, x, yy, s: ops.tm_train_step_kernel(
                cfg, t, x, yy, s, **kwargs)[1])
            return lambda: jitted(ta, X, y, seed)

        t = _time_interleaved(
            dict(
                fused=step(use_kernel=True, interpret=interpret, fuse=True,
                           blocks=blocks),
                unfused=step(use_kernel=True, interpret=interpret,
                             fuse=False),
                oracle=step(use_kernel=False, batch_chunk=_ORACLE_CHUNK),
            ),
            reps,
        )
        tag = f"b{B}_c{C}_l{L}_k{K}"
        blk_str = ";".join(f"{k}={v}" for k, v in sorted((blocks or {}).items()))
        rows.append((f"fusedtrain_fused_{tag}", t["fused"] * 1e6,
                     f"speedup_vs_unfused={t['unfused'] / t['fused']:.2f}x"
                     + (f";{blk_str}" if blk_str else "")))
        rows.append((f"fusedtrain_unfused_{tag}", t["unfused"] * 1e6,
                     "three_dispatch_pipeline"))
        rows.append((f"fusedtrain_oracle_{tag}", t["oracle"] * 1e6,
                     f"pure_jnp_xla;batch_chunk={_ORACLE_CHUNK}"))
    return rows


def write_report(rows: list, path: str = "BENCH_fused_train.json") -> None:
    _, interpret = ops.kernel_dispatch(True, None)
    report = dict(
        benchmark="fused_train",
        backend=jax.default_backend(),
        interpret_mode=bool(interpret),
        jax_version=jax.__version__,
        platform=platform.platform(),
        autotune_cache=_autotune.cache_path(),
        rows=[dict(name=n, us_per_call=us, derived=d) for n, us, d in rows],
    )
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
