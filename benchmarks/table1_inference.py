"""Paper Table I analog: TM accelerator vs FINN-style BNN, like-for-like.

On the paper's FPGA the comparison is LUTs/BRAM/latency/throughput; on this
substrate the like-for-like quantities are inference latency (us/datapoint),
throughput (inf/s), accuracy on the same synthetic dataset, and the
"resource" analog — model bytes moved per inference (the streaming
bandwidth the MATADOR design is built around).

Emits ``name,us_per_call,derived`` CSV rows (benchmarks.run contract).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import bnn
from repro.core import compiler, packetizer, tm, train
from repro.data import paper_dataset


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(dataset: str = "mnist", n_eval: int = 2048) -> list:
    rows = []
    X, y, Xte, yte = paper_dataset(dataset, n_train=3000, n_test=n_eval)

    # --- MATADOR TM (200 clauses/class for MNIST per paper Table II scale) --
    cfg = tm.TMConfig(n_features=X.shape[1], n_classes=int(y.max()) + 1,
                      clauses_per_class=40, threshold=40, s=8.0)
    st = tm.init(cfg, jax.random.PRNGKey(0))
    st = train.fit(cfg, st, jnp.asarray(X), jnp.asarray(y), epochs=6,
                   batch_size=50, rng=jax.random.PRNGKey(1))
    comp = compiler.compile_tm(cfg, st.ta_state)
    xp = packetizer.pack_literals(jnp.asarray(Xte))
    run_tm = jax.jit(lambda xw: jnp.argmax(compiler.run_compiled(comp, xw), -1))
    dt = _time(run_tm, xp)
    acc = float((np.asarray(run_tm(xp)) == yte).mean())
    bytes_per_inf = comp.include_words.nbytes / n_eval + comp.n_words_active * 4
    rows.append((
        f"table1_tm_{dataset}",
        dt / n_eval * 1e6,
        f"acc={acc:.3f};inf_s={n_eval / dt:,.0f};words={comp.n_words_active};"
        f"unique_clauses={comp.n_unique};stream_bytes={bytes_per_inf:.0f}",
    ))

    # --- FINN-style BNN (784-256-256-256-10 topology, Table II) -------------
    bcfg = bnn.BNNConfig(
        layer_sizes=(X.shape[1], 256, 256, 256, int(y.max()) + 1), lr=5e-2
    )
    params = bnn.bnn_init(bcfg, jax.random.PRNGKey(0))
    params = bnn.bnn_train(bcfg, params, X, y, epochs=15, batch_size=50,
                           rng=jax.random.PRNGKey(1))
    packed = bnn.bnn_pack(params)
    run_bnn = jax.jit(lambda xb: bnn.bnn_predict(packed, xb))
    xte = jnp.asarray(Xte)
    dt_b = _time(run_bnn, xte)
    acc_b = float((np.asarray(run_bnn(xte)) == yte).mean())
    weight_bytes = sum(int(w.nbytes) for w, _ in packed)
    rows.append((
        f"table1_bnn_{dataset}",
        dt_b / n_eval * 1e6,
        f"acc={acc_b:.3f};inf_s={n_eval / dt_b:,.0f};weight_bytes={weight_bytes};"
        f"tm_speedup={dt_b / dt:.2f}x",
    ))
    return rows
