"""Clause-sharded fused TM step scaling benchmark -> BENCH_sharded.json.

    PYTHONPATH=src python -m benchmarks.sharded_step [--devices 4] [--reps 3]

Times the clause-sharded ``shard_map`` schedules of PR 3 (fused Pallas
pipeline per ``model`` shard, one int32 class-sum psum) against the
single-device fused step on the same problem, on an EMULATED host-device
mesh (``--xla_force_host_platform_device_count``, set before jax init —
this module must therefore be its own process; ``scripts/bench_smoke.py``
and ``benchmarks/run.py`` keep their single-device view and never import
it).

On CPU the kernels run in Pallas interpret mode, so absolute numbers are
not TPU throughput — the point of the file is the cross-PR trajectory of
(a) the sharded-vs-single overhead factor (collective + shard_map plumbing
cost on a fixed problem) and (b) that the schedule runs at all on every
jax bump.  On a real TPU runner the same flags produce compiled scaling
numbers.

Rows (``name,us_per_call,derived``):
  * shardedtrain_1dev_*   — single-device fused train step
  * shardedtrain_mesh_*   — model=N clause-sharded fused train step
  * shardedinfer_1dev_*   — single-device fused forward
  * shardedinfer_mesh_*   — model=N clause-sharded fused forward
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time


def _early_arg(flag: str, default: str) -> str:
    for i, a in enumerate(sys.argv):
        if a == flag and i + 1 < len(sys.argv):
            return sys.argv[i + 1]
        if a.startswith(flag + "="):
            return a.split("=", 1)[1]
    return default


_N_DEVICES = int(_early_arg("--devices", os.environ.get("REPRO_BENCH_DEVICES", "4")))
# MUST precede any jax import: device count locks on first init.
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + f" --xla_force_host_platform_device_count={_N_DEVICES}"
).strip()

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402

from repro.core import packetizer, sharding, tm   # noqa: E402
from repro.kernels import ops, ref                # noqa: E402

# (B, n_features, n_classes, clauses_per_class) — sized so the interpret-mode
# CI smoke stays ~a minute; --full adds the BENCH_fused_train lead shape.
SHAPES = [
    (256, 128, 8, 128),     # C = 1024, L = 256
]
FULL_SHAPES = SHAPES + [
    (512, 128, 8, 512),     # C = 4096: the fused-train lead shape
]


def _time(fn, reps: int) -> float:
    fn().block_until_ready()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def run(n_model: int, reps: int = 3, full: bool = False) -> list:
    interpret = jax.default_backend() != "tpu"
    rng = np.random.default_rng(0)
    rows = []
    mesh = jax.make_mesh((n_model,), ("model",))
    for B, F, K, cpc in (FULL_SHAPES if full else SHAPES):
        cfg = tm.TMConfig(n_features=F, n_classes=K, clauses_per_class=cpc,
                          threshold=40, s=8.0, clause_pad_multiple=n_model)
        C, L = cfg.n_clauses_total, cfg.n_literals
        ta = jnp.asarray(rng.integers(-64, 64, (C, L), dtype=np.int8))
        X = jnp.asarray(rng.integers(0, 2, (B, F), dtype=np.uint8))
        y = jnp.asarray(rng.integers(0, K, B, dtype=np.int32))
        seed = jnp.uint32(3)
        tag = f"b{B}_c{C}_l{L}_m{n_model}"

        one = jax.jit(lambda t, xx, yy, s: ops.tm_train_step_kernel(
            cfg, t, xx, yy, s, fuse=True, use_kernel=True,
            interpret=interpret)[0])
        step_sh = sharding.sharded_train_step_fn(
            cfg, mesh, engine="kernel", use_kernel=True, interpret=interpret)
        # equality gate: the bench refuses to record numbers for a schedule
        # that drifted off the oracle
        np.testing.assert_array_equal(
            np.asarray(one(ta, X, y, seed)),
            np.asarray(step_sh(ta, X, y, seed)))

        t1 = _time(lambda: one(ta, X, y, seed), reps)
        tm_ = _time(lambda: step_sh(ta, X, y, seed), reps)
        rows.append((f"shardedtrain_1dev_{tag}", t1 * 1e6,
                     f"samples_s={B / t1:,.0f}"))
        rows.append((f"shardedtrain_mesh_{tag}", tm_ * 1e6,
                     f"samples_s={B / tm_:,.0f};vs_1dev={t1 / tm_:.2f}x"))

        iw = packetizer.pack_include_masks(ta)
        votes = tm.vote_matrix(cfg)
        ne = jnp.any(ta >= 0, -1).astype(jnp.uint8)
        lw = packetizer.pack_bits(tm.literals(X))
        one_f = jax.jit(lambda l, i, v, n: ops.tm_forward_packed(
            l, i, v, n, use_kernel=True, interpret=interpret, fuse=True))
        fwd_sh = sharding.sharded_forward_fn(
            mesh, use_kernel=True, interpret=interpret)
        np.testing.assert_array_equal(
            np.asarray(one_f(lw, iw, votes, ne)),
            np.asarray(fwd_sh(iw, votes, ne, lw)))

        t1 = _time(lambda: one_f(lw, iw, votes, ne), reps)
        tm_ = _time(lambda: fwd_sh(iw, votes, ne, lw), reps)
        rows.append((f"shardedinfer_1dev_{tag}", t1 * 1e6,
                     f"inf_s={B / t1:,.0f}"))
        rows.append((f"shardedinfer_mesh_{tag}", tm_ * 1e6,
                     f"inf_s={B / tm_:,.0f};vs_1dev={t1 / tm_:.2f}x"))
    return rows


def write_report(rows: list, n_model: int,
                 path: str = "BENCH_sharded.json") -> None:
    report = dict(
        benchmark="sharded_step",
        backend=jax.default_backend(),
        interpret_mode=jax.default_backend() != "tpu",
        n_devices=jax.device_count(),
        mesh_model=n_model,
        jax_version=jax.__version__,
        platform=platform.platform(),
        rows=[dict(name=n, us_per_call=us, derived=d) for n, us, d in rows],
    )
    with open(path, "w") as f:
        json.dump(report, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=_N_DEVICES,
                    help="emulated host device count (= model mesh axis)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--full", action="store_true",
                    help="also run the BENCH_fused_train lead shape")
    ap.add_argument("--out", default="BENCH_sharded.json")
    args = ap.parse_args()
    n_model = min(args.devices, jax.device_count())
    rows = run(n_model, reps=args.reps, full=args.full)
    write_report(rows, n_model, args.out)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
