"""Roofline summary from the dry-run JSONL (assignment deliverable g).

Reads ``dryrun_results.jsonl`` (latest record wins per cell) and emits one
CSV row per compiled cell with the three terms and the bottleneck.
"""

from __future__ import annotations

import json
import os


def run(path: str = "dryrun_results.jsonl") -> list:
    if not os.path.exists(path):
        return [("roofline_report", 0.0, f"missing:{path} (run launch.dryrun --all)")]
    cells = {}
    for line in open(path):
        r = json.loads(line)
        cells[(r["arch"], r["shape"], r["mesh"])] = r
    rows = []
    for (arch, shape, mesh), r in sorted(cells.items()):
        if r.get("status") != "ok":
            rows.append((f"roofline_{arch}_{shape}_{mesh}", 0.0,
                         f"status={r.get('status')}"))
            continue
        dom = max(("t_comp", "t_mem", "t_coll"), key=lambda k: r[k])
        rows.append((
            f"roofline_{arch}_{shape}_{mesh}",
            r[dom] * 1e6,
            f"bottleneck={r['bottleneck']};t_comp={r['t_comp']:.3g};"
            f"t_mem={r['t_mem']:.3g};t_coll={r['t_coll']:.3g};"
            f"useful={r['useful_flops_ratio']:.3f};temp_gb={r['temp_bytes']/1e9:.1f}",
        ))
    return rows
