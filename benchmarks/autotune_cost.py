"""Predict-vs-sweep autotuner regret benchmark (-> BENCH_autotune.json).

The predict-first autotuner's promise is that a COLD-START artifact —
the zoo load path — can pick its kernel tiling from the analytical cost
model alone, with ZERO timing runs, and land within a few percent of
what a full wall-clock sweep would have chosen.  This benchmark measures
that promise end-to-end and turns it into a gated number:

1. Train + compile three small TMs spanning the regimes that move the
   model's inputs (include density and term sharing differ by seed /
   prototype density).  Two are TRAINING artifacts, one is HELD OUT.
2. Sweep the training artifacts with sidecar logging — the cost model
   refits from exactly the rows a production fleet would accumulate.
3. On the held-out artifact, in this order:
     * ``predict``: rank candidates analytically, take top-1 — the
       benchmark asserts ``autotune.TIMING_RUNS`` did not move;
     * ``verify``: wall-clock only the model's top-3 shortlist;
     * ``sweep``: time EVERY candidate — the ground truth.
4. Report regret = t(chosen)/t(best_swept) - 1 per policy.

The lead row (``autotune_sparse_predict_coldstart``) carries ``regret``
and ``timing_runs`` (must be 0); ``scripts/check_bench.py`` fails the
build when the fresh predict regret exceeds 10% — the paper-level claim
this PR ships.  Everything runs against a TEMP autotune cache + sidecar
so committed state and local caches never leak into the measurement.
"""

from __future__ import annotations

import json
import os
import platform
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compiler, tm
from repro.data import make_boolean_classification
from repro.kernels import autotune, cost_model, ops

# (n_features, n_classes, clauses_per_class, prototype_density, seed):
# the density spread moves include sparsity AND partial-term sharing, so
# the held-out artifact is a genuine generalization test, not a replay.
_ARTIFACTS = (
    (256, 4, 64, 0.08, 0),    # training: denser includes
    (384, 6, 64, 0.03, 1),    # training: sparser includes
    (320, 5, 64, 0.05, 2),    # HELD OUT
)
_B = 128                      # serving batch the tilings are picked for
_TRAIN_SAMPLES = 512
_TRAIN_EPOCHS = 2
_TRAIN_BATCH = 64


def _train_artifact(n_features, n_classes, cpc, density, seed):
    cfg = tm.TMConfig(n_features=n_features, n_classes=n_classes,
                      clauses_per_class=cpc, threshold=30, s=8.0)
    X, y = make_boolean_classification(
        _TRAIN_SAMPLES, n_features, n_classes,
        prototype_density=density, seed=seed)
    state = tm.init(cfg, jax.random.PRNGKey(seed))
    step = jax.jit(
        lambda ta, x, yy, s: ops.tm_train_step_matmul(cfg, ta, x, yy, s)[0])
    ta, k = state.ta_state, 0
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    for _ in range(_TRAIN_EPOCHS):
        for i in range(_TRAIN_SAMPLES // _TRAIN_BATCH):
            sl = slice(i * _TRAIN_BATCH, (i + 1) * _TRAIN_BATCH)
            ta = step(ta, Xj[sl], yj[sl], jnp.uint32(k))
            k += 1
    return compiler.compile_tm(cfg, np.asarray(ta))


def _sweep_timings(new_rows, kernel):
    """measured_us per tiling from the sidecar rows one sweep just wrote."""
    out = {}
    for row in new_rows:
        if row.get("kernel") == kernel:
            out[tuple(sorted(row["blocks"].items()))] = row["measured_us"]
    return out


def run(fast: bool = False) -> list:
    _, interpret = ops.kernel_dispatch(True, None)
    tmp = tempfile.mkdtemp(prefix="bench_autotune_")
    saved = {k: os.environ.get(k)
             for k in ("REPRO_AUTOTUNE_CACHE", "REPRO_TUNE_DATA")}
    os.environ["REPRO_AUTOTUNE_CACHE"] = os.path.join(tmp, "cache.json")
    os.environ["REPRO_TUNE_DATA"] = os.path.join(tmp, "data.json")
    autotune._PROC_CACHE.clear()
    cost_model._invalidate_model_cache()
    try:
        return _run_hermetic(interpret)
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v
        autotune._PROC_CACHE.clear()
        cost_model._invalidate_model_cache()


def _run_hermetic(interpret: bool) -> list:
    t0 = time.time()
    arts = [_train_artifact(*spec) for spec in _ARTIFACTS]
    held = arts[-1]
    print(f"trained {len(arts)} artifacts in {time.time() - t0:.1f}s; "
          f"held-out U={held.include_words.shape[0]} "
          f"sharing={held.stats.partial_term_sharing:.2f}")

    # 2. sidecar training data: sweep the two training artifacts
    for art in arts[:-1]:
        autotune.tune(
            "sparse_infer", B=_B, K=art.n_classes,
            include_words=art.include_words, interpret=interpret,
            policy="sweep", refresh=True, features=art.extract_features())
    n_train_rows = len(cost_model.load_observations())

    # 3a. predict on the held-out artifact — MUST issue zero timing runs
    runs_before = autotune.TIMING_RUNS
    ranked = autotune.rank_candidates(
        "sparse_infer", B=_B, K=held.n_classes,
        include_words=held.include_words, interpret=interpret)
    pred_blocks, pred_us = ranked[0]
    predict_runs = autotune.TIMING_RUNS - runs_before
    assert predict_runs == 0, f"predict issued {predict_runs} timing runs"

    # 3b. verify: wall-clock only the model's top-3
    runs_before = autotune.TIMING_RUNS
    verify_blocks = autotune.tune(
        "sparse_infer", B=_B, K=held.n_classes,
        include_words=held.include_words, interpret=interpret,
        policy="verify", top_k=3, refresh=True)
    verify_runs = autotune.TIMING_RUNS - runs_before

    # 3c. ground truth: full sweep, per-candidate times via the sidecar
    obs_before = len(cost_model.load_observations())
    runs_before = autotune.TIMING_RUNS
    sweep_blocks = autotune.tune(
        "sparse_infer", B=_B, K=held.n_classes,
        include_words=held.include_words, interpret=interpret,
        policy="sweep", refresh=True)
    sweep_runs = autotune.TIMING_RUNS - runs_before
    timings = _sweep_timings(
        cost_model.load_observations()[obs_before:], "sparse_infer")
    best_us = min(timings.values())

    def regret(blocks):
        return timings[tuple(sorted(blocks.items()))] / best_us - 1.0

    rows = [
        dict(name="autotune_sparse_predict_coldstart",
             us_per_call=timings[tuple(sorted(pred_blocks.items()))],
             regret=regret(pred_blocks), timing_runs=predict_runs,
             blocks=pred_blocks, predicted_us=pred_us,
             train_rows=n_train_rows),
        dict(name="autotune_sparse_verify_top3",
             us_per_call=timings[tuple(sorted(verify_blocks.items()))],
             regret=regret(verify_blocks), timing_runs=verify_runs,
             blocks=verify_blocks),
        dict(name="autotune_sparse_sweep_full",
             us_per_call=best_us, regret=regret(sweep_blocks),
             timing_runs=sweep_runs, blocks=sweep_blocks,
             candidates=len(timings)),
    ]
    for r in rows:
        print(f"{r['name']}: {r['us_per_call']:.0f}us regret="
              f"{r['regret']:.3f} timing_runs={r['timing_runs']}")
    return rows


def write_report(rows: list, path: str = "BENCH_autotune.json") -> None:
    _, interpret = ops.kernel_dispatch(True, None)
    report = dict(
        benchmark="autotune_cost",
        backend=jax.default_backend(),
        interpret_mode=bool(interpret),
        jax_version=jax.__version__,
        platform=platform.platform(),
        rows=rows,
    )
    with open(path, "w") as f:
        json.dump(report, f, indent=1)


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_autotune.json")
    args = ap.parse_args(argv)
    rows = run()
    write_report(rows, args.out)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
