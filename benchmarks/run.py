"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV (one row per measurement):
  * table1_*   — paper Table I analog (TM vs FINN-style BNN)
  * fig8_*     — paper Fig. 8 analog (logic-sharing resource savings)
  * fig7_*     — paper Fig. 7 analog (HCB chain schedule sweep)
  * tmcore_*   — TM datapath micro-benchmarks (train/infer steps)
  * fusedinfer_* — fused single-pass inference kernel vs the unfused
    two-kernel pipeline vs the jnp oracle (also written, with metadata,
    to BENCH_fused_infer.json — the cross-PR perf trajectory file)
  * fusedtrain_* — fused single-pass TRAINING kernel (clause fire ->
    feedback -> TA delta in one pallas_call) vs the three-dispatch
    pipeline vs the jnp oracle (-> BENCH_fused_train.json)
  * roofline_* — per dry-run cell roofline terms (deliverable g)
"""

from __future__ import annotations

import argparse
import sys
import time


def _tm_core_micro() -> list:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import tm
    from repro.kernels import ops

    rows = []
    cfg = tm.TMConfig(n_features=784, n_classes=10, clauses_per_class=100,
                      threshold=40, s=8.0)
    st = tm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.integers(0, 2, (256, 784), dtype=np.uint8))
    y = jnp.asarray(rng.integers(0, 10, 256, dtype=np.int32))

    step = jax.jit(lambda ta, x, yy, s: ops.tm_train_step_kernel(cfg, ta, x, yy, s)[0])
    ta = step(st.ta_state, X, y, jnp.uint32(0))
    ta.block_until_ready()
    t0 = time.perf_counter()
    for i in range(3):
        ta = step(ta, X, y, jnp.uint32(i))
    ta.block_until_ready()
    dt = (time.perf_counter() - t0) / 3
    rows.append(("tmcore_train_step_b256", dt * 1e6,
                 f"samples_s={256 / dt:,.0f}"))

    pred = jax.jit(lambda ta, x: tm.predict(cfg, tm.TMState(ta, jnp.int32(0)), x))
    pred(ta, X).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        out = pred(ta, X)
    out.block_until_ready()
    dt = (time.perf_counter() - t0) / 5
    rows.append(("tmcore_dense_infer_b256", dt * 1e6,
                 f"inf_s={256 / dt:,.0f}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the slow train-from-scratch tables")
    args = ap.parse_args()

    from benchmarks import (fused_infer, fused_train, hcb_pipeline,
                            logic_sharing, roofline_report, table1_inference)

    rows = []
    rows += _tm_core_micro()
    rows += hcb_pipeline.run()
    fused_rows = fused_infer.run(fast=args.fast)
    fused_infer.write_report(fused_rows)
    rows += fused_rows
    train_rows = fused_train.run(fast=args.fast)
    fused_train.write_report(train_rows)
    rows += train_rows
    if not args.fast:
        rows += table1_inference.run("mnist")
        rows += logic_sharing.run("mnist")
    rows += roofline_report.run()

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
