"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV (one row per measurement):
  * table1_*   — paper Table I analog (TM vs FINN-style BNN)
  * fig8_*     — paper Fig. 8 analog (logic-sharing resource savings)
  * fig7_*     — paper Fig. 7 analog (HCB chain schedule sweep)
  * tmcore_*   — TM datapath micro-benchmarks (train/infer steps)
  * fusedinfer_* — fused single-pass inference kernel vs the unfused
    two-kernel pipeline vs the jnp oracle (also written, with metadata,
    to BENCH_fused_infer.json — the cross-PR perf trajectory file)
  * fusedtrain_* — fused single-pass TRAINING kernel (clause fire ->
    feedback -> TA delta in one pallas_call) vs the three-dispatch
    pipeline vs the jnp oracle (-> BENCH_fused_train.json)
  * sparseinfer_* — block-sparse compiled-schedule inference on a trained
    artifact vs the dense fused kernel vs the uncompiled bank
    (-> BENCH_sparse_infer.json; speedup scales with model sparsity)
  * terminfer_* — shared-term FACTORIZED inference (unique AND terms
    evaluated once per sample slab) vs the flat sparse schedule vs the
    dense kernel, + a synthetic sharing sweep (-> BENCH_term_infer.json;
    speedup scales with the artifact's term-sharing fraction)
  * anytime_* — margin-ordered anytime inference on a trained artifact:
    exact early-exit speedup + the budgeted quality-tier
    accuracy-vs-latency frontier (-> BENCH_anytime.json; run as its own
    CI job, recorded in BENCH_STATUS here)
  * roofline_* — per dry-run cell roofline terms (deliverable g)
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


def _tm_core_micro() -> list:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import tm
    from repro.kernels import ops

    rows = []
    cfg = tm.TMConfig(n_features=784, n_classes=10, clauses_per_class=100,
                      threshold=40, s=8.0)
    st = tm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.integers(0, 2, (256, 784), dtype=np.uint8))
    y = jnp.asarray(rng.integers(0, 10, 256, dtype=np.int32))

    step = jax.jit(lambda ta, x, yy, s: ops.tm_train_step_kernel(cfg, ta, x, yy, s)[0])
    ta = step(st.ta_state, X, y, jnp.uint32(0))
    ta.block_until_ready()
    t0 = time.perf_counter()
    for i in range(3):
        ta = step(ta, X, y, jnp.uint32(i))
    ta.block_until_ready()
    dt = (time.perf_counter() - t0) / 3
    rows.append(("tmcore_train_step_b256", dt * 1e6,
                 f"samples_s={256 / dt:,.0f}"))

    pred = jax.jit(lambda ta, x: tm.predict(cfg, tm.TMState(ta, jnp.int32(0)), x))
    pred(ta, X).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        out = pred(ta, X)
    out.block_until_ready()
    dt = (time.perf_counter() - t0) / 5
    rows.append(("tmcore_dense_infer_b256", dt * 1e6,
                 f"inf_s={256 / dt:,.0f}"))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the slow train-from-scratch tables")
    ap.add_argument("--status-out", default=None,
                    help="also write the per-benchmark status JSON here")
    args = ap.parse_args()

    from benchmarks import (fused_infer, fused_train, hcb_pipeline,
                            logic_sharing, roofline_report, sparse_infer,
                            table1_inference, term_infer)

    # Per-benchmark status (name -> ok | skipped | "fail: <exc>") so the CI
    # log shows which benchmark actually ran — wall times alone can't
    # distinguish "fast" from "crashed before timing".
    status: dict = {}
    rows = []

    def section(name: str, fn):
        try:
            r = fn()
            status[name] = "ok"
            rows.extend(r)
        except Exception as e:  # noqa: BLE001 — keep benching, report at end
            status[name] = f"fail: {type(e).__name__}: {e}"
            traceback.print_exc()

    section("tmcore", _tm_core_micro)
    section("hcb_pipeline", hcb_pipeline.run)

    def _fused_infer():
        r = fused_infer.run(fast=args.fast)
        fused_infer.write_report(r)
        return r

    def _fused_train():
        r = fused_train.run(fast=args.fast)
        fused_train.write_report(r)
        return r

    def _sparse_infer():
        r = sparse_infer.run(fast=args.fast)
        sparse_infer.write_report(r)
        return r

    def _term_infer():
        r = term_infer.run(fast=args.fast)
        term_infer.write_report(r)
        return r

    section("fused_infer", _fused_infer)
    section("fused_train", _fused_train)
    if args.fast:
        # sparse_infer / term_infer: the CI bench job already trains +
        # times these artifacts via scripts/bench_smoke.py (fresh_sparse /
        # fresh_term.json); re-running the heavy train-and-time here would
        # double their share
        status["sparse_infer"] = "skipped (covered by scripts/bench_smoke.py)"
        status["term_infer"] = "skipped (covered by scripts/bench_smoke.py)"
        status["table1_inference"] = "skipped"
        status["logic_sharing"] = "skipped"
    else:
        section("sparse_infer", _sparse_infer)
        section("term_infer", _term_infer)
        section("table1_inference", lambda: table1_inference.run("mnist"))
        section("logic_sharing", lambda: logic_sharing.run("mnist"))
    section("roofline", roofline_report.run)
    # benchmarks/sharded_step.py needs its own process (forced device
    # count); it is a separate CI step, recorded here as such.
    status["sharded_step"] = "skipped (own process: python -m benchmarks.sharded_step)"
    # benchmarks/anytime.py trains its own edge-XL artifact and is gated
    # by the dedicated `anytime` CI job; re-running it here would double
    # the train-and-time cost of the bench job.
    status["anytime"] = "skipped (own CI job: python -m benchmarks.anytime)"

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    print("BENCH_STATUS " + json.dumps(status, sort_keys=True))
    if args.status_out:
        with open(args.status_out, "w") as f:
            json.dump(status, f, indent=1, sort_keys=True)
    return 1 if any(str(v).startswith("fail") for v in status.values()) else 0


if __name__ == "__main__":
    sys.exit(main())
