"""Block-sparse compiled inference benchmark (perf trajectory tracker).

This is the first benchmark whose measured speedup scales with TRAINED-MODEL
SPARSITY rather than raw shape: a TM is trained on class-structured data,
compiled (``core/compiler.py``: dedup + dead words + chain-schedule
emission), and the same compiled artifact is timed through four engines on
the same request stream:

  * ``sparse``     — kernels/sparse_infer.py: the block-sparse chain
    schedule (scalar-prefetched ragged tile grid, bit-parallel over
    samples; work ~ include bits of the artifact) [the lead row]
  * ``dense``      — kernels/fused_infer.py on the compiled artifact at the
    autotuner's best dense tiling (streams every literal word per clause
    block)
  * ``uncompiled`` — kernels/fused_infer.py on the RAW trained bank
    (no dedup / dead-word elim; empty clauses masked at runtime)
  * ``oracle``     — the pure-jnp XLA path on the compiled artifact

The lead shape is the repo's edge-XL-width bank: B=512 requests x C=4096
clauses over 4096 boolean features (W=256 literal words) — wide enough that
a trained clause's ~20-bit chain leaves >90% of the dense word stream
untouched.  Training uses the fast matmul engine (statistically equivalent
feedback; the artifact's include statistics are what matter here).

Engines are timed in ISOLATED per-engine loops, the whole sweep run twice
(see ``_time_isolated`` — a round-robin would charge whichever engine runs
after the oracle for its ~2 GB evicted working set), and written to
``BENCH_sparse_infer.json`` by ``write_report`` — the cross-PR perf
trajectory file gated by scripts/check_bench.py.  On this CPU container
both kernels run in Pallas interpret mode; the sparse-vs-dense ratio is the
tracked quantity.
"""

from __future__ import annotations

import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compiler, packetizer, tm
from repro.data import make_boolean_classification
from repro.kernels import autotune as _autotune
from repro.kernels import ops

# (B, n_features, n_classes, clauses_per_class): the lead row is
# B=512 x C=4096 at edge-XL literal width (W=256 words).
SHAPES = [
    (512, 4096, 8, 512),
    (512, 784, 8, 512),    # paper MNIST width (W=49)
]
# enough steps that clauses converge to their sparse include sets (the
# young-model regime is dense and under-represents a deployed artifact)
_TRAIN_SAMPLES = 1536
_TRAIN_EPOCHS = 3
_TRAIN_BATCH = 64


def _train_artifact(n_features: int, n_classes: int, cpc: int, seed: int = 0):
    """Train a TM with the matmul engine and compile it."""
    cfg = tm.TMConfig(n_features=n_features, n_classes=n_classes,
                      clauses_per_class=cpc, threshold=50, s=10.0)
    X, y = make_boolean_classification(
        _TRAIN_SAMPLES, n_features, n_classes,
        prototype_density=0.05, seed=seed,
    )
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    state = tm.init(cfg, jax.random.PRNGKey(seed))
    step = jax.jit(
        lambda ta, x, yy, s: ops.tm_train_step_matmul(cfg, ta, x, yy, s)[0]
    )
    ta = state.ta_state
    k = 0
    n_batches = _TRAIN_SAMPLES // _TRAIN_BATCH
    for _ in range(_TRAIN_EPOCHS):
        for i in range(n_batches):
            sl = slice(i * _TRAIN_BATCH, (i + 1) * _TRAIN_BATCH)
            ta = step(ta, Xj[sl], yj[sl], jnp.uint32(k))
            k += 1
    ta.block_until_ready()
    return cfg, ta, compiler.compile_tm(cfg, ta)


def _time_isolated(fns: dict, reps: int, sweeps: int = 2) -> dict:
    """min seconds per engine, each timed in its own consecutive loop.

    Unlike the round-robin used by the dense benches, engines here have
    very different working sets (the oracle materializes the (B, C, W)
    violation tensor, ~2 GB at the lead shape; the raw bank streams the
    full dense word grid) — in a rotation, whoever runs after the big one
    is charged its evicted caches, which on a small container flips the
    measured ratio run to run.  Isolated loops give each engine its own
    steady state; running the whole sweep twice still catches container
    drift across the bench.
    """
    for fn in fns.values():
        fn().block_until_ready()        # compile + warm
    best = {k: float("inf") for k in fns}
    for _ in range(sweeps):
        for k, fn in fns.items():
            fn().block_until_ready()    # re-warm this engine's buffers
            for _ in range(reps):
                t0 = time.perf_counter()
                fn().block_until_ready()
                best[k] = min(best[k], time.perf_counter() - t0)
    return best


def run(fast: bool = True, reps: int = 5, autotune: bool = True) -> list:
    _, interpret = ops.kernel_dispatch(True, None)
    rng = np.random.default_rng(0)
    rows = []
    for B, F, K, cpc in SHAPES[:1] if fast else SHAPES:
        cfg, ta, comp = _train_artifact(F, K, cpc)
        W = comp.stats.n_words_dense
        lit = jnp.asarray(
            packetizer.pack_literals(
                jnp.asarray(rng.integers(0, 2, (B, F), dtype=np.uint8))
            )
        )

        sblocks = (
            _autotune.autotune_sparse_infer_blocks(
                B, K, comp.include_words, interpret=interpret)
            if autotune else {}
        )
        dblocks = (
            _autotune.autotune_fused_blocks(
                B, comp.n_unique, comp.n_words_active, K,
                interpret=interpret)
            if autotune else {}
        )
        raw_iw = packetizer.pack_include_masks(jnp.asarray(ta))
        raw_votes = tm.vote_matrix(cfg)
        raw_ne = jnp.any(jnp.asarray(ta) >= 0, axis=-1).astype(jnp.uint8)
        rblocks = (
            _autotune.autotune_fused_blocks(
                B, cfg.n_clauses_total, W, K, interpret=interpret)
            if autotune else {}
        )

        def compiled_fwd(engine, **blk):
            # engine="sparse" (not "auto"): this bench tracks the PR-4
            # flat bit-chain kernel; under "auto" the factorize heuristic
            # would serve the term-schedule kernel on high-sharing trained
            # artifacts and silently corrupt the sparse trajectory row
            jitted = jax.jit(lambda l: compiler.run_compiled(
                comp, l, engine=engine, interpret=interpret, **blk,
            ))
            return lambda: jitted(lit)

        def raw_fwd(**blk):
            jitted = jax.jit(lambda l: ops.tm_forward_packed(
                l, raw_iw, raw_votes, raw_ne,
                use_kernel=True, interpret=interpret, **blk,
            ))
            return lambda: jitted(lit)

        def oracle_fwd():
            jitted = jax.jit(lambda l: compiler.run_compiled(
                comp, l, engine="oracle"))
            return lambda: jitted(lit)

        t = _time_isolated(
            dict(
                sparse=compiled_fwd("sparse", **sblocks),
                dense=compiled_fwd("dense", **dblocks),
                uncompiled=raw_fwd(**rblocks),
            ),
            reps,
        )
        # informational row; ~0.5 s/call, so a short isolated loop suffices
        t.update(_time_isolated(dict(oracle=oracle_fwd()), 2, sweeps=1))
        sched = comp.schedule(sblocks.get("block_c"), sblocks.get("block_j"))
        tag = f"b{B}_c{cfg.n_clauses_total}_w{W}_k{K}"
        sblk = ";".join(f"{k}={v}" for k, v in sorted(sblocks.items()))
        rows.append((
            f"sparseinfer_sparse_{tag}", t["sparse"] * 1e6,
            f"speedup_vs_dense={t['dense'] / t['sparse']:.2f}x;"
            f"include_sparsity={comp.stats.include_sparsity:.4f};"
            f"tile_sparsity={sched.tile_sparsity:.4f};"
            f"n_tiles={sched.n_tiles}"
            + (f";{sblk}" if sblk else ""),
        ))
        rows.append((
            f"sparseinfer_dense_{tag}", t["dense"] * 1e6,
            "compiled_dense_fused;" + ";".join(
                f"{k}={v}" for k, v in sorted(dblocks.items())),
        ))
        rows.append((
            f"sparseinfer_uncompiled_{tag}", t["uncompiled"] * 1e6,
            f"raw_bank_fused;speedup_compiled_sparse="
            f"{t['uncompiled'] / t['sparse']:.2f}x",
        ))
        rows.append((
            f"sparseinfer_oracle_{tag}", t["oracle"] * 1e6, "pure_jnp_xla",
        ))
    return rows


def write_report(rows: list, path: str = "BENCH_sparse_infer.json") -> None:
    _, interpret = ops.kernel_dispatch(True, None)
    report = dict(
        benchmark="sparse_infer",
        backend=jax.default_backend(),
        interpret_mode=bool(interpret),
        jax_version=jax.__version__,
        platform=platform.platform(),
        autotune_cache=_autotune.cache_path(),
        rows=[dict(name=n, us_per_call=us, derived=d) for n, us, d in rows],
    )
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
