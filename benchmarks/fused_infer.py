"""Fused vs unfused TM inference microbenchmark (perf trajectory tracker).

Times three execution engines on identical problem shapes:

  * ``fused``    — kernels/fused_infer.py single-pass kernel (clause eval +
    class sum in one ``pallas_call``, no (B, C) fired matrix in HBM), at
    the block tiling picked by kernels/autotune.py's cached sweep
  * ``unfused``  — the legacy two-kernel pipeline (clause_eval then
    class_sum at their shipped default tilings, fired matrix materialized
    between them)
  * ``oracle``   — the pure-jnp XLA path (the off-TPU default engine)

Engines are timed interleaved (alternating calls, min over rounds) so
container noise hits all rows equally.  ``write_report`` persists the rows
to ``BENCH_fused_infer.json`` so the fused-kernel perf trajectory is
tracked across PRs.  On this CPU container both kernel paths run in Pallas
interpret mode — the fused-vs-unfused ratio is still meaningful (same
interpreter, one pass vs two + the materialized intermediate); on TPU the
same harness times compiled kernels.
"""

from __future__ import annotations

import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packetizer
from repro.kernels import autotune as _autotune
from repro.kernels import ops

# (B, C, W, K): serving bucket x clause bank x literal words x classes.
# The lead shape is a big clause bank — where the (B, C) HBM intermediate
# the unfused pipeline materializes actually costs something.
SHAPES = [
    (512, 4096, 8, 10),
    (256, 512, 16, 10),
]


def _time_interleaved(fns: dict, reps: int) -> dict:
    """min seconds per engine over `reps` alternating rounds."""
    for fn in fns.values():
        fn().block_until_ready()        # compile + warm
    best = {k: float("inf") for k in fns}
    for _ in range(reps):
        for k, fn in fns.items():
            t0 = time.perf_counter()
            fn().block_until_ready()
            best[k] = min(best[k], time.perf_counter() - t0)
    return best


def run(fast: bool = True, reps: int = 8, autotune: bool = True) -> list:
    _, interpret = ops.kernel_dispatch(True, None)
    rng = np.random.default_rng(0)
    rows = []
    for B, C, W, K in SHAPES[:1] if fast else SHAPES:
        lit = jnp.asarray(rng.integers(0, 2**32, (B, W), dtype=np.uint32))
        inc_bits = (rng.random((C, W * 32)) < 0.03).astype(np.uint8)
        inc = jnp.asarray(packetizer.pack_bits_np(inc_bits))
        votes = jnp.asarray(rng.integers(-2, 3, (C, K), dtype=np.int32))
        ne = jnp.asarray(rng.integers(0, 2, (C,), dtype=np.uint8))

        blocks = (
            _autotune.autotune_fused_blocks(B, C, W, K, interpret=interpret)
            if autotune else {}
        )

        def fwd(use_kernel, fuse, **blk):
            # inputs stay jit arguments (not closure constants) so XLA
            # cannot constant-fold the timed computation away
            jitted = jax.jit(lambda l, i, v, n: ops.tm_forward_packed(
                l, i, v, n,
                use_kernel=use_kernel, interpret=interpret, fuse=fuse, **blk,
            ))
            return lambda: jitted(lit, inc, votes, ne)

        t = _time_interleaved(
            dict(
                fused=fwd(True, True, **blocks),
                unfused=fwd(True, False),
                oracle=fwd(False, True),
            ),
            reps,
        )
        tag = f"b{B}_c{C}_w{W}_k{K}"
        blk_str = ";".join(f"{k}={v}" for k, v in sorted(blocks.items()))
        rows.append((f"fusedinfer_fused_{tag}", t["fused"] * 1e6,
                     f"speedup_vs_unfused={t['unfused'] / t['fused']:.2f}x"
                     + (f";{blk_str}" if blk_str else "")))
        rows.append((f"fusedinfer_unfused_{tag}", t["unfused"] * 1e6,
                     "two_kernel_pipeline"))
        rows.append((f"fusedinfer_oracle_{tag}", t["oracle"] * 1e6,
                     "pure_jnp_xla"))
    return rows


def write_report(rows: list, path: str = "BENCH_fused_infer.json") -> None:
    _, interpret = ops.kernel_dispatch(True, None)
    report = dict(
        benchmark="fused_infer",
        backend=jax.default_backend(),
        interpret_mode=bool(interpret),
        jax_version=jax.__version__,
        platform=platform.platform(),
        autotune_cache=_autotune.cache_path(),
        rows=[dict(name=n, us_per_call=us, derived=d) for n, us, d in rows],
    )
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
