"""Serving-gateway load benchmark + chaos harness -> BENCH_serve.json.

    PYTHONPATH=src python -m benchmarks.serve_gateway [--out BENCH_serve.json]
    PYTHONPATH=src python -m benchmarks.serve_gateway --chaos

Drives the resilient async gateway (``runtime/gateway.py``) + artifact zoo
(``runtime/zoo.py``) with live mixed-tenant load against a trained+compiled
tiny TM artifact served through the engine ladder:

  * ``serve_openloop_*`` [the lead row] — open-loop Poisson arrivals at a
    fixed offered rate over 3 round-robin tenants: the latency a client
    actually observes (queueing + batching + engine), reported as
    p50/p99 ms and achieved req/s.  Open loop does not slow down when the
    server does, so backlog and shedding are REAL, not masked by client
    back-pressure.
  * ``serve_closedloop_*`` — N concurrent clients, each submit->await->
    submit: the saturated-throughput shape.

``--chaos`` turns the same Poisson run into a fault drill: one injected
fault per class (admission: ``gateway.queue_overflow``; zoo:
``zoo.load_fail@2`` targeting tenant t2; engine: ``kernel.dense`` demoting
the ladder mid-stream), a mid-stream atomic hot-swap on tenant t0 (plus an
injected ``zoo.swap_abort`` killing t1's swap pre-commit — t1 must keep
serving version 1), and a real mid-stream SIGTERM that triggers the
graceful drain.  The run then asserts the gateway's contract — every
offered request was answered or shed with a typed reason (``unaccounted ==
0``), the quarantined tenant's sheds are typed while healthy tenants keep
serving, and the drained process exits 0 — and exits non-zero on any
violation.  CI runs this as the ``gateway`` job's acceptance drill.

``--chaos`` additionally runs the BROWNOUT OVERLOAD drill: the same
Poisson stream at ~2x the runner's modeled capacity, once with the
brownout controller and once without.  The brownout run must shed
STRICTLY fewer requests than the baseline (degrading quality buys real
capacity), keep p99 under a hard cap, keep every quality tier's agreement
with the exact predictions above a floor, and account for 100% of offered
requests in both runs.  Two more sites are drilled alongside:
``anytime.margin_corrupt`` (a tampered margin table must be REJECTED at
artifact load, never served) and ``gateway.brownout_stuck`` (a wedged
step-down path must be recovered by the controller's low-pressure
watchdog).

Rows carry ``us_per_call`` (= p99 latency, the gated scalar) plus explicit
``p99_ms`` / ``req_per_s`` fields; scripts/check_bench.py gates the lead
row on BOTH (p99 regression or throughput collapse >2x fails).
"""

from __future__ import annotations

import argparse
import asyncio
import itertools
import json
import os
import platform
import signal
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.matador_tm import TM_CONFIGS
from repro.core import compiler, packetizer, tm, train
from repro.data import make_boolean_classification
from repro.kernels import ops
from repro.runtime import faults
from repro.runtime.gateway import (BrownoutConfig, BrownoutController,
                                   Gateway)
from repro.runtime.zoo import ArtifactZoo

TENANTS = ("t0", "t1", "t2")
BUCKET = 64


def _build_compiled(arch: str = "tm-tiny"):
    config = TM_CONFIGS[arch]
    X, y = make_boolean_classification(
        512, config.n_features, config.n_classes, seed=0)
    state = tm.init(config, jax.random.PRNGKey(0))
    state = train.fit(config, state, jnp.asarray(X), jnp.asarray(y),
                      epochs=1, batch_size=64, rng=jax.random.PRNGKey(1))
    return config, compiler.compile_tm(config, state.ta_state)


def _build_runner(compiled, bucket: int, W: int, warm: bool = True):
    """Zoo-wrapped gateway runner over a dense-kernel -> oracle ladder.

    The dense engine runs the Pallas kernel in interpret mode so the
    ``kernel.dense`` chaos fault exercises the REAL demotion path; the
    oracle level keeps every bucket answerable after the demotion.
    """
    ladder = ops.EngineLadder([
        ("dense", lambda: jax.jit(lambda xw: compiler.run_compiled(
            compiled, xw, engine="dense", interpret=True).argmax(-1))),
        ("oracle", lambda: jax.jit(lambda xw: compiler.run_compiled(
            compiled, xw, engine="oracle").argmax(-1))),
    ])
    counter = itertools.count()

    def run_rows(rows):
        i = next(counter)
        padded = np.zeros((bucket, W), np.uint32)
        padded[:len(rows)] = rows
        out = ladder.run(lambda: jnp.asarray(padded), bucket=i)
        return np.asarray(out)[:len(rows)]

    if warm:
        # warm probe: both ladder levels pay their jit trace BEFORE the
        # load stream so the measured latencies are serving, not
        # compilation.  The chaos drill must NOT pre-trace: the
        # kernel.dense fault site runs at trace time, so a warmed dense
        # engine would never see the injected fault.
        ladder.run(lambda: jnp.zeros((bucket, W), jnp.uint32),
                   bucket="warm", count=False)
        ladder._run_at(1, lambda: jnp.zeros((bucket, W), jnp.uint32))
    nbytes = int(compiled.include_words.nbytes + compiled.votes.nbytes)
    zoo = ArtifactZoo(lambda tenant: (tenant, nbytes),
                      max_entries=len(TENANTS) - 1, breaker_threshold=3)
    return zoo.runner(lambda obj, rows: run_rows(rows)), ladder, zoo


def _requests(n: int, config):
    Xr, _ = make_boolean_classification(
        n, config.n_features, config.n_classes, seed=2)
    return np.asarray(packetizer.pack_literals(jnp.asarray(Xr)))


async def _drive(gw: Gateway, offer_all, *,
                 sigterm_after: float | None = None):
    """Run ``offer_all(futs)`` to completion (or SIGTERM), then drain.

    Returns (responses, final_health, sigterm_seen).  The offer coroutine
    may keep offering after the drain starts — those offers shed
    ``shutting_down`` and the FINAL health (taken after it finishes) still
    accounts for them.
    """
    loop = asyncio.get_running_loop()
    stop = asyncio.Event()
    try:
        loop.add_signal_handler(signal.SIGTERM, stop.set)
    except (NotImplementedError, RuntimeError):
        pass
    if sigterm_after is not None:
        threading.Timer(sigterm_after,
                        lambda: os.kill(os.getpid(), signal.SIGTERM)).start()
    futs: list = []
    done = asyncio.ensure_future(offer_all(futs))
    sig = asyncio.ensure_future(stop.wait())
    await asyncio.wait({done, sig}, return_when=asyncio.FIRST_COMPLETED)
    await gw.drain()
    sig.cancel()
    await done
    responses = await asyncio.gather(*futs)
    return responses, gw.health(), stop.is_set()


async def _open_loop(gw, xp, rate: float, n: int, deadline: float | None,
                     futs: list) -> None:
    """Poisson arrivals at ``rate`` req/s, round-robin over TENANTS."""
    rng = np.random.default_rng(0)
    gaps = rng.exponential(1.0 / rate, size=n)
    t_next = time.perf_counter()
    for j in range(n):
        t_next += gaps[j]
        delay = t_next - time.perf_counter()
        if delay > 0:
            await asyncio.sleep(delay)
        futs.append(gw.offer(TENANTS[j % len(TENANTS)], xp[j % len(xp)],
                             deadline=deadline))


async def _closed_loop(gw, xp, n_clients: int, per_client: int,
                       futs: list) -> None:
    async def client(c):
        for k in range(per_client):
            j = (c * per_client + k) % len(xp)
            fut = gw.offer(TENANTS[(c + k) % len(TENANTS)], xp[j])
            futs.append(fut)
            await fut
    await asyncio.gather(*(client(c) for c in range(n_clients)))


def _row(name: str, health: dict, wall: float) -> dict:
    p99 = health["latency_ms"]["p99"] or 0.0
    answered = health["answered"]
    shed_frac = health["shed_total"] / max(health["offered"], 1)
    return dict(
        name=name,
        us_per_call=p99 * 1e3,
        p99_ms=p99,
        req_per_s=answered / wall if wall > 0 else 0.0,
        derived=(f"p50_ms={health['latency_ms']['p50'] or 0.0:.3f};"
                 f"offered={health['offered']};answered={answered};"
                 f"shed_frac={shed_frac:.4f};buckets={health['buckets']}"),
    )


def run(rate: float = 1500.0, n: int = 1200, clients: int = 32,
        per_client: int = 25) -> list:
    config, compiled = _build_compiled()
    xp = _requests(512, config)
    rows = []

    runner, _, _ = _build_runner(compiled, BUCKET, xp.shape[1])

    async def open_run():
        gw = await Gateway(runner, bucket=BUCKET, max_wait=0.005).start()
        t0 = time.perf_counter()
        res, h, _ = await _drive(
            gw, lambda futs: _open_loop(gw, xp, rate, n, None, futs))
        return res, h, time.perf_counter() - t0

    _, h, wall = asyncio.run(open_run())
    assert h["unaccounted"] == 0, h
    rows.append(_row(f"serve_openloop_poisson_r{int(rate)}_t{len(TENANTS)}"
                     f"_b{BUCKET}", h, wall))

    runner, _, _ = _build_runner(compiled, BUCKET, xp.shape[1])

    async def closed_run():
        gw = await Gateway(runner, bucket=BUCKET, max_wait=0.005).start()
        t0 = time.perf_counter()
        res, h, _ = await _drive(
            gw, lambda futs: _closed_loop(gw, xp, clients, per_client, futs))
        return res, h, time.perf_counter() - t0

    _, h, wall = asyncio.run(closed_run())
    assert h["unaccounted"] == 0, h
    rows.append(_row(f"serve_closedloop_c{clients}_t{len(TENANTS)}"
                     f"_b{BUCKET}", h, wall))
    return rows


def chaos(rate: float = 1500.0, n: int = 1200) -> int:
    """Poisson run with one injected fault per class + mid-stream SIGTERM.

    Also drills the hot-swap path mid-stream: tenant t0 gets a REAL
    ``zoo.swap`` while its requests keep flowing (in-flight buckets finish
    on the old version, later ones on the new — zero drops either way),
    and tenant t1 gets a swap that the injected ``zoo.swap_abort`` site
    kills before its commit point (t1 must keep serving version 1).

    Returns 0 when every gateway invariant holds, 1 otherwise.
    """
    from repro.runtime.zoo import SwapAborted

    config, compiled = _build_compiled()
    xp = _requests(512, config)
    runner, ladder, zoo = _build_runner(compiled, BUCKET, xp.shape[1],
                                        warm=False)
    nbytes = int(compiled.include_words.nbytes + compiled.votes.nbytes)
    # prime t0 so the mid-stream swap bumps a LIVE entry (1 -> 2) instead
    # of cold-installing version 1
    with zoo.lease("t0"):
        pass
    swap_log: dict = {}

    def midstream_swaps():
        try:
            swap_log["t0"] = zoo.swap("t0", ("t0-v2", nbytes), nbytes)
        except Exception as e:           # pragma: no cover - drill fails
            swap_log["t0_error"] = repr(e)
        try:
            zoo.swap("t1", ("t1-v2", nbytes), nbytes)
            swap_log["t1_error"] = "swap committed despite zoo.swap_abort"
        except SwapAborted:
            swap_log["t1_aborted"] = True

    async def go():
        # hot-swaps land ~20% through the arrivals, well before SIGTERM
        threading.Timer(0.2 * n / rate, midstream_swaps).start()
        gw = await Gateway(runner, bucket=BUCKET, max_queue=512,
                           max_wait=0.005, drain_timeout=10.0).start()
        # SIGTERM lands mid-stream (~40% through the planned arrivals)
        return await _drive(
            gw, lambda futs: _open_loop(gw, xp, rate, n, 5.0, futs),
            sigterm_after=0.4 * n / rate)

    with faults.injected("gateway.queue_overflow*5, zoo.load_fail@2*3, "
                         "kernel.dense*1, zoo.swap_abort@1*1"):
        responses, h, sigtermed = asyncio.run(go())

    failures = []
    if h["unaccounted"] != 0:
        failures.append(f"unaccounted != 0: {h['unaccounted']}")
    untyped = [r for r in responses if not r.ok and not r.reason]
    if untyped:
        failures.append(f"{len(untyped)} sheds carry no typed reason")
    if len(responses) != h["offered"]:
        failures.append(f"{h['offered']} offered but only "
                        f"{len(responses)} responses resolved")
    if h["shed"].get("queue_full", 0) < 1:
        failures.append("queue_overflow drill produced no queue_full shed")
    t2 = h["tenants"].get("t2", {}).get("shed", {})
    if t2.get("load_failed", 0) + t2.get("tenant_quarantined", 0) < 1:
        failures.append("zoo.load_fail@2 produced no typed shed on t2")
    healthy = [t for t in ("t0", "t1") if
               h["tenants"].get(t, {}).get("answered", 0) > 0]
    if len(healthy) < 2:
        failures.append(f"healthy tenants stopped serving: {healthy}")
    if not ladder.demotions:
        failures.append("kernel.dense drill produced no ladder demotion")
    if not sigtermed:
        failures.append("SIGTERM was never delivered")
    if not h["draining"]:
        failures.append("SIGTERM did not put the gateway in drain")
    zh = zoo.health()
    if swap_log.get("t0") != 2:
        failures.append(f"mid-stream hot-swap did not commit t0 at "
                        f"version 2: {swap_log}")
    if zoo.version("t0") != 2:
        failures.append(f"t0 serves version {zoo.version('t0')}, not the "
                        "swapped version 2")
    if not swap_log.get("t1_aborted"):
        failures.append(f"zoo.swap_abort@1 did not abort t1's swap: "
                        f"{swap_log}")
    if zh["swap_aborts"] != 1:
        failures.append(f"expected exactly 1 swap abort, saw "
                        f"{zh['swap_aborts']}")
    if zoo.version("t1") not in (1, None):
        failures.append(f"aborted swap left t1 half-promoted at version "
                        f"{zoo.version('t1')}")
    if h["tenants"].get("t0", {}).get("answered", 0) < 1:
        failures.append("t0 stopped serving across its hot-swap")

    h["zoo"] = zoo.health()
    h["ladder"] = dict(final_engine=ladder.engine,
                       demotions=ladder.demotions)
    print("GATEWAY_HEALTH " + json.dumps(h))

    # brownout drills: overload (2x capacity, brownout vs baseline),
    # tampered margin metadata, wedged step-down recovery
    failures += overload_drill(config, compiled)
    failures += margin_corrupt_drill(compiled)
    failures += brownout_stuck_drill()

    if failures:
        for f in failures:
            print("CHAOS_FAIL " + f)
        return 1
    print(f"CHAOS_OK offered={h['offered']} answered={h['answered']} "
          f"shed={h['shed']} (all typed, zero silent drops; brownout "
          "overload/margin-corrupt/stuck drills passed)")
    return 0


# -- brownout overload drill -------------------------------------------------

# tm-tiny at this tiling has ~80 schedule tiles, so the quality prefixes
# actually truncate (the serving default of one giant tile would make
# every tier identical to exact)
_OVERLOAD_BLOCKS = dict(block_c=4, block_j=1)
_P99_CAP_MS = 2000.0      # brownout p99 hard cap under 2x overload
_AGREE_FLOOR = 0.9        # per-tier agreement with exact predictions


def _build_anytime_runner(compiled, xp, base_service: float):
    """Quality-aware gateway runner with a MODELED service time.

    Per-tier predictions are precomputed on the canned request set with
    the REAL budgeted kernels (the gateway serves genuine prefix answers
    and their bounds); the worker then sleeps the modeled per-bucket
    service time scaled by the tier's tile-prefix fraction — degrading
    quality buys capacity exactly the way the tile walk does, and the
    drill's capacity math stays deterministic on a noisy CI container.
    """
    levels = compiled.quality_levels(engine="sparse", **_OVERLOAD_BLOCKS)
    n_full = levels[0]["n_tiles"]
    lit = jnp.asarray(xp)
    preds, frac, bound = {}, {}, {}
    for q in levels:
        lvl = q["level"]
        sums = compiler.run_compiled(compiled, lit, engine="sparse",
                                     quality=lvl, interpret=True,
                                     **_OVERLOAD_BLOCKS)
        preds[lvl] = np.asarray(sums.argmax(-1))
        frac[lvl] = q["n_tiles"] / n_full
        bound[lvl] = q["bound"]
    idx = {xp[i].tobytes(): i for i in range(len(xp))}

    def runner(tenant, rows, quality=0):
        lvl = min(int(quality), max(preds))
        out = np.array([preds[lvl][idx[np.asarray(r).tobytes()]]
                        for r in rows])
        time.sleep(base_service * frac[lvl])
        return out, dict(quality=lvl,
                         err_bound=bound[lvl] if lvl else None)

    return runner, preds[0]


def _run_overload(runner, xp, *, brownout: bool, rate: float, n: int,
                  bucket: int):
    async def go():
        gw = await Gateway(
            runner, bucket=bucket, max_queue=4 * bucket, max_wait=0.005,
            drain_timeout=10.0,
            brownout=BrownoutController() if brownout else None).start()
        return await _drive(
            gw, lambda futs: _open_loop(gw, xp, rate, n, 1.0, futs))

    res, h, _ = asyncio.run(go())
    return res, h


def overload_drill(config, compiled, n: int = 1200, bucket: int = 16,
                   base_service: float = 0.02) -> list:
    """2x-capacity Poisson overload, brownout vs no-brownout baseline.

    Returns the list of contract violations (empty = drill passed).
    """
    failures = []
    xp = _requests(512, config)
    runner, exact = _build_anytime_runner(compiled, xp, base_service)
    rate = 2.0 * bucket / base_service      # 2x the exact-tier capacity
    res_b, h_b = _run_overload(runner, xp, brownout=True, rate=rate,
                               n=n, bucket=bucket)
    res_0, h_0 = _run_overload(runner, xp, brownout=False, rate=rate,
                               n=n, bucket=bucket)

    for tag, res, h in (("brownout", res_b, h_b), ("baseline", res_0, h_0)):
        if h["unaccounted"] != 0:
            failures.append(f"{tag}: unaccounted != 0: {h['unaccounted']}")
        if len(res) != h["offered"]:
            failures.append(f"{tag}: {h['offered']} offered but "
                            f"{len(res)} responses resolved")
        untyped = [r for r in res if not r.ok and not r.reason]
        if untyped:
            failures.append(f"{tag}: {len(untyped)} sheds with no reason")

    if h_b["shed_total"] >= h_0["shed_total"]:
        failures.append(
            f"brownout shed {h_b['shed_total']} >= baseline "
            f"{h_0['shed_total']} — degrading bought no capacity")
    p99 = h_b["latency_ms"]["p99"] or 0.0
    if p99 > _P99_CAP_MS:
        failures.append(f"brownout p99 {p99:.0f}ms over the "
                        f"{_P99_CAP_MS:.0f}ms cap")
    if h_b["answered_degraded"] < 1:
        failures.append("brownout never served a degraded answer under "
                        "2x overload")
    if (h_b.get("brownout") or {}).get("escalations", 0) < 1:
        failures.append("brownout controller never escalated")
    for tier in sorted({r.quality for r in res_b if r.ok}):
        hits = [int(r.pred == exact[j % len(xp)])
                for j, r in enumerate(res_b) if r.ok and r.quality == tier]
        agree = float(np.mean(hits))
        if agree < _AGREE_FLOOR:
            failures.append(f"tier {tier} agreement with exact "
                            f"{agree:.3f} < {_AGREE_FLOOR} floor "
                            f"({len(hits)} answers)")
    bad = [r for r in res_b if r.ok and r.quality > 0 and r.err_bound is None]
    if bad:
        failures.append(f"{len(bad)} degraded answers carry no err_bound")

    print("BROWNOUT_HEALTH " + json.dumps(dict(
        offered_rate=rate, brownout=h_b,
        baseline=dict(shed_total=h_0["shed_total"],
                      answered=h_0["answered"],
                      p99_ms=h_0["latency_ms"]["p99"]))))
    return failures


def margin_corrupt_drill(compiled) -> list:
    """anytime.margin_corrupt: tampered margin metadata must be REJECTED
    at load (validate_artifact's vote-table consistency check), and the
    clean artifact must still load once the site disarms."""
    import tempfile

    failures = []
    with tempfile.TemporaryDirectory(prefix="anytime_art_") as d:
        path = compiled.save(os.path.join(d, "art.npz"))
        with faults.injected("anytime.margin_corrupt"):
            try:
                compiler.CompiledTM.load(path)
                failures.append("anytime.margin_corrupt: tampered margins "
                                "were accepted at load")
            except compiler.ArtifactError as e:
                if "margin" not in str(e).lower():
                    failures.append(
                        f"margin tamper rejected with wrong error: {e}")
        try:
            compiler.CompiledTM.load(path)
        except compiler.ArtifactError as e:
            failures.append(f"clean artifact rejected after drill: {e}")
    return failures


def brownout_stuck_drill() -> list:
    """gateway.brownout_stuck: with the primary step-down path wedged,
    the low-pressure watchdog must still recover exact serving."""
    failures = []
    c = BrownoutController(BrownoutConfig(watchdog_evals=4))
    with faults.injected("gateway.brownout_stuck*8"):
        c.update(0.9)                  # escalate straight to level 3
        for _ in range(4):
            c.update(0.05)             # calm, but step-down is wedged
    if c.level != 0 or c.watchdog_resets != 1:
        failures.append(
            f"brownout_stuck: watchdog did not recover (level={c.level}, "
            f"watchdog_resets={c.watchdog_resets})")
    return failures


def write_report(rows: list, path: str = "BENCH_serve.json") -> None:
    report = dict(
        benchmark="serve_gateway",
        backend=jax.default_backend(),
        interpret_mode=True,           # the dense ladder level interprets
        jax_version=jax.__version__,
        platform=platform.platform(),
        rows=rows,
    )
    with open(path, "w") as f:
        json.dump(report, f, indent=1)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--rate", type=float, default=1500.0,
                    help="open-loop Poisson offered rate (req/s)")
    ap.add_argument("--requests", type=int, default=1200)
    ap.add_argument("--chaos", action="store_true",
                    help="fault-injection drill instead of the benchmark: "
                         "one fault per class + mid-stream SIGTERM, exits "
                         "non-zero on any gateway-contract violation")
    args = ap.parse_args()
    if args.chaos:
        return chaos(rate=args.rate, n=args.requests)
    rows = run(rate=args.rate, n=args.requests)
    write_report(rows, args.out)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.3f},req_per_s="
              f"{r['req_per_s']:.0f};{r['derived']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
