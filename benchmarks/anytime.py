"""Anytime inference benchmark -> BENCH_anytime.json.

    PYTHONPATH=src python -m benchmarks.anytime [--out BENCH_anytime.json]

Measures the two anytime serving modes on a TRAINED edge-XL artifact (the
same train+compile recipe as benchmarks/sparse_infer.py, so the margin
table reflects a deployed model's vote-mass distribution, not a random
bank):

  * ``anytime_exact_ee_*`` [the lead row] — the exact early-exit kernel
    mode: per-sample certification against the artifact's cumulative
    margin table lets a slab stop folding tiles once every sample's lead
    exceeds the residual swing.  Argmax is BIT-IDENTICAL to the full walk
    (asserted here on every eval batch); the tracked quantity is the
    speedup over the full schedule at identical answers.
  * ``anytime_q{1..3}_*`` — the budgeted quality tiers (brownout levels):
    each serves the margin-ordered tile PREFIX from
    ``compiled.quality_levels()``, trading a concrete vote-margin error
    bound for latency.  Rows carry ``accuracy`` (on held-out labeled
    data), the reported ``bound``, and the REALIZED worst-case vote
    deficit (asserted ``<= bound`` — the bench fails if the bound lies).
  * ``anytime_full_*`` — the exact full-schedule baseline the other rows
    are normalized against.

Together the rows are the accuracy-vs-latency frontier the brownout
controller walks under overload.  scripts/check_bench.py gates the report
two-axis: the exact-early-exit row's ``us_per_call`` against the committed
baseline factor, and each quality tier's ``accuracy`` against its
committed baseline minus an absolute tolerance.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.sparse_infer import (_TRAIN_SAMPLES, SHAPES, _time_isolated,
                                     _train_artifact)
from repro.core import compiler, packetizer
from repro.data import make_boolean_classification
from repro.kernels import autotune as _autotune
from repro.kernels import ops

# accuracy floor is enforced relative to the committed baseline by
# scripts/check_bench.py (ANYTIME_ACC_TOL there); the bench itself only
# asserts the HARD guarantees: exactness of early exit and bound soundness

# Tile-granular tiling, PINNED rather than autotuned: the latency
# autotuner happily picks block_c ~ C (2 tiles total), which makes every
# quality prefix degenerate to the full walk and leaves early exit
# nothing to skip.  The anytime frontier needs tiles as its currency.
ANYTIME_BLOCKS = dict(block_c=256, block_j=32)


def _frontier(comp, lit, y, levels, sblocks, interpret, reps):
    """Time + score the full walk, exact early exit, and each budgeted
    prefix; returns (times, sums-per-mode) with exactness asserted."""

    def fwd(quality=0, early_exit=False):
        jitted = jax.jit(lambda l: compiler.run_compiled(
            comp, l, engine="sparse", quality=quality,
            early_exit=early_exit, interpret=interpret, **sblocks))
        return lambda: jitted(lit)

    fns = {"full": fwd(), "exact_ee": fwd(early_exit=True)}
    for q in levels:
        if q["level"] > 0:
            fns[f"q{q['level']}"] = fwd(quality=q["level"])
    t = _time_isolated(fns, reps)
    sums = {k: np.asarray(fn()) for k, fn in fns.items()}
    # the exact mode's contract: truncated sums, identical argmax
    np.testing.assert_array_equal(sums["full"].argmax(-1),
                                  sums["exact_ee"].argmax(-1))
    return t, sums


def run(fast: bool = True, reps: int = 3) -> list:
    _, interpret = ops.kernel_dispatch(True, None)
    rows = []
    for B, F, K, cpc in SHAPES[:1] if fast else SHAPES:
        cfg, _, comp = _train_artifact(F, K, cpc)
        W = comp.stats.n_words_dense
        # held-out labeled eval set: SAME seed (same class prototypes as
        # training — a different seed would be a different task and every
        # tier would score chance), fresh sample draws (the longer request
        # for n shifts the generator stream past the training set's X)
        Xe, ye = make_boolean_classification(
            _TRAIN_SAMPLES + B, F, K, prototype_density=0.05, seed=0)
        Xe, ye = Xe[-B:], ye[-B:]
        lit = jnp.asarray(packetizer.pack_literals(jnp.asarray(Xe)))

        sblocks = dict(ANYTIME_BLOCKS)
        levels = comp.quality_levels(
            engine="sparse", block_c=sblocks.get("block_c"),
            block_j=sblocks.get("block_j"))
        t, sums = _frontier(comp, lit, ye, levels, sblocks, interpret, reps)

        full = sums["full"]
        pred_full = full.argmax(-1)
        tag = f"b{B}_c{cfg.n_clauses_total}_w{W}_k{K}"
        n_tiles_full = levels[0]["n_tiles"]

        rows.append(dict(
            name=f"anytime_exact_ee_{tag}",
            us_per_call=t["exact_ee"] * 1e6,
            accuracy=float((pred_full == ye).mean()),
            level=0, bound=0,
            speedup_vs_full=t["full"] / t["exact_ee"],
            derived=(f"speedup_vs_full={t['full'] / t['exact_ee']:.2f}x;"
                     f"argmax_identical=True;n_tiles={n_tiles_full};"
                     + ";".join(f"{k}={v}" for k, v in sorted(
                         sblocks.items()))),
        ))
        rows.append(dict(
            name=f"anytime_full_{tag}",
            us_per_call=t["full"] * 1e6,
            accuracy=float((pred_full == ye).mean()),
            level=0, bound=0,
            derived=f"exact_full_walk;n_tiles={n_tiles_full}",
        ))
        for q in levels:
            if q["level"] == 0:
                continue
            s_q = sums[f"q{q['level']}"]
            pred_q = s_q.argmax(-1)
            # realized deficit: how many votes the served class trails the
            # true winner by, in EXACT sums — the quantity bound promises
            deficit = full[np.arange(len(full)), pred_full] \
                - full[np.arange(len(full)), pred_q]
            realized = int(deficit.max())
            assert realized <= q["bound"], (
                f"q{q['level']}: realized deficit {realized} exceeds the "
                f"reported bound {q['bound']}")
            rows.append(dict(
                name=f"anytime_q{q['level']}_{tag}",
                us_per_call=t[f"q{q['level']}"] * 1e6,
                accuracy=float((pred_q == ye).mean()),
                level=q["level"], bound=q["bound"],
                realized_err=realized,
                derived=(f"n_tiles={q['n_tiles']}/{n_tiles_full};"
                         f"frac={q['frac']};realized_err={realized};"
                         f"agree_exact="
                         f"{float((pred_q == pred_full).mean()):.4f}"),
            ))
    return rows


def write_report(rows: list, path: str = "BENCH_anytime.json") -> None:
    _, interpret = ops.kernel_dispatch(True, None)
    report = dict(
        benchmark="anytime",
        backend=jax.default_backend(),
        interpret_mode=bool(interpret),
        jax_version=jax.__version__,
        platform=platform.platform(),
        autotune_cache=_autotune.cache_path(),
        rows=rows,
    )
    with open(path, "w") as f:
        json.dump(report, f, indent=1)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_anytime.json")
    ap.add_argument("--full", action="store_true",
                    help="also run the paper-MNIST-width shape")
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args()
    t0 = time.time()
    rows = run(fast=not args.full, reps=args.reps)
    write_report(rows, args.out)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.3f},"
              f"accuracy={r['accuracy']:.4f};{r['derived']}")
    print(f"anytime bench wall: {time.time() - t0:.1f}s -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
