"""Version shims for core jax API drift.

``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
and renamed its replication-check kwarg (``check_rep`` -> ``check_vma``);
call through here so either jax generation works.

The compiled-executable introspection surface drifted too: the old
``jax.xla_computation`` idiom is gone (AOT ``jit(f).lower(...).compile()``
replaces it), and ``Compiled.cost_analysis()`` returns a plain dict on
newer jax but a one-per-device LIST of dicts on older releases.
``launch/hlo_analysis.py`` / ``launch/roofline.py`` and the autotuner cost
model all read these — they go through :func:`lower_compiled`,
:func:`cost_analysis`, and :func:`memory_analysis` so a jax upgrade breaks
one shim, not every analysis consumer.
"""

from __future__ import annotations

import jax


def axis_size(name):
    """``jax.lax.axis_size`` (newer jax) with a psum(1) fallback: the size
    of a named mesh axis from inside shard_map."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


def lower_compiled(fn, *args, **kwargs):
    """AOT-compile ``fn`` for the given abstract/concrete args and return
    the ``Compiled`` executable (the modern replacement for the retired
    ``jax.xla_computation`` idiom).  ``compiled.as_text()`` is the
    post-optimization HLO that ``launch/hlo_analysis.parse_hlo`` consumes.
    """
    return jax.jit(fn).lower(*args, **kwargs).compile()


def cost_analysis(compiled):
    """``Compiled.cost_analysis()`` normalized to ONE dict (or None).

    Older jax returns a list with one entry per device; newer jax returns
    the dict directly.  Callers should never see the list shape.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):      # older jax: one dict per device
        ca = ca[0] if ca else None
    return ca if isinstance(ca, dict) else None


def memory_analysis(compiled):
    """``Compiled.memory_analysis()`` normalized to one object (or None) —
    same one-per-device list drift as :func:`cost_analysis`."""
    try:
        ma = compiled.memory_analysis()
    except Exception:                      # backend without the analysis
        return None
    if isinstance(ma, (list, tuple)):
        ma = ma[0] if ma else None
    return ma
