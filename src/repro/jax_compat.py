"""Version shims for core jax API drift.

``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
and renamed its replication-check kwarg (``check_rep`` -> ``check_vma``);
call through here so either jax generation works.
"""

from __future__ import annotations

import jax


def axis_size(name):
    """``jax.lax.axis_size`` (newer jax) with a psum(1) fallback: the size
    of a named mesh axis from inside shard_map."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )
