"""Multi-head Latent Attention (DeepSeek-V2): low-rank compressed KV cache.

The KV cache stores only the kv_lora-dim latent + the shared rope key
(kv_lora + rope_head_dim per token, vs 2*K*hd for GQA) — the arch's defining
serving optimization, reflected directly in the dry-run memory analysis.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.attention import NEG_INF, flash_attention
from repro.models.config import ModelConfig


def init_mla(rng, cfg: ModelConfig, dtype) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    nope, rope_d = cfg.resolved_head_dim, cfg.rope_head_dim
    vd = cfg.v_head_dim or nope
    r = jax.random.split(rng, 8)
    p = {
        # queries (optionally low-rank)
        "wq_a": layers.init_dense(r[0], d, cfg.q_lora, dtype),
        "q_norm": jnp.zeros((cfg.q_lora,), dtype),
        "wq_b": layers.init_dense(r[1], cfg.q_lora, H * (nope + rope_d), dtype)
        .reshape(cfg.q_lora, H, nope + rope_d),
        # compressed kv latent + shared rope key
        "wkv_a": layers.init_dense(r[2], d, cfg.kv_lora + rope_d, dtype),
        "kv_norm": jnp.zeros((cfg.kv_lora,), dtype),
        "wk_b": layers.init_dense(r[3], cfg.kv_lora, H * nope, dtype)
        .reshape(cfg.kv_lora, H, nope),
        "wv_b": layers.init_dense(r[4], cfg.kv_lora, H * vd, dtype)
        .reshape(cfg.kv_lora, H, vd),
        "wo": layers.init_dense(r[5], H * vd, d, dtype).reshape(H, vd, d),
    }
    return p


def _mla_qkv(cfg: ModelConfig, params, x, positions):
    nope, rope_d = cfg.resolved_head_dim, cfg.rope_head_dim
    q_lat = layers.rms_norm(x @ params["wq_a"], params["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_lat, params["wq_b"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)

    kv = x @ params["wkv_a"]                                  # (B,S,kv_lora+rope)
    c_kv = layers.rms_norm(kv[..., : cfg.kv_lora], params["kv_norm"], cfg.norm_eps)
    k_rope = layers.apply_rope(
        kv[..., cfg.kv_lora :][:, :, None, :], positions, cfg.rope_theta
    )                                                          # (B,S,1,rope)
    return q_nope, q_rope, c_kv, k_rope


def _expand_kv(cfg, params, c_kv, k_rope):
    """Latent -> per-head K/V (B,S,H,nope+rope) and (B,S,H,vd)."""
    nope = cfg.resolved_head_dim
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["wv_b"])
    H = k_nope.shape[2]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_rope.shape[:2] + (H, k_rope.shape[-1]))],
        axis=-1,
    )
    return k, v


def mla_block(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    cache: Optional[dict] = None,
    ctx=None,
) -> Tuple[jax.Array, Optional[dict]]:
    from repro.models.attention import constrain_heads

    q_nope, q_rope, c_kv, k_rope = _mla_qkv(cfg, params, x, positions)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)            # (B,S,H,nope+rope)
    q = constrain_heads(ctx, q)

    if cache is None:
        k, v = _expand_kv(cfg, params, c_kv, k_rope)
        k = constrain_heads(ctx, k)
        v = constrain_heads(ctx, v)
        out = flash_attention(q, k, v, positions, positions)
    else:
        pos = cache["pos"]
        cc = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, pos, 0))
        cr = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope[:, :, 0, :], (0, pos, 0)
        )
        cache = {"c_kv": cc, "k_rope": cr, "pos": pos + x.shape[1]}
        if x.shape[1] == 1:
            out = _mla_decode(cfg, params, q, cc, cr, positions)
        else:
            k, v = _expand_kv(cfg, params, cc, cr[:, :, None, :])
            S_max = cc.shape[1]
            kv_pos = jnp.broadcast_to(
                jnp.arange(S_max, dtype=positions.dtype)[None, :],
                (x.shape[0], S_max),
            )
            kv_pos = jnp.where(kv_pos < pos + x.shape[1], kv_pos, jnp.int32(2**30))
            out = flash_attention(q, k, v, positions, kv_pos)

    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, cache


def _mla_decode(cfg, params, q, c_kv, k_rope, positions):
    """Latent-space decode: absorb wk_b/wv_b into the query/output so the
    (B, T, kv_lora) cache is attended directly (no per-head K/V expansion)."""
    nope = cfg.resolved_head_dim
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    # absorb k up-projection: q_lat[b,h,r] = sum_k q_nope[b,1,h,k] wk_b[r,h,k]
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, params["wk_b"])[:, 0]
    s = jnp.einsum("bhr,btr->bht", q_lat.astype(jnp.float32), c_kv.astype(jnp.float32))
    s += jnp.einsum(
        "bshk,btk->bht", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32)
    )
    s *= (nope + cfg.rope_head_dim) ** -0.5
    T = c_kv.shape[1]
    mask = jnp.arange(T, dtype=positions.dtype)[None, :] <= positions[:, :1]
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bht,btr->bhr", p, c_kv.astype(jnp.float32))  # (B,H,r)
    out = jnp.einsum("bhr,rhk->bhk", o_lat, params["wv_b"].astype(jnp.float32))
    return out[:, None].astype(q.dtype)                       # (B,1,H,vd)


def init_mla_cache(cfg: ModelConfig, batch: int, s_max: int, dtype) -> dict:
    return {
        "c_kv": jnp.zeros((batch, s_max, cfg.kv_lora), dtype),
        "k_rope": jnp.zeros((batch, s_max, cfg.rope_head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
