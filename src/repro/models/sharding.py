"""Partitioning rules: param/optimizer/cache/batch PartitionSpecs per arch.

Name-based rules (MaxText-style logical axes, resolved against the physical
mesh with divisibility fallbacks):
  * tensor parallelism over ``model``: attention heads, d_ff, vocab,
    MoE expert dim, recurrent width;
  * FSDP over ``data`` in train mode (the non-TP dim of every large matrix);
  * batch over (``pod``, ``data``); KV caches heads-then-head_dim over
    ``model`` with sequence-over-``data`` fallback for batch=1 serving.

Every spec is validated for divisibility against the actual mesh; an axis
that does not divide is dropped (replicated) rather than failing — small
models on big meshes lower cleanly.
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, GetAttrKey, SequenceKey

from repro.models.config import ModelConfig


def axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, np.shape(mesh.devices)))


def _fit(spec_axes, dim: int, sizes: dict):
    """Return spec entry if dim divides the (product of) mesh axes, else None."""
    if spec_axes is None:
        return None
    axes = spec_axes if isinstance(spec_axes, tuple) else (spec_axes,)
    axes = tuple(a for a in axes if a in sizes)
    if not axes:
        return None
    total = int(np.prod([sizes[a] for a in axes]))
    if total == 0 or dim % total != 0:
        return None
    return axes if len(axes) > 1 else axes[0]


def _mk(sizes: dict, shape, *axes) -> P:
    assert len(axes) == len(shape), (axes, shape)
    return P(*[_fit(a, d, sizes) for a, d in zip(axes, shape)])


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, DictKey):
            return str(entry.key)
        if isinstance(entry, GetAttrKey):
            return entry.name
    return ""


def _in_groups(path) -> bool:
    return any(isinstance(e, DictKey) and e.key == "groups" for e in path)


_REPLICATED = {
    "norm1", "norm2", "final_norm", "q_norm", "k_norm", "kv_norm",
    "out_norm", "router", "pos", "steps",
}


def _param_rule(cfg: ModelConfig, name: str, shape, fsdp, sizes) -> P:
    nd = len(shape)
    if name in _REPLICATED or nd == 0:
        return P(*([None] * nd))
    if name == "embed":
        return _mk(sizes, shape, "model", fsdp)
    if name == "unembed":
        return _mk(sizes, shape, fsdp, "model")
    if name == "lam":
        return _mk(sizes, shape, "model")
    # attention: shard the (expanded) head axis; when n_heads does not divide
    # the model axis (36 or 15 heads on 16-way TP), shard head_dim instead so
    # q and kv stay contraction-consistent.  GQA kv with K < model axis is
    # replicated (cheap) and sharded post-expansion.
    heads_ok = _fit("model", cfg.n_heads, sizes) is not None
    if name in ("wq", "wk", "wv") and nd == 3 and shape[0] not in (cfg.n_heads,):
        if heads_ok:
            return _mk(sizes, shape, fsdp, "model", None)
        return _mk(sizes, shape, fsdp, None, "model")
    if name == "wo":
        if heads_ok:
            return _mk(sizes, shape, "model", None, fsdp)
        # non-divisible heads: replicate wo (small) — an hd-sharded wo makes
        # the output projection a (B, S, d) partial-sum all-reduce per layer
        return _mk(sizes, shape, None, None, fsdp)
    # mla
    if name == "wq_a":
        return _mk(sizes, shape, fsdp, "model")
    if name == "wq_b":
        return _mk(sizes, shape, None, "model", None)
    if name == "wkv_a":
        return _mk(sizes, shape, fsdp, None)
    if name in ("wk_b", "wv_b"):
        return _mk(sizes, shape, None, "model", None)
    # MoE expert banks (E, d, fe) / (E, fe, d) — expert-parallel over model,
    # ZeRO-3 over data (the shard_map in_specs re-gather at use)
    if name in ("gate", "up", "down") and nd == 3:
        return _mk(sizes, shape, "model", fsdp, None)
    # dense mlp
    if name in ("gate", "up"):
        return _mk(sizes, shape, fsdp, "model")
    if name == "down":
        return _mk(sizes, shape, "model", fsdp)
    # rglru
    if name in ("wx", "wgate"):
        return _mk(sizes, shape, fsdp, "model")
    if name == "conv":
        return _mk(sizes, shape, None, "model")
    if name in ("w_r", "w_i") and nd == 2 and shape[0] == shape[1]:
        return _mk(sizes, shape, None, "model")
    if name == "wout":
        return _mk(sizes, shape, "model", fsdp)
    # mlstm / slstm
    if name in ("w_up", "w_gate"):
        return _mk(sizes, shape, fsdp, "model")
    if name in ("wq", "wk", "wv") and nd == 3:        # (H, dh, dh) block-diag
        return _mk(sizes, shape, None, None, "model")
    if name in ("w_f", "w_i") and nd == 2:
        return _mk(sizes, shape, "model", None)
    if name == "w_down":
        return _mk(sizes, shape, "model", fsdp)
    if name in ("w_z", "w_o") or (name.startswith("w_") and nd == 2):
        return _mk(sizes, shape, fsdp, "model")
    if name.startswith("r_") and nd == 3:
        return _mk(sizes, shape, None, None, "model")
    if name == "w_out":
        return _mk(sizes, shape, "model", fsdp)
    return P(*([None] * nd))


def param_specs(cfg: ModelConfig, params_tree, mesh: Mesh, *, train: bool,
                pure_dp: bool = False):
    """PartitionSpec pytree matching ``params_tree`` (shapes or arrays).

    ``pure_dp``: drop all tensor-parallel ("model") placements — params are
    ZeRO-sharded over ``data`` only and gathered at use (small models whose
    batch covers the mesh)."""
    sizes = axis_sizes(mesh)
    fsdp = "data" if train else None

    def strip_model(spec):
        return P(*[
            None if a == "model" else a
            for a in (tuple(spec) + (None,) * 8)[: len(spec)]
        ])

    def rule(path, leaf):
        name = _leaf_name(path)
        shape = tuple(leaf.shape)
        if _in_groups(path) and shape:
            spec = _param_rule(cfg, name, shape[1:], fsdp, sizes)
            spec = strip_model(spec) if pure_dp else spec
            return P(*((None,) + tuple(spec)))
        spec = _param_rule(cfg, name, shape, fsdp, sizes)
        return strip_model(spec) if pure_dp else spec

    return jax.tree_util.tree_map_with_path(rule, params_tree)


def _cache_rule(cfg: ModelConfig, name: str, shape, dp, sizes) -> P:
    nd = len(shape)
    if name == "pos" or nd == 0:
        return P(*([None] * nd))
    b_ok = _fit(dp, shape[0], sizes) is not None if nd else False
    bspec = dp if b_ok else None
    # sequence axis of attention caches: absorbs the data axes when batch=1
    # (long-context serving) and the model axis when kv heads don't divide it
    # (decode attention over a seq-sharded cache needs only tiny softmax-stat
    # collectives, vs. huge score psums for head_dim-sharded contraction).
    def seq_axes(head_shardable: bool):
        ax = [] if b_ok else list(dp)
        if not head_shardable:
            ax.append("model")
        return tuple(ax) if ax else None

    if name in ("k", "v") and nd == 4:                 # (B, S, K, hd)
        k_ok = _fit("model", shape[2], sizes) is not None
        return _mk(
            sizes, shape, bspec, seq_axes(k_ok), "model" if k_ok else None, None
        )
    if name == "kv_pos":
        return _mk(sizes, shape, bspec, seq_axes(False))
    if name == "c_kv":                                  # (B, S, kv_lora)
        return _mk(sizes, shape, bspec, seq_axes(False), None)
    if name == "k_rope":
        return _mk(sizes, shape, bspec, seq_axes(False), None)
    if name == "h" and nd == 2:                         # rglru (B, w)
        return _mk(sizes, shape, bspec, "model")
    if name == "conv" and nd == 3:
        return _mk(sizes, shape, bspec, None, "model")
    if name == "S" and nd == 4:                         # mlstm (B,H,dk,dv)
        return _mk(sizes, shape, bspec, None, None, "model")
    if name == "n" and nd == 3:
        return _mk(sizes, shape, bspec, None, None)
    if name in ("c", "h", "m") and nd == 3:             # slstm (B,H,dh)
        return _mk(sizes, shape, bspec, None, "model")
    return P(*([None] * nd))


def cache_specs(cfg: ModelConfig, cache_tree, mesh: Mesh):
    sizes = axis_sizes(mesh)
    dp = tuple(a for a in ("pod", "data") if a in sizes)

    def rule(path, leaf):
        name = _leaf_name(path)
        shape = tuple(leaf.shape)
        if shape:  # caches are scan-stacked: leading repeats dim
            spec = _cache_rule(cfg, name, shape[1:], dp, sizes)
            return P(*((None,) + tuple(spec)))
        return P()

    return jax.tree_util.tree_map_with_path(rule, cache_tree)


def batch_specs(cfg: ModelConfig, batch_tree, mesh: Mesh, *, pure_dp: bool = False):
    sizes = axis_sizes(mesh)
    axes = ("pod", "data", "model") if pure_dp else ("pod", "data")
    dp = tuple(a for a in axes if a in sizes)

    def rule(path, leaf):
        shape = tuple(leaf.shape)
        rest = [None] * (len(shape) - 1)
        return P(_fit(dp, shape[0], sizes), *rest)

    return jax.tree_util.tree_map_with_path(rule, batch_tree)


def to_named(tree, mesh: Mesh):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), tree,
        is_leaf=lambda x: isinstance(x, P),
    )
