"""Step builders: train / prefill / decode, mesh-aware.

These are the functions the launcher jits (and the dry-run lowers):
  * train_step:   (params, opt_state, batch) -> (params', opt_state', metrics)
  * prefill_step: (params, batch, caches) -> (last-token logits, caches')
  * decode_step:  (params, caches, inputs, pos) -> (logits, caches')
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.transformer import RunCtx
from repro.optim import adamw


def make_train_step(
    cfg: ModelConfig,
    mesh=None,
    opt_cfg: Optional[adamw.AdamWConfig] = None,
    remat: bool = True,
    microbatches: int = 1,
    pure_dp: bool = False,
):
    """Train step; ``microbatches > 1`` scans gradient accumulation over
    batch slices (activation memory / n_micro — how the 200B+ MoE cells fit
    a 16 GB v5e at global batch 256)."""
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    ctx = RunCtx(mesh=mesh, pure_dp=pure_dp)

    def loss_fn(p, b):
        return transformer.loss_fn(cfg, p, b, ctx=ctx, remat=remat)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                loss_acc, g_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (loss_acc + l, g_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.float32(0.0), g0), micro
            )
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        new_params, new_opt, info = adamw.adamw_update(
            opt_cfg, grads, params, opt_state
        )
        return new_params, new_opt, dict(info, loss=loss)

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh=None):
    ctx = RunCtx(mesh=mesh)

    def prefill_step(params, batch, caches):
        hidden, caches = transformer.forward(
            cfg,
            params,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            caches=caches,
            ctx=ctx,
        )
        w = transformer.unembed_matrix(cfg, params)
        logits = (hidden[:, -1] @ w).astype(jnp.float32)
        return logits, caches

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh=None):
    ctx = RunCtx(mesh=mesh)

    def decode_step(params, caches, inputs, pos):
        B = (inputs.get("tokens") if "tokens" in inputs else inputs["embeds"]).shape[0]
        positions = jnp.full((B, 1), pos, jnp.int32)
        hidden, caches = transformer.forward(
            cfg,
            params,
            tokens=inputs.get("tokens"),
            embeds=inputs.get("embeds"),
            positions=positions,
            caches=caches,
            ctx=ctx,
        )
        w = transformer.unembed_matrix(cfg, params)
        logits = (hidden[:, -1] @ w).astype(jnp.float32)
        return logits, caches

    return decode_step
