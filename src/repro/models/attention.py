"""Attention: GQA (+ qk-norm, RoPE, local windows), flash-style chunked
softmax for train/prefill, dense single-step for decode.

The chunked path is pure jnp (lax.scan with online-softmax accumulators) so
it lowers/partitions under GSPMD for the dry-run; on real TPU it is the
shape XLA pattern-matches well, and a Pallas flash kernel can drop in behind
the same signature.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig

NEG_INF = -1e30


def init_attention(rng, cfg: ModelConfig, dtype) -> dict:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    r = jax.random.split(rng, 4)
    p = {
        "wq": layers.init_dense(r[0], d, H * hd, dtype).reshape(d, H, hd),
        "wk": layers.init_dense(r[1], d, K * hd, dtype).reshape(d, K, hd),
        "wv": layers.init_dense(r[2], d, K * hd, dtype).reshape(d, K, hd),
        "wo": layers.init_dense(r[3], H * hd, d, dtype).reshape(H, hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _qk_normalize(cfg: ModelConfig, params: dict, q, k):
    if cfg.qk_norm:
        q = layers.rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = layers.rms_norm(k, params["k_norm"], cfg.norm_eps)
    return q, k


def _project_qkv(cfg, params, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q, k = _qk_normalize(cfg, params, q, k)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _expand_kv(x: jax.Array, H: int) -> jax.Array:
    """(B, T, K, d) -> (B, T, H, d) by repeating each kv head H//K times.

    Sharding rationale: GSPMD cannot shard the grouped (K, G) reshape of q
    over a single mesh axis, which replicates the score tensors; expanding kv
    to the full head axis keeps everything sharded over ``model`` (the repeat
    fuses into the following dot, so no extra HBM traffic materializes).
    """
    K = x.shape[2]
    if K == H:
        return x
    return jnp.repeat(x, H // K, axis=2)


def _chunks(x, n, size):
    """(B, S, ...) -> (n, B, size, ...) leading-chunk layout for lax.scan."""
    B = x.shape[0]
    return x.reshape((B, n, size) + x.shape[2:]).swapaxes(0, 1)


def _unchunks(x):
    """(n, B, size, ...) -> (B, n*size, ...)."""
    n, B, size = x.shape[:3]
    return x.swapaxes(0, 1).reshape((B, n * size) + x.shape[3:])


def _flash_fwd(q, k, v, q_pos, kv_pos, window, q_chunk, kv_chunk):
    """Returns (out (B,S,H,dv), lse (B,S,H)) — online-softmax tiles."""
    B, S, H, hd = q.shape
    T, dv = k.shape[1], v.shape[-1]
    nq, nk = S // q_chunk, T // kv_chunk
    scale = hd**-0.5
    qs, qp = _chunks(q, nq, q_chunk), _chunks(q_pos, nq, q_chunk)
    ks, kp = _chunks(k, nk, kv_chunk), _chunks(kv_pos, nk, kv_chunk)
    vs = _chunks(v, nk, kv_chunk)

    def q_body(_, q_in):
        qc, qpc = q_in

        def kv_body(carry, kv_in):
            m, l, acc = carry
            kc, vc, kpc = kv_in
            s = jnp.einsum(
                "bqhd,bthd->bqht", qc.astype(jnp.float32), kc.astype(jnp.float32)
            ) * scale
            mask = kpc[:, None, :] <= qpc[:, :, None]
            if window:
                mask &= kpc[:, None, :] > qpc[:, :, None] - window
            s = jnp.where(mask[:, :, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqht,bthv->bqhv", p.astype(vc.dtype), vc
            ).astype(jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, q_chunk, H), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, H), jnp.float32)
        a0 = jnp.zeros((B, q_chunk, H, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), (ks, vs, kp))
        l_safe = jnp.maximum(l, 1e-30)
        out = acc / l_safe[..., None]
        lse = m + jnp.log(l_safe)
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_body, None, (qs, qp))
    return _unchunks(outs).astype(q.dtype), _unchunks(lses)


def _flash_tile_p(qc, kc, qpc, kpc, lse_c, scale, window):
    """Recompute the (q_chunk x kv_chunk) probability tile in the backward."""
    s = jnp.einsum(
        "bqhd,bthd->bqht", qc.astype(jnp.float32), kc.astype(jnp.float32)
    ) * scale
    mask = kpc[:, None, :] <= qpc[:, :, None]
    if window:
        mask &= kpc[:, None, :] > qpc[:, :, None] - window
    p = jnp.exp(s - lse_c[..., None])
    return jnp.where(mask[:, :, None, :], p, 0.0)            # (B,q,H,t)


@functools.lru_cache(maxsize=None)
def _flash_custom(window: int, q_chunk: int, kv_chunk: int):
    """Flash attention with a recomputing custom VJP.

    Residuals are only (q, k, v, positions, out, lse): the backward pass
    re-derives each probability tile — O(S) memory instead of the O(S^2)
    score matrices jax would otherwise stash for the scan backward (this is
    what made the naive train_4k dry-run need 39 GB/device of temps).
    """

    @jax.custom_vjp
    def flash(q, k, v, q_pos, kv_pos):
        # On TPU, plain causal attention dispatches to the Pallas kernel
        # (VMEM-resident tiles — kernels/flash_attention.py); the XLA path
        # below is the oracle/partitioning fallback and the CPU engine.
        if (
            jax.default_backend() == "tpu"
            and window == 0
            and q.shape[1] == k.shape[1]
        ):
            from repro.kernels.flash_attention import flash_forward

            return flash_forward(q, k, v, causal=True)
        out, _ = _flash_fwd(q, k, v, q_pos, kv_pos, window, q_chunk, kv_chunk)
        return out

    def fwd(q, k, v, q_pos, kv_pos):
        out, lse = _flash_fwd(q, k, v, q_pos, kv_pos, window, q_chunk, kv_chunk)
        return out, (q, k, v, q_pos, kv_pos, out, lse)

    def bwd(res, do):
        q, k, v, q_pos, kv_pos, out, lse = res
        B, S, H, hd = q.shape
        T, dv = k.shape[1], v.shape[-1]
        nq, nk = S // q_chunk, T // kv_chunk
        scale = hd**-0.5
        delta = jnp.sum(
            do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1
        )                                                     # (B,S,H)

        qs, qp = _chunks(q, nq, q_chunk), _chunks(q_pos, nq, q_chunk)
        ks, kp = _chunks(k, nk, kv_chunk), _chunks(kv_pos, nk, kv_chunk)
        vs = _chunks(v, nk, kv_chunk)
        dos, lses = _chunks(do, nq, q_chunk), _chunks(lse, nq, q_chunk)
        deltas = _chunks(delta, nq, q_chunk)

        # pass A: dq (scan q tiles; reduce over kv tiles)
        def dq_body(_, q_in):
            qc, qpc, doc, lse_c, dc = q_in

            def inner(dq_acc, kv_in):
                kc, vc, kpc = kv_in
                p = _flash_tile_p(qc, kc, qpc, kpc, lse_c, scale, window)
                dp = jnp.einsum(
                    "bqhv,bthv->bqht", doc.astype(jnp.float32), vc.astype(jnp.float32)
                )
                ds = p * (dp - dc[..., None])
                dq_acc += jnp.einsum(
                    "bqht,bthd->bqhd", ds.astype(kc.dtype), kc
                ).astype(jnp.float32) * scale
                return dq_acc, None

            dq0 = jnp.zeros((B, q_chunk, H, hd), jnp.float32)
            dq_c, _ = jax.lax.scan(inner, dq0, (ks, vs, kp))
            return None, dq_c

        _, dqs = jax.lax.scan(dq_body, None, (qs, qp, dos, lses, deltas))

        # pass B: dk, dv (scan kv tiles; reduce over q tiles)
        def dkv_body(_, kv_in):
            kc, vc, kpc = kv_in

            def inner(carry, q_in):
                dk_acc, dv_acc = carry
                qc, qpc, doc, lse_c, dc = q_in
                p = _flash_tile_p(qc, kc, qpc, kpc, lse_c, scale, window)
                dv_acc += jnp.einsum(
                    "bqht,bqhv->bthv", p.astype(doc.dtype), doc
                ).astype(jnp.float32)
                dp = jnp.einsum(
                    "bqhv,bthv->bqht", doc.astype(jnp.float32), vc.astype(jnp.float32)
                )
                ds = p * (dp - dc[..., None])
                dk_acc += jnp.einsum(
                    "bqht,bqhd->bthd", ds.astype(qc.dtype), qc
                ).astype(jnp.float32) * scale
                return (dk_acc, dv_acc), None

            z = (
                jnp.zeros((B, kv_chunk, H, hd), jnp.float32),
                jnp.zeros((B, kv_chunk, H, dv), jnp.float32),
            )
            (dk_c, dv_c), _ = jax.lax.scan(inner, z, (qs, qp, dos, lses, deltas))
            return None, (dk_c, dv_c)

        _, (dks, dvs) = jax.lax.scan(dkv_body, None, (ks, vs, kp))

        dq = _unchunks(dqs).astype(q.dtype)
        dk = _unchunks(dks).astype(k.dtype)
        dv = _unchunks(dvs).astype(v.dtype)
        import numpy as _np

        f0 = lambda x: _np.zeros(x.shape, jax.dtypes.float0)
        return dq, dk, dv, f0(q_pos), f0(kv_pos)

    flash.defvjp(fwd, bwd)
    return flash


def flash_attention(
    q: jax.Array,            # (B, S, H, hd)
    k: jax.Array,            # (B, T, K, hd), K divides H
    v: jax.Array,            # (B, T, K, dv)
    q_pos: jax.Array,        # (B, S)
    kv_pos: jax.Array,       # (B, T)
    *,
    window: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Causal (optionally windowed) attention, online softmax, O(S) memory
    in both directions (recomputing custom VJP)."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    while S % q_chunk:
        q_chunk //= 2
    while T % kv_chunk:
        kv_chunk //= 2
    return _flash_custom(window, q_chunk, kv_chunk)(q, k, v, q_pos, kv_pos)


def constrain_heads(ctx, t: jax.Array) -> jax.Array:
    """Pin (B, S, H, hd) attention activations to a shardable layout.

    Heads over ``model`` when they divide it.  When they don't (10/15/36
    heads on 16-way TP), shard the *sequence* instead — context-parallel
    attention: every score tile is then fully local and kv is a small
    all-gather.  The previously-tried head_dim fallback turns every flash
    tile into a partial-sum all-reduce (measured 1.3 TB of collective
    traffic on recurrentgemma prefill_32k — EXPERIMENTS.md §Perf).
    Decode (S == 1) falls back to replicated — its tensors are tiny and the
    KV cache is already sequence-sharded by models/sharding.py.
    """
    if ctx is None or ctx.mesh is None or t.ndim != 4:
        return t
    from jax.sharding import PartitionSpec as P

    if getattr(ctx, "pure_dp", False):
        return ctx.constrain(t, P(ctx.dp_axes, None, None, None))
    size = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape)).get("model", 1)
    if t.shape[2] % size == 0:
        spec = P(ctx.dp_axes, None, "model", None)
    elif t.shape[1] % size == 0 and t.shape[1] > 1:
        spec = P(ctx.dp_axes, "model", None, None)
    else:
        spec = P(ctx.dp_axes, None, None, None)
    return ctx.constrain(t, spec)


def attention_block(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,             # (B, S, d)
    positions: jax.Array,     # (B, S)
    *,
    kind: str,                # "attn" | "local"
    cache: Optional[dict] = None,
    ctx=None,
) -> Tuple[jax.Array, Optional[dict]]:
    """Self-attention with optional KV cache (decode/prefill)."""
    window = cfg.window if kind == "local" else 0
    q, k, v = _project_qkv(cfg, params, x, positions)
    q = constrain_heads(ctx, q)

    if cache is None:
        k = constrain_heads(ctx, _expand_kv(k, cfg.n_heads))
        v = constrain_heads(ctx, _expand_kv(v, cfg.n_heads))
        out = flash_attention(q, k, v, positions, positions, window=window)
        out = constrain_heads(ctx, out)
        new_cache = None
    elif "kv_pos" in cache:
        out, new_cache = _ring_cache_attention(
            cfg, params, q, k, v, positions, window, cache
        )
    else:
        S_max = cache["k"].shape[1]
        pos = cache["pos"]                                 # int32 scalar
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
        new_cache = {"k": ck, "v": cv, "pos": pos + x.shape[1]}
        if x.shape[1] == 1:  # decode: dense single-query attention
            kv_pos = jnp.broadcast_to(
                jnp.arange(S_max, dtype=positions.dtype)[None, :],
                (x.shape[0], S_max),
            )
            out = _decode_attention(cfg, q, ck, cv, positions, kv_pos, window)
        else:                # prefill through cache
            kv_pos = jnp.broadcast_to(
                jnp.arange(S_max, dtype=positions.dtype)[None, :],
                (x.shape[0], S_max),
            )
            valid = kv_pos[:, :] < (pos + x.shape[1])
            kv_pos = jnp.where(valid, kv_pos, jnp.int32(2**30))  # mask empties
            out = flash_attention(q, ck, cv, positions, kv_pos, window=window)

    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache


def _ring_cache_attention(cfg, params, q, k, v, positions, window, cache):
    """Sliding-window ring-buffer KV cache (slot = position % ring).

    Prefill is assumed to start at position 0 (the framework's serving flow);
    a windowed prefill never needs context older than the window anyway.
    """
    B, S = q.shape[0], q.shape[1]
    ring = cache["k"].shape[1]
    pos = cache["pos"]
    if S == 1:  # decode
        slot = pos % ring
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        kv_pos = jax.lax.dynamic_update_slice(
            cache["kv_pos"], jnp.broadcast_to(pos, (B, 1)), (0, slot)
        )
        out = _decode_attention(cfg, q, ck, cv, positions, kv_pos, window)
    else:       # prefill from 0: full windowed flash, then fill the ring
        out = flash_attention(q, k, v, positions, positions, window=window)
        r = min(S, ring)
        idx = (pos + S - r + jnp.arange(r)) % ring
        ck = cache["k"].at[:, idx].set(k[:, -r:])
        cv = cache["v"].at[:, idx].set(v[:, -r:])
        kv_pos = cache["kv_pos"].at[:, idx].set(positions[:, -r:])
    new_cache = {"k": ck, "v": cv, "kv_pos": kv_pos, "pos": pos + S}
    return out, new_cache


def _decode_attention(cfg, q, k, v, positions, kv_pos, window):
    """q: (B, 1, H, hd) against a cache (B, T, K, hd) with explicit kv_pos."""
    B, _, H, hd = q.shape
    T = k.shape[1]
    dv = v.shape[-1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    s = jnp.einsum(
        "bhd,bthd->bht", q[:, 0].astype(jnp.float32), k.astype(jnp.float32)
    ) * (hd**-0.5)                                         # (B,H,T)
    mask = (kv_pos >= 0) & (kv_pos <= positions[:, :1])    # (B,T)
    if window:
        mask &= kv_pos > positions[:, :1] - window
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bht,bthv->bhv", p.astype(v.dtype), v)
    return out.reshape(B, 1, H, dv).astype(q.dtype)


def init_cache(cfg: ModelConfig, batch: int, s_max: int, dtype) -> dict:
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, s_max, K, hd), dtype),
        "v": jnp.zeros((batch, s_max, K, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
