"""Mixture-of-Experts FF with expert parallelism (GShard-style capacity).

Routing: softmax router (f32), top-k experts per token, renormalized gates.
Dispatch: per-expert top-capacity token selection — each expert picks its
``capacity`` highest-gate tokens (tokens beyond capacity are dropped, the
standard GShard semantics).  Unrouted slots gather token 0 with gate 0, so
they contribute nothing — no masks needed.

Parallelism: experts are sharded over the ``model`` mesh axis.  Under
``shard_map`` each model shard computes only its local experts against the
(replicated-over-model) token block and the partial outputs are ``psum``-ed —
i.e. expert parallelism with an all-reduce combine.  Without a mesh the same
code runs with all experts local (smoke tests).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import jax_compat
from repro.models import layers
from repro.models.config import ModelConfig


def init_moe(rng, cfg: ModelConfig, dtype) -> dict:
    d, E, fe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    r = jax.random.split(rng, 5)
    p = {
        "router": layers.init_dense(r[0], d, E, jnp.float32),
        "gate": (jax.random.normal(r[1], (E, d, fe)) * d**-0.5).astype(dtype),
        "up": (jax.random.normal(r[2], (E, d, fe)) * d**-0.5).astype(dtype),
        "down": (jax.random.normal(r[3], (E, fe, d)) * fe**-0.5).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = layers.init_mlp(
            r[4], d, cfg.n_shared_experts * fe, dtype
        )
    return p


def _route(cfg: ModelConfig, router_w, x_flat):
    """x_flat: (T, d) -> gates (T, E) f32 with top-k renormalized weights."""
    logits = (x_flat.astype(jnp.float32) @ router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_v, top_i = jax.lax.top_k(probs, cfg.top_k)             # (T, k)
    top_v = top_v / jnp.maximum(jnp.sum(top_v, -1, keepdims=True), 1e-9)
    gates = jnp.zeros_like(probs)
    T = probs.shape[0]
    gates = gates.at[jnp.arange(T)[:, None], top_i].set(top_v)
    return gates                                               # (T, E)


def _expert_compute(cfg: ModelConfig, gates_loc, x_flat, gate_w, up_w, down_w):
    """gates_loc: (T, E_loc) f32; x_flat: (T, d); weights (E_loc, d|fe, ...).

    Each local expert selects its top-``capacity`` tokens by gate weight and
    runs a SwiGLU FF on the gathered block; results scatter-add back.
    """
    T = x_flat.shape[0]
    E_loc = gates_loc.shape[1]
    cap = min(
        T,
        max(8, int(T * cfg.top_k * cfg.capacity_factor / max(cfg.n_experts, 1))),
    )
    w_sel, idx = jax.lax.top_k(gates_loc.T, cap)               # (E_loc, cap)
    xe = x_flat[idx.reshape(-1)].reshape(E_loc, cap, -1)       # (E_loc, cap, d)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, gate_w))
    h = g * jnp.einsum("ecd,edf->ecf", xe, up_w)
    out_e = jnp.einsum("ecf,efd->ecd", h, down_w)              # (E_loc, cap, d)
    out_e = out_e * w_sel[..., None].astype(out_e.dtype)
    out = jnp.zeros_like(x_flat)
    return out.at[idx.reshape(-1)].add(out_e.reshape(E_loc * cap, -1))


def _moe_local(cfg: ModelConfig, params: dict, x: jax.Array, axis: Optional[str]):
    """Runs on one model shard (or the whole device when axis is None)."""
    B, S, d = x.shape
    x_flat = x.reshape(B * S, d)
    gates = _route(cfg, params["router"], x_flat)              # (T, E) global

    if axis is None:
        gates_loc = gates
    else:
        n_shards = jax_compat.axis_size(axis)
        e_loc = cfg.n_experts // n_shards
        e0 = jax.lax.axis_index(axis) * e_loc
        gates_loc = jax.lax.dynamic_slice_in_dim(gates, e0, e_loc, axis=1)

    out = _expert_compute(
        cfg, gates_loc, x_flat, params["gate"], params["up"], params["down"]
    )
    if axis is not None:
        out = jax.lax.psum(out, axis)
    return out.reshape(B, S, d)


def moe_ff(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,
    mesh: Optional[Mesh] = None,
    dp_axes: tuple = (),
) -> jax.Array:
    """(B, S, d) -> (B, S, d) MoE feed-forward (+ shared experts)."""
    if mesh is not None and "model" in mesh.axis_names:
        routed = jax_compat.shard_map(
            lambda p, xx: _moe_local(cfg, p, xx, "model"),
            mesh=mesh,
            in_specs=(
                {
                    "router": P(),
                    "gate": P("model", None, None),
                    "up": P("model", None, None),
                    "down": P("model", None, None),
                },
                P(dp_axes, None, None),
            ),
            out_specs=P(dp_axes, None, None),
            check_vma=False,
        )({k: params[k] for k in ("router", "gate", "up", "down")}, x)
    else:
        routed = _moe_local(
            cfg, {k: params[k] for k in ("router", "gate", "up", "down")}, x, None
        )
    if cfg.n_shared_experts:
        routed = routed + layers.mlp(params["shared"], x)
    return routed
