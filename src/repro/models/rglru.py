"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Real-Gated Linear Recurrent Unit: diagonal recurrence
    a_t = exp(-c * softplus(Lambda) * r_t),     c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)
with input/recurrence gates r_t, i_t = sigmoid(linear(u_t)).  Training and
prefill use ``jax.lax.associative_scan`` over the sequence (log-depth,
sub-quadratic — this arch runs the ``long_500k`` cell); decode carries
(h, conv) state.  Block = gated branch merge as in Griffin:
    out = W_out( gelu(W_gate x) * RG-LRU(conv4(W_x x)) ).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig

_C = 8.0


def init_rglru(rng, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    w = cfg.rnn_width or d
    r = jax.random.split(rng, 6)
    # Lambda init so a ~ U[0.9, 0.999] at r=1 (Griffin appendix)
    lam = jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, w)) / _C))
    return {
        "wx": layers.init_dense(r[0], d, w, dtype),
        "wgate": layers.init_dense(r[1], d, w, dtype),
        "conv": (jax.random.normal(r[2], (cfg.conv_width, w)) * 0.1).astype(dtype),
        "w_r": layers.init_dense(r[3], w, w, dtype),
        "w_i": layers.init_dense(r[4], w, w, dtype),
        "lam": lam.astype(jnp.float32),
        "wout": layers.init_dense(r[5], w, d, dtype),
    }


def _causal_conv(u: jax.Array, w: jax.Array, state: Optional[jax.Array]):
    """Depthwise causal conv, width cw. u: (B,S,w); state: (B,cw-1,w)."""
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)                 # (B, S+cw-1, w)
    out = sum(
        full[:, i : i + u.shape[1], :] * w[i][None, None, :] for i in range(cw)
    )
    new_state = full[:, -(cw - 1) :, :] if cw > 1 else None
    return out, new_state


def _rglru_gates(params, u):
    r = jax.nn.sigmoid((u @ params["w_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ params["w_i"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r          # (B,S,w) f32
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * u.astype(jnp.float32)
    )
    return a, b


def rglru_block(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,             # (B, S, d)
    *,
    cache: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    gate = jax.nn.gelu(x @ params["wgate"])
    u = x @ params["wx"]
    u, conv_state = _causal_conv(
        u, params["conv"], cache["conv"] if cache is not None else None
    )
    a, b = _rglru_gates(params, u)

    S = x.shape[1]
    if cache is None or S > 1:
        # h_t = a_t h_{t-1} + b_t  via associative scan over seq; a cached
        # initial state folds into the first step's offset term.
        if cache is not None:
            b = b.at[:, 0, :].add(a[:, 0, :] * cache["h"])

        def combine(l, r):
            return (l[0] * r[0], l[1] * r[0] + r[1])

        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        new_cache = None
        if cache is not None:  # prefill-through-cache
            new_cache = {
                "h": h[:, -1, :], "conv": conv_state, "pos": cache["pos"] + S
            }
    else:
        h = a[:, 0] * cache["h"] + b[:, 0]                    # decode step
        new_cache = {"h": h, "conv": conv_state, "pos": cache["pos"] + 1}
        h = h[:, None, :]

    out = (gate * h.astype(gate.dtype)) @ params["wout"]
    return out, new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    w = cfg.rnn_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
