"""Shared LM building blocks: norms, RoPE, SwiGLU, embeddings, fused CE loss."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def init_dense(rng, d_in: int, d_out: int, dtype) -> jax.Array:
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32) * (d_in**-0.5)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU FF
# ---------------------------------------------------------------------------

def init_mlp(rng, d_model: int, d_ff: int, dtype, gated: bool = True) -> dict:
    r1, r2, r3 = jax.random.split(rng, 3)
    p = {
        "up": init_dense(r2, d_model, d_ff, dtype),
        "down": init_dense(r3, d_ff, d_model, dtype),
    }
    if gated:
        p["gate"] = init_dense(r1, d_model, d_ff, dtype)
    return p


def mlp(params: dict, x: jax.Array) -> jax.Array:
    if "gate" in params:  # SwiGLU
        g = jax.nn.silu(x @ params["gate"])
        return (g * (x @ params["up"])) @ params["down"]
    return jax.nn.gelu(x @ params["up"]) @ params["down"]


# ---------------------------------------------------------------------------
# Fused (chunked) softmax cross-entropy: never materializes full-seq logits
# ---------------------------------------------------------------------------

def chunked_ce_loss(
    x: jax.Array,           # (B, S, d) final hidden states
    w_unembed: jax.Array,   # (d, V)
    labels: jax.Array,      # (B, S) int32, -1 = masked
    n_chunks: int = 8,
) -> jax.Array:
    """Mean CE over unmasked positions, computing logits chunk-by-chunk."""
    B, S, d = x.shape
    while S % n_chunks:
        n_chunks //= 2
    xs = x.reshape(B, n_chunks, S // n_chunks, d).swapaxes(0, 1)
    ls = labels.reshape(B, n_chunks, S // n_chunks).swapaxes(0, 1)

    def body(carry, inp):
        tot, cnt = carry
        xc, lc = inp
        logits = (xc @ w_unembed).astype(jnp.float32)          # (B, s, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1
        )[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((lse - tgt) * mask)
        cnt = cnt + jnp.sum(mask)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (xs, ls)
    )
    return tot / jnp.maximum(cnt, 1.0)
