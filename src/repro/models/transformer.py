"""Unified causal-LM assembly over all layer families.

Layers are grouped into maximal runs of a repeating unit (the config
``pattern``) and executed with ``jax.lax.scan`` over stacked parameters —
compile time is O(#distinct units), not O(n_layers), which is what makes the
512-device dry-run of 60-94 layer models tractable (and is the standard
production structure, cf. MaxText).

Activation layout (DESIGN.md §5): block-boundary activations are sharded
(batch over data axes, sequence over ``model``) — Megatron-style sequence
parallelism; interior matmuls run tensor-parallel over ``model`` (GSPMD
inserts the all-gather / reduce-scatter pair).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import attention, layers, mla, moe, rglru, xlstm
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class RunCtx:
    """Distribution context threaded through the forward pass.

    ``pure_dp``: batch sharded over ALL mesh axes (ZeRO-3 data parallelism,
    no tensor parallelism) — the right layout when params are small and the
    global batch covers the chip count; TP contractions (e.g. the mLSTM
    head_dim psums) disappear entirely.
    """

    mesh: Optional[Mesh] = None
    seq_shard: bool = True  # shard boundary activations' seq dim over model
    pure_dp: bool = False

    @property
    def dp_axes(self) -> tuple:
        if self.mesh is None:
            return ()
        axes = ("pod", "data", "model") if self.pure_dp else ("pod", "data")
        return tuple(a for a in axes if a in self.mesh.axis_names)

    def constrain(self, x: jax.Array, spec: P) -> jax.Array:
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def boundary(self, x: jax.Array) -> jax.Array:
        """(B, S, d) layer-boundary constraint."""
        if self.mesh is None:
            return x
        seq = (
            "model"
            if (self.seq_shard and not self.pure_dp and x.shape[1] > 1)
            else None
        )
        return self.constrain(x, P(self.dp_axes, seq, None))


# ---------------------------------------------------------------------------
# Layer grouping
# ---------------------------------------------------------------------------

def _ff_kind(cfg: ModelConfig, layer_idx: int, kind: str) -> str:
    if kind == "mlstm":
        return "none"
    if kind == "slstm":
        return "dense43"
    if cfg.is_moe and layer_idx >= cfg.first_dense_layers:
        return "moe"
    return "dense"


def layer_specs(cfg: ModelConfig) -> list:
    return [
        (kind, _ff_kind(cfg, i, kind)) for i, kind in enumerate(cfg.layer_kinds)
    ]


def group_layers(cfg: ModelConfig) -> list:
    """[(unit: tuple[spec], repeats: int)] covering all layers in order."""
    specs = layer_specs(cfg)
    p = len(cfg.pattern)
    groups, i, L = [], 0, len(specs)
    while i < L:
        unit = tuple(specs[i : i + p])
        r = 0
        while i + (r + 1) * p <= L and tuple(specs[i + r * p : i + (r + 1) * p]) == unit:
            r += 1
        if r >= 1 and len(unit) == p:
            groups.append((unit, r))
            i += r * p
        else:
            groups.append(((specs[i],), 1))
            i += 1
    return groups


# ---------------------------------------------------------------------------
# Per-block init / apply
# ---------------------------------------------------------------------------

def _ff_dim(cfg: ModelConfig, ff: str) -> int:
    return int(4 * cfg.d_model / 3) if ff == "dense43" else cfg.d_ff


def init_block(cfg: ModelConfig, spec: Tuple[str, str], rng, dtype) -> dict:
    kind, ff = spec
    r = jax.random.split(rng, 4)
    p: dict = {"norm1": jnp.zeros((cfg.d_model,), dtype)}
    if kind in ("attn", "local"):
        p["mix"] = (
            mla.init_mla(r[0], cfg, dtype)
            if cfg.attn_kind == "mla"
            else attention.init_attention(r[0], cfg, dtype)
        )
    elif kind == "rec":
        p["mix"] = rglru.init_rglru(r[0], cfg, dtype)
    elif kind == "mlstm":
        p["mix"] = xlstm.init_mlstm(r[0], cfg, dtype)
    elif kind == "slstm":
        p["mix"] = xlstm.init_slstm(r[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if ff != "none":
        p["norm2"] = jnp.zeros((cfg.d_model,), dtype)
        if ff == "moe":
            p["ff"] = moe.init_moe(r[1], cfg, dtype)
        else:
            p["ff"] = layers.init_mlp(
                r[1], cfg.d_model, _ff_dim(cfg, ff), dtype, gated=cfg.gated_mlp
            )
    return p


def apply_block(
    cfg: ModelConfig,
    spec: Tuple[str, str],
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cache: Optional[dict],
    ctx: RunCtx,
):
    kind, ff = spec
    h = layers.rms_norm(x, params["norm1"], cfg.norm_eps)
    if kind in ("attn", "local"):
        if cfg.attn_kind == "mla":
            h, new_cache = mla.mla_block(
                cfg, params["mix"], h, positions, cache=cache, ctx=ctx
            )
        else:
            h, new_cache = attention.attention_block(
                cfg, params["mix"], h, positions, kind=kind, cache=cache, ctx=ctx
            )
    elif kind == "rec":
        h, new_cache = rglru.rglru_block(cfg, params["mix"], h, cache=cache)
    elif kind == "mlstm":
        h, new_cache = xlstm.mlstm_block(cfg, params["mix"], h, cache=cache)
    elif kind == "slstm":
        h, new_cache = xlstm.slstm_block(cfg, params["mix"], h, cache=cache)
    else:
        raise ValueError(kind)
    x = ctx.boundary(x + h)

    if ff != "none":
        h2 = layers.rms_norm(x, params["norm2"], cfg.norm_eps)
        if ff == "moe":
            moe_mesh = None if ctx.pure_dp else ctx.mesh
            h2 = moe.moe_ff(cfg, params["ff"], h2, moe_mesh, ctx.dp_axes)
        else:
            h2 = layers.mlp(params["ff"], h2)
        x = ctx.boundary(x + h2)
    return x, new_cache


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def init_block_cache(
    cfg: ModelConfig, spec: Tuple[str, str], batch: int, s_max: int, dtype
) -> dict:
    kind, _ = spec
    if kind == "attn":
        if cfg.attn_kind == "mla":
            return mla.init_mla_cache(cfg, batch, s_max, dtype)
        return attention.init_cache(cfg, batch, s_max, dtype)
    if kind == "local":
        ring = min(s_max, cfg.window) if cfg.window else s_max
        c = attention.init_cache(cfg, batch, ring, dtype)
        c["kv_pos"] = jnp.full((batch, ring), -1, jnp.int32)
        return c
    if kind == "rec":
        return rglru.init_rglru_cache(cfg, batch, dtype)
    if kind == "mlstm":
        return xlstm.init_mlstm_cache(cfg, batch)
    if kind == "slstm":
        return xlstm.init_slstm_cache(cfg, batch)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Whole-model params / forward
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, rng) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    groups = group_layers(cfg)
    r_embed, r_head, rng = jax.random.split(rng, 3)
    params: dict = {"final_norm": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.frontend != "audio_stub":
        params["embed"] = (
            jax.random.normal(r_embed, (cfg.vocab_size, cfg.d_model)) * 0.02
        ).astype(dtype)
    if not cfg.tie_embeddings:
        params["unembed"] = layers.init_dense(
            r_head, cfg.d_model, cfg.vocab_size * cfg.n_codebooks, dtype
        )

    gs = []
    for gi, (unit, repeats) in enumerate(groups):
        def init_unit(key, unit=unit):
            ks = jax.random.split(key, len(unit))
            return [init_block(cfg, spec, k, dtype) for spec, k in zip(unit, ks)]

        keys = jax.random.split(jax.random.fold_in(rng, gi), repeats)
        gs.append(jax.vmap(init_unit)(keys))  # leaves: (repeats, ...)
    params["groups"] = gs
    return params


def init_caches(cfg: ModelConfig, batch: int, s_max: int, dtype=None) -> list:
    dtype = dtype or jnp.dtype(cfg.dtype)
    caches = []
    for unit, repeats in group_layers(cfg):
        def one(_, unit=unit):
            return [init_block_cache(cfg, spec, batch, s_max, dtype) for spec in unit]

        caches.append(jax.vmap(one)(jnp.arange(repeats)))
    return caches


def unembed_matrix(cfg: ModelConfig, params: dict) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


def forward(
    cfg: ModelConfig,
    params: dict,
    *,
    tokens: Optional[jax.Array] = None,     # (B, S_txt) int32
    embeds: Optional[jax.Array] = None,     # (B, S_emb, d) stub frontend
    positions: Optional[jax.Array] = None,  # (B, S) int32
    caches: Optional[list] = None,
    ctx: RunCtx = RunCtx(),
    remat: bool = False,
):
    """Returns (hidden (B, S, d), new_caches or None)."""
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(jnp.dtype(cfg.dtype)))
    if tokens is not None:
        parts.append(params["embed"][tokens])
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = ctx.boundary(x)

    groups = group_layers(cfg)
    new_caches = [] if caches is not None else None
    for gi, (unit, repeats) in enumerate(groups):
        gp = params["groups"][gi]
        gc = caches[gi] if caches is not None else None

        def body(x, per_layer, unit=unit):
            p_unit, c_unit = per_layer
            if ctx.pure_dp and ctx.mesh is not None:
                # ZeRO-3 gather-at-use: without this GSPMD contracts against
                # data-sharded weights, psum-ing activations per layer
                p_unit = jax.tree.map(
                    lambda t: ctx.constrain(t, P(*([None] * t.ndim))), p_unit
                )
            ncs = []
            for li, spec in enumerate(unit):
                c = c_unit[li] if c_unit is not None else None

                # remat per BLOCK (not per unit): the backward holds one
                # block's recompute residuals at a time — for multi-block
                # units (griffin triplets, xlstm octets) this divides the
                # activation peak by the unit length.
                def block_fn(x, p, c, spec=spec):
                    return apply_block(cfg, spec, p, x, positions, c, ctx)

                fn = jax.checkpoint(block_fn) if remat else block_fn
                x, nc = fn(x, p_unit[li], c)
                ncs.append(nc if nc is not None else 0)
            return x, (ncs if caches is not None else 0)

        body_fn = body
        if repeats == 1:
            p0 = jax.tree.map(lambda a: a[0], gp)
            c0 = jax.tree.map(lambda a: a[0], gc) if gc is not None else None
            x, ncs = body_fn(x, (p0, c0))
            if caches is not None:
                new_caches.append(jax.tree.map(lambda a: a[None], ncs))
        else:
            x, ncs = jax.lax.scan(body_fn, x, (gp, gc))
            if caches is not None:
                new_caches.append(ncs)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_caches


def loss_fn(
    cfg: ModelConfig,
    params: dict,
    batch: dict,
    ctx: RunCtx = RunCtx(),
    remat: bool = True,
) -> jax.Array:
    hidden, _ = forward(
        cfg,
        params,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        ctx=ctx,
        remat=remat,
    )
    labels = batch["labels"]
    w = unembed_matrix(cfg, params)
    if cfg.n_codebooks > 1:
        B, S, nc = labels.shape
        V = cfg.vocab_size
        wb = w.reshape(cfg.d_model, nc, V)
        tot = 0.0
        for c in range(nc):
            tot = tot + layers.chunked_ce_loss(hidden, wb[:, c], labels[..., c])
        return tot / nc
    # frontends prepend embeds: only the trailing label positions are scored
    if labels.shape[1] != hidden.shape[1]:
        hidden = hidden[:, -labels.shape[1] :]
    return layers.chunked_ce_loss(hidden, w, labels)
