"""xLSTM blocks: chunkwise-parallel mLSTM and sequential sLSTM.

mLSTM: per-head matrix memory S in R^{dk x dv} with scalar gates,
    S_t = f_t S_{t-1} + i_t k_t v_t^T,   n_t = f_t n_{t-1} + i_t k_t,
    h_t = (S_t^T q_t) / max(|n_t^T q_t|, 1)
computed in chunkwise-parallel form (intra-chunk quadratic + inter-chunk
recurrence) — linear in sequence length, so this arch runs ``long_500k``.
Simplification vs the paper (DESIGN.md §2): sigmoid input gate and f32
accumulation instead of the exp-gate + m_t max-stabilizer; recurrence
structure unchanged.

sLSTM: scalar memory with exponential gating AND the m_t stabilizer,
block-diagonal recurrent weights per head — inherently sequential
(lax.scan over time), as in the paper.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mlstm_dims(cfg: ModelConfig) -> Tuple[int, int]:
    di = 2 * cfg.d_model               # projection factor 2
    return di, di // cfg.n_heads


def init_mlstm(rng, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    di, dh = _mlstm_dims(cfg)
    H = cfg.n_heads
    r = jax.random.split(rng, 8)
    return {
        "w_up": layers.init_dense(r[0], d, di, dtype),
        "w_gate": layers.init_dense(r[1], d, di, dtype),
        "wq": (jax.random.normal(r[2], (H, dh, dh)) * dh**-0.5).astype(dtype),
        "wk": (jax.random.normal(r[3], (H, dh, dh)) * dh**-0.5).astype(dtype),
        "wv": (jax.random.normal(r[4], (H, dh, dh)) * dh**-0.5).astype(dtype),
        "w_f": layers.init_dense(r[5], di, H, dtype),
        "w_i": layers.init_dense(r[6], di, H, dtype),
        "out_norm": jnp.zeros((dh,), dtype),
        "w_down": layers.init_dense(r[7], di, d, dtype),
    }


def _mlstm_core_chunked(q, k, v, log_f, i_gate, chunk: int = 512, state=None):
    """q,k,v: (B,S,H,dh) f32; log_f (<=0), i_gate: (B,S,H) f32.

    Returns (out (B,S,H,dh), (S_state, n_state)) — the final state feeds the
    decode cache when prefilling.
    """
    B, S, H, dh = q.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    n = S // chunk

    def split(x):
        return x.reshape((B, n, chunk) + x.shape[2:]).swapaxes(0, 1)

    qs, ks, vs, lfs, igs = map(split, (q, k, v, log_f, i_gate))

    def body(carry, inp):
        S_st, n_st = carry                       # (B,H,dh,dh), (B,H,dh)
        qc, kc, vc, lf, ig = inp                 # (B,c,H,dh) / (B,c,H)
        clf = jnp.cumsum(lf, axis=1)             # decay chunk-start..t incl.
        dec_q = jnp.exp(clf)[..., None]          # (B,c,H,1)
        tot = jnp.exp(clf[:, -1])                # (B,H) full-chunk decay

        qf = qc.astype(jnp.float32)
        # inter-chunk (carried state)
        o_inter = jnp.einsum("bthk,bhkv->bthv", qf * dec_q, S_st)
        d_inter = jnp.einsum("bthk,bhk->bth", qf * dec_q, n_st)

        # intra-chunk: att[t,s] = (q_t.k_s) exp(clf_t - clf_s) i_s, s <= t
        w_ts = jnp.exp(clf[:, :, None, :] - clf[:, None, :, :])  # (B,t,s,H)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        w_ts = jnp.where(causal[None, :, :, None], w_ts, 0.0) * ig[:, None]
        att = jax.lax.dot_general(
            qc, kc, (((3,), (3,)), ((0, 2), (0, 2))),
            preferred_element_type=jnp.float32,
        ).transpose(0, 2, 3, 1) * w_ts                            # (B,t,s,H)
        o_intra = jnp.einsum(
            "btsh,bshv->bthv", att.astype(kc.dtype), vc
        ).astype(jnp.float32)
        d_intra = jnp.sum(att, axis=2)                            # (B,t,H)

        # state to end of chunk
        kw = kc.astype(jnp.float32) * (jnp.exp(clf[:, -1:, :] - clf) * ig)[..., None]
        S_new = S_st * tot[:, :, None, None] + jnp.einsum(
            "bshk,bshv->bhkv", kw.astype(kc.dtype), vc
        ).astype(jnp.float32)
        n_new = n_st * tot[:, :, None] + jnp.sum(kw, axis=1)

        num = o_inter + o_intra
        den = jnp.maximum(jnp.abs(d_inter + d_intra), 1.0)[..., None]
        return (S_new, n_new), num / den

    if state is None:
        state = (
            jnp.zeros((B, H, dh, dh), jnp.float32),
            jnp.zeros((B, H, dh), jnp.float32),
        )
    state, outs = jax.lax.scan(body, state, (qs, ks, vs, lfs, igs))
    return outs.swapaxes(0, 1).reshape(B, S, H, dh), state


def mlstm_block(
    cfg: ModelConfig, params: dict, x: jax.Array, *, cache: Optional[dict] = None
):
    B, S, d = x.shape
    H = cfg.n_heads
    di, dh = _mlstm_dims(cfg)
    u = x @ params["w_up"]                                     # (B,S,di)
    g = x @ params["w_gate"]
    uh = u.reshape(B, S, H, dh)
    # q/k/v stay bf16 (the core accumulates in f32 via preferred_element_type)
    # — storing them f32 was a 3.2 GB/layer residual term in the train cell
    q = jnp.einsum("bshk,hkj->bshj", uh, params["wq"])
    k = jnp.einsum("bshk,hkj->bshj", uh, params["wk"]) * dh**-0.5
    v = jnp.einsum("bshk,hkj->bshj", uh, params["wv"])
    log_f = jax.nn.log_sigmoid((u @ params["w_f"]).astype(jnp.float32))   # (B,S,H)
    i_g = jax.nn.sigmoid((u @ params["w_i"]).astype(jnp.float32))

    if cache is None or S > 1:
        state = (cache["S"], cache["n"]) if cache is not None else None
        h, (S_f, n_f) = _mlstm_core_chunked(q, k, v, log_f, i_g, state=state)
        new_cache = None
        if cache is not None:  # prefill-through-cache
            new_cache = {"S": S_f, "n": n_f, "pos": cache["pos"] + S}
    else:
        f = jnp.exp(log_f[:, 0])[..., None]                    # (B,H,1)
        S_new = cache["S"] * f[..., None] + jnp.einsum(
            "bhk,bhv->bhkv", (i_g[:, 0, :, None] * k[:, 0]), v[:, 0]
        )
        n_new = cache["n"] * f + i_g[:, 0, :, None] * k[:, 0]
        num = jnp.einsum("bhk,bhkv->bhv", q[:, 0], S_new)
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhk,bhk->bh", q[:, 0], n_new)), 1.0
        )[..., None]
        h = (num / den)[:, None]                               # (B,1,H,dh)
        new_cache = {"S": S_new, "n": n_new, "pos": cache["pos"] + 1}

    h = layers.rms_norm(h.astype(x.dtype), params["out_norm"], cfg.norm_eps)
    h = h.reshape(B, S, di) * jax.nn.silu(g)
    return h @ params["w_down"], new_cache


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> dict:
    di, dh = _mlstm_dims(cfg)
    H = cfg.n_heads
    return {
        "S": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(rng, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    r = jax.random.split(rng, 9)
    p = {}
    for j, name in enumerate(("z", "i", "f", "o")):
        p[f"w_{name}"] = layers.init_dense(r[2 * j], d, d, dtype)
        p[f"r_{name}"] = (
            jax.random.normal(r[2 * j + 1], (H, dh, dh)) * dh**-0.5
        ).astype(dtype)
    p["w_out"] = layers.init_dense(r[8], d, d, dtype)
    return p


def _slstm_scan(
    cfg: ModelConfig, params: dict, x: jax.Array, state: dict, chunk: int = 64
):
    """x: (B,S,d); state: c,n,h,m (B,H,dh).

    Nested O(sqrt-T)-remat scan: the outer scan over sequence chunks is
    checkpointed, so the backward holds only chunk-boundary states plus one
    chunk's step residuals — per-step gate tensors never accumulate over the
    full sequence (this was a 20 GB/device temp term in the train dry-run).
    """
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    n = S // chunk
    xs = x.reshape(B, n, chunk, d).swapaxes(0, 1)            # (n,B,c,d)

    def chunk_body(st, xc):
        gx = {
            name: (xc @ params[f"w_{name}"]).astype(jnp.float32)
            .reshape(B, chunk, H, dh)
            for name in ("z", "i", "f", "o")
        }

        def step(st, t):
            h = st["h"]

            def gate(name):
                rec = jnp.einsum(
                    "bhk,hkj->bhj", h.astype(x.dtype), params[f"r_{name}"]
                ).astype(jnp.float32)
                return gx[name][:, t] + rec

            z = jnp.tanh(gate("z"))
            o = jax.nn.sigmoid(gate("o"))
            i_t = gate("i")                  # log-space exponential gates
            f_t = gate("f")
            m_new = jnp.maximum(f_t + st["m"], i_t)
            i_p = jnp.exp(i_t - m_new)
            f_p = jnp.exp(f_t + st["m"] - m_new)
            c = f_p * st["c"] + i_p * z
            nrm = f_p * st["n"] + i_p
            h_new = o * (c / jnp.maximum(nrm, 1e-6))
            return {"c": c, "n": nrm, "h": h_new, "m": m_new}, h_new

        st, hs = jax.lax.scan(step, st, jnp.arange(chunk))   # hs (c,B,H,dh)
        return st, hs

    state, hs = jax.lax.scan(jax.checkpoint(chunk_body), state, xs)  # (n,c,B,H,dh)
    out = hs.transpose(2, 0, 1, 3, 4).reshape(B, S, d)
    return out.astype(x.dtype), state


def slstm_block(
    cfg: ModelConfig, params: dict, x: jax.Array, *, cache: Optional[dict] = None
):
    B = x.shape[0]
    state = (
        {k: cache[k] for k in ("c", "n", "h", "m")}
        if cache is not None
        else init_slstm_state(cfg, B)
    )
    h, state = _slstm_scan(cfg, params, x, state)
    new_cache = None
    if cache is not None:
        new_cache = dict(state, pos=cache["pos"] + x.shape[1])
    return h @ params["w_out"], new_cache


def init_slstm_state(cfg: ModelConfig, batch: int) -> dict:
    H = cfg.n_heads
    dh = cfg.d_model // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z - 30.0}


def init_slstm_cache(cfg: ModelConfig, batch: int) -> dict:
    return dict(init_slstm_state(cfg, batch), pos=jnp.zeros((), jnp.int32))
