"""Model configuration for the assigned architecture pool.

One frozen dataclass covers all 10 families via a layer-kind ``pattern``
(tiled over ``n_layers``) and per-family sub-configs (MoE, MLA, RG-LRU,
xLSTM).  ``[audio]``/``[vlm]`` archs specify the transformer backbone only;
their modality frontends are stubs fed by ``input_specs()`` with precomputed
frame/patch embeddings (per assignment).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                     # 0 -> d_model // n_heads
    # layer kinds tiled over n_layers: "attn" (global), "local" (windowed),
    # "rec" (RG-LRU), "mlstm", "slstm". MoE replaces the FF of attn layers.
    pattern: Tuple[str, ...] = ("attn",)
    qk_norm: bool = False
    rope_theta: float = 10000.0
    window: int = 0                       # local-attention window
    norm_eps: float = 1e-6
    gated_mlp: bool = True                # SwiGLU (True) vs GELU 2-matrix MLP
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0           # leading layers use dense FF
    capacity_factor: float = 1.25
    # --- MLA (deepseek-v2) ---
    attn_kind: str = "gqa"                # "gqa" | "mla"
    q_lora: int = 0
    kv_lora: int = 0
    rope_head_dim: int = 0
    v_head_dim: int = 0
    # --- recurrent families ---
    conv_width: int = 4                   # RG-LRU / mLSTM short conv
    rnn_width: int = 0                    # RG-LRU width (0 -> d_model)
    # --- frontends / heads ---
    frontend: str = "none"                # "none" | "audio_stub" | "vision_stub"
    n_codebooks: int = 1                  # musicgen: parallel codebook heads
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # long-context capability (sub-quadratic): run long_500k iff True
    subquadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        reps = (self.n_layers + len(self.pattern) - 1) // len(self.pattern)
        return (self.pattern * reps)[: self.n_layers]

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6 N D)."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k experts only)."""
        return _param_count(self, active_only=True)


def _ff_params(cfg: ModelConfig, kind: str, layer_idx: int, active: bool) -> int:
    d = cfg.d_model
    if kind in ("mlstm", "slstm"):
        return 0  # recurrent blocks carry their own FF inside block params
    if cfg.is_moe and layer_idx >= cfg.first_dense_layers:
        fe = cfg.d_ff_expert
        routed = cfg.n_experts * 3 * d * fe
        if active:
            routed = cfg.top_k * 3 * d * fe
        shared = cfg.n_shared_experts * 3 * d * fe
        router = d * cfg.n_experts
        return routed + shared + router
    n_mats = 3 if cfg.gated_mlp else 2
    return n_mats * d * cfg.d_ff


def _mix_params(cfg: ModelConfig, kind: str) -> int:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    H, K = cfg.n_heads, cfg.n_kv_heads
    if kind in ("attn", "local"):
        if cfg.attn_kind == "mla":
            vd = cfg.v_head_dim or hd
            qd = hd + cfg.rope_head_dim
            q = (d * cfg.q_lora + cfg.q_lora * H * qd) if cfg.q_lora else d * H * qd
            kv = d * (cfg.kv_lora + cfg.rope_head_dim)
            up = cfg.kv_lora * H * (hd + vd)
            out = H * vd * d
            return q + kv + up + out
        return d * H * hd + 2 * d * K * hd + H * hd * d
    if kind == "rec":
        w = cfg.rnn_width or d
        # in/gate proj, conv, 2 gates, lambda, out proj
        return 2 * d * w + cfg.conv_width * w + 2 * w * w // 8 + w + w * d
    if kind == "mlstm":
        up = 2 * d  # x2 up-projection
        inner = 2 * d
        return d * up * 2 // 2 + up * d + inner * (3 * inner // 1) // 1  # approx
    if kind == "slstm":
        hd_s = d // cfg.n_heads
        return 4 * d * d + 4 * cfg.n_heads * hd_s * hd_s + 2 * d * int(4 * d / 3)
    raise ValueError(kind)


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    d = cfg.d_model
    total = cfg.vocab_size * d * cfg.n_codebooks  # embed
    if not cfg.tie_embeddings:
        total += d * cfg.vocab_size * cfg.n_codebooks  # lm head(s)
    for i, kind in enumerate(cfg.layer_kinds):
        if kind == "mlstm":
            # x2 up proj (gate+val), qkv from inner, out proj
            inner = 2 * d
            total += d * inner * 2 + inner * d + 3 * inner * inner // cfg.n_heads
            continue
        if kind == "slstm":
            hd_s = d // cfg.n_heads
            ff = int(4 * d / 3)
            total += 4 * d * d + 4 * cfg.n_heads * hd_s * hd_s + 2 * d * ff
            continue
        total += _mix_params(cfg, kind)
        total += _ff_params(cfg, kind, i, active_only)
        total += 2 * d  # norms
    return total
