"""MATADOR core: the Tsetlin Machine and its boolean-to-silicon compiler."""

from repro.core.tm import (  # noqa: F401
    TMConfig,
    TMState,
    accuracy,
    class_sums,
    clause_outputs,
    include_mask,
    init,
    literals,
    polarity,
    predict,
    vote_matrix,
)
from repro.core.compiler import (  # noqa: F401
    CompiledTM,
    CompileStats,
    compile_tm,
    predict_compiled,
    run_compiled,
)
from repro.core.train import eval_step, fit, train_step  # noqa: F401


def __getattr__(name):
    # EngineSpec/ENGINE_NAMES live in kernels/ops and are re-exported
    # lazily through compiler — eager resolution here would re-open the
    # kernels <-> core import cycle compiler.__getattr__ exists to break.
    if name in ("EngineSpec", "ENGINE_NAMES"):
        from repro.core import compiler
        return getattr(compiler, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
