"""MATADOR core: the Tsetlin Machine and its boolean-to-silicon compiler."""

from repro.core.tm import (  # noqa: F401
    TMConfig,
    TMState,
    accuracy,
    class_sums,
    clause_outputs,
    include_mask,
    init,
    literals,
    polarity,
    predict,
    vote_matrix,
)
from repro.core.compiler import CompiledTM, CompileStats, compile_tm, run_compiled  # noqa: F401
from repro.core.train import eval_step, fit, train_step  # noqa: F401
