"""TM training / eval steps (single-host and mesh-sharded).

The sharded step is the distribution story of DESIGN.md §5: automata are
sharded over the ``model`` axis on the clause dimension, the batch over
``data`` (× ``pod``); the only cross-device traffic is
  * an int32 ``psum`` of feedback deltas over ``data`` — the TM's native
    "compressed gradient" (bounded small ints), and
  * nothing at all over ``model`` for feedback (each clause's feedback is
    local to its shard; class sums inside feedback are computed per-class
    from the local slice — clause shards are class-aligned by construction).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import feedback, tm


@functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
def train_step(
    config: tm.TMConfig, state: tm.TMState, x: jax.Array, y: jax.Array, rng: jax.Array
) -> Tuple[tm.TMState, dict]:
    delta = feedback.batch_feedback_delta(config, state.ta_state, x, y, rng)
    new_ta = feedback.apply_delta(config, state.ta_state, delta)
    new_state = tm.TMState(ta_state=new_ta, steps=state.steps + 1)
    metrics = {
        "delta_abs_sum": jnp.sum(jnp.abs(delta)),
        "include_frac": jnp.mean((new_ta >= 0).astype(jnp.float32)),
    }
    return new_state, metrics


@functools.partial(jax.jit, static_argnums=(0, 5, 6), donate_argnums=1)
def train_step_kernel(
    config: tm.TMConfig, state: tm.TMState, x: jax.Array, y: jax.Array,
    seed: jax.Array, batch_chunk: int | None = None, fuse: bool = True,
) -> Tuple[tm.TMState, dict]:
    """Kernel-path batch step (hash RNG; fused Pallas pipeline by default).

    Same contract as :func:`train_step` but driven by ``ops.
    tm_train_step_kernel`` — on the kernel path the whole step is two
    fused ``pallas_call`` launches (class sums, then clause-fire ->
    feedback -> TA delta with nothing spilled to HBM).  ``state`` is
    donated so the int8 automata bank is updated in place across long
    ``fit`` runs instead of double-buffering.
    """
    from repro.kernels import ops

    new_ta, delta = ops.tm_train_step_kernel(
        config, state.ta_state, x, y, seed,
        batch_chunk=batch_chunk, fuse=fuse,
    )
    new_state = tm.TMState(ta_state=new_ta, steps=state.steps + 1)
    metrics = {
        "delta_abs_sum": jnp.sum(jnp.abs(delta)),
        "include_frac": jnp.mean((new_ta >= 0).astype(jnp.float32)),
    }
    return new_state, metrics


@functools.partial(jax.jit, static_argnums=0)
def online_step(
    config: tm.TMConfig, ta_state: jax.Array, x: jax.Array, y: jax.Array,
    seed: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """One streaming-feedback step on a RAW automata bank (hash RNG).

    The online updater (``runtime/online.py``) steps a live bank beside a
    serving loop: it feeds fixed-size feedback batches (one jit trace),
    seeds by its own global step counter for reproducibility, and keeps
    the previous bank un-donated — rollback and SIGTERM-drain
    checkpointing both need the pre-step buffer intact.  Returns
    ``(new_ta, delta_abs_sum)``.
    """
    from repro.kernels import ops

    new_ta, delta = ops.tm_train_step_kernel(config, ta_state, x, y, seed)
    return new_ta, jnp.sum(jnp.abs(delta))


@functools.partial(jax.jit, static_argnums=0)
def eval_step(
    config: tm.TMConfig, state: tm.TMState, x: jax.Array, y: jax.Array
) -> jax.Array:
    return tm.accuracy(config, state, x, y)


def fit(
    config: tm.TMConfig,
    state: tm.TMState,
    x: jax.Array,
    y: jax.Array,
    *,
    epochs: int,
    batch_size: int,
    rng: jax.Array,
    x_val=None,
    y_val=None,
    log_every: int = 0,
    engine: str = "jnp",
    batch_chunk: int | None = None,
    mesh=None,
    ckpt_manager=None,
    ckpt_every: int = 0,
    preemption=None,
    monitor=None,
) -> tm.TMState:
    """Simple host loop used by examples/tests (the GUI "Train" button).

    The batch stream is pre-shuffled ONCE per epoch on device (one gather
    of ``x``/``y``), so the inner loop slices contiguous device buffers
    instead of re-gathering ``x[idx]`` every step; the TA state is donated
    through both step functions, so long runs keep a single automata
    buffer alive instead of double-buffering.

    ``engine="jnp"`` runs the per-sample jax.random step (paper-faithful
    sequential semantics, batch-accumulated); ``engine="kernel"`` runs the
    hash-RNG kernel-path step (fused Pallas pipeline on the kernel path),
    seeded by the global step index so runs are reproducible.

    ``mesh`` (with ``engine="kernel"``) runs every step through the
    clause-sharded ``shard_map`` schedule of
    ``core/sharding.py:sharded_train_step_fn(engine="kernel")`` — automata
    sharded over ``model``, batch over the data axes.  The shuffle stream
    and per-step seeds are unchanged, and the sharded step is bit-identical
    to the single-device one, so ``fit`` results do not depend on the mesh.

    **Fault tolerance** — ``ckpt_manager`` (a ``CheckpointManager``) with
    ``ckpt_every > 0`` checkpoints the TA state, the (epoch, step-in-epoch)
    cursor, and the EPOCH-START rng key at step boundaries, and auto-resumes
    from the newest checkpoint when the directory already holds one.
    Resume is *bit-exact*: the epoch's shuffle permutation is re-derived
    from the saved epoch key and the per-step rng splits already consumed
    are replayed, so an interrupted-then-resumed run produces exactly the
    TA state of an uninterrupted one (drilled in
    tests/test_fault_tolerance.py).  ``preemption`` (a ``PreemptionHandler``)
    turns SIGTERM into checkpoint + ``sys.exit(RESUME_EXIT_CODE)`` at the
    next step boundary; ``monitor`` (a ``StragglerMonitor``) flags slow
    steps.  Fault-injection sites (``runtime/faults.py``): ``train.sigterm``
    and ``train.slow_step``, keyed by the global step index.
    """
    from repro.runtime import faults

    sharded_step = None
    if mesh is not None:
        if engine != "kernel":
            raise ValueError("fit(mesh=...) requires engine='kernel' "
                             "(the hash-RNG step; no cross-shard RNG state)")
        from repro.core import sharding as tm_sharding

        sharded_step = tm_sharding.sharded_train_step_fn(
            config, mesh, batch_chunk=batch_chunk, engine="kernel"
        )
    n = x.shape[0]
    steps_per_epoch = max(1, n // batch_size)
    gstep = 0
    start_epoch = start_step = 0
    if ckpt_manager is not None and ckpt_manager.latest_step() is not None:
        restored, extra = ckpt_manager.restore(
            {"ta": state.ta_state, "rng": rng})
        rng = jnp.asarray(restored["rng"], jnp.uint32)   # epoch-start key
        start_epoch = int(extra["epoch"])
        start_step = int(extra["step_in_epoch"])
        gstep = int(extra["gstep"])
        state = tm.TMState(ta_state=restored["ta"], steps=jnp.int32(gstep))
        print(f"fit: resumed at epoch {start_epoch} step {start_step} "
              f"(global step {gstep})")

    def save_ckpt(ep, next_step, rng_epoch, blocking=True):
        ckpt_manager.save(
            gstep, {"ta": state.ta_state, "rng": rng_epoch},
            extra={"epoch": ep, "step_in_epoch": next_step, "gstep": gstep},
            blocking=blocking)

    for ep in range(start_epoch, epochs):
        rng_epoch = rng                  # resume anchor: key at epoch start
        rng, rp = jax.random.split(rng)
        perm = jax.random.permutation(rp, n)
        xs, ys = x[perm], y[perm]        # one device-side shuffle per epoch
        i0 = start_step if ep == start_epoch else 0
        for _ in range(i0):              # replay consumed per-step splits
            rng, _ = jax.random.split(rng)
        for i in range(i0, steps_per_epoch):
            if monitor is not None:
                monitor.start_step()
            xb = xs[i * batch_size : (i + 1) * batch_size]
            yb = ys[i * batch_size : (i + 1) * batch_size]
            rng, rs = jax.random.split(rng)
            if sharded_step is not None:
                new_ta = sharded_step(state.ta_state, xb, yb,
                                      jnp.uint32(gstep))
                state = tm.TMState(ta_state=new_ta, steps=state.steps + 1)
            elif engine == "kernel":
                state, _ = train_step_kernel(
                    config, state, xb, yb, jnp.uint32(gstep), batch_chunk
                )
            else:
                state, _ = train_step(config, state, xb, yb, rs)
            faults.sleep_if("train.slow_step", step=gstep)
            gstep += 1
            if monitor is not None:
                flag = monitor.end_step(gstep - 1)
                if flag:
                    print(f"fit: straggler flagged: {flag}")
            if (ckpt_manager is not None and ckpt_every
                    and gstep % ckpt_every == 0):
                save_ckpt(ep, i + 1, rng_epoch, blocking=False)
            faults.sigterm_if("train.sigterm", step=gstep - 1)
            if preemption is not None and preemption.preempted:
                print("fit: preempted — checkpointing and exiting for resume")
                preemption.checkpoint_and_exit(
                    (lambda: save_ckpt(ep, i + 1, rng_epoch))
                    if ckpt_manager is not None else (lambda: None))
        if log_every and (ep + 1) % log_every == 0 and x_val is not None:
            acc = eval_step(config, state, x_val, y_val)
            print(f"epoch {ep + 1}: val_acc={float(acc):.4f}")
    if ckpt_manager is not None:
        ckpt_manager.wait()              # surface any pending async failure
    return state
