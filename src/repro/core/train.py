"""TM training / eval steps (single-host and mesh-sharded).

The sharded step is the distribution story of DESIGN.md §5: automata are
sharded over the ``model`` axis on the clause dimension, the batch over
``data`` (× ``pod``); the only cross-device traffic is
  * an int32 ``psum`` of feedback deltas over ``data`` — the TM's native
    "compressed gradient" (bounded small ints), and
  * nothing at all over ``model`` for feedback (each clause's feedback is
    local to its shard; class sums inside feedback are computed per-class
    from the local slice — clause shards are class-aligned by construction).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import feedback, tm


@functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
def train_step(
    config: tm.TMConfig, state: tm.TMState, x: jax.Array, y: jax.Array, rng: jax.Array
) -> Tuple[tm.TMState, dict]:
    delta = feedback.batch_feedback_delta(config, state.ta_state, x, y, rng)
    new_ta = feedback.apply_delta(config, state.ta_state, delta)
    new_state = tm.TMState(ta_state=new_ta, steps=state.steps + 1)
    metrics = {
        "delta_abs_sum": jnp.sum(jnp.abs(delta)),
        "include_frac": jnp.mean((new_ta >= 0).astype(jnp.float32)),
    }
    return new_state, metrics


@functools.partial(jax.jit, static_argnums=(0, 5, 6), donate_argnums=1)
def train_step_kernel(
    config: tm.TMConfig, state: tm.TMState, x: jax.Array, y: jax.Array,
    seed: jax.Array, batch_chunk: int | None = None, fuse: bool = True,
) -> Tuple[tm.TMState, dict]:
    """Kernel-path batch step (hash RNG; fused Pallas pipeline by default).

    Same contract as :func:`train_step` but driven by ``ops.
    tm_train_step_kernel`` — on the kernel path the whole step is two
    fused ``pallas_call`` launches (class sums, then clause-fire ->
    feedback -> TA delta with nothing spilled to HBM).  ``state`` is
    donated so the int8 automata bank is updated in place across long
    ``fit`` runs instead of double-buffering.
    """
    from repro.kernels import ops

    new_ta, delta = ops.tm_train_step_kernel(
        config, state.ta_state, x, y, seed,
        batch_chunk=batch_chunk, fuse=fuse,
    )
    new_state = tm.TMState(ta_state=new_ta, steps=state.steps + 1)
    metrics = {
        "delta_abs_sum": jnp.sum(jnp.abs(delta)),
        "include_frac": jnp.mean((new_ta >= 0).astype(jnp.float32)),
    }
    return new_state, metrics


@functools.partial(jax.jit, static_argnums=0)
def eval_step(
    config: tm.TMConfig, state: tm.TMState, x: jax.Array, y: jax.Array
) -> jax.Array:
    return tm.accuracy(config, state, x, y)


def fit(
    config: tm.TMConfig,
    state: tm.TMState,
    x: jax.Array,
    y: jax.Array,
    *,
    epochs: int,
    batch_size: int,
    rng: jax.Array,
    x_val=None,
    y_val=None,
    log_every: int = 0,
    engine: str = "jnp",
    batch_chunk: int | None = None,
    mesh=None,
) -> tm.TMState:
    """Simple host loop used by examples/tests (the GUI "Train" button).

    The batch stream is pre-shuffled ONCE per epoch on device (one gather
    of ``x``/``y``), so the inner loop slices contiguous device buffers
    instead of re-gathering ``x[idx]`` every step; the TA state is donated
    through both step functions, so long runs keep a single automata
    buffer alive instead of double-buffering.

    ``engine="jnp"`` runs the per-sample jax.random step (paper-faithful
    sequential semantics, batch-accumulated); ``engine="kernel"`` runs the
    hash-RNG kernel-path step (fused Pallas pipeline on the kernel path),
    seeded by the global step index so runs are reproducible.

    ``mesh`` (with ``engine="kernel"``) runs every step through the
    clause-sharded ``shard_map`` schedule of
    ``core/sharding.py:sharded_train_step_fn(engine="kernel")`` — automata
    sharded over ``model``, batch over the data axes.  The shuffle stream
    and per-step seeds are unchanged, and the sharded step is bit-identical
    to the single-device one, so ``fit`` results do not depend on the mesh.
    """
    sharded_step = None
    if mesh is not None:
        if engine != "kernel":
            raise ValueError("fit(mesh=...) requires engine='kernel' "
                             "(the hash-RNG step; no cross-shard RNG state)")
        from repro.core import sharding as tm_sharding

        sharded_step = tm_sharding.sharded_train_step_fn(
            config, mesh, batch_chunk=batch_chunk, engine="kernel"
        )
    n = x.shape[0]
    steps_per_epoch = max(1, n // batch_size)
    gstep = 0
    for ep in range(epochs):
        rng, rp = jax.random.split(rng)
        perm = jax.random.permutation(rp, n)
        xs, ys = x[perm], y[perm]        # one device-side shuffle per epoch
        for i in range(steps_per_epoch):
            xb = xs[i * batch_size : (i + 1) * batch_size]
            yb = ys[i * batch_size : (i + 1) * batch_size]
            rng, rs = jax.random.split(rng)
            if sharded_step is not None:
                new_ta = sharded_step(state.ta_state, xb, yb,
                                      jnp.uint32(gstep))
                state = tm.TMState(ta_state=new_ta, steps=state.steps + 1)
            elif engine == "kernel":
                state, _ = train_step_kernel(
                    config, state, xb, yb, jnp.uint32(gstep), batch_chunk
                )
            else:
                state, _ = train_step(config, state, xb, yb, rs)
            gstep += 1
        if log_every and (ep + 1) % log_every == 0 and x_val is not None:
            acc = eval_step(config, state, x_val, y_val)
            print(f"epoch {ep + 1}: val_acc={float(acc):.4f}")
    return state
