"""Tsetlin Automata feedback (Type I / Type II) — batch-parallel training.

Semantics follow Granmo'18 (paper ref [9]) exactly at per-sample granularity:

Type I (recognize; target-class positive clauses, negative-class negative
clauses), applied to clause j with probability ``(T - clamp(sum))/2T`` resp.
``(T + clamp(sum))/2T``:
  * clause=1, literal=1: state += 1  w.p. 1 (boost) else (s-1)/s
  * clause=1, literal=0: state -= 1  w.p. 1/s
  * clause=0:            state -= 1  w.p. 1/s   (all literals)

Type II (reject; the polarity-mirrored clauses):
  * clause=1, literal=0, currently excluded: state += 1 (deterministic)

The paper trains sample-sequentially on the host; here feedback deltas are
computed per sample and *accumulated over the batch* before being applied
(clamped) — the standard batch-parallel TM formulation that lets training
shard over a `data` mesh axis (DESIGN.md §2 "changed assumptions").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tm


def _clause_fire(ta_slice: jax.Array, lits: jax.Array) -> jax.Array:
    """(cpc, L) int8 x (L,) {0,1} -> (cpc,) uint8, training semantics."""
    inc = ta_slice >= 0
    viol = inc & (lits[None, :] == 0)
    return (~jnp.any(viol, axis=-1)).astype(jnp.uint8)


def _clause_polarity(cpc: int) -> jax.Array:
    return jnp.where(jnp.arange(cpc) % 2 == 0, 1, -1).astype(jnp.int32)


def _class_feedback_delta(
    config: tm.TMConfig,
    ta_slice: jax.Array,     # (cpc, L) int8 — automata of one class
    lits: jax.Array,         # (L,) {0,1}
    is_target: jax.Array,    # bool scalar: True -> target-class roles
    rng: jax.Array,
) -> jax.Array:
    """Per-sample feedback delta for one class. Returns (cpc, L) int8."""
    cpc, L = ta_slice.shape
    T = config.threshold
    pol = _clause_polarity(cpc)

    fire = _clause_fire(ta_slice, lits)                     # (cpc,)
    csum = jnp.clip(jnp.sum(pol * fire.astype(jnp.int32)), -T, T)

    p = jnp.where(is_target, (T - csum) / (2.0 * T), (T + csum) / (2.0 * T))

    r_sel, r_act, r_inact = jax.random.split(rng, 3)
    sel = jax.random.uniform(r_sel, (cpc,)) < p             # clause selected
    # Type I goes to +polarity clauses of the target class and -polarity
    # clauses of the negative class; Type II to the mirrored set.
    type1 = jnp.where(is_target, pol > 0, pol < 0)          # (cpc,)

    lit_on = lits[None, :] == 1                             # (1->cpc, L)
    fire_b = (fire == 1)[:, None]                           # (cpc, 1)

    # --- Type I ---
    p_act = 1.0 if config.boost_true_positive else (config.s - 1.0) / config.s
    act = jax.random.uniform(r_act, (cpc, L)) < p_act
    inact = jax.random.uniform(r_inact, (cpc, L)) < (1.0 / config.s)
    d1 = jnp.where(
        fire_b,
        jnp.where(lit_on, act.astype(jnp.int8), -inact.astype(jnp.int8)),
        -inact.astype(jnp.int8),
    )

    # --- Type II ---
    excluded = ta_slice < 0
    d2 = (fire_b & (~lit_on) & excluded).astype(jnp.int8)

    d = jnp.where(type1[:, None], d1, d2)
    return jnp.where(sel[:, None], d, jnp.int8(0))


def batch_feedback_delta(
    config: tm.TMConfig,
    ta_state: jax.Array,   # (C_total, L) int8
    x: jax.Array,          # (B, F) {0,1}
    y: jax.Array,          # (B,) int32
    rng: jax.Array,
) -> jax.Array:
    """Summed feedback deltas over the batch: (C_total, L) int32.

    Scans over samples (bounded memory: one (cpc, L) random field at a time)
    and scatter-adds the per-class deltas of the target and one sampled
    negative class.
    """
    cpc = config.clauses_per_class
    B = x.shape[0]
    lits_all = tm.literals(x)                                # (B, L)
    acc0 = jnp.zeros(ta_state.shape, jnp.int32)

    def body(acc, inp):
        lits, yb, r = inp
        r_neg, r_t, r_n = jax.random.split(r, 3)
        # sample a negative class != yb (paper: one random other class)
        kn = jax.random.randint(r_neg, (), 0, config.n_classes - 1)
        kn = kn + (kn >= yb)

        for cls_idx, is_tgt, rr in ((yb, True, r_t), (kn, False, r_n)):
            off = cls_idx * cpc
            sl = jax.lax.dynamic_slice_in_dim(ta_state, off, cpc, axis=0)
            d = _class_feedback_delta(
                config, sl, lits, jnp.asarray(is_tgt), rr
            ).astype(jnp.int32)
            cur = jax.lax.dynamic_slice_in_dim(acc, off, cpc, axis=0)
            acc = jax.lax.dynamic_update_slice_in_dim(acc, cur + d, off, axis=0)
        return acc, None

    rngs = jax.random.split(rng, B)
    acc, _ = jax.lax.scan(body, acc0, (lits_all, y, rngs))
    return acc


def apply_delta(config: tm.TMConfig, ta_state: jax.Array, delta: jax.Array) -> jax.Array:
    """states <- clamp(states + delta) in int32, cast back to int8."""
    new = jnp.clip(
        ta_state.astype(jnp.int32) + delta,
        -config.n_states,
        config.n_states - 1,
    )
    return new.astype(jnp.int8)
