"""Mesh sharding for TM training/inference (DESIGN.md §5).

Layout:
  * automata / include words: clause axis over ``model``, replicated over
    ``data`` (and ``pod``);
  * batch: over (``pod`` x) ``data``;
  * vote matrix: clause axis over ``model``;
  * class sums: partial per model-shard -> one tiny ``psum`` over ``model``
    (the only inference collective);
  * training feedback deltas: computed locally per (data, model) shard, then
    ``psum`` over ``data`` only — int32 bounded-magnitude "compressed
    gradients".

Two execution engines share this one dispatch layer (PR 3):

  * ``engine="gspmd"`` — jit + NamedSharding constraints; GSPMD inserts the
    collectives above.  The original path; kernel-free, XLA everywhere.
  * ``engine="kernel"`` — an explicit ``shard_map`` schedule whose per-shard
    body IS the fused Pallas pipeline (``ops.tm_train_step_kernel`` /
    ``ops.tm_forward_packed``): each ``model`` shard runs the fused kernels
    on its local clause bank with runtime ``b_offset``/``c_offset`` global
    RNG ids, one int32 class-sum ``psum`` over ``model`` completes the
    partial adder-bank outputs, and training deltas ``psum`` over ``data``.
    Bit-identical to the single-device ``ref.py`` oracle (the hash RNG is
    indexed by global (sample, clause, literal) ids on every shard) —
    verified in tests/test_sharded_fused.py on an emulated mesh.

The clause axis is the natural partition unit (the eFPGA runtime-tunable TM
work partitions by clause bank for exactly this reason): clause banks larger
than one core's VMEM split across ``model`` with only the tiny (B, K) psum
on the wire.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import jax_compat
from repro.core import tm


def data_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _engine_dispatch(engine, use_kernel, interpret, *, allowed,
                     fuse: bool = True) -> tuple:
    """Resolve a forward builder's ``(use_kernel, interpret, fuse)`` from
    either an ``ops.EngineSpec``/name (the high-level vocabulary) or the
    low-level ``use_kernel``/``interpret`` overrides — not both.  Each
    builder already names its kernel, so only the engines it can actually
    build (``allowed``) plus ``"auto"``/``"oracle"`` are accepted: asking
    the dense-fused builder for ``"sparse"`` would silently build the
    wrong schedule."""
    from repro.kernels import ops

    if engine is None:
        uk, it = ops.kernel_dispatch(use_kernel, interpret)
        return uk, it, fuse
    if use_kernel is not None:
        raise TypeError("pass engine= or use_kernel=, not both")
    spec = ops.EngineSpec.coerce(engine)
    if spec.name not in allowed:
        raise ValueError(
            f"engine {spec.name!r} does not apply to this sharded builder; "
            f"one of {allowed}")
    uk_s, it_s, fuse_s, _, _ = spec.resolve(interpret)
    uk, it = ops.kernel_dispatch(uk_s, it_s)
    return uk, it, fuse_s


def tm_shardings(config: tm.TMConfig, mesh: Mesh):
    """(state_sharding, batch_sharding) for the TM train/serve steps."""
    d = data_axes(mesh)
    state = tm.TMState(
        ta_state=NamedSharding(mesh, P("model", None)),
        steps=NamedSharding(mesh, P()),
    )
    batch = NamedSharding(mesh, P(d, None))
    return state, batch


def sharded_forward_fn(mesh: Mesh, *, engine=None,
                       use_kernel: bool | None = None,
                       interpret: bool | None = None, fuse: bool = True,
                       blocks: dict | None = None):
    """Clause-sharded fused forward: (inc_words, votes, nonempty,
    lit_words) -> (B, K) int32 GLOBAL class sums.

    An explicit ``shard_map`` schedule: each ``model`` shard evaluates its
    local clause bank with the fused single-pass inference kernel (or the
    oracle, per ``engine`` — ``"auto"``/``"dense"``/``"oracle"``, or the
    low-level ``use_kernel`` override) — the full bank never needs to fit
    one core's VMEM — and one int32 ``psum`` over ``model`` completes the
    adder bank.  Exact: integer partial sums compose bit-identically to
    the unsharded kernel.  Shape-agnostic (works for dense banks and
    compiled artifacts); the clause axis size must be divisible by the
    ``model`` axis size.
    """
    from repro.kernels import ops

    uk, it, fuse = _engine_dispatch(engine, use_kernel, interpret,
                                    allowed=("auto", "dense", "oracle"),
                                    fuse=fuse)
    d = data_axes(mesh)

    def body(inc_loc, votes_loc, ne_loc, lw_loc):
        sums = ops.tm_forward_packed(
            lw_loc, inc_loc, votes_loc, ne_loc,
            use_kernel=uk, interpret=it, fuse=fuse, **(blocks or {}),
        )
        return jax.lax.psum(sums, "model")

    fwd = jax_compat.shard_map(
        body, mesh=mesh,
        in_specs=(P("model", None), P("model", None), P("model"), P(d, None)),
        out_specs=P(d, None),
        check_vma=False,
    )
    return jax.jit(fwd)


def sharded_schedule_forward_fn(mesh: Mesh, *,
                                block_c: int, block_j: int,
                                block_s: int | None = None,
                                engine=None,
                                use_kernel: bool | None = None,
                                interpret: bool | None = None):
    """Clause-sharded COMPILED-SCHEDULE forward: each ``model`` shard owns
    its own block-sparse tile table (built by
    ``kernels/sparse_infer.stack_shard_schedules``) and runs the
    scalar-prefetched chain kernel on its local clause bank; one int32
    ``psum`` over ``model`` completes the adder bank.  The batch shards
    over the data axes.

    Signature of the returned jit'd fn:
    ``(chain_stack (n, Cp, Jp), votes_stack (n, Cp, K),
    tile_stack (n, 4, T), lit_words (B, Wa)) -> (B, K) int32``.

    Exact: per-shard partial sums are integers, and no-op padding tiles
    (all-sentinel chains, never first/last) equalize tile counts across
    shards without touching any shard's class sums.
    """
    from repro.kernels import ops, sparse_infer

    uk, it, _ = _engine_dispatch(engine, use_kernel, interpret,
                                 allowed=("auto", "sparse", "oracle"))
    d = data_axes(mesh)
    bs = block_s or sparse_infer.DEFAULT_BLOCK_S

    def body(chain_loc, votes_loc, tiles_loc, lw_loc):
        chain, vt, tiles = chain_loc[0], votes_loc[0], tiles_loc[0]
        if uk:
            sums = sparse_infer.sparse_tm_forward_tables(
                lw_loc, chain, vt, tiles,
                block_c=block_c, block_j=block_j, block_s=bs, interpret=it,
            )
        else:
            sums = sparse_infer.schedule_class_sums_ref(lw_loc, chain, vt)
        return jax.lax.psum(sums, "model")

    fwd = jax_compat.shard_map(
        body, mesh=mesh,
        in_specs=(P("model", None, None), P("model", None, None),
                  P("model", None, None), P(d, None)),
        out_specs=P(d, None),
        check_vma=False,
    )
    return jax.jit(fwd)


def sharded_factorized_forward_fn(mesh: Mesh, *,
                                  block_t: int, block_c: int, block_j: int,
                                  block_s: int | None = None,
                                  engine=None,
                                  use_kernel: bool | None = None,
                                  interpret: bool | None = None):
    """Clause-sharded FACTORIZED-schedule forward: each ``model`` shard
    owns its own term table + tile table (built by
    ``kernels/term_infer.stack_shard_factorized`` — terms are extracted
    per shard, so stage 1 evaluates only the terms the shard's clauses
    reference) and runs the two-stage kernel on its local bank; one int32
    ``psum`` over ``model`` completes the adder bank.  The batch shards
    over the data axes.

    Signature of the returned jit'd fn:
    ``(term_stack (n, Tp, term_w), chain_stack (n, Cp, Jp),
    votes_stack (n, Cp, K), tile_stack (n, 6, T), lit_words (B, Wa))
    -> (B, K) int32``.

    Exact: per-shard partial sums are integers; no-op padding tiles and
    all-sentinel padding term rows change no shard's class sums.
    """
    from repro.kernels import ops, term_infer

    uk, it, _ = _engine_dispatch(engine, use_kernel, interpret,
                                 allowed=("auto", "factorized", "oracle"))
    d = data_axes(mesh)
    bs = block_s or term_infer.DEFAULT_BLOCK_S

    def body(term_loc, chain_loc, votes_loc, tiles_loc, lw_loc):
        term, chain, vt, tiles = (term_loc[0], chain_loc[0],
                                  votes_loc[0], tiles_loc[0])
        if uk:
            sums = term_infer.factorized_tm_forward_tables(
                lw_loc, term, chain, vt, tiles,
                block_t=block_t, block_c=block_c, block_j=block_j,
                block_s=bs, interpret=it,
            )
        else:
            sums = term_infer.factorized_class_sums_ref(lw_loc, term, chain, vt)
        return jax.lax.psum(sums, "model")

    fwd = jax_compat.shard_map(
        body, mesh=mesh,
        in_specs=(P("model", None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None),
                  P(d, None)),
        out_specs=P(d, None),
        check_vma=False,
    )
    return jax.jit(fwd)


def sharded_predict_fn(config: tm.TMConfig, mesh: Mesh, *,
                       engine=None,
                       use_kernel: bool | None = None,
                       interpret: bool | None = None, fuse: bool = True,
                       blocks: dict | None = None):
    """Build a jit'd sharded inference fn: packed literals -> class ids.

    Clause axis sharded over ``model``.  On the kernel path (``use_kernel``
    / ``REPRO_USE_PALLAS``) the per-shard body is the fused single-pass
    Pallas kernel inside an explicit ``shard_map`` (clause banks bigger
    than one core's VMEM split across the mesh; one (B, K) class-sum psum
    on the wire).  Otherwise GSPMD turns the vote matmul into a local
    matmul + all-reduce over ``model`` of the (B, K) partial sums.
    """
    from repro.kernels import ops

    uk, it, fuse = _engine_dispatch(engine, use_kernel, interpret,
                                    allowed=("auto", "dense", "oracle"),
                                    fuse=fuse)
    d = data_axes(mesh)
    votes_s = NamedSharding(mesh, P("model", None))
    inc_s = NamedSharding(mesh, P("model", None))
    x_s = NamedSharding(mesh, P(d, None))
    out_s = NamedSharding(mesh, P(d))

    if uk:
        fwd = sharded_forward_fn(mesh, use_kernel=uk, interpret=it,
                                 fuse=fuse, blocks=blocks)

        def predict(inc_words, votes, nonempty, lit_words):
            return jnp.argmax(fwd(inc_words, votes, nonempty, lit_words),
                              axis=-1)
    else:
        def predict(inc_words, votes, nonempty, lit_words):
            fired = ops.clause_fire(lit_words, inc_words, use_kernel=False)
            fired = fired * nonempty[None, :].astype(fired.dtype)
            sums = fired.astype(jnp.int32) @ votes
            return jnp.argmax(sums, axis=-1)

    return jax.jit(
        predict,
        in_shardings=(inc_s, votes_s, NamedSharding(mesh, P("model")), x_s),
        out_shardings=out_s,
    )


def sharded_train_step_fn(config: tm.TMConfig, mesh: Mesh,
                          batch_chunk: int | None = 2048,
                          algorithm: str = "bitwise",
                          *,
                          engine: str = "gspmd",
                          use_kernel: bool | None = None,
                          interpret: bool | None = None,
                          fuse: bool = True,
                          blocks: dict | None = None):
    """Build a jit'd sharded batch training step.

    The kernel-path step (hash RNG) is used because its feedback plan is a
    pure function of (fire, y, seed) — no cross-shard RNG state. Automata are
    replicated over ``data`` and sharded over ``model`` on the clause axis;
    the per-data-shard deltas are combined by GSPMD's all-reduce when the
    (replicated-output) update is applied.

    ``engine`` selects the execution engine of the clause shards:

      * ``"gspmd"`` (default) — jit + NamedSharding; XLA partitions the
        oracle step.  Semantically the whole-bank function; sharding is
        pure layout.
      * ``"kernel"`` — explicit ``shard_map`` schedule running
        ``ops.tm_train_step_kernel`` per shard (the fused two-launch Pallas
        pipeline when the kernel path is active; ``fuse``/``use_kernel``/
        ``interpret``/``blocks`` pass through).  Collectives: one int32
        (B, K) class-sum ``psum`` over ``model`` + one int32 (C_loc, L)
        delta ``psum`` over ``data``.  Bit-identical to the single-device
        oracle — every hash is indexed by global (sample, clause) ids via
        runtime ``b_offset``/``c_offset`` scalars.  Requires the clause
        axis divisible by the ``model`` axis size (``clause_pad_multiple``)
        and the batch by the data axes.

    ``algorithm="matmul"`` selects the beyond-paper binomial-aggregation
    step (its own shard_map schedule; statistically, not bitwise, exact).
    """
    if engine not in ("gspmd", "kernel"):
        # all engines are bit-identical, so a silent fallthrough on a typo
        # would "work" while measuring the wrong schedule — fail loudly
        raise ValueError(f"unknown engine {engine!r}: expected 'gspmd' or "
                         "'kernel'")
    if engine == "kernel" and config.n_clauses_total % mesh.shape["model"]:
        raise ValueError(
            f"clause axis ({config.n_clauses_total}) not divisible by the "
            f"model axis ({mesh.shape['model']}); align via "
            "clause_pad_multiple")
    d = data_axes(mesh)
    # matmul path: automata sharded over BOTH axes (clauses x literals): the
    # step all-gathers the int8 states over `data` (34 MB at pod scale) and
    # GSPMD reduce-scatters the f32 delta — far less wire than all-reducing
    # the dense delta against data-replicated states.
    lit_shard = d if algorithm == "matmul" else None
    state_s = NamedSharding(mesh, P("model", lit_shard))
    x_s = NamedSharding(mesh, P(d, None))
    y_s = NamedSharding(mesh, P(d))

    def step(ta_state, x, y, seed):
        from repro.kernels import ops

        if algorithm == "matmul":   # beyond-paper binomial-aggregation path
            # explicit shard_map schedule: GSPMD falls back to a dense f32
            # delta all-reduce here; the hand schedule is AG(int8) + two tiny
            # psums + psum_scatter (see EXPERIMENTS.md §Perf, TM cell)
            data_ax = d[-1] if d else "data"

            return jax_compat.shard_map(
                lambda ta, xx, yy: ops.tm_train_step_matmul_local(
                    config, ta, xx, yy, seed
                ),
                mesh=mesh,
                in_specs=(P("model", data_ax), P(d, None), P(d)),
                out_specs=P("model", data_ax),
                check_vma=False,
            )(ta_state, x, y)

        if engine == "kernel":
            # explicit clause-sharded shard_map schedule around the fused
            # kernel pipeline: each model shard owns (C_loc, L) automata and
            # evaluates/updates them locally; one class-sum psum over
            # `model`, one delta psum over the data axes.
            def body(ta_loc, xx, yy):
                C_loc, B_loc = ta_loc.shape[0], xx.shape[0]
                c_off = (jax.lax.axis_index("model").astype(jnp.uint32)
                         * jnp.uint32(C_loc))
                b_off = jnp.uint32(0)
                for ax in d:   # row-major global id of this data shard
                    b_off = (b_off * jnp.uint32(jax_compat.axis_size(ax))
                             + jax.lax.axis_index(ax).astype(jnp.uint32))
                b_off = b_off * jnp.uint32(B_loc)
                _, delta = ops.tm_train_step_kernel(
                    config, ta_loc, xx, yy, seed,
                    batch_chunk=batch_chunk, fuse=fuse, blocks=blocks,
                    b_offset=b_off, c_offset=c_off,
                    c_total=config.n_clauses_total,
                    sums_reduce=lambda s: jax.lax.psum(s, "model"),
                    use_kernel=use_kernel, interpret=interpret,
                )
                if d:   # combine the per-data-shard int32 partial deltas
                    delta = jax.lax.psum(delta, d)
                return jnp.clip(
                    ta_loc.astype(jnp.int32) + delta,
                    -config.n_states, config.n_states - 1,
                ).astype(jnp.int8)

            return jax_compat.shard_map(
                body, mesh=mesh,
                in_specs=(P("model", None), P(d, None), P(d)),
                out_specs=P("model", None),
                check_vma=False,
            )(ta_state, x, y)

        new_ta, _ = ops.tm_train_step_kernel(
            config, ta_state, x, y, seed, use_kernel=False,
            batch_chunk=batch_chunk,
        )
        return new_ta

    return jax.jit(
        step,
        in_shardings=(state_s, x_s, y_s, None),
        out_shardings=state_s,
        donate_argnums=0,
    )
