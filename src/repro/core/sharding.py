"""Mesh sharding for TM training/inference (DESIGN.md §5).

Layout:
  * automata / include words: clause axis over ``model``, replicated over
    ``data`` (and ``pod``);
  * batch: over (``pod`` x) ``data``;
  * vote matrix: clause axis over ``model``;
  * class sums: partial per model-shard -> one tiny ``psum`` over ``model``
    (the only inference collective);
  * training feedback deltas: computed locally per (data, model) shard, then
    ``psum`` over ``data`` only — int32 bounded-magnitude "compressed
    gradients".

Implemented with jit + NamedSharding constraints (GSPMD inserts exactly the
collectives above; verified in tests/test_sharding.py and the dry-run).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import tm


def data_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def tm_shardings(config: tm.TMConfig, mesh: Mesh):
    """(state_sharding, batch_sharding) for the TM train/serve steps."""
    d = data_axes(mesh)
    state = tm.TMState(
        ta_state=NamedSharding(mesh, P("model", None)),
        steps=NamedSharding(mesh, P()),
    )
    batch = NamedSharding(mesh, P(d, None))
    return state, batch


def sharded_predict_fn(config: tm.TMConfig, mesh: Mesh):
    """Build a jit'd sharded inference fn: packed literals -> class ids.

    Clause axis sharded over ``model``; GSPMD turns the vote matmul into a
    local matmul + all-reduce over ``model`` of the (B, K) partial sums.
    """
    d = data_axes(mesh)
    votes_s = NamedSharding(mesh, P("model", None))
    inc_s = NamedSharding(mesh, P("model", None))
    x_s = NamedSharding(mesh, P(d, None))
    out_s = NamedSharding(mesh, P(d))

    def predict(inc_words, votes, nonempty, lit_words):
        from repro.kernels import ops

        fired = ops.clause_fire(lit_words, inc_words, use_kernel=False)
        fired = fired * nonempty[None, :].astype(fired.dtype)
        sums = fired.astype(jnp.int32) @ votes
        return jnp.argmax(sums, axis=-1)

    return jax.jit(
        predict,
        in_shardings=(inc_s, votes_s, NamedSharding(mesh, P("model")), x_s),
        out_shardings=out_s,
    )


def sharded_train_step_fn(config: tm.TMConfig, mesh: Mesh,
                          batch_chunk: int | None = 2048,
                          algorithm: str = "bitwise"):
    """Build a jit'd sharded batch training step.

    The kernel-path step (hash RNG) is used because its feedback plan is a
    pure function of (fire, y, seed) — no cross-shard RNG state. Automata are
    replicated over ``data`` and sharded over ``model`` on the clause axis;
    the per-data-shard deltas are combined by GSPMD's all-reduce when the
    (replicated-output) update is applied.
    """
    d = data_axes(mesh)
    # matmul path: automata sharded over BOTH axes (clauses x literals): the
    # step all-gathers the int8 states over `data` (34 MB at pod scale) and
    # GSPMD reduce-scatters the f32 delta — far less wire than all-reducing
    # the dense delta against data-replicated states.
    lit_shard = d if algorithm == "matmul" else None
    state_s = NamedSharding(mesh, P("model", lit_shard))
    x_s = NamedSharding(mesh, P(d, None))
    y_s = NamedSharding(mesh, P(d))

    def step(ta_state, x, y, seed):
        from repro.kernels import ops

        if algorithm == "matmul":   # beyond-paper binomial-aggregation path
            # explicit shard_map schedule: GSPMD falls back to a dense f32
            # delta all-reduce here; the hand schedule is AG(int8) + two tiny
            # psums + psum_scatter (see EXPERIMENTS.md §Perf, TM cell)
            data_ax = d[-1] if d else "data"
            from repro import jax_compat

            return jax_compat.shard_map(
                lambda ta, xx, yy: ops.tm_train_step_matmul_local(
                    config, ta, xx, yy, seed
                ),
                mesh=mesh,
                in_specs=(P("model", data_ax), P(d, None), P(d)),
                out_specs=P("model", data_ax),
                check_vma=False,
            )(ta_state, x, y)
        new_ta, _ = ops.tm_train_step_kernel(
            config, ta_state, x, y, seed, use_kernel=False,
            batch_chunk=batch_chunk,
        )
        return new_ta

    return jax.jit(
        step,
        in_shardings=(state_s, x_s, y_s, None),
        out_shardings=state_s,
        donate_argnums=0,
    )
