"""Tsetlin Machine core — paper-faithful definition (MATADOR / Granmo'18).

The TM model is a bank of Tsetlin Automata, one per (class, clause, literal).
``int8`` states centered at zero; action = *include* iff state >= 0.  A clause
is the AND of its included literals; class sums are polarity-weighted clause
votes; classification is the argmax over class sums.

Everything here is a pure function over a ``TMState`` pytree so it composes
with jit / vmap / shard_map.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TMConfig:
    """Hyperparameters of a (multiclass, vanilla) Tsetlin Machine.

    Mirrors the knobs MATADOR's GUI exposes: clauses per class, threshold T,
    specificity s, number of automata states.
    """

    n_features: int
    n_classes: int
    clauses_per_class: int
    n_states: int = 128          # states per action -> int8 in [-128, 127]
    threshold: int = 15          # T
    s: float = 10.0              # specificity
    boost_true_positive: bool = True
    # Pad the flattened clause axis to a multiple of this (sharding alignment;
    # padded clauses are permanently empty and vote 0).
    clause_pad_multiple: int = 1

    @property
    def n_literals(self) -> int:
        return 2 * self.n_features

    @property
    def n_clauses_total(self) -> int:
        raw = self.n_classes * self.clauses_per_class
        m = self.clause_pad_multiple
        return ((raw + m - 1) // m) * m

    @property
    def n_clauses_raw(self) -> int:
        return self.n_classes * self.clauses_per_class

    def replace(self, **kw: Any) -> "TMConfig":
        return dataclasses.replace(self, **kw)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TMState:
    """Trainable state: the automata bank, flattened over (class, clause)."""

    ta_state: jax.Array  # int8 (n_clauses_total, n_literals)
    steps: jax.Array     # int32 scalar

    @property
    def dtype(self):
        return self.ta_state.dtype


def init(config: TMConfig, rng: jax.Array) -> TMState:
    """Random init in {-1, 0}: automata sit just either side of the decision
    boundary, per standard TM initialization."""
    shape = (config.n_clauses_total, config.n_literals)
    st = jax.random.randint(rng, shape, minval=-1, maxval=1, dtype=jnp.int8)
    if config.n_clauses_total != config.n_clauses_raw:
        # padded clauses are pinned to all-exclude (empty) forever
        pad_from = config.n_clauses_raw
        st = st.at[pad_from:].set(jnp.int8(-config.n_states))
    return TMState(ta_state=st, steps=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# Literals & clauses
# ---------------------------------------------------------------------------

def literals(x: jax.Array) -> jax.Array:
    """(B, F) {0,1} -> (B, 2F): each feature contributes x and ~x (Fig. 1b)."""
    x = x.astype(jnp.uint8)
    return jnp.concatenate([x, 1 - x], axis=-1)


def include_mask(ta_state: jax.Array) -> jax.Array:
    """Boolean include/exclude actions of each automaton."""
    return ta_state >= 0


def clause_outputs(
    ta_state: jax.Array, lits: jax.Array, *, training: bool
) -> jax.Array:
    """Dense clause evaluation (the ``ref`` semantics the kernels must match).

    clause fires iff no included literal is 0.  Empty clauses output 1 during
    training (vacuous AND) and 0 at inference (they are dropped from the
    compiled circuit, paper §III).

    Args:
      ta_state: (C, L) int8.
      lits: (B, L) {0,1}.
    Returns:
      (B, C) uint8 clause outputs.
    """
    inc = include_mask(ta_state)                       # (C, L)
    viol = inc[None, :, :] & (lits[:, None, :] == 0)    # (B, C, L)
    fire = ~jnp.any(viol, axis=-1)                      # (B, C)
    if not training:
        nonempty = jnp.any(inc, axis=-1)                # (C,)
        fire = fire & nonempty[None, :]
    return fire.astype(jnp.uint8)


def polarity(config: TMConfig) -> jax.Array:
    """+1/-1 alternating within each class; 0 on padded clauses."""
    j = jnp.arange(config.n_clauses_total)
    pol = jnp.where(j % 2 == 0, 1, -1).astype(jnp.int32)
    return jnp.where(j < config.n_clauses_raw, pol, 0)


def vote_matrix(config: TMConfig) -> jax.Array:
    """(C_total, n_classes) int32: class-sum = clause_outputs @ vote_matrix.

    This is the class-sum adder bank of the paper's accelerator expressed as
    an (MXU-friendly) int matmul.
    """
    c = jnp.arange(config.n_clauses_total)
    cls = jnp.clip(c // config.clauses_per_class, 0, config.n_classes - 1)
    onehot = (cls[:, None] == jnp.arange(config.n_classes)[None, :])
    return onehot.astype(jnp.int32) * polarity(config)[:, None]


def class_sums(
    config: TMConfig, ta_state: jax.Array, lits: jax.Array, *, training: bool
) -> jax.Array:
    """(B, n_classes) int32 polarity-weighted clause votes."""
    out = clause_outputs(ta_state, lits, training=training)   # (B, C)
    return out.astype(jnp.int32) @ vote_matrix(config)


def predict(
    config: TMConfig,
    state: TMState,
    x: jax.Array,
    *,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
    **blocks,
) -> jax.Array:
    """Argmax classification (binary-tree comparison in the paper).

    When the kernel path is active (``use_kernel=True`` or
    ``REPRO_USE_PALLAS=1``) the sums come from the fused single-pass Pallas
    kernel over packed literals (kernels/fused_infer.py); otherwise the
    dense XLA path below.
    """
    from repro.kernels import ops

    uk, it = ops.kernel_dispatch(use_kernel, interpret)
    if uk:
        from repro.core import packetizer

        lw = packetizer.pack_literals(x)
        iw = packetizer.pack_include_masks(state.ta_state)
        nonempty = jnp.any(state.ta_state >= 0, axis=-1).astype(jnp.uint8)
        sums = ops.tm_forward_packed(
            lw, iw, vote_matrix(config), nonempty,
            use_kernel=uk, interpret=it, **blocks,
        )
    else:
        sums = class_sums(config, state.ta_state, literals(x), training=False)
    return jnp.argmax(sums, axis=-1)


def accuracy(config: TMConfig, state: TMState, x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.mean((predict(config, state, x) == y).astype(jnp.float32))
