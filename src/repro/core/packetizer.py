"""Bandwidth-driven literal packing — the TPU analog of MATADOR's Packetizer.

The paper streams each datapoint to the FPGA as 64-bit AXI packets
(Fig. 4a): least-significant-bit first, zero-padded final packet.  On TPU the
"channel" is the HBM->VMEM DMA, and the packet is a 32-bit vector lane: we
pack the 2F literals of each datapoint into ``ceil(2F/32)`` uint32 words,
bit i of word w = literal ``32*w + i`` (LSB-first, matching Fig. 4a), with
zero padding in the final word.

Zero padding is safe by construction: include masks are packed with the same
layout, padding bits of the include mask are 0, and a clause violation is
``include & ~literal`` — a zero include bit can never produce a violation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32


def n_words(n_bits: int, word_bits: int = WORD_BITS) -> int:
    return (n_bits + word_bits - 1) // word_bits


def pack_bits(bits: jax.Array, word_bits: int = WORD_BITS) -> jax.Array:
    """Pack a {0,1} array along its last axis into uint32 words (LSB-first).

    (..., L) -> (..., ceil(L/word_bits)) uint32.
    """
    L = bits.shape[-1]
    W = n_words(L, word_bits)
    pad = W * word_bits - L
    b = bits.astype(jnp.uint32)
    if pad:
        b = jnp.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, pad)])
    b = b.reshape(b.shape[:-1] + (W, word_bits))
    weights = (jnp.uint32(1) << jnp.arange(word_bits, dtype=jnp.uint32))
    return jnp.sum(b * weights, axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jax.Array, n_bits: int, word_bits: int = WORD_BITS) -> jax.Array:
    """Inverse of :func:`pack_bits`. (..., W) uint32 -> (..., n_bits) uint8."""
    shifts = jnp.arange(word_bits, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(words.shape[:-1] + (words.shape[-1] * word_bits,))
    return bits[..., :n_bits].astype(jnp.uint8)


def pack_literals(x: jax.Array, word_bits: int = WORD_BITS) -> jax.Array:
    """(B, F) {0,1} features -> (B, ceil(2F/32)) packed literal words."""
    from repro.core.tm import literals

    return pack_bits(literals(x), word_bits)


def pack_include_masks(ta_state: jax.Array, word_bits: int = WORD_BITS) -> jax.Array:
    """(C, L) int8 automata -> (C, W) packed include masks."""
    inc = (ta_state >= 0).astype(jnp.uint8)
    return pack_bits(inc, word_bits)


# -- numpy twins (host-side "Packetizer" used by the offline compiler) -------

def pack_bits_np(bits: np.ndarray, word_bits: int = WORD_BITS) -> np.ndarray:
    L = bits.shape[-1]
    W = n_words(L, word_bits)
    pad = W * word_bits - L
    b = bits.astype(np.uint64)
    if pad:
        b = np.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, pad)])
    b = b.reshape(b.shape[:-1] + (W, word_bits))
    weights = (np.uint64(1) << np.arange(word_bits, dtype=np.uint64))
    return (b * weights).sum(axis=-1).astype(np.uint32)


def unpack_bits_np(words: np.ndarray, n_bits: int, word_bits: int = WORD_BITS) -> np.ndarray:
    shifts = np.arange(word_bits, dtype=np.uint32)
    bits = (words[..., None] >> shifts) & np.uint32(1)
    bits = bits.reshape(words.shape[:-1] + (words.shape[-1] * word_bits,))
    return bits[..., :n_bits].astype(np.uint8)
