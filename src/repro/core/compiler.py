"""The boolean-to-silicon pass — MATADOR's model compiler, TPU edition.

The paper translates a trained TM into a compact combinational circuit by
exploiting (a) include sparsity and (b) logic sharing between clauses within
and across classes (paper §II, Fig. 3, Fig. 8).  On FPGA that compression is
performed by the synthesis tool's logic-absorption algorithms; here it is an
explicit, host-side (numpy) compilation pass with three optimizations:

  1. **Empty-clause removal** — all-exclude clauses are constant 0 at
     inference; drop them (paper: they never reach the netlist).
  2. **Clause deduplication** — identical include rows are evaluated once;
     their votes are folded into an int32 (unique_clause x class) vote
     matrix carrying multiplicity x polarity.  This is clause-granular logic
     sharing: the shared sub-circuit is computed once and fanned out.
  3. **Dead-word elimination** — packed literal words that no surviving
     clause includes are never loaded (column pruning).  This is the
     bandwidth optimization: the accelerator only streams words that matter.

The compiled artifact runs through the same bitpacked evaluation path (and
Pallas kernel) as the dense model and is *provably equivalent* to dense
inference (tests/test_compiler.py, hypothesis property).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import packetizer, tm


@dataclasses.dataclass
class CompileStats:
    n_clauses_dense: int
    n_clauses_nonempty: int
    n_clauses_unique: int
    n_words_dense: int
    n_words_active: int
    n_includes: int
    n_literals: int
    # partial-clause (HCB-term) sharing: two clauses whose include bits agree
    # within word w share that word's AND gate (paper Fig. 5 logic sharing —
    # on FPGA the synthesis absorbs these; we quantify the opportunity)
    n_partial_terms_dense: int = 0
    n_partial_terms_unique: int = 0

    @property
    def include_sparsity(self) -> float:
        tot = self.n_clauses_dense * self.n_literals
        return 1.0 - self.n_includes / max(tot, 1)

    @property
    def clause_sharing(self) -> float:
        """Fraction of non-empty clauses absorbed by sharing (paper Fig. 8)."""
        if self.n_clauses_nonempty == 0:
            return 0.0
        return 1.0 - self.n_clauses_unique / self.n_clauses_nonempty

    @property
    def word_compaction(self) -> float:
        return 1.0 - self.n_words_active / max(self.n_words_dense, 1)

    @property
    def partial_term_sharing(self) -> float:
        """Fraction of per-word AND gates absorbed by sub-clause sharing."""
        if self.n_partial_terms_dense == 0:
            return 0.0
        return 1.0 - self.n_partial_terms_unique / self.n_partial_terms_dense

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(
            include_sparsity=self.include_sparsity,
            clause_sharing=self.clause_sharing,
            word_compaction=self.word_compaction,
            partial_term_sharing=self.partial_term_sharing,
        )
        return d


@dataclasses.dataclass
class CompiledTM:
    """Deployable inference artifact (the "bitstream" analog)."""

    include_words: np.ndarray   # (U, Wa) uint32 — deduped, word-compacted
    word_ids: np.ndarray        # (Wa,) int32 — active word indices into dense W
    votes: np.ndarray           # (U, n_classes) int32 — multiplicity x polarity
    n_features: int
    n_classes: int
    stats: CompileStats

    @property
    def n_unique(self) -> int:
        return self.include_words.shape[0]

    @property
    def n_words_active(self) -> int:
        return self.include_words.shape[1]

    def save(self, path: str) -> None:
        np.savez_compressed(
            path,
            include_words=self.include_words,
            word_ids=self.word_ids,
            votes=self.votes,
            meta=np.frombuffer(
                json.dumps(
                    dict(
                        n_features=self.n_features,
                        n_classes=self.n_classes,
                        stats=self.stats.as_dict(),
                    )
                ).encode(),
                dtype=np.uint8,
            ),
        )

    @staticmethod
    def load(path: str) -> "CompiledTM":
        z = np.load(path)
        meta = json.loads(bytes(z["meta"]).decode())
        st = meta["stats"]
        stats = CompileStats(
            **{k: st[k] for k in (
                "n_clauses_dense", "n_clauses_nonempty", "n_clauses_unique",
                "n_words_dense", "n_words_active", "n_includes", "n_literals",
                "n_partial_terms_dense", "n_partial_terms_unique",
            ) if k in st}
        )
        return CompiledTM(
            include_words=z["include_words"],
            word_ids=z["word_ids"],
            votes=z["votes"],
            n_features=meta["n_features"],
            n_classes=meta["n_classes"],
            stats=stats,
        )


def compile_tm(
    config: tm.TMConfig,
    ta_state,
    *,
    dedup: bool = True,
    prune_words: bool = True,
) -> CompiledTM:
    """Compile a trained automata bank into a :class:`CompiledTM`.

    ``dedup=False, prune_words=False`` is the DON'T-TOUCH-pragma analog used
    by benchmarks/logic_sharing.py to measure the savings (paper Fig. 8).
    """
    ta = np.asarray(ta_state)
    C_raw = config.n_clauses_raw
    inc = (ta[:C_raw] >= 0).astype(np.uint8)               # (C, L)
    pol = np.where(np.arange(C_raw) % 2 == 0, 1, -1).astype(np.int32)
    cls = np.arange(C_raw) // config.clauses_per_class

    nonempty = inc.any(axis=1)
    inc_ne = inc[nonempty]
    pol_ne = pol[nonempty]
    cls_ne = cls[nonempty]
    n_nonempty = int(inc_ne.shape[0])

    words_dense = packetizer.pack_bits_np(inc_ne) if n_nonempty else np.zeros(
        (0, packetizer.n_words(config.n_literals)), np.uint32
    )
    W = packetizer.n_words(config.n_literals)

    if dedup and n_nonempty:
        uniq, inv = np.unique(words_dense, axis=0, return_inverse=True)
    else:
        uniq, inv = words_dense, np.arange(n_nonempty)
    U = uniq.shape[0]

    votes = np.zeros((max(U, 1), config.n_classes), np.int32)
    if n_nonempty:
        np.add.at(votes, (inv, cls_ne), pol_ne)
    if U == 0:
        uniq = np.zeros((1, W), np.uint32)  # degenerate all-empty model
        U = 1

    if prune_words:
        active = uniq.any(axis=0)
        if not active.any():
            active[:1] = True
        word_ids = np.nonzero(active)[0].astype(np.int32)
    else:
        word_ids = np.arange(uniq.shape[1], dtype=np.int32)
    uniq = uniq[:, word_ids]

    # partial-clause sharing opportunity: unique nonzero include words per
    # word column (zero words are free — they never gate anything)
    nonzero_terms = int((uniq != 0).sum())
    unique_terms = sum(
        len(np.unique(col[col != 0])) for col in uniq.T
    )
    stats = CompileStats(
        n_clauses_dense=C_raw,
        n_clauses_nonempty=n_nonempty,
        n_clauses_unique=int(U),
        n_words_dense=int(W),
        n_words_active=int(word_ids.shape[0]),
        n_includes=int(inc.sum()),
        n_literals=config.n_literals,
        n_partial_terms_dense=nonzero_terms,
        n_partial_terms_unique=int(unique_terms),
    )
    return CompiledTM(
        include_words=uniq.astype(np.uint32),
        word_ids=word_ids,
        votes=votes[:U],
        n_features=config.n_features,
        n_classes=config.n_classes,
        stats=stats,
    )


def run_compiled(
    compiled: CompiledTM,
    x_packed: jnp.ndarray,
    *,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
    fuse: bool = True,
    **blocks,
) -> jnp.ndarray:
    """Inference with the compiled artifact: (B, W_dense) packed literals ->
    (B, n_classes) int32 class sums.

    Dispatch defers to ``kernels/ops`` resolution: ``use_kernel=None``
    follows ``REPRO_USE_PALLAS``; ``interpret=None`` compiles on TPU and
    interprets elsewhere (no more unconditional ``interpret=True``).  The
    kernel path runs the fused single-pass kernel (``fuse=False`` for the
    legacy two-kernel pipeline); otherwise the pure-jnp oracle.  Empty-clause
    masking is unnecessary here — compilation already dropped empty clauses
    (the degenerate all-empty artifact keeps one all-zero clause whose votes
    are zero).
    """
    from repro.kernels import ops

    xw = x_packed[:, jnp.asarray(compiled.word_ids)]        # dead-word elim
    inc = jnp.asarray(compiled.include_words)
    votes = jnp.asarray(compiled.votes)
    return ops.tm_forward_packed(
        xw, inc, votes, None,
        use_kernel=use_kernel, interpret=interpret, fuse=fuse, **blocks,
    )


def predict_compiled(compiled: CompiledTM, x: jnp.ndarray, **kw) -> jnp.ndarray:
    """(B, F) raw boolean features -> predicted class ids."""
    xp = packetizer.pack_literals(x)
    return jnp.argmax(run_compiled(compiled, xp, **kw), axis=-1)
