"""The boolean-to-silicon pass — MATADOR's model compiler, TPU edition.

The paper translates a trained TM into a compact combinational circuit by
exploiting (a) include sparsity and (b) logic sharing between clauses within
and across classes (paper §II, Fig. 3, Fig. 8).  On FPGA that compression is
performed by the synthesis tool's logic-absorption algorithms; here it is an
explicit, host-side (numpy) compilation pass with three optimizations:

  1. **Empty-clause removal** — all-exclude clauses are constant 0 at
     inference; drop them (paper: they never reach the netlist).
  2. **Clause deduplication** — identical include rows are evaluated once;
     their votes are folded into an int32 (unique_clause x class) vote
     matrix carrying multiplicity x polarity.  This is clause-granular logic
     sharing: the shared sub-circuit is computed once and fanned out.
  3. **Dead-word elimination** — packed literal words that no surviving
     clause includes are never loaded (column pruning).  This is the
     bandwidth optimization: the accelerator only streams words that matter.
  4. **Chain-schedule emission** — unique clauses are clustered by
     (chain length, active-word signature) and each clause's include bits
     become a compacted literal-id chain, tiled into a CSR-like
     block-sparse execution schedule (``kernels/sparse_infer.py``).  The
     sparse fused kernel walks only the tiles that exist, so inference
     work scales with the artifact's include count — the paper's
     "miniscule number of AND gates" — instead of ``C x W``.
  5. **Shared-term factorization** — the unique (word, include-pattern)
     AND terms across the deduped bank are extracted into a term table and
     each clause is rewritten as a chain of TERM ids
     (``kernels/term_infer.py``).  This is sub-clause logic sharing (paper
     Fig. 5 absorption, the opportunity ``partial_term_sharing``
     measures): a term shared by ``n`` clauses is evaluated once per
     sample slab instead of ``n`` times.  The factorized kernel is the
     kernel-path default when the artifact's measured sharing clears
     ``FACTORIZE_SHARING_THRESHOLD``.

The compiled artifact runs through the same bitpacked evaluation path (and
Pallas kernels) as the dense model and is *provably equivalent* to dense
inference (tests/test_compiler.py + tests/test_sparse_infer.py, hypothesis
properties).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import struct
import zipfile
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import packetizer, tm
from repro.runtime import faults

# kernel-path default: serve the factorized (two-level) schedule when at
# least this fraction of the artifact's per-word AND terms are absorbed by
# sub-clause sharing — below it the term table amortizes too little stage-1
# work to beat the flat bit-chain kernel
FACTORIZE_SHARING_THRESHOLD = 0.30

# On-disk artifact schema.  Version 1 added the integrity envelope (schema
# tag + content checksum, saved atomically); version-0 artifacts (no tag)
# predate it and are REJECTED at load — an unverifiable artifact must be
# recompiled, not served on trust.
ARTIFACT_SCHEMA_VERSION = 2   # v2: per-tile-prefix anytime margin metadata


class ArtifactError(RuntimeError):
    """A compiled artifact failed integrity verification at load.

    Raised for unreadable/truncated files, schema-version mismatches,
    content-checksum mismatches (bit-rot, partial writes), and schedule
    invariant violations.  The serve path treats this as fatal: a corrupt
    artifact must never serve silently-wrong predictions (out-of-range
    word gathers clamp instead of failing).
    """


def _artifact_checksum(arrays: dict, meta: dict) -> str:
    """Content hash over every artifact array + the meta (sans checksum).

    Arrays hash (name, dtype, shape, bytes) in sorted-name order; the meta
    dict hashes as canonical JSON, so save() and load() agree byte-for-byte
    on the same content.
    """
    h = hashlib.sha256()
    for key in sorted(arrays):
        a = np.ascontiguousarray(arrays[key])
        h.update(key.encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    h.update(json.dumps(meta, sort_keys=True).encode())
    return h.hexdigest()


def _payload_offset(path: str) -> Optional[int]:
    """Byte offset of real member payload inside the saved npz.

    The bit-rot drill (``artifact.bitflip``) flips one byte of the file;
    aiming at the middle of the *largest member's compressed data* keeps
    the drill meaningful regardless of how the zip layout shifts between
    schema versions — a flip at a naive ``size // 2`` can land in a local
    file header's redundant csize/crc fields, which zipfile never reads
    (it trusts the central directory), so the "corrupt" artifact would
    load cleanly and the drill would assert nothing.
    """
    try:
        with zipfile.ZipFile(path) as zf:
            info = max(zf.infolist(), key=lambda zi: zi.compress_size)
            with open(path, "rb") as f:
                # local header: fnlen @ +26, extralen @ +28 (little-endian)
                f.seek(info.header_offset + 26)
                fnlen, extralen = struct.unpack("<HH", f.read(4))
            data_start = info.header_offset + 30 + fnlen + extralen
            return data_start + info.compress_size // 2
    except Exception:
        return None


@dataclasses.dataclass
class CompileStats:
    n_clauses_dense: int
    n_clauses_nonempty: int
    n_clauses_unique: int
    n_words_dense: int
    n_words_active: int
    n_includes: int
    n_literals: int
    # partial-clause (HCB-term) sharing: two clauses whose include bits agree
    # within word w share that word's AND gate (paper Fig. 5 logic sharing —
    # on FPGA the synthesis absorbs these; we quantify the opportunity)
    n_partial_terms_dense: int = 0
    n_partial_terms_unique: int = 0

    @property
    def include_sparsity(self) -> float:
        tot = self.n_clauses_dense * self.n_literals
        return 1.0 - self.n_includes / max(tot, 1)

    @property
    def clause_sharing(self) -> float:
        """Fraction of non-empty clauses absorbed by sharing (paper Fig. 8)."""
        if self.n_clauses_nonempty == 0:
            return 0.0
        return 1.0 - self.n_clauses_unique / self.n_clauses_nonempty

    @property
    def word_compaction(self) -> float:
        return 1.0 - self.n_words_active / max(self.n_words_dense, 1)

    @property
    def partial_term_sharing(self) -> float:
        """Fraction of per-word AND gates absorbed by sub-clause sharing."""
        if self.n_partial_terms_dense == 0:
            return 0.0
        return 1.0 - self.n_partial_terms_unique / self.n_partial_terms_dense

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(
            include_sparsity=self.include_sparsity,
            clause_sharing=self.clause_sharing,
            word_compaction=self.word_compaction,
            partial_term_sharing=self.partial_term_sharing,
        )
        return d


@dataclasses.dataclass
class DriftStats:
    """How far a live automata bank has drifted from a reference bank.

    Measured on the DENSE packed include words (every raw clause, before
    dedup/pruning), so the comparison is stable across recompiles: two
    banks compare row-for-row regardless of how their compiled artifacts
    deduped.  ``drift`` is the normalized signal the online updater
    thresholds on — changed include bits relative to the reference bank's
    include count (a freshly-promoted artifact reads 0.0).
    """

    n_clauses: int
    n_clauses_changed: int
    n_bits_changed: int
    n_includes_ref: int
    n_includes_live: int

    @property
    def drift(self) -> float:
        return self.n_bits_changed / max(self.n_includes_ref, 1)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["drift"] = self.drift
        return d


def dense_include_words(config: tm.TMConfig, ta_state) -> np.ndarray:
    """(C_raw, W) packed include words of a raw automata bank — the
    drift-tracking snapshot (no dedup, no pruning, no clustering)."""
    ta = np.asarray(ta_state)
    inc = (ta[: config.n_clauses_raw] >= 0).astype(np.uint8)
    return packetizer.pack_bits_np(inc)


def include_drift(ref_words: np.ndarray, live_words: np.ndarray) -> DriftStats:
    """Compare two dense packed include banks (same shape) bit-for-bit."""
    ref = np.asarray(ref_words, dtype=np.uint32)
    live = np.asarray(live_words, dtype=np.uint32)
    if ref.shape != live.shape:
        raise ValueError(
            f"include_drift: shape mismatch {ref.shape} vs {live.shape} — "
            "drift is only defined against the same clause bank layout")
    x = np.ascontiguousarray(ref ^ live)
    return DriftStats(
        n_clauses=int(ref.shape[0]),
        n_clauses_changed=int(x.any(axis=1).sum()) if ref.size else 0,
        n_bits_changed=int(np.unpackbits(x.view(np.uint8)).sum()),
        n_includes_ref=int(np.unpackbits(
            np.ascontiguousarray(ref).view(np.uint8)).sum()),
        n_includes_live=int(np.unpackbits(
            np.ascontiguousarray(live).view(np.uint8)).sum()),
    )


@dataclasses.dataclass
class CompiledTM:
    """Deployable inference artifact (the "bitstream" analog).

    Rows of ``include_words``/``votes`` are in :func:`cluster_order` (chain
    length, then active-word signature) so the block-sparse schedules built
    from them get chain-length-homogeneous clause blocks.  Schedules are
    memoized per ``(block_c, block_j)`` tiling — the autotuner picks the
    tiling, the artifact answers with the matching tile table.
    """

    include_words: np.ndarray   # (U, Wa) uint32 — deduped, word-compacted
    word_ids: np.ndarray        # (Wa,) int32 — active word indices into dense W
    votes: np.ndarray           # (U, n_classes) int32 — multiplicity x polarity
    n_features: int
    n_classes: int
    stats: CompileStats
    _schedules: dict = dataclasses.field(default_factory=dict, repr=False)
    _fschedules: dict = dataclasses.field(default_factory=dict, repr=False)
    # anytime-inference metadata (kernels/anytime.py): per-tile-prefix
    # residual-swing margins, keyed like the schedule memos; quality-level
    # prefix schedules keyed (engine, schedule key, level)
    _margins: dict = dataclasses.field(default_factory=dict, repr=False)
    _fmargins: dict = dataclasses.field(default_factory=dict, repr=False)
    _prefix_schedules: dict = dataclasses.field(default_factory=dict,
                                                repr=False)
    # autotuned kernel tilings recorded against this artifact (keyed
    # "<kernel>:B<bucket>"), shipped by save() so a cold-start server loads
    # a tuned schedule instead of re-paying the sweep
    tuned: dict = dataclasses.field(default_factory=dict, repr=False)
    # candidate-independent cost-model features
    # (``kernels/cost_model.artifact_features``), shipped by save() so a
    # zoo cold-load predicts a tiling with neither timing runs nor the
    # HLO-lowering the feature extraction pays once
    features: dict = dataclasses.field(default_factory=dict, repr=False)

    @property
    def n_unique(self) -> int:
        return self.include_words.shape[0]

    @property
    def n_words_active(self) -> int:
        return self.include_words.shape[1]

    def schedule(self, block_c: int | None = None, block_j: int | None = None):
        """Block-sparse chain schedule for this artifact at the given
        tiling (defaults from ``kernels/sparse_infer.py``), memoized."""
        from repro.kernels import sparse_infer

        key = (
            block_c or sparse_infer.DEFAULT_BLOCK_C,
            block_j or sparse_infer.DEFAULT_BLOCK_J,
        )
        if key not in self._schedules:
            self._schedules[key] = sparse_infer.build_schedule(
                self.include_words, block_c=key[0], block_j=key[1]
            )
        return self._schedules[key]

    @property
    def default_schedule(self):
        return self.schedule()

    def factorized_schedule(self, block_c: int | None = None,
                            block_j: int | None = None,
                            block_t: int | None = None,
                            term_w: int | None = None):
        """Two-level factorized (shared-term) schedule for this artifact
        at the given tiling (defaults from ``kernels/term_infer.py``;
        ``term_w=None`` auto-picks the bit-chain width), memoized."""
        from repro.kernels import term_infer

        if term_w is None:
            term_w = term_infer.pick_term_width(self.include_words)
        key = (
            block_c or term_infer.DEFAULT_BLOCK_C,
            block_j or term_infer.DEFAULT_BLOCK_J,
            block_t or term_infer.DEFAULT_BLOCK_T,
            term_w,
        )
        if key not in self._fschedules:
            self._fschedules[key] = term_infer.build_factorized_schedule(
                self.include_words, block_c=key[0], block_j=key[1],
                block_t=key[2], term_w=key[3],
            )
        return self._fschedules[key]

    @property
    def default_factorized_schedule(self):
        return self.factorized_schedule()

    def tile_margins(self, block_c: int | None = None,
                     block_j: int | None = None) -> np.ndarray:
        """(T,) residual-swing margin table for the sparse chain schedule
        at the given tiling (``kernels/anytime.py``), memoized; loaded
        artifacts ship the default-tiling table verbatim."""
        from repro.kernels import anytime, sparse_infer

        key = (
            block_c or sparse_infer.DEFAULT_BLOCK_C,
            block_j or sparse_infer.DEFAULT_BLOCK_J,
        )
        if key not in self._margins:
            self._margins[key] = anytime.sparse_tile_margins(
                self.schedule(*key), self.votes)
        return self._margins[key]

    def factorized_tile_margins(self, block_c: int | None = None,
                                block_j: int | None = None,
                                block_t: int | None = None,
                                term_w: int | None = None) -> np.ndarray:
        """(T,) residual-swing margin table for the factorized schedule at
        the given tiling, memoized."""
        from repro.kernels import anytime

        fsched = self.factorized_schedule(block_c, block_j, block_t, term_w)
        # mirror factorized_schedule's memo key exactly (term_w auto-pick)
        key = next(k for k, v in self._fschedules.items() if v is fsched)
        if key not in self._fmargins:
            self._fmargins[key] = anytime.factorized_tile_margins(
                fsched, self.votes)
        return self._fmargins[key]

    def quality_levels(self, engine: str = "sparse", **tiling) -> list:
        """Quality tiers for this artifact on the given schedule engine:
        ``[{level, n_tiles, bound, frac}, ...]`` with level 0 = exact full
        walk (bound 0) and levels 1..N progressively shorter tile prefixes
        whose error bound (``kernels/anytime.py`` semantics: the served
        class trails the true winner by at most ``bound`` votes) is the
        residual swing after the prefix."""
        from repro.kernels import anytime

        if engine == "factorized":
            fsched = self.factorized_schedule(**tiling)
            margins = self.factorized_tile_margins(**tiling)
            full, min_tiles = fsched.n_tiles, fsched.n_term_tiles + 1
        else:
            sched = self.schedule(**tiling)
            margins = self.tile_margins(**tiling)
            full, min_tiles = sched.n_tiles, 1
        levels = [dict(level=0, n_tiles=full, bound=0, frac=0.0)]
        levels.extend(anytime.quality_prefixes(
            margins, anytime.total_swing(self.votes), min_tiles=min_tiles))
        return levels

    def quality_prefix_schedule(self, level: int, engine: str = "sparse",
                                **tiling):
        """The tile-prefix schedule serving quality ``level`` (level 0
        returns the full schedule), memoized."""
        from repro.kernels import anytime

        if level <= 0:
            return (self.factorized_schedule(**tiling)
                    if engine == "factorized" else self.schedule(**tiling))
        key = (engine, tuple(sorted(tiling.items())), int(level))
        if key not in self._prefix_schedules:
            levels = self.quality_levels(engine, **tiling)
            q = levels[min(level, len(levels) - 1)]
            if engine == "factorized":
                self._prefix_schedules[key] = anytime.factorized_prefix_schedule(
                    self.factorized_schedule(**tiling), q["n_tiles"])
            else:
                self._prefix_schedules[key] = anytime.sparse_prefix_schedule(
                    self.schedule(**tiling), q["n_tiles"])
        return self._prefix_schedules[key]

    @staticmethod
    def _tuned_key(kernel: str, bucket: int, rows: int | None,
                   mode: str | None) -> str:
        key = f"{kernel}:B{int(bucket)}"
        if rows is not None:
            key += f":U{int(rows)}"      # shard-slice vs full-bank sweeps
        if mode is not None:
            key += f":{mode}"            # backend:interp|compiled
        return key

    def record_tuned(self, kernel: str, bucket: int, blocks: dict, *,
                     rows: int | None = None, mode: str | None = None) -> None:
        """Remember an autotuned tiling for this artifact (persisted by
        ``save()``): ``kernel`` is the sweep family (``sparse_infer`` /
        ``term_infer`` / ``fused_infer``), ``bucket`` the request-batch
        size the sweep ran at, ``rows`` the clause-row count the sweep
        actually saw (a mesh run tunes a per-shard SLICE — its winner must
        not answer for the full bank), and ``mode`` the backend/interpret
        tag (``kernels/autotune._mode_backend``) so a CPU-interpret tiling
        is never recalled on a compiled TPU server."""
        self.tuned[self._tuned_key(kernel, bucket, rows, mode)] = dict(blocks)

    def tuned_blocks(self, kernel: str, bucket: int, *,
                     rows: int | None = None,
                     mode: str | None = None) -> dict | None:
        """Recall a tiling recorded by :meth:`record_tuned` (or shipped
        inside a loaded artifact); None when this exact (kernel, bucket,
        rows, mode) was never tuned."""
        blocks = self.tuned.get(self._tuned_key(kernel, bucket, rows, mode))
        return dict(blocks) if blocks is not None else None

    def extract_features(self, refresh: bool = False) -> dict:
        """Candidate-independent cost-model features of this artifact
        (``kernels/cost_model.artifact_features``), memoized on the
        instance and persisted by :meth:`save`.  The HLO-derived terms
        degrade gracefully: a shape the oracle can't lower (or a backend
        without cost analysis) still yields the schedule-statistic
        features, so prediction never blocks serving."""
        if self.features and not refresh:
            return dict(self.features)
        from repro.kernels import cost_model

        try:
            feats = cost_model.artifact_features(self)
        except Exception:
            feats = cost_model.artifact_features(self, with_hlo=False)
        self.features = feats
        return dict(feats)

    def save(self, path: str) -> str:
        """Write the artifact atomically with an integrity envelope.

        The default-tiling schedules ship inside the artifact (the
        "bitstream" carries its execution schedules); other tilings are
        rebuilt on demand from the include rows.  Autotuned tilings
        recorded via record_tuned() and the cost-model feature dict ride
        in the meta JSON, so a server cold-starting from this file skips
        both the sweep and the feature extraction entirely.

        Integrity: the meta carries ``ARTIFACT_SCHEMA_VERSION`` and a
        sha256 content checksum over every array + the meta itself, and
        the file is written to a tmp path then ``os.replace``d — a SIGTERM
        mid-save can never truncate the artifact the next run will load,
        and ``load()`` rejects any byte that rotted after the replace.
        Returns the final path (``.npz`` is appended when missing, the
        same normalization ``np.savez`` applies).
        """
        sched = self.default_schedule
        fsched = self.default_factorized_schedule
        arrays = dict(
            include_words=self.include_words,
            word_ids=self.word_ids,
            votes=self.votes,
            sched_margin=np.asarray(self.tile_margins(), np.int64),
            fsched_margin=np.asarray(self.factorized_tile_margins(), np.int64),
            sched_chain_ids=sched.chain_ids,
            sched_tiles=np.stack([sched.tile_cb, sched.tile_jb,
                                  sched.tile_first, sched.tile_last])
            if sched.n_tiles else np.zeros((4, 0), np.int32),
            sched_counts=sched.counts,
            fsched_term_chain=fsched.term_chain,
            fsched_term_table=np.stack([
                fsched.term_word,
                fsched.term_val.astype(np.int64).astype(np.int32)])
            if fsched.n_terms else np.zeros((2, 0), np.int32),
            fsched_clause_chain=fsched.clause_chain,
            fsched_tiles=np.stack([
                fsched.tile_stage, fsched.tile_tb, fsched.tile_cb,
                fsched.tile_jb, fsched.tile_first, fsched.tile_last])
            if fsched.n_tiles else np.zeros((6, 0), np.int32),
            fsched_counts=fsched.counts,
        )
        meta = dict(
            schema=ARTIFACT_SCHEMA_VERSION,
            n_features=self.n_features,
            n_classes=self.n_classes,
            stats=self.stats.as_dict(),
            schedule=dict(block_c=sched.block_c,
                          block_j=sched.block_j,
                          n_rows=sched.n_rows,
                          n_lit_bits=sched.n_lit_bits),
            fschedule=dict(block_c=fsched.block_c,
                           block_j=fsched.block_j,
                           block_t=fsched.block_t,
                           term_w=fsched.term_w,
                           n_rows=fsched.n_rows,
                           n_terms=fsched.n_terms,
                           n_lit_bits=fsched.n_lit_bits),
            tuned=self.tuned,
            features=self.extract_features(),
        )
        meta["checksum"] = _artifact_checksum(arrays, meta)
        final = path if path.endswith(".npz") else path + ".npz"
        tmp = f"{final}.tmp-{os.getpid()}"
        try:
            with open(tmp, "wb") as f:
                np.savez_compressed(
                    f,
                    meta=np.frombuffer(json.dumps(meta).encode(), np.uint8),
                    **arrays,
                )
                f.flush()
                os.fsync(f.fileno())
            faults.raise_if("artifact.save_abort")
            os.replace(tmp, final)
        finally:
            if os.path.exists(tmp):       # failed save leaves no debris
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
        faults.corrupt_if("artifact.bitflip", final,
                          default_pos=_payload_offset(final))
        return final

    @staticmethod
    def load(path: str) -> "CompiledTM":
        """Load and VERIFY an artifact; raise :class:`ArtifactError` rather
        than ever returning one that could serve wrong predictions."""
        from repro.kernels import sparse_infer, term_infer

        try:
            z = np.load(path)
            meta = json.loads(bytes(z["meta"]).decode())
            arrays = {k: z[k] for k in z.files if k != "meta"}
        except Exception as e:
            raise ArtifactError(
                f"artifact {path} is unreadable (truncated or not a "
                f"compiled artifact): {type(e).__name__}: {e}") from e
        schema = meta.get("schema", 0)
        if schema != ARTIFACT_SCHEMA_VERSION:
            raise ArtifactError(
                f"artifact {path} has schema version {schema}; this runtime "
                f"requires {ARTIFACT_SCHEMA_VERSION} — recompile the model "
                "(compile_tm + save) instead of serving a stale artifact")
        recorded = meta.pop("checksum", None)
        recomputed = _artifact_checksum(arrays, meta)
        if recorded != recomputed:
            raise ArtifactError(
                f"artifact {path} failed its content checksum (recorded "
                f"{recorded}, recomputed {recomputed}) — the file is corrupt "
                "(bit-rot or a partial write); refusing to serve it")
        st = meta["stats"]
        stats = CompileStats(
            **{k: st[k] for k in (
                "n_clauses_dense", "n_clauses_nonempty", "n_clauses_unique",
                "n_words_dense", "n_words_active", "n_includes", "n_literals",
                "n_partial_terms_dense", "n_partial_terms_unique",
            ) if k in st}
        )
        compiled = CompiledTM(
            include_words=z["include_words"],
            word_ids=z["word_ids"],
            votes=z["votes"],
            n_features=meta["n_features"],
            n_classes=meta["n_classes"],
            stats=stats,
        )
        if "schedule" in meta:   # pre-schedule artifacts rebuild lazily
            sm = meta["schedule"]
            tiles = z["sched_tiles"]
            counts = z["sched_counts"]
            # save() ships the DEFAULT-tiling schedule; memoize it under
            # the default (requested) key — sm["block_c"] is the clipped
            # effective value, which small artifacts would never look up
            compiled._schedules[(sparse_infer.DEFAULT_BLOCK_C,
                                 sparse_infer.DEFAULT_BLOCK_J)] = (
                sparse_infer.SparseSchedule(
                    block_c=sm["block_c"], block_j=sm["block_j"],
                    n_rows=sm["n_rows"], n_lit_bits=sm["n_lit_bits"],
                    chain_ids=z["sched_chain_ids"],
                    tile_cb=tiles[0], tile_jb=tiles[1],
                    tile_first=tiles[2], tile_last=tiles[3],
                    counts=counts,
                    indptr=np.concatenate(
                        [[0], np.cumsum(counts)]).astype(np.int32),
                )
            )
            margin = np.asarray(z["sched_margin"], np.int64)
            if faults.fire_if("anytime.margin_corrupt"):
                # a producer writing wrong margins re-checksums them, so
                # the envelope passes — only validate_artifact's vote-table
                # consistency check stands between this and silently
                # skewed early-exit predictions
                margin = margin.copy()
                if margin.size:
                    margin[0] += 1
                else:
                    margin = np.array([1], np.int64)
            compiled._margins[(sparse_infer.DEFAULT_BLOCK_C,
                               sparse_infer.DEFAULT_BLOCK_J)] = margin
        if "fschedule" in meta:   # pre-factorization artifacts rebuild lazily
            fm = meta["fschedule"]
            ftiles = z["fsched_tiles"]
            fcounts = z["fsched_counts"]
            tt = z["fsched_term_table"]
            compiled._fschedules[(term_infer.DEFAULT_BLOCK_C,
                                  term_infer.DEFAULT_BLOCK_J,
                                  term_infer.DEFAULT_BLOCK_T,
                                  fm["term_w"])] = (
                term_infer.FactorizedSchedule(
                    block_c=fm["block_c"], block_j=fm["block_j"],
                    block_t=fm["block_t"], term_w=fm["term_w"],
                    n_rows=fm["n_rows"], n_terms=fm["n_terms"],
                    n_lit_bits=fm["n_lit_bits"],
                    term_word=tt[0], term_val=tt[1].astype(np.uint32),
                    term_chain=z["fsched_term_chain"],
                    clause_chain=z["fsched_clause_chain"],
                    tile_stage=ftiles[0], tile_tb=ftiles[1],
                    tile_cb=ftiles[2], tile_jb=ftiles[3],
                    tile_first=ftiles[4], tile_last=ftiles[5],
                    counts=fcounts,
                    indptr=np.concatenate(
                        [[0], np.cumsum(fcounts)]).astype(np.int32),
                )
            )
            compiled._fmargins[(term_infer.DEFAULT_BLOCK_C,
                                term_infer.DEFAULT_BLOCK_J,
                                term_infer.DEFAULT_BLOCK_T,
                                fm["term_w"])] = np.asarray(
                z["fsched_margin"], np.int64)
        compiled.tuned.update(meta.get("tuned", {}))
        compiled.features.update(meta.get("features", {}) or {})
        validate_artifact(compiled)
        return compiled


def validate_artifact(compiled: CompiledTM) -> None:
    """Structural invariant checks on an artifact and its shipped schedules.

    A second verification layer behind the checksum: the checksum catches
    bytes that changed after ``save()``, this catches an artifact that was
    *written* wrong (a buggy or adversarial producer) — out-of-range chain
    or term ids would otherwise gather-clamp into silently wrong class
    sums.  Raises :class:`ArtifactError` on the first violation.
    """

    def fail(msg: str):
        raise ArtifactError(f"artifact invariant violated: {msg}")

    inc, votes, wid = compiled.include_words, compiled.votes, compiled.word_ids
    if inc.ndim != 2:
        fail(f"include_words must be 2-D, got shape {inc.shape}")
    U, Wa = inc.shape
    if votes.shape != (U, compiled.n_classes):
        fail(f"votes shape {votes.shape} != ({U}, {compiled.n_classes})")
    if wid.shape != (Wa,):
        fail(f"word_ids shape {wid.shape} != ({Wa},)")
    if Wa and (int(wid[0]) < 0 or (Wa > 1 and np.any(np.diff(wid) <= 0))):
        fail("word_ids must be non-negative and strictly increasing")
    n_dense = compiled.stats.n_words_dense
    if n_dense and Wa and int(wid[-1]) >= n_dense:
        fail(f"word_ids reach {int(wid[-1])} but the dense model has only "
             f"{n_dense} words — gathers would clamp")

    def check_tiles(tag, counts, indptr, n_tiles, tile_cb):
        if indptr.shape[0] != counts.shape[0] + 1 or (indptr.size and indptr[0] != 0):
            fail(f"{tag}: indptr shape/origin inconsistent with counts")
        if np.any(counts < 0) or np.any(np.diff(indptr) != counts):
            fail(f"{tag}: tile indptr is not the monotone prefix sum of counts")
        if int(counts.sum()) > n_tiles:
            fail(f"{tag}: counts claim {int(counts.sum())} tiles but the "
                 f"tile table has {n_tiles}")
        if n_tiles and (np.any(tile_cb < 0) or np.any(tile_cb >= counts.shape[0])):
            fail(f"{tag}: tile clause-block ids out of range")

    for s in compiled._schedules.values():
        if s.n_rows != U:
            fail(f"chain schedule covers {s.n_rows} rows, artifact has {U}")
        if s.n_lit_bits != 32 * Wa:
            fail(f"chain schedule n_lit_bits {s.n_lit_bits} != 32*{Wa}")
        if np.any(s.chain_ids < 0) or np.any(s.chain_ids > s.n_lit_bits):
            fail("chain ids out of range (sentinel is the maximum legal id)")
        if s.chain_ids.shape[0] > s.n_rows and not np.all(
                s.chain_ids[s.n_rows:] == s.n_lit_bits):
            fail("padded chain rows past n_rows must be all-sentinel")
        check_tiles("chain schedule", s.counts, s.indptr, s.n_tiles, s.tile_cb)

    for fs in compiled._fschedules.values():
        if fs.n_rows != U:
            fail(f"factorized schedule covers {fs.n_rows} rows, artifact has {U}")
        if fs.n_lit_bits != 32 * Wa:
            fail(f"factorized schedule n_lit_bits {fs.n_lit_bits} != 32*{Wa}")
        if np.any(fs.term_chain < 0) or np.any(fs.term_chain > fs.n_lit_bits):
            fail("term-chain literal ids out of range")
        if np.any(fs.clause_chain < 0) or np.any(fs.clause_chain > fs.n_terms):
            fail("clause-chain term ids out of range (sentinel == n_terms)")
        if fs.clause_chain.shape[0] > fs.n_rows and not np.all(
                fs.clause_chain[fs.n_rows:] == fs.n_terms):
            fail("padded clause-chain rows past n_rows must be all-sentinel")
        if fs.term_chain.shape[0] > fs.n_terms and not np.all(
                fs.term_chain[fs.n_terms:] == fs.n_lit_bits):
            fail("padded term rows past n_terms must be all-sentinel")
        if fs.term_word.shape[0] != fs.n_terms or fs.term_val.shape[0] != fs.n_terms:
            fail("term table length != n_terms")
        if fs.n_terms and (np.any(fs.term_word < 0) or np.any(fs.term_word >= Wa)):
            fail("term active-word indices out of range")
        if np.any((fs.tile_stage != 0) & (fs.tile_stage != 1)):
            fail("tile_stage entries must be 0 (term) or 1 (clause)")
        n_ctiles = int((fs.tile_stage == 1).sum())
        check_tiles("factorized schedule", fs.counts, fs.indptr, n_ctiles,
                    fs.tile_cb[fs.tile_stage == 1] if fs.n_tiles else fs.tile_cb)

    # anytime margin metadata: monotone non-increasing AND exactly the
    # residual swing the vote table implies — corrupt margins would make
    # early-exit certify too eagerly (wrong argmax) or budgeted mode
    # under-report its error bound
    def check_margins(tag, margins, sched, recompute):
        margins = np.asarray(margins)
        if margins.shape != (sched.n_tiles,):
            fail(f"{tag}: margin table shape {margins.shape} != "
                 f"({sched.n_tiles},)")
        if margins.size == 0:
            return
        if np.any(margins < 0):
            fail(f"{tag}: margin table has negative entries")
        if np.any(np.diff(margins) > 0):
            fail(f"{tag}: margin table is not monotone non-increasing")
        expect = recompute(sched, compiled.votes)
        if not np.array_equal(margins, expect):
            fail(f"{tag}: margin table is inconsistent with the vote table "
                 "(residual swing mismatch)")

    from repro.kernels import anytime

    for key, m in compiled._margins.items():
        s = compiled._schedules.get(key)
        if s is None:
            fail(f"chain margin table for unknown tiling {key}")
        check_margins("chain margins", m, s, anytime.sparse_tile_margins)
    for key, m in compiled._fmargins.items():
        fs = compiled._fschedules.get(key)
        if fs is None:
            fail(f"factorized margin table for unknown tiling {key}")
        check_margins("factorized margins", m, fs,
                      anytime.factorized_tile_margins)


def compile_tm(
    config: tm.TMConfig,
    ta_state,
    *,
    dedup: bool = True,
    prune_words: bool = True,
    cluster: bool = True,
) -> CompiledTM:
    """Compile a trained automata bank into a :class:`CompiledTM`.

    ``cluster`` reorders the surviving unique clauses by (chain length,
    active-word signature) — the row order the block-sparse schedule wants;
    votes move with their rows, so class sums are invariant.
    ``dedup=False, prune_words=False, cluster=False`` is the
    DON'T-TOUCH-pragma analog used by benchmarks/logic_sharing.py to
    measure the savings (paper Fig. 8).
    """
    ta = np.asarray(ta_state)
    C_raw = config.n_clauses_raw
    inc = (ta[:C_raw] >= 0).astype(np.uint8)               # (C, L)
    pol = np.where(np.arange(C_raw) % 2 == 0, 1, -1).astype(np.int32)
    cls = np.arange(C_raw) // config.clauses_per_class

    nonempty = inc.any(axis=1)
    inc_ne = inc[nonempty]
    pol_ne = pol[nonempty]
    cls_ne = cls[nonempty]
    n_nonempty = int(inc_ne.shape[0])

    words_dense = packetizer.pack_bits_np(inc_ne) if n_nonempty else np.zeros(
        (0, packetizer.n_words(config.n_literals)), np.uint32
    )
    W = packetizer.n_words(config.n_literals)

    if dedup and n_nonempty:
        uniq, inv = np.unique(words_dense, axis=0, return_inverse=True)
    else:
        uniq, inv = words_dense, np.arange(n_nonempty)
    U = uniq.shape[0]

    votes = np.zeros((max(U, 1), config.n_classes), np.int32)
    if n_nonempty:
        np.add.at(votes, (inv, cls_ne), pol_ne)
    if U == 0:
        uniq = np.zeros((1, W), np.uint32)  # degenerate all-empty model
        U = 1

    if prune_words:
        active = uniq.any(axis=0)
        if not active.any():
            active[:1] = True
        word_ids = np.nonzero(active)[0].astype(np.int32)
    else:
        word_ids = np.arange(uniq.shape[1], dtype=np.int32)
    uniq = uniq[:, word_ids]

    votes = votes[:U]
    if cluster and U > 1:
        from repro.kernels import anytime, sparse_infer

        # vote-mass bands (|polarity x multiplicity| descending) so the
        # anytime margin decays steeply, density-clustered within bands so
        # tile counts stay near the pure-clustered layout
        order = anytime.margin_order(uniq, votes,
                                     cluster_fn=sparse_infer.cluster_order)
        uniq = uniq[order]
        votes = votes[order]

    # partial-clause sharing opportunity: unique nonzero include words per
    # word column (zero words are free — they never gate anything)
    nonzero_terms = int((uniq != 0).sum())
    unique_terms = sum(
        len(np.unique(col[col != 0])) for col in uniq.T
    )
    stats = CompileStats(
        n_clauses_dense=C_raw,
        n_clauses_nonempty=n_nonempty,
        n_clauses_unique=int(U),
        n_words_dense=int(W),
        n_words_active=int(word_ids.shape[0]),
        n_includes=int(inc.sum()),
        n_literals=config.n_literals,
        n_partial_terms_dense=nonzero_terms,
        n_partial_terms_unique=int(unique_terms),
    )
    return CompiledTM(
        include_words=uniq.astype(np.uint32),
        word_ids=word_ids,
        votes=votes,
        n_features=config.n_features,
        n_classes=config.n_classes,
        stats=stats,
    )


def incremental_recompile(
    config: tm.TMConfig,
    ta_state,
    prev: CompiledTM,
    *,
    dedup: bool = True,
    prune_words: bool = True,
    cluster: bool = True,
) -> tuple[CompiledTM, dict]:
    """Recompile a drifted bank, reusing ``prev``'s schedule work where the
    layout survived.

    The host compile pipeline itself (:func:`compile_tm`) is cheap numpy;
    the expensive artifact state is the chain SCHEDULE (a per-clause python
    compaction loop) and the autotuned tilings.  When the new artifact
    lands on the same word layout and row count as ``prev`` — the common
    case for small online drift — the default-tiling chain schedule is
    rebuilt incrementally (``sparse_infer.build_schedule_incremental``:
    only clauses whose include rows moved are re-compacted) and ``prev``'s
    tuned tilings carry over.  Any layout change falls back to the full
    lazy rebuild.

    Returns ``(compiled, info)``; ``info["mode"]`` is ``"incremental"`` or
    ``"full"``, with ``rows_reused``/``tiles_reused`` counters in the
    incremental case.  Either way the result is bit-identical to a
    from-scratch ``compile_tm`` (the incremental schedule is exact, and
    the factorized schedule stays lazy).
    """
    from repro.kernels import sparse_infer

    new = compile_tm(config, ta_state, dedup=dedup,
                     prune_words=prune_words, cluster=cluster)
    info: dict = dict(mode="full", rows_reused=0, tiles_reused=0)
    key = (sparse_infer.DEFAULT_BLOCK_C, sparse_infer.DEFAULT_BLOCK_J)
    prev_sched = prev._schedules.get(key)
    if (prev_sched is not None
            and new.include_words.shape == prev.include_words.shape
            and np.array_equal(new.word_ids, prev.word_ids)):
        sched, re_info = sparse_infer.build_schedule_incremental(
            new.include_words, prev_sched, prev.include_words,
            block_c=key[0], block_j=key[1])
        new._schedules[key] = sched
        info = dict(mode="incremental", **re_info)
        # same shape family: prev's swept/predicted tilings remain valid
        # keys (kernel:bucket[:rows][:mode]) for the successor artifact
        new.tuned.update({k: dict(v) for k, v in prev.tuned.items()})
    return new, info


_UNSET = object()   # sentinel distinguishing "not passed" from None/False


def run_compiled(
    compiled: CompiledTM,
    x_packed: jnp.ndarray,
    *,
    engine=None,
    interpret: bool | None = None,
    quality: int = 0,
    early_exit: bool = False,
    use_kernel=_UNSET,
    fuse=_UNSET,
    sparse=_UNSET,
    factorize=_UNSET,
    **blocks,
) -> jnp.ndarray:
    """Inference with the compiled artifact: (B, W_dense) packed literals ->
    (B, n_classes) int32 class sums.

    The engine is selected by ``engine=`` — an ``ops.EngineSpec`` or one
    of the :class:`ops.EngineLadder` level names ``"auto"`` (default) /
    ``"factorized"`` / ``"sparse"`` / ``"dense"`` / ``"oracle"``.
    ``"auto"`` defers to ``kernels/ops`` ambient resolution
    (``REPRO_USE_PALLAS``; ``interpret=None`` compiles on TPU and
    interprets elsewhere) and, on the kernel path, picks the two-level
    FACTORIZED schedule kernel (``kernels/term_infer.py``: each unique
    AND term evaluated once per sample slab) when the artifact's
    ``partial_term_sharing`` clears ``FACTORIZE_SHARING_THRESHOLD``, else
    the flat block-sparse chain kernel (``kernels/sparse_infer.py``); the
    named engines pin the choice.  All engines are bit-identical.
    Empty-clause masking is unnecessary here — compilation already
    dropped empty clauses (the degenerate all-empty artifact keeps one
    all-zero clause whose votes are zero).

    The pre-``EngineSpec`` booleans (``use_kernel=``, ``fuse=``,
    ``sparse=``, ``factorize=``) still work as deprecation shims emitting
    ``DeprecationWarning``; they cannot be combined with ``engine=``.

    Schedule-path tiling comes from ``blocks`` keys ``block_c``/``block_j``
    (chain tiling, memoized on the artifact), ``block_s`` (sample slab),
    and — factorized only — ``block_t``/``term_w`` (term-table tiling);
    the dense paths keep their ``block_b``/``block_c``/``block_w``.
    Under ``engine="auto"``, a caller that pins dense-only keys
    (``block_b``/``block_w``) keeps the dense fused kernel — a dense-tuned
    configuration must not be silently reinterpreted as a schedule tiling.

    Anytime inference (``kernels/anytime.py``): ``quality > 0`` serves a
    budgeted tile prefix (error bounded by the artifact's margin
    metadata — ``compiled.quality_levels()``), ``early_exit=True`` runs
    the exact early-exit kernel mode (argmax-identical to the full walk).
    Both apply only on the schedule-kernel paths; the dense and oracle
    engines always serve exact sums (a stronger answer than requested, so
    ladder degradation stays safe).
    """
    import warnings

    from repro.kernels import ops

    known = {"block_b", "block_c", "block_w", "block_j", "block_s",
             "block_t", "term_w"}
    unknown = blocks.keys() - known
    if unknown:
        # the per-path whitelists below would silently drop a typo like
        # block_ww=8, serving at default tilings while the caller believes
        # their tuning applied
        raise TypeError(f"run_compiled: unknown block kwargs {sorted(unknown)}; "
                        f"expected a subset of {sorted(known)}")

    legacy = {name: v for name, v in (
        ("use_kernel", use_kernel), ("fuse", fuse),
        ("sparse", sparse), ("factorize", factorize)) if v is not _UNSET}
    if legacy:
        if engine is not None:
            raise TypeError(
                f"run_compiled: engine= cannot be combined with the "
                f"deprecated kwargs {sorted(legacy)}")
        warnings.warn(
            f"run_compiled kwargs {sorted(legacy)} are deprecated; pass "
            f"engine=EngineSpec(...) or one of {ops.ENGINE_NAMES} instead",
            DeprecationWarning, stacklevel=2)
        use_kernel = legacy.get("use_kernel")
        fuse = legacy.get("fuse", True)
        sparse = legacy.get("sparse")
        factorize = legacy.get("factorize")
        uk, it = ops.kernel_dispatch(use_kernel, interpret)
    else:
        spec = ops.EngineSpec.coerce(engine)
        use_kernel, interpret, fuse, sparse, factorize = (
            spec.resolve(interpret))
        if spec.name == "auto":
            uk, it = ops.kernel_dispatch(use_kernel, interpret)
        else:
            # named engines already resolved use_kernel; only interpret
            # still follows the ambient backend default
            uk, it = use_kernel, ops.kernel_dispatch(None, interpret)[1]

    xw = x_packed[:, jnp.asarray(compiled.word_ids)]        # dead-word elim
    votes = jnp.asarray(compiled.votes)
    if sparse is None:
        # the chain schedules ride the fused default, unless the caller
        # passed a dense-kernel tiling
        sparse = fuse and not ({"block_b", "block_w"} & blocks.keys())
    fact_keys = {"block_t", "term_w"} & blocks.keys()
    if factorize is None:
        # heuristic default: factorized execution pays when enough terms
        # are shared for stage 1 to amortize (the compiler measured it);
        # a factorized-only tiling key pins the factorized kernel the same
        # way a dense-only key pins the dense one — a tuned configuration
        # must not be silently reinterpreted
        factorize = sparse and (
            bool(fact_keys)
            or compiled.stats.partial_term_sharing
            >= FACTORIZE_SHARING_THRESHOLD
        )
    elif not factorize and fact_keys:
        raise TypeError(
            f"run_compiled: factorize=False but factorized-only block "
            f"kwargs {sorted(fact_keys)} were passed — they would be "
            "silently dropped")
    if factorize and not (fuse and sparse):
        # the docstring promises factorize=True pins the factorized
        # engine; serving the dense kernel instead must fail loudly
        raise TypeError(
            "run_compiled: factorize=True requires the schedule path "
            "(fuse=True and sparse not pinned off via sparse=False or a "
            "dense-kernel tiling)")
    if uk and fuse and sparse and factorize:
        ftiling = dict(block_c=blocks.get("block_c"),
                       block_j=blocks.get("block_j"),
                       block_t=blocks.get("block_t"),
                       term_w=blocks.get("term_w"))
        if quality > 0:
            fsched = compiled.quality_prefix_schedule(
                quality, "factorized", **ftiling)
        else:
            fsched = compiled.factorized_schedule(**ftiling)
        margin = None
        if early_exit and quality <= 0 and fsched.n_tiles:
            margin = jnp.asarray(
                compiled.factorized_tile_margins(**ftiling), jnp.int32)
        return ops.tm_forward_factorized(
            xw, compiled.include_words, votes, fsched,
            use_kernel=True, interpret=it,
            block_s=blocks.get("block_s"), tile_margin=margin,
        )
    if uk and fuse and sparse:
        stiling = dict(block_c=blocks.get("block_c"),
                       block_j=blocks.get("block_j"))
        if quality > 0:
            sched = compiled.quality_prefix_schedule(
                quality, "sparse", **stiling)
        else:
            sched = compiled.schedule(**stiling)
        margin = None
        if early_exit and quality <= 0 and sched.n_tiles:
            margin = jnp.asarray(compiled.tile_margins(**stiling), jnp.int32)
        return ops.tm_forward_schedule(
            xw, compiled.include_words, votes, sched,
            use_kernel=True, interpret=it,
            block_s=blocks.get("block_s"), tile_margin=margin,
        )
    inc = jnp.asarray(compiled.include_words)
    dense_blocks = {k: v for k, v in blocks.items()
                    if k in ("block_b", "block_c", "block_w")}
    return ops.tm_forward_packed(
        xw, inc, votes, None,
        use_kernel=uk, interpret=it, fuse=fuse, **dense_blocks,
    )


def predict_compiled(compiled: CompiledTM, x: jnp.ndarray, **kw) -> jnp.ndarray:
    """(B, F) raw boolean features -> predicted class ids."""
    xp = packetizer.pack_literals(x)
    return jnp.argmax(run_compiled(compiled, xp, **kw), axis=-1)


# Re-exported so engine selection and artifact execution come from one
# module (serve and the tests spell ``compiler.EngineSpec``).  Lazy (PEP
# 562) rather than a plain import: ``kernels/ops`` pulls the whole kernel
# stack in, and the kernel modules import ``repro.core`` — an eager import
# here is circular whenever a kernel module is the first thing imported.
def __getattr__(name):
    if name in ("EngineSpec", "ENGINE_NAMES"):
        from repro.kernels import ops
        return getattr(ops, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
