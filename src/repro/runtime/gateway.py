"""Resilient async serving gateway: continuous batching + admission control.

The synchronous serve loop (pre-PR-7 ``launch/serve.py``) executed a fixed
request array bucket by bucket — fine for a benchmark, useless under live
traffic where requests arrive one at a time, carry deadlines, and belong
to different tenants/models.  This gateway is the traffic-facing layer:

* **Continuous batching** — requests are admitted into a partially-filled
  per-tenant bucket (one jit trace per tenant: the executed batch is
  always padded to the fixed ``bucket`` size, so a partial flush never
  retraces).  A bucket flushes when it fills, when its OLDEST request has
  waited ``max_wait`` seconds (age-based flush — tail latency is bounded
  even at low arrival rates), or at drain.

* **Admission control / load shedding** — the pending-request queue is
  bounded by ``max_queue``: when it is full the request is REJECTED at
  admission with the typed reason ``queue_full`` instead of growing an
  unbounded backlog.  Per-request deadlines are enforced at dequeue: an
  expired request is rejected ``deadline_expired``, never executed and
  never silently dropped.  Every offered request resolves to exactly one
  :class:`Response` — answered or shed with a typed reason — and
  :meth:`Gateway.health` proves it (``unaccounted`` must be 0).

* **Typed bucket rejection** — the runner (engine ladder / artifact zoo)
  signals per-bucket failure by raising; an exception carrying a
  ``shed_reason`` attribute (e.g. ``zoo.TenantQuarantined``) rejects the
  bucket's requests with that reason, anything else with
  ``engine_failed``.  One tenant's poisoned artifact therefore sheds THAT
  tenant's requests while other tenants keep flushing.

* **Shadow mirror** — an optional ``mirror(tenant, rows, preds)`` tap
  observes each successfully-answered bucket on the worker thread (the
  online updater's shadow-canary: replay the bucket against a candidate
  artifact and compare).  The tap is best-effort by construction: its
  exceptions are swallowed and counted (``mirror_failures``), and it can
  never shed or alter an answer.

* **Graceful drain** — :meth:`drain` (wired to SIGTERM by the server)
  stops admission (``shutting_down``), flushes the remaining partial
  buckets under ``drain_timeout`` seconds, and rejects whatever is still
  queued when the timer expires with ``drain_timeout``.  The final
  ``GATEWAY_HEALTH`` dict accounts for 100% of offered requests.

* **Brownout serving** — under overload the gateway can degrade ANSWER
  QUALITY instead of shedding: a :class:`BrownoutController` maps load
  pressure (queue depth, bucket age, deadline pressure) to an anytime
  quality level (0 = exact, 1..max = budgeted prefix inference with a
  concrete vote-margin error bound — see ``kernels/anytime.py``).  A
  quality-aware runner (one taking a ``quality`` keyword) receives the
  level per bucket and may return ``(preds, info)`` where ``info``
  carries the quality actually served and its ``err_bound``.  Degraded
  answers are still ANSWERS: the accounting invariant refines to
  ``offered == answered_exact + answered_degraded + shed_total`` and
  :meth:`Gateway.health` reports the quality-tier distribution.
  Escalation is immediate (one evaluation above an enter threshold);
  recovery steps down one level per evaluation with hysteresis
  (``exit[k] < enter[k]``), and a fault-independent low-pressure
  watchdog forces exact serving if the primary step-down path wedges.

Fault sites (``runtime/faults.py``): ``gateway.queue_overflow`` forces an
admission-time shed; ``gateway.drain_timeout`` forces the drain timer to
expire immediately; ``gateway.brownout_stuck`` pins the controller's
primary step-down path so the watchdog recovery is drilled.  All are
drilled in ``tests/test_gateway.py`` and under live Poisson load in
``benchmarks/serve_gateway.py --chaos``.

Execution is serialized through a single worker thread: the engines are
jit'd callables whose per-bucket wall-time is the unit of straggler/
deadline attribution, and the event loop stays free to admit, age-flush,
and shed while a bucket is on the accelerator.
"""

from __future__ import annotations

import asyncio
import collections
import dataclasses
import inspect
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.runtime import faults

# Typed shed reasons: the closed vocabulary of ways the gateway refuses
# work.  Every non-answer carries exactly one of these — "silently
# dropped" is not in the list by construction.
QUEUE_FULL = "queue_full"            # admission: bounded queue at capacity
SHUTTING_DOWN = "shutting_down"      # admission: drain already started
DEADLINE_EXPIRED = "deadline_expired"  # dequeue: request deadline passed
DRAIN_TIMEOUT = "drain_timeout"      # drain: still queued when timer expired
ENGINE_FAILED = "engine_failed"      # execution: runner raised (untyped)
# The built-in vocabulary; runner exceptions extend it via a
# ``shed_reason`` attribute (zoo: tenant_quarantined, load_failed), so
# shed counters are an OPEN dict keyed by whatever reasons actually fired.
SHED_REASONS = (QUEUE_FULL, SHUTTING_DOWN, DEADLINE_EXPIRED, DRAIN_TIMEOUT,
                ENGINE_FAILED)


@dataclasses.dataclass
class Response:
    """Terminal outcome of one request: answered or typed-shed.

    ``quality`` is the anytime level the answer was served at (0 = exact
    full-schedule inference); a degraded answer (``quality > 0``) carries
    the concrete vote-margin ``err_bound`` it was computed under.
    """
    tenant: str
    ok: bool
    pred: Optional[int] = None
    reason: Optional[str] = None
    latency_s: float = 0.0
    quality: int = 0
    err_bound: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class BrownoutConfig:
    """Hysteresis thresholds for the brownout controller.

    ``enter[k-1]`` is the pressure at which level ``k`` is entered;
    ``exit[k-1]`` the pressure below which level ``k`` steps down one
    level.  ``exit[k] < enter[k]`` gives the hysteresis band that stops
    the controller from flapping around a threshold.  ``watchdog_evals``
    consecutive evaluations below ``exit[0]`` force level 0 through a
    path that does NOT consult the primary step-down logic — the
    recovery drilled by the ``gateway.brownout_stuck`` fault site.
    """
    max_level: int = 3
    enter: tuple = (0.5, 0.7, 0.85)
    exit: tuple = (0.3, 0.5, 0.65)
    watchdog_evals: int = 8


class BrownoutController:
    """Maps load pressure to an anytime quality level with hysteresis.

    Pressure is the worst of three normalized signals — queue occupancy,
    oldest-bucket age (relative to 4x the age-flush window), and the
    flushed bucket's deadline pressure (fraction of its tightest
    deadline already elapsed) — clipped to [0, 1].  Escalation is
    immediate: one evaluation at/above ``enter[k-1]`` jumps straight to
    level ``k``.  Recovery is deliberate: one level per evaluation once
    pressure drops below the current level's exit threshold.
    """

    def __init__(self, config: Optional[BrownoutConfig] = None):
        self.cfg = config or BrownoutConfig()
        self.level = 0
        self.escalations = 0
        self.stepdowns = 0
        self.watchdog_resets = 0
        self.evals = 0
        self._calm = 0    # consecutive evaluations below exit[0]

    @staticmethod
    def pressure(*, pending: int, max_queue: Optional[int],
                 oldest_age: float, max_wait: float,
                 deadline_frac: float = 0.0) -> float:
        terms = [float(deadline_frac)]
        if max_queue:
            terms.append(pending / max_queue)
        if max_wait > 0:
            terms.append(oldest_age / (4.0 * max_wait))
        return min(max(max(terms), 0.0), 1.0)

    def update(self, pressure: float) -> int:
        """Fold one pressure sample; returns the quality level to serve."""
        cfg = self.cfg
        self.evals += 1
        self._calm = self._calm + 1 if pressure < cfg.exit[0] else 0
        target = 0
        for k in range(cfg.max_level, 0, -1):
            if pressure >= cfg.enter[k - 1]:
                target = k
                break
        if target > self.level:
            self.level = target
            self.escalations += 1
            return self.level
        if self.level > 0 and self._calm >= cfg.watchdog_evals:
            # fault-independent recovery: sustained calm forces exact
            # serving even when the primary step-down path is wedged
            self.level = 0
            self.watchdog_resets += 1
            self._calm = 0
            return self.level
        if (self.level > 0 and pressure < cfg.exit[self.level - 1]
                and not faults.fire_if("gateway.brownout_stuck")):
            self.level -= 1
            self.stepdowns += 1
        return self.level

    def health(self) -> dict:
        return dict(level=self.level, evals=self.evals,
                    escalations=self.escalations, stepdowns=self.stepdowns,
                    watchdog_resets=self.watchdog_resets)


@dataclasses.dataclass
class _Request:
    tenant: str
    x: np.ndarray
    t_submit: float
    deadline: Optional[float]            # absolute clock() time, or None
    future: "asyncio.Future[Response]"


class Gateway:
    """Async request gateway over a per-tenant bucket runner.

    ``runner(tenant, rows)`` executes one bucket: ``rows`` is a non-empty
    list of request payloads (each an ``(W,)`` array) and the return value
    is the ``(len(rows),)`` prediction array.  The runner owns padding to
    its jit trace shape, engine-ladder demotion, and straggler accounting;
    it raises to reject the whole bucket (typed via a ``shed_reason``
    attribute on the exception, else ``engine_failed``).

    A quality-aware runner additionally accepts a ``quality`` keyword
    (the brownout controller's level for this bucket) and may return
    ``(preds, info)`` where ``info`` is a dict with the quality actually
    served (``quality``) and its vote-margin ``err_bound``.  A plain
    runner under brownout keeps serving exact — degradation is opt-in.
    """

    def __init__(self, runner: Callable, *, bucket: int = 128,
                 max_queue: Optional[int] = None, max_wait: float = 0.02,
                 drain_timeout: float = 5.0, clock=time.monotonic,
                 mirror: Optional[Callable] = None,
                 brownout: Optional[BrownoutController] = None):
        self._runner = runner
        self._brownout = brownout
        try:
            self._runner_quality = "quality" in inspect.signature(
                runner).parameters
        except (TypeError, ValueError):   # builtins / C callables
            self._runner_quality = False
        # shadow-canary tap: ``mirror(tenant, rows, preds)`` observes a
        # successfully-answered bucket (worker thread, AFTER the serving
        # predictions are computed).  It must never affect the answer: any
        # exception is swallowed and counted, never shed
        self._mirror = mirror
        self.mirrored = 0
        self.mirror_failures = 0
        self.bucket = int(bucket)
        self.max_queue = max_queue if max_queue and max_queue > 0 else None
        self.max_wait = float(max_wait)
        self.drain_timeout = float(drain_timeout)
        self._clock = clock
        self._queues: Dict[str, collections.deque] = {}
        self._pending = 0
        self._inflight = 0
        self._draining = False
        self._task: Optional[asyncio.Task] = None
        self._wake: Optional[asyncio.Event] = None
        self._idle: Optional[asyncio.Event] = None
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="gw-exec")
        # -- accounting: offered == answered_exact + answered_degraded
        #    + sum(shed.values()) always (answered = exact + degraded) --
        self.offered = 0
        self.admitted = 0
        self.answered = 0
        self.answered_exact = 0
        self.answered_degraded = 0
        self.quality_tiers: Dict[int, int] = {}
        self.shed: Dict[str, int] = {}
        self.buckets = 0
        self.flushes = {"full": 0, "age": 0, "drain": 0}
        self.tenants: Dict[str, dict] = {}
        self._latencies: List[float] = []

    # -- admission -----------------------------------------------------------

    def _tenant_row(self, tenant: str) -> dict:
        row = self.tenants.get(tenant)
        if row is None:
            row = self.tenants[tenant] = dict(offered=0, answered=0, shed={})
        return row

    def _resolve(self, req: _Request, resp: Response) -> None:
        if req.future.done():        # already rejected (e.g. drain sweep)
            return
        row = self._tenant_row(req.tenant)
        if resp.ok:
            self.answered += 1
            row["answered"] += 1
            q = int(resp.quality)
            self.quality_tiers[q] = self.quality_tiers.get(q, 0) + 1
            if q == 0:
                self.answered_exact += 1
            else:
                self.answered_degraded += 1
            self._latencies.append(resp.latency_s)
        else:
            self.shed[resp.reason] = self.shed.get(resp.reason, 0) + 1
            row["shed"][resp.reason] = row["shed"].get(resp.reason, 0) + 1
        req.future.set_result(resp)

    def _shed_at_admission(self, tenant: str, reason: str,
                           fut: "asyncio.Future[Response]") -> None:
        self.shed[reason] = self.shed.get(reason, 0) + 1
        row = self._tenant_row(tenant)["shed"]
        row[reason] = row.get(reason, 0) + 1
        fut.set_result(Response(tenant=tenant, ok=False, reason=reason))

    def offer(self, tenant: str, x, deadline: Optional[float] = None
              ) -> "asyncio.Future[Response]":
        """Admit (or typed-shed) one request; returns a Future[Response].

        Must be called on the event-loop thread.  ``deadline`` is seconds
        from now; a request still queued when it expires is rejected
        ``deadline_expired`` at dequeue time.
        """
        loop = asyncio.get_running_loop()
        fut: asyncio.Future = loop.create_future()
        now = self._clock()
        self.offered += 1
        self._tenant_row(tenant)["offered"] += 1
        if self._draining:
            self._shed_at_admission(tenant, SHUTTING_DOWN, fut)
            return fut
        over = self.max_queue is not None and self._pending >= self.max_queue
        if over or faults.fire_if("gateway.queue_overflow"):
            self._shed_at_admission(tenant, QUEUE_FULL, fut)
            return fut
        self.admitted += 1
        req = _Request(tenant=tenant, x=x, t_submit=now,
                       deadline=None if deadline is None else now + deadline,
                       future=fut)
        self._queues.setdefault(tenant, collections.deque()).append(req)
        self._pending += 1
        if self._idle is not None:
            self._idle.clear()
        if self._wake is not None:
            self._wake.set()
        return fut

    async def submit(self, tenant: str, x,
                     deadline: Optional[float] = None) -> Response:
        return await self.offer(tenant, x, deadline)

    # -- dispatch ------------------------------------------------------------

    async def start(self) -> "Gateway":
        self._wake = asyncio.Event()
        self._idle = asyncio.Event()
        self._idle.set()
        self._task = asyncio.create_task(self._dispatch_loop())
        return self

    def _expire(self, now: float) -> None:
        """Shed queued requests whose deadline has already passed."""
        for q in self._queues.values():
            kept = [r for r in q if not (r.deadline is not None
                                         and r.deadline < now)]
            if len(kept) != len(q):
                for r in q:
                    if r.deadline is not None and r.deadline < now:
                        self._pending -= 1
                        self._resolve(r, Response(
                            tenant=r.tenant, ok=False,
                            reason=DEADLINE_EXPIRED,
                            latency_s=now - r.t_submit))
                q.clear()
                q.extend(kept)

    def _pick_flush(self, now: float):
        """(tenant, cause) to flush now, or (None, earliest-age-due)."""
        due: Optional[float] = None
        for tenant, q in self._queues.items():
            if not q:
                continue
            if len(q) >= self.bucket:
                return tenant, "full"
            if self._draining:
                return tenant, "drain"
            age_due = q[0].t_submit + self.max_wait
            if age_due <= now:
                return tenant, "age"
            due = age_due if due is None else min(due, age_due)
        return None, due

    def set_mirror(self, mirror: Optional[Callable]) -> None:
        """Install/remove the shadow tap (safe while serving: the tap is
        read once per bucket on the worker thread)."""
        self._mirror = mirror

    def _run_bucket(self, tenant: str, rows, quality: int = 0):
        """Worker-thread bucket execution + best-effort shadow mirror.

        Returns ``(preds, info)`` where ``info`` records the quality the
        bucket was actually served at (a runner may serve BETTER than
        requested — e.g. a dense fallback is always exact) and, for
        degraded service, the concrete error bound.
        """
        if self._runner_quality:
            out = self._runner(tenant, rows, quality=quality)
        else:
            out = self._runner(tenant, rows)
        if (isinstance(out, tuple) and len(out) == 2
                and isinstance(out[1], dict)):
            preds, info = out
        else:
            preds, info = out, {}
        info = dict(quality=int(info.get("quality", 0)),
                    err_bound=info.get("err_bound"))
        mirror = self._mirror
        if mirror is not None:
            try:
                mirror(tenant, rows, preds)
                self.mirrored += 1
            except Exception:  # noqa: BLE001 — the tap must never shed
                self.mirror_failures += 1
        return preds, info

    async def _dispatch_loop(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            now = self._clock()
            self._expire(now)
            tenant, cause = self._pick_flush(now)
            if tenant is None:
                if self._pending == 0 and self._inflight == 0:
                    self._idle.set()
                self._wake.clear()
                timeout = None if cause is None else max(cause - now, 0.0)
                try:
                    await asyncio.wait_for(self._wake.wait(), timeout)
                except asyncio.TimeoutError:
                    pass
                continue
            q = self._queues[tenant]
            reqs = [q.popleft() for _ in range(min(self.bucket, len(q)))]
            self._pending -= len(reqs)
            self._inflight += len(reqs)
            self.flushes[cause] += 1
            self.buckets += 1
            quality = self._brownout_level(reqs, now)
            try:
                preds, info = await loop.run_in_executor(
                    self._pool, self._run_bucket, tenant,
                    [r.x for r in reqs], quality)
            except Exception as e:  # noqa: BLE001 — typed bucket rejection
                reason = getattr(e, "shed_reason", ENGINE_FAILED)
                end = self._clock()
                for r in reqs:
                    self._resolve(r, Response(
                        tenant=tenant, ok=False, reason=reason,
                        latency_s=end - r.t_submit))
            else:
                preds = np.asarray(preds)
                end = self._clock()
                served_q = info["quality"]
                bound = info["err_bound"] if served_q else None
                for i, r in enumerate(reqs):
                    self._resolve(r, Response(
                        tenant=tenant, ok=True, pred=int(preds[i]),
                        latency_s=end - r.t_submit,
                        quality=served_q, err_bound=bound))
            finally:
                self._inflight -= len(reqs)

    def _brownout_level(self, reqs, now: float) -> int:
        """Quality level for the bucket about to run (0 when disabled)."""
        if self._brownout is None:
            return 0
        frac = 0.0
        for r in reqs:
            if r.deadline is not None and r.deadline > r.t_submit:
                frac = max(frac, (now - r.t_submit)
                           / (r.deadline - r.t_submit))
        oldest = 0.0
        for q in self._queues.values():
            if q:
                oldest = max(oldest, now - q[0].t_submit)
        p = BrownoutController.pressure(
            pending=self._pending, max_queue=self.max_queue,
            oldest_age=oldest, max_wait=self.max_wait, deadline_frac=frac)
        return self._brownout.update(p)

    # -- drain / shutdown ----------------------------------------------------

    async def drain(self, timeout: Optional[float] = None) -> dict:
        """Stop admitting, flush what fits in the window, shed the rest.

        Returns the final health dict.  Idempotent enough for the common
        SIGTERM-then-natural-completion race: a second call finds empty
        queues and returns immediately.
        """
        self._draining = True
        if self._wake is not None:
            self._wake.set()
        timeout = self.drain_timeout if timeout is None else timeout
        if faults.fire_if("gateway.drain_timeout"):
            timeout = 0.0
        if self._idle is not None:
            try:
                await asyncio.wait_for(self._idle.wait(), timeout)
            except asyncio.TimeoutError:
                now = self._clock()
                for q in self._queues.values():
                    while q:
                        r = q.popleft()
                        self._pending -= 1
                        self._resolve(r, Response(
                            tenant=r.tenant, ok=False, reason=DRAIN_TIMEOUT,
                            latency_s=now - r.t_submit))
                # an in-flight bucket still completes (its futures resolve
                # normally); wait for it so shutdown never abandons work
                await self._idle.wait()
        if self._task is not None:
            self._task.cancel()
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            self._task = None
        self._pool.shutdown(wait=True)
        return self.health()

    # -- health --------------------------------------------------------------

    def health(self) -> dict:
        """GATEWAY_HEALTH: full accounting — ``unaccounted`` must be 0."""
        lat = np.sort(np.asarray(self._latencies)) * 1e3
        pct = (lambda p: float(lat[min(int(len(lat) * p / 100),
                                       len(lat) - 1)]) if len(lat) else None)
        shed_total = sum(self.shed.values())
        return dict(
            offered=self.offered, admitted=self.admitted,
            answered=self.answered,
            answered_exact=self.answered_exact,
            answered_degraded=self.answered_degraded,
            quality_tiers={str(k): v for k, v in
                           sorted(self.quality_tiers.items())},
            brownout=(None if self._brownout is None
                      else self._brownout.health()),
            shed={k: v for k, v in self.shed.items() if v},
            shed_total=shed_total,
            unaccounted=(self.offered - self.answered_exact
                         - self.answered_degraded - shed_total),
            buckets=self.buckets, bucket_size=self.bucket,
            flushes=dict(self.flushes),
            queue_depth=self._pending, draining=self._draining,
            mirrored=self.mirrored, mirror_failures=self.mirror_failures,
            latency_ms=dict(p50=pct(50), p99=pct(99)),
            tenants={
                t: dict(offered=row["offered"], answered=row["answered"],
                        shed={k: v for k, v in row["shed"].items() if v})
                for t, row in self.tenants.items()},
        )
