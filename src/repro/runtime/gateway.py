"""Resilient async serving gateway: continuous batching + admission control.

The synchronous serve loop (pre-PR-7 ``launch/serve.py``) executed a fixed
request array bucket by bucket — fine for a benchmark, useless under live
traffic where requests arrive one at a time, carry deadlines, and belong
to different tenants/models.  This gateway is the traffic-facing layer:

* **Continuous batching** — requests are admitted into a partially-filled
  per-tenant bucket (one jit trace per tenant: the executed batch is
  always padded to the fixed ``bucket`` size, so a partial flush never
  retraces).  A bucket flushes when it fills, when its OLDEST request has
  waited ``max_wait`` seconds (age-based flush — tail latency is bounded
  even at low arrival rates), or at drain.

* **Admission control / load shedding** — the pending-request queue is
  bounded by ``max_queue``: when it is full the request is REJECTED at
  admission with the typed reason ``queue_full`` instead of growing an
  unbounded backlog.  Per-request deadlines are enforced at dequeue: an
  expired request is rejected ``deadline_expired``, never executed and
  never silently dropped.  Every offered request resolves to exactly one
  :class:`Response` — answered or shed with a typed reason — and
  :meth:`Gateway.health` proves it (``unaccounted`` must be 0).

* **Typed bucket rejection** — the runner (engine ladder / artifact zoo)
  signals per-bucket failure by raising; an exception carrying a
  ``shed_reason`` attribute (e.g. ``zoo.TenantQuarantined``) rejects the
  bucket's requests with that reason, anything else with
  ``engine_failed``.  One tenant's poisoned artifact therefore sheds THAT
  tenant's requests while other tenants keep flushing.

* **Shadow mirror** — an optional ``mirror(tenant, rows, preds)`` tap
  observes each successfully-answered bucket on the worker thread (the
  online updater's shadow-canary: replay the bucket against a candidate
  artifact and compare).  The tap is best-effort by construction: its
  exceptions are swallowed and counted (``mirror_failures``), and it can
  never shed or alter an answer.

* **Graceful drain** — :meth:`drain` (wired to SIGTERM by the server)
  stops admission (``shutting_down``), flushes the remaining partial
  buckets under ``drain_timeout`` seconds, and rejects whatever is still
  queued when the timer expires with ``drain_timeout``.  The final
  ``GATEWAY_HEALTH`` dict accounts for 100% of offered requests.

Fault sites (``runtime/faults.py``): ``gateway.queue_overflow`` forces an
admission-time shed; ``gateway.drain_timeout`` forces the drain timer to
expire immediately.  Both are drilled in ``tests/test_gateway.py`` and
under live Poisson load in ``benchmarks/serve_gateway.py --chaos``.

Execution is serialized through a single worker thread: the engines are
jit'd callables whose per-bucket wall-time is the unit of straggler/
deadline attribution, and the event loop stays free to admit, age-flush,
and shed while a bucket is on the accelerator.
"""

from __future__ import annotations

import asyncio
import collections
import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.runtime import faults

# Typed shed reasons: the closed vocabulary of ways the gateway refuses
# work.  Every non-answer carries exactly one of these — "silently
# dropped" is not in the list by construction.
QUEUE_FULL = "queue_full"            # admission: bounded queue at capacity
SHUTTING_DOWN = "shutting_down"      # admission: drain already started
DEADLINE_EXPIRED = "deadline_expired"  # dequeue: request deadline passed
DRAIN_TIMEOUT = "drain_timeout"      # drain: still queued when timer expired
ENGINE_FAILED = "engine_failed"      # execution: runner raised (untyped)
# The built-in vocabulary; runner exceptions extend it via a
# ``shed_reason`` attribute (zoo: tenant_quarantined, load_failed), so
# shed counters are an OPEN dict keyed by whatever reasons actually fired.
SHED_REASONS = (QUEUE_FULL, SHUTTING_DOWN, DEADLINE_EXPIRED, DRAIN_TIMEOUT,
                ENGINE_FAILED)


@dataclasses.dataclass
class Response:
    """Terminal outcome of one request: answered or typed-shed."""
    tenant: str
    ok: bool
    pred: Optional[int] = None
    reason: Optional[str] = None
    latency_s: float = 0.0


@dataclasses.dataclass
class _Request:
    tenant: str
    x: np.ndarray
    t_submit: float
    deadline: Optional[float]            # absolute clock() time, or None
    future: "asyncio.Future[Response]"


class Gateway:
    """Async request gateway over a per-tenant bucket runner.

    ``runner(tenant, rows)`` executes one bucket: ``rows`` is a non-empty
    list of request payloads (each an ``(W,)`` array) and the return value
    is the ``(len(rows),)`` prediction array.  The runner owns padding to
    its jit trace shape, engine-ladder demotion, and straggler accounting;
    it raises to reject the whole bucket (typed via a ``shed_reason``
    attribute on the exception, else ``engine_failed``).
    """

    def __init__(self, runner: Callable, *, bucket: int = 128,
                 max_queue: Optional[int] = None, max_wait: float = 0.02,
                 drain_timeout: float = 5.0, clock=time.monotonic,
                 mirror: Optional[Callable] = None):
        self._runner = runner
        # shadow-canary tap: ``mirror(tenant, rows, preds)`` observes a
        # successfully-answered bucket (worker thread, AFTER the serving
        # predictions are computed).  It must never affect the answer: any
        # exception is swallowed and counted, never shed
        self._mirror = mirror
        self.mirrored = 0
        self.mirror_failures = 0
        self.bucket = int(bucket)
        self.max_queue = max_queue if max_queue and max_queue > 0 else None
        self.max_wait = float(max_wait)
        self.drain_timeout = float(drain_timeout)
        self._clock = clock
        self._queues: Dict[str, collections.deque] = {}
        self._pending = 0
        self._inflight = 0
        self._draining = False
        self._task: Optional[asyncio.Task] = None
        self._wake: Optional[asyncio.Event] = None
        self._idle: Optional[asyncio.Event] = None
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="gw-exec")
        # -- accounting: offered == answered + sum(shed.values()) always --
        self.offered = 0
        self.admitted = 0
        self.answered = 0
        self.shed: Dict[str, int] = {}
        self.buckets = 0
        self.flushes = {"full": 0, "age": 0, "drain": 0}
        self.tenants: Dict[str, dict] = {}
        self._latencies: List[float] = []

    # -- admission -----------------------------------------------------------

    def _tenant_row(self, tenant: str) -> dict:
        row = self.tenants.get(tenant)
        if row is None:
            row = self.tenants[tenant] = dict(offered=0, answered=0, shed={})
        return row

    def _resolve(self, req: _Request, resp: Response) -> None:
        if req.future.done():        # already rejected (e.g. drain sweep)
            return
        row = self._tenant_row(req.tenant)
        if resp.ok:
            self.answered += 1
            row["answered"] += 1
            self._latencies.append(resp.latency_s)
        else:
            self.shed[resp.reason] = self.shed.get(resp.reason, 0) + 1
            row["shed"][resp.reason] = row["shed"].get(resp.reason, 0) + 1
        req.future.set_result(resp)

    def _shed_at_admission(self, tenant: str, reason: str,
                           fut: "asyncio.Future[Response]") -> None:
        self.shed[reason] = self.shed.get(reason, 0) + 1
        row = self._tenant_row(tenant)["shed"]
        row[reason] = row.get(reason, 0) + 1
        fut.set_result(Response(tenant=tenant, ok=False, reason=reason))

    def offer(self, tenant: str, x, deadline: Optional[float] = None
              ) -> "asyncio.Future[Response]":
        """Admit (or typed-shed) one request; returns a Future[Response].

        Must be called on the event-loop thread.  ``deadline`` is seconds
        from now; a request still queued when it expires is rejected
        ``deadline_expired`` at dequeue time.
        """
        loop = asyncio.get_running_loop()
        fut: asyncio.Future = loop.create_future()
        now = self._clock()
        self.offered += 1
        self._tenant_row(tenant)["offered"] += 1
        if self._draining:
            self._shed_at_admission(tenant, SHUTTING_DOWN, fut)
            return fut
        over = self.max_queue is not None and self._pending >= self.max_queue
        if over or faults.fire_if("gateway.queue_overflow"):
            self._shed_at_admission(tenant, QUEUE_FULL, fut)
            return fut
        self.admitted += 1
        req = _Request(tenant=tenant, x=x, t_submit=now,
                       deadline=None if deadline is None else now + deadline,
                       future=fut)
        self._queues.setdefault(tenant, collections.deque()).append(req)
        self._pending += 1
        if self._idle is not None:
            self._idle.clear()
        if self._wake is not None:
            self._wake.set()
        return fut

    async def submit(self, tenant: str, x,
                     deadline: Optional[float] = None) -> Response:
        return await self.offer(tenant, x, deadline)

    # -- dispatch ------------------------------------------------------------

    async def start(self) -> "Gateway":
        self._wake = asyncio.Event()
        self._idle = asyncio.Event()
        self._idle.set()
        self._task = asyncio.create_task(self._dispatch_loop())
        return self

    def _expire(self, now: float) -> None:
        """Shed queued requests whose deadline has already passed."""
        for q in self._queues.values():
            kept = [r for r in q if not (r.deadline is not None
                                         and r.deadline < now)]
            if len(kept) != len(q):
                for r in q:
                    if r.deadline is not None and r.deadline < now:
                        self._pending -= 1
                        self._resolve(r, Response(
                            tenant=r.tenant, ok=False,
                            reason=DEADLINE_EXPIRED,
                            latency_s=now - r.t_submit))
                q.clear()
                q.extend(kept)

    def _pick_flush(self, now: float):
        """(tenant, cause) to flush now, or (None, earliest-age-due)."""
        due: Optional[float] = None
        for tenant, q in self._queues.items():
            if not q:
                continue
            if len(q) >= self.bucket:
                return tenant, "full"
            if self._draining:
                return tenant, "drain"
            age_due = q[0].t_submit + self.max_wait
            if age_due <= now:
                return tenant, "age"
            due = age_due if due is None else min(due, age_due)
        return None, due

    def set_mirror(self, mirror: Optional[Callable]) -> None:
        """Install/remove the shadow tap (safe while serving: the tap is
        read once per bucket on the worker thread)."""
        self._mirror = mirror

    def _run_bucket(self, tenant: str, rows):
        """Worker-thread bucket execution + best-effort shadow mirror."""
        preds = self._runner(tenant, rows)
        mirror = self._mirror
        if mirror is not None:
            try:
                mirror(tenant, rows, preds)
                self.mirrored += 1
            except Exception:  # noqa: BLE001 — the tap must never shed
                self.mirror_failures += 1
        return preds

    async def _dispatch_loop(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            now = self._clock()
            self._expire(now)
            tenant, cause = self._pick_flush(now)
            if tenant is None:
                if self._pending == 0 and self._inflight == 0:
                    self._idle.set()
                self._wake.clear()
                timeout = None if cause is None else max(cause - now, 0.0)
                try:
                    await asyncio.wait_for(self._wake.wait(), timeout)
                except asyncio.TimeoutError:
                    pass
                continue
            q = self._queues[tenant]
            reqs = [q.popleft() for _ in range(min(self.bucket, len(q)))]
            self._pending -= len(reqs)
            self._inflight += len(reqs)
            self.flushes[cause] += 1
            self.buckets += 1
            try:
                preds = await loop.run_in_executor(
                    self._pool, self._run_bucket, tenant,
                    [r.x for r in reqs])
            except Exception as e:  # noqa: BLE001 — typed bucket rejection
                reason = getattr(e, "shed_reason", ENGINE_FAILED)
                end = self._clock()
                for r in reqs:
                    self._resolve(r, Response(
                        tenant=tenant, ok=False, reason=reason,
                        latency_s=end - r.t_submit))
            else:
                preds = np.asarray(preds)
                end = self._clock()
                for i, r in enumerate(reqs):
                    self._resolve(r, Response(
                        tenant=tenant, ok=True, pred=int(preds[i]),
                        latency_s=end - r.t_submit))
            finally:
                self._inflight -= len(reqs)

    # -- drain / shutdown ----------------------------------------------------

    async def drain(self, timeout: Optional[float] = None) -> dict:
        """Stop admitting, flush what fits in the window, shed the rest.

        Returns the final health dict.  Idempotent enough for the common
        SIGTERM-then-natural-completion race: a second call finds empty
        queues and returns immediately.
        """
        self._draining = True
        if self._wake is not None:
            self._wake.set()
        timeout = self.drain_timeout if timeout is None else timeout
        if faults.fire_if("gateway.drain_timeout"):
            timeout = 0.0
        if self._idle is not None:
            try:
                await asyncio.wait_for(self._idle.wait(), timeout)
            except asyncio.TimeoutError:
                now = self._clock()
                for q in self._queues.values():
                    while q:
                        r = q.popleft()
                        self._pending -= 1
                        self._resolve(r, Response(
                            tenant=r.tenant, ok=False, reason=DRAIN_TIMEOUT,
                            latency_s=now - r.t_submit))
                # an in-flight bucket still completes (its futures resolve
                # normally); wait for it so shutdown never abandons work
                await self._idle.wait()
        if self._task is not None:
            self._task.cancel()
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            self._task = None
        self._pool.shutdown(wait=True)
        return self.health()

    # -- health --------------------------------------------------------------

    def health(self) -> dict:
        """GATEWAY_HEALTH: full accounting — ``unaccounted`` must be 0."""
        lat = np.sort(np.asarray(self._latencies)) * 1e3
        pct = (lambda p: float(lat[min(int(len(lat) * p / 100),
                                       len(lat) - 1)]) if len(lat) else None)
        shed_total = sum(self.shed.values())
        return dict(
            offered=self.offered, admitted=self.admitted,
            answered=self.answered,
            shed={k: v for k, v in self.shed.items() if v},
            shed_total=shed_total,
            unaccounted=self.offered - self.answered - shed_total,
            buckets=self.buckets, bucket_size=self.bucket,
            flushes=dict(self.flushes),
            queue_depth=self._pending, draining=self._draining,
            mirrored=self.mirrored, mirror_failures=self.mirror_failures,
            latency_ms=dict(p50=pct(50), p99=pct(99)),
            tenants={
                t: dict(offered=row["offered"], answered=row["answered"],
                        shed={k: v for k, v in row["shed"].items() if v})
                for t, row in self.tenants.items()},
        )
