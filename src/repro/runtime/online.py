"""Online-learning service: stream feedback, drift-track, hot-swap safely.

MATADOR compiles a *frozen* TM; this module closes the train→compile→serve
loop (ROADMAP item 5, grounded in "An FPGA Architecture for Online Learning
using the Tsetlin Machine"): labeled feedback streams into a live automata
bank beside the serving artifact, fused-train steps update it, and when the
bank's include bits have drifted far enough from what is deployed, the
updater rebuilds and — robustly — promotes a successor artifact.

Promotion is a pipeline, not an assignment:

1. **Drift tracking** — every accepted feedback batch runs one
   ``train.online_step``; the bank's dense packed include words are
   compared against the anchor snapshot taken at the last compile
   (``compiler.include_drift``).  Crossing ``drift_threshold`` arms a
   rebuild.
2. **Incremental recompile** — ``compiler.incremental_recompile`` reuses
   the previous artifact's chain-schedule rows for clauses that did not
   move and falls back to a full ``compile_tm`` on layout changes.  The
   ``online.rebuild_fail`` fault site fires here: a failed rebuild keeps
   the deployed artifact serving and retries at the next drift check.
3. **Integrity envelope** — the candidate is saved and re-loaded through
   the PR-6 artifact path (atomic write, sha256 checksum,
   ``validate_artifact``), which also materializes both default schedules
   so the swap installs a pre-warmed artifact.
4. **Shadow canary** — the gateway's mirror tap replays a sampled
   fraction of live buckets against the candidate (``canary_frac``) and
   compares predictions bucket-for-bucket with the serving artifact.
   Agreement below ``canary_agreement`` after ``canary_min`` mirrored
   buckets fails the canary: the candidate is discarded and the tenant's
   circuit breaker is tripped (``swap_policy="immediate"`` skips this
   phase).
5. **Atomic swap** — ``zoo.swap`` commits the candidate with a single
   assignment under the zoo lock: in-flight leases finish on the old
   version, new admissions route to the new one, and the gateway's
   ``offered == answered + shed`` invariant is untouched.  The
   ``zoo.swap_abort`` drill proves an aborted swap leaves the old entry
   serving, bit-intact.
6. **Post-swap watch + rollback** — deployed-artifact accuracy on the
   labeled feedback stream (and optionally a bucket-latency EWMA via
   :meth:`OnlineUpdater.record_bucket_latency`) is tracked across the
   swap; a regression swaps the RETAINED previous object back (bit-exact)
   and trips the breaker.

Feedback hygiene: :meth:`OnlineUpdater.ingest` validates every record
(shape, label range) before it can touch the bank — the
``online.feedback_corrupt`` site corrupts a record *pre-validation* and
the drill asserts it is rejected, never trained on.  SIGTERM drains the
pending feedback queue through the PR-6 checkpoint path
(:meth:`OnlineUpdater.drain`), and a restarted updater re-ingests it.
"""

from __future__ import annotations

import dataclasses
import os
import tempfile
import threading
import time
from typing import Callable, List, Optional

import numpy as np

from repro.core import compiler, tm
from repro.runtime import faults

IDLE, CANARY = "idle", "canary"


@dataclasses.dataclass
class OnlineConfig:
    """Policy knobs of the updater (CLI: ``launch/serve.py --online``)."""

    drift_threshold: float = 0.05     # include-bit drift arming a rebuild
    batch_size: int = 64              # feedback batch (one jit trace)
    max_pending: int = 4096           # feedback queue bound (typed drops)
    canary_frac: float = 0.25         # fraction of live buckets mirrored
    canary_min: int = 4               # mirrored buckets before a verdict
    canary_agreement: float = 0.98    # pass bar: candidate-vs-serving match
    swap_policy: str = "canary"       # "canary" | "immediate"
    regression_window: int = 4        # feedback batches per accuracy window
    regression_drop: float = 0.2      # post-swap accuracy drop -> rollback
    latency_factor: float = 3.0       # post-swap latency blow-up -> rollback
    latency_warmup: int = 3           # post-swap buckets exempt from the
                                      # watch (rebound engines re-trace)


class FeedbackQueue:
    """Bounded, thread-safe labeled-feedback buffer.

    Producers (the serving loop, a label joiner) call :meth:`put` from any
    thread; the updater pops full training batches.  Overflow drops are
    COUNTED (``dropped_overflow``) — feedback is best-effort by nature,
    but the accounting never lies about it.
    """

    def __init__(self, max_pending: int = 4096):
        self.max_pending = int(max_pending)
        self._lock = threading.Lock()
        self._x: List[np.ndarray] = []
        self._y: List[int] = []
        self.accepted = 0
        self.dropped_overflow = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._y)

    def put(self, x: np.ndarray, y: int) -> bool:
        with self._lock:
            if len(self._y) >= self.max_pending:
                self.dropped_overflow += 1
                return False
            self._x.append(x)
            self._y.append(int(y))
            self.accepted += 1
            return True

    def pop_batch(self, n: int):
        """A full ``n``-record batch, or None when fewer are pending
        (partial batches stay queued — fixed batch = one jit trace)."""
        with self._lock:
            if len(self._y) < n:
                return None
            xs, self._x = self._x[:n], self._x[n:]
            ys, self._y = self._y[:n], self._y[n:]
        return np.stack(xs), np.asarray(ys, np.int32)

    def snapshot_and_clear(self):
        """Everything pending (for the SIGTERM-drain checkpoint)."""
        with self._lock:
            xs, ys = self._x, self._y
            self._x, self._y = [], []
        if not ys:
            return None, None
        return np.stack(xs), np.asarray(ys, np.int32)


class OnlineUpdater:
    """The streaming train→compile→canary→swap engine for ONE tenant.

    ``make_obj(compiled) -> (obj, nbytes)`` builds the zoo entry the
    serving layer wants (runner closure, engine plan, ...);
    ``serve_fn(obj, rows) -> preds`` executes one bucket against such an
    object — the same callable the zoo runner uses, reused here to run
    the candidate side of the shadow canary (which doubles as the
    candidate's jit warm-up, so the post-swap first bucket pays no
    trace).  ``zoo``/``tenant`` are the serving cache to swap under;
    ``ckpt_manager`` (optional) is the PR-6 checkpoint path the SIGTERM
    drain writes through — when its directory already holds a
    checkpoint, construction resumes from it (bank + pending feedback).
    """

    def __init__(self, config: tm.TMConfig, ta_state, deployed, *,
                 cfg: Optional[OnlineConfig] = None,
                 zoo=None, tenant: str = "t0",
                 make_obj: Optional[Callable] = None,
                 serve_fn: Optional[Callable] = None,
                 deployed_obj=None, deployed_nbytes: int = 0,
                 ckpt_manager=None, artifact_dir: Optional[str] = None,
                 on_promote: Optional[Callable] = None,
                 clock=time.monotonic):
        self.config = config
        self.cfg = cfg or OnlineConfig()
        if self.cfg.swap_policy not in ("canary", "immediate"):
            raise ValueError(
                f"swap_policy must be 'canary' or 'immediate', "
                f"got {self.cfg.swap_policy!r}")
        self.zoo = zoo
        self.tenant = tenant
        self.make_obj = make_obj or self._default_make_obj
        self.serve_fn = serve_fn or self._default_serve
        self._clock = clock
        self._ckpt = ckpt_manager
        self._artifact_dir = artifact_dir
        # on_promote(compiled) fires AFTER the zoo commit (and after a
        # rollback re-commit) so the serving layer can rebind anything
        # outside the zoo — e.g. serve.py's engine ladder — to the newly
        # deployed artifact
        self._on_promote = on_promote
        self.queue = FeedbackQueue(self.cfg.max_pending)
        self._lock = threading.RLock()

        self._ta = np.asarray(ta_state)
        self.deployed = deployed
        self._deployed_obj = deployed_obj
        self._deployed_nbytes = int(deployed_nbytes)
        # drift anchor: the dense include snapshot of the bank the
        # DEPLOYED artifact was compiled from
        self._anchor = compiler.dense_include_words(config, self._ta)
        self.gstep = 0

        # canary state
        self.state = IDLE
        self._candidate = None
        self._cand_obj = None
        self._cand_nbytes = 0
        self._canary_buckets = 0
        self._canary_agree = 0
        self._canary_total = 0
        self._mirror_count = 0
        # rollback state
        self._previous = None         # (compiled, obj, nbytes) pre-swap
        self._acc_window: List[float] = []
        self._acc_at_promote: Optional[float] = None
        self._lat_ewma: Optional[float] = None
        self._lat_at_promote: Optional[float] = None
        self._lat_warmup = 0
        self._drift_crossed_at: Optional[float] = None

        # telemetry
        self.ingested = 0
        self.rejected_corrupt = 0
        self.steps = 0
        self.rebuilds = 0
        self.rebuild_failures = 0
        self.incremental_rebuilds = 0
        self.full_rebuilds = 0
        self.canary_passes = 0
        self.canary_failures = 0
        self.promotions = 0
        self.swap_aborts = 0
        self.rollbacks: List[dict] = []
        self.last_drift = 0.0
        self.drift_to_promotion_ms: List[float] = []

        if self._ckpt is not None and self._ckpt.latest_step() is not None:
            self._resume()

    # -- defaults ------------------------------------------------------------

    @staticmethod
    def _artifact_nbytes(compiled) -> int:
        return (compiled.include_words.nbytes + compiled.word_ids.nbytes
                + compiled.votes.nbytes)

    def _default_make_obj(self, compiled):
        return {"compiled": compiled}, self._artifact_nbytes(compiled)

    @staticmethod
    def _default_serve(obj, rows):
        xw = np.stack([np.asarray(r) for r in rows])
        sums = compiler.run_compiled(obj["compiled"], xw)
        return np.argmax(np.asarray(sums), axis=-1)

    # -- feedback ingest -----------------------------------------------------

    def ingest(self, x, y) -> bool:
        """Validate one labeled feedback record and queue it.

        The ``online.feedback_corrupt`` site corrupts the record BEFORE
        validation — the drill for "a corrupt record is rejected and
        counted, never trained on".  Returns True when accepted.
        """
        x = np.asarray(x)
        y = int(y)
        if faults.fire_if("online.feedback_corrupt"):
            y = self.config.n_classes + 1_000_000      # wild label
        if x.shape != (self.config.n_features,):
            self.rejected_corrupt += 1
            return False
        if not (0 <= y < self.config.n_classes):
            self.rejected_corrupt += 1
            return False
        if not self.queue.put(x.astype(np.uint8), y):
            return False
        self.ingested += 1
        return True

    # -- training + drift ----------------------------------------------------

    def step(self) -> bool:
        """Train on ONE full pending feedback batch (if any), update the
        drift/accuracy trackers, and advance the promotion pipeline.
        Returns True when a batch was consumed."""
        batch = self.queue.pop_batch(self.cfg.batch_size)
        if batch is None:
            self._check_regression()
            return False
        xb, yb = batch
        with self._lock:
            self._track_accuracy(xb, yb)
            from repro.core import train

            import jax.numpy as jnp
            new_ta, _ = train.online_step(
                self.config, jnp.asarray(self._ta), jnp.asarray(xb),
                jnp.asarray(yb), jnp.uint32(self.gstep))
            self._ta = np.asarray(new_ta)
            self.gstep += 1
            self.steps += 1

            drift = compiler.include_drift(
                self._anchor,
                compiler.dense_include_words(self.config, self._ta))
            self.last_drift = drift.drift
            if (self.state == IDLE
                    and drift.drift >= self.cfg.drift_threshold):
                if self._drift_crossed_at is None:
                    self._drift_crossed_at = self._clock()
                self._rebuild()
            self._check_regression()
        return True

    def _track_accuracy(self, xb, yb) -> None:
        """Deployed-artifact accuracy on the labeled feedback stream —
        the post-swap regression signal (labels are right here; no extra
        eval traffic needed)."""
        obj = self._deployed_obj
        if obj is None:
            compiled = self.deployed
            preds = np.argmax(np.asarray(compiler.run_compiled(
                compiled, self._pack(xb))), axis=-1)
        else:
            try:
                preds = np.asarray(self.serve_fn(obj, list(self._pack(xb))))
            except Exception:
                return                      # serving trouble is not signal
        acc = float((preds == yb).mean())
        self._acc_window.append(acc)
        if len(self._acc_window) > self.cfg.regression_window:
            self._acc_window.pop(0)

    def _pack(self, xb) -> np.ndarray:
        from repro.core import packetizer

        lits = np.concatenate([xb, 1 - xb], axis=1).astype(np.uint8)
        return packetizer.pack_bits_np(lits)

    # -- rebuild + integrity -------------------------------------------------

    def _rebuild(self) -> None:
        """Drift crossed: build + validate a candidate, start its canary."""
        try:
            faults.raise_if("online.rebuild_fail")
            candidate, info = compiler.incremental_recompile(
                self.config, self._ta, self.deployed)
            candidate = self._integrity_roundtrip(candidate)
        except Exception as e:  # noqa: BLE001 — keep serving the old artifact
            self.rebuild_failures += 1
            print(f"online: rebuild failed ({type(e).__name__}: {e}); "
                  "still serving the deployed artifact, will retry")
            return
        self.rebuilds += 1
        if info["mode"] == "incremental":
            self.incremental_rebuilds += 1
        else:
            self.full_rebuilds += 1
        obj, nbytes = self.make_obj(candidate)
        self._candidate = candidate
        self._cand_obj = obj
        self._cand_nbytes = int(nbytes)
        # fresh anchor candidate: the bank the candidate was compiled from
        self._cand_anchor = compiler.dense_include_words(
            self.config, self._ta)
        if self.cfg.swap_policy == "immediate":
            self._promote()
        else:
            self.state = CANARY
            self._canary_buckets = 0
            self._canary_agree = 0
            self._canary_total = 0

    def _integrity_roundtrip(self, candidate):
        """PR-6 envelope: atomic save + checksum/validate re-load.  Also
        materializes both default schedules, so the promoted artifact is
        schedule-warm."""
        d = self._artifact_dir or tempfile.mkdtemp(prefix="online-cand-")
        os.makedirs(d, exist_ok=True)
        path = candidate.save(os.path.join(
            d, f"candidate-{self.tenant}-{self.gstep}.npz"))
        loaded = compiler.CompiledTM.load(path)
        if self._artifact_dir is None:
            try:
                os.unlink(path)
                os.rmdir(d)
            except OSError:
                pass
        # keep the incrementally-built schedule objects (bit-identical to
        # the loaded ones, already memoized) + carried-over tunings; the
        # roundtrip's job was verification
        candidate.features = loaded.features or candidate.features
        return candidate

    # -- shadow canary -------------------------------------------------------

    def mirror(self, tenant: str, rows, preds) -> None:
        """Gateway mirror tap: replay a sampled bucket on the candidate.

        Deterministic sampling (every ``round(1/canary_frac)``-th bucket)
        keeps drills reproducible.  Called on the gateway worker thread;
        exceptions are swallowed by the gateway (counted, never shed).
        """
        if tenant != self.tenant:
            return
        with self._lock:
            if self.state != CANARY or self._cand_obj is None:
                return
            self._mirror_count += 1
            stride = max(1, int(round(1.0 / max(self.cfg.canary_frac,
                                                1e-9))))
            if (self._mirror_count - 1) % stride != 0:
                return
            cand = np.asarray(self.serve_fn(self._cand_obj, rows))
            serving = np.asarray(preds)
            self._canary_agree += int((cand == serving).sum())
            self._canary_total += int(serving.shape[0])
            self._canary_buckets += 1
            if self._canary_buckets >= self.cfg.canary_min:
                self._finish_canary()

    @property
    def canary_agreement(self) -> float:
        if self._canary_total == 0:
            return 1.0
        return self._canary_agree / self._canary_total

    def _finish_canary(self) -> None:
        if self.canary_agreement >= self.cfg.canary_agreement:
            self.canary_passes += 1
            self._promote()
        else:
            self.canary_failures += 1
            print(f"online: canary FAILED for {self.tenant!r} "
                  f"(agreement {self.canary_agreement:.3f} < "
                  f"{self.cfg.canary_agreement}); discarding candidate "
                  "and tripping the breaker")
            self._discard_candidate()
            if self.zoo is not None:
                self.zoo.trip(self.tenant)

    def _discard_candidate(self) -> None:
        self.state = IDLE
        self._candidate = None
        self._cand_obj = None
        self._cand_nbytes = 0
        self._drift_crossed_at = None

    # -- promotion / rollback ------------------------------------------------

    def _promote(self) -> None:
        """Commit the candidate via the zoo's atomic swap."""
        from repro.runtime import zoo as zoo_mod

        candidate, obj, nbytes = (self._candidate, self._cand_obj,
                                  self._cand_nbytes)
        if self.zoo is not None:
            try:
                self.zoo.swap(self.tenant, obj, nbytes)
            except zoo_mod.SwapAborted as e:
                self.swap_aborts += 1
                print(f"online: swap aborted for {self.tenant!r}: {e}; "
                      "the old artifact keeps serving")
                self._discard_candidate()
                return
        self._previous = (self.deployed, self._deployed_obj,
                          self._deployed_nbytes)
        self.deployed = candidate
        self._deployed_obj = obj
        self._deployed_nbytes = int(nbytes)
        self._anchor = self._cand_anchor
        self.promotions += 1
        if self._drift_crossed_at is not None:
            self.drift_to_promotion_ms.append(
                (self._clock() - self._drift_crossed_at) * 1e3)
        self._acc_at_promote = (float(np.mean(self._acc_window))
                                if self._acc_window else None)
        self._lat_at_promote = self._lat_ewma
        self._lat_warmup = self.cfg.latency_warmup
        self._acc_window = []
        self._discard_candidate()
        if self._on_promote is not None:
            self._on_promote(self.deployed)

    def record_bucket_latency(self, seconds: float) -> None:
        """Optional serving-side latency feed for the post-swap watch.

        The first ``latency_warmup`` buckets after a promotion are exempt:
        the swap rebinds the serving engines, and their fresh jit traces
        would otherwise read as a latency regression of the ARTIFACT."""
        with self._lock:
            if self._lat_warmup > 0:
                self._lat_warmup -= 1
                return
            a = 0.2
            self._lat_ewma = (seconds if self._lat_ewma is None
                              else (1 - a) * self._lat_ewma + a * seconds)

    def _check_regression(self) -> None:
        if self._previous is None:
            return
        if (self._acc_at_promote is not None
                and len(self._acc_window) >= self.cfg.regression_window):
            acc = float(np.mean(self._acc_window))
            if acc < self._acc_at_promote - self.cfg.regression_drop:
                self.rollback(
                    f"accuracy regression: {acc:.3f} < "
                    f"{self._acc_at_promote:.3f} - {self.cfg.regression_drop}")
                return
        if (self._lat_at_promote is not None and self._lat_ewma is not None
                and self._lat_ewma
                > self.cfg.latency_factor * max(self._lat_at_promote, 1e-9)):
            self.rollback(
                f"latency regression: ewma {self._lat_ewma * 1e3:.2f}ms > "
                f"{self.cfg.latency_factor}x pre-swap")

    def rollback(self, reason: str) -> None:
        """Swap the retained pre-promotion object back (bit-exact) and
        trip the tenant's breaker."""
        with self._lock:
            if self._previous is None:
                return
            prev_compiled, prev_obj, prev_nbytes = self._previous
            print(f"online: ROLLBACK for {self.tenant!r}: {reason}")
            if self.zoo is not None and prev_obj is not None:
                self.zoo.swap(self.tenant, prev_obj, prev_nbytes)
                self.zoo.trip(self.tenant)
            self.deployed = prev_compiled
            self._deployed_obj = prev_obj
            self._deployed_nbytes = prev_nbytes
            self._previous = None
            self._acc_at_promote = None
            self._lat_at_promote = None
            self._acc_window = []
            self.rollbacks.append(dict(reason=reason, gstep=self.gstep))
            if self._on_promote is not None:
                self._on_promote(self.deployed)
            # restart drift from the CURRENT live bank: the regressed
            # direction already accumulated once, so requiring a fresh
            # threshold crossing before the next rebuild acts as a
            # cooldown instead of immediately re-promoting the same bank
            self._anchor = compiler.dense_include_words(self.config, self._ta)

    # -- drain / resume (PR-6 checkpoint path) -------------------------------

    def drain(self) -> Optional[int]:
        """SIGTERM path: checkpoint the bank + every pending feedback
        record through the PR-6 checkpoint store.  Returns the
        checkpointed step (None without a manager)."""
        if self._ckpt is None:
            return None
        with self._lock:
            xs, ys = self.queue.snapshot_and_clear()
            if xs is None:
                xs = np.zeros((0, self.config.n_features), np.uint8)
                ys = np.zeros((0,), np.int32)
            tree = {"ta": np.asarray(self._ta),
                    "pending_x": xs, "pending_y": ys}
            extra = dict(gstep=self.gstep, ingested=self.ingested,
                         n_pending=int(ys.shape[0]),
                         rejected_corrupt=self.rejected_corrupt)
            self._ckpt.save(self.gstep, tree, extra=extra, blocking=True)
            return self.gstep

    def _resume(self) -> None:
        target = {"ta": self._ta,
                  "pending_x": np.zeros((0,), np.uint8),
                  "pending_y": np.zeros((0,), np.int32)}
        tree, extra = self._ckpt.restore(target)
        self._ta = np.asarray(tree["ta"])
        self.gstep = int(extra.get("gstep", 0))
        self.ingested = int(extra.get("ingested", 0))
        self.rejected_corrupt = int(extra.get("rejected_corrupt", 0))
        px, py = np.asarray(tree["pending_x"]), np.asarray(tree["pending_y"])
        for i in range(py.shape[0]):
            self.queue.put(px[i].astype(np.uint8), int(py[i]))
        self._anchor = compiler.dense_include_words(self.config, self._ta)
        print(f"online: resumed at gstep {self.gstep} with "
              f"{int(py.shape[0])} pending feedback records")

    # -- health --------------------------------------------------------------

    def health(self) -> dict:
        with self._lock:
            return dict(
                tenant=self.tenant, state=self.state, gstep=self.gstep,
                steps=self.steps, ingested=self.ingested,
                rejected_corrupt=self.rejected_corrupt,
                pending=len(self.queue),
                dropped_overflow=self.queue.dropped_overflow,
                drift=self.last_drift,
                rebuilds=self.rebuilds,
                rebuild_failures=self.rebuild_failures,
                incremental_rebuilds=self.incremental_rebuilds,
                full_rebuilds=self.full_rebuilds,
                canary=dict(buckets=self._canary_buckets,
                            agreement=self.canary_agreement,
                            passes=self.canary_passes,
                            failures=self.canary_failures),
                promotions=self.promotions,
                swap_aborts=self.swap_aborts,
                rollbacks=list(self.rollbacks),
                drift_to_promotion_ms=list(self.drift_to_promotion_ms),
            )
