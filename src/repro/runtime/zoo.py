"""Multi-tenant artifact zoo: LRU cache of loaded models + circuit breakers.

A production gateway serves MANY compiled TMs — far more than fit in
memory at once.  The zoo is the tenant-facing model cache:

* **LRU under a byte cap** — ``loader(tenant)`` returns ``(obj, nbytes)``
  (``obj`` is whatever the serving layer wants per tenant: typically a
  dict with the validated ``CompiledTM`` and its ``EngineLadder``).
  Entries are evicted least-recently-used when ``capacity_bytes`` /
  ``max_entries`` is exceeded.

* **Pin/lease** — :meth:`lease` pins the entry for the duration of a
  bucket; a pinned entry is NEVER evicted mid-flight.  When pressure (or
  the ``zoo.evict_inflight`` fault drill) targets a pinned entry, the
  eviction is DEFERRED: the entry is marked and dropped when its last
  lease is released, the in-flight bucket completes untouched.

* **Atomic hot-swap** — :meth:`swap` promotes a new artifact version for
  a tenant under the zoo lock with a single-assignment commit: in-flight
  leases finish against the OLD version (the release path identity-checks
  its entry, so draining leases never delete the successor), new
  admissions route to the new one, and the gateway's
  ``offered == answered + shed`` accounting never sees a gap.  The
  ``zoo.swap_abort`` fault site fires between candidate preparation and
  the commit — an aborted swap raises :class:`SwapAborted` and leaves the
  old entry serving, bit-intact (drilled).  :meth:`trip` force-opens a
  tenant's breaker, the rollback hook for a failed canary or a post-swap
  regression.

* **Per-tenant circuit breaker** — a tenant whose artifact repeatedly
  fails (load errors via the ``zoo.load_fail`` site, validation
  rejections, engine-ladder exhaustion reported through
  :meth:`record_fault`) trips its breaker OPEN: subsequent leases raise
  :class:`TenantQuarantined` (``shed_reason="tenant_quarantined"`` — the
  gateway sheds that tenant's requests with a typed reason) instead of
  re-paying the failure in the shared dispatch loop.  After an
  exponential-backoff cooldown the breaker half-opens and admits ONE
  probe lease: success closes it, failure re-opens with doubled backoff.
  Healthy tenants never notice.

The breaker clock is injectable so the open/half-open/close transitions
are unit-testable without sleeping.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import re
import threading
import time
from typing import Callable, Dict, Optional

from repro.runtime import faults

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


class TenantQuarantined(RuntimeError):
    """Lease refused: the tenant's breaker is open (typed gateway shed)."""
    shed_reason = "tenant_quarantined"

    def __init__(self, tenant: str, retry_in: float):
        super().__init__(
            f"tenant {tenant!r} quarantined; retry in {retry_in:.2f}s")
        self.tenant = tenant
        self.retry_in = retry_in


class ArtifactLoadError(RuntimeError):
    """Loading/validating the tenant's artifact failed (typed shed)."""
    shed_reason = "load_failed"


class SwapAborted(RuntimeError):
    """Hot-swap died before its commit point; the old entry still serves."""


class CircuitBreaker:
    """closed -> open (threshold consecutive faults) -> half_open (after
    cooldown * 2^(trips-1)) -> closed on probe success / re-open on probe
    failure."""

    def __init__(self, threshold: int = 3, cooldown: float = 1.0,
                 max_cooldown: float = 300.0, clock=time.monotonic):
        self.threshold = threshold
        self.cooldown = cooldown
        self.max_cooldown = max_cooldown
        self._clock = clock
        self.state = CLOSED
        self.consecutive = 0
        self.trips = 0                      # times opened (drives backoff)
        self.retry_at: Optional[float] = None

    def _open(self) -> None:
        self.state = OPEN
        self.trips += 1
        backoff = min(self.cooldown * (2 ** (self.trips - 1)),
                      self.max_cooldown)
        self.retry_at = self._clock() + backoff

    def allow(self) -> bool:
        """May a lease proceed?  OPEN past its cooldown admits one probe."""
        if self.state == OPEN:
            if self._clock() >= self.retry_at:
                self.state = HALF_OPEN
                return True
            return False
        return True                          # CLOSED or HALF_OPEN (probe)

    def record_failure(self) -> None:
        self.consecutive += 1
        if self.state == HALF_OPEN or self.consecutive >= self.threshold:
            self._open()

    def record_success(self) -> None:
        self.consecutive = 0
        if self.state in (HALF_OPEN, OPEN):
            self.state = CLOSED
            self.trips = 0
            self.retry_at = None

    @property
    def retry_in(self) -> float:
        if self.retry_at is None:
            return 0.0
        return max(self.retry_at - self._clock(), 0.0)


@dataclasses.dataclass
class _Entry:
    tenant: str
    obj: object
    nbytes: int
    pins: int = 0
    evict_on_release: bool = False
    version: int = 1                 # bumped by swap(); 1 = cold load


def artifact_loader(resolve_path: Callable[[str], str], *,
                    batch: int = 64, interpret: Optional[bool] = None,
                    policy: str = "predict") -> Callable:
    """Build a zoo ``loader`` that cold-loads compiled-TM artifacts.

    ``resolve_path(tenant)`` maps a tenant name to a ``save()``-produced
    artifact path.  The loader validates + loads the ``CompiledTM`` and
    asks ``kernels.autotune.plan_engine`` for an engine + block plan.
    Under the default ``policy="predict"`` the plan comes purely from
    the persisted feature vector and the analytical cost model — a cold
    zoo load issues ZERO kernel timing runs.  Returns the ``(obj,
    nbytes)`` pair the zoo expects, with ``obj`` a dict::

        {"compiled": CompiledTM, "engine": str, "blocks": dict}
    """
    def load(tenant: str):
        from repro.core import compiler
        from repro.kernels import autotune

        compiled = compiler.CompiledTM.load(resolve_path(tenant))
        engine, blocks = autotune.plan_engine(
            compiled, batch, interpret=interpret, policy=policy)
        nbytes = (compiled.include_words.nbytes + compiled.word_ids.nbytes
                  + compiled.votes.nbytes)
        return {"compiled": compiled, "engine": engine,
                "blocks": dict(blocks)}, nbytes
    return load


def _tenant_step(tenant: str) -> Optional[int]:
    """Trailing integer of a tenant name — lets ``zoo.load_fail@K`` target
    tenant ``...K`` specifically in multi-tenant drills."""
    m = re.search(r"(\d+)$", tenant)
    return int(m.group(1)) if m else None


class ArtifactZoo:
    def __init__(self, loader: Callable, *,
                 capacity_bytes: Optional[int] = None,
                 max_entries: Optional[int] = None,
                 breaker_threshold: int = 3,
                 breaker_cooldown: float = 1.0,
                 breaker_max_cooldown: float = 300.0,
                 clock=time.monotonic):
        self._loader = loader
        self.capacity_bytes = capacity_bytes
        self.max_entries = max_entries
        self._clock = clock
        self._mk_breaker = lambda: CircuitBreaker(
            threshold=breaker_threshold, cooldown=breaker_cooldown,
            max_cooldown=breaker_max_cooldown, clock=clock)
        # insertion order == recency order (move_to_end on touch)
        self._entries: "collections.OrderedDict[str, _Entry]" = \
            collections.OrderedDict()
        self.breakers: Dict[str, CircuitBreaker] = {}
        # reentrant: swap() and lease bookkeeping share _evict(); the lock
        # makes the zoo safe to hot-swap from an updater thread while the
        # gateway's worker thread leases (the loader itself runs under the
        # lock — cold loads serialize, which is the safe default for a
        # cache whose loads mutate shared autotune state)
        self._lock = threading.RLock()
        self.loads = 0
        self.load_failures = 0
        self.evictions = 0
        self.deferred_evictions = 0
        self.quarantine_rejections = 0
        self.swaps = 0
        self.swap_aborts = 0

    # -- breaker plumbing ----------------------------------------------------

    def _breaker(self, tenant: str) -> CircuitBreaker:
        br = self.breakers.get(tenant)
        if br is None:
            br = self.breakers[tenant] = self._mk_breaker()
        return br

    def record_fault(self, tenant: str) -> None:
        """Report a serving fault (e.g. engine-ladder exhaustion) against
        the tenant's breaker."""
        with self._lock:
            self._breaker(tenant).record_failure()

    def record_success(self, tenant: str) -> None:
        with self._lock:
            self._breaker(tenant).record_success()

    def trip(self, tenant: str) -> None:
        """Force the tenant's breaker OPEN immediately — the rollback hook
        for a failed canary or a post-swap regression.  New admissions
        shed ``tenant_quarantined`` until the backoff expires (half-open
        probe semantics apply as usual afterwards)."""
        with self._lock:
            br = self._breaker(tenant)
            br.consecutive = max(br.consecutive, br.threshold)
            br._open()

    # -- cache ---------------------------------------------------------------

    @property
    def nbytes(self) -> int:
        return sum(e.nbytes for e in self._entries.values())

    def _over_cap(self) -> bool:
        # entries already marked for deferred eviction are as good as
        # freed — counting them would cascade-mark every pinned entry
        live = [e for e in self._entries.values() if not e.evict_on_release]
        if self.max_entries is not None and len(live) > self.max_entries:
            return True
        return (self.capacity_bytes is not None
                and sum(e.nbytes for e in live) > self.capacity_bytes)

    def _evict(self) -> None:
        # the drill forces the scan to target a PINNED entry first: the
        # only acceptable behavior is deferral, never a mid-flight yank
        if faults.fire_if("zoo.evict_inflight"):
            for e in self._entries.values():
                if e.pins > 0 and not e.evict_on_release:
                    e.evict_on_release = True
                    self.deferred_evictions += 1
                    break
        while self._over_cap():
            victim = None
            for e in self._entries.values():     # oldest (LRU) first
                if e.pins == 0:
                    victim = e
                    break
            if victim is None:
                # everything is in flight: defer to the release path
                for e in self._entries.values():
                    if not e.evict_on_release:
                        e.evict_on_release = True
                        self.deferred_evictions += 1
                        break
                return
            del self._entries[victim.tenant]
            self.evictions += 1

    def _get(self, tenant: str) -> _Entry:
        br = self._breaker(tenant)
        if not br.allow():
            self.quarantine_rejections += 1
            raise TenantQuarantined(tenant, br.retry_in)
        entry = self._entries.get(tenant)
        if entry is not None:
            self._entries.move_to_end(tenant)
            return entry
        try:
            faults.raise_if("zoo.load_fail", step=_tenant_step(tenant))
            obj, nbytes = self._loader(tenant)
        except Exception as e:
            self.load_failures += 1
            br.record_failure()
            raise ArtifactLoadError(
                f"loading artifact for tenant {tenant!r} failed: "
                f"{type(e).__name__}: {e}") from e
        self.loads += 1
        entry = self._entries[tenant] = _Entry(
            tenant=tenant, obj=obj, nbytes=int(nbytes))
        return entry

    @contextlib.contextmanager
    def lease(self, tenant: str):
        """Pin the tenant's artifact for one bucket; yields the loaded obj.

        Raises :class:`TenantQuarantined` / :class:`ArtifactLoadError`
        (both carry ``shed_reason`` for the gateway's typed rejection).
        A load that succeeds counts toward closing a half-open breaker
        only when the caller also reports :meth:`record_success` after
        the bucket actually serves.
        """
        with self._lock:
            entry = self._get(tenant)
            entry.pins += 1
            # evict AFTER pinning: a freshly-loaded entry must not be the
            # LRU scan's own victim before its first bucket runs
            self._evict()
        try:
            yield entry.obj
        finally:
            with self._lock:
                entry.pins -= 1
                # identity check: after a swap() the tenant maps to the
                # NEW entry — a draining lease on the old version must
                # never delete its successor
                if (entry.pins == 0 and entry.evict_on_release
                        and self._entries.get(tenant) is entry):
                    del self._entries[tenant]
                    self.evictions += 1

    def swap(self, tenant: str, obj: object, nbytes: int) -> int:
        """Atomically promote ``obj`` as the tenant's serving artifact.

        The new entry is prepared (version = old + 1), the
        ``zoo.swap_abort`` fault site gets its shot (``@step`` gates on
        the tenant's trailing integer), and only then does a SINGLE dict
        assignment commit the promotion — there is no intermediate state
        in which a lease can observe a half-promoted object.  In-flight
        leases pinned to the old entry finish against the old object;
        admissions after the commit route to the new one.  An abort
        raises :class:`SwapAborted` and leaves the old entry serving,
        untouched.  Returns the committed version number.
        """
        with self._lock:
            old = self._entries.get(tenant)
            entry = _Entry(tenant=tenant, obj=obj, nbytes=int(nbytes),
                           version=(old.version + 1) if old else 1)
            try:
                faults.raise_if("zoo.swap_abort", step=_tenant_step(tenant))
            except Exception as e:
                self.swap_aborts += 1
                raise SwapAborted(
                    f"hot-swap for tenant {tenant!r} aborted before "
                    f"commit: {e}") from e
            self._entries[tenant] = entry         # the commit point
            self._entries.move_to_end(tenant)
            self.swaps += 1
            self._evict()
            return entry.version

    def version(self, tenant: str) -> Optional[int]:
        """Serving version of the tenant's entry (None when not loaded)."""
        with self._lock:
            entry = self._entries.get(tenant)
            return entry.version if entry else None

    def runner(self, serve: Callable) -> Callable:
        """Gateway-runner adapter: ``serve(obj, rows) -> preds`` under a
        lease, reporting success/fault to the tenant's breaker."""
        def run(tenant, rows):
            with self.lease(tenant) as obj:
                try:
                    preds = serve(obj, rows)
                except Exception:
                    self.record_fault(tenant)
                    raise
            self.record_success(tenant)
            return preds
        return run

    def health(self) -> dict:
        with self._lock:
            return dict(
                entries=sorted(self._entries),
                nbytes=self.nbytes, loads=self.loads,
                load_failures=self.load_failures,
                evictions=self.evictions,
                deferred_evictions=self.deferred_evictions,
                quarantine_rejections=self.quarantine_rejections,
                swaps=self.swaps,
                swap_aborts=self.swap_aborts,
                versions={t: e.version for t, e in self._entries.items()
                          if e.version > 1},
                breakers={t: dict(state=b.state, trips=b.trips,
                                  consecutive=b.consecutive)
                          for t, b in self.breakers.items()
                          if b.state != CLOSED or b.trips or b.consecutive},
            )
