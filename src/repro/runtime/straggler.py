"""Straggler detection for the synchronous step loop.

With SPMD collectives a slow host stalls everyone, so mitigation at this
layer is (a) detecting it fast and (b) keeping the input pipeline off the
critical path (data/loader.py prefetch).  The monitor keeps an EWMA of step
wall-times; steps slower than ``threshold x`` EWMA are flagged with the
step index so the launcher can correlate across hosts and evict/replace the
offender (the actual replacement is the cluster manager's job; elastic
restore in checkpoint/store.py handles the mesh change).

Flagged samples are EXCLUDED from the EWMA update: an outlier that feeds
back into the baseline inflates it, so a second straggler right behind the
first would compare against a poisoned mean and slip under the threshold.
The EWMA tracks the healthy-step distribution only.
"""

from __future__ import annotations

import time
from typing import List, Optional


class StragglerMonitor:
    def __init__(self, alpha: float = 0.1, threshold: float = 2.0, warmup: int = 5):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.ewma: Optional[float] = None
        self.n = 0
        self.events: List[dict] = []
        self._t0: Optional[float] = None

    def start_step(self) -> None:
        self._t0 = time.monotonic()

    def end_step(self, step: int) -> Optional[dict]:
        assert self._t0 is not None, "start_step not called"
        dt = time.monotonic() - self._t0
        self._t0 = None
        self.n += 1
        flagged = None
        if self.ewma is None:
            self.ewma = dt
        else:
            if self.n > self.warmup and dt > self.threshold * self.ewma:
                flagged = {"step": step, "seconds": dt, "ewma": self.ewma}
                self.events.append(flagged)
            if flagged is None:
                # outliers stay out of the baseline: folding a straggler in
                # would desensitize the very next detection
                self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return flagged
