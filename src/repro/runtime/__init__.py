from repro.runtime import faults  # noqa: F401
from repro.runtime.gateway import (BrownoutConfig, BrownoutController,  # noqa: F401
                                   Gateway, Response)
from repro.runtime.preemption import RESUME_EXIT_CODE, PreemptionHandler  # noqa: F401
from repro.runtime.straggler import StragglerMonitor  # noqa: F401
from repro.runtime.zoo import ArtifactZoo, TenantQuarantined  # noqa: F401
