from repro.runtime.preemption import PreemptionHandler  # noqa: F401
from repro.runtime.straggler import StragglerMonitor  # noqa: F401
