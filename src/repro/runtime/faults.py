"""Deterministic fault injection: the drill harness behind the runtime's
fault-tolerance claims.

Every recovery behavior in this repo (artifact-checksum rejection, the
serve engine degradation ladder, preemption-safe training, straggler
flagging, async-checkpoint error surfacing) is *drill-tested* by arming a
named fault site and asserting the runtime degrades the way it promises —
not merely asserted in a docstring.  Sites fire deterministically (no
randomness), so a failing drill reproduces exactly.

Arming
------
Set ``REPRO_FAULT_INJECT`` to a comma-separated list of entries::

    site[@step][:param][*count]

  * ``site``  — a registered site name (see ``SITES``).
  * ``@step`` — fire only when the call site passes that step/bucket index.
  * ``:param``— site-specific float (sleep seconds, byte offset, ...).
  * ``*count``— maximum number of firings (default: unlimited).

Examples::

    REPRO_FAULT_INJECT=kernel.factorized,kernel.sparse      # ladder drill
    REPRO_FAULT_INJECT=train.sigterm@7                      # preemption drill
    REPRO_FAULT_INJECT=serve.slow_bucket@3:0.5              # straggler drill
    REPRO_FAULT_INJECT=artifact.bitflip                     # bit-rot drill

In-process tests arm sites with the :func:`injected` context manager
instead of the environment variable.  With nothing armed every probe is a
dict miss — the harness costs nothing in production.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import signal
import time
from typing import List, Optional

ENV_VAR = "REPRO_FAULT_INJECT"

# Registry of injection sites: name -> (where it fires, what it simulates).
# Drills and README documentation are generated against this table; adding
# a site here is the contract that some recovery path is drilled for it.
SITES = {
    "kernel.factorized": "ops.tm_forward_factorized kernel launch — a "
                         "Mosaic lowering/compile failure of the two-level "
                         "factorized schedule kernel",
    "kernel.sparse": "ops.tm_forward_schedule kernel launch — a lowering "
                     "failure of the flat block-sparse chain kernel",
    "kernel.dense": "ops.tm_forward_packed fused kernel launch — a lowering "
                    "failure of the dense single-pass kernel",
    "serve.slow_bucket": "launch/serve.py bucket loop — a stalled bucket "
                         "(param = seconds of stall)",
    "train.sigterm": "core/train.fit + launch/train.py step boundary — "
                     "delivers SIGTERM to this process (preemption)",
    "train.slow_step": "training step loop — a straggling step "
                       "(param = seconds of stall)",
    "ckpt.write_fail": "checkpoint/store.save_checkpoint — a failed "
                       "checkpoint write (disk full / permission)",
    "artifact.bitflip": "compiler.CompiledTM.save — flips one byte of the "
                        "written artifact (bit-rot; param = byte offset)",
    "artifact.save_abort": "compiler.CompiledTM.save — dies after writing "
                           "the tmp file, before the atomic replace "
                           "(SIGTERM mid-save)",
    "gateway.queue_overflow": "runtime/gateway.py admission — forces the "
                              "bounded request queue to report full, so the "
                              "request is SHED with a typed queue_full "
                              "rejection (never silently dropped)",
    "gateway.drain_timeout": "runtime/gateway.py drain — forces the drain "
                             "timer to expire immediately, so still-queued "
                             "requests are rejected drain_timeout instead "
                             "of being flushed",
    "zoo.evict_inflight": "runtime/zoo.py eviction — forces the LRU scan to "
                          "target a PINNED (in-flight) artifact; the zoo "
                          "must defer the eviction until the lease drops, "
                          "never yank a bucket's model mid-run",
    "zoo.load_fail": "runtime/zoo.py artifact load — an I/O/validation "
                     "failure loading a tenant's artifact (@step gates on "
                     "the tenant's trailing integer, e.g. zoo.load_fail@2 "
                     "targets tenant 't2' only)",
    "zoo.swap_abort": "runtime/zoo.py hot-swap — dies after the candidate "
                      "entry is prepared, before the atomic commit; the "
                      "tenant must keep serving the OLD artifact intact "
                      "(@step gates on the tenant's trailing integer)",
    "online.rebuild_fail": "runtime/online.py incremental recompile — the "
                           "candidate rebuild blows up (OOM / lowering "
                           "failure); the updater must keep serving the "
                           "deployed artifact and retry at the next drift "
                           "check",
    "online.feedback_corrupt": "runtime/online.py feedback ingest — "
                               "corrupts a labeled feedback record before "
                               "validation (label out of range); the "
                               "updater must reject it, never train on it",
    "anytime.margin_corrupt": "compiler.CompiledTM.load — tampers the "
                              "anytime margin metadata after the checksum "
                              "passes (adversarial producer); "
                              "validate_artifact must reject the artifact, "
                              "never serve early-exit/budgeted answers "
                              "from skewed margins",
    "gateway.brownout_stuck": "runtime/gateway.py brownout controller — "
                              "pins the primary level-lowering path so the "
                              "controller stays at a degraded quality "
                              "level after pressure clears; the low-"
                              "pressure watchdog must force recovery to "
                              "exact serving",
}


class InjectedFault(RuntimeError):
    """Raised at an armed raise-type fault site."""


@dataclasses.dataclass
class FaultSpec:
    site: str
    step: Optional[int] = None    # fire only at this step/bucket index
    param: Optional[float] = None
    count: Optional[int] = None   # max firings; None = unlimited
    fired: int = 0


def parse_spec(spec: str) -> List[FaultSpec]:
    """Parse the ``REPRO_FAULT_INJECT`` grammar into FaultSpecs."""
    out: List[FaultSpec] = []
    for entry in spec.split(","):
        entry = entry.strip()
        if not entry:
            continue
        count = param = step = None
        if "*" in entry:
            entry, c = entry.rsplit("*", 1)
            count = int(c)
        if ":" in entry:
            entry, p = entry.split(":", 1)
            param = float(p)
        if "@" in entry:
            entry, s = entry.split("@", 1)
            step = int(s)
        if entry not in SITES:
            raise ValueError(
                f"unknown fault site {entry!r}; registered sites: "
                f"{sorted(SITES)}")
        out.append(FaultSpec(site=entry, step=step, param=param, count=count))
    return out


class FaultInjector:
    """Holds armed FaultSpecs and answers per-site probes."""

    def __init__(self, specs):
        self._specs = list(specs)

    @property
    def armed(self) -> bool:
        return bool(self._specs)

    def poll(self, site: str, step=None) -> Optional[FaultSpec]:
        """The armed spec for ``site`` (consuming one firing), else None."""
        for sp in self._specs:
            if sp.site != site:
                continue
            if sp.step is not None and (step is None or int(step) != sp.step):
                continue
            if sp.count is not None and sp.fired >= sp.count:
                continue
            sp.fired += 1
            return sp
        return None

    # -- standard actions ---------------------------------------------------
    def raise_if(self, site: str, step=None) -> None:
        if self.poll(site, step) is not None:
            at = f" (step {step})" if step is not None else ""
            raise InjectedFault(f"injected fault at {site}{at}")

    def sleep_if(self, site: str, step=None, default: float = 0.25) -> bool:
        sp = self.poll(site, step)
        if sp is None:
            return False
        time.sleep(sp.param if sp.param is not None else default)
        return True

    def sigterm_if(self, site: str, step=None) -> bool:
        sp = self.poll(site, step)
        if sp is None:
            return False
        os.kill(os.getpid(), signal.SIGTERM)
        return True

    def corrupt_if(self, site: str, path: str, step=None,
                   default_pos: Optional[int] = None) -> bool:
        """Flip one byte of ``path`` (XOR 0x40) at an armed site.

        The spec ``:param`` wins as the byte offset; otherwise the call
        site's ``default_pos`` (a position it knows holds real payload —
        e.g. inside a zip member's compressed data rather than redundant
        container metadata); otherwise the middle of the file.
        """
        sp = self.poll(site, step)
        if sp is None:
            return False
        with open(path, "r+b") as f:
            f.seek(0, os.SEEK_END)
            size = f.tell()
            if sp.param is not None:
                pos = int(sp.param)
            elif default_pos is not None:
                pos = int(default_pos)
            else:
                pos = size // 2
            pos = min(max(pos, 0), size - 1)
            f.seek(pos)
            b = f.read(1)
            f.seek(pos)
            f.write(bytes([b[0] ^ 0x40]))
        return True


_DISARMED = FaultInjector([])
_installed: Optional[FaultInjector] = None
_env_cache: tuple = (None, _DISARMED)


def get_injector() -> FaultInjector:
    """The active injector: in-process install > env var > disarmed.

    The env spec is re-read on every probe (cached per value) so a
    subprocess drill controls its sites purely through the environment;
    spec state (firing counts) persists across probes of the same spec.
    """
    if _installed is not None:
        return _installed
    spec = os.environ.get(ENV_VAR)
    if not spec:
        return _DISARMED
    global _env_cache
    if _env_cache[0] != spec:
        _env_cache = (spec, FaultInjector(parse_spec(spec)))
    return _env_cache[1]


@contextlib.contextmanager
def injected(spec: str):
    """Arm sites in-process (tests): ``with faults.injected("ckpt.write_fail"):``"""
    global _installed
    prev = _installed
    _installed = FaultInjector(parse_spec(spec))
    try:
        yield _installed
    finally:
        _installed = prev


# -- module-level conveniences (the call-site API) ---------------------------
def armed() -> bool:
    return get_injector().armed


def fire_if(site: str, step=None) -> bool:
    """True when ``site`` is armed (consumes one firing) — for call sites
    whose degraded behavior is a branch, not an exception/sleep/signal."""
    return get_injector().poll(site, step) is not None


def raise_if(site: str, step=None) -> None:
    get_injector().raise_if(site, step)


def sleep_if(site: str, step=None) -> bool:
    return get_injector().sleep_if(site, step)


def sigterm_if(site: str, step=None) -> bool:
    return get_injector().sigterm_if(site, step)


def corrupt_if(site: str, path: str, step=None, default_pos=None) -> bool:
    return get_injector().corrupt_if(site, path, step, default_pos=default_pos)
