"""Preemption-safe training: signal -> barrier -> checkpoint -> exit.

On TPU pods the maintenance system delivers SIGTERM ahead of eviction; the
handler flips a flag the step loop polls, so the loop checkpoints at the
next step boundary and exits with a dedicated code the launcher (or k8s
restart policy) recognizes as "resume me".

The handler is a good citizen in a process that already owns its signals
(the serving gateway wires SIGTERM to graceful drain): :meth:`install`
CHAINS to whatever handler was previously registered instead of silently
replacing it, and :meth:`uninstall` restores the previous handlers
exactly — so nested ``install()``/``uninstall()`` pairs (train loop
inside a serving process, tests inside pytest's own INT handling) unwind
like a stack.
"""

from __future__ import annotations

import signal
import sys
from typing import Callable, Dict, Optional

RESUME_EXIT_CODE = 42


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._preempted = False
        self._signals = signals
        self._installed = False
        self._previous: Dict[int, object] = {}

    def install(self) -> "PreemptionHandler":
        """Install, chaining to (not clobbering) any existing handlers.

        After our flag flips, the PREVIOUS handler still runs: a gateway
        drain wired to SIGTERM keeps draining, a nested outer
        PreemptionHandler still sees its own flag flip.  Idempotent —
        a second install() without uninstall() is a no-op.
        """
        if self._installed:
            return self

        def make_handler(prev):
            def handler(signum, frame):
                self._preempted = True
                if callable(prev):
                    prev(signum, frame)

            return handler

        for s in self._signals:
            try:
                prev = signal.getsignal(s)
                signal.signal(s, make_handler(prev))
            except ValueError:
                pass  # not main thread (tests)
            else:
                self._previous[s] = prev
        self._installed = True
        return self

    def uninstall(self) -> "PreemptionHandler":
        """Restore the handlers that were registered before install().

        Safe to call when never installed (no-op), and after uninstall
        a later install() chains afresh.
        """
        for s, prev in self._previous.items():
            try:
                signal.signal(s, prev)
            except (ValueError, TypeError):
                pass  # not main thread, or prev was SIG_IGN-as-int etc.
        self._previous = {}
        self._installed = False
        return self

    @property
    def preempted(self) -> bool:
        return self._preempted

    def trigger(self) -> None:  # tests / manual drills
        self._preempted = True

    def checkpoint_and_exit(self, save_fn: Callable[[], None]) -> None:
        save_fn()
        sys.exit(RESUME_EXIT_CODE)
