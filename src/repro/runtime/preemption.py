"""Preemption-safe training: signal -> barrier -> checkpoint -> exit.

On TPU pods the maintenance system delivers SIGTERM ahead of eviction; the
handler flips a flag the step loop polls, so the loop checkpoints at the
next step boundary and exits with a dedicated code the launcher (or k8s
restart policy) recognizes as "resume me".
"""

from __future__ import annotations

import signal
import sys
from typing import Callable, Optional

RESUME_EXIT_CODE = 42


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._preempted = False
        self._signals = signals
        self._installed = False

    def install(self) -> "PreemptionHandler":
        def handler(signum, frame):
            self._preempted = True

        for s in self._signals:
            try:
                signal.signal(s, handler)
            except ValueError:
                pass  # not main thread (tests)
        self._installed = True
        return self

    @property
    def preempted(self) -> bool:
        return self._preempted

    def trigger(self) -> None:  # tests / manual drills
        self._preempted = True

    def checkpoint_and_exit(self, save_fn: Callable[[], None]) -> None:
        save_fn()
        sys.exit(RESUME_EXIT_CODE)
