"""Roofline terms from a compiled dry-run cell (TPU v5e targets).

    compute term    t_comp = per_device_FLOPs / peak_FLOP/s
    memory term     t_mem  = per_device_HBM_bytes / HBM_bw
    collective term t_coll = per_device_collective_wire_bytes / link_bw

FLOPs/bytes come from launch/hlo_analysis.py (post-SPMD HLO, while-loop trip
counts resolved — see that module for why cost_analysis() alone is wrong for
scanned models).  MODEL_FLOPS uses the standard 6·N·D (train) / 2·N·D
(inference) convention with N_active for MoE.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.launch import hlo_analysis
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    # per-device quantities
    flops: float
    hbm_bytes: float
    coll_bytes: float
    coll_by_kind: dict
    t_comp: float
    t_mem: float
    t_coll: float
    bottleneck: str
    model_flops_global: float
    useful_flops_ratio: float
    # memory analysis (bytes per device)
    arg_bytes: int = 0
    temp_bytes: int = 0
    output_bytes: int = 0
    compile_seconds: float = 0.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def model_flops(cfg, shape_kind: str, global_batch: int, seq_len: int) -> float:
    """6·N·D train, 2·N·D prefill, 2·N·B decode (N_active for MoE)."""
    n = cfg.active_param_count()
    if shape_kind == "train":
        return 6.0 * n * global_batch * seq_len
    if shape_kind == "prefill":
        return 2.0 * n * global_batch * seq_len
    return 2.0 * n * global_batch          # decode: one token per sequence


def build_report(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    n_devices: int,
    hlo_text: str,
    model_flops_global: float,
    mem_analysis=None,
    compile_seconds: float = 0.0,
) -> RooflineReport:
    cost = hlo_analysis.analyze(hlo_text)
    t_comp = cost.flops / PEAK_FLOPS_BF16
    t_mem = cost.bytes / HBM_BW
    t_coll = cost.coll_bytes / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops_global / max(cost.flops * n_devices, 1.0)

    kw = {}
    if mem_analysis is not None:
        for field, attr in (
            ("arg_bytes", "argument_size_in_bytes"),
            ("temp_bytes", "temp_size_in_bytes"),
            ("output_bytes", "output_size_in_bytes"),
        ):
            kw[field] = int(getattr(mem_analysis, attr, 0) or 0)

    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        n_devices=n_devices,
        flops=cost.flops,
        hbm_bytes=cost.bytes,
        coll_bytes=cost.coll_bytes,
        coll_by_kind=dict(cost.coll_by_kind),
        t_comp=t_comp,
        t_mem=t_mem,
        t_coll=t_coll,
        bottleneck=bottleneck,
        model_flops_global=model_flops_global,
        useful_flops_ratio=useful,
        compile_seconds=compile_seconds,
        **kw,
    )
