import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
# The dry-run (and only the dry-run) fabricates 512 host devices so
# jax.make_mesh can build the production meshes; smoke tests and benches
# never import this module and see 1 device.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
#       --shape train_4k --mesh pod
#   PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.jsonl
#
# Each cell: jit(step).lower(**ShapeDtypeStructs) -> .compile() ->
# memory_analysis() + cost/collective roofline (launch/roofline.py).

if os.environ.get("REPRO_DRYRUN_DEVICES"):  # tests use a small device count
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"]
    )

import argparse
import functools
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import jax_compat
from repro.configs import ARCH_IDS, get_config
from repro.configs.matador_tm import TM_CONFIGS
from repro.launch import roofline, specs
from repro.launch.mesh import make_production_mesh
from repro.models import sharding as shd
from repro.models import steps, transformer
from repro.optim import adamw


def _mesh(name: str):
    if name == "multipod":
        return make_production_mesh(multi_pod=True)
    if name == "pod":
        return make_production_mesh(multi_pod=False)
    # "DxM" shorthand or "model=N" / "data=D,model=M" axis specs
    from repro.launch.mesh import parse_mesh_spec

    return parse_mesh_spec(name)


def _named(tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def lower_cell(arch: str, shape_name: str, mesh, *, smoke: bool = False):
    """Returns (lowered, model_flops_global). Raises on inapplicable cells."""
    if arch.startswith("tm-"):
        return _lower_tm_cell(arch, shape_name, mesh)

    if smoke:  # reduced config + shapes (subprocess sharding tests)
        from repro.configs import get_smoke_config
        import dataclasses as _dc

        cfg = get_smoke_config(arch)
        sp = specs.SHAPES[shape_name]
        sp = _dc.replace(
            sp, seq_len=min(sp.seq_len, 128), global_batch=min(sp.global_batch, 16)
        )
        specs.SHAPES[shape_name + "|smoke"] = sp
        shape_name = shape_name + "|smoke"
    else:
        cfg = get_config(arch)
        sp = specs.SHAPES[shape_name]
    if not specs.cell_is_runnable(cfg, shape_name):
        raise SkipCell(
            f"{arch} is full-attention; long_500k requires sub-quadratic "
            "attention (skip noted in DESIGN.md §7)"
        )
    if getattr(sp, "layout", "tp") == "dp" and cfg.param_count() >= 1e10:
        raise SkipCell(
            "pure-DP layout is for <10B-param archs (weights are gathered "
            "per use; large models need TP/EP)"
        )
    batch = specs.input_specs(cfg, shape_name)
    p_struct = specs.params_struct(cfg)
    mf = roofline.model_flops(cfg, sp.kind, sp.global_batch, sp.seq_len)

    if sp.kind == "train":
        pure_dp = getattr(sp, "layout", "tp") == "dp"
        p_specs = shd.param_specs(cfg, p_struct, mesh, train=True, pure_dp=pure_dp)
        o_struct = jax.eval_shape(adamw.adamw_init, p_struct)
        o_specs = adamw.OptState(m=p_specs, v=p_specs, step=P())
        b_specs = shd.batch_specs(cfg, batch, mesh, pure_dp=pure_dp)
        # 200B+ models need gradient accumulation to fit activations in HBM
        n_micro = 4 if cfg.param_count() > 5e10 else 1
        step = steps.make_train_step(
            cfg, mesh, microbatches=n_micro, pure_dp=pure_dp
        )
        jitted = jax.jit(
            step,
            in_shardings=(
                _named(p_specs, mesh), _named(o_specs, mesh), _named(b_specs, mesh),
            ),
            out_shardings=(_named(p_specs, mesh), _named(o_specs, mesh), None),
            donate_argnums=(0, 1),
        )
        return jitted.lower(p_struct, o_struct, batch), mf

    p_specs = shd.param_specs(cfg, p_struct, mesh, train=False)
    c_struct = specs.cache_specs_struct(cfg, shape_name)
    c_specs = shd.cache_specs(cfg, c_struct, mesh)
    if sp.kind == "prefill":
        b_specs = shd.batch_specs(cfg, batch, mesh)
        step = steps.make_prefill_step(cfg, mesh)
        jitted = jax.jit(
            step,
            in_shardings=(
                _named(p_specs, mesh), _named(b_specs, mesh), _named(c_specs, mesh),
            ),
            out_shardings=(None, _named(c_specs, mesh)),
            donate_argnums=(2,),
        )
        return jitted.lower(p_struct, batch, c_struct), mf

    # decode
    b_specs = shd.batch_specs(cfg, batch, mesh)
    step = steps.make_decode_step(cfg, mesh)
    jitted = jax.jit(
        step,
        in_shardings=(
            _named(p_specs, mesh), _named(c_specs, mesh), _named(b_specs, mesh), None,
        ),
        out_shardings=(None, _named(c_specs, mesh)),
        donate_argnums=(1,),
    )
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return jitted.lower(p_struct, c_struct, batch, pos), mf


class SkipCell(Exception):
    pass


# ---------------------------------------------------------------------------
# TM (the paper's own model) cells
# ---------------------------------------------------------------------------

TM_SHAPES = {
    "tm_train": dict(batch=8192, kind="train"),
    "tm_train_matmul": dict(batch=8192, kind="train", algorithm="matmul"),
    "tm_train_fused": dict(batch=8192, kind="train", engine="kernel"),
    "tm_infer": dict(batch=65536, kind="infer"),
    "tm_infer_fused": dict(batch=65536, kind="infer", engine="kernel"),
}


def _lower_tm_cell(arch: str, shape_name: str, mesh):
    from repro.core import packetizer, sharding as tm_shd, tm

    config = TM_CONFIGS[arch]
    spec = TM_SHAPES[shape_name]
    B = spec["batch"]
    C, L = config.n_clauses_total, config.n_literals
    W = packetizer.n_words(L)
    engine = spec.get("engine", "gspmd")
    # the *_fused cells lower the clause-sharded shard_map schedule with the
    # fused Pallas kernels as the per-shard body (interpret mode off-TPU)
    kernel_kw = dict(use_kernel=True) if engine == "kernel" else {}

    if spec["kind"] == "train":
        fn = tm_shd.sharded_train_step_fn(
            config, mesh, algorithm=spec.get("algorithm", "bitwise"),
            engine=engine, **kernel_kw,
        )
        args = (
            jax.ShapeDtypeStruct((C, L), jnp.int8),
            jax.ShapeDtypeStruct((B, config.n_features), jnp.uint8),
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.uint32),
        )
        # TM "model flops" analog: one bit-op per (sample, clause, literal)
        # pass for eval + feedback; report as equivalent MACs/2.
        mf = 2.0 * B * C * L
        return fn.lower(*args), mf

    fn = tm_shd.sharded_predict_fn(config, mesh, **kernel_kw)
    args = (
        jax.ShapeDtypeStruct((C, W), jnp.uint32),
        jax.ShapeDtypeStruct((C, config.n_classes), jnp.int32),
        jax.ShapeDtypeStruct((C,), jnp.uint8),
        jax.ShapeDtypeStruct((B, W), jnp.uint32),
    )
    mf = 2.0 * B * C * W
    return fn.lower(*args), mf


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_name: str, *, smoke: bool = False) -> dict:
    mesh = _mesh(mesh_name)
    t0 = time.time()
    lowered, mf = lower_cell(arch, shape_name, mesh, smoke=smoke)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = jax_compat.memory_analysis(compiled)
    report = roofline.build_report(
        arch=arch,
        shape=shape_name,
        mesh_name=mesh_name,
        n_devices=mesh.devices.size,
        hlo_text=compiled.as_text(),
        model_flops_global=mf,
        mem_analysis=mem,
        compile_seconds=t_compile,
    )
    rec = report.as_dict()
    rec["lower_seconds"] = t_lower
    ca = jax_compat.cost_analysis(compiled)
    rec["xla_cost_flops"] = float(ca.get("flops", 0.0)) if ca else 0.0
    return rec


def all_cells():
    for arch in ARCH_IDS:
        for shape_name in specs.SHAPES:
            yield arch, shape_name
    for arch in ("tm-mnist", "tm-edge-xl"):
        for shape_name in TM_SHAPES:
            yield arch, shape_name


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", help="pod | multipod | DxM")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--meshes", default="pod,multipod")
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs/shapes (sharding tests)")
    args = ap.parse_args(argv)

    cells = (
        [(a, s, m) for (a, s) in all_cells() for m in args.meshes.split(",")]
        if args.all
        else [(args.arch, args.shape, args.mesh)]
    )

    failures = 0
    for arch, shape_name, mesh_name in cells:
        try:
            rec = run_cell(arch, shape_name, mesh_name, smoke=args.smoke)
            status = "ok"
        except SkipCell as e:
            rec = {
                "arch": arch, "shape": shape_name, "mesh": mesh_name,
                "skipped": str(e),
            }
            status = "skip"
        except Exception as e:  # noqa: BLE001 — report and continue the sweep
            rec = {
                "arch": arch, "shape": shape_name, "mesh": mesh_name,
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc(limit=20),
            }
            status = "FAIL"
            failures += 1
        rec["status"] = status
        line = json.dumps(rec)
        if args.out:
            with open(args.out, "a") as f:
                f.write(line + "\n")
        brief = {
            k: rec.get(k)
            for k in (
                "arch", "shape", "mesh", "status", "bottleneck", "t_comp",
                "t_mem", "t_coll", "useful_flops_ratio", "temp_bytes",
                "compile_seconds", "error", "skipped",
            )
            if k in rec
        }
        print(json.dumps(brief), flush=True)
        if status == "ok":
            # the two artifacts the assignment names explicitly:
            print(f"  memory_analysis: args={rec['arg_bytes']:.3e} "
                  f"temp={rec['temp_bytes']:.3e} out={rec['output_bytes']:.3e} "
                  f"bytes/device", flush=True)
            print(f"  cost_analysis:   xla_flops={rec['xla_cost_flops']:.3e} "
                  f"(per-device, body-once) hlo_flops={rec['flops']:.3e} "
                  f"(trip-resolved)", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
