"""Post-SPMD HLO text analysis: per-device FLOPs, HBM bytes, collective bytes.

Why not ``compiled.cost_analysis()`` alone?  XLA's cost analysis counts each
``while`` body ONCE, ignoring ``known_trip_count`` — for scanned-layer models
that undercounts by the layer count.  This module parses the compiled HLO
text into computations, costs each op, and resolves the call graph with trip
multipliers:

  * ``dot``: 2 x result_elems x contraction_size (operand shapes resolved
    through a per-computation symbol table);
  * elementwise/copy ops: bytes = operands + result at the top level
    (fusion internals are free — the fusion op is costed at its boundary,
    except embedded dots, which are costed through the called computation);
  * ``while``: (body + condition) x known_trip_count;
  * collectives: result bytes x op-specific wire multiplier (ring algorithms)
    summed as *per-device bytes on the busiest link*.

Validated against a known scanned matmul in tests/test_hlo_analysis.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "e4m3": 1, "e5m2": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
)

# 1-flop-per-element ops we bother counting (the rest round to 0; dots
# dominate by orders of magnitude)
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "exponential",
    "tanh", "rsqrt", "sqrt", "log", "negate", "power", "compare", "select",
    "and", "or", "xor", "not", "convert", "floor", "clamp", "sine", "cosine",
    "logistic",
}


@dataclasses.dataclass
class Shape:
    dtype: str
    dims: Tuple[int, ...]
    tuple_elems: Optional[List["Shape"]] = None

    @property
    def n_elems(self) -> int:
        if self.tuple_elems is not None:
            return sum(t.n_elems for t in self.tuple_elems)
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def n_bytes(self) -> int:
        if self.tuple_elems is not None:
            return sum(t.n_bytes for t in self.tuple_elems)
        return self.n_elems * _DTYPE_BYTES.get(self.dtype, 4)


_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([^\]]*)\]")


def parse_shape(s: str) -> Shape:
    s = s.strip()
    if s.startswith("("):
        elems, depth, cur = [], 0, ""
        for ch in s[1:-1]:
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                depth -= 1
            if ch == "," and depth == 0:
                if cur.strip():
                    elems.append(parse_shape(cur))
                cur = ""
            else:
                cur += ch
        if cur.strip():
            elems.append(parse_shape(cur))
        return Shape("tuple", (), elems)
    m = _SHAPE_RE.match(s)
    if not m:
        return Shape("opaque", ())
    dtype, dims_s = m.groups()
    dims = tuple(
        int(d.replace("<=", "")) for d in dims_s.split(",") if d.strip()
    )
    return Shape(dtype, dims)


@dataclasses.dataclass
class Op:
    name: str
    shape: Shape
    opcode: str
    operands: List[str]
    attrs: str
    args_raw: str = ""


@dataclasses.dataclass
class Computation:
    name: str
    ops: Dict[str, Op]
    order: List[str]


_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<rest>.*)$"
)


def _split_type_op(rest: str) -> Optional[Tuple[str, str, str, str]]:
    """rest = 'TYPE opcode(args), attrs' -> (type, opcode, args, attrs)."""
    rest = rest.strip()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                type_s, tail = rest[: i + 1], rest[i + 1 :]
                break
        else:
            return None
    else:
        m = re.match(r"^([a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)", rest)
        if not m:
            return None
        type_s, tail = m.group(1), rest[m.end() :]
    tail = tail.strip()
    m = re.match(r"^([a-z0-9\-]+)\(", tail)
    if not m:
        return None
    opcode = m.group(1)
    depth, i = 0, m.end() - 1
    for j in range(i, len(tail)):
        depth += tail[j] == "("
        depth -= tail[j] == ")"
        if depth == 0:
            args, attrs = tail[i + 1 : j], tail[j + 1 :]
            return type_s, opcode, args, attrs
    return None


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        ls = line.strip()
        header = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{$", ls)
        if header and "=" not in ls.split("(")[0]:
            cur = Computation(header.group(2), {}, [])
            comps[cur.name] = cur
            if header.group(1):
                entry = cur.name
            continue
        if ls.startswith("}"):
            cur = None
            continue
        if cur is None or "=" not in ls:
            continue
        m = _OP_LINE.match(ls)
        if not m:
            continue
        parsed = _split_type_op(m.group("rest"))
        if parsed is None:
            continue
        type_s, opcode, args, attrs = parsed
        operands = re.findall(r"%([\w.\-]+)", args)
        op = Op(m.group("name"), parse_shape(type_s), opcode, operands, attrs, args)
        cur.ops[op.name] = op
        cur.order.append(op.name)
    return comps, entry


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)

    def __add__(self, o: "Cost") -> "Cost":
        kinds = dict(self.coll_by_kind)
        for k, v in o.coll_by_kind.items():
            kinds[k] = kinds.get(k, 0.0) + v
        return Cost(
            self.flops + o.flops, self.bytes + o.bytes,
            self.coll_bytes + o.coll_bytes, kinds,
        )

    def scaled(self, f: float) -> "Cost":
        return Cost(
            self.flops * f, self.bytes * f, self.coll_bytes * f,
            {k: v * f for k, v in self.coll_by_kind.items()},
        )


def _operand_shape(comp: Computation, name: str) -> Optional[Shape]:
    op = comp.ops.get(name)
    return op.shape if op else None


def _replica_group_size(attrs: str) -> int:
    # replica_groups=[32,16]<=[512] -> group size 16 (last dim);
    # replica_groups={{0,1},{2,3}} -> size of first group
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return 2


def _collective_wire_bytes(op: Op, comp: Computation) -> float:
    """Per-device bytes crossing links (ring algorithms)."""
    g = _replica_group_size(op.attrs)
    out_b = op.shape.n_bytes
    kind = op.opcode.replace("-start", "")
    if kind == "all-gather":
        return out_b * (g - 1) / max(g, 1)
    if kind == "all-reduce":
        return 2.0 * out_b * (g - 1) / max(g, 1)
    if kind == "reduce-scatter":
        return out_b * (g - 1)
    if kind == "all-to-all":
        return out_b * (g - 1) / max(g, 1)
    if kind == "collective-permute":
        return out_b
    return out_b


_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id",
}


def _dot_flops(op: Op, comp: Computation) -> float:
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    lhs = _operand_shape(comp, op.operands[0]) if op.operands else None
    contraction = 1
    if m and lhs is not None and lhs.dims:
        for d in m.group(1).split(","):
            if d.strip():
                contraction *= lhs.dims[int(d)]
    return 2.0 * op.shape.n_elems * contraction


def comp_multiplicities(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    """How many times each computation executes per ENTRY run (trip counts)."""
    mult: Dict[str, float] = {}

    def visit(name: str, m: float):
        comp = comps.get(name)
        if comp is None:
            return
        mult[name] = mult.get(name, 0.0) + m
        for op_name in comp.order:
            op = comp.ops[op_name]
            if op.opcode == "while":
                tm = re.search(r"known_trip_count[^0-9]*(\d+)", op.attrs)
                trip = int(tm.group(1)) if tm else 1
                for key in ("body", "condition"):
                    t = re.search(key + r"=%?([\w.\-]+)", op.attrs)
                    if t:
                        visit(t.group(1), m * trip)
            elif op.opcode in ("call", "conditional"):
                for t in re.findall(r"to_apply=%?([\w.\-]+)", op.attrs):
                    visit(t, m)

    visit(entry, 1.0)
    return mult


def contributions(text: str, top: int = 30):
    """Per-op HBM-bytes contributions (x execution multiplicity), sorted.

    Debug/profiling aid for the §Perf loop: shows where the memory term
    actually lives.
    """
    comps, entry = parse_hlo(text)
    full = analyze(text)  # reuses the cost model for fusion/boundary logic

    # rebuild per-op byte costs with multiplicities (mirror of analyze())
    mult = comp_multiplicities(comps, entry or "")
    walker = _Walker(comps)
    rows = []
    for cname, m in mult.items():
        comp = comps[cname]
        for op_name in comp.order:
            op = comp.ops[op_name]
            b = walker.op_bytes(comp, op)
            f = walker.op_flops(comp, op)
            if b or f:
                rows.append(
                    dict(comp=cname, op=op_name, opcode=op.opcode,
                         bytes=b * m, flops=f * m, mult=m)
                )
    rows.sort(key=lambda r: -r["bytes"])
    return rows[:top], full


class _Walker:
    """Per-op cost helpers shared by contributions() (mirrors analyze())."""

    def __init__(self, comps):
        self.comps = comps

    def op_flops(self, comp, op) -> float:
        if op.opcode == "dot":
            return _dot_flops(op, comp)
        if op.opcode in _ELEMENTWISE:
            return float(op.shape.n_elems)
        if op.opcode == "fusion":
            called = re.search(r"calls=%?([\w.\-]+)", op.attrs)
            if called and called.group(1) in self.comps:
                sub = self.comps[called.group(1)]
                return sum(self.op_flops(sub, sub.ops[o]) for o in sub.order)
        return 0.0

    def op_bytes(self, comp, op) -> float:
        if op.opcode in _FREE_OPS or op.opcode == "while":
            return 0.0
        if op.opcode == "fusion":
            called = re.search(r"calls=%?([\w.\-]+)", op.attrs)
            sub = self.comps.get(called.group(1)) if called else None
            return _fusion_bytes_standalone(comp, op, sub)
        return _io_bytes_standalone(comp, op)


def analyze(text: str) -> Cost:
    """Total per-device cost of the ENTRY computation (call graph resolved)."""
    comps, entry = parse_hlo(text)
    memo: Dict[str, Cost] = {}

    def comp_cost(name: str, flops_only: bool = False) -> Cost:
        key = name + ("|f" if flops_only else "")
        if key in memo:
            return memo[key]
        comp = comps.get(name)
        if comp is None:
            return Cost()
        total = Cost()
        memo[key] = total  # break cycles defensively
        for op_name in comp.order:
            op = comp.ops[op_name]
            c = Cost()
            if op.opcode in _FREE_OPS:
                pass
            elif op.opcode == "while":
                m = re.search(r'known_trip_count[^0-9]*(\d+)', op.attrs)
                trip = int(m.group(1)) if m else 1
                body = re.search(r"body=%?([\w.\-]+)", op.attrs)
                cond = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                if body:
                    c = c + comp_cost(body.group(1), flops_only).scaled(trip)
                if cond:
                    c = c + comp_cost(cond.group(1), flops_only).scaled(trip)
            elif op.opcode == "fusion":
                called = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                if called:
                    c = c + Cost(flops=comp_cost(called.group(1), True).flops)
                    if not flops_only:
                        c.bytes += _fusion_bytes(comp, op, comps.get(called.group(1)))
                elif not flops_only:
                    c.bytes += _io_bytes(comp, op)
            elif op.opcode in ("call", "conditional", "async-start", "async-done"):
                for target in re.findall(
                    r"(?:to_apply|called_computations=\{|branch_computations=\{)=?%?([\w.\-]+)",
                    op.attrs,
                ):
                    c = c + comp_cost(target, flops_only)
                if not flops_only:
                    c.bytes += _io_bytes(comp, op)
            elif op.opcode.startswith(_COLLECTIVES) or op.opcode in _COLLECTIVES:
                if not flops_only:
                    wire = _collective_wire_bytes(op, comp)
                    c.coll_bytes += wire
                    kind = op.opcode.replace("-start", "")
                    c.coll_by_kind = {kind: wire}
                    c.bytes += _io_bytes(comp, op)
            else:
                if op.opcode == "dot":
                    c.flops += _dot_flops(op, comp)
                elif op.opcode == "convolution":
                    c.flops += 2.0 * op.shape.n_elems  # not used by our models
                elif op.opcode in _ELEMENTWISE:
                    c.flops += op.shape.n_elems
                if not flops_only:
                    c.bytes += _io_bytes(comp, op)
            total = total + c
        memo[key] = total
        return total

    def _fusion_bytes(comp: Computation, op: Op, called: Optional[Computation]) -> float:
        return _fusion_bytes_standalone(comp, op, called)

    def _io_bytes(comp: Computation, op: Op) -> float:
        return _io_bytes_standalone(comp, op)

    return comp_cost(entry or "", False)


def _fusion_bytes_standalone(
    comp: Computation, op: Op, called: Optional[Computation]
) -> float:
    """HBM traffic of a fusion op, resolved through its interior.

    A fusion parameter consumed *only* by dynamic-slice reads just the
    slices (scanned-layer weight lookup); a root dynamic-update-slice
    writes just the update region (in-place aliasing).  Everything else
    is counted at the boundary.
    """
    if called is None:
        return _io_bytes_standalone(comp, op)
    # map parameter index -> name, and follow bitcast aliases
    params = {}
    alias = {}
    for o in called.ops.values():
        if o.opcode == "parameter":
            try:
                params[int(o.args_raw.strip())] = o.name
            except ValueError:
                pass
        if o.opcode in ("bitcast", "reshape", "copy") and o.operands:
            alias[o.name] = o.operands[0]

    def root_name(n):
        seen = set()
        while n in alias and n not in seen:
            seen.add(n)
            n = alias[n]
        return n

    uses: Dict[str, List[Op]] = {}
    for o in called.ops.values():
        for src in o.operands:
            uses.setdefault(root_name(src), []).append(o)

    b = 0.0
    for i, operand in enumerate(op.operands):
        oshape = _operand_shape(comp, operand)
        full = oshape.n_bytes if oshape else 0.0
        pname = params.get(i)
        if pname is None:
            b += full
            continue
        pus = [
            u for u in uses.get(pname, [])
            if u.opcode not in ("bitcast", "reshape", "copy")
        ]
        if pus and all(
            u.opcode == "dynamic-slice" and root_name(u.operands[0]) == pname
            for u in pus
        ):
            b += sum(u.shape.n_bytes for u in pus)
        else:
            b += full
    root = called.ops.get(called.order[-1]) if called.order else None
    if root is not None and root.opcode == "dynamic-update-slice":
        upd = (
            called.ops.get(root_name(root.operands[1]))
            if len(root.operands) > 1
            else None
        )
        b += 2.0 * (upd.shape.n_bytes if upd else root.shape.n_bytes)
    else:
        b += op.shape.n_bytes
    return b


def _io_bytes_standalone(comp: Computation, op: Op) -> float:
    # Sliced/in-place ops move only the touched region, not the buffer:
    # while-loop carries alias in place (XLA buffer donation), so counting
    # full operands would scale O(layers^2) for scanned models.
    if op.opcode == "dynamic-slice":
        return 2.0 * op.shape.n_bytes          # read slice + write result
    if op.opcode == "dynamic-update-slice":
        upd = (
            _operand_shape(comp, op.operands[1])
            if len(op.operands) > 1
            else None
        )
        return 2.0 * (upd.n_bytes if upd else op.shape.n_bytes)
    if op.opcode == "gather":
        return 2.0 * op.shape.n_bytes
    if op.opcode == "scatter":
        upd = (
            _operand_shape(comp, op.operands[2])
            if len(op.operands) > 2
            else None
        )
        return 2.0 * (upd.n_bytes if upd else op.shape.n_bytes)
    b = op.shape.n_bytes
    for o in op.operands:
        s = _operand_shape(comp, o)
        if s is not None:
            b += s.n_bytes
    return b
