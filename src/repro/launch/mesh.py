"""Production meshes.

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax init).

Topology (TPU v5e pods): a pod is a 16x16 chip slice; the single-pod mesh is
(data=16, model=16); the multi-pod mesh adds a leading ``pod`` axis over the
DCN/ICI-linked second pod: (pod=2, data=16, model=16) = 512 chips.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4):
    """Small mesh over host devices for tests (requires forced device count)."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~4 links/chip; we use 1,
                                # i.e. the conservative per-collective figure)
