"""Production meshes.

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax init).

Topology (TPU v5e pods): a pod is a 16x16 chip slice; the single-pod mesh is
(data=16, model=16); the multi-pod mesh adds a leading ``pod`` axis over the
DCN/ICI-linked second pod: (pod=2, data=16, model=16) = 512 chips.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4):
    """Small mesh over host devices for tests (requires forced device count)."""
    return jax.make_mesh((data, model), ("data", "model"))


def parse_mesh_spec(spec: str):
    """CLI ``--mesh`` spec -> Mesh.

    Accepts ``model=N``, ``data=D,model=M``, ``pod=P,data=D,model=M`` (axis
    order is canonicalised to pod, data, model) and the dry-run's bare
    ``DxM`` shorthand for ``data=D,model=M``.  Raises a clear error when the
    host doesn't expose enough devices (on CPU set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax
    initialises).
    """
    spec = spec.strip()

    def _bad():
        return ValueError(
            f"bad --mesh spec {spec!r}: expected e.g. 'model=4', "
            "'data=2,model=4', or 'DxM' (axes: pod, data, model; "
            "'model' is required — it is the clause-shard axis)"
        )

    if "=" not in spec and "x" in spec:
        parts = spec.split("x")
        if len(parts) != 2 or not all(p.strip().isdigit() for p in parts):
            raise _bad()
        axes = {"data": int(parts[0]), "model": int(parts[1])}
    else:
        axes = {}
        for part in spec.split(","):
            k, _, v = part.partition("=")
            k = k.strip()
            if k not in ("pod", "data", "model") or not v.strip().isdigit():
                raise _bad()
            axes[k] = int(v)
    if "model" not in axes or any(v < 1 for v in axes.values()):
        raise _bad()
    names = tuple(k for k in ("pod", "data", "model") if k in axes)
    shape = tuple(axes[k] for k in names)
    need = 1
    for s in shape:
        need *= s
    have = len(jax.devices())
    if need > have:
        raise ValueError(
            f"--mesh {spec!r} needs {need} devices but only {have} visible; "
            "on CPU export XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{need} before running"
        )
    return jax.make_mesh(shape, names)


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~4 links/chip; we use 1,
                                # i.e. the conservative per-collective figure)
