"""Serving driver: batched TM inference (the paper's accelerator loop) and
LM prefill+decode.

    PYTHONPATH=src python -m repro.launch.serve --arch tm-mnist --requests 4096
    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke

The TM path mirrors the MATADOR runtime: train -> compile (compiler.py) ->
packetize requests -> stream through the clause-eval datapath -> argmax,
reporting throughput the way the paper's jupyter flow does.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def serve_tm(args) -> None:
    from repro.configs.matador_tm import TM_CONFIGS
    from repro.core import compiler, packetizer, tm, train
    from repro.data import make_boolean_classification

    config = TM_CONFIGS[args.arch]
    X, y = make_boolean_classification(
        args.n_train, config.n_features, config.n_classes, seed=0
    )
    state = tm.init(config, jax.random.PRNGKey(0))
    state = train.fit(
        config, state, jnp.asarray(X), jnp.asarray(y),
        epochs=args.epochs, batch_size=64, rng=jax.random.PRNGKey(1),
    )
    compiled = compiler.compile_tm(config, state.ta_state)
    print("compile stats:", compiled.stats.as_dict())

    Xr, _ = make_boolean_classification(
        args.requests, config.n_features, config.n_classes, seed=2
    )
    xp = packetizer.pack_literals(jnp.asarray(Xr))
    run = jax.jit(lambda xw: compiler.run_compiled(compiled, xw).argmax(-1))
    run(xp[:8]).block_until_ready()            # warm
    t0 = time.perf_counter()
    preds = run(xp).block_until_ready()
    dt = time.perf_counter() - t0
    print(f"{args.requests} inferences in {dt * 1e3:.2f} ms "
          f"({args.requests / dt:,.0f} inf/s, {dt / args.requests * 1e6:.2f} us/inf)")
    acc = float((np.asarray(preds) == 0).mean())  # placeholder label-free run
    _ = acc


def serve_lm(args) -> None:
    from repro.configs import get_config, get_smoke_config
    from repro.models import steps, transformer

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    B, S_max = args.batch_size, args.seq_len
    caches = transformer.init_caches(cfg, B, S_max)
    prefill = jax.jit(steps.make_prefill_step(cfg))
    decode = jax.jit(steps.make_decode_step(cfg))

    nprng = np.random.default_rng(0)
    prompt_len = S_max // 2
    if cfg.frontend == "audio_stub":
        batch = {"embeds": jnp.asarray(
            nprng.normal(size=(B, prompt_len, cfg.d_model)), jnp.float32)}
        mk_inp = lambda tok: {"embeds": jnp.zeros((B, 1, cfg.d_model), jnp.float32)}
    else:
        batch = {"tokens": jnp.asarray(
            nprng.integers(0, cfg.vocab_size, (B, prompt_len)), jnp.int32)}
        mk_inp = lambda tok: {"tokens": tok}

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch, caches)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0
    tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)[:, None]

    n_new = args.new_tokens
    t0 = time.perf_counter()
    for i in range(n_new):
        logits, caches = decode(params, caches, mk_inp(tok), jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)[:, None]
    tok.block_until_ready()
    t_decode = time.perf_counter() - t0
    print(f"prefill {prompt_len} tok x {B}: {t_prefill * 1e3:.1f} ms; "
          f"decode {n_new} steps: {t_decode / n_new * 1e3:.2f} ms/step "
          f"({B * n_new / t_decode:,.0f} tok/s)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4096)
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--n-train", type=int, default=2000)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.arch.startswith("tm-"):
        serve_tm(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
