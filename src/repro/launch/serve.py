"""Serving driver: batched TM inference (the paper's accelerator loop) and
LM prefill+decode.

    PYTHONPATH=src python -m repro.launch.serve --arch tm-mnist --requests 4096
    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke

The TM path mirrors the MATADOR runtime: train -> compile (compiler.py) ->
packetize requests -> stream through the clause-eval datapath -> argmax,
reporting throughput the way the paper's jupyter flow does.
"""

from __future__ import annotations

import argparse
import asyncio
import itertools
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np


def serve_tm(args) -> None:
    """Chunked streaming TM serve loop with an engine degradation ladder.

    Requests stream through fixed-size buckets of ``--bucket`` datapoints:
    one jit trace (bucket-shaped input, donated on accelerators) serves any
    request count — the last bucket is zero-padded, never retraced.  With
    the kernel path active (``REPRO_USE_PALLAS=1`` / TPU) each bucket runs
    the schedule/fused kernels; ``--autotune`` picks block sizes via
    ``kernels/autotune.tune`` under ``--tune-policy``: ``predict`` trusts
    the analytical cost model (zero timing runs — the zoo cold-start
    mode), ``verify`` (default) wall-clocks only the model's top-3
    shortlist, ``sweep`` times the full candidate grid (and feeds the
    model's training-data sidecar).

    **Fault tolerance** — each bucket runs through an
    ``ops.EngineLadder`` (factorized -> sparse -> dense-fused -> XLA
    oracle; a ``--mesh`` engine sits on top and degrades to the unsharded
    ladder): a guarded warm probe catches kernel/lowering failures before
    the request stream starts, any per-bucket failure demotes one engine
    and retries that bucket, and ``--bucket-deadline N`` additionally
    demotes when a bucket runs longer than ``N x`` the ``StragglerMonitor``
    EWMA of bucket wall-times.  ``--promote-after N`` adds the
    re-promotion path: after N healthy buckets the ladder probes one level
    up.  The run ends with a machine-readable ``SERVE_HEALTH`` JSON line
    reporting which engine served each bucket, every demotion/promotion,
    and straggler flags.

    **Gateway** — requests flow through the resilient async gateway
    (``runtime/gateway.py``): continuous per-tenant batching with
    age-based partial flushes (``--max-wait-ms``), bounded-queue admission
    control (``--max-queue``), per-request deadlines (``--deadline-ms``),
    and graceful drain on SIGTERM (``--drain-timeout``) — every offered
    request is answered or shed with a typed reason, and the final
    ``GATEWAY_HEALTH`` JSON line proves it (``unaccounted == 0`` or the
    process exits non-zero).  ``--zoo N`` serves N round-robin tenants
    through the artifact zoo (``runtime/zoo.py``): per-tenant circuit
    breakers and an LRU-capped artifact cache.  Buckets still execute one
    at a time (a single executor thread) so failures and deadlines
    attribute to the bucket that caused them.

    **Anytime / brownout** — ``--early-exit`` serves exact buckets
    through the in-kernel certified early-exit path (bit-identical
    argmax, tiles skipped once the artifact's margin metadata proves the
    leader unassailable).  ``--brownout`` arms the gateway's
    :class:`~repro.runtime.gateway.BrownoutController`: under overload,
    buckets on the schedule engines run budgeted prefix inference at the
    controller's quality level and each degraded answer carries its
    concrete vote-margin error bound.  The dense/oracle engines (and the
    zoo/online tenant paths, whose runner protocol is exact-only) keep
    serving exact — serving better than requested is always allowed.
    ``SERVE_HEALTH``/``GATEWAY_HEALTH`` report the quality-tier
    distribution.
    """
    import json
    import os

    from repro.configs.matador_tm import TM_CONFIGS
    from repro.core import compiler, packetizer, tm, train
    from repro.data import make_boolean_classification
    from repro.kernels import ops
    from repro.runtime import StragglerMonitor, faults

    config = TM_CONFIGS[args.arch]
    if args.artifact and not args.artifact.endswith(".npz"):
        # np.savez_compressed appends .npz — normalize up front so the
        # load check looks for the file save() actually wrote
        args.artifact += ".npz"
    trained_this_run = False
    state = None
    if args.online and args.artifact and os.path.exists(args.artifact):
        # the online updater trains a LIVE bank next to serving; a loaded
        # artifact has no automata to train, so --online always takes the
        # train path (the artifact is rewritten at exit as usual)
        print(f"--online: training a live bank (artifact {args.artifact} "
              "will be refreshed at exit)")
    if args.artifact and os.path.exists(args.artifact) and not args.online:
        # cold-start fast path: the artifact ships its execution schedules
        # AND the tilings recorded by a previous --autotune run, so neither
        # the training loop nor the sweep is re-paid.  load() verifies
        # schema, checksum, and schedule invariants — a corrupt or stale
        # artifact is rejected here instead of serving wrong predictions.
        try:
            compiled = compiler.CompiledTM.load(args.artifact)
        except compiler.ArtifactError as e:
            raise SystemExit(f"refusing to serve: {e}")
        if (compiled.n_features != config.n_features
                or compiled.n_classes != config.n_classes):
            # a mismatched artifact would serve silently wrong predictions
            # (out-of-range word gathers clamp instead of failing)
            raise SystemExit(
                f"artifact {args.artifact} was compiled for "
                f"F={compiled.n_features}/K={compiled.n_classes}, but "
                f"--arch {args.arch} is F={config.n_features}/"
                f"K={config.n_classes}")
        print(f"loaded artifact {args.artifact} "
              f"(U={compiled.n_unique}, tuned={sorted(compiled.tuned)})")
    else:
        X, y = make_boolean_classification(
            args.n_train, config.n_features, config.n_classes, seed=0
        )
        state = tm.init(config, jax.random.PRNGKey(0))
        state = train.fit(
            config, state, jnp.asarray(X), jnp.asarray(y),
            epochs=args.epochs, batch_size=64, rng=jax.random.PRNGKey(1),
        )
        compiled = compiler.compile_tm(config, state.ta_state)
        trained_this_run = True
    tuned_at_start = dict(compiled.tuned)
    print("compile stats:", compiled.stats.as_dict())
    if args.online and args.mesh:
        raise SystemExit("--online hot-swaps the unsharded engine ladder; "
                         "combine it with --mesh once the sharded builders "
                         "read the swapped artifact")
    # the serving artifact, as a mutable cell: the online updater promotes
    # a successor by updating this and rebinding the ladder (built engines
    # closed over the old artifact's schedules are discarded lazily)
    current = {"compiled": compiled}

    bucket = args.bucket
    use_kernel, interpret = ops.kernel_dispatch()
    # kernel-path default: the chain-schedule kernels (work scales with the
    # artifact's include structure); --no-sparse pins the dense kernel.
    # Within the schedule path the FACTORIZED kernel serves when the
    # artifact's measured term sharing clears the compile-time threshold
    # (shared AND terms evaluated once per bucket); --no-factorize pins
    # the flat bit-chain kernel, --factorize pins the factorized one
    # regardless of the measured sharing.
    if args.factorize and args.no_factorize:
        raise SystemExit("--factorize and --no-factorize are exclusive")
    sparse = use_kernel and not args.no_sparse
    factorize = sparse and not args.no_factorize and (
        args.factorize
        or compiled.stats.partial_term_sharing
        >= compiler.FACTORIZE_SHARING_THRESHOLD
    )

    def tuned_blocks(n_clauses):
        # autotune the shape the kernel ACTUALLY runs: per-shard C_loc on a
        # mesh, the whole unique bank otherwise
        if not (use_kernel and args.autotune):
            return {}
        from repro.kernels import autotune

        blocks = autotune.tune(
            "fused_infer", B=bucket, C=n_clauses,
            W=compiled.n_words_active, K=compiled.n_classes,
            interpret=interpret, policy=args.tune_policy,
        )
        print(f"autotuned dense blocks (C={n_clauses}, "
              f"policy={args.tune_policy}):", blocks)
        return blocks

    def _tuned_ctx(inc_rows):
        # recorded tunings are keyed by (bucket, swept rows, backend/mode):
        # a mesh run tunes a per-shard SLICE and an interpret-mode tiling
        # must not answer for a compiled server
        from repro.kernels import autotune

        return dict(rows=inc_rows.shape[0],
                    mode=autotune._mode_backend(interpret))

    def tuned_sparse_blocks(inc_rows):
        # the schedule tiling is swept on the rows the shard actually
        # serves, under sparse_infer: cache keys (artifact-hashed); an
        # artifact-recorded tiling (save()d by a previous run) short-
        # circuits the sweep on cold starts
        if not (use_kernel and args.autotune):
            return {}
        ctx = _tuned_ctx(inc_rows)
        recorded = compiled.tuned_blocks("sparse_infer", bucket, **ctx)
        if recorded is not None:
            print("artifact-recorded sparse blocks:", recorded)
            return recorded
        from repro.kernels import autotune

        blocks = autotune.tune(
            "sparse_infer", B=bucket, K=compiled.n_classes,
            include_words=inc_rows, interpret=interpret,
            policy=args.tune_policy, features=compiled.features or None,
        )
        if args.tune_policy != "predict":
            # measured tilings persist with the artifact; predictions are
            # re-derived in microseconds and must not masquerade as sweeps
            compiled.record_tuned("sparse_infer", bucket, blocks, **ctx)
        print(f"autotuned sparse blocks (U={inc_rows.shape[0]}, "
              f"policy={args.tune_policy}):", blocks)
        return blocks

    def tuned_factorized_blocks(inc_rows):
        # term_infer: cache keys are artifact-hashed too (the stage-1/2
        # work split is a property of the trained include structure)
        if not (use_kernel and args.autotune):
            return {}
        ctx = _tuned_ctx(inc_rows)
        recorded = compiled.tuned_blocks("term_infer", bucket, **ctx)
        if recorded is not None:
            print("artifact-recorded factorized blocks:", recorded)
            return recorded
        from repro.kernels import autotune

        blocks = autotune.tune(
            "term_infer", B=bucket, K=compiled.n_classes,
            include_words=inc_rows, interpret=interpret,
            policy=args.tune_policy, features=compiled.features or None,
        )
        if args.tune_policy != "predict":
            compiled.record_tuned("term_infer", bucket, blocks, **ctx)
        print(f"autotuned factorized blocks (U={inc_rows.shape[0]}, "
              f"policy={args.tune_policy}):", blocks)
        return blocks

    # donation recycles each bucket's literal buffer on accelerators
    donate = (0,) if jax.default_backend() != "cpu" else ()
    word_ids = jnp.asarray(compiled.word_ids)

    def build_mesh():
        # clause-sharded serve: the compiled artifact's unique-clause bank
        # splits over `model` (banks bigger than one core's VMEM), each
        # shard runs the fused kernel on its local bank — carrying its own
        # block-sparse tile table on the sparse path — and one (B, K)
        # class-sum psum completes the adder bank; requests shard over the
        # data axes.
        from repro.core import sharding as tm_sharding
        from repro.launch.mesh import parse_mesh_spec

        mesh = parse_mesh_spec(args.mesh)
        n_model = mesh.shape["model"]
        U = compiled.n_unique
        if args.autotune:
            # ROADMAP "Next": seed the per-shard C_loc cache entries for
            # ALL kernels so later mesh runs skip the sweeps
            tuned_blocks(-(-U // n_model))
        if factorize:
            from repro.kernels import sparse_infer, term_infer

            C_loc_est = sparse_infer._rup(-(-max(U, 1) // n_model), 8)
            fblocks = tuned_factorized_blocks(
                np.ascontiguousarray(compiled.include_words[:C_loc_est]))
            schedules, term_stack, chain_stack, votes_stack, tile_stack, \
                C_loc = term_infer.stack_shard_factorized(
                    compiled.include_words, compiled.votes, n_model,
                    block_c=fblocks.get(
                        "block_c", term_infer.DEFAULT_BLOCK_C),
                    block_j=fblocks.get(
                        "block_j", term_infer.DEFAULT_BLOCK_J),
                    block_t=fblocks.get(
                        "block_t", term_infer.DEFAULT_BLOCK_T),
                    term_w=fblocks.get("term_w"),
                )
            fwd = tm_sharding.sharded_factorized_forward_fn(
                mesh,
                block_t=schedules[0].block_t,
                block_c=schedules[0].block_c, block_j=schedules[0].block_j,
                block_s=fblocks.get("block_s"),
            )
            terms_sh = jnp.asarray(term_stack)
            chains = jnp.asarray(chain_stack)
            votes_sh = jnp.asarray(votes_stack)
            tiles = jnp.asarray(tile_stack)
            print(f"mesh {dict(mesh.shape)}: {C_loc * n_model} unique "
                  f"clauses sharded over model={n_model} ({C_loc}/shard, "
                  f"{tile_stack.shape[-1]} tiles/shard, "
                  f"{term_stack.shape[1]} term rows/shard)")
            run_bucket = jax.jit(
                lambda xw: fwd(terms_sh, chains, votes_sh, tiles,
                               xw[:, word_ids]).argmax(-1),
                donate_argnums=donate,
            )
        elif sparse:
            from repro.kernels import sparse_infer

            C_loc_est = sparse_infer._rup(-(-max(U, 1) // n_model), 8)
            sblocks = tuned_sparse_blocks(
                np.ascontiguousarray(compiled.include_words[:C_loc_est]))
            schedules, chain_stack, votes_stack, tile_stack, C_loc = (
                sparse_infer.stack_shard_schedules(
                    compiled.include_words, compiled.votes, n_model,
                    block_c=sblocks.get(
                        "block_c", sparse_infer.DEFAULT_BLOCK_C),
                    block_j=sblocks.get(
                        "block_j", sparse_infer.DEFAULT_BLOCK_J),
                ))
            fwd = tm_sharding.sharded_schedule_forward_fn(
                mesh,
                block_c=schedules[0].block_c, block_j=schedules[0].block_j,
                block_s=sblocks.get("block_s"),
            )
            chains = jnp.asarray(chain_stack)
            votes_sh = jnp.asarray(votes_stack)
            tiles = jnp.asarray(tile_stack)
            print(f"mesh {dict(mesh.shape)}: {C_loc * n_model} unique "
                  f"clauses sharded over model={n_model} ({C_loc}/shard, "
                  f"{tile_stack.shape[-1]} chain tiles/shard)")
            run_bucket = jax.jit(
                lambda xw: fwd(chains, votes_sh, tiles,
                               xw[:, word_ids]).argmax(-1),
                donate_argnums=donate,
            )
        else:
            Up = -(-U // n_model) * n_model
            blocks = tuned_blocks(Up // n_model)
            # zero include words never violate -> padded clauses fire but
            # carry zero votes, so the class sums are unchanged.
            inc_sh = jnp.asarray(np.pad(compiled.include_words,
                                        ((0, Up - U), (0, 0))))
            votes_sh = jnp.asarray(np.pad(compiled.votes,
                                          ((0, Up - U), (0, 0))))
            ne_sh = jnp.asarray(np.ones((Up,), np.uint8))
            fwd = tm_sharding.sharded_forward_fn(mesh, blocks=blocks or None)
            print(f"mesh {dict(mesh.shape)}: {Up} unique clauses sharded "
                  f"over model={n_model} ({Up // n_model}/shard)")

            # same jit + donation shape as the unsharded path: the
            # dead-word slice and argmax fuse into one dispatch per bucket
            run_bucket = jax.jit(
                lambda xw: fwd(inc_sh, votes_sh, ne_sh,
                               xw[:, word_ids]).argmax(-1),
                donate_argnums=donate,
            )
        return run_bucket

    # anytime serving state: per-engine {level: err_bound} tables (filled
    # when a schedule engine is built) and the served-tier histogram
    ee0 = bool(args.early_exit or args.brownout)
    quality_bounds = {}
    quality_served = {}

    def _quality_engine(art, engine, blocks, tiling_keys):
        # one jit trace per (engine, quality): level 0 is the full
        # schedule (early-exit kernel when armed), level q > 0 slices the
        # tile table to the artifact's margin-certified prefix.  Traces
        # build lazily — a server that never browns out pays only q=0.
        tiling = {k: v for k, v in blocks.items() if k in tiling_keys}
        quality_bounds[engine] = {
            q["level"]: q["bound"]
            for q in art.quality_levels(engine=engine, **tiling)}
        fns = {}

        def make(q):
            return jax.jit(
                lambda xw: compiler.run_compiled(
                    art, xw, engine=engine, quality=q,
                    early_exit=ee0 and q == 0, **blocks).argmax(-1),
                donate_argnums=donate)

        def run(xw, quality=0):
            q = min(int(quality), max(quality_bounds[engine], default=0))
            fn = fns.get(q)
            if fn is None:
                fn = fns[q] = make(q)
            return fn(xw)

        run.supports_quality = True
        return run

    def build_engine(name):
        # lazy per-level builders: engines the ladder never reaches pay
        # neither their jit trace nor their autotune sweep.  The serving
        # artifact is read from the `current` cell at BUILD time, so a
        # ladder.rebind() after an online hot-swap rebuilds against the
        # promoted artifact.
        art = current["compiled"]
        if name.startswith("mesh"):
            return build_mesh()
        if name == "factorized":
            blocks = tuned_factorized_blocks(art.include_words)
            return _quality_engine(
                art, "factorized", blocks,
                ("block_c", "block_j", "block_t", "term_w"))
        if name == "sparse":
            blocks = tuned_sparse_blocks(art.include_words)
            return _quality_engine(
                art, "sparse", blocks, ("block_c", "block_j"))
        if name == "dense":
            blocks = tuned_blocks(art.n_unique)
            return jax.jit(
                lambda xw: compiler.run_compiled(
                    art, xw, engine="dense", **blocks).argmax(-1),
                donate_argnums=donate)
        # bottom of the ladder: pure-XLA oracle — no Pallas lowering, no
        # donation, so it survives whatever failure killed the kernels
        assert name == "oracle", name
        return jax.jit(
            lambda xw: compiler.run_compiled(
                art, xw, engine="oracle").argmax(-1))

    levels = []
    if use_kernel:
        if factorize:
            levels.append("factorized")
        if sparse:
            levels.append("sparse")
        levels.append("dense")
    levels.append("oracle")
    if args.mesh:
        # the sharded engine degrades to the unsharded ladder: a mesh-only
        # failure (bad spec, per-shard lowering) still serves every bucket
        levels.insert(0, f"mesh-{levels[0]}")
    ladder = ops.EngineLadder(
        [(name, (lambda n=name: build_engine(n))) for name in levels],
        promote_after=args.promote_after)

    Xr, yr = make_boolean_classification(
        args.requests, config.n_features, config.n_classes, seed=2
    )
    # --online: the request stream's labels double as the labeled feedback
    # stream (serve.py's stand-in for a production label joiner)
    xp = np.asarray(packetizer.pack_literals(jnp.asarray(Xr)))
    n, W = xp.shape

    mon = StragglerMonitor(threshold=args.bucket_deadline or 2.0, warmup=2)
    # guarded warm probe: kernel/lowering failures surface here (one trace
    # per attempted engine, demoting through the ladder), so the request
    # stream starts on an engine that actually runs
    ladder.run(lambda: jnp.asarray(xp[:bucket]), bucket="warm", count=False)

    bucket_i = itertools.count()
    online_hooks = {"latency": None}   # filled when --online wires the updater

    def run_rows(rows, quality=0):
        # one gateway bucket: zero-pad to the fixed jit trace shape (a
        # partial age/drain flush never retraces), run the engine ladder,
        # and keep the straggler/deadline accounting of the old sync loop.
        # ``quality`` is the brownout controller's level; only engines
        # that opt in (supports_quality) ever degrade, and the returned
        # info records what was ACTUALLY served plus its error bound.
        i = next(bucket_i)
        t_b = time.perf_counter()
        mon.start_step()
        faults.sleep_if("serve.slow_bucket", step=i)    # deadline drill site
        padded = np.zeros((bucket, W), xp.dtype)
        padded[:len(rows)] = rows
        out = ladder.run(lambda: jnp.asarray(padded), bucket=i,
                         quality=quality)
        preds = np.asarray(out)[:len(rows)]
        q = ladder.last_quality
        quality_served[q] = quality_served.get(q, 0) + 1
        info = dict(quality=q,
                    err_bound=quality_bounds.get(
                        ladder.engine, {}).get(q) if q else None)
        flag = mon.end_step(i)
        # an engine's FIRST bucket pays its jit trace — exempting it from
        # the deadline stops one slow bucket cascading down the ladder
        if flag and args.bucket_deadline and ladder.counts[ladder.engine] > 1:
            ladder.demote(
                f"bucket deadline: {flag['seconds'] * 1e3:.1f} ms > "
                f"{args.bucket_deadline:g}x EWMA {flag['ewma'] * 1e3:.1f} ms",
                bucket=i)
        if online_hooks["latency"] is not None:
            # post-swap latency watch: a promoted artifact that blows up
            # bucket wall-time gets rolled back by the updater
            online_hooks["latency"](time.perf_counter() - t_b)
        return preds, info

    zoo = None
    updater = None
    if args.online:
        # online mode always routes through the zoo (one tenant unless
        # --zoo): the updater's atomic hot-swap IS a zoo operation, and
        # every bucket leases the entry it answers with, so in-flight
        # buckets finish on the version they started on
        from repro.runtime import online as online_mod
        from repro.runtime.zoo import ArtifactZoo

        def _nbytes(c):
            return int(c.include_words.nbytes + c.word_ids.nbytes
                       + c.votes.nbytes)

        def make_obj(c):
            # the zoo entry pairs the artifact with the shared ladder
            # runner: leases pin the object (and thus its version); the
            # ladder itself is rebound on promote via on_promote below
            return {"compiled": c, "run": run_rows}, _nbytes(c)

        zoo = ArtifactZoo(lambda tenant: make_obj(current["compiled"]),
                          max_entries=max(args.zoo or 1, 1))
        runner = zoo.runner(lambda obj, rows: obj["run"](rows))

        def canary_serve(obj, rows):
            # candidate side of the shadow canary: a standalone XLA-oracle
            # runner per artifact (bit-identical predictions to every
            # ladder engine), padded to the live trace shape so the
            # candidate's jit warm-up happens HERE, not on its first
            # post-swap bucket
            fn = obj.get("_canary_fn")
            if fn is None:
                c = obj["compiled"]
                fn = obj["_canary_fn"] = jax.jit(
                    lambda xw: compiler.run_compiled(
                        c, xw, engine="oracle").argmax(-1))
            padded = np.zeros((bucket, W), xp.dtype)
            padded[:len(rows)] = rows
            return np.asarray(fn(jnp.asarray(padded)))[:len(rows)]

        def on_promote(cand):
            current["compiled"] = cand
            ladder.rebind(
                [(nm, (lambda n2=nm: build_engine(n2))) for nm in levels])
            print(f"online: promoted artifact live (U={cand.n_unique}); "
                  "engine ladder rebound")

        ckpt_manager = None
        if args.online_ckpt_dir:
            from repro.checkpoint.store import CheckpointManager

            ckpt_manager = CheckpointManager(args.online_ckpt_dir)
        updater = online_mod.OnlineUpdater(
            config, state.ta_state, compiled,
            cfg=online_mod.OnlineConfig(
                drift_threshold=args.drift_threshold,
                canary_frac=args.canary_frac,
                swap_policy=args.swap_policy),
            zoo=zoo, tenant="t0", make_obj=make_obj, serve_fn=canary_serve,
            deployed_obj={"compiled": compiled, "run": run_rows},
            deployed_nbytes=_nbytes(compiled),
            ckpt_manager=ckpt_manager, on_promote=on_promote)
        online_hooks["latency"] = updater.record_bucket_latency
    elif args.zoo:
        # multi-tenant mode: requests round-robin over --zoo tenants that
        # share the compiled engines but carry per-tenant circuit breakers;
        # max_entries < tenants keeps the LRU churning under real pressure
        from repro.runtime.zoo import ArtifactZoo

        nbytes = int(compiled.include_words.nbytes + compiled.votes.nbytes)
        zoo = ArtifactZoo(lambda tenant: (tenant, nbytes),
                          max_entries=max(args.zoo - 1, 1))
        runner = zoo.runner(lambda obj, rows: run_rows(rows))
    else:
        # the single-tenant runner is quality-aware (the zoo runner
        # protocol is exact-only: leases/breakers wrap a plain
        # run(tenant, rows), so multi-tenant brownout would need a
        # protocol bump — those paths serve exact under pressure)
        runner = lambda tenant, rows, quality=0: run_rows(rows, quality)

    def tenant_of(j):
        return f"t{j % args.zoo}" if args.zoo else "t0"

    async def stream():
        from repro.runtime.gateway import BrownoutController, Gateway

        gw = await Gateway(
            runner, bucket=bucket, max_queue=args.max_queue or None,
            max_wait=args.max_wait_ms / 1e3,
            drain_timeout=args.drain_timeout,
            mirror=updater.mirror if updater is not None else None,
            brownout=BrownoutController() if args.brownout else None,
        ).start()
        loop = asyncio.get_running_loop()
        stop = asyncio.Event()
        try:
            # graceful drain: SIGTERM stops admission, flushes what fits
            # in the drain window, typed-sheds the rest, exits 0
            loop.add_signal_handler(signal.SIGTERM, stop.set)
        except (NotImplementedError, RuntimeError):
            pass
        stop_online = threading.Event()
        online_thread = None
        if updater is not None:
            # the updater's own thread: ingest labeled feedback in batch-
            # sized slices and train/drift-check between gateway buckets
            feed = iter(range(n))

            def online_loop():
                while not stop_online.is_set():
                    progressed = False
                    for _ in range(updater.cfg.batch_size):
                        j = next(feed, None)
                        if j is None:
                            break
                        updater.ingest(Xr[j], int(yr[j]))
                        progressed = True
                    progressed = updater.step() or progressed
                    if not progressed:
                        time.sleep(0.002)

            online_thread = threading.Thread(
                target=online_loop, name="online-updater", daemon=True)
            online_thread.start()
        deadline = args.deadline_ms / 1e3 if args.deadline_ms else None
        futs = [gw.offer(tenant_of(j), xp[j], deadline=deadline)
                for j in range(n)]
        answered = asyncio.ensure_future(asyncio.gather(*futs))
        sigterm = asyncio.ensure_future(stop.wait())
        await asyncio.wait({answered, sigterm},
                           return_when=asyncio.FIRST_COMPLETED)
        health = await gw.drain()
        if online_thread is not None:
            stop_online.set()
            online_thread.join(timeout=10)
        if updater is not None and stop.is_set():
            # SIGTERM: after the gateway drains, flush the pending feedback
            # queue through the PR-6 checkpoint path — a restarted updater
            # resumes the bank and re-ingests every drained record
            ck_step = updater.drain()
            if ck_step is not None:
                print(f"online: feedback queue drained to checkpoint "
                      f"step {ck_step}")
        sigterm.cancel()
        return await answered, health, stop.is_set()

    t0 = time.perf_counter()
    responses, gw_health, sigtermed = asyncio.run(stream())
    dt = time.perf_counter() - t0
    if sigtermed:
        print("SIGTERM: gateway drained "
              f"({gw_health['answered']}/{gw_health['offered']} answered, "
              f"{gw_health['shed_total']} typed-shed)")
    if args.artifact and (trained_this_run
                          or compiled.tuned != tuned_at_start):
        # persist schedules + newly recorded tunings for cold starts; a
        # pure load with nothing new recorded skips the multi-MB rewrite.
        # Saved AFTER the stream so tilings recorded lazily by ladder
        # builders (when an engine first actually runs) persist too.
        # Under --online this is the PROMOTED artifact, not the boot one.
        current["compiled"].save(args.artifact)
        print(f"saved artifact (schedules + tuned tilings) to {args.artifact}")
    engine_labels = {"factorized": "factorized-schedule",
                     "sparse": "sparse-schedule",
                     "dense": "fused-kernel", "oracle": "oracle"}
    eng = ladder.engine
    label = (f"clause-sharded {engine_labels[eng[len('mesh-'):]]} "
             f"({args.mesh})" if eng.startswith("mesh-")
             else engine_labels[eng])
    n_answered = gw_health["answered"]
    n_buckets = gw_health["buckets"]
    print(f"{n_answered} inferences in {n_buckets} buckets of {bucket} "
          f"[{label}] in {dt * 1e3:.2f} ms ({max(n_answered, 1) / dt:,.0f} "
          f"inf/s, {dt / max(n_answered, 1) * 1e6:.2f} us/inf)")
    health = dict(
        requests=n, buckets=n_buckets, bucket_size=bucket,
        ladder=levels, final_engine=ladder.engine,
        engine_buckets=ladder.counts, demotions=ladder.demotions,
        promotions=ladder.promotions, probe_failures=ladder.probe_failures,
        stragglers=mon.events,
        early_exit=ee0, brownout=bool(args.brownout),
        quality_tiers={str(k): v
                       for k, v in sorted(quality_served.items())},
    )
    print("SERVE_HEALTH " + json.dumps(health))
    if zoo is not None:
        gw_health["zoo"] = zoo.health()
    print("GATEWAY_HEALTH " + json.dumps(gw_health))
    if updater is not None:
        print("ONLINE_HEALTH " + json.dumps(updater.health()))
    if gw_health["unaccounted"]:
        raise SystemExit(
            f"gateway accounting violated: {gw_health['unaccounted']} "
            f"of {gw_health['offered']} requests unaccounted for")
    preds = np.asarray([r.pred for r in responses if r.ok], np.int64)
    hist = np.bincount(preds, minlength=config.n_classes)
    print("pred class histogram:", hist.tolist())


def serve_lm(args) -> None:
    from repro.configs import get_config, get_smoke_config
    from repro.models import steps, transformer

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    B, S_max = args.batch_size, args.seq_len
    caches = transformer.init_caches(cfg, B, S_max)
    prefill = jax.jit(steps.make_prefill_step(cfg))
    decode = jax.jit(steps.make_decode_step(cfg))

    nprng = np.random.default_rng(0)
    prompt_len = S_max // 2
    if cfg.frontend == "audio_stub":
        batch = {"embeds": jnp.asarray(
            nprng.normal(size=(B, prompt_len, cfg.d_model)), jnp.float32)}
        mk_inp = lambda tok: {"embeds": jnp.zeros((B, 1, cfg.d_model), jnp.float32)}
    else:
        batch = {"tokens": jnp.asarray(
            nprng.integers(0, cfg.vocab_size, (B, prompt_len)), jnp.int32)}
        mk_inp = lambda tok: {"tokens": tok}

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch, caches)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0
    tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)[:, None]

    n_new = args.new_tokens
    t0 = time.perf_counter()
    for i in range(n_new):
        logits, caches = decode(params, caches, mk_inp(tok), jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)[:, None]
    tok.block_until_ready()
    t_decode = time.perf_counter() - t0
    print(f"prefill {prompt_len} tok x {B}: {t_prefill * 1e3:.1f} ms; "
          f"decode {n_new} steps: {t_decode / n_new * 1e3:.2f} ms/step "
          f"({B * n_new / t_decode:,.0f} tok/s)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4096)
    ap.add_argument("--bucket", type=int, default=512,
                    help="TM streaming bucket size (one jit trace per run)")
    ap.add_argument("--autotune", action="store_true",
                    help="autotune fused-kernel block sizes for the bucket shape")
    ap.add_argument("--tune-policy", default="verify",
                    choices=("predict", "verify", "sweep"),
                    help="TM --autotune mode: 'predict' trusts the "
                         "analytical cost model (zero timing runs), "
                         "'verify' (default) wall-clocks only the model's "
                         "top-3 shortlist, 'sweep' times every candidate "
                         "and feeds the model's training-data sidecar")
    ap.add_argument("--no-sparse", action="store_true",
                    help="TM kernel path: serve the compiled artifact with "
                         "the dense fused kernel instead of the default "
                         "block-sparse chain schedule")
    ap.add_argument("--no-factorize", action="store_true",
                    help="TM kernel path: pin the flat bit-chain sparse "
                         "kernel even when the artifact's partial_term_"
                         "sharing clears the factorized-serving threshold")
    ap.add_argument("--factorize", action="store_true",
                    help="TM kernel path: start the engine ladder on the "
                         "factorized kernel even when the artifact's "
                         "measured term sharing is below the threshold")
    ap.add_argument("--bucket-deadline", type=float, default=None,
                    help="TM: demote the serving engine when a bucket runs "
                         "longer than this multiple of the EWMA of bucket "
                         "wall-times (soft per-bucket deadline)")
    ap.add_argument("--promote-after", type=int, default=None,
                    help="TM: probe the engine one ladder level up after "
                         "this many consecutive healthy buckets (failed "
                         "probes double the cooldown); default: demote-only")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="TM gateway: bound the pending-request queue — a "
                         "full queue sheds new requests with the typed "
                         "reason queue_full (default: unbounded)")
    ap.add_argument("--max-wait-ms", type=float, default=20.0,
                    help="TM gateway: flush a partial bucket once its "
                         "oldest request has waited this long (age-based "
                         "continuous batching)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="TM gateway: per-request deadline — a request "
                         "still queued past it is shed deadline_expired, "
                         "never executed (default: none)")
    ap.add_argument("--drain-timeout", type=float, default=5.0,
                    help="TM gateway: seconds the SIGTERM/end-of-stream "
                         "drain may spend flushing before shedding the "
                         "remainder drain_timeout")
    ap.add_argument("--early-exit", action="store_true",
                    help="TM: serve exact buckets through the in-kernel "
                         "certified early-exit path (bit-identical argmax; "
                         "tiles skipped once the artifact's anytime margin "
                         "metadata proves the leader unassailable)")
    ap.add_argument("--brownout", action="store_true",
                    help="TM gateway: degrade answer QUALITY instead of "
                         "shedding under overload — a hysteresis "
                         "controller maps queue depth / bucket age / "
                         "deadline pressure to an anytime quality level; "
                         "degraded answers carry a concrete vote-margin "
                         "error bound (implies --early-exit for exact "
                         "buckets)")
    ap.add_argument("--zoo", type=int, default=None,
                    help="TM gateway: serve this many round-robin tenants "
                         "through the artifact zoo (per-tenant circuit "
                         "breakers, LRU-capped cache) instead of one")
    ap.add_argument("--online", action="store_true",
                    help="TM: run the online-learning updater beside "
                         "serving — stream labeled feedback into a live "
                         "automata bank, rebuild on include-bit drift, "
                         "shadow-canary the candidate on mirrored buckets, "
                         "and hot-swap it atomically through the artifact "
                         "zoo (zero dropped requests)")
    ap.add_argument("--drift-threshold", type=float, default=0.05,
                    help="TM --online: include-bit drift fraction (live "
                         "bank vs the deployed artifact's bank) that arms "
                         "an incremental recompile")
    ap.add_argument("--canary-frac", type=float, default=0.25,
                    help="TM --online: fraction of live buckets the "
                         "gateway mirrors to the candidate during the "
                         "shadow canary")
    ap.add_argument("--swap-policy", default="canary",
                    choices=("canary", "immediate"),
                    help="TM --online: 'canary' (default) shadow-validates "
                         "the candidate on mirrored traffic before the "
                         "atomic swap; 'immediate' promotes as soon as the "
                         "integrity envelope passes")
    ap.add_argument("--online-ckpt-dir", default=None,
                    help="TM --online: checkpoint directory the SIGTERM "
                         "drain writes the live bank + pending feedback "
                         "through (a restart resumes from it)")
    ap.add_argument("--artifact", default=None,
                    help="TM: compiled-artifact .npz path — loaded instead "
                         "of train+compile when it exists, (re)saved with "
                         "schedules + autotuned tilings after serving")
    ap.add_argument("--mesh", default=None,
                    help="TM: mesh spec, e.g. 'model=4' — shard the compiled "
                         "clause bank over the mesh (fused kernel per shard, "
                         "one class-sum psum); on CPU export XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N first")
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--n-train", type=int, default=2000)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.arch.startswith("tm-"):
        serve_tm(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
