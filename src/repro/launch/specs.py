"""ShapeDtypeStruct input stands-ins for every (arch x shape) dry-run cell.

The assigned input-shape set (LM family):
  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> prefill_step
  decode_32k   seq 32,768  global_batch 128   -> decode_step (1 new token)
  long_500k    seq 524,288 global_batch 1     -> decode_step; sub-quadratic
               archs only (recurrentgemma, xlstm) — full-attention archs are
               skipped per assignment (noted in DESIGN.md §7).

``[audio]``/``[vlm]`` archs receive precomputed frame/patch embeddings (the
modality frontend is a stub per assignment).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"
    layout: str = "tp"  # "tp" (TP+SP over model) | "dp" (ZeRO-3 pure data)


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    # optimized-layout variant (§Perf): pure data parallelism for small archs
    "train_4k_dp": ShapeSpec("train_4k_dp", 4096, 256, "train", layout="dp"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_is_runnable(cfg: ModelConfig, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return cfg.subquadratic
    return True


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """Batch / input ShapeDtypeStructs for the given cell (no allocation)."""
    sp = SHAPES[shape_name]
    B, S = sp.global_batch, sp.seq_len
    d = cfg.d_model
    act = jnp.dtype(cfg.dtype)

    if sp.kind == "train":
        if cfg.frontend == "audio_stub":
            return {
                "embeds": _sds((B, S, d), act),
                "labels": _sds((B, S, cfg.n_codebooks), jnp.int32),
            }
        if cfg.frontend == "vision_stub":
            s_img = S // 4
            return {
                "embeds": _sds((B, s_img, d), act),
                "tokens": _sds((B, S - s_img), jnp.int32),
                "labels": _sds((B, S - s_img), jnp.int32),
            }
        return {
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }

    if sp.kind == "prefill":
        if cfg.frontend == "audio_stub":
            return {"embeds": _sds((B, S, d), act)}
        if cfg.frontend == "vision_stub":
            s_img = S // 4
            return {
                "embeds": _sds((B, s_img, d), act),
                "tokens": _sds((B, S - s_img), jnp.int32),
            }
        return {"tokens": _sds((B, S), jnp.int32)}

    # decode: one new token against a seq_len-deep cache
    if cfg.frontend == "audio_stub":
        return {"embeds": _sds((B, 1, d), act)}
    return {"tokens": _sds((B, 1), jnp.int32)}


def cache_specs_struct(cfg: ModelConfig, shape_name: str):
    """ShapeDtypeStruct tree for the decode/prefill caches of this cell."""
    sp = SHAPES[shape_name]
    return jax.eval_shape(
        functools.partial(
            transformer.init_caches, cfg, sp.global_batch, sp.seq_len
        )
    )


def params_struct(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(transformer.init_params, cfg), jax.random.PRNGKey(0)
    )
