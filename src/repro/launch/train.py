"""Training launcher: TM (the paper's flow) and LM archs, fault-tolerant.

    PYTHONPATH=src python -m repro.launch.train --arch tm-mnist \
        --steps 200 --batch-size 64 --ckpt-dir /tmp/ckpt
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 10

The loop wires together every production substrate in this repo: sharded
step functions, the prefetching loader, async atomic checkpoints with
restart-resume, preemption handling, and the straggler monitor.  ``--smoke``
swaps in the reduced config so the same driver runs on one CPU.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import ShardedBatcher, make_boolean_classification, paper_dataset
from repro.runtime import (RESUME_EXIT_CODE, PreemptionHandler,
                           StragglerMonitor, faults)


def train_tm(args) -> None:
    from repro.configs.matador_tm import TM_CONFIGS
    from repro.core import tm
    from repro.kernels import ops

    config = TM_CONFIGS[args.arch]
    name = args.arch.replace("tm-", "")
    if name in ("mnist", "kmnist", "fmnist", "cifar2", "kws6"):
        X, y, Xte, yte = paper_dataset(name, n_train=args.n_train)
    else:
        X, y = make_boolean_classification(
            args.n_train, config.n_features, config.n_classes, seed=0
        )
        Xte, yte = make_boolean_classification(
            1000, config.n_features, config.n_classes, seed=1
        )

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    state = tm.init(config, jax.random.PRNGKey(args.seed))
    start_step = 0
    loader = ShardedBatcher((X, y), args.batch_size, seed=args.seed)
    if mgr and mgr.latest_step() is not None:
        restored, extra = mgr.restore({"ta": state.ta_state})
        state = tm.TMState(ta_state=restored["ta"], steps=jnp.int32(extra["step"]))
        loader.load_state_dict(extra["loader"])
        start_step = extra["step"]
        print(f"resumed from step {start_step}")

    # chains to any handler the host process already registered and is
    # uninstalled in the finally below, so embedding this loop in a
    # serving process never clobbers the gateway's SIGTERM drain
    pre = PreemptionHandler().install()
    mon = StragglerMonitor()
    ta = state.ta_state
    it = iter(loader)
    # the fused training pipeline (fuse=True) is the kernel-path default:
    # two pallas launches per step, no (B, C) fire/ftype HBM round-trips.
    # --autotune resolves (and caches) the fused block tilings on first use.
    step_kw = dict(
        batch_chunk=args.batch_chunk,
        fuse=not args.no_fuse,
        autotune=args.autotune,
    )
    if args.use_kernel:
        step_kw["use_kernel"] = True
    sharded_step = None
    if args.mesh:
        # clause-sharded shard_map schedule: automata over `model`, batch
        # over the data axes, fused kernels per shard — bit-identical to
        # the single-device step (sharding.py engine="kernel").
        from repro.core import sharding as tm_sharding
        from repro.launch.mesh import parse_mesh_spec

        mesh = parse_mesh_spec(args.mesh)
        if config.n_clauses_total % mesh.shape["model"]:
            raise SystemExit(
                f"clause axis ({config.n_clauses_total}) not divisible by "
                f"mesh model={mesh.shape['model']}; pick a divisor (configs "
                "pad via clause_pad_multiple)")
        blocks = None
        if args.autotune:
            # autotune the PER-SHARD shapes (C_loc clauses, B_loc samples)
            # outside the shard_map trace and pin them via `blocks`
            uk, interp = ops.kernel_dispatch(
                True if args.use_kernel else None, None)
            if uk and not args.no_fuse:
                from repro.core import packetizer
                from repro.kernels import autotune as _autotune

                d_size = 1
                for ax in ("pod", "data"):
                    d_size *= mesh.shape.get(ax, 1)
                C_loc = config.n_clauses_total // mesh.shape["model"]
                B_loc = max(1, args.batch_size // d_size)
                if args.batch_chunk and B_loc > args.batch_chunk:
                    B_loc = args.batch_chunk
                blocks = _autotune.autotune_fused_train_blocks(
                    B_loc, C_loc, packetizer.n_words(config.n_literals),
                    config.n_literals, config.n_classes, interpret=interp)
                print("autotuned sharded blocks:", blocks)
            else:
                print("--autotune ignored: fused kernel path inactive "
                      "(need --use-kernel/REPRO_USE_PALLAS=1, no --no-fuse)")
        sharded_step = tm_sharding.sharded_train_step_fn(
            config, mesh, batch_chunk=args.batch_chunk, engine="kernel",
            fuse=not args.no_fuse, blocks=blocks,
            use_kernel=True if args.use_kernel else None,
        )
        print(f"mesh {dict(mesh.shape)}: clause axis sharded over "
              f"model={mesh.shape['model']}")
    try:
        for step in range(start_step, args.steps):
            mon.start_step()
            xb, yb = next(it)
            if sharded_step is not None:
                ta = sharded_step(ta, jnp.asarray(xb), jnp.asarray(yb),
                                  jnp.uint32(step))
            else:
                ta, _ = ops.tm_train_step_kernel(
                    config, ta, jnp.asarray(xb), jnp.asarray(yb),
                    jnp.uint32(step), **step_kw,
                )
            faults.sleep_if("train.slow_step", step=step)  # straggler drill
            flag = mon.end_step(step)
            if flag:
                print(f"straggler flagged: {flag}")
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, {"ta": ta},
                         extra={"step": step + 1,
                                "loader": loader.state_dict()},
                         blocking=False)
            faults.sigterm_if("train.sigterm", step=step)  # preemption drill
            if pre.preempted:
                # checkpoint (when durable storage is configured) and exit
                # with the dedicated code the launcher restarts on — even
                # without a --ckpt-dir the exit code must still say
                # "resume me", not crash
                print("preempted: checkpointing and exiting for restart "
                      f"(exit code {RESUME_EXIT_CODE})")
                pre.checkpoint_and_exit(
                    (lambda: mgr.save(
                        step + 1, {"ta": ta},
                        extra={"step": step + 1,
                               "loader": loader.state_dict()}))
                    if mgr else (lambda: None))
            if (step + 1) % args.log_every == 0:
                st = tm.TMState(ta_state=ta, steps=jnp.int32(step))
                acc = float(tm.accuracy(
                    config, st, jnp.asarray(Xte), jnp.asarray(yte)))
                inc = float((np.asarray(ta) >= 0).mean())
                print(f"step {step + 1}: test_acc={acc:.4f} "
                      f"include_frac={inc:.4f}")
    finally:
        pre.uninstall()
    if mgr:
        mgr.save(args.steps, {"ta": ta},
                 extra={"step": args.steps, "loader": loader.state_dict()})
        mgr.wait()
    import json as _json

    print("TRAIN_HEALTH " + _json.dumps(dict(
        steps=args.steps, resumed_from=start_step, stragglers=mon.events)))


def train_lm(args) -> None:
    from repro.configs import get_config, get_smoke_config
    from repro.models import steps as lm_steps, transformer
    from repro.optim import adamw

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    rng = jax.random.PRNGKey(args.seed)
    params = transformer.init_params(cfg, rng)
    opt = adamw.adamw_init(params)
    step_fn = jax.jit(lm_steps.make_train_step(cfg))

    B, S = args.batch_size, args.seq_len
    nprng = np.random.default_rng(args.seed)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    mon = StragglerMonitor()
    for step in range(args.steps):
        mon.start_step()
        tokens = nprng.integers(0, cfg.vocab_size, (B, S + 1))
        batch = {
            "tokens": jnp.asarray(tokens[:, :-1], jnp.int32),
            "labels": jnp.asarray(tokens[:, 1:], jnp.int32),
        }
        if cfg.frontend == "audio_stub":
            batch = {
                "embeds": jnp.asarray(
                    nprng.normal(size=(B, S, cfg.d_model)), jnp.float32
                ),
                "labels": jnp.asarray(
                    nprng.integers(0, cfg.vocab_size, (B, S, cfg.n_codebooks)),
                    jnp.int32,
                ),
            }
        elif cfg.frontend == "vision_stub":
            si = S // 4
            batch = {
                "embeds": jnp.asarray(
                    nprng.normal(size=(B, si, cfg.d_model)), jnp.float32
                ),
                "tokens": jnp.asarray(tokens[:, : S - si], jnp.int32),
                "labels": jnp.asarray(tokens[:, 1 : S - si + 1], jnp.int32),
            }
        params, opt, info = step_fn(params, opt, batch)
        mon.end_step(step)
        print(f"step {step + 1}: loss={float(info['loss']):.4f} "
              f"gnorm={float(info['grad_norm']):.3f}")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, {"params": params}, extra={"step": step + 1},
                     blocking=False)
    if mgr:
        mgr.wait()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--n-train", type=int, default=4000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch-chunk", type=int, default=None,
                    help="TM: scan the batch in slices of this size "
                         "(O(chunk) working set; ragged tails are padded "
                         "and masked, results stay bit-identical)")
    ap.add_argument("--no-fuse", action="store_true",
                    help="TM: use the legacy three-dispatch training step "
                         "instead of the fused Pallas pipeline")
    ap.add_argument("--autotune", action="store_true",
                    help="TM: pick fused-kernel block tilings from the "
                         "cached autotuner sweep")
    ap.add_argument("--use-kernel", action="store_true",
                    help="TM: force the Pallas kernel path (same as "
                         "REPRO_USE_PALLAS=1)")
    ap.add_argument("--mesh", default=None,
                    help="TM: mesh spec, e.g. 'model=4' or 'data=2,model=4' "
                         "— clause-sharded shard_map training step (on CPU "
                         "export XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N first)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=20)
    args = ap.parse_args()
    if args.arch.startswith("tm-"):
        train_tm(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
