"""Pallas TPU kernel: bitpacked Tsetlin clause evaluation.

This is the MATADOR accelerator datapath (paper §III) re-tiled for a TPU:

  * A "packet" is a VMEM block of ``block_w`` uint32 literal words
    (32 literals per word, packetizer.py layout).
  * Each grid step along the word axis is one **Hard-Coded Clause Block**:
    it evaluates the partial clauses for its literal window and carries the
    running clause state to the next step through the output block
    (``Clause In`` / ``Clause Out`` in paper Fig. 5) — the word axis is an
    ``arbitrary`` (sequential) grid dimension, exactly the HCB chain.
  * HCB 0 initializes all clauses to 1 (paper: "starts with the assumption
    that all clause outputs are 1"); each block ANDs in
    ``(include & ~literal) == 0`` for its window.

Tiling: literals (block_b, block_w) and includes (block_c, block_w) blocks
stream through VMEM; the (block_b, block_c) clause accumulator lives in the
output block across the word-axis steps.  All matmul-free VPU bit ops;
``block_c`` sits on the 128-lane axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat


def _clause_fire_kernel(lit_ref, inc_ref, out_ref, *, block_w: int):
    w = pl.program_id(2)
    nw = pl.num_programs(2)

    @pl.when(w == 0)
    def _init():  # HCB 0: all clauses start at 1
        out_ref[...] = jnp.ones_like(out_ref)

    lit = lit_ref[...]          # (block_b, block_w) uint32
    inc = inc_ref[...]          # (block_c, block_w) uint32

    def body(i, ok):
        l_w = jax.lax.dynamic_slice_in_dim(lit, i, 1, axis=1)   # (bb, 1)
        i_w = jax.lax.dynamic_slice_in_dim(inc, i, 1, axis=1)   # (bc, 1)
        viol = jnp.bitwise_and(i_w.reshape(1, -1), ~l_w)        # (bb, bc)
        return ok & (viol == 0)

    ok = jax.lax.fori_loop(
        0, block_w, body, out_ref[...] != 0, unroll=True
    )
    out_ref[...] = ok.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_c", "block_w", "interpret"),
)
def clause_fire(
    lit_words: jax.Array,   # (B, W) uint32
    inc_words: jax.Array,   # (C, W) uint32
    *,
    block_b: int = 128,
    block_c: int = 128,
    block_w: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """(B, C) int8 clause outputs; semantics of kernels/ref.py:clause_fire_ref."""
    B, W = lit_words.shape
    C, Wc = inc_words.shape
    assert W == Wc, (W, Wc)

    block_b = min(block_b, _rup(B, 8))
    block_c = min(block_c, _rup(C, 128))
    block_w = min(block_w, W)

    Bp, Cp, Wp = _rup(B, block_b), _rup(C, block_c), _rup(W, block_w)
    lit = _pad2(lit_words, Bp, Wp)
    inc = _pad2(inc_words, Cp, Wp)   # zero include words never violate

    grid = (Bp // block_b, Cp // block_c, Wp // block_w)
    out = pl.pallas_call(
        functools.partial(_clause_fire_kernel, block_w=block_w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_w), lambda b, c, w: (b, w)),
            pl.BlockSpec((block_c, block_w), lambda b, c, w: (c, w)),
        ],
        out_specs=pl.BlockSpec((block_b, block_c), lambda b, c, w: (b, c)),
        out_shape=jax.ShapeDtypeStruct((Bp, Cp), jnp.int8),
        compiler_params=pallas_compat.CompilerParams(dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lit, inc)
    return out[:B, :C]


def _rup(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad2(x: jax.Array, d0: int, d1: int) -> jax.Array:
    return jnp.pad(x, ((0, d0 - x.shape[0]), (0, d1 - x.shape[1])))
