"""Pallas TPU kernel: fused single-pass TM training step delta.

PR 1 fused inference so the ``(B, C)`` fired matrix never touches HBM; this
kernel does the same for the *training* hot loop.  The unfused path runs
three dispatches with two ``(B, C)`` HBM round-trips in between::

    clause_fire -> fire (HBM) -> feedback_plan -> ftype (HBM) -> ta_delta

Here the whole chain runs in ONE ``pallas_call``: the clause-fire word
chain is evaluated into VMEM scratch (exactly the fused-inference HCB
chain), the per-(sample, clause) feedback type is computed inline from
per-sample probabilities using the same counter-based hash RNG as
``ref.py`` (the TPU analog of the LFSR feedback blocks in the FPGA online
trainers, arXiv 2306.01027), and the int32 TA delta is accumulated
directly into the ``(C, L)`` output block — ``fire`` and ``ftype`` never
leave VMEM.

Grid: ``(clause-block, batch-block, word-chain)``.  The clause axis is
OUTERMOST (not the batch axis) so each ``(block_c, L)`` delta accumulator
block stays resident in VMEM across the entire batch sweep and is written
to HBM exactly once — with the batch axis outermost every batch block
would flush and re-fetch the whole ``(C, L)`` accumulator.

  * axis 0 (``c``, parallel)   — clause banks; owns one output block.
  * axis 1 (``b``, arbitrary)  — datapoint packets, accumulated into the
    resident output block.
  * axis 2 (``w``, arbitrary)  — the HCB word chain; carried clause state
    in VMEM scratch, same as ``fused_infer.py``.

On the last chain step the finished fire block is turned into feedback
types and folded into the delta.  TM feedback is *sparse by construction*
(per sample only the target class and one sampled negative class receive
feedback — 2/K of all clauses, further thinned by the clause-selection
probability), so the per-sample delta fold is guarded by a
``lax.cond`` that skips the hash-field evaluation for (sample, clause
block) pairs with no feedback at all.  The skip is bit-exact: a zero
``ftype`` row contributes exactly zero delta.

Per-sample scalars (target class, sampled negative class, Type I/II
selection probabilities) are computed by the caller from the class sums of
a cheap fused-inference first pass (``ops.tm_train_step_kernel``), so one
training step is two kernel launches total instead of three plus the HBM
intermediates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat
from repro.kernels import ref as kref
from repro.kernels.fused_infer import _pad2, _rup

# hash-stream constants — MUST match ops.feedback_select / ops.feedback_plan
_SEL_MIX = np.uint32(0x9E3779B1)
_SEL_XOR = np.uint32(0x85EBCA6B)


def _fused_train_kernel(
    scal_ref,   # (1, 3) uint32: [seed, b_offset, c_offset]
    lit_ref,    # (block_b, block_w) uint32 packed literal words
    inc_ref,    # (block_c, block_w) uint32 packed include words
    lits_ref,   # (block_b, Lp) uint8 unpacked literals
    ta_ref,     # (block_c, Lp) int8 automata states
    yk_ref,     # (2, block_b) int32: [target class; sampled negative class]
    pp_ref,     # (2, block_b) float32: [p_type1; p_type2] selection probs
    cm_ref,     # (2, block_c) int32: [clause class; clause polarity]
    out_ref,    # (block_c, Lp) int32 delta accumulator
    ok_ref,     # VMEM scratch (block_b, block_c) int32 carried clause state
    *,
    block_b: int,
    block_c: int,
    block_w: int,
    c_dim: int,
    l_dim: int,
    t_act,
    t_inact,
    global_clause: bool,
):
    b = pl.program_id(1)
    w = pl.program_id(2)
    nw = pl.num_programs(2)
    # program_id must be read at the kernel top level (the interpret-mode
    # evaluator does not rewrite it inside pl.when/cond sub-jaxprs)
    b0 = (b * block_b).astype(jnp.uint32)
    c0 = (pl.program_id(0) * block_c).astype(jnp.uint32)

    @pl.when((b == 0) & (w == 0))
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(w == 0)
    def _init_ok():  # HCB 0: all clauses start at 1 (training semantics)
        ok_ref[...] = jnp.ones_like(ok_ref)

    lit = lit_ref[...]
    inc = inc_ref[...]

    def chain(i, ok):
        l_w = jax.lax.dynamic_slice_in_dim(lit, i, 1, axis=1)   # (bb, 1)
        i_w = jax.lax.dynamic_slice_in_dim(inc, i, 1, axis=1)   # (bc, 1)
        viol = jnp.bitwise_and(i_w.reshape(1, -1), ~l_w)        # (bb, bc)
        return ok & (viol == 0)

    ok = jax.lax.fori_loop(0, block_w, chain, ok_ref[...] != 0, unroll=True)

    @pl.when(w < nw - 1)
    def _carry():  # Clause Out -> next HCB's Clause In
        ok_ref[...] = ok.astype(ok_ref.dtype)

    @pl.when(w == nw - 1)
    def _feedback():
        seed = scal_ref[0, 0]
        b_off = scal_ref[0, 1]
        c_off = scal_ref[0, 2]

        # ---- inline feedback plan: bit-identical to ops.feedback_select.
        # Clause-selection randomness is hashed on GLOBAL (sample, clause)
        # ids (b_offset / c_offset) so chunked and sharded callers reproduce
        # the unsharded stream exactly.
        bg = b0 + b_off + jax.lax.broadcasted_iota(
            jnp.uint32, (block_b, block_c), 0)
        cg = c0 + c_off + jax.lax.broadcasted_iota(
            jnp.uint32, (block_b, block_c), 1)
        r_sel = kref.hash_u32(bg * _SEL_MIX + cg, seed ^ _SEL_XOR)
        r_sel = r_sel.astype(jnp.float32) / jnp.float32(2**32)

        yv = yk_ref[0, :][:, None]       # (block_b, 1)
        knv = yk_ref[1, :][:, None]
        cls = cm_ref[0, :][None, :]      # (1, block_c)
        pol = cm_ref[1, :][None, :]
        is_t = cls == yv
        is_n = cls == knv
        p = jnp.where(is_t, pp_ref[0, :][:, None],
                      jnp.where(is_n, pp_ref[1, :][:, None], 0.0))
        sel = r_sel < p
        pos = pol > 0
        neg = pol < 0
        ftype = jnp.where(is_t & pos, 1, jnp.where(is_t & neg, 2,
                jnp.where(is_n & pos, 2, jnp.where(is_n & neg, 1, 0))))
        ft = jnp.where(sel, ftype, 0).astype(jnp.int32)   # (block_b, block_c)

        # ---- TA delta fold: bit-identical to ref.ta_delta_ref.  The
        # per-automaton hash is indexed by LOCAL (c, l) — matching the
        # unfused composition, where ta_delta runs on the local shard —
        # unless ``global_clause`` (the clause-sharded trainer), which
        # indexes by GLOBAL clause id so every shard reproduces exactly the
        # full bank's draws for its rows.
        shape = out_ref.shape                              # (block_c, Lp)
        c_idx = c0 + jax.lax.broadcasted_iota(jnp.uint32, shape, 0)
        if global_clause:
            c_idx = c_idx + c_off
        l_idx = jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
        excl = ta_ref[...] < 0
        lits_all = lits_ref[...]

        def fold(i, acc):
            ft_b = jax.lax.dynamic_slice_in_dim(ft, i, 1, 0)   # (1, bc)

            def dense(a):
                bu = b0 + b_off + jnp.uint32(i)
                gidx = (bu * jnp.uint32(c_dim) + c_idx) \
                    * jnp.uint32(l_dim) + l_idx
                r = kref.hash_u32(gidx, seed)
                act = (r < t_act).astype(jnp.int32)
                inact = (r < t_inact).astype(jnp.int32)
                lit_on = jax.lax.dynamic_slice_in_dim(lits_all, i, 1, 0) == 1
                fire_c = jax.lax.dynamic_slice_in_dim(ok, i, 1, 0) \
                    .reshape(block_c, 1)
                ft_c = ft_b.reshape(block_c, 1)
                d1 = jnp.where(fire_c,
                               jnp.where(lit_on, act, -inact), -inact)
                d2 = (fire_c & ~lit_on & excl).astype(jnp.int32)
                return a + jnp.where(ft_c == 1, d1,
                                     jnp.where(ft_c == 2, d2, 0))

            # feedback sparsity skip (bit-exact: ftype == 0 -> delta == 0)
            return jax.lax.cond(jnp.any(ft_b != 0), dense, lambda a: a, acc)

        out_ref[...] += jax.lax.fori_loop(
            0, block_b, fold, jnp.zeros(shape, jnp.int32))


@functools.partial(
    jax.jit,
    static_argnames=("p_act", "p_inact", "block_b", "block_c", "block_w",
                     "interpret", "c_total"),
)
def fused_tm_train_delta(
    ta: jax.Array,            # (C, L) int8 automata states
    lits: jax.Array,          # (B, L) uint8 {0,1} literals (unpacked)
    lit_words: jax.Array,     # (B, W) uint32 packed literals
    inc_words: jax.Array,     # (C, W) uint32 packed include masks
    y: jax.Array,             # (B,) int32 target class (-1 = padded sample)
    kn: jax.Array,            # (B,) int32 sampled negative class
    p_t: jax.Array,           # (B,) float32 Type-I-side selection prob
    p_n: jax.Array,           # (B,) float32 Type-II-side selection prob
    clause_class: jax.Array,  # (C,) int32 class id per clause
    clause_pol: jax.Array,    # (C,) int32 +1/-1 polarity (0 = padded)
    seed: jax.Array,          # uint32 scalar
    *,
    p_act: float,
    p_inact: float,
    b_offset=0,               # global index of sample 0 (runtime scalar ok)
    c_offset=0,               # global index of clause 0 (runtime scalar ok)
    c_total: int | None = None,  # global clause count (clause-sharded caller)
    block_b: int = 128,
    block_c: int = 256,
    block_w: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """Batch-summed feedback delta -> (C, L) int32, single fused pass.

    Bit-identical to the unfused three-dispatch composition::

        fire  = clause_fire_ref(lit_words, inc_words)
        ftype = feedback_select(y, kn, p_t, p_n, clause_class, clause_pol,
                                seed, b_offset, c_offset)  # masked by fire
        delta = ta_delta_ref(ta, lits, fire, ftype, seed,
                             p_act=p_act, p_inact=p_inact, b_offset=b_offset)

    ``b_offset``/``c_offset`` are runtime scalars (traced values from a
    ``lax.scan`` chunk loop or a shard_map body are fine): the selection
    hash is indexed by global (sample, clause) id and the automaton hash by
    (global sample, local clause, local literal), so chunked, sharded, and
    unsharded callers produce identical bits.  ``c_total`` (static)
    switches the automaton hash too onto GLOBAL clause ids in a bank of
    ``c_total`` clauses — with it, a clause shard's delta equals the
    corresponding rows of the FULL-bank delta (the clause-sharded
    ``shard_map`` trainer's invariant), not just the per-shard composition.
    """
    C, L = ta.shape
    B, W = lit_words.shape
    assert lits.shape == (B, L), (lits.shape, (B, L))
    assert inc_words.shape == (C, W), (inc_words.shape, (C, W))

    block_b = min(block_b, _rup(B, 8))
    block_c = min(block_c, _rup(C, 128))
    block_w = min(block_w, W)

    Bp, Cp, Wp = _rup(B, block_b), _rup(C, block_c), _rup(W, block_w)
    Lp = _rup(L, 128)

    lit_p = _pad2(lit_words, Bp, Wp)    # zero literal words: harmless
    inc_p = _pad2(inc_words, Cp, Wp)    # zero include words never violate
    lits_p = _pad2(lits, Bp, Lp)
    ta_p = jnp.pad(ta, ((0, Cp - C), (0, Lp - L)), constant_values=-1)
    # padded samples get class -1, padded clauses class -1 / polarity 0:
    # any (padded, padded) class match still yields ftype 0 via polarity 0,
    # and padded rows/cols are sliced off the output anyway.
    yk = jnp.stack([
        jnp.pad(y.astype(jnp.int32), (0, Bp - B), constant_values=-1),
        jnp.pad(kn.astype(jnp.int32), (0, Bp - B), constant_values=-1),
    ])
    pp = jnp.stack([
        jnp.pad(p_t.astype(jnp.float32), (0, Bp - B)),
        jnp.pad(p_n.astype(jnp.float32), (0, Bp - B)),
    ])
    cm = jnp.stack([
        jnp.pad(clause_class.astype(jnp.int32), (0, Cp - C),
                constant_values=-1),
        jnp.pad(clause_pol.astype(jnp.int32), (0, Cp - C)),
    ])
    scal = jnp.stack([
        jnp.asarray(seed).astype(jnp.uint32),
        jnp.asarray(b_offset).astype(jnp.uint32),
        jnp.asarray(c_offset).astype(jnp.uint32),
    ]).reshape(1, 3)

    grid = (Cp // block_c, Bp // block_b, Wp // block_w)
    out = pl.pallas_call(
        functools.partial(
            _fused_train_kernel,
            block_b=block_b, block_c=block_c, block_w=block_w,
            c_dim=C if c_total is None else c_total, l_dim=L,
            t_act=kref.prob_to_u32(p_act),
            t_inact=kref.prob_to_u32(p_inact),
            global_clause=c_total is not None,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), lambda c, b, w: (0, 0)),            # scal
            pl.BlockSpec((block_b, block_w), lambda c, b, w: (b, w)),  # lit
            pl.BlockSpec((block_c, block_w), lambda c, b, w: (c, w)),  # inc
            pl.BlockSpec((block_b, Lp), lambda c, b, w: (b, 0)),     # lits
            pl.BlockSpec((block_c, Lp), lambda c, b, w: (c, 0)),     # ta
            pl.BlockSpec((2, block_b), lambda c, b, w: (0, b)),      # y/kn
            pl.BlockSpec((2, block_b), lambda c, b, w: (0, b)),      # probs
            pl.BlockSpec((2, block_c), lambda c, b, w: (0, c)),      # cls/pol
        ],
        out_specs=pl.BlockSpec((block_c, Lp), lambda c, b, w: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((Cp, Lp), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_b, block_c), jnp.int32)],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")
        ),
        interpret=interpret,
    )(scal, lit_p, inc_p, lits_p, ta_p, yk, pp, cm)
    return out[:C, :L]
