"""Pallas TPU kernel: block-sparse compiled TM inference over a chain schedule.

The dense fused kernel (``fused_infer.py``) streams EVERY literal word for
every clause block — on a trained model that is almost all wasted work:
MATADOR's central observation (paper §II) is that a trained clause includes
a miniscule fraction of its literals, so its AND chain needs only the
included bits.  This kernel executes a **compiled chain schedule** emitted
by ``core/compiler.py``:

  * unique clauses are clustered by (chain length, active-word signature) so
    clauses with similar include structure land in the same clause block;
  * each clause's include BITS become a compacted chain — a sorted list of
    literal ids, padded with a sentinel id whose literal column is constant
    1 (an AND identity, so ragged chains stay exact);
  * per clause block, the chain splits into ``(block_c, block_j)`` tiles and
    a CSR-like table records each block's tile count; the flattened tile
    list (clause-block id, chain-block id, first/last flags) is
    scalar-prefetched so the grid only visits tiles that exist — the
    block-sparse flash-attention pattern, with the ragged inner grid driven
    by ``PrefetchScalarGridSpec`` index maps.

The datapath is bit-parallel over SAMPLES (the hardware trick of the TM
accelerators the paper cites): literals are bit-transposed so row ``l`` of
``litT`` packs literal ``l`` of 32 consecutive datapoints into one uint32.
The carried clause state (``Clause In``/``Clause Out`` of paper Fig. 5) is
then a (block_c, block_s) bitvector in VMEM scratch, and one chain step is
``ok &= litT[chain_id]`` — work scales with the number of INCLUDE BITS in
the artifact, not with ``C x W``.  An ``lax.cond`` early-exit skips a
tile's gather+AND chain entirely once its carried clause state is all-zero
(every clause in the block already dead for every sample in the slab).

On the last tile of a block the finished clause bits are unpacked and
folded into the int32 class sums through the deduped multiplicity x
polarity vote matrix — dedup fan-out stays in the kernel, and the fired
matrix never exists in HBM.

Correctness contract: all-zero include rows (clause-padding and the
degenerate all-empty artifact) FIRE under this kernel (vacuous AND), so
their vote rows must be zero — true for every ``compile_tm`` artifact
(empty clauses are dropped at compile time).  Do not point this kernel at
a raw (uncompiled) model whose empty clauses carry votes.

Like the other kernels in this package the schedule path is validated
bit-exactly against the jnp oracle in Pallas interpret mode; compiled TPU
lowering of the in-kernel row gather is tracked in ROADMAP "Next".
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import packetizer
from repro.kernels.fused_infer import _rup

# default chain tiling: 512-clause banks, 32-bit chain tiles, 16-word
# (512-sample) slabs — see kernels/autotune.py for the swept alternatives
DEFAULT_BLOCK_C = 512
DEFAULT_BLOCK_J = 32
DEFAULT_BLOCK_S = 16


# eq=False: identity hashing, so a schedule works as a jit static argument
# (its ndarray fields are unhashable by value); compile memoizes schedules
# per artifact, so identity is stable across calls.
@dataclasses.dataclass(frozen=True, eq=False)
class SparseSchedule:
    """Compiled block-sparse execution schedule for one clause bank.

    ``chain_ids[c, j]`` is the literal BIT id of clause ``c``'s ``j``-th
    chain step in the packed-word bit layout (literal ``32*w + i`` = bit
    ``i`` of word ``w``); entries past the clause's include count hold
    ``sentinel`` (= ``n_lit_bits``), whose transposed literal row is
    constant 1.  ``counts``/``indptr`` are the CSR view over chain tiles
    per clause block; ``tile_*`` are the flattened (scalar-prefetched)
    tile table the kernel's ragged grid walks.  Tiles with
    ``tile_first == tile_last == 0`` and an all-sentinel chain block are
    no-op padding (used to equalize tile counts across shards).
    """

    block_c: int
    block_j: int
    n_rows: int                 # unique clauses covered (pre-padding)
    n_lit_bits: int             # sentinel id == index of the all-ones row
    chain_ids: np.ndarray       # (Cp, Jp) int32
    tile_cb: np.ndarray         # (T,) int32 clause-block id per tile
    tile_jb: np.ndarray         # (T,) int32 chain-block id per tile
    tile_first: np.ndarray      # (T,) int32 1 = first tile of its block
    tile_last: np.ndarray       # (T,) int32 1 = last tile of its block
    counts: np.ndarray          # (n_cblocks,) int32 tiles per clause block
    indptr: np.ndarray          # (n_cblocks + 1,) int32 CSR row pointers

    @property
    def n_tiles(self) -> int:
        return int(self.tile_cb.shape[0])

    @property
    def n_cblocks(self) -> int:
        return int(self.counts.shape[0])

    @property
    def n_tiles_dense(self) -> int:
        """Tiles a dense chain over the full literal space would visit."""
        per_block = -(-self.n_lit_bits // self.block_j)
        return self.n_cblocks * per_block

    @property
    def tile_sparsity(self) -> float:
        """Fraction of the dense (clause-block x chain-block) grid skipped."""
        dense = self.n_tiles_dense
        real = int(self.counts.sum())   # padding tiles are not chain work
        return 1.0 - real / dense if dense else 0.0

    def as_dict(self) -> dict:
        return dict(
            block_c=self.block_c, block_j=self.block_j,
            n_tiles=self.n_tiles, n_tiles_dense=self.n_tiles_dense,
            tile_sparsity=self.tile_sparsity,
        )


def cluster_order(include_words: np.ndarray) -> np.ndarray:
    """Clause permutation that clusters rows by chain structure.

    Primary key: include-bit count (chain length), so clause blocks are
    chain-length homogeneous and the per-block padded chain ``Jp`` tracks
    the block's own clauses instead of the global maximum.  Secondary:
    active-word signature then word values, lexicographic — clauses sharing
    sub-chains become block neighbours (DMA locality, and the whole block's
    carried state dies together for the early-exit).
    """
    iw = np.ascontiguousarray(include_words)
    U, Wa = iw.shape
    if U <= 1:
        return np.arange(U)
    act = iw != 0
    nbits = packetizer.unpack_bits_np(iw, Wa * 32).sum(axis=1)
    # np.lexsort: LAST key is primary
    keys = [iw[:, j] for j in range(Wa - 1, -1, -1)]
    keys += [act[:, j].astype(np.uint8) for j in range(Wa - 1, -1, -1)]
    keys.append(nbits)
    return np.lexsort(keys)


def artifact_tag(include_words) -> str:
    """Content hash of an artifact's include rows — THE identity of a
    compiled bank for schedule memoization and autotune cache keys (two
    same-shape artifacts with different sparsity must never share)."""
    import hashlib

    iw = np.ascontiguousarray(np.asarray(include_words, dtype=np.uint32))
    h = hashlib.sha1(iw.tobytes())
    h.update(str(iw.shape).encode())
    return h.hexdigest()


# schedules are identity-hashed jit static args, so repeated builds for the
# same artifact+tiling must return the SAME object or every call re-lowers
# the kernel; keyed by the artifact content hash.
_SCHEDULE_CACHE: dict = {}


def build_schedule_cached(
    include_words: np.ndarray,
    *,
    block_c: int = DEFAULT_BLOCK_C,
    block_j: int = DEFAULT_BLOCK_J,
) -> SparseSchedule:
    """Content-memoized :func:`build_schedule` for callers without a
    :class:`CompiledTM` to memoize on (e.g. ``ops.tm_forward_schedule``
    called with raw include rows in a serving loop)."""
    key = (artifact_tag(include_words), block_c, block_j)
    if key not in _SCHEDULE_CACHE:
        _SCHEDULE_CACHE[key] = build_schedule(
            np.asarray(include_words, dtype=np.uint32),
            block_c=block_c, block_j=block_j)
    return _SCHEDULE_CACHE[key]


def build_schedule(
    include_words: np.ndarray,
    *,
    block_c: int = DEFAULT_BLOCK_C,
    block_j: int = DEFAULT_BLOCK_J,
    pad_tiles_to: int | None = None,
) -> SparseSchedule:
    """Compile ``(U, Wa)`` packed include rows into a chain schedule.

    Rows are taken in the given order (``compile_tm`` has already applied
    :func:`cluster_order`).  ``pad_tiles_to`` appends no-op tiles so
    shards of one artifact can share a common tile-table shape.
    """
    iw = np.ascontiguousarray(np.asarray(include_words, dtype=np.uint32))
    U, Wa = iw.shape
    n_lit_bits = Wa * 32
    block_c = max(min(block_c, _rup(max(U, 1), 8)), 1)
    Cp = _rup(max(U, 1), block_c)
    bits = np.zeros((Cp, n_lit_bits), np.uint8)
    if U:
        bits[:U] = packetizer.unpack_bits_np(iw, n_lit_bits)

    n_cblocks = Cp // block_c
    counts = np.zeros(n_cblocks, np.int32)
    per_clause = bits.sum(axis=1)
    for b in range(n_cblocks):
        j_max = int(per_clause[b * block_c:(b + 1) * block_c].max())
        counts[b] = -(-j_max // block_j)
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)

    T_real = int(counts.sum())
    T = max(T_real, pad_tiles_to or 0)
    n_jblocks = int(counts.max()) if T_real else 0
    pad_jblock = n_jblocks if T > T_real or n_jblocks == 0 else None
    if pad_jblock is not None:
        n_jblocks += 1                    # all-sentinel block for no-op tiles
    Jp = n_jblocks * block_j

    chain_ids = np.full((Cp, max(Jp, block_j)), n_lit_bits, np.int32)
    for c in range(Cp):
        (lids,) = np.nonzero(bits[c])
        chain_ids[c, : lids.shape[0]] = lids

    tile_cb = np.zeros(max(T, 1), np.int32)
    tile_jb = np.zeros(max(T, 1), np.int32)
    tile_first = np.zeros(max(T, 1), np.int32)
    tile_last = np.zeros(max(T, 1), np.int32)
    t = 0
    for b in range(n_cblocks):
        n = int(counts[b])
        for j in range(n):
            tile_cb[t], tile_jb[t] = b, j
            tile_first[t] = int(j == 0)
            tile_last[t] = int(j == n - 1)
            t += 1
    # no-op padding tiles: all-sentinel chain block, never first/last
    for tt in range(t, T):
        tile_cb[tt] = 0
        tile_jb[tt] = pad_jblock if pad_jblock is not None else 0

    return SparseSchedule(
        block_c=block_c, block_j=block_j, n_rows=U, n_lit_bits=n_lit_bits,
        chain_ids=chain_ids,
        tile_cb=tile_cb[:T] if T else tile_cb[:0],
        tile_jb=tile_jb[:T] if T else tile_jb[:0],
        tile_first=tile_first[:T] if T else tile_first[:0],
        tile_last=tile_last[:T] if T else tile_last[:0],
        counts=counts, indptr=indptr,
    )


def build_schedule_incremental(
    include_words: np.ndarray,
    prev: SparseSchedule,
    prev_include_words: np.ndarray,
    *,
    block_c: int = DEFAULT_BLOCK_C,
    block_j: int = DEFAULT_BLOCK_J,
) -> tuple[SparseSchedule, dict]:
    """Rebuild a chain schedule, reusing ``prev``'s chain rows where the
    include bits did not move.

    The expensive part of :func:`build_schedule` is the per-clause
    ``nonzero`` loop that compacts include bits into literal-id chains;
    online drift touches a small fraction of clauses, so rows whose packed
    include words are identical to ``prev_include_words`` copy their chain
    straight out of ``prev.chain_ids`` (sentinel padding is layout-
    compatible because the literal space and tiling are checked first).
    The tile table and CSR counts are always rebuilt — they are cheap and
    depend on the global chain-length maximum.

    Returns ``(schedule, info)`` where ``info`` reports ``rows_reused`` /
    ``rows_rebuilt`` / ``tiles_reused`` (tiles of clause blocks with no
    changed row).  The result is bit-exact against a from-scratch
    :func:`build_schedule`; incompatible layouts (different row count,
    word count, or effective tiling) fall back to the full build with
    zero reuse.
    """
    iw = np.ascontiguousarray(np.asarray(include_words, dtype=np.uint32))
    piw = np.ascontiguousarray(np.asarray(prev_include_words, dtype=np.uint32))
    U, Wa = iw.shape
    n_lit_bits = Wa * 32
    eff_block_c = max(min(block_c, _rup(max(U, 1), 8)), 1)
    if (piw.shape != iw.shape
            or prev.block_c != eff_block_c or prev.block_j != block_j
            or prev.n_rows != U or prev.n_lit_bits != n_lit_bits):
        full = build_schedule(iw, block_c=block_c, block_j=block_j)
        return full, dict(rows_reused=0, rows_rebuilt=U, tiles_reused=0)

    Cp = _rup(max(U, 1), eff_block_c)
    bits = np.zeros((Cp, n_lit_bits), np.uint8)
    if U:
        bits[:U] = packetizer.unpack_bits_np(iw, n_lit_bits)

    n_cblocks = Cp // eff_block_c
    counts = np.zeros(n_cblocks, np.int32)
    per_clause = bits.sum(axis=1)
    for b in range(n_cblocks):
        j_max = int(per_clause[b * eff_block_c:(b + 1) * eff_block_c].max())
        counts[b] = -(-j_max // block_j)
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)

    T_real = int(counts.sum())
    T = T_real
    n_jblocks = int(counts.max()) if T_real else 0
    pad_jblock = n_jblocks if n_jblocks == 0 else None
    if pad_jblock is not None:
        n_jblocks += 1
    Jp = n_jblocks * block_j

    row_same = np.zeros(Cp, bool)
    row_same[:U] = (iw == piw).all(axis=1)
    row_same[U:] = True                  # padding rows are sentinel in both

    width = max(Jp, block_j)
    chain_ids = np.full((Cp, width), n_lit_bits, np.int32)
    copy_w = min(width, prev.chain_ids.shape[1])
    # a reused row's chain fits the new width: its include count bounds the
    # new global j_max, and entries past the chain are sentinel either way
    chain_ids[row_same, :copy_w] = prev.chain_ids[row_same, :copy_w]
    for c in np.nonzero(~row_same)[0]:
        (lids,) = np.nonzero(bits[c])
        chain_ids[c, :lids.shape[0]] = lids

    tile_cb = np.zeros(max(T, 1), np.int32)
    tile_jb = np.zeros(max(T, 1), np.int32)
    tile_first = np.zeros(max(T, 1), np.int32)
    tile_last = np.zeros(max(T, 1), np.int32)
    t = 0
    for b in range(n_cblocks):
        n = int(counts[b])
        for j in range(n):
            tile_cb[t], tile_jb[t] = b, j
            tile_first[t] = int(j == 0)
            tile_last[t] = int(j == n - 1)
            t += 1

    block_clean = row_same.reshape(n_cblocks, eff_block_c).all(axis=1)
    sched = SparseSchedule(
        block_c=eff_block_c, block_j=block_j, n_rows=U, n_lit_bits=n_lit_bits,
        chain_ids=chain_ids,
        tile_cb=tile_cb[:T] if T else tile_cb[:0],
        tile_jb=tile_jb[:T] if T else tile_jb[:0],
        tile_first=tile_first[:T] if T else tile_first[:0],
        tile_last=tile_last[:T] if T else tile_last[:0],
        counts=counts, indptr=indptr,
    )
    info = dict(
        rows_reused=int(row_same[:U].sum()),
        rows_rebuilt=int(U - row_same[:U].sum()),
        tiles_reused=int(counts[block_clean].sum()),
    )
    return sched, info


def bit_transpose_literals(lit_words: jax.Array, n_lit_bits: int) -> jax.Array:
    """(B, W) packed literal words -> (n_lit_bits + 1, ceil(B/32)) uint32.

    Row ``l`` packs literal ``l`` of 32 consecutive samples per word
    (LSB-first, matching ``packetizer.pack_bits``); the appended final row
    is constant 1 — the chain sentinel's AND identity.  Padding samples
    beyond ``B`` read as literal 0, so any clause with at least one include
    reports 0 for them (and all-zero rows only ever carry zero votes).
    """
    bits = packetizer.unpack_bits(lit_words, n_lit_bits)      # (B, L)
    lit_t = packetizer.pack_bits(bits.T)                      # (L, Sw)
    ones = jnp.full((1, lit_t.shape[1]), 0xFFFFFFFF, jnp.uint32)
    return jnp.concatenate([lit_t, ones], axis=0)


# Sentinel for masking padded class columns in the early-exit margin
# check: far below any real class sum (|sums| <= total vote mass) while
# keeping top1 - second inside int32.
_NEG_SUM = -(2 ** 28)


def _slab_lead_margin(sums, n_classes):
    """Per-sample top1 - top2 over the real class columns; ties -> 0."""
    col = jax.lax.broadcasted_iota(jnp.int32, sums.shape, 1)
    masked = jnp.where(col < n_classes, sums, jnp.int32(_NEG_SUM))
    top1 = jnp.max(masked, axis=1)
    is_top = masked == top1[:, None]
    second = jnp.max(jnp.where(is_top, jnp.int32(_NEG_SUM), masked), axis=1)
    tied = jnp.sum(is_top.astype(jnp.int32), axis=1) > 1
    return jnp.where(tied, jnp.int32(0), top1 - second)


def _sparse_infer_kernel(
    *refs,
    # positional refs: tcb, tjb, tfirst, tlast, [tmargin,] litT, chain,
    # votes -> out, ok scratch [, done scratch]
    #   tcb/tjb     (T,) scalar-prefetch: clause-/chain-block id per tile
    #   tfirst/tlast (T,) scalar-prefetch: first/last tile of its clause block
    #   tmargin     (T,) scalar-prefetch: residual vote swing after tile t
    #   litT        (L + 1, block_s) uint32 bit-transposed literals
    #   chain       (block_c, block_j) int32 literal ids of this chain tile
    #   votes       (block_c, Kp) int32 multiplicity x polarity votes
    #   out         (block_s * 32, Kp) int32 class sums
    #   ok          VMEM scratch (block_c, block_s) uint32 carried clause bits
    #   done        SMEM scratch (1,) int32 — slab certified, skip tiles
    block_c: int,
    block_j: int,
    block_s: int,
    n_classes: int = 0,
    n_samples: int = 0,
    early_exit: bool = False,
):
    if early_exit:
        (tcb_ref, tjb_ref, tfirst_ref, tlast_ref, tmargin_ref,
         litT_ref, chain_ref, votes_ref, out_ref, ok_ref, done_ref) = refs
    else:
        (tcb_ref, tjb_ref, tfirst_ref, tlast_ref,
         litT_ref, chain_ref, votes_ref, out_ref, ok_ref) = refs
        tmargin_ref = done_ref = None
    t = pl.program_id(1)
    slab = pl.program_id(0)   # hoisted: program_id can't lower inside pl.when

    @pl.when(t == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)
        if early_exit:
            done_ref[0] = 0

    active = jnp.logical_not(done_ref[0]) if early_exit else True

    @pl.when(tfirst_ref[t] == 1)
    def _init_ok():   # chain start: every clause alive for every sample
        ok_ref[...] = jnp.full_like(ok_ref, 0xFFFFFFFF)

    ok0 = ok_ref[...]

    def chain(ok):
        # one gather for the whole tile's chain, then a tree-AND over the
        # block_j bit positions (log2 ops instead of block_j — the chain
        # is associative); sentinel ids land on the all-ones row
        ids = chain_ref[...].reshape(-1)                      # (bc * bj,)
        g = jnp.take(litT_ref[...], ids, axis=0)
        g = g.reshape(block_c, block_j, block_s)
        while g.shape[1] > 1:
            half = g.shape[1] // 2
            lo = g[:, :half, :] & g[:, half:2 * half, :]
            g = (jnp.concatenate([lo, g[:, 2 * half:, :]], axis=1)
                 if g.shape[1] % 2 else lo)
        return ok & g[:, 0, :]

    # early exit: the whole slab of clauses is already dead — skip the
    # gather and the AND chain (Clause-Out all zero propagates unchanged);
    # in exact early-exit mode a certified slab skips every remaining tile
    live = jnp.any(ok0 != 0)
    ok = jax.lax.cond(jnp.logical_and(live, active) if early_exit else live,
                      chain, lambda o: o, ok0)

    @pl.when(tlast_ref[t] == 0)
    def _carry():   # Clause Out -> next chain tile's Clause In
        ok_ref[...] = ok

    fold_pred = tlast_ref[t] == 1
    if early_exit:
        fold_pred = jnp.logical_and(fold_pred, active)

    @pl.when(fold_pred)
    def _fold():    # adder bank: unpack sample bits, fold multiplicity votes
        shifts = jnp.arange(32, dtype=jnp.uint32)
        fired = ((ok[:, :, None] >> shifts) & jnp.uint32(1)).astype(jnp.int32)
        fired = fired.reshape(block_c, block_s * 32)          # (bc, samples)
        out_ref[...] += jax.lax.dot_general(
            fired.T, votes_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        if early_exit:
            # certify: every sample's lead STRICTLY beats the residual
            # swing -> no remaining tile can change any argmax in the slab
            # (padding sample slots sum to 0 forever; count them certified)
            lead = _slab_lead_margin(out_ref[...], n_classes)
            row = slab * (block_s * 32) + jax.lax.iota(jnp.int32, block_s * 32)
            lead = jnp.where(row < n_samples, lead, jnp.int32(-_NEG_SUM))
            certified = jnp.all(lead > tmargin_ref[t])
            done_ref[0] = jnp.where(certified, 1, done_ref[0])


@functools.partial(
    jax.jit,
    static_argnames=("schedule", "block_s", "interpret"),
)
def sparse_tm_forward(
    lit_words: jax.Array,       # (B, W) uint32 packed literals
    votes: jax.Array,           # (U, K) int32 — rows aligned with schedule
    schedule: SparseSchedule,
    *,
    block_s: int = DEFAULT_BLOCK_S,
    interpret: bool = False,
    tile_margin: jax.Array | None = None,   # (T,) residual swing after tile t
) -> jax.Array:
    """Packed literals -> (B, K) int32 class sums via the chain schedule.

    Bit-identical to ``class_sum_ref(clause_fire_ref(lit, include_words),
    votes)`` for the include rows the schedule was built from (vacuous-AND
    semantics: all-zero rows fire, so their votes must be zero — guaranteed
    by ``compile_tm``).

    With ``tile_margin`` (see :mod:`repro.kernels.anytime`) the kernel
    runs in exact early-exit mode: a sample slab stops folding once every
    sample's lead strictly exceeds the residual swing.  Argmax over the
    result is identical to the full walk; the sums themselves may be
    truncated.
    """
    B, W = lit_words.shape
    U, K = votes.shape
    assert U <= schedule.chain_ids.shape[0], (U, schedule.chain_ids.shape)
    assert schedule.n_lit_bits == W * 32, (schedule.n_lit_bits, W)
    if schedule.n_tiles == 0:   # degenerate all-empty schedule: nothing votes
        return jnp.zeros((B, K), jnp.int32)

    Cp = schedule.chain_ids.shape[0]
    vts = jnp.pad(votes.astype(jnp.int32), ((0, Cp - U), (0, 0)))
    tiles = jnp.asarray(np.stack([
        schedule.tile_cb, schedule.tile_jb,
        schedule.tile_first, schedule.tile_last,
    ]))   # padded clauses fire vacuously but vote 0
    return sparse_tm_forward_tables(
        lit_words, jnp.asarray(schedule.chain_ids), vts, tiles,
        block_c=schedule.block_c, block_j=schedule.block_j,
        block_s=block_s, interpret=interpret, tile_margin=tile_margin,
    )


def stack_shard_schedules(
    include_words: np.ndarray,      # (U, Wa) — compile_tm row order
    votes: np.ndarray,              # (U, K)
    n_shards: int,
    *,
    block_c: int = DEFAULT_BLOCK_C,
    block_j: int = DEFAULT_BLOCK_J,
):
    """Clause-shard a compiled schedule: each shard carries its own tile
    table, padded to common shapes so the stacks shard over ``model``.

    Returns ``(schedules, chain_stack, votes_stack, tile_stack, C_loc)``:
    per-shard :class:`SparseSchedule` objects (CSR metadata), the
    ``(n_shards, C_loc_p, Jp)`` chain-id stack, the matching vote stack,
    and the ``(n_shards, 4, T)`` tile table (cb, jb, first, last).  Shards
    with fewer real tiles ride on no-op padding tiles, so every shard runs
    the same grid — partial class sums then compose exactly through one
    int32 ``psum``.
    """
    iw = np.ascontiguousarray(np.asarray(include_words, dtype=np.uint32))
    U, Wa = iw.shape
    K = votes.shape[1]
    C_loc = -(-max(U, 1) // n_shards)
    C_loc = _rup(C_loc, 8)
    Up = C_loc * n_shards
    iw = np.pad(iw, ((0, Up - U), (0, 0)))
    vt = np.pad(np.asarray(votes, np.int32), ((0, Up - U), (0, 0)))

    schedules = [
        build_schedule(iw[s * C_loc:(s + 1) * C_loc],
                       block_c=block_c, block_j=block_j)
        for s in range(n_shards)
    ]
    T = max(max(s.n_tiles for s in schedules), 1)
    Jp = max(max(s.chain_ids.shape[1] for s in schedules), block_j)
    schedules = [
        build_schedule(iw[s * C_loc:(s + 1) * C_loc],
                       block_c=block_c, block_j=block_j, pad_tiles_to=T)
        for s in range(n_shards)
    ]
    Jp = max(max(s.chain_ids.shape[1] for s in schedules), Jp)
    Cp = max(s.chain_ids.shape[0] for s in schedules)

    chain_stack = np.full((n_shards, Cp, Jp), Wa * 32, np.int32)
    votes_stack = np.zeros((n_shards, Cp, K), np.int32)
    tile_stack = np.zeros((n_shards, 4, T), np.int32)
    for s, sched in enumerate(schedules):
        cp, jp = sched.chain_ids.shape
        chain_stack[s, :cp, :jp] = sched.chain_ids
        votes_stack[s, :C_loc] = vt[s * C_loc:(s + 1) * C_loc]
        tile_stack[s, 0] = sched.tile_cb
        tile_stack[s, 1] = sched.tile_jb
        tile_stack[s, 2] = sched.tile_first
        tile_stack[s, 3] = sched.tile_last
    return schedules, chain_stack, votes_stack, tile_stack, C_loc


def sparse_tm_forward_tables(
    lit_words: jax.Array,       # (B, W) uint32
    chain_ids: jax.Array,       # (Cp, Jp) int32
    votes: jax.Array,           # (Cp, K) int32 (already padded rows)
    tiles: jax.Array,           # (4, T) int32 — cb, jb, first, last
    *,
    block_c: int,
    block_j: int,
    block_s: int = DEFAULT_BLOCK_S,
    interpret: bool = False,
    tile_margin: jax.Array | None = None,
) -> jax.Array:
    """Traced-table twin of :func:`sparse_tm_forward` for ``shard_map``
    bodies: the chain/tile tables arrive as (sharded) arrays instead of a
    static schedule, so one jit serves every shard."""
    B, W = lit_words.shape
    Cp, Jp = chain_ids.shape
    K = votes.shape[1]
    T = tiles.shape[1]
    Kp = _rup(K, 128)
    Sw = packetizer.n_words(B)
    block_s = max(min(block_s, Sw), 1)
    Swp = _rup(Sw, block_s)

    litT = bit_transpose_literals(lit_words, W * 32)
    litT = jnp.pad(litT, ((0, 0), (0, Swp - litT.shape[1])))
    vts = jnp.pad(votes.astype(jnp.int32), ((0, 0), (0, Kp - K)))

    early_exit = tile_margin is not None
    n_prefetch = 5 if early_exit else 4
    scratch = [pltpu.VMEM((block_c, block_s), jnp.uint32)]
    if early_exit:
        scratch.append(pltpu.SMEM((1,), jnp.int32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_prefetch,
        grid=(Swp // block_s, T),
        in_specs=[
            pl.BlockSpec((W * 32 + 1, block_s), lambda s, t, *refs: (0, s)),
            pl.BlockSpec((block_c, block_j),
                         lambda s, t, cb, jb, *refs: (cb[t], jb[t])),
            pl.BlockSpec((block_c, Kp),
                         lambda s, t, cb, jb, *refs: (cb[t], 0)),
        ],
        out_specs=pl.BlockSpec((block_s * 32, Kp), lambda s, t, *refs: (s, 0)),
        scratch_shapes=scratch,
    )
    prefetch = [tiles[0], tiles[1], tiles[2], tiles[3]]
    if early_exit:
        prefetch.append(jnp.asarray(tile_margin, jnp.int32))
    out = pl.pallas_call(
        functools.partial(
            _sparse_infer_kernel,
            block_c=block_c, block_j=block_j, block_s=block_s,
            n_classes=K, n_samples=B, early_exit=early_exit,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Swp * 32, Kp), jnp.int32),
        interpret=interpret,
    )(*prefetch, litT, chain_ids, vts)
    return out[:B, :K]


def schedule_class_sums_ref(
    lit_words: jax.Array,       # (B, W) uint32
    chain_ids: jax.Array,       # (Cp, Jp) int32 (sentinel = W * 32)
    votes: jax.Array,           # (Cp, K) int32
) -> jax.Array:
    """jnp oracle over chain tables (the non-kernel engine of the sharded
    schedule path): fire iff every chain literal is 1, sentinel ids read
    constant 1.  Bit-identical to the Pallas schedule kernel."""
    B, W = lit_words.shape
    bits = packetizer.unpack_bits(lit_words, W * 32)          # (B, L)
    padded = jnp.concatenate(
        [bits, jnp.ones((B, 1), bits.dtype)], axis=1)         # sentinel col
    g = jnp.take(padded, chain_ids.reshape(-1), axis=1)
    fired = jnp.all(g.reshape(B, *chain_ids.shape) != 0, axis=2)
    return fired.astype(jnp.int32) @ votes.astype(jnp.int32)
