"""Pallas TPU kernels (+ pure-jnp oracles) for the MATADOR datapath.

Kernels: fused_infer (the whole inference datapath — HCB chain + class-sum
adder bank in one pass, no fired matrix in HBM), clause_eval (HCB chain),
class_sum (vote adders), ta_update (training feedback), xnor_popcount (BNN
baseline layer).  ``ops`` is the dispatch layer; ``ref`` holds the oracles
the kernels are tested against; ``autotune`` picks fused-kernel block
tilings per (shape, backend) with an on-disk cache; ``pallas_compat``
absorbs pallas API drift between jax versions.
"""
