"""Pallas TPU kernels (+ pure-jnp oracles) for the MATADOR datapath.

Kernels: clause_eval (HCB chain), class_sum (vote adders), ta_update
(training feedback), xnor_popcount (BNN baseline layer).  ``ops`` is the
dispatch layer; ``ref`` holds the oracles the kernels are tested against.
"""
