"""Analytical cost model for the kernel autotuner: predict, don't sweep.

The wall-clock autotuner (``kernels/autotune.py``) times every candidate
tiling — fine for one artifact, hopeless for a production zoo where a cold
tenant's first request must not trigger a timing sweep.  This module is the
predict-first tier behind ``autotune.tune(policy=...)``:

* **Workload features** (:func:`artifact_features`) — candidate-independent
  statistics of the compiled artifact: include-bit counts, chain-length
  distribution, ``partial_term_sharing``, term-table size (all already
  computed by ``core/compiler.CompileStats`` / the schedule builders), plus
  bytes/flops/HBM-traffic extracted from the compiled oracle HLO via
  ``launch/hlo_analysis`` and divided by the roofline peaks from
  ``launch/mesh`` (:func:`hlo_forward_features`).  ``CompiledTM.save()``
  persists this dict so a zoo cold-load never re-pays the HLO lowering.

* **Per-candidate basis** — each tuned kernel registers a featurizer in
  ``autotune``'s kernel registry that maps ``(shape, artifact, candidate)``
  to a small dict of roofline-style work terms (grid steps, gather volume,
  fold volume, HBM bytes — computed from the REAL schedule the candidate
  would execute, so ragged tile counts are exact, and exactly the terms a
  linear timing model can weight).

* **The model** (:class:`CostModel`) — predicted microseconds are a
  non-negative linear combination of the basis terms.  Shipped
  coefficients (:data:`DEFAULT_COEFFS`) were fitted on this repo's
  interpret-mode sweeps; every measured sweep ANYWHERE logs
  ``(features, basis, tiling, measured_us)`` rows into a persistent
  training-data sidecar (:func:`record_observations` — atomic
  ``os.replace``, same contract as the tune cache) and
  :func:`get_model` refits from it, so predictions keep improving as
  sweeps accumulate.

The model ranks candidates; ``autotune.tune`` decides what to do with the
ranking per policy: ``predict`` returns the top-1 with ZERO timing runs,
``verify`` times only the top-k, ``sweep`` times everything (and feeds the
sidecar).
"""

from __future__ import annotations

import functools
import json
import math
import os

import numpy as np

FEATURE_SCHEMA_VERSION = 1

# -- training-data sidecar ---------------------------------------------------

_DATA_ENV = "REPRO_TUNE_DATA"
_DATA_SCHEMA = 1
# FIFO cap: the sidecar is a rolling window, not an unbounded log — old
# observations age out as newer (same-machine, same-jax) sweeps land
_MAX_OBSERVATIONS = 4096
# below this many rows for a (kernel, mode) the fit is underdetermined and
# the shipped defaults answer instead
MIN_FIT_ROWS = 8


def data_path() -> str:
    p = os.environ.get(_DATA_ENV)
    if p:
        return p
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune_data.json")


def load_observations() -> list:
    """Sidecar rows from disk; [] on missing, corrupt, or stale-schema
    files (same invalidate-never-crash contract as the tune cache)."""
    try:
        with open(data_path()) as f:
            raw = json.load(f)
    except (OSError, ValueError):
        return []
    if not isinstance(raw, dict) or raw.get("schema") != _DATA_SCHEMA:
        return []
    rows = raw.get("observations")
    return rows if isinstance(rows, list) else []


def record_observations(rows: list) -> None:
    """Append sweep observations to the sidecar (read-merge-write under an
    atomic ``os.replace`` — concurrent sweeps are last-writer-wins per
    write, never a torn file; worst case a lost row is re-measured by a
    future sweep).  Rows beyond the FIFO cap age out oldest-first."""
    if not rows:
        return
    path = data_path()
    merged = load_observations() + list(rows)
    if len(merged) > _MAX_OBSERVATIONS:
        merged = merged[-_MAX_OBSERVATIONS:]
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump({"schema": _DATA_SCHEMA, "observations": merged}, f)
    os.replace(tmp, path)
    _invalidate_model_cache()


def make_observation(kernel: str, mode: str, blocks: dict, basis: dict,
                     measured_us: float, features: dict | None = None) -> dict:
    """One sidecar row.  ``mode`` is ``autotune._mode_backend`` output —
    interpret-mode timings must never train a compiled-backend model."""
    return dict(
        kernel=kernel, mode=mode, blocks=dict(blocks),
        basis={k: float(v) for k, v in basis.items()},
        measured_us=float(measured_us),
        features=dict(features) if features else None,
    )


# -- HLO-derived workload features -------------------------------------------

_HLO_REF_BATCH = 64


@functools.lru_cache(maxsize=64)
def hlo_forward_features(U: int, Wa: int, K: int,
                         batch: int = _HLO_REF_BATCH) -> dict:
    """bytes/flops/HBM-traffic of the compiled ORACLE forward at this
    artifact shape, per sample.

    The oracle (pure-XLA ``ref.clause_fire_ref`` + ``class_sum_ref``) is
    the one engine every backend can lower, so its post-optimization HLO
    is a backend-honest measure of the workload's intrinsic arithmetic and
    memory traffic — the quantity the roofline terms divide.  Extraction
    goes through ``jax_compat.lower_compiled`` (the modern AOT idiom; the
    retired ``jax.xla_computation`` path rotted here once) and
    ``launch/hlo_analysis.analyze``.  Memoized per shape: one lowering per
    (U, Wa, K), shared by every candidate and every batch bucket.
    """
    import jax
    import jax.numpy as jnp

    from repro import jax_compat
    from repro.kernels import ref
    from repro.launch import hlo_analysis
    from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

    def fwd(lit_words, inc_words, votes):
        fired = ref.clause_fire_ref(lit_words, inc_words)
        return ref.class_sum_ref(fired, votes)

    compiled = jax_compat.lower_compiled(
        fwd,
        jax.ShapeDtypeStruct((batch, Wa), jnp.uint32),
        jax.ShapeDtypeStruct((U, Wa), jnp.uint32),
        jax.ShapeDtypeStruct((U, K), jnp.int32),
    )
    cost = hlo_analysis.analyze(compiled.as_text())
    ca = jax_compat.cost_analysis(compiled) or {}
    flops = cost.flops / batch
    hbm = cost.bytes / batch
    return dict(
        hlo_flops_per_sample=flops,
        hlo_bytes_per_sample=hbm,
        xla_flops_per_sample=float(ca.get("flops", 0.0)) / batch,
        # roofline bounds (seconds/sample on the reference accelerator):
        # what the workload costs when compute- / memory-bound — the
        # analytic floor the predicted tilings are judged against
        roofline_t_comp=flops / PEAK_FLOPS_BF16,
        roofline_t_mem=hbm / HBM_BW,
    )


def artifact_features(compiled, *, with_hlo: bool = True) -> dict:
    """Candidate-independent workload features of a compiled artifact.

    ``compiled`` is duck-typed (``include_words``/``votes``/``stats``/
    ``n_classes`` — a ``core/compiler.CompiledTM`` or anything
    shape-compatible).  The dict is JSON-serializable; ``CompiledTM.save``
    persists it under ``meta["features"]`` so cold loads skip both the
    stat recomputation and the HLO lowering (``with_hlo=False`` skips the
    lowering here too, for callers that only need schedule stats).
    """
    iw = np.ascontiguousarray(np.asarray(compiled.include_words,
                                         dtype=np.uint32))
    U, Wa = iw.shape
    K = int(compiled.n_classes)
    chain = np.unpackbits(iw.view(np.uint8)).reshape(U, -1).sum(axis=1)
    n_includes = int(chain.sum())
    stats = getattr(compiled, "stats", None)
    feats = dict(
        schema=FEATURE_SCHEMA_VERSION,
        n_rows=U,
        n_words_active=Wa,
        n_classes=K,
        n_includes=n_includes,
        include_density=n_includes / max(U * Wa * 32, 1),
        chain_mean=float(chain.mean()) if U else 0.0,
        chain_p95=float(np.percentile(chain, 95)) if U else 0.0,
        chain_max=int(chain.max()) if U else 0,
        partial_term_sharing=(
            float(stats.partial_term_sharing) if stats is not None else 0.0),
        n_partial_terms_unique=(
            int(stats.n_partial_terms_unique) if stats is not None else 0),
    )
    if with_hlo:
        feats.update(hlo_forward_features(U, Wa, K))
    return feats


# -- the model ---------------------------------------------------------------

# Shipped coefficients: predicted MICROSECONDS per basis unit, fitted with
# ridge least squares (non-negative) on this container's interpret-mode
# sweeps across the four kernels' candidate grids (see
# benchmarks/autotune_cost.py for the refit-and-measure loop).  Interpret
# mode is dominated by per-grid-step dispatch overhead, which is why the
# ``steps`` terms carry most of the weight; ``*_melem`` terms are
# millions-of-elements work volumes.  A compiled backend should not trust
# these numbers — it should run sweeps (which feed the sidecar) until
# ``get_model`` has enough same-mode rows to refit.
# Shipped zero-data defaults: fit on the CI container (cpu:interp mode)
# via `scripts/fit_cost_model.py --sweep --interpret` over a grid of
# small/wide/tall problems and low/high-sharing include banks.  Units are
# µs per basis term; only the RANKING matters, so a different machine's
# absolute error is harmless until its sidecar refits these.  In
# interpret mode the per-grid-step dispatch overhead (`steps`) and the
# K-wide class-sum fold (`fold_melem`) dominate; `bytes_mb` fits to ~0
# because interpret mode never touches real HBM.
DEFAULT_COEFFS: dict = {
    "fused_infer": {
        "intercept": 8.45, "steps": 99.497,
        "work_melem": 441.127, "fold_melem": 1193.107, "bytes_mb": 0.0,
    },
    "fused_train": {
        "intercept": 22849.81, "steps": 2262.699,
        "work_melem": 74479.131, "l_work_melem": 0.0, "bytes_mb": 72658.346,
    },
    "sparse_infer": {
        "intercept": 40.774, "steps": 27.033,
        "chain_melem": 82.833, "fold_melem": 55197.206, "bytes_mb": 0.0,
    },
    "term_infer": {
        "intercept": 0.0, "steps": 179.94,
        "term_melem": 1220.827, "chain_melem": 1233.48,
        "fold_melem": 45300.49, "bytes_mb": 0.0,
    },
}


class CostModel:
    """Non-negative linear timing model over per-candidate basis terms."""

    def __init__(self, coeffs: dict | None = None):
        self.coeffs = {k: dict(v) for k, v in
                       (coeffs or DEFAULT_COEFFS).items()}

    def predict_us(self, kernel: str, basis: dict) -> float:
        theta = self.coeffs.get(kernel)
        if theta is None:
            # an unregistered kernel still gets a deterministic ranking:
            # fewer grid steps first (the structurally-better default)
            return float(basis.get("steps", 0.0))
        us = theta.get("intercept", 0.0)
        for name, value in basis.items():
            us += theta.get(name, 0.0) * float(value)
        return float(us)

    def rank(self, kernel: str, items: list) -> list:
        """``items`` is ``[(candidate, basis_dict), ...]``; returns
        ``[(candidate, predicted_us), ...]`` best-first.  Ties break
        toward the LARGER tiling, matching the sweep's noise-floor rule
        (fewer grid steps is structurally better when the model can't
        separate candidates)."""
        scored = [(cand, self.predict_us(kernel, basis))
                  for cand, basis in items]
        return sorted(scored, key=lambda cb: (cb[1], -math.prod(cb[0])))

    def fit(self, observations: list, mode: str,
            min_rows: int = MIN_FIT_ROWS, ridge: float = 1e-3) -> "CostModel":
        """Refit per-kernel coefficients from sidecar rows of the SAME
        backend/interpret mode (interpret timings must not train a
        compiled-mode model).  Kernels with fewer than ``min_rows``
        same-mode rows keep their current coefficients.  Ridge-regularized
        least squares with negative weights clipped to zero — a negative
        work coefficient would rank unboundedly-large tilings first.
        """
        new = CostModel(self.coeffs)
        by_kernel: dict = {}
        for row in observations:
            if not isinstance(row, dict) or row.get("mode") != mode:
                continue
            k = row.get("kernel")
            basis, us = row.get("basis"), row.get("measured_us")
            if k and isinstance(basis, dict) and isinstance(us, (int, float)):
                by_kernel.setdefault(k, []).append((basis, float(us)))
        for kernel, rows in by_kernel.items():
            if len(rows) < min_rows:
                continue
            names = sorted({n for basis, _ in rows for n in basis})
            if not names:
                continue
            X = np.array([[1.0] + [float(b.get(n, 0.0)) for n in names]
                          for b, _ in rows])
            y = np.array([us for _, us in rows])
            # scale-normalized ridge so the penalty is unit-agnostic
            scale = np.maximum(np.abs(X).max(axis=0), 1e-9)
            Xs = X / scale
            A = Xs.T @ Xs + ridge * np.eye(Xs.shape[1])
            try:
                theta = np.linalg.solve(A, Xs.T @ y) / scale
            except np.linalg.LinAlgError:
                continue
            theta = np.maximum(theta, 0.0)
            if not np.any(theta > 0):
                continue
            new.coeffs[kernel] = dict(
                intercept=float(theta[0]),
                **{n: float(t) for n, t in zip(names, theta[1:])})
        return new


_MODEL_CACHE: dict = {}


def _invalidate_model_cache() -> None:
    _MODEL_CACHE.clear()


def get_model(mode: str, refresh: bool = False) -> CostModel:
    """The process-wide model for a backend mode: shipped defaults refit
    against whatever same-mode observations the sidecar holds.  Memoized
    per (sidecar path, mode); new :func:`record_observations` writes
    invalidate the memo so every sweep immediately improves predictions.
    """
    key = (data_path(), mode)
    if not refresh and key in _MODEL_CACHE:
        return _MODEL_CACHE[key]
    model = CostModel().fit(load_observations(), mode)
    _MODEL_CACHE[key] = model
    return model
