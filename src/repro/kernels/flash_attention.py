"""Pallas TPU kernel: causal flash attention (forward).

The §Perf analysis (EXPERIMENTS.md) shows XLA-level flash streams its score
tiles through HBM, leaving prefill/train attention memory-bound; this kernel
keeps the (block_q x block_kv) tiles and the online-softmax accumulators in
VMEM — the real-TPU fix, behind the same semantics as
models/attention.flash_attention's forward (ref: kernels/ref.py:flash_ref).

Grid: (batch*heads, q blocks, kv blocks); the kv axis is the sequential
("arbitrary") dimension carrying (m, l, acc) scratch across iterations.
Backward on TPU uses the recomputing custom-VJP in models/attention.py (the
kernel slots in as its forward via ops.flash_forward when on TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, block_q: int, block_kv: int, scale: float, causal: bool,
):
    kv_i = pl.program_id(2)
    nkv = pl.num_programs(2)

    @pl.when(kv_i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)              # (block_q, hd)
    k = k_ref[0].astype(jnp.float32)              # (block_kv, hd)
    v = v_ref[0]                                   # (block_kv, dv)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                      # (block_q, block_kv)
    if causal:
        q_i = pl.program_id(1)
        q_pos = q_i * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0
        )
        k_pos = kv_i * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1
        )
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc_prev * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(kv_i == nkv - 1)
    def _finish():
        o_ref[0] = (acc_new / jnp.maximum(l_new, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_kv", "interpret")
)
def flash_forward(
    q: jax.Array,   # (B, S, H, hd)
    k: jax.Array,   # (B, T, H, hd)  (kv pre-expanded to H heads)
    v: jax.Array,   # (B, T, H, dv)
    *,
    causal: bool = True,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Causal attention forward == kernels/ref.py:flash_ref."""
    B, S, H, hd = q.shape
    T, dv = k.shape[1], v.shape[-1]
    block_q = min(block_q, S)
    block_kv = min(block_kv, T)
    while S % block_q:
        block_q //= 2
    while T % block_kv:
        block_kv //= 2

    # (B*H, S, hd) layout: one grid row per (batch, head)
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * H, T, dv)

    grid = (B * H, S // block_q, T // block_kv)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, block_q=block_q, block_kv=block_kv,
            scale=hd**-0.5, causal=causal,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_kv, dv), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dv), jnp.float32),
        ],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, S, dv).transpose(0, 2, 1, 3)
