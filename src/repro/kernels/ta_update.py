"""Pallas TPU kernel: batched Tsetlin Automata feedback deltas.

The training hot loop touches every (clause, literal) automaton per sample —
a purely memory-bound elementwise pass over the (C, L) state bank.  The FPGA
trainers the paper cites ([19]-[21]) feed it from on-chip LFSRs; here the
randomness is a counter-based integer hash generated *inside* the kernel
(kernels/ref.py:hash_u32), so no (B, C, L) random tensor ever exists in HBM.

Grid tiles (C, L); the batch is an in-kernel loop so each (block_c, block_l)
state tile is read once and its int32 delta accumulator stays in registers/
VMEM for all B samples — arithmetic intensity scales with B.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat

from repro.kernels import ref as kref


def _ta_delta_kernel(
    scal_ref, ta_ref, lit_ref, fire_ref, ft_ref, out_ref,
    *, n_batch: int, c_dim: int, l_dim: int, block_c: int, block_l: int,
    t_act, t_inact, global_clause: bool,
):
    c0 = pl.program_id(0) * block_c
    l0 = pl.program_id(1) * block_l

    c_idx = c0 + jax.lax.broadcasted_iota(jnp.uint32, (block_c, block_l), 0)
    l_idx = l0 + jax.lax.broadcasted_iota(jnp.uint32, (block_c, block_l), 1)
    seed = scal_ref[0, 0]
    b_off = scal_ref[0, 1]   # runtime scalar: chunk loops pass traced offsets
    if global_clause:        # clause-sharded caller: hash on GLOBAL clause id
        c_idx = c_idx + scal_ref[0, 2]

    excl = ta_ref[...] < 0                                    # (bc, bl)

    def body(b, acc):
        bu = jnp.uint32(b) + b_off
        gidx = (bu * jnp.uint32(c_dim) + c_idx) * jnp.uint32(l_dim) + l_idx
        r = kref.hash_u32(gidx, seed)
        act = (r < t_act).astype(jnp.int32)
        inact = (r < t_inact).astype(jnp.int32)

        lit_on = jax.lax.dynamic_slice_in_dim(lit_ref[...], b, 1, 0) == 1   # (1, bl)
        fire_b = jax.lax.dynamic_slice_in_dim(fire_ref[...], b, 1, 0) == 1  # (1, bc)
        ft = jax.lax.dynamic_slice_in_dim(ft_ref[...], b, 1, 0)             # (1, bc)
        fire_c = fire_b.reshape(block_c, 1)
        ft_c = ft.reshape(block_c, 1)

        d1 = jnp.where(fire_c, jnp.where(lit_on, act, -inact), -inact)
        d2 = (fire_c & ~lit_on & excl).astype(jnp.int32)
        d = jnp.where(ft_c == 1, d1, jnp.where(ft_c == 2, d2, 0))
        return acc + d

    out_ref[...] = jax.lax.fori_loop(
        0, n_batch, body, jnp.zeros((block_c, block_l), jnp.int32)
    )


@functools.partial(
    jax.jit,
    static_argnames=("p_act", "p_inact", "block_c", "block_l", "interpret",
                     "c_total"),
)
def ta_delta(
    ta: jax.Array,       # (C, L) int8
    lits: jax.Array,     # (B, L) uint8
    fire: jax.Array,     # (B, C) uint8
    ftype: jax.Array,    # (B, C) uint8 (0 none / 1 Type I / 2 Type II)
    seed: jax.Array,     # uint32 scalar
    *,
    p_act: float,
    p_inact: float,
    b_offset: int = 0,
    c_offset=0,
    c_total: int | None = None,
    block_c: int = 256,
    block_l: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """(C, L) int32 batch-summed feedback delta == kernels/ref.py:ta_delta_ref.

    ``c_total`` (static, with runtime ``c_offset``) switches the automaton
    hash to GLOBAL clause ids in a bank of ``c_total`` clauses — the
    clause-sharded trainer's indexing; the default keeps local ids.
    """
    C, L = ta.shape
    B = lits.shape[0]
    block_c = min(block_c, _rup(C, 8))
    block_l = min(block_l, _rup(L, 128))
    Cp, Lp = _rup(C, block_c), _rup(L, block_l)

    ta_p = jnp.pad(ta, ((0, Cp - C), (0, Lp - L)), constant_values=-1)
    lit_p = jnp.pad(lits, ((0, 0), (0, Lp - L)))
    fire_p = jnp.pad(fire, ((0, 0), (0, Cp - C)))
    ft_p = jnp.pad(ftype, ((0, 0), (0, Cp - C)))
    scal = jnp.stack([
        jnp.asarray(seed).astype(jnp.uint32),
        jnp.asarray(b_offset).astype(jnp.uint32),
        jnp.asarray(c_offset).astype(jnp.uint32),
    ]).reshape(1, 3)

    grid = (Cp // block_c, Lp // block_l)
    out = pl.pallas_call(
        functools.partial(
            _ta_delta_kernel,
            n_batch=B, c_dim=C if c_total is None else c_total, l_dim=L,
            block_c=block_c, block_l=block_l,
            t_act=kref.prob_to_u32(p_act), t_inact=kref.prob_to_u32(p_inact),
            global_clause=c_total is not None,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), lambda c, l: (0, 0)),            # seed/offs
            pl.BlockSpec((block_c, block_l), lambda c, l: (c, l)),  # ta
            pl.BlockSpec((B, block_l), lambda c, l: (0, l)),        # lits
            pl.BlockSpec((B, block_c), lambda c, l: (0, c)),        # fire
            pl.BlockSpec((B, block_c), lambda c, l: (0, c)),        # ftype
        ],
        out_specs=pl.BlockSpec((block_c, block_l), lambda c, l: (c, l)),
        out_shape=jax.ShapeDtypeStruct((Cp, Lp), jnp.int32),
        compiler_params=pallas_compat.CompilerParams(dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(scal, ta_p, lit_p, fire_p, ft_p)
    return out[:C, :L]


def _rup(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
