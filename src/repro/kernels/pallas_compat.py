"""Version shims for `jax.experimental.pallas.tpu` API drift.

jax renamed ``TPUCompilerParams`` to ``CompilerParams`` (and back-compat
varies by release); every kernel in this package imports the symbol from
here so the repo tracks whichever name the installed jax exposes.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
