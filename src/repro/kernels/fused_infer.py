"""Pallas TPU kernel: fused single-pass TM inference (clause eval + class sum).

This is the whole MATADOR inference datapath of paper Fig. 5 in ONE
``pallas_call`` — the Hard-Coded Clause Block chain feeding the class-sum
adder bank with no off-chip traffic in between.  The unfused pipeline
(``clause_eval.py`` then ``class_sum.py``) materializes the full ``(B, C)``
fired matrix in HBM; the eFPGA (arXiv:2502.07823) and 65-nm ASIC
(arXiv:2501.19347) TM accelerators both keep clause outputs on-chip, and so
does this kernel: the fired block lives in VMEM scratch and is folded into
the class-sum accumulator the moment its word chain completes.

Grid-axis map onto the paper's Fig. 5 stages:

  * axis 0 (``b``, parallel)   — datapoint packets: the Packetizer stream.
    Each step owns a ``(block_b,)`` slab of requests.
  * axis 1 (``c``, arbitrary)  — clause banks: which slice of the clause
    array (HCB column) is being evaluated.  Sequential, because every bank
    accumulates into the same ``(block_b, K)`` class-sum output block —
    this is the 2xCL adder bank being time-multiplexed.
  * axis 2 (``w``, arbitrary)  — the HCB chain itself: each step ANDs one
    ``block_w``-word literal window into the carried clause state
    (``Clause In``/``Clause Out`` in Fig. 5), held in VMEM scratch.
    HCB 0 initializes all clauses to 1.

On the last chain step the finished clause block is masked by the
``nonempty`` vector (empty clauses output 0 at inference, paper §III) and
folded into the int32 class sums via one MXU dot — the fired matrix never
exists in HBM at any block size.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat


def _fused_infer_kernel(
    lit_ref,    # (block_b, block_w) uint32 literal words
    inc_ref,    # (block_c, block_w) uint32 include words
    votes_ref,  # (block_c, Kp) int32 polarity votes
    ne_ref,     # (1, block_c) int32 nonempty mask
    out_ref,    # (block_b, Kp) int32 class-sum accumulator
    ok_ref,     # VMEM scratch (block_b, block_c) int32 carried clause state
    *,
    block_w: int,
):
    c = pl.program_id(1)
    w = pl.program_id(2)
    nw = pl.num_programs(2)

    @pl.when((c == 0) & (w == 0))
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(w == 0)
    def _init_ok():  # HCB 0: all clauses start at 1
        ok_ref[...] = jnp.ones_like(ok_ref)

    lit = lit_ref[...]
    inc = inc_ref[...]

    def body(i, ok):
        l_w = jax.lax.dynamic_slice_in_dim(lit, i, 1, axis=1)   # (bb, 1)
        i_w = jax.lax.dynamic_slice_in_dim(inc, i, 1, axis=1)   # (bc, 1)
        viol = jnp.bitwise_and(i_w.reshape(1, -1), ~l_w)        # (bb, bc)
        return ok & (viol == 0)

    ok = jax.lax.fori_loop(0, block_w, body, ok_ref[...] != 0, unroll=True)

    @pl.when(w < nw - 1)
    def _carry():  # Clause Out -> next HCB's Clause In
        ok_ref[...] = ok.astype(ok_ref.dtype)

    @pl.when(w == nw - 1)
    def _fold():  # adder bank: mask empties, accumulate the finished block
        fired = (ok & (ne_ref[...] != 0)).astype(jnp.int32)     # (bb, bc)
        out_ref[...] += jax.lax.dot_general(
            fired, votes_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_c", "block_w", "interpret"),
)
def fused_tm_forward(
    lit_words: jax.Array,           # (B, W) uint32
    inc_words: jax.Array,           # (C, W) uint32
    votes: jax.Array,               # (C, K) int32
    nonempty: jax.Array | None = None,   # (C,) {0,1}; None = no masking
    *,
    block_b: int = 128,
    block_c: int = 128,
    block_w: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """Packed literals -> (B, K) int32 class sums, single fused pass.

    Bit-identical to ``class_sum_ref(clause_fire_ref(lit, inc) * nonempty,
    votes)``; with ``nonempty=None`` to the unmasked (training-semantics)
    composition.
    """
    B, W = lit_words.shape
    C, Wc = inc_words.shape
    K = votes.shape[1]
    assert W == Wc, (W, Wc)
    assert votes.shape[0] == C, (votes.shape, C)

    if nonempty is None:
        nonempty = jnp.ones((C,), jnp.int32)

    block_b = min(block_b, _rup(B, 8))
    block_c = min(block_c, _rup(C, 128))
    block_w = min(block_w, W)

    Bp, Cp, Wp = _rup(B, block_b), _rup(C, block_c), _rup(W, block_w)
    Kp = _rup(K, 128)
    lit = _pad2(lit_words, Bp, Wp)
    inc = _pad2(inc_words, Cp, Wp)      # zero include words never violate
    vts = _pad2(votes.astype(jnp.int32), Cp, Kp)   # padded clauses vote 0
    ne = jnp.pad(nonempty.astype(jnp.int32), (0, Cp - C))[None, :]  # (1, Cp)

    grid = (Bp // block_b, Cp // block_c, Wp // block_w)
    out = pl.pallas_call(
        functools.partial(_fused_infer_kernel, block_w=block_w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_w), lambda b, c, w: (b, w)),
            pl.BlockSpec((block_c, block_w), lambda b, c, w: (c, w)),
            pl.BlockSpec((block_c, Kp), lambda b, c, w: (c, 0)),
            pl.BlockSpec((1, block_c), lambda b, c, w: (0, c)),
        ],
        out_specs=pl.BlockSpec((block_b, Kp), lambda b, c, w: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, Kp), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_b, block_c), jnp.int32)],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")
        ),
        interpret=interpret,
    )(lit, inc, vts, ne)
    return out[:B, :K]


def _rup(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad2(x: jax.Array, d0: int, d1: int) -> jax.Array:
    return jnp.pad(x, ((0, d0 - x.shape[0]), (0, d1 - x.shape[1])))
