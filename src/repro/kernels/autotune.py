"""Block-size autotuner for the fused TM inference kernel.

The fused kernel's throughput is a function of its ``(block_b, block_c,
block_w)`` tiling, and the best tiling depends on problem shape and backend
(VMEM budget, grid overhead, interpret vs compiled).  This module sweeps a
small candidate grid once per ``(shape, backend)`` and memoizes the winner
in an on-disk JSON cache so serving processes never re-pay the sweep.

Cache location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro/autotune.json``.  Entries are keyed by
``fused_infer:v1:<backend>:<interp|compiled>:B..C..W..K..`` so a TPU run
never reads CPU-interpret timings and vice versa.
"""

from __future__ import annotations

import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import fused_infer

_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
_KEY_VERSION = "v1"

# candidate tilings: a deliberately small grid — the sweep is paid once per
# shape and cached, but each candidate costs a kernel compile.
_DEFAULT_CANDIDATES = (
    (128, 128, 64),   # clause_eval.py's defaults (VMEM-lean)
    (128, 256, 64),   # wider clause bank: fewer adder-fold steps
    (256, 128, 64),   # taller request slab: fewer batch steps
    (256, 256, 32),
    (512, 512, 16),   # few big tiles: minimal grid overhead (small models)
    (64, 512, 64),
)


def cache_path() -> str:
    p = os.environ.get(_CACHE_ENV)
    if p:
        return p
    return os.path.join(os.path.expanduser("~"), ".cache", "repro", "autotune.json")


def _load_cache() -> dict:
    try:
        with open(cache_path()) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _save_cache(cache: dict) -> None:
    path = cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(cache, f, indent=1, sort_keys=True)
    # os.replace keeps the file whole; concurrent tuners are last-writer-wins
    # (worst case a lost entry's sweep is re-paid, never a torn file)
    os.replace(tmp, path)


def _shape_key(B, C, W, K, interpret, clipped_candidates) -> str:
    mode = "interp" if interpret else "compiled"
    backend = jax.default_backend()
    # the candidate set is part of the key: a sweep over a restricted custom
    # candidate list must not answer for the default sweep (or vice versa)
    cands = ",".join("x".join(map(str, c)) for c in clipped_candidates)
    return (f"fused_infer:{_KEY_VERSION}:{backend}:{mode}:"
            f"B{B}:C{C}:W{W}:K{K}:cands[{cands}]")


def _clip_candidate(blocks, B: int, C: int, W: int):
    """Apply the same clipping the kernel wrapper does, so duplicate
    post-clip candidates are swept only once."""
    bb, bc, bw = blocks
    bb = min(bb, fused_infer._rup(B, 8))
    bc = min(bc, fused_infer._rup(C, 128))
    bw = min(bw, W)
    return bb, bc, bw


def _sweep(lit, inc, votes, nonempty, candidates, *, interpret, reps) -> dict:
    """min seconds per candidate tiling, timed round-robin so container
    noise drifts over every candidate equally instead of biasing the sweep
    order."""
    runs = {}
    for bb, bc, bw in candidates:
        run = functools.partial(
            fused_infer.fused_tm_forward, lit, inc, votes, nonempty,
            block_b=bb, block_c=bc, block_w=bw, interpret=interpret,
        )
        run().block_until_ready()      # compile + warm
        runs[(bb, bc, bw)] = run
    best = {k: float("inf") for k in runs}
    for _ in range(reps):
        for k, run in runs.items():
            t0 = time.perf_counter()
            run().block_until_ready()
            best[k] = min(best[k], time.perf_counter() - t0)
    return best


def autotune_fused_blocks(
    B: int,
    C: int,
    W: int,
    K: int,
    *,
    interpret: bool,
    candidates=None,
    reps: int = 5,
    refresh: bool = False,
) -> dict:
    """Best ``{block_b, block_c, block_w}`` for a fused-inference shape.

    Sweeps ``candidates`` on synthetic data of the given shape, memoizing
    the winner on disk.  ``refresh=True`` ignores (and overwrites) any
    cached entry.
    """
    clipped = []
    for cand in candidates or _DEFAULT_CANDIDATES:
        c = _clip_candidate(cand, B, C, W)
        if c not in clipped:
            clipped.append(c)

    key = _shape_key(B, C, W, K, interpret, clipped)
    cache = _load_cache()
    if not refresh and key in cache:
        return dict(cache[key]["blocks"])

    rng = np.random.default_rng(0)
    lit = jnp.asarray(rng.integers(0, 2**32, (B, W), dtype=np.uint32))
    inc = jnp.asarray(rng.integers(0, 2**32, (C, W), dtype=np.uint32))
    votes = jnp.asarray(rng.integers(-2, 3, (C, K), dtype=np.int32))
    nonempty = jnp.ones((C,), jnp.int32)

    timings = _sweep(
        lit, inc, votes, nonempty, clipped, interpret=interpret, reps=reps
    )
    # within the measurement noise floor, prefer the largest tiling: fewer
    # grid steps is the structurally better config when timings can't
    # separate the candidates
    t_min = min(timings.values())
    best_blocks = max(
        (blk for blk, t in timings.items() if t <= t_min * 1.05),
        key=lambda blk: blk[0] * blk[1] * blk[2],
    )
    best_t = timings[best_blocks]

    bb, bc, bw = best_blocks
    result = dict(block_b=bb, block_c=bc, block_w=bw)
    cache = _load_cache()   # re-read to narrow the concurrent-writer window
    cache[key] = dict(blocks=result, us_per_call=best_t * 1e6)
    _save_cache(cache)
    return result
