"""Block-size autotuner for the fused TM Pallas kernels.

A fused kernel's throughput is a function of its ``(block_b, block_c,
block_w)`` tiling, and the best tiling depends on problem shape and backend
(VMEM budget, grid overhead, interpret vs compiled).  All four tuned
kernels (``fused_infer``, ``fused_train``, ``sparse_infer``, ``term_infer``)
register here (:data:`_REGISTRY`) and are tuned through ONE facade:

    tune("sparse_infer", B=512, K=10, include_words=iw,
         interpret=True, policy="verify")

with a three-mode ``policy``:

* ``"sweep"`` — wall-clock-time every candidate (the classic behavior),
  memoize the winner in the on-disk cache, and log every ``(basis,
  tiling, measured_us)`` observation into the cost model's training-data
  sidecar (``kernels/cost_model.py``) so sweeps anywhere keep improving
  predictions.
* ``"verify"`` (default) — rank candidates with the analytical cost
  model, then time only the predicted top-``k``.
* ``"predict"`` — trust the model outright: ZERO timing runs (the
  module-level :data:`TIMING_RUNS` counter proves it), which is what a
  multi-tenant zoo cold-load needs.

The legacy ``autotune_*_blocks`` entry points are thin wrappers over
``tune(..., policy="sweep")`` with identical cache keys and results.

Cache location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro/autotune.json``.  The file is ``{"schema": N, "entries":
{...}}``; a schema mismatch (older repo version, foreign writer, corrupt
file) invalidates the whole cache instead of crashing or silently reusing
blocks tuned for a different kernel signature.  Entries are keyed by
``<kernel>:v1:<backend>:<interp|compiled>:<shape>:cands[...]`` so a TPU run
never reads CPU-interpret timings, inference timings never answer for
training shapes, and vice versa; model-assisted policies add a
``:p<policy>`` tag so a prediction never masquerades as a measurement.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (cost_model, fused_infer, fused_train, sparse_infer,
                           term_infer)

_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
_KEY_VERSION = "v1"
# Bump when the on-disk layout (or the meaning of cached blocks) changes:
# schema 1 was the bare key->entry dict; schema 2 wrapped it in
# {"schema", "entries"}; schema 3 adds policy-tagged entries (":pverify" /
# ":ppredict" keys, "policy"/"predicted_us" fields) — a schema-2 cache may
# hold winners a model-restricted sweep would not have picked, so it
# invalidates wholesale like any other stale schema.
_SCHEMA_VERSION = 3

POLICIES = ("sweep", "verify", "predict")

# Every wall-clock kernel invocation the tuner makes (warmup included)
# increments this: ``policy="predict"`` leaving it untouched is the
# zero-timing-runs guarantee, asserted by tests and the regret benchmark.
TIMING_RUNS = 0

# candidate tilings: a deliberately small grid — the sweep is paid once per
# shape and cached, but each candidate costs a kernel compile.
_DEFAULT_CANDIDATES = (
    (128, 128, 64),   # clause_eval.py's defaults (VMEM-lean)
    (128, 256, 64),   # wider clause bank: fewer adder-fold steps
    (256, 128, 64),   # taller request slab: fewer batch steps
    (256, 256, 32),
    (512, 512, 16),   # few big tiles: minimal grid overhead (small models)
    (64, 512, 64),
    (512, 1024, 256),  # whole word chain per step (wide-literal shapes)
)

# sparse (chain-schedule) kernel candidates: (block_c, block_j, block_s) —
# clause bank x chain-tile bits x sample-word slab.  The schedule is
# rebuilt per candidate (tile tables depend on the tiling), so the sweep
# measures real tile counts, not synthetic occupancy.
_SPARSE_CANDIDATES = (
    (512, 32, 16),    # sparse_infer.py defaults
    (1024, 32, 16),
    (512, 64, 16),
    (256, 32, 16),
    (1024, 64, 8),
    (512, 16, 16),
    (2048, 128, 16),  # long-chain trained banks: few big whole-chain tiles
    (4096, 128, 16),
)

# factorized (two-level term-schedule) kernel candidates: (block_c,
# block_j, block_t, block_s, term_w) — clause bank x term-chain tile x
# stage-1 term tile x sample-word slab x term bit-chain width (0 = the
# artifact's auto width).  Schedules are rebuilt per candidate: term table
# size and tile counts depend on the tiling.
_TERM_CANDIDATES = (
    (1024, 64, 32768, 16, 0),   # term_infer.py defaults, auto width
    (1024, 64, 32768, 16, 2),   # narrowest rows: fat terms split to pieces
    (1024, 128, 32768, 16, 2),
    (2048, 128, 32768, 16, 2),
    (4096, 64, 32768, 16, 2),
    (1024, 32, 16384, 16, 0),
    (512, 32, 4096, 16, 0),     # small-artifact shapes clip here
)

# training kernel candidates: the delta accumulator block is (block_c, L),
# so block_c also scales VMEM; block_b scales the fire/ftype scratch.
_TRAIN_CANDIDATES = (
    (128, 256, 64),   # fused_train.py defaults
    (128, 128, 64),
    (256, 256, 64),
    (64, 512, 64),
    (256, 512, 32),
)


def cache_path() -> str:
    p = os.environ.get(_CACHE_ENV)
    if p:
        return p
    return os.path.join(os.path.expanduser("~"), ".cache", "repro", "autotune.json")


def _load_cache() -> dict:
    """Entry dict from disk; {} on missing, corrupt, or stale-schema files."""
    try:
        with open(cache_path()) as f:
            raw = json.load(f)
    except (OSError, ValueError):
        return {}
    if not isinstance(raw, dict) or raw.get("schema") != _SCHEMA_VERSION:
        return {}   # stale schema: invalidate, never reuse or crash
    entries = raw.get("entries")
    return entries if isinstance(entries, dict) else {}


def _save_cache(entries: dict) -> None:
    path = cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump({"schema": _SCHEMA_VERSION, "entries": entries},
                  f, indent=1, sort_keys=True)
    # os.replace keeps the file whole; concurrent tuners are last-writer-wins
    # (worst case a lost entry's sweep is re-paid, never a torn file)
    os.replace(tmp, path)


def _clip_candidate(blocks, B: int, C: int, W: int):
    """Apply the same clipping the kernel wrappers do, so duplicate
    post-clip candidates are swept only once."""
    bb, bc, bw = blocks
    bb = min(bb, fused_infer._rup(B, 8))
    bc = min(bc, fused_infer._rup(C, 128))
    bw = min(bw, W)
    return bb, bc, bw


def _clipped(candidates, B, C, W):
    out = []
    for cand in candidates:
        c = _clip_candidate(cand, B, C, W)
        if c not in out:
            out.append(c)
    return out


def _sweep(runs: dict, reps: int) -> dict:
    """min seconds per candidate tiling, timed round-robin so container
    noise drifts over every candidate equally instead of biasing the sweep
    order."""
    global TIMING_RUNS
    for run in runs.values():
        TIMING_RUNS += 1
        run().block_until_ready()      # compile + warm
    best = {k: float("inf") for k in runs}
    for _ in range(reps):
        for k, run in runs.items():
            TIMING_RUNS += 1
            t0 = time.perf_counter()
            run().block_until_ready()
            best[k] = min(best[k], time.perf_counter() - t0)
    return best


# in-process memo so hot loops (e.g. launch/train.py --autotune calling the
# resolver every step) never re-read and re-parse the on-disk JSON; keyed by
# (cache file, entry) so switching $REPRO_AUTOTUNE_CACHE mid-process works.
_PROC_CACHE: dict = {}


_DENSE_KEYS = ("block_b", "block_c", "block_w")


def _memoized_best(key: str, make_runs, reps: int, refresh: bool,
                   block_names=_DENSE_KEYS, observe=None) -> dict:
    """Sweep (or recall) the best block dict for `key`; ``block_names``
    labels the candidate-tuple fields (dense kernels use block_b/c/w, the
    sparse schedule kernel block_c/j/s).  ``observe(timings)`` fires only
    when a sweep actually ran (never on cache hits) — the tune facade uses
    it to feed the cost model's training-data sidecar."""
    pkey = (cache_path(), key)
    if not refresh and pkey in _PROC_CACHE:
        return dict(_PROC_CACHE[pkey])
    cache = _load_cache()
    if not refresh and key in cache:
        _PROC_CACHE[pkey] = dict(cache[key]["blocks"])
        return dict(cache[key]["blocks"])

    timings = _sweep(make_runs(), reps)
    if observe is not None:
        observe(timings)
    # within the measurement noise floor, prefer the largest tiling: fewer
    # grid steps is the structurally better config when timings can't
    # separate the candidates
    t_min = min(timings.values())
    best_blocks = max(
        (blk for blk, t in timings.items() if t <= t_min * 1.05),
        key=lambda blk: math.prod(blk),
    )
    result = dict(zip(block_names, best_blocks))
    cache = _load_cache()   # re-read to narrow the concurrent-writer window
    cache[key] = dict(blocks=result, us_per_call=timings[best_blocks] * 1e6)
    _save_cache(cache)
    _PROC_CACHE[pkey] = dict(result)
    return result


def _mode_backend(interpret: bool) -> str:
    mode = "interp" if interpret else "compiled"
    return f"{jax.default_backend()}:{mode}"


def _cands_tag(clipped) -> str:
    # the candidate set is part of the key: a sweep over a restricted custom
    # candidate list must not answer for the default sweep (or vice versa)
    return ",".join("x".join(map(str, c)) for c in clipped)


def _artifact_tag(include_words) -> str:
    """Short content hash of an artifact's include rows: the sparse
    kernel's runtime depends on the SCHEDULE (tile counts, chain lengths),
    so two same-shape artifacts with different sparsity must not share a
    cache entry.  Same hashing rule as the schedule memo
    (``sparse_infer.artifact_tag``)."""
    return sparse_infer.artifact_tag(include_words)[:10]


def _clip_sparse_candidate(blocks, B: int, U: int):
    bc, bj, bs = blocks
    bc = min(bc, fused_infer._rup(max(U, 1), 8))
    bs = max(min(bs, fused_infer._rup(-(-B // 32), 1)), 1)
    return bc, bj, bs


def _lit_tag(lit_words) -> str:
    """Key fragment for a caller-supplied representative literal stream:
    tunings measured on different workloads must not share an entry (a
    random stream kills trained chains in one tile — its winner can lose
    on the in-distribution stream a server actually sees)."""
    if lit_words is None:
        return ""
    return ":lit" + sparse_infer.artifact_tag(np.asarray(lit_words))[:10]


def _clip_term_candidate(blocks, B: int, U: int, iw, n_pieces_bound: int
                         ) -> tuple:
    bc, bj, bt, bs, tw = blocks
    bc = min(bc, fused_infer._rup(max(U, 1), 8))
    bs = max(min(bs, fused_infer._rup(-(-B // 32), 1)), 1)
    if tw == 0:   # 0 = the artifact's auto width (resolved so duplicate
        tw = term_infer.pick_term_width(iw)   # post-clip candidates dedup)
    # the schedule builder clips block_t to its term count; apply the same
    # bound here (pieces <= total include bits) so small artifacts dedup
    # candidates that only differ in an unreachable block_t
    bt = max(min(bt, fused_infer._rup(n_pieces_bound + 1, 8)), 1)
    return bc, bj, bt, bs, tw


# ---------------------------------------------------------------------------
# Kernel registry: candidates, cache keys, timed runs, and cost-model basis
# ---------------------------------------------------------------------------

def _ceil_div(a: int, b: int) -> int:
    return -(-a // max(b, 1))


@dataclasses.dataclass(frozen=True)
class KernelTuner:
    """One tuned kernel's registration: how to clip/dedup its candidate
    tuples, key its cache entries, build timed runs, and featurize a
    candidate into the cost model's roofline-style basis terms.  All four
    callables take the normalized ``problem`` dict built by ``prepare``
    from ``tune(...)``'s shape kwargs — this registry is the cost model's
    single registration point (a fifth kernel plugs in here and every
    policy, sidecar row, and benchmark picks it up)."""
    name: str
    block_names: tuple
    default_candidates: tuple
    default_reps: int
    prepare: callable       # (**shape_kwargs) -> problem dict
    clip: callable          # (candidates, problem) -> unique clipped tuples
    cache_key: callable     # (problem, clipped, mode) -> sweep cache key
    make_runs: callable     # (problem, clipped, interpret) -> {cand: thunk}
    basis: callable         # (problem, cand) -> {basis_term: float}


_REGISTRY: dict = {}


def register(tuner: KernelTuner) -> None:
    _REGISTRY[tuner.name] = tuner


def kernels() -> tuple:
    """Registered tunable kernel names."""
    return tuple(_REGISTRY)


# -- fused dense inference ---------------------------------------------------

def _dense_prepare(*, B, C, W, K):
    return dict(B=int(B), C=int(C), W=int(W), K=int(K))


def _dense_clip(candidates, p):
    return _clipped(candidates, p["B"], p["C"], p["W"])


def _dense_key(p, clipped, mode):
    return (f"fused_infer:{_KEY_VERSION}:{mode}:"
            f"B{p['B']}:C{p['C']}:W{p['W']}:K{p['K']}:"
            f"cands[{_cands_tag(clipped)}]")


def _dense_runs(p, clipped, interpret):
    B, C, W, K = p["B"], p["C"], p["W"], p["K"]
    rng = np.random.default_rng(0)
    lit = jnp.asarray(rng.integers(0, 2**32, (B, W), dtype=np.uint32))
    inc = jnp.asarray(rng.integers(0, 2**32, (C, W), dtype=np.uint32))
    votes = jnp.asarray(rng.integers(-2, 3, (C, K), dtype=np.int32))
    nonempty = jnp.ones((C,), jnp.int32)
    return {
        (bb, bc, bw): functools.partial(
            fused_infer.fused_tm_forward, lit, inc, votes, nonempty,
            block_b=bb, block_c=bc, block_w=bw, interpret=interpret,
        )
        for bb, bc, bw in clipped
    }


def _dense_basis(p, cand):
    """Roofline terms for one (block_b, block_c, block_w): grid steps
    (per-step dispatch dominates interpret mode), padded clause-eval
    volume, class-sum fold volume, and HBM tile traffic."""
    B, C, W, K = p["B"], p["C"], p["W"], p["K"]
    bb, bc, bw = cand
    nb, nc, nw = _ceil_div(B, bb), _ceil_div(C, bc), _ceil_div(W, bw)
    steps = nb * nc * nw
    return dict(
        steps=float(steps),
        work_melem=steps * bb * bc * bw / 1e6,
        fold_melem=nb * nc * bb * bc * K / 1e6,
        bytes_mb=steps * (bb * bw + bc * bw) * 4 / 1e6,
    )


register(KernelTuner(
    name="fused_infer", block_names=_DENSE_KEYS,
    default_candidates=_DEFAULT_CANDIDATES, default_reps=5,
    prepare=_dense_prepare, clip=_dense_clip, cache_key=_dense_key,
    make_runs=_dense_runs, basis=_dense_basis,
))


# -- fused training ----------------------------------------------------------

def _train_prepare(*, B, C, W, L, K):
    return dict(B=int(B), C=int(C), W=int(W), L=int(L), K=int(K))


def _train_clip(candidates, p):
    return _clipped(candidates, p["B"], p["C"], p["W"])


def _train_key(p, clipped, mode):
    return (f"fused_train:{_KEY_VERSION}:{mode}:"
            f"B{p['B']}:C{p['C']}:W{p['W']}:L{p['L']}:K{p['K']}:"
            f"cands[{_cands_tag(clipped)}]")


def _train_runs(p, clipped, interpret):
    from repro.core import packetizer

    B, C, W, L, K = p["B"], p["C"], p["W"], p["L"], p["K"]
    rng = np.random.default_rng(0)
    bits = rng.integers(0, 2, (B, L), dtype=np.uint8)
    lits = jnp.asarray(bits)
    lit_words = jnp.asarray(packetizer.pack_bits_np(bits))
    inc_bits = (rng.random((C, L)) < 0.05).astype(np.uint8)
    inc_full = np.zeros((C, W * 32), np.uint8)
    inc_full[:, :L] = inc_bits
    inc_words = jnp.asarray(packetizer.pack_bits_np(inc_full))
    ta = jnp.asarray(rng.integers(-64, 64, (C, L), dtype=np.int8))
    y = jnp.asarray(rng.integers(0, K, B, dtype=np.int32))
    kn = jnp.asarray((y + 1) % K, jnp.int32)
    p_t = jnp.asarray(rng.random(B, dtype=np.float32))
    p_n = jnp.asarray(rng.random(B, dtype=np.float32))
    cpc = max(1, C // K)
    cls = jnp.asarray(np.clip(np.arange(C) // cpc, 0, K - 1), jnp.int32)
    pol = jnp.asarray(np.where(np.arange(C) % 2 == 0, 1, -1), jnp.int32)
    seed = jnp.uint32(0)
    return {
        (bb, bc, bw): functools.partial(
            fused_train.fused_tm_train_delta,
            ta, lits, lit_words, inc_words, y, kn, p_t, p_n, cls, pol,
            seed, p_act=1.0, p_inact=0.1,
            block_b=bb, block_c=bc, block_w=bw, interpret=interpret,
        )
        for bb, bc, bw in clipped
    }


def _train_basis(p, cand):
    """Dense-inference terms plus the (block_c, L) delta-accumulator and
    (block_b, L) literal-slab traffic the training kernel adds."""
    B, C, W, L, K = p["B"], p["C"], p["W"], p["L"], p["K"]
    bb, bc, bw = cand
    nb, nc, nw = _ceil_div(B, bb), _ceil_div(C, bc), _ceil_div(W, bw)
    steps = nb * nc * nw
    return dict(
        steps=float(steps),
        work_melem=steps * bb * bc * bw / 1e6,
        l_work_melem=nb * nc * (bc + bb) * L / 1e6,
        bytes_mb=(steps * (bb * bw + bc * bw) + nb * nc * bc * L) * 4 / 1e6,
    )


register(KernelTuner(
    name="fused_train", block_names=_DENSE_KEYS,
    default_candidates=_TRAIN_CANDIDATES, default_reps=3,
    prepare=_train_prepare, clip=_train_clip, cache_key=_train_key,
    make_runs=_train_runs, basis=_train_basis,
))


# -- sparse chain-schedule inference -----------------------------------------

def _sparse_prepare(*, B, K, include_words, lit_words=None):
    iw = np.ascontiguousarray(np.asarray(include_words, dtype=np.uint32))
    U, Wa = iw.shape
    return dict(B=int(B), K=int(K), iw=iw, U=U, Wa=Wa, lit_words=lit_words)


def _sparse_clip(candidates, p):
    clipped = []
    for cand in candidates:
        c = _clip_sparse_candidate(cand, p["B"], p["U"])
        if c not in clipped:
            clipped.append(c)
    return clipped


def _sparse_key(p, clipped, mode):
    return (f"sparse_infer:{_KEY_VERSION}:{mode}:"
            f"B{p['B']}:U{p['U']}:W{p['Wa']}:K{p['K']}:"
            f"sig{_artifact_tag(p['iw'])}{_lit_tag(p['lit_words'])}:"
            f"cands[{_cands_tag(clipped)}]")


def _sparse_runs(p, clipped, interpret):
    rng = np.random.default_rng(0)
    lw = p["lit_words"]
    lit = (jnp.asarray(np.asarray(lw)) if lw is not None
           else jnp.asarray(
               rng.integers(0, 2**32, (p["B"], p["Wa"]), dtype=np.uint32)))
    votes = jnp.asarray(
        rng.integers(-2, 3, (p["U"], p["K"]), dtype=np.int32))
    runs = {}
    for bc, bj, bs in clipped:
        sched = sparse_infer.build_schedule(p["iw"], block_c=bc, block_j=bj)
        runs[(bc, bj, bs)] = functools.partial(
            sparse_infer.sparse_tm_forward, lit, votes, sched,
            block_s=bs, interpret=interpret,
        )
    return runs


def _sparse_basis(p, cand):
    """Terms from the REAL ragged schedule this candidate would execute
    (``build_schedule_cached`` — numpy-only, memoized): actual tile count
    and clause-block count, not a dense-occupancy guess."""
    bc, bj, bs = cand
    sched = sparse_infer.build_schedule_cached(
        p["iw"], block_c=bc, block_j=bj)
    n_tiles = int(len(sched.tile_cb))
    n_cblocks = int(len(sched.counts))
    sw = _ceil_div(_ceil_div(p["B"], 32), bs)
    steps = sw * n_tiles
    return dict(
        steps=float(steps),
        chain_melem=steps * bc * bj * bs / 1e6,
        fold_melem=sw * n_cblocks * bc * p["K"] * bs / 1e6,
        bytes_mb=steps * bc * bj * 4 / 1e6,
    )


register(KernelTuner(
    name="sparse_infer", block_names=("block_c", "block_j", "block_s"),
    default_candidates=_SPARSE_CANDIDATES, default_reps=5,
    prepare=_sparse_prepare, clip=_sparse_clip, cache_key=_sparse_key,
    make_runs=_sparse_runs, basis=_sparse_basis,
))


# -- factorized two-level term-schedule inference ----------------------------

def _term_prepare(*, B, K, include_words, lit_words=None):
    iw = np.ascontiguousarray(np.asarray(include_words, dtype=np.uint32))
    U, Wa = iw.shape
    n_bits_total = int(np.unpackbits(iw.view(np.uint8)).sum())
    return dict(B=int(B), K=int(K), iw=iw, U=U, Wa=Wa,
                n_bits_total=n_bits_total, lit_words=lit_words)


def _term_clip(candidates, p):
    clipped = []
    for cand in candidates:
        c = _clip_term_candidate(cand, p["B"], p["U"], p["iw"],
                                 p["n_bits_total"])
        if c not in clipped:
            clipped.append(c)
    return clipped


def _term_key(p, clipped, mode):
    return (f"term_infer:{_KEY_VERSION}:{mode}:"
            f"B{p['B']}:U{p['U']}:W{p['Wa']}:K{p['K']}:"
            f"sig{_artifact_tag(p['iw'])}{_lit_tag(p['lit_words'])}:"
            f"cands[{_cands_tag(clipped)}]")


def _term_runs(p, clipped, interpret):
    rng = np.random.default_rng(0)
    lw = p["lit_words"]
    lit = (jnp.asarray(np.asarray(lw)) if lw is not None
           else jnp.asarray(
               rng.integers(0, 2**32, (p["B"], p["Wa"]), dtype=np.uint32)))
    votes = jnp.asarray(
        rng.integers(-2, 3, (p["U"], p["K"]), dtype=np.int32))
    runs = {}
    for bc, bj, bt, bs, tw in clipped:
        sched = term_infer.build_factorized_schedule(
            p["iw"], block_c=bc, block_j=bj, block_t=bt, term_w=tw)
        runs[(bc, bj, bt, bs, tw)] = functools.partial(
            term_infer.factorized_tm_forward, lit, votes, sched,
            block_s=bs, interpret=interpret,
        )
    return runs


def _term_basis(p, cand):
    """Terms from the real factorized schedule: the stage-1 (term eval) /
    stage-2 (clause chain) tile split and the term-table size are
    properties of the trained artifact + tiling, so both stages get their
    own work term for the model to weight."""
    bc, bj, bt, bs, tw = cand
    sched = term_infer.build_factorized_schedule_cached(
        p["iw"], block_c=bc, block_j=bj, block_t=bt, term_w=tw)
    stage = np.asarray(sched.tile_stage)
    n_tiles = int(len(stage))
    n_term_tiles = int((stage == 0).sum())
    n_clause_tiles = n_tiles - n_term_tiles
    n_cblocks = int(len(sched.counts))
    sw = _ceil_div(_ceil_div(p["B"], 32), bs)
    return dict(
        steps=float(sw * n_tiles),
        term_melem=sw * n_term_tiles * bt * tw * bs / 1e6,
        chain_melem=sw * n_clause_tiles * bc * bj * bs / 1e6,
        fold_melem=sw * n_cblocks * bc * p["K"] * bs / 1e6,
        bytes_mb=sw * (n_term_tiles * bt * tw
                       + n_clause_tiles * bc * bj) * 4 / 1e6,
    )


register(KernelTuner(
    name="term_infer",
    block_names=("block_c", "block_j", "block_t", "block_s", "term_w"),
    default_candidates=_TERM_CANDIDATES, default_reps=5,
    prepare=_term_prepare, clip=_term_clip, cache_key=_term_key,
    make_runs=_term_runs, basis=_term_basis,
))


# ---------------------------------------------------------------------------
# The facade
# ---------------------------------------------------------------------------

def tune(
    kernel: str,
    *,
    interpret: bool,
    policy: str = "verify",
    top_k: int = 3,
    candidates=None,
    reps: int | None = None,
    refresh: bool = False,
    features: dict | None = None,
    **shape,
) -> dict:
    """Best block dict for one registered kernel under a tuning policy.

    ``shape`` kwargs are per kernel: ``fused_infer`` takes ``B, C, W, K``;
    ``fused_train`` adds ``L``; ``sparse_infer``/``term_infer`` take
    ``B, K, include_words`` (+ optional ``lit_words`` representative
    stream).  ``features`` optionally attaches the artifact's
    candidate-independent feature dict (``cost_model.artifact_features``)
    to the sidecar rows a sweep logs.

    Policies: ``"sweep"`` times every candidate; ``"verify"`` times only
    the cost model's top-``top_k``; ``"predict"`` returns the model's
    top-1 with zero timing runs.  All three memoize on disk — predictions
    under a ``:ppredict``-tagged key carrying ``predicted_us`` instead of
    a measurement, so a later sweep of the same shape never reads them.
    """
    try:
        tuner = _REGISTRY[kernel]
    except KeyError:
        raise ValueError(
            f"unknown kernel {kernel!r}; registered: {sorted(_REGISTRY)}")
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")

    problem = tuner.prepare(**shape)
    clipped = tuner.clip(candidates or tuner.default_candidates, problem)
    mode = _mode_backend(interpret)
    base_key = tuner.cache_key(problem, clipped, mode)
    reps = tuner.default_reps if reps is None else reps

    def observe(cands):
        def _log(timings):
            rows = [cost_model.make_observation(
                kernel, mode, dict(zip(tuner.block_names, cand)),
                tuner.basis(problem, cand), t * 1e6, features)
                for cand, t in timings.items()]
            cost_model.record_observations(rows)
        return _log

    if policy == "sweep":
        return _memoized_best(
            base_key, lambda: tuner.make_runs(problem, clipped, interpret),
            reps, refresh, block_names=tuner.block_names,
            observe=observe(clipped))

    ranked = cost_model.get_model(mode).rank(
        kernel, [(cand, tuner.basis(problem, cand)) for cand in clipped])

    if policy == "predict":
        key = f"{base_key}:ppredict"
        pkey = (cache_path(), key)
        if not refresh and pkey in _PROC_CACHE:
            return dict(_PROC_CACHE[pkey])
        cache = _load_cache()
        if not refresh and key in cache:
            _PROC_CACHE[pkey] = dict(cache[key]["blocks"])
            return dict(cache[key]["blocks"])
        best, pred_us = ranked[0]
        result = dict(zip(tuner.block_names, best))
        cache = _load_cache()
        cache[key] = dict(blocks=result, predicted_us=pred_us,
                          policy="predict")
        _save_cache(cache)
        _PROC_CACHE[pkey] = dict(result)
        return result

    # verify: wall-clock only the predicted top-k.  The shortlist is part
    # of the key — as the model refits, a new shortlist re-verifies rather
    # than trusting a stale one.
    short = [cand for cand, _ in ranked[:max(1, int(top_k))]]
    key = f"{base_key}:pverify:top[{_cands_tag(short)}]"
    return _memoized_best(
        key, lambda: tuner.make_runs(problem, short, interpret),
        reps, refresh, block_names=tuner.block_names,
        observe=observe(short))


def rank_candidates(kernel: str, *, interpret: bool, candidates=None,
                    **shape) -> list:
    """The cost model's full analytical ranking for a shape —
    ``[(blocks_dict, predicted_us), ...]`` best-first, zero timing runs.
    The introspection hook the regret benchmark and tests use."""
    tuner = _REGISTRY[kernel]
    problem = tuner.prepare(**shape)
    clipped = tuner.clip(candidates or tuner.default_candidates, problem)
    ranked = cost_model.get_model(_mode_backend(interpret)).rank(
        kernel, [(cand, tuner.basis(problem, cand)) for cand in clipped])
    return [(dict(zip(tuner.block_names, cand)), us) for cand, us in ranked]


def plan_engine(compiled, B: int, *, interpret: bool,
                policy: str = "predict", top_k: int = 3,
                refresh: bool = False) -> tuple:
    """Pick ``(engine_name, blocks)`` for serving a compiled artifact at
    batch ``B`` — the zoo cold-load path: with ``policy="predict"`` this
    makes ZERO timing runs (engine by the compiler's sharing heuristic,
    tiling by the cost model over the artifact's persisted features).
    """
    from repro.core import compiler

    stats = getattr(compiled, "stats", None)
    sharing = float(getattr(stats, "partial_term_sharing", 0.0) or 0.0)
    engine = ("factorized" if sharing >= compiler.FACTORIZE_SHARING_THRESHOLD
              else "sparse")
    kernel = "term_infer" if engine == "factorized" else "sparse_infer"
    blocks = tune(
        kernel, B=B, K=int(compiled.n_classes),
        include_words=compiled.include_words, interpret=interpret,
        policy=policy, top_k=top_k, refresh=refresh,
        features=getattr(compiled, "features", None) or None)
    return engine, blocks


# ---------------------------------------------------------------------------
# Legacy entry points (thin wrappers; same cache keys, same results)
# ---------------------------------------------------------------------------

def autotune_fused_blocks(
    B: int,
    C: int,
    W: int,
    K: int,
    *,
    interpret: bool,
    candidates=None,
    reps: int = 5,
    refresh: bool = False,
) -> dict:
    """Best ``{block_b, block_c, block_w}`` for a fused-INFERENCE shape.

    Thin wrapper over ``tune("fused_infer", ..., policy="sweep")``:
    sweeps ``candidates`` on synthetic data of the given shape, memoizing
    the winner on disk.  ``refresh=True`` ignores (and overwrites) any
    cached entry.
    """
    return tune("fused_infer", B=B, C=C, W=W, K=K, interpret=interpret,
                policy="sweep", candidates=candidates, reps=reps,
                refresh=refresh)


def autotune_sparse_infer_blocks(
    B: int,
    K: int,
    include_words,
    *,
    interpret: bool,
    candidates=None,
    reps: int = 5,
    refresh: bool = False,
    lit_words=None,
) -> dict:
    """Best ``{block_c, block_j, block_s}`` for a SPARSE-schedule artifact.

    Thin wrapper over ``tune("sparse_infer", ..., policy="sweep")``.
    Cached under ``sparse_infer:`` keys that include a content hash of the
    include rows — the ragged tile grid's cost is a property of the
    trained artifact, not just its shape.  Each candidate is timed on the
    real schedule it would execute (``build_schedule`` per tiling).
    ``lit_words`` supplies a representative packed request stream (e.g.
    an in-distribution serving bucket) — without it the sweep uses
    uniform-random literals, which let every trained chain die in its
    first tile and can crown a tiling that loses on live traffic.
    """
    return tune("sparse_infer", B=B, K=K, include_words=include_words,
                lit_words=lit_words, interpret=interpret, policy="sweep",
                candidates=candidates, reps=reps, refresh=refresh)


def autotune_term_infer_blocks(
    B: int,
    K: int,
    include_words,
    *,
    interpret: bool,
    candidates=None,
    reps: int = 5,
    refresh: bool = False,
    lit_words=None,
) -> dict:
    """Best ``{block_c, block_j, block_t, block_s, term_w}`` for a
    FACTORIZED-schedule artifact.

    Thin wrapper over ``tune("term_infer", ..., policy="sweep")``.
    Cached under ``term_infer:`` keys that include a content hash of the
    include rows — term-table size, tile counts, and the stage-1/stage-2
    work split are all properties of the trained artifact, not its shape.
    Each candidate is timed on the real factorized schedule it would
    execute (``build_factorized_schedule`` per tiling).  ``lit_words``
    supplies a representative packed request stream (see
    :func:`autotune_sparse_infer_blocks`).
    """
    return tune("term_infer", B=B, K=K, include_words=include_words,
                lit_words=lit_words, interpret=interpret, policy="sweep",
                candidates=candidates, reps=reps, refresh=refresh)


def autotune_fused_train_blocks(
    B: int,
    C: int,
    W: int,
    L: int,
    K: int,
    *,
    interpret: bool,
    candidates=None,
    reps: int = 3,
    refresh: bool = False,
) -> dict:
    """Best ``{block_b, block_c, block_w}`` for a fused-TRAINING shape.

    Thin wrapper over ``tune("fused_train", ..., policy="sweep")``.
    Cached under a distinct ``fused_train`` key — training tilings are
    never answered by inference sweeps (the training kernel's VMEM budget
    includes the (block_c, L) delta accumulator and the (block_b, L)
    literal slab, so its optimum differs).  Synthetic data uses
    class-aligned clause banks so the kernel's feedback-sparsity skip sees
    a realistic feedback density.
    """
    return tune("fused_train", B=B, C=C, W=W, L=L, K=K, interpret=interpret,
                policy="sweep", candidates=candidates, reps=reps,
                refresh=refresh)
