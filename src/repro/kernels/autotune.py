"""Block-size autotuner for the fused TM Pallas kernels.

A fused kernel's throughput is a function of its ``(block_b, block_c,
block_w)`` tiling, and the best tiling depends on problem shape and backend
(VMEM budget, grid overhead, interpret vs compiled).  This module sweeps a
small candidate grid once per ``(kernel, shape, backend)`` and memoizes the
winner in an on-disk JSON cache so serving/training processes never re-pay
the sweep.

Cache location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro/autotune.json``.  The file is ``{"schema": N, "entries":
{...}}``; a schema mismatch (older repo version, foreign writer, corrupt
file) invalidates the whole cache instead of crashing or silently reusing
blocks tuned for a different kernel signature.  Entries are keyed by
``<kernel>:v1:<backend>:<interp|compiled>:<shape>:cands[...]`` so a TPU run
never reads CPU-interpret timings, inference timings never answer for
training shapes, and vice versa.
"""

from __future__ import annotations

import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

import math

from repro.kernels import fused_infer, fused_train, sparse_infer, term_infer

_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
_KEY_VERSION = "v1"
# Bump when the on-disk layout (or the meaning of cached blocks) changes:
# schema 1 was the bare key->entry dict; schema 2 wraps it in
# {"schema", "entries"} so stale caches are detectable.
_SCHEMA_VERSION = 2

# candidate tilings: a deliberately small grid — the sweep is paid once per
# shape and cached, but each candidate costs a kernel compile.
_DEFAULT_CANDIDATES = (
    (128, 128, 64),   # clause_eval.py's defaults (VMEM-lean)
    (128, 256, 64),   # wider clause bank: fewer adder-fold steps
    (256, 128, 64),   # taller request slab: fewer batch steps
    (256, 256, 32),
    (512, 512, 16),   # few big tiles: minimal grid overhead (small models)
    (64, 512, 64),
    (512, 1024, 256),  # whole word chain per step (wide-literal shapes)
)

# sparse (chain-schedule) kernel candidates: (block_c, block_j, block_s) —
# clause bank x chain-tile bits x sample-word slab.  The schedule is
# rebuilt per candidate (tile tables depend on the tiling), so the sweep
# measures real tile counts, not synthetic occupancy.
_SPARSE_CANDIDATES = (
    (512, 32, 16),    # sparse_infer.py defaults
    (1024, 32, 16),
    (512, 64, 16),
    (256, 32, 16),
    (1024, 64, 8),
    (512, 16, 16),
    (2048, 128, 16),  # long-chain trained banks: few big whole-chain tiles
    (4096, 128, 16),
)

# factorized (two-level term-schedule) kernel candidates: (block_c,
# block_j, block_t, block_s, term_w) — clause bank x term-chain tile x
# stage-1 term tile x sample-word slab x term bit-chain width (0 = the
# artifact's auto width).  Schedules are rebuilt per candidate: term table
# size and tile counts depend on the tiling.
_TERM_CANDIDATES = (
    (1024, 64, 32768, 16, 0),   # term_infer.py defaults, auto width
    (1024, 64, 32768, 16, 2),   # narrowest rows: fat terms split to pieces
    (1024, 128, 32768, 16, 2),
    (2048, 128, 32768, 16, 2),
    (4096, 64, 32768, 16, 2),
    (1024, 32, 16384, 16, 0),
    (512, 32, 4096, 16, 0),     # small-artifact shapes clip here
)

# training kernel candidates: the delta accumulator block is (block_c, L),
# so block_c also scales VMEM; block_b scales the fire/ftype scratch.
_TRAIN_CANDIDATES = (
    (128, 256, 64),   # fused_train.py defaults
    (128, 128, 64),
    (256, 256, 64),
    (64, 512, 64),
    (256, 512, 32),
)


def cache_path() -> str:
    p = os.environ.get(_CACHE_ENV)
    if p:
        return p
    return os.path.join(os.path.expanduser("~"), ".cache", "repro", "autotune.json")


def _load_cache() -> dict:
    """Entry dict from disk; {} on missing, corrupt, or stale-schema files."""
    try:
        with open(cache_path()) as f:
            raw = json.load(f)
    except (OSError, ValueError):
        return {}
    if not isinstance(raw, dict) or raw.get("schema") != _SCHEMA_VERSION:
        return {}   # stale schema: invalidate, never reuse or crash
    entries = raw.get("entries")
    return entries if isinstance(entries, dict) else {}


def _save_cache(entries: dict) -> None:
    path = cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump({"schema": _SCHEMA_VERSION, "entries": entries},
                  f, indent=1, sort_keys=True)
    # os.replace keeps the file whole; concurrent tuners are last-writer-wins
    # (worst case a lost entry's sweep is re-paid, never a torn file)
    os.replace(tmp, path)


def _clip_candidate(blocks, B: int, C: int, W: int):
    """Apply the same clipping the kernel wrappers do, so duplicate
    post-clip candidates are swept only once."""
    bb, bc, bw = blocks
    bb = min(bb, fused_infer._rup(B, 8))
    bc = min(bc, fused_infer._rup(C, 128))
    bw = min(bw, W)
    return bb, bc, bw


def _clipped(candidates, B, C, W):
    out = []
    for cand in candidates:
        c = _clip_candidate(cand, B, C, W)
        if c not in out:
            out.append(c)
    return out


def _sweep(runs: dict, reps: int) -> dict:
    """min seconds per candidate tiling, timed round-robin so container
    noise drifts over every candidate equally instead of biasing the sweep
    order."""
    for run in runs.values():
        run().block_until_ready()      # compile + warm
    best = {k: float("inf") for k in runs}
    for _ in range(reps):
        for k, run in runs.items():
            t0 = time.perf_counter()
            run().block_until_ready()
            best[k] = min(best[k], time.perf_counter() - t0)
    return best


# in-process memo so hot loops (e.g. launch/train.py --autotune calling the
# resolver every step) never re-read and re-parse the on-disk JSON; keyed by
# (cache file, entry) so switching $REPRO_AUTOTUNE_CACHE mid-process works.
_PROC_CACHE: dict = {}


_DENSE_KEYS = ("block_b", "block_c", "block_w")


def _memoized_best(key: str, make_runs, reps: int, refresh: bool,
                   block_names=_DENSE_KEYS) -> dict:
    """Sweep (or recall) the best block dict for `key`; ``block_names``
    labels the candidate-tuple fields (dense kernels use block_b/c/w, the
    sparse schedule kernel block_c/j/s)."""
    pkey = (cache_path(), key)
    if not refresh and pkey in _PROC_CACHE:
        return dict(_PROC_CACHE[pkey])
    cache = _load_cache()
    if not refresh and key in cache:
        _PROC_CACHE[pkey] = dict(cache[key]["blocks"])
        return dict(cache[key]["blocks"])

    timings = _sweep(make_runs(), reps)
    # within the measurement noise floor, prefer the largest tiling: fewer
    # grid steps is the structurally better config when timings can't
    # separate the candidates
    t_min = min(timings.values())
    best_blocks = max(
        (blk for blk, t in timings.items() if t <= t_min * 1.05),
        key=lambda blk: math.prod(blk),
    )
    result = dict(zip(block_names, best_blocks))
    cache = _load_cache()   # re-read to narrow the concurrent-writer window
    cache[key] = dict(blocks=result, us_per_call=timings[best_blocks] * 1e6)
    _save_cache(cache)
    _PROC_CACHE[pkey] = dict(result)
    return result


def _mode_backend(interpret: bool) -> str:
    mode = "interp" if interpret else "compiled"
    return f"{jax.default_backend()}:{mode}"


def _cands_tag(clipped) -> str:
    # the candidate set is part of the key: a sweep over a restricted custom
    # candidate list must not answer for the default sweep (or vice versa)
    return ",".join("x".join(map(str, c)) for c in clipped)


def autotune_fused_blocks(
    B: int,
    C: int,
    W: int,
    K: int,
    *,
    interpret: bool,
    candidates=None,
    reps: int = 5,
    refresh: bool = False,
) -> dict:
    """Best ``{block_b, block_c, block_w}`` for a fused-INFERENCE shape.

    Sweeps ``candidates`` on synthetic data of the given shape, memoizing
    the winner on disk.  ``refresh=True`` ignores (and overwrites) any
    cached entry.
    """
    clipped = _clipped(candidates or _DEFAULT_CANDIDATES, B, C, W)
    key = (f"fused_infer:{_KEY_VERSION}:{_mode_backend(interpret)}:"
           f"B{B}:C{C}:W{W}:K{K}:cands[{_cands_tag(clipped)}]")

    def make_runs():
        rng = np.random.default_rng(0)
        lit = jnp.asarray(rng.integers(0, 2**32, (B, W), dtype=np.uint32))
        inc = jnp.asarray(rng.integers(0, 2**32, (C, W), dtype=np.uint32))
        votes = jnp.asarray(rng.integers(-2, 3, (C, K), dtype=np.int32))
        nonempty = jnp.ones((C,), jnp.int32)
        return {
            (bb, bc, bw): functools.partial(
                fused_infer.fused_tm_forward, lit, inc, votes, nonempty,
                block_b=bb, block_c=bc, block_w=bw, interpret=interpret,
            )
            for bb, bc, bw in clipped
        }

    return _memoized_best(key, make_runs, reps, refresh)


def _artifact_tag(include_words) -> str:
    """Short content hash of an artifact's include rows: the sparse
    kernel's runtime depends on the SCHEDULE (tile counts, chain lengths),
    so two same-shape artifacts with different sparsity must not share a
    cache entry.  Same hashing rule as the schedule memo
    (``sparse_infer.artifact_tag``)."""
    return sparse_infer.artifact_tag(include_words)[:10]


def _clip_sparse_candidate(blocks, B: int, U: int):
    bc, bj, bs = blocks
    bc = min(bc, fused_infer._rup(max(U, 1), 8))
    bs = max(min(bs, fused_infer._rup(-(-B // 32), 1)), 1)
    return bc, bj, bs


def _lit_tag(lit_words) -> str:
    """Key fragment for a caller-supplied representative literal stream:
    tunings measured on different workloads must not share an entry (a
    random stream kills trained chains in one tile — its winner can lose
    on the in-distribution stream a server actually sees)."""
    if lit_words is None:
        return ""
    return ":lit" + sparse_infer.artifact_tag(np.asarray(lit_words))[:10]


def autotune_sparse_infer_blocks(
    B: int,
    K: int,
    include_words,
    *,
    interpret: bool,
    candidates=None,
    reps: int = 5,
    refresh: bool = False,
    lit_words=None,
) -> dict:
    """Best ``{block_c, block_j, block_s}`` for a SPARSE-schedule artifact.

    Cached under ``sparse_infer:`` keys that include a content hash of the
    include rows — the ragged tile grid's cost is a property of the
    trained artifact, not just its shape.  Each candidate is timed on the
    real schedule it would execute (``build_schedule`` per tiling).
    ``lit_words`` supplies a representative packed request stream (e.g.
    an in-distribution serving bucket) — without it the sweep uses
    uniform-random literals, which let every trained chain die in its
    first tile and can crown a tiling that loses on live traffic.
    """
    iw = np.ascontiguousarray(np.asarray(include_words, dtype=np.uint32))
    U, Wa = iw.shape
    clipped = []
    for cand in candidates or _SPARSE_CANDIDATES:
        c = _clip_sparse_candidate(cand, B, U)
        if c not in clipped:
            clipped.append(c)
    key = (f"sparse_infer:{_KEY_VERSION}:{_mode_backend(interpret)}:"
           f"B{B}:U{U}:W{Wa}:K{K}:sig{_artifact_tag(iw)}"
           f"{_lit_tag(lit_words)}:cands[{_cands_tag(clipped)}]")

    def make_runs():
        rng = np.random.default_rng(0)
        lit = (jnp.asarray(np.asarray(lit_words)) if lit_words is not None
               else jnp.asarray(
                   rng.integers(0, 2**32, (B, Wa), dtype=np.uint32)))
        votes = jnp.asarray(rng.integers(-2, 3, (U, K), dtype=np.int32))
        runs = {}
        for bc, bj, bs in clipped:
            sched = sparse_infer.build_schedule(iw, block_c=bc, block_j=bj)
            runs[(bc, bj, bs)] = functools.partial(
                sparse_infer.sparse_tm_forward, lit, votes, sched,
                block_s=bs, interpret=interpret,
            )
        return runs

    return _memoized_best(key, make_runs, reps, refresh,
                          block_names=("block_c", "block_j", "block_s"))


def _clip_term_candidate(blocks, B: int, U: int, iw, n_pieces_bound: int
                         ) -> tuple:
    bc, bj, bt, bs, tw = blocks
    bc = min(bc, fused_infer._rup(max(U, 1), 8))
    bs = max(min(bs, fused_infer._rup(-(-B // 32), 1)), 1)
    if tw == 0:   # 0 = the artifact's auto width (resolved so duplicate
        tw = term_infer.pick_term_width(iw)   # post-clip candidates dedup)
    # the schedule builder clips block_t to its term count; apply the same
    # bound here (pieces <= total include bits) so small artifacts dedup
    # candidates that only differ in an unreachable block_t
    bt = max(min(bt, fused_infer._rup(n_pieces_bound + 1, 8)), 1)
    return bc, bj, bt, bs, tw


def autotune_term_infer_blocks(
    B: int,
    K: int,
    include_words,
    *,
    interpret: bool,
    candidates=None,
    reps: int = 5,
    refresh: bool = False,
    lit_words=None,
) -> dict:
    """Best ``{block_c, block_j, block_t, block_s, term_w}`` for a
    FACTORIZED-schedule artifact.

    Cached under ``term_infer:`` keys that include a content hash of the
    include rows — term-table size, tile counts, and the stage-1/stage-2
    work split are all properties of the trained artifact, not its shape.
    Each candidate is timed on the real factorized schedule it would
    execute (``build_factorized_schedule`` per tiling).  ``lit_words``
    supplies a representative packed request stream (see
    :func:`autotune_sparse_infer_blocks`).
    """
    iw = np.ascontiguousarray(np.asarray(include_words, dtype=np.uint32))
    U, Wa = iw.shape
    n_bits_total = int(np.unpackbits(iw.view(np.uint8)).sum())
    clipped = []
    for cand in candidates or _TERM_CANDIDATES:
        c = _clip_term_candidate(cand, B, U, iw, n_bits_total)
        if c not in clipped:
            clipped.append(c)
    key = (f"term_infer:{_KEY_VERSION}:{_mode_backend(interpret)}:"
           f"B{B}:U{U}:W{Wa}:K{K}:sig{_artifact_tag(iw)}"
           f"{_lit_tag(lit_words)}:cands[{_cands_tag(clipped)}]")

    def make_runs():
        rng = np.random.default_rng(0)
        lit = (jnp.asarray(np.asarray(lit_words)) if lit_words is not None
               else jnp.asarray(
                   rng.integers(0, 2**32, (B, Wa), dtype=np.uint32)))
        votes = jnp.asarray(rng.integers(-2, 3, (U, K), dtype=np.int32))
        runs = {}
        for bc, bj, bt, bs, tw in clipped:
            sched = term_infer.build_factorized_schedule(
                iw, block_c=bc, block_j=bj, block_t=bt, term_w=tw)
            runs[(bc, bj, bt, bs, tw)] = functools.partial(
                term_infer.factorized_tm_forward, lit, votes, sched,
                block_s=bs, interpret=interpret,
            )
        return runs

    return _memoized_best(
        key, make_runs, reps, refresh,
        block_names=("block_c", "block_j", "block_t", "block_s", "term_w"))


def autotune_fused_train_blocks(
    B: int,
    C: int,
    W: int,
    L: int,
    K: int,
    *,
    interpret: bool,
    candidates=None,
    reps: int = 3,
    refresh: bool = False,
) -> dict:
    """Best ``{block_b, block_c, block_w}`` for a fused-TRAINING shape.

    Cached under a distinct ``fused_train`` key — training tilings are
    never answered by inference sweeps (the training kernel's VMEM budget
    includes the (block_c, L) delta accumulator and the (block_b, L)
    literal slab, so its optimum differs).  Synthetic data uses
    class-aligned clause banks so the kernel's feedback-sparsity skip sees
    a realistic feedback density.
    """
    clipped = _clipped(candidates or _TRAIN_CANDIDATES, B, C, W)
    key = (f"fused_train:{_KEY_VERSION}:{_mode_backend(interpret)}:"
           f"B{B}:C{C}:W{W}:L{L}:K{K}:cands[{_cands_tag(clipped)}]")

    def make_runs():
        from repro.core import packetizer

        rng = np.random.default_rng(0)
        bits = rng.integers(0, 2, (B, L), dtype=np.uint8)
        lits = jnp.asarray(bits)
        lit_words = jnp.asarray(packetizer.pack_bits_np(bits))
        inc_bits = (rng.random((C, L)) < 0.05).astype(np.uint8)
        inc_full = np.zeros((C, W * 32), np.uint8)
        inc_full[:, :L] = inc_bits
        inc_words = jnp.asarray(packetizer.pack_bits_np(inc_full))
        ta = jnp.asarray(rng.integers(-64, 64, (C, L), dtype=np.int8))
        y = jnp.asarray(rng.integers(0, K, B, dtype=np.int32))
        kn = jnp.asarray((y + 1) % K, jnp.int32)
        p_t = jnp.asarray(rng.random(B, dtype=np.float32))
        p_n = jnp.asarray(rng.random(B, dtype=np.float32))
        cpc = max(1, C // K)
        cls = jnp.asarray(np.clip(np.arange(C) // cpc, 0, K - 1), jnp.int32)
        pol = jnp.asarray(np.where(np.arange(C) % 2 == 0, 1, -1), jnp.int32)
        seed = jnp.uint32(0)
        return {
            (bb, bc, bw): functools.partial(
                fused_train.fused_tm_train_delta,
                ta, lits, lit_words, inc_words, y, kn, p_t, p_n, cls, pol,
                seed, p_act=1.0, p_inact=0.1,
                block_b=bb, block_c=bc, block_w=bw, interpret=interpret,
            )
            for bb, bc, bw in clipped
        }

    return _memoized_best(key, make_runs, reps, refresh)
