"""Pure-jnp oracles for every Pallas kernel in this package.

Each function here defines the *exact* semantics its kernel must reproduce
(tests/test_kernels.py sweeps shapes/dtypes and asserts equality).  The
training oracle uses the same integer hash RNG as the kernel so results match
bit-for-bit (DESIGN.md §2: the TPU analog of the paper's LFSR-based FPGA
random number generators, refs [20][21]).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Counter-based RNG (xxhash-style avalanche) — identical in kernel and oracle.
# Constants are *numpy* scalars so the hash traces inside Pallas kernels
# without becoming captured jax-array constants.
# ---------------------------------------------------------------------------

_H1 = np.uint32(2654435761)
_H2 = np.uint32(2246822519)
_H3 = np.uint32(3266489917)


def hash_u32(idx: jax.Array, seed: jax.Array) -> jax.Array:
    """Deterministic uint32 hash of (index, seed) — the kernel's RNG."""
    x = idx.astype(jnp.uint32) * _H1 + seed.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * _H2
    x = x ^ (x >> 13)
    x = x * _H3
    x = x ^ (x >> 16)
    return x


def prob_to_u32(p: float) -> np.uint32:
    """Threshold such that P[hash < t] == p (up to 2^-32)."""
    return np.uint32(min(int(round(p * 2**32)), 2**32 - 1))


# ---------------------------------------------------------------------------
# clause_fire: bitpacked clause evaluation (the HCB chain)
# ---------------------------------------------------------------------------

def clause_fire_ref(lit_words: jax.Array, inc_words: jax.Array) -> jax.Array:
    """(B, W) uint32 literals x (C, W) uint32 includes -> (B, C) int8 fire.

    fire[b, c] = 1 iff every include bit of clause c sees literal 1:
    AND_w ((inc[c, w] & ~lit[b, w]) == 0).  Vacuous AND (empty clause) = 1;
    empty-clause masking is the caller's concern (inference drops them).
    """
    viol = inc_words[None, :, :] & ~lit_words[:, None, :]      # (B, C, W)
    return (~jnp.any(viol != 0, axis=-1)).astype(jnp.int8)


# ---------------------------------------------------------------------------
# class_sum: polarity-weighted vote tally (the class-sum adder bank)
# ---------------------------------------------------------------------------

def class_sum_ref(fired: jax.Array, votes: jax.Array) -> jax.Array:
    """(B, C) {0,1} x (C, K) int32 -> (B, K) int32."""
    return fired.astype(jnp.int32) @ votes.astype(jnp.int32)


# ---------------------------------------------------------------------------
# ta_delta: batched Type I/II feedback deltas (training hot loop)
# ---------------------------------------------------------------------------

def ta_delta_ref(
    ta: jax.Array,        # (C, L) int8 automata states
    lits: jax.Array,      # (B, L) uint8 {0,1}
    fire: jax.Array,      # (B, C) uint8 clause outputs (training semantics)
    ftype: jax.Array,     # (B, C) uint8: 0 = none, 1 = Type I, 2 = Type II
    seed: jax.Array,      # uint32 scalar
    *,
    p_act: float,
    p_inact: float,
    b_offset=0,           # global index of lits[0] (batch-chunked training)
    c_offset=0,           # global index of ta[0] (clause-sharded training)
    c_total: int | None = None,  # global clause count when ta is a shard
) -> jax.Array:
    """Summed feedback delta over the batch -> (C, L) int32.

    Random draws use ``hash_u32(global_index, seed)`` with
    global_index = ((b + b_offset) * Cg + c + c_offset) * L + l  (uint32,
    wraps — fine for RNG); ``b_offset`` makes chunked evaluation
    bit-identical to unchunked.  ``c_total`` (with ``c_offset``) switches
    the clause index to GLOBAL ids in a bank of ``c_total`` clauses, so a
    clause shard reproduces exactly the full-bank stream's draws for its
    rows; the default (``c_total=None``) keeps local indexing, matching the
    unfused per-shard composition the pre-sharded tests pin down.
    """
    B, L = lits.shape
    C = ta.shape[0]
    Cg = C if c_total is None else c_total
    t_act = prob_to_u32(p_act)
    t_inact = prob_to_u32(p_inact)

    b_idx = (
        jnp.arange(B, dtype=jnp.uint32) + jnp.uint32(b_offset)
    )[:, None, None]
    c_idx = jnp.arange(C, dtype=jnp.uint32)[None, :, None]
    if c_total is not None:
        c_idx = c_idx + jnp.uint32(c_offset)
    l_idx = jnp.arange(L, dtype=jnp.uint32)[None, None, :]
    gidx = (b_idx * jnp.uint32(Cg) + c_idx) * jnp.uint32(L) + l_idx
    r = hash_u32(gidx, seed)                                   # (B, C, L)

    lit_on = (lits[:, None, :] == 1)                           # (B, 1->C, L)
    fire_b = (fire[:, :, None] == 1)                           # (B, C, 1->L)
    excl = (ta[None, :, :] < 0)

    act = r < t_act
    inact = r < t_inact
    d1 = jnp.where(
        fire_b,
        jnp.where(lit_on, act.astype(jnp.int32), -inact.astype(jnp.int32)),
        -inact.astype(jnp.int32),
    )
    d2 = (fire_b & ~lit_on & excl).astype(jnp.int32)

    ft = ftype[:, :, None]
    d = jnp.where(ft == 1, d1, jnp.where(ft == 2, d2, 0))
    return jnp.sum(d, axis=0, dtype=jnp.int32)


# ---------------------------------------------------------------------------
# xnor_popcount: binarized matmul (FINN-style BNN baseline layer)
# ---------------------------------------------------------------------------

def xnor_popcount_ref(a_words: jax.Array, w_words: jax.Array, n_bits: int) -> jax.Array:
    """(B, W) uint32 x (O, W) uint32 -> (B, O) int32 of +1/-1 dot products.

    Bits encode {-1:0, +1:1}; dot = matches - mismatches
    = 2 * popcount(~(a ^ w)) - n_bits  (padding bits cancelled by caller
    passing the true n_bits).
    """
    x = ~(a_words[:, None, :] ^ w_words[None, :, :])           # (B, O, W)
    pop = jnp.sum(jax.lax.population_count(x), axis=-1, dtype=jnp.int32)
    pad_bits = a_words.shape[-1] * 32 - n_bits
    matches = pop - pad_bits                                   # padding: ~(0^0) = all ones
    return 2 * matches - n_bits


# ---------------------------------------------------------------------------
# flash_attention forward (LM substrate kernel)
# ---------------------------------------------------------------------------

def flash_ref(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True):
    """(B,S,H,hd) x (B,T,H,hd) x (B,T,H,dv) -> (B,S,H,dv) dense oracle."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    s = jnp.einsum(
        "bqhd,bthd->bhqt", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (hd**-0.5)
    if causal:
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqt,bthv->bqhv", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
