"""Pallas TPU kernel: XNOR-popcount binarized matmul (BNN baseline layer).

The paper benchmarks MATADOR against FINN BNNs whose core op is the
XNOR-popcount dot product over {-1,+1} packed into bits.  We implement that
baseline with the same bitpacked streaming structure as clause_eval (shared
word-axis "packet" decomposition), so the Table-I comparison is like-for-like
on this substrate too.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat


def _xnor_kernel(a_ref, w_ref, out_ref, *, block_w: int):
    w = pl.program_id(2)

    @pl.when(w == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]      # (bb, bw) uint32
    b = w_ref[...]      # (bo, bw) uint32

    def body(i, acc):
        a_w = jax.lax.dynamic_slice_in_dim(a, i, 1, axis=1)     # (bb, 1)
        b_w = jax.lax.dynamic_slice_in_dim(b, i, 1, axis=1)     # (bo, 1)
        x = ~(jnp.bitwise_xor(b_w.reshape(1, -1), a_w))         # (bb, bo)
        return acc + jax.lax.population_count(x).astype(jnp.int32)

    out_ref[...] = jax.lax.fori_loop(
        0, block_w, body, out_ref[...], unroll=True
    )


@functools.partial(
    jax.jit, static_argnames=("n_bits", "block_b", "block_o", "block_w", "interpret")
)
def xnor_popcount(
    a_words: jax.Array,   # (B, W) uint32 packed {-1:0,+1:1} activations
    w_words: jax.Array,   # (O, W) uint32 packed weights
    n_bits: int,
    *,
    block_b: int = 128,
    block_o: int = 128,
    block_w: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """(B, O) int32 +1/-1 dot products == kernels/ref.py:xnor_popcount_ref."""
    B, W = a_words.shape
    O = w_words.shape[0]
    block_b = min(block_b, _rup(B, 8))
    block_o = min(block_o, _rup(O, 128))
    block_w = min(block_w, W)
    Bp, Op, Wp = _rup(B, block_b), _rup(O, block_o), _rup(W, block_w)

    a = jnp.pad(a_words, ((0, Bp - B), (0, Wp - W)))
    w = jnp.pad(w_words, ((0, Op - O), (0, Wp - W)))

    grid = (Bp // block_b, Op // block_o, Wp // block_w)
    pop = pl.pallas_call(
        functools.partial(_xnor_kernel, block_w=block_w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_w), lambda b, o, w: (b, w)),
            pl.BlockSpec((block_o, block_w), lambda b, o, w: (o, w)),
        ],
        out_specs=pl.BlockSpec((block_b, block_o), lambda b, o, w: (b, o)),
        out_shape=jax.ShapeDtypeStruct((Bp, Op), jnp.int32),
        compiler_params=pallas_compat.CompilerParams(dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, w)[:B, :O]

    # padded words contribute ~(0^0) = 32 ones each; fold them out with the
    # true-bit correction so the result matches the unpadded oracle exactly.
    matches = pop - (Wp * 32 - n_bits)
    return 2 * matches - n_bits


def _rup(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
