"""Jit'd dispatch wrappers over the Pallas kernels (with oracle fallback).

Every op takes ``use_kernel``/``interpret`` switches: on a real TPU the
kernels run compiled (``interpret=False``); in this CPU container they are
validated in interpret mode against the ``ref.py`` oracles, and the oracle
path is the default execution engine (it is XLA-compiled and fast on CPU).

``REPRO_USE_PALLAS=1`` flips the default to the kernels (interpret on CPU).
"""

from __future__ import annotations

import dataclasses
import functools
import os

import jax
import jax.numpy as jnp

from repro import jax_compat
from repro.runtime import faults

from repro.kernels import class_sum as _class_sum_kernel
from repro.kernels import clause_eval as _clause_eval_kernel
from repro.kernels import fused_infer as _fused_infer_kernel
from repro.kernels import fused_train as _fused_train_kernel
from repro.kernels import ref
from repro.kernels import sparse_infer as _sparse_infer_kernel
from repro.kernels import ta_update as _ta_update_kernel
from repro.kernels import term_infer as _term_infer_kernel
from repro.kernels import xnor_popcount as _xnor_kernel

_DEFAULT_USE_KERNEL = os.environ.get("REPRO_USE_PALLAS", "0") == "1"
_ON_TPU = jax.default_backend() == "tpu"


def _resolve(use_kernel, interpret):
    if use_kernel is None:
        use_kernel = _DEFAULT_USE_KERNEL
    if interpret is None:
        interpret = not _ON_TPU
    return use_kernel, interpret


def kernel_dispatch(use_kernel=None, interpret=None):
    """Public resolver for callers that branch on the dispatch decision
    (serve loop, compiled-artifact runner): (use_kernel, interpret)."""
    return _resolve(use_kernel, interpret)


ENGINE_NAMES = ("auto", "factorized", "sparse", "dense", "oracle")


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """One inference-engine selection, replacing the old ``use_kernel=/
    sparse=/factorize=`` boolean sprawl on ``core.compiler.run_compiled``.

    ``name`` uses the :class:`EngineLadder` level vocabulary — serve's
    degradation ladder and the library share one set of words:

    * ``"auto"`` — ambient dispatch (``REPRO_USE_PALLAS`` via
      :func:`kernel_dispatch`); on the kernel path the schedule heuristics
      pick factorized vs sparse exactly as before.
    * ``"factorized"`` — the two-level shared-term schedule kernel.
    * ``"sparse"`` — the flat block-sparse chain schedule kernel.
    * ``"dense"`` — the fused dense kernel (``fuse=False`` for the legacy
      two-kernel pipeline).
    * ``"oracle"`` — the pure-jnp XLA reference path.

    Named kernel engines pin ``use_kernel=True`` (that is what naming them
    means); ``"oracle"`` pins ``use_kernel=False``.  ``use_kernel`` on the
    spec is only meaningful for ``"auto"``, where it overrides the ambient
    default; a contradiction (e.g. ``"sparse"`` with ``use_kernel=False``)
    raises rather than silently serving a different engine.  ``interpret``
    rides along as the spec's default, overridden by a call-site
    ``interpret=``.
    """

    name: str = "auto"
    use_kernel: bool | None = None
    interpret: bool | None = None
    fuse: bool = True

    def __post_init__(self):
        if self.name not in ENGINE_NAMES:
            raise ValueError(
                f"unknown engine {self.name!r}; one of {ENGINE_NAMES}")
        if self.name == "oracle" and self.use_kernel:
            raise ValueError("engine 'oracle' is the non-kernel path; "
                             "use_kernel=True contradicts it")
        if (self.name in ("factorized", "sparse", "dense")
                and self.use_kernel is False):
            raise ValueError(
                f"engine {self.name!r} names a Pallas kernel; "
                "use_kernel=False contradicts it")
        if self.name == "factorized" and not self.fuse:
            raise ValueError("engine 'factorized' has no unfused form")
        if self.name == "sparse" and not self.fuse:
            raise ValueError("engine 'sparse' has no unfused form")

    @classmethod
    def coerce(cls, spec) -> "EngineSpec":
        """``None`` -> auto; a level-name string -> that engine; an
        ``EngineSpec`` passes through."""
        if spec is None:
            return cls()
        if isinstance(spec, cls):
            return spec
        if isinstance(spec, str):
            return cls(name=spec)
        raise TypeError(
            f"engine must be an EngineSpec or one of {ENGINE_NAMES}, "
            f"got {type(spec).__name__}")

    def resolve(self, interpret: bool | None = None) -> tuple:
        """Legacy dispatch tuple ``(use_kernel, interpret, fuse, sparse,
        factorize)`` consumed by ``run_compiled``'s engine body; call-site
        ``interpret`` wins over the spec's."""
        it = self.interpret if interpret is None else interpret
        if self.name == "factorized":
            return True, it, True, True, True
        if self.name == "sparse":
            return True, it, True, True, False
        if self.name == "dense":
            return True, it, self.fuse, False, False
        if self.name == "oracle":
            return False, it, self.fuse, False, False
        return self.use_kernel, it, self.fuse, None, None


class EngineLadder:
    """Degradation ladder over inference engines (serve fault tolerance).

    ``engines`` is an ordered ``[(name, builder)]`` list, preferred engine
    first; ``builder()`` returns the engine's callable and is invoked
    lazily, so engines the ladder never reaches pay neither their jit
    trace nor their autotune sweep.  :meth:`run` executes the current
    engine on a *fresh* input from ``make_input`` (re-invoked per attempt
    so a retry never reuses a buffer a failed call may already have
    donated), blocks until the result is ready so asynchronous failures
    surface here, and on ANY exception — a Mosaic lowering error on a real
    backend, an injected fault in a drill — demotes one level and retries
    the same input.  Only the LAST engine's failure propagates: the run
    degrades instead of crashing.  ``counts``/``demotions`` feed the serve
    health summary (which engine actually served each bucket).

    **Re-promotion** (``promote_after=N``): a demotion is not a life
    sentence — after ``N`` consecutive healthy buckets at the current
    level, the next :meth:`run` serves its bucket as a PROBE on the engine
    one level up.  A successful probe promotes (the probe bucket IS served
    by the higher engine, so probing costs nothing extra); a failed probe
    falls back to the current engine for the same input, resets the
    healthy streak, and DOUBLES the cooldown (the streak required before
    the next probe) — a permanent fault converges to exponentially-rare
    probes while a transient one no longer pins the tenant on the slow
    oracle forever.  A demotion resets both streak and cooldown to base.
    ``promote_after=None`` (default) keeps the demote-only behavior.
    ``promotions``/``probe_failures`` feed the health summary alongside
    ``demotions``.

    **Anytime quality** (brownout serving): :meth:`run` takes a
    ``quality`` level.  Engines whose built callable is marked
    ``supports_quality = True`` (an attribute the builder sets on the
    closure) are invoked ``fn(x, quality)`` and serve the budgeted tile
    prefix; every other engine serves exact.  ``last_quality`` reports
    what the serving engine actually delivered (0 = exact) so the caller
    can attribute the answer — a ladder demoted to the dense or oracle
    engine keeps serving exact answers under brownout, which is safe
    (stronger than requested).
    """

    def __init__(self, engines, promote_after: int | None = None):
        self._names = [name for name, _ in engines]
        self._builders = dict(engines)
        self._built: dict = {}
        self._level = 0
        self.counts = {name: 0 for name in self._names}
        self.demotions: list = []
        self.promote_after = promote_after
        self.promotions: list = []
        self.probe_failures: list = []
        self._healthy = 0                    # success streak at this level
        self._cooldown = promote_after or 0  # streak required to probe up
        self.last_quality = 0                # quality the last run served

    @property
    def engine(self) -> str:
        """Name of the engine currently serving."""
        return self._names[self._level]

    @property
    def exhausted(self) -> bool:
        return self._level + 1 >= len(self._names)

    def demote(self, reason: str, bucket=None) -> bool:
        """Drop one level (False when already on the last engine)."""
        if self.exhausted:
            print(f"engine ladder exhausted at {self.engine!r}; cannot "
                  f"demote further ({reason})")
            return False
        frm, to = self._names[self._level], self._names[self._level + 1]
        self.demotions.append(
            dict(frm=frm, to=to, bucket=bucket, reason=reason))
        print(f"engine demoted: {frm} -> {to} (bucket {bucket}): {reason}")
        self._level += 1
        self._healthy = 0
        self._cooldown = self.promote_after or 0
        return True

    def rebind(self, engines) -> None:
        """Swap in a new ``[(name, builder)]`` list (artifact hot-swap).

        Built callables are discarded — they closed over the OLD
        artifact's schedules — and rebuild lazily on next use, while the
        ladder's health state (current level, streaks, telemetry) carries
        over: a tenant demoted to a safe engine stays demoted across a
        swap instead of re-crashing its way down the ladder.  The engine
        names must match the existing ladder (the level index keeps its
        meaning).
        """
        names = [name for name, _ in engines]
        if names != self._names:
            raise ValueError(
                f"rebind: engine names {names} != ladder levels "
                f"{self._names} — a swap must not reorder the ladder")
        self._builders = dict(engines)
        self._built = {}

    def _run_at(self, level, make_input, quality=0):
        name = self._names[level]
        fn = self._built.get(name)
        if fn is None:
            fn = self._built[name] = self._builders[name]()
        if quality and getattr(fn, "supports_quality", False):
            out = jax.block_until_ready(fn(make_input(), quality))
            self.last_quality = int(quality)
        else:
            out = jax.block_until_ready(fn(make_input()))
            self.last_quality = 0
        return out

    def _maybe_probe(self, make_input, bucket, count, quality=0):
        """Serve this bucket on the engine one level up when the healthy
        streak has cleared the cooldown; returns the output or None."""
        if (not self.promote_after or self._level == 0
                or self._healthy < self._cooldown):
            return None
        target = self._names[self._level - 1]
        try:
            out = self._run_at(self._level - 1, make_input, quality)
        except Exception as e:  # noqa: BLE001 — a failed probe never escapes
            self.probe_failures.append(dict(
                engine=target, bucket=bucket,
                reason=f"{type(e).__name__}: {e}"))
            self._healthy = 0
            self._cooldown *= 2
            print(f"engine probe failed: {target} (bucket {bucket}); "
                  f"cooldown now {self._cooldown} healthy buckets")
            return None
        self.promotions.append(
            dict(to=target, frm=self.engine, bucket=bucket,
                 after_healthy=self._healthy))
        print(f"engine promoted: {self.engine} -> {target} (bucket {bucket}) "
              f"after {self._healthy} healthy buckets")
        self._level -= 1
        self._healthy = 0
        self._cooldown = self.promote_after
        if count:
            self.counts[target] += 1
        return out

    def run(self, make_input, bucket=None, count=True, quality=0):
        """Run the current engine on ``make_input()``, demoting on failure.

        ``quality > 0`` requests a budgeted (anytime) answer; engines
        without quality support serve exact.  ``self.last_quality`` holds
        the level actually served after the call returns.
        """
        probed = self._maybe_probe(make_input, bucket, count, quality)
        if probed is not None:
            return probed
        while True:
            name = self.engine
            try:
                out = self._run_at(self._level, make_input, quality)
            except Exception as e:  # noqa: BLE001 — any engine failure demotes
                if not self.demote(f"{type(e).__name__}: {e}", bucket=bucket):
                    raise
                continue
            if count:
                self.counts[name] += 1
            self._healthy += 1
            return out


def clause_fire(
    lit_words: jax.Array,
    inc_words: jax.Array,
    *,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
    **blocks,
) -> jax.Array:
    """(B, W) x (C, W) packed -> (B, C) int8 clause outputs."""
    use_kernel, interpret = _resolve(use_kernel, interpret)
    if use_kernel:
        return _clause_eval_kernel.clause_fire(
            lit_words, inc_words, interpret=interpret, **blocks
        )
    return ref.clause_fire_ref(lit_words, inc_words)


def class_sums(
    fired: jax.Array,
    votes: jax.Array,
    *,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
    **blocks,
) -> jax.Array:
    use_kernel, interpret = _resolve(use_kernel, interpret)
    if use_kernel:
        return _class_sum_kernel.class_sum(fired, votes, interpret=interpret, **blocks)
    return ref.class_sum_ref(fired, votes)


def ta_delta(
    ta, lits, fire, ftype, seed, *, p_act, p_inact, b_offset=0,
    c_offset=0, c_total=None,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
    **blocks,
) -> jax.Array:
    use_kernel, interpret = _resolve(use_kernel, interpret)
    if use_kernel:
        return _ta_update_kernel.ta_delta(
            ta, lits, fire, ftype, seed,
            p_act=p_act, p_inact=p_inact, b_offset=b_offset,
            c_offset=c_offset, c_total=c_total,
            interpret=interpret, **blocks,
        )
    return ref.ta_delta_ref(ta, lits, fire, ftype, seed, p_act=p_act,
                            p_inact=p_inact, b_offset=b_offset,
                            c_offset=c_offset, c_total=c_total)


def xnor_dot(
    a_words, w_words, n_bits: int,
    *,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
    **blocks,
) -> jax.Array:
    use_kernel, interpret = _resolve(use_kernel, interpret)
    if use_kernel:
        return _xnor_kernel.xnor_popcount(
            a_words, w_words, n_bits, interpret=interpret, **blocks
        )
    return ref.xnor_popcount_ref(a_words, w_words, n_bits)


# ---------------------------------------------------------------------------
# Fused TM pipelines (the full accelerator datapath)
# ---------------------------------------------------------------------------

def tm_forward_packed(
    lit_words: jax.Array,    # (B, W)
    inc_words: jax.Array,    # (C, W)
    votes: jax.Array,        # (C, K)
    nonempty: jax.Array | None = None,  # (C,) uint8; None = training semantics
    *,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
    fuse: bool = True,
    autotune: bool = False,
    **blocks,
) -> jax.Array:
    """Packed literals -> (B, K) class sums (HCB chain + adder bank + mask).

    Kernel path (``use_kernel=True`` or ``REPRO_USE_PALLAS=1``) runs the
    fused single-pass kernel (``fused_infer.py``) — clause eval and vote
    accumulation in one ``pallas_call``, no (B, C) fired matrix in HBM.
    ``fuse=False`` keeps the legacy two-kernel pipeline; the oracle path is
    the default execution engine off-TPU.  ``autotune=True`` (kernel path,
    no explicit blocks) picks block sizes via ``autotune.py``'s cached sweep.
    """
    use_kernel, interpret = _resolve(use_kernel, interpret)
    if use_kernel and fuse:
        faults.raise_if("kernel.dense")   # drill: dense-kernel lowering failure
        if autotune and not blocks:
            from repro.kernels import autotune as _autotune

            B, W = lit_words.shape
            C, K = votes.shape
            blocks = _autotune.autotune_fused_blocks(
                B, C, W, K, interpret=interpret
            )
        return _fused_infer_kernel.fused_tm_forward(
            lit_words, inc_words, votes, nonempty, interpret=interpret, **blocks
        )
    kw = dict(use_kernel=use_kernel, interpret=interpret)
    cf_blocks = {k: v for k, v in blocks.items()
                 if k in ("block_b", "block_c", "block_w")}
    cs_blocks = {k: v for k, v in blocks.items() if k in ("block_b", "block_c")}
    fired = clause_fire(lit_words, inc_words, **kw, **cf_blocks)
    if nonempty is not None:
        fired = fired * nonempty[None, :].astype(fired.dtype)
    return class_sums(fired, votes, **kw, **cs_blocks)


def tm_forward_schedule(
    lit_words: jax.Array,       # (B, Wa) packed literals (word-compacted)
    include_words,              # (U, Wa) uint32 — np or jax; oracle operand
    votes: jax.Array,           # (U, K) int32 multiplicity x polarity
    schedule=None,              # kernels/sparse_infer.SparseSchedule
    *,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
    autotune: bool = False,
    block_s: int | None = None,
    tile_margin=None,           # (T,) anytime margins -> exact early-exit
    **blocks,
) -> jax.Array:
    """Compiled-artifact class sums via the block-sparse chain schedule.

    Kernel path: ``sparse_infer.sparse_tm_forward`` — the scalar-prefetched
    ragged tile grid, work proportional to the artifact's include bits.
    Otherwise the jnp oracle (vacuous-AND semantics: no nonempty mask —
    valid because ``compile_tm`` artifacts give all-zero rows zero votes;
    do NOT call this with a raw model whose empty clauses carry votes).
    ``schedule=None`` builds (or, with ``autotune=True``, sweeps) the
    tiling from ``include_words``.
    """
    use_kernel, interpret = _resolve(use_kernel, interpret)
    if use_kernel:
        faults.raise_if("kernel.sparse")  # drill: chain-kernel lowering failure
        if schedule is None:
            import numpy as np

            inc_np = np.asarray(include_words)
            if autotune and not blocks and block_s is None:
                from repro.kernels import autotune as _autotune

                B = lit_words.shape[0]
                tuned = _autotune.autotune_sparse_infer_blocks(
                    B, votes.shape[1], inc_np, interpret=interpret
                )
                blocks = {k: tuned[k] for k in ("block_c", "block_j")}
                block_s = tuned["block_s"]
            # content-memoized: the schedule is an identity-hashed jit
            # static arg, so per-call rebuilds would re-lower the kernel
            schedule = _sparse_infer_kernel.build_schedule_cached(
                inc_np,
                block_c=blocks.get(
                    "block_c", _sparse_infer_kernel.DEFAULT_BLOCK_C),
                block_j=blocks.get(
                    "block_j", _sparse_infer_kernel.DEFAULT_BLOCK_J),
            )
        return _sparse_infer_kernel.sparse_tm_forward(
            lit_words, votes, schedule,
            block_s=block_s or _sparse_infer_kernel.DEFAULT_BLOCK_S,
            interpret=interpret, tile_margin=tile_margin,
        )
    # oracle path ignores tile_margin: full sums are exact, which is a
    # strictly stronger answer than early-exit promises
    fired = ref.clause_fire_ref(lit_words, jnp.asarray(include_words))
    return ref.class_sum_ref(fired, votes)


def tm_forward_factorized(
    lit_words: jax.Array,       # (B, Wa) packed literals (word-compacted)
    include_words,              # (U, Wa) uint32 — np or jax; schedule source
    votes: jax.Array,           # (U, K) int32 multiplicity x polarity
    schedule=None,              # kernels/term_infer.FactorizedSchedule
    *,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
    autotune: bool = False,
    block_s: int | None = None,
    tile_margin=None,           # (T,) anytime margins -> exact early-exit
    **blocks,
) -> jax.Array:
    """Compiled-artifact class sums via the two-level FACTORIZED schedule.

    Kernel path: ``term_infer.factorized_tm_forward`` — stage 1 evaluates
    each unique (word, include-pattern) AND term once per sample slab into
    a VMEM term bitvector, stage 2 chains TERM ids per clause, so shared
    terms are computed once instead of once per clause.  Off the kernel
    path the jnp table oracle (``factorized_class_sums_ref``) runs the
    same two-level gather — both are bit-identical to dense ``ref``
    semantics for ``compile_tm`` artifacts (vacuous-AND contract as in
    ``tm_forward_schedule``).  ``schedule=None`` builds (or, with
    ``autotune=True``, sweeps) the tiling from ``include_words``.
    """
    import numpy as np

    use_kernel, interpret = _resolve(use_kernel, interpret)
    if use_kernel:
        # drill: factorized-kernel lowering failure (fires before the
        # schedule build so a demoted serve never pays it either)
        faults.raise_if("kernel.factorized")
    if schedule is None:
        inc_np = np.asarray(include_words)
        if (use_kernel and autotune and not blocks and block_s is None):
            from repro.kernels import autotune as _autotune

            B = lit_words.shape[0]
            tuned = _autotune.autotune_term_infer_blocks(
                B, votes.shape[1], inc_np, interpret=interpret
            )
            blocks = {k: tuned[k]
                      for k in ("block_c", "block_j", "block_t", "term_w")}
            block_s = tuned["block_s"]
        # content-memoized: the schedule is an identity-hashed jit static
        # arg, so per-call rebuilds would re-lower the kernel
        schedule = _term_infer_kernel.build_factorized_schedule_cached(
            inc_np,
            block_c=blocks.get(
                "block_c", _term_infer_kernel.DEFAULT_BLOCK_C),
            block_j=blocks.get(
                "block_j", _term_infer_kernel.DEFAULT_BLOCK_J),
            block_t=blocks.get(
                "block_t", _term_infer_kernel.DEFAULT_BLOCK_T),
            term_w=blocks.get("term_w"),
        )
    if use_kernel:
        return _term_infer_kernel.factorized_tm_forward(
            lit_words, votes, schedule,
            block_s=block_s or _term_infer_kernel.DEFAULT_BLOCK_S,
            interpret=interpret, tile_margin=tile_margin,
        )
    Cp = schedule.clause_chain.shape[0]
    vts = jnp.pad(votes.astype(jnp.int32), ((0, Cp - votes.shape[0]), (0, 0)))
    return _term_infer_kernel.factorized_class_sums_ref(
        lit_words, jnp.asarray(schedule.term_chain),
        jnp.asarray(schedule.clause_chain), vts,
    )


# ---------------------------------------------------------------------------
# Kernel-path TM training step (hash-RNG; matches ref.py bit-for-bit)
# ---------------------------------------------------------------------------

def feedback_probs(
    sums: jax.Array,       # (B, K) int32 CLAMPED class sums
    y: jax.Array,          # (B,) int32 targets (-1 = padded/invalid sample)
    n_classes: int,
    threshold: int,
    seed: jax.Array,       # uint32 scalar
    b_offset=0,            # global index of sample 0 (chunked training)
):
    """Per-sample feedback scalars: (kn, p_t, p_n).

    ``kn`` is the hash-sampled negative class (uniform over the K-1 others);
    ``p_t``/``p_n`` are the Type-I-side / Type-II-side clause selection
    probabilities ``(T -/+ clamp(sum))/2T``.  These are the only O(B)
    quantities the per-(sample, clause) feedback plan needs — the fused
    training kernel consumes them directly.
    """
    B = y.shape[0]
    T = threshold
    b_idx = jnp.arange(B, dtype=jnp.uint32) + jnp.uint32(b_offset)
    # negative class: hash-sampled uniformly from the K-1 others
    r_neg = ref.hash_u32(b_idx, seed ^ jnp.uint32(0x9E3779B9))
    kn = (r_neg % jnp.uint32(n_classes - 1)).astype(jnp.int32)
    kn = kn + (kn >= y)

    sum_t = jnp.take_along_axis(sums, y[:, None], axis=1)[:, 0]
    sum_n = jnp.take_along_axis(sums, kn[:, None], axis=1)[:, 0]
    p_t = (T - sum_t).astype(jnp.float32) / (2.0 * T)
    p_n = (T + sum_n).astype(jnp.float32) / (2.0 * T)
    return kn, p_t, p_n


def feedback_select(
    y: jax.Array,          # (B,) int32 targets
    kn: jax.Array,         # (B,) int32 sampled negative classes
    p_t: jax.Array,        # (B,) float32
    p_n: jax.Array,        # (B,) float32
    clause_class: jax.Array,   # (C,) int32 class id per clause
    clause_pol: jax.Array,     # (C,) int32 +1/-1 (0 = padded)
    seed: jax.Array,       # uint32 scalar
    b_offset=0,            # global index of sample 0
    c_offset=0,            # global index of clause 0 (clause-sharded step)
) -> jax.Array:
    """(B, C) uint8 feedback types: 0 none, 1 Type I, 2 Type II.

    This is the oracle the fused training kernel reproduces bit-for-bit;
    randomness is the same counter hash as the ta_update kernel, indexed by
    GLOBAL (sample, clause) id so sharded/chunked callers match unsharded.
    """
    B = y.shape[0]
    C = clause_class.shape[0]
    b_idx = jnp.arange(B, dtype=jnp.uint32) + jnp.uint32(b_offset)
    c_idx = (jnp.arange(C, dtype=jnp.uint32) + jnp.uint32(c_offset))[None, :]
    # hash indexed by global (b, c) via an offset-consistent mixing
    # (identical for sharded and unsharded callers)
    r_sel = ref.hash_u32(
        b_idx[:, None] * jnp.uint32(0x9E3779B1) + c_idx,
        seed ^ jnp.uint32(0x85EBCA6B),
    ).astype(jnp.float32) / jnp.float32(2**32)

    is_t = clause_class[None, :] == y[:, None]                 # (B, C)
    is_n = clause_class[None, :] == kn[:, None]
    p = jnp.where(is_t, p_t[:, None], jnp.where(is_n, p_n[:, None], 0.0))
    sel = r_sel < p

    pos = clause_pol[None, :] > 0
    neg = clause_pol[None, :] < 0
    ftype = jnp.where(
        is_t & pos, 1, jnp.where(is_t & neg, 2,
        jnp.where(is_n & pos, 2, jnp.where(is_n & neg, 1, 0))),
    )
    return jnp.where(sel, ftype, 0).astype(jnp.uint8)


def feedback_plan(
    fire: jax.Array,       # (B, C) uint8 training-mode clause outputs
    y: jax.Array,          # (B,) int32 targets
    votes: jax.Array,      # (C, K) int32
    clause_class: jax.Array,   # (C,) int32 class id per clause
    clause_pol: jax.Array,     # (C,) int32 +1/-1 (0 = padded)
    threshold: int,
    seed: jax.Array,       # uint32 scalar
    b_offset=0,            # global index of fire[0] (chunked training)
    c_offset=0,            # global index of fire[:, 0] (clause-sharded step)
    sums: jax.Array | None = None,  # precomputed clamped class sums (B, K)
):
    """Compute per-(sample, clause) feedback types: 0 none, 1 Type I, 2 Type II.

    Clause-level randomness uses the same hash RNG as the ta_update kernel so
    the whole kernel-path training step is reproducible and oracle-testable.
    """
    K = votes.shape[1]
    T = threshold
    if sums is None:
        sums = jnp.clip(fire.astype(jnp.int32) @ votes, -T, T)  # (B, K)
    kn, p_t, p_n = feedback_probs(sums, y, K, T, seed, b_offset=b_offset)
    ftype = feedback_select(
        y, kn, p_t, p_n, clause_class, clause_pol, seed,
        b_offset=b_offset, c_offset=c_offset,
    )
    return ftype, sums


def tm_train_step_kernel(
    config,
    ta_state: jax.Array,     # (C, L) int8 — the full bank OR a clause shard
    x: jax.Array,            # (B, F) {0,1}
    y: jax.Array,            # (B,)
    seed: jax.Array,         # uint32 scalar
    batch_chunk: int | None = None,
    *,
    fuse: bool = True,
    autotune: bool = False,
    blocks: dict | None = None,
    b_offset=0,              # global index of sample 0 (data-sharded caller)
    c_offset=0,              # global index of clause 0 (clause-sharded caller)
    c_total: int | None = None,  # set when ta_state is a clause shard
    sums_reduce=None,        # e.g. lambda s: lax.psum(s, "model")
    **kw,
):
    """Full kernel-path batch training step (clause_fire -> plan -> ta_delta).

    On the kernel path (``use_kernel=True`` / ``REPRO_USE_PALLAS=1``),
    ``fuse=True`` (the default) runs the whole step as TWO kernel launches:
    a fused-inference pass for the class sums the feedback plan needs, then
    the fused training kernel (``fused_train.py``) — clause fire, feedback
    type, and TA delta in one ``pallas_call``, with the ``(B, C)`` fire and
    ftype matrices never touching HBM.  ``fuse=False`` keeps the legacy
    three-dispatch pipeline; off the kernel path the ``ref.py`` oracles run.
    All engines are bit-identical.

    ``batch_chunk`` scans the batch in slices, accumulating the int32 delta —
    bit-identical to unchunked (the hash RNG is indexed by global sample id)
    but with O(chunk) working set instead of O(batch).  A ragged tail
    (``B % batch_chunk != 0``) is zero-padded to a full chunk and masked out
    of the feedback plan, so every batch size chunks bit-identically.

    ``autotune=True`` picks the fused kernels' block tilings from
    ``kernels/autotune.py``'s cached sweep (training shapes cache under
    their own key); ``blocks`` pins the fused training kernel tiling
    explicitly.

    **Clause-sharded mode** (the ``shard_map`` body of
    ``core/sharding.py:sharded_train_step_fn(engine="kernel")``): pass
    ``ta_state`` as the local ``(C_loc, L)`` shard, ``c_offset`` as its
    global clause offset (a traced ``axis_index``-derived scalar is fine),
    ``c_total=config.n_clauses_total``, and ``sums_reduce`` as the
    class-sum ``psum`` over the clause-shard axis.  ``b_offset`` is the
    global id of ``x[0]`` for data-sharded batches.  Every hash draw is
    then indexed by GLOBAL (sample, clause, literal) ids, so the returned
    shard delta equals the corresponding rows of the unsharded full-bank
    delta bit-for-bit.  NOTE: the returned ``new_ta`` applies only the
    LOCAL batch's delta — a data-sharded caller must ``psum`` the returned
    delta over its data axes and apply it to the shard itself.
    """
    from repro.core import packetizer, tm

    use_kernel, interpret = _resolve(kw.get("use_kernel"), kw.get("interpret"))
    fused = bool(fuse and use_kernel)
    inc_words = packetizer.pack_include_masks(ta_state)
    C_loc = ta_state.shape[0]
    votes = tm.vote_matrix(config)
    c = jnp.arange(config.n_clauses_total)
    clause_class = jnp.clip(c // config.clauses_per_class, 0, config.n_classes - 1)
    pol = tm.polarity(config)
    if c_total is not None:   # clause shard: local slices of the bank metadata
        assert c_total == config.n_clauses_total, (c_total, config)
        votes = jax.lax.dynamic_slice_in_dim(votes, c_offset, C_loc, 0)
        clause_class = jax.lax.dynamic_slice_in_dim(clause_class, c_offset, C_loc, 0)
        pol = jax.lax.dynamic_slice_in_dim(pol, c_offset, C_loc, 0)
    p_act = 1.0 if config.boost_true_positive else (config.s - 1.0) / config.s
    T = config.threshold
    B = x.shape[0]
    b_base = jnp.asarray(b_offset).astype(jnp.uint32)

    infer_blocks = {}
    if fused and autotune:
        from repro.kernels import autotune as _autotune

        chunk_b = batch_chunk if (batch_chunk and B > batch_chunk) else B
        C_tot, L = ta_state.shape
        W = packetizer.n_words(config.n_literals)
        if blocks is None:
            blocks = _autotune.autotune_fused_train_blocks(
                chunk_b, C_tot, W, L, config.n_classes, interpret=interpret
            )
        infer_blocks = _autotune.autotune_fused_blocks(
            chunk_b, C_tot, W, config.n_classes, interpret=interpret
        )

    def chunk_delta(xc, yc, b_off, valid):
        lits = tm.literals(xc)
        lit_words = packetizer.pack_bits(lits)
        if fused:
            # launch 1: class sums via the fused-inference accumulator
            # (training semantics: no nonempty mask) — bit-identical ints
            # to fire @ votes.  On a clause shard these are PARTIAL sums
            # over the local bank; ``sums_reduce`` (a psum over the
            # clause-shard axis) completes them exactly (int32 addition).
            sums = _fused_infer_kernel.fused_tm_forward(
                lit_words, inc_words, votes, None,
                interpret=interpret, **infer_blocks,
            )
            if sums_reduce is not None:
                sums = sums_reduce(sums)
            kn, p_t, p_n = feedback_probs(
                jnp.clip(sums, -T, T), yc, config.n_classes, T, seed,
                b_offset=b_off,
            )
            if valid is not None:     # padded tail samples select nothing
                p_t = jnp.where(valid, p_t, 0.0)
                p_n = jnp.where(valid, p_n, 0.0)
            # launch 2: fire -> ftype -> delta, all in VMEM
            return _fused_train_kernel.fused_tm_train_delta(
                ta_state, lits, lit_words, inc_words, yc, kn, p_t, p_n,
                clause_class, pol, seed,
                p_act=p_act, p_inact=1.0 / config.s, b_offset=b_off,
                c_offset=c_offset, c_total=c_total,
                interpret=interpret, **(blocks or {}),
            )
        fire = clause_fire(lit_words, inc_words, **kw).astype(jnp.uint8)
        sums = None
        if sums_reduce is not None:   # clause shard: complete the partials
            sums = jnp.clip(
                sums_reduce(fire.astype(jnp.int32) @ votes), -T, T
            )
        ftype, _ = feedback_plan(
            fire, yc, votes, clause_class, pol, T, seed, b_offset=b_off,
            c_offset=c_offset, sums=sums,
        )
        if valid is not None:
            ftype = jnp.where(valid[:, None], ftype, jnp.uint8(0))
        return ta_delta(
            ta_state, lits, fire, ftype, seed,
            p_act=p_act, p_inact=1.0 / config.s, b_offset=b_off,
            c_offset=c_offset, c_total=c_total, **kw,
        )

    if batch_chunk and B > batch_chunk:
        n = -(-B // batch_chunk)
        Bp = n * batch_chunk
        xs, ys = x, y
        if Bp != B:   # ragged tail: zero-pad samples, mask their feedback
            xs = jnp.pad(x, ((0, Bp - B), (0, 0)))
            ys = jnp.pad(y, (0, Bp - B), constant_values=-1)
        xs = xs.reshape(n, batch_chunk, *x.shape[1:])
        ys = ys.reshape(n, batch_chunk)
        need_mask = Bp != B

        def body(acc, inp):
            i, xc, yc = inp
            local_off = i * jnp.uint32(batch_chunk)
            valid = (
                (jnp.arange(batch_chunk, dtype=jnp.uint32) + local_off)
                < jnp.uint32(B)
            ) if need_mask else None
            return acc + chunk_delta(xc, yc, b_base + local_off, valid), None

        delta, _ = jax.lax.scan(
            body,
            jnp.zeros(ta_state.shape, jnp.int32),
            (jnp.arange(n, dtype=jnp.uint32), xs, ys),
        )
    else:
        delta = chunk_delta(x, y, b_base, None)
    new_ta = jnp.clip(
        ta_state.astype(jnp.int32) + delta, -config.n_states, config.n_states - 1
    ).astype(jnp.int8)
    return new_ta, delta


# ---------------------------------------------------------------------------
# Beyond-paper: matmul + binomial-aggregation TM training step
# ---------------------------------------------------------------------------

def _binomial_approx(n: jax.Array, p: float, gidx: jax.Array, seed: jax.Array):
    """~Binomial(n, p) per element via moment-matched normal (triangular z).

    Exact in mean/variance; the normal approximation error is negligible for
    the O(batch)-sized counts this path aggregates (and TM training is robust
    to RNG quality by design — the paper's trainers use LFSRs).
    """
    u1 = ref.hash_u32(gidx, seed).astype(jnp.float32) / jnp.float32(2**32)
    u2 = ref.hash_u32(gidx, seed ^ jnp.uint32(0xC2B2AE35)).astype(jnp.float32) \
        / jnp.float32(2**32)
    z = (u1 + u2 - 1.0) * jnp.float32(2.449489742783178)   # sqrt(6): unit var
    nf = n.astype(jnp.float32)
    s = nf * p + jnp.sqrt(jnp.maximum(nf * p * (1.0 - p), 0.0)) * z
    return jnp.clip(jnp.round(s), 0.0, nf).astype(jnp.int32)


def tm_train_step_matmul(
    config,
    ta_state: jax.Array,     # (C, L) int8
    x: jax.Array,            # (B, F) {0,1}
    y: jax.Array,            # (B,)
    seed: jax.Array,         # uint32 scalar
    delta_constrain=None,    # optional (C, L) sharding constraint: applied at
                             # the dot outputs so partial sums reduce-scatter
):
    """Batch TM training as three MXU matmuls + (C, L) elementwise sampling.

    Decomposition (boost_true_positive=True):
      Type I, clause=1, lit=1: deterministic +1  -> A   = M1f^T @ lit
      Type I penalties (p=1/s):        counts n1 = M1f^T @ (1-lit) + rowsum(M1n)
                                       draw ~ Binomial(n1, 1/s)
      Type II (deterministic on excluded, lit=0): n2 = M2^T @ (1-lit)
    where M1f/M1n/M2 are (B, C) feedback masks.  Memory is O(BC + BL + CL) —
    no (B, C, L) intermediate exists, and clause evaluation itself is the
    violation-count matmul (C,L)@(L,B).  Statistically equivalent to the
    exact per-sample path (matched mean/variance; see tests).
    """
    from repro.core import tm

    assert config.boost_true_positive, "matmul path assumes boost (p_act=1)"
    B = x.shape[0]
    C, L = ta_state.shape
    lits = tm.literals(x)                                    # (B, L) uint8
    lit_f = lits.astype(jnp.bfloat16)
    inc = (ta_state >= 0).astype(jnp.bfloat16)               # (C, L)

    # clause evaluation as a violation-count matmul (MXU)
    viol = jax.lax.dot_general(
        inc, (1.0 - lit_f), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                        # (C, B)
    fire = (viol.T < 0.5).astype(jnp.uint8)                  # (B, C)

    votes = tm.vote_matrix(config)
    c = jnp.arange(config.n_clauses_total)
    clause_class = jnp.clip(c // config.clauses_per_class, 0, config.n_classes - 1)
    ftype, _ = feedback_plan(
        fire, y, votes, clause_class, tm.polarity(config), config.threshold, seed
    )

    f1 = (ftype == 1)
    m1f = (f1 & (fire == 1)).astype(jnp.bfloat16)            # (B, C)
    m1n = (f1 & (fire == 0)).astype(jnp.float32)
    m2 = ((ftype == 2) & (fire == 1)).astype(jnp.bfloat16)

    def cb_matmul(m_bc, lit_bl):                             # -> (C, L) f32
        return jax.lax.dot_general(
            m_bc, lit_bl, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    A = cb_matmul(m1f, lit_f)                                # reward counts
    n1 = cb_matmul(m1f, 1.0 - lit_f) + jnp.sum(m1n, axis=0)[:, None]
    n2 = cb_matmul(m2, 1.0 - lit_f)
    if delta_constrain is not None:
        A, n1, n2 = map(delta_constrain, (A, n1, n2))

    gidx = (
        jnp.arange(C, dtype=jnp.uint32)[:, None] * jnp.uint32(L)
        + jnp.arange(L, dtype=jnp.uint32)[None, :]
    )
    pen = _binomial_approx(n1, 1.0 / config.s, gidx, seed ^ jnp.uint32(0x27D4EB2F))
    excl = (ta_state < 0).astype(jnp.int32)
    if delta_constrain is not None:
        excl = delta_constrain(excl)
    delta = A.astype(jnp.int32) - pen + n2.astype(jnp.int32) * excl

    new_ta = jnp.clip(
        ta_state.astype(jnp.int32) + delta, -config.n_states, config.n_states - 1
    ).astype(jnp.int8)
    return new_ta, delta


def tm_train_step_matmul_local(
    config,
    ta_loc: jax.Array,     # (C_loc, L_loc) int8 — dual-axis shard
    x_loc: jax.Array,      # (B_loc, F) {0,1}
    y_loc: jax.Array,      # (B_loc,)
    seed: jax.Array,       # uint32 scalar
):
    """shard_map body for the matmul TM step on a ("data", "model") mesh.

    Explicit collective schedule (GSPMD's partitioner falls back to a dense
    all-reduce of the f32 delta here — see EXPERIMENTS.md §Perf):
      1. all-gather int8 automata over `data`       (C_loc x L, ~31 MB)
      2. local viol/feedback matmuls (MXU)
      3. one tiny psum of (B_loc, K) class sums over `model`
      4. psum_scatter the f32 partial deltas over `data` -> (C_loc, L_loc)
    """
    from repro.core import tm

    di = jax.lax.axis_index("data")
    mi = jax.lax.axis_index("model")
    n_data = jax_compat.axis_size("data")
    C_loc, L_loc = ta_loc.shape
    B_loc = x_loc.shape[0]
    b_off = di * B_loc
    c_off = mi * C_loc
    l_off = di * L_loc

    ta_full = jax.lax.all_gather(ta_loc, "data", axis=1, tiled=True)  # (C_loc, L)
    lits = tm.literals(x_loc)                                 # (B_loc, L)
    lit_f = lits.astype(jnp.bfloat16)
    inc = (ta_full >= 0).astype(jnp.bfloat16)

    viol = jax.lax.dot_general(
        inc, (1.0 - lit_f), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                         # (C_loc, B_loc)
    fire = (viol.T < 0.5).astype(jnp.uint8)                   # (B_loc, C_loc)

    votes = tm.vote_matrix(config)                            # (C, K) global
    votes_loc = jax.lax.dynamic_slice_in_dim(votes, c_off, C_loc, 0)
    sums = jax.lax.psum(fire.astype(jnp.int32) @ votes_loc, "model")
    sums = jnp.clip(sums, -config.threshold, config.threshold)

    cc = jnp.clip(
        jnp.arange(config.n_clauses_total) // config.clauses_per_class,
        0, config.n_classes - 1,
    )
    pol = tm.polarity(config)
    cc_loc = jax.lax.dynamic_slice_in_dim(cc, c_off, C_loc, 0)
    pol_loc = jax.lax.dynamic_slice_in_dim(pol, c_off, C_loc, 0)
    ftype, _ = feedback_plan(
        fire, y_loc, votes_loc, cc_loc, pol_loc, config.threshold, seed,
        b_offset=b_off, c_offset=c_off, sums=sums,
    )

    f1 = (ftype == 1)
    m1f = (f1 & (fire == 1)).astype(jnp.bfloat16)
    m1n = (f1 & (fire == 0)).astype(jnp.float32)
    m2 = ((ftype == 2) & (fire == 1)).astype(jnp.bfloat16)

    def cb(m_bc, lit_bl):
        return jax.lax.dot_general(
            m_bc, lit_bl, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    A = cb(m1f, lit_f)                                        # (C_loc, L) partial
    n1 = cb(m1f, 1.0 - lit_f) + jnp.sum(m1n, axis=0)[:, None]
    n2 = cb(m2, 1.0 - lit_f)
    stacked = jnp.stack([A, n1, n2])                          # (3, C_loc, L)
    stacked = jax.lax.psum_scatter(
        stacked, "data", scatter_dimension=2, tiled=True
    )                                                         # (3, C_loc, L_loc)
    A, n1, n2 = stacked[0], stacked[1], stacked[2]

    L_total = L_loc * n_data
    gidx = (
        (jnp.arange(C_loc, dtype=jnp.uint32) + jnp.uint32(c_off))[:, None]
        * jnp.uint32(L_total)
        + (jnp.arange(L_loc, dtype=jnp.uint32) + jnp.uint32(l_off))[None, :]
    )
    pen = _binomial_approx(n1, 1.0 / config.s, gidx, seed ^ jnp.uint32(0x27D4EB2F))
    excl = (ta_loc < 0).astype(jnp.int32)
    delta = jnp.round(A).astype(jnp.int32) - pen + jnp.round(n2).astype(jnp.int32) * excl
    return jnp.clip(
        ta_loc.astype(jnp.int32) + delta,
        -config.n_states, config.n_states - 1,
    ).astype(jnp.int8)
