"""Pallas TPU kernel: polarity-weighted class-sum vote tally.

The paper's class-sum stage is a bank of 2xCL adders behind the HCB chain
(Fig. 5), pipelined against clause evaluation.  On TPU it is an integer
matmul of the fired-clause matrix against the (clause x class) vote matrix;
this kernel tiles the clause (reduction) axis so it streams behind the
clause_eval kernel's output blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat


def _class_sum_kernel(fired_ref, votes_ref, out_ref):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    f = fired_ref[...].astype(jnp.int32)     # (bb, bc)
    v = votes_ref[...]                        # (bc, K)
    out_ref[...] += jax.lax.dot_general(
        f, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


@functools.partial(jax.jit, static_argnames=("block_b", "block_c", "interpret"))
def class_sum(
    fired: jax.Array,   # (B, C) int8/uint8 {0,1}
    votes: jax.Array,   # (C, K) int32
    *,
    block_b: int = 256,
    block_c: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """(B, K) int32 class sums == kernels/ref.py:class_sum_ref."""
    B, C = fired.shape
    K = votes.shape[1]
    block_b = min(block_b, _rup(B, 8))
    block_c = min(block_c, _rup(C, 128))
    Bp, Cp, Kp = _rup(B, block_b), _rup(C, block_c), _rup(K, 128)

    f = jnp.pad(fired, ((0, Bp - B), (0, Cp - C)))
    v = jnp.pad(votes, ((0, Cp - C), (0, Kp - K)))

    grid = (Bp // block_b, Cp // block_c)
    out = pl.pallas_call(
        _class_sum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_c), lambda b, c: (b, c)),
            pl.BlockSpec((block_c, Kp), lambda b, c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, Kp), lambda b, c: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, Kp), jnp.int32),
        compiler_params=pallas_compat.CompilerParams(dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(f, v)
    return out[:B, :K]


def _rup(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
