"""Anytime-inference metadata: per-tile-prefix margin bounds + quality tiers.

TM class sums are monotone vote accumulations (PAPERS.md, "Runtime Tunable
Tsetlin Machines"): after walking a prefix of the tile schedule, the
not-yet-folded clause blocks can move any *pairwise* class margin by at
most the sum of their per-row vote swings.  That single scalar per tile
prefix — ``margin[t]`` = remaining maximum vote swing after tile ``t`` —
funds both runtime exit modes:

* **exact early-exit** — once the leading class's top1-top2 margin is
  *strictly* greater than ``margin[t]``, no remaining tile can change the
  argmax (strict: at equality a final tie could flip argmax toward a
  lower class index).  Predictions are bit-identical to the full walk.
* **budgeted mode** — run only the first ``P`` tiles and report
  ``margin[P - 1]`` as the error bound: every pairwise class-sum margin
  of the served answer is within ±bound of the full walk's, so the served
  class trails the true winner by at most ``bound`` votes.

Soundness of the per-row swing: an unfolded row ``r`` contributes either
``votes[r]`` (fires) or ``0`` to the class sums, so its contribution to
any pairwise delta ``S[a] - S[b]`` lies in ``{0, votes[r][a] -
votes[r][b]}`` — bounded in magnitude by ``votes[r].max() -
votes[r].min()``.  Rows whose clause block never folds (zero-tile blocks)
contribute to neither the full walk nor the bound.

``margin_order`` re-orders clause rows so high-|vote|-mass blocks fold
first (margins decay fast -> early exit fires sooner); ordering is purely
a performance lever — the bounds above hold for any order.

Everything here is plain numpy over schedule metadata; the kernels only
ever see the finished ``(T,)`` margin table (scalar-prefetch) or a sliced
prefix schedule.
"""

from __future__ import annotations

import dataclasses

import numpy as np

# Residual-swing fraction allowed per quality level (1 = mildest
# degradation .. 3 = deepest brownout).  Level 0 is always exact.
DEFAULT_QUALITY_FRACS = (0.05, 0.15, 0.35)
MAX_QUALITY = len(DEFAULT_QUALITY_FRACS)


def row_swing(votes: np.ndarray) -> np.ndarray:
    """(U, K) int votes -> (U,) int64 per-row max pairwise vote swing."""
    v = np.asarray(votes, dtype=np.int64)
    if v.ndim != 2 or v.shape[1] == 0:
        return np.zeros(v.shape[0], np.int64)
    return v.max(axis=1) - v.min(axis=1)


def total_swing(votes: np.ndarray) -> int:
    """Sum of all row swings — the margin "before tile 0"."""
    return int(row_swing(votes).sum())


def _fold_margins(fold_tiles: np.ndarray, block_swing: np.ndarray,
                  n_tiles: int) -> np.ndarray:
    """margin[t] = sum of block_swing over blocks whose fold tile > t."""
    margins = np.zeros(n_tiles, np.int64)
    if n_tiles == 0 or fold_tiles.size == 0:
        return margins
    folded_at = np.bincount(fold_tiles, weights=block_swing.astype(np.float64),
                            minlength=n_tiles)[:n_tiles]
    margins[:] = block_swing.sum() - np.cumsum(folded_at).astype(np.int64)
    return np.maximum(margins, 0)


def sparse_tile_margins(schedule, votes: np.ndarray) -> np.ndarray:
    """(T,) int64 residual-swing table for a :class:`SparseSchedule`.

    ``votes`` is the (U, K) vote table aligned with the schedule's row
    order (padded rows, if passed, are all-zero and contribute nothing).
    """
    T = schedule.n_tiles
    swing = row_swing(votes)
    bc = schedule.block_c
    n_cb = schedule.n_cblocks
    # per-clause-block swing over its real rows
    need = n_cb * bc
    sw = np.pad(swing, (0, max(0, need - len(swing))))[:need]
    block_swing = sw.reshape(n_cb, bc).sum(axis=1)
    counts = np.asarray(schedule.counts, np.int64)
    fold = np.asarray(schedule.indptr, np.int64)[1:] - 1   # last tile per cb
    live = counts > 0                                      # zero-tile blocks never fold
    return _fold_margins(fold[live], block_swing[:len(fold)][live], T)


def factorized_tile_margins(fschedule, votes: np.ndarray) -> np.ndarray:
    """(T,) int64 residual-swing table for a :class:`FactorizedSchedule`.

    Stage-1 term tiles (indices ``[0, n_term_tiles)``) fold no votes, so
    the margin there is the full total swing; clause-tile folds are offset
    by ``n_term_tiles``.
    """
    T = fschedule.n_tiles
    nt = fschedule.n_term_tiles
    swing = row_swing(votes)
    bc = fschedule.block_c
    counts = np.asarray(fschedule.counts, np.int64)
    n_cb = len(counts)
    need = n_cb * bc
    sw = np.pad(swing, (0, max(0, need - len(swing))))[:need]
    block_swing = sw.reshape(n_cb, bc).sum(axis=1)
    fold = nt + np.asarray(fschedule.indptr, np.int64)[1:] - 1
    live = counts > 0
    return _fold_margins(fold[live], block_swing[live], T)


def sparse_prefix_schedule(schedule, n_tiles: int):
    """Slice a sparse schedule to its first ``n_tiles`` tiles.

    Clause blocks cut mid-chain never reach their fold tile and so
    contribute exactly 0 votes — which is what the ``margin[P-1]`` bound
    already accounts for.
    """
    P = int(max(1, min(n_tiles, schedule.n_tiles)))
    if P == schedule.n_tiles:
        return schedule
    indptr = np.asarray(schedule.indptr, np.int64)
    counts_p = (np.clip(indptr[1:], 0, P)
                - np.clip(indptr[:-1], 0, P)).astype(schedule.counts.dtype)
    indptr_p = np.concatenate([[0], np.cumsum(counts_p)]).astype(
        schedule.indptr.dtype)
    return dataclasses.replace(
        schedule,
        tile_cb=schedule.tile_cb[:P], tile_jb=schedule.tile_jb[:P],
        tile_first=schedule.tile_first[:P], tile_last=schedule.tile_last[:P],
        counts=counts_p, indptr=indptr_p,
    )


def factorized_prefix_schedule(fschedule, n_tiles: int):
    """Slice a factorized schedule to its first ``n_tiles`` tiles.

    Every stage-1 term tile is always retained (clause chains read the
    term scratch, which must be fully populated), so the effective prefix
    is clamped to ``n_term_tiles + 1``.
    """
    nt = fschedule.n_term_tiles
    P = int(max(nt + 1, min(n_tiles, fschedule.n_tiles)))
    if P >= fschedule.n_tiles:
        return fschedule
    indptr = np.asarray(fschedule.indptr, np.int64)
    Pc = P - nt                                  # clause tiles kept
    counts_p = (np.clip(indptr[1:], 0, Pc)
                - np.clip(indptr[:-1], 0, Pc)).astype(fschedule.counts.dtype)
    indptr_p = np.concatenate([[0], np.cumsum(counts_p)]).astype(
        fschedule.indptr.dtype)
    return dataclasses.replace(
        fschedule,
        tile_stage=fschedule.tile_stage[:P], tile_tb=fschedule.tile_tb[:P],
        tile_cb=fschedule.tile_cb[:P], tile_jb=fschedule.tile_jb[:P],
        tile_first=fschedule.tile_first[:P], tile_last=fschedule.tile_last[:P],
        counts=counts_p, indptr=indptr_p,
    )


def quality_prefixes(margins: np.ndarray, total: int,
                     fracs=DEFAULT_QUALITY_FRACS,
                     min_tiles: int = 1) -> list:
    """Map quality levels to tile prefixes.

    Returns ``[{level, n_tiles, bound, frac}, ...]`` for levels ``1..N``:
    the smallest prefix whose residual margin is at most ``frac * total``
    swing.  Level 0 (exact, full walk, bound 0) is implicit.
    """
    m = np.asarray(margins, np.int64)
    out = []
    for lvl, frac in enumerate(fracs, start=1):
        if m.size == 0:
            out.append(dict(level=lvl, n_tiles=0, bound=0, frac=frac))
            continue
        target = int(frac * total)
        ok = m <= target                 # monotone: False..False True..True
        first = int(np.argmax(ok)) if ok.any() else m.size - 1
        P = max(min_tiles, first + 1)
        out.append(dict(level=lvl, n_tiles=P, bound=int(m[P - 1]), frac=frac))
    return out


def margin_order(include_words: np.ndarray, votes: np.ndarray,
                 cluster_fn=None, n_bands: int = 8) -> np.ndarray:
    """Row permutation: vote-mass (|polarity x multiplicity|) bands
    descending, density-clustered within each band.

    High-mass blocks fold first so ``margins`` decays steeply (early exit
    certifies sooner, short budgeted prefixes carry most of the vote
    mass), while in-band clustering keeps chain lengths homogeneous so
    tile counts stay near the pure-clustered layout.
    """
    votes = np.asarray(votes)
    U = votes.shape[0]
    if U <= 1:
        return np.arange(U)
    mass = np.abs(votes.astype(np.int64)).sum(axis=1)
    top = int(mass.max())
    if top <= 0:
        band = np.zeros(U, np.int64)
    else:
        # log2-spaced bands below the max mass; zero-mass rows last
        with np.errstate(divide="ignore"):
            band = np.floor(np.log2(top / np.maximum(mass, 1))).astype(np.int64)
        band = np.clip(band, 0, n_bands - 1)
        band[mass == 0] = n_bands
    order = []
    for b in np.unique(band):
        rows = np.nonzero(band == b)[0]
        if cluster_fn is not None and len(rows) > 1:
            rows = rows[cluster_fn(include_words[rows])]
        order.append(rows)
    return np.concatenate(order)
