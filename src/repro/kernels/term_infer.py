"""Pallas TPU kernel: shared-term FACTORIZED compiled TM inference.

The block-sparse chain kernel (``sparse_infer.py``) walks each clause's
include BITS — but on a trained bank the same word-level AND term (an
(active-word, include-pattern) pair) appears in many clauses: MATADOR's
Fig. 5 logic absorption collapses those to ONE gate, and
``CompileStats.partial_term_sharing`` measures exactly that opportunity.
This kernel *exploits* it with a two-level factorized execution schedule
emitted by ``core/compiler.py``:

  * **term table** — the unique nonzero ``(word, include-value)`` pairs
    across the deduped clause bank, each compiled into a literal-bit chain
    of ``<= 32`` steps (one packed word's worth of include bits);
  * **clause chains** — every clause is rewritten as a compacted chain of
    *term ids* (one id per active word), tiled into the same CSR-like
    per-clause-block table the sparse kernel uses.

Execution is ONE ``pallas_call`` over grid ``(sample-word-block, tile)``
with two in-VMEM stages per sample block, driven by a scalar-prefetched
tile table (``tile_stage`` flags term vs clause tiles; term tiles come
first so the flat tile walk is stage 1 then stage 2):

  * **stage 1** (term tiles): each unique term is evaluated ONCE against
    the bit-transposed literals — gather the term's literal rows, tree-AND
    them — into a ``(Tp, block_s)`` uint32 bitvector scratch (row ``t`` =
    term ``t`` of 32 samples per word, the same sample-parallel layout as
    the clause state);
  * **stage 2** (clause tiles): the carried ``(block_c, block_s)`` clause
    state gathers TERM rows from the scratch and tree-ANDs them — one step
    per *active word*, not per include bit — then the last tile of each
    clause block unpacks the fired bits and folds the multiplicity x
    polarity votes through one MXU dot.

Work therefore scales with the artifact's UNIQUE include structure: a term
shared by ``n`` clauses costs its bit chain once plus ``n`` single-row
gathers, instead of ``n`` full bit chains.  Exactness contract matches the
sparse kernel: padding terms (rows past ``n_terms``) have empty bit chains
and evaluate to constant 1, so sentinel-padded clause chains are exact,
all-zero clause rows fire vacuously, and their votes must be zero (true
for every ``compile_tm`` artifact).

Validated bit-exactly against the jnp oracle in Pallas interpret mode
(tests/test_term_infer.py); compiled-TPU lowering of the in-kernel row
gather shares the ROADMAP "Next" item with the sparse kernel.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import packetizer
from repro.kernels.fused_infer import _rup
from repro.kernels.sparse_infer import (_NEG_SUM, _slab_lead_margin,
                                        artifact_tag, bit_transpose_literals)

# default factorized tiling: 1024-clause banks, 64-term chain tiles, one
# big 32768-term stage-1 tile (term evaluation is the cheap stage — fewer,
# larger tiles beat grid overhead), 16-word (512-sample) slabs — see
# kernels/autotune.py for the swept alternatives; small artifacts clip
DEFAULT_BLOCK_C = 1024
DEFAULT_BLOCK_J = 64
DEFAULT_BLOCK_T = 32768
DEFAULT_BLOCK_S = 16


@dataclasses.dataclass(frozen=True, eq=False)
class FactorizedSchedule:
    """Two-level factorized execution schedule for one clause bank.

    ``term_chain[t, i]`` is the literal BIT id of term ``t``'s ``i``-th
    include bit (sentinel ``n_lit_bits`` past the term's popcount — the
    all-ones transposed literal row); rows past ``n_terms`` are all-
    sentinel padding terms that evaluate to constant 1.  ``clause_chain[c,
    j]`` is the TERM id of clause ``c``'s ``j``-th active word (sentinel
    ``n_terms`` — a padding term — past the clause's active-word count).
    The flat scalar-prefetched tile table walks stage-1 term tiles first
    (``tile_stage == 0``, ``tile_tb`` selects the term block) then stage-2
    clause tiles (``tile_stage == 1``; ``tile_cb``/``tile_jb``/
    ``tile_first``/``tile_last`` as in ``SparseSchedule``); ``counts``/
    ``indptr`` are the CSR view over CLAUSE tiles per clause block.
    Identity-hashed (``eq=False``) so a schedule works as a jit static
    argument, like ``SparseSchedule``.
    """

    block_c: int
    block_j: int                # term-chain positions per clause tile
    block_t: int                # term rows per stage-1 tile
    term_w: int                 # bit-chain positions per term row
    n_rows: int                 # unique clauses covered (pre-padding)
    n_terms: int                # unique (word, value) terms (pre-padding)
    n_lit_bits: int             # literal-bit sentinel id
    term_word: np.ndarray       # (n_terms,) int32 active-word index per term
    term_val: np.ndarray        # (n_terms,) uint32 include-word value
    term_chain: np.ndarray      # (Tp, term_w) int32 literal bit ids
    clause_chain: np.ndarray    # (Cp, Jp) int32 term ids
    tile_stage: np.ndarray      # (T,) int32 0 = term tile, 1 = clause tile
    tile_tb: np.ndarray         # (T,) int32 term-block id (stage-1 tiles)
    tile_cb: np.ndarray         # (T,) int32 clause-block id (stage-2 tiles)
    tile_jb: np.ndarray         # (T,) int32 chain-block id (stage-2 tiles)
    tile_first: np.ndarray      # (T,) int32 1 = first clause tile of block
    tile_last: np.ndarray       # (T,) int32 1 = last clause tile of block
    counts: np.ndarray          # (n_cblocks,) int32 clause tiles per block
    indptr: np.ndarray          # (n_cblocks + 1,) int32 CSR row pointers

    @property
    def n_tiles(self) -> int:
        return int(self.tile_stage.shape[0])

    @property
    def n_term_tiles(self) -> int:
        return int((self.tile_stage == 0).sum())

    @property
    def n_cblocks(self) -> int:
        return int(self.counts.shape[0])

    @property
    def n_term_refs(self) -> int:
        """Total term references across all clause chains — the number of
        term evaluations a non-factorized executor would pay."""
        # clause_chain rows past n_rows are all-sentinel padding
        return int((self.clause_chain[: self.n_rows] != self.n_terms).sum())

    @property
    def realized_term_sharing(self) -> float:
        """Fraction of per-word AND terms this schedule does NOT evaluate:
        1 - terms_evaluated / terms_pre_factorization.  The *realized*
        counterpart of ``CompileStats.partial_term_sharing`` (equal for
        ``compile_tm`` artifacts when no term splits — the compiler stat
        quantifies exactly the sharing this schedule exploits; with fat
        terms split into pieces both counts are piece-granular)."""
        dense = self.n_term_refs
        if dense == 0:
            return 0.0
        return 1.0 - self.n_terms / dense

    def as_dict(self) -> dict:
        return dict(
            block_c=self.block_c, block_j=self.block_j, block_t=self.block_t,
            term_w=self.term_w, n_terms=self.n_terms, n_tiles=self.n_tiles,
            n_term_tiles=self.n_term_tiles,
            realized_term_sharing=self.realized_term_sharing,
        )


def pick_term_width(include_words: np.ndarray) -> int:
    """Auto bit-chain width for an artifact's term table: the smallest
    power of two covering the 95th-percentile popcount of its unique
    (word, value) terms, clipped to [2, 32].  Trained TM terms are mostly
    1-2 bits, so a narrow fixed row keeps stage-1 gather work ~2 rows per
    term; the rare fat term (thermometer-run includes) splits into pieces
    instead of widening every row."""
    iw = np.ascontiguousarray(np.asarray(include_words, dtype=np.uint32))
    act_c, act_w = np.nonzero(iw)
    if act_c.size == 0:
        return 2
    key = (act_w.astype(np.uint64) << np.uint64(32)) \
        | iw[act_c, act_w].astype(np.uint64)
    vals = (np.unique(key) & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    pcs = np.array([int(v).bit_count() for v in vals])
    p95 = int(np.percentile(pcs, 95))
    w = 2
    while w < min(max(p95, 2), 32):
        w *= 2
    return w


def build_factorized_schedule(
    include_words: np.ndarray,
    *,
    block_c: int = DEFAULT_BLOCK_C,
    block_j: int = DEFAULT_BLOCK_J,
    block_t: int = DEFAULT_BLOCK_T,
    term_w: int | None = None,
    pad_tiles_to: int | None = None,
) -> FactorizedSchedule:
    """Compile ``(U, Wa)`` packed include rows into a factorized schedule.

    Rows are taken in the given order (``compile_tm`` has already applied
    ``cluster_order``).  Terms are ordered by (word, value) so the term
    table inherits the words' DMA locality.  A (word, value) term whose
    popcount exceeds ``term_w`` (default: :func:`pick_term_width`) is
    split into deduped PIECES of ``<= term_w`` bits — a piece is itself a
    (word, sub-pattern) AND term, two fat terms sharing a sub-pattern
    share its piece, and the owning clauses chain every piece, so the
    factorization stays exact.  ``pad_tiles_to`` appends no-op clause
    tiles so shards of one artifact can share a common tile-table shape
    (the cross-shard equalizer, as in ``build_schedule``).
    """
    iw = np.ascontiguousarray(np.asarray(include_words, dtype=np.uint32))
    U, Wa = iw.shape
    n_lit_bits = Wa * 32
    if term_w is None:
        term_w = pick_term_width(iw)

    # word-term table: unique nonzero (word, value) pairs, (word, value)
    # sorted; then split into <= term_w-bit pieces, deduped by bit pattern
    act_c, act_w = np.nonzero(iw)
    vals = iw[act_c, act_w]
    key = (act_w.astype(np.uint64) << np.uint64(32)) | vals.astype(np.uint64)
    uniq_key, wterm_of_entry = np.unique(key, return_inverse=True)
    piece_id: dict = {}
    term_word_l: list = []
    term_val_l: list = []
    term_chain_l: list = []
    pieces_of_wterm: list = []
    for k in uniq_key:
        w = int(k >> np.uint64(32))
        v = int(k & np.uint64(0xFFFFFFFF))
        bits = [i for i in range(32) if v >> i & 1]
        ids = []
        for lo in range(0, len(bits), term_w):
            chunk = tuple(bits[lo:lo + term_w])
            pk = (w, chunk)
            if pk not in piece_id:
                piece_id[pk] = len(term_chain_l)
                term_word_l.append(w)
                term_val_l.append(sum(1 << b for b in chunk))
                term_chain_l.append([32 * w + b for b in chunk])
            ids.append(piece_id[pk])
        pieces_of_wterm.append(ids)
    n_terms = len(term_chain_l)
    term_word = np.asarray(term_word_l, np.int32).reshape(-1)
    term_val = np.asarray(term_val_l, np.uint32).reshape(-1)

    block_t = max(min(block_t, _rup(max(n_terms + 1, 1), 8)), 1)
    Tp = _rup(n_terms + 1, block_t)   # >= 1 all-ones padding term (sentinel)
    term_chain = np.full((Tp, term_w), n_lit_bits, np.int32)
    for t, lids in enumerate(term_chain_l):
        term_chain[t, : len(lids)] = lids

    # clause chains over term (piece) ids — one step per active word piece
    chain_of_clause: list = [[] for _ in range(U)]
    for c, wt in zip(act_c, wterm_of_entry.reshape(-1)):
        chain_of_clause[c].extend(pieces_of_wterm[wt])
    block_c = max(min(block_c, _rup(max(U, 1), 8)), 1)
    Cp = _rup(max(U, 1), block_c)
    per_clause = np.zeros(Cp, np.int32)
    for c in range(U):
        per_clause[c] = len(chain_of_clause[c])

    n_cblocks = Cp // block_c
    counts = np.zeros(n_cblocks, np.int32)
    for b in range(n_cblocks):
        j_max = int(per_clause[b * block_c:(b + 1) * block_c].max())
        counts[b] = -(-j_max // block_j)
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)

    n_term_tiles = Tp // block_t
    T_clause_real = int(counts.sum())
    T_real = n_term_tiles + T_clause_real
    T = max(T_real, pad_tiles_to or 0)
    n_jblocks = int(counts.max()) if T_clause_real else 0
    pad_jblock = n_jblocks if T > T_real or n_jblocks == 0 else None
    if pad_jblock is not None:
        n_jblocks += 1                    # all-sentinel block for no-op tiles
    Jp = n_jblocks * block_j

    clause_chain = np.full((Cp, max(Jp, block_j)), n_terms, np.int32)
    for c in range(U):
        ids = chain_of_clause[c]
        clause_chain[c, : len(ids)] = sorted(ids)

    tile_stage = np.ones(max(T, 1), np.int32)
    tile_tb = np.zeros(max(T, 1), np.int32)
    tile_cb = np.zeros(max(T, 1), np.int32)
    tile_jb = np.zeros(max(T, 1), np.int32)
    tile_first = np.zeros(max(T, 1), np.int32)
    tile_last = np.zeros(max(T, 1), np.int32)
    # stage 1 first: every term is in scratch before any clause tile reads it
    for t in range(n_term_tiles):
        tile_stage[t] = 0
        tile_tb[t] = t
    t = n_term_tiles
    for b in range(n_cblocks):
        n = int(counts[b])
        for j in range(n):
            tile_cb[t], tile_jb[t] = b, j
            tile_first[t] = int(j == 0)
            tile_last[t] = int(j == n - 1)
            t += 1
    # no-op padding tiles: all-sentinel clause chain block, never first/last
    for tt_ in range(t, T):
        tile_jb[tt_] = pad_jblock if pad_jblock is not None else 0

    return FactorizedSchedule(
        block_c=block_c, block_j=block_j, block_t=block_t, term_w=term_w,
        n_rows=U, n_terms=n_terms, n_lit_bits=n_lit_bits,
        term_word=term_word, term_val=term_val,
        term_chain=term_chain, clause_chain=clause_chain,
        tile_stage=tile_stage[:T] if T else tile_stage[:0],
        tile_tb=tile_tb[:T] if T else tile_tb[:0],
        tile_cb=tile_cb[:T] if T else tile_cb[:0],
        tile_jb=tile_jb[:T] if T else tile_jb[:0],
        tile_first=tile_first[:T] if T else tile_first[:0],
        tile_last=tile_last[:T] if T else tile_last[:0],
        counts=counts, indptr=indptr,
    )


# identity-hashed jit static args: repeated builds for the same artifact +
# tiling must return the SAME object (see sparse_infer._SCHEDULE_CACHE)
_FSCHEDULE_CACHE: dict = {}


def build_factorized_schedule_cached(
    include_words: np.ndarray,
    *,
    block_c: int = DEFAULT_BLOCK_C,
    block_j: int = DEFAULT_BLOCK_J,
    block_t: int = DEFAULT_BLOCK_T,
    term_w: int | None = None,
) -> FactorizedSchedule:
    """Content-memoized :func:`build_factorized_schedule` for callers
    without a :class:`CompiledTM` to memoize on."""
    if term_w is None:
        term_w = pick_term_width(include_words)
    key = (artifact_tag(include_words), block_c, block_j, block_t, term_w)
    if key not in _FSCHEDULE_CACHE:
        _FSCHEDULE_CACHE[key] = build_factorized_schedule(
            np.asarray(include_words, dtype=np.uint32),
            block_c=block_c, block_j=block_j, block_t=block_t,
            term_w=term_w)
    return _FSCHEDULE_CACHE[key]


def _term_infer_kernel(
    *refs,
    # positional refs: tstage, ttb, tcb, tjb, tfirst, tlast, [tmargin,]
    # litT, tchain, cchain, votes -> out, term scratch, ok scratch
    # [, done scratch]
    #   tstage       (T,) scalar-prefetch: 0 = term tile, 1 = clause tile
    #   ttb          (T,) scalar-prefetch: term-block id per stage-1 tile
    #   tcb/tjb      (T,) scalar-prefetch: clause-/chain-block id (stage 2)
    #   tfirst/tlast (T,) scalar-prefetch: first/last clause tile of block
    #   tmargin      (T,) scalar-prefetch: residual vote swing after tile t
    #   litT         (L + 1, block_s) uint32 bit-transposed literals
    #   tchain       (block_t, term_w) int32 literal ids of this term tile
    #   cchain       (block_c, block_j) int32 term ids of this clause tile
    #   votes        (block_c, Kp) int32 multiplicity x polarity votes
    #   out          (block_s * 32, Kp) int32 class sums
    #   term         VMEM scratch (Tp, block_s) uint32 term bitvectors
    #   ok           VMEM scratch (block_c, block_s) uint32 carried bits
    #   done         SMEM scratch (1,) int32 — slab certified, skip tiles
    block_t: int,
    block_c: int,
    block_j: int,
    block_s: int,
    term_w: int,
    n_classes: int = 0,
    n_samples: int = 0,
    early_exit: bool = False,
):
    if early_exit:
        (tstage_ref, ttb_ref, tcb_ref, tjb_ref, tfirst_ref, tlast_ref,
         tmargin_ref, litT_ref, tchain_ref, cchain_ref, votes_ref,
         out_ref, term_ref, ok_ref, done_ref) = refs
    else:
        (tstage_ref, ttb_ref, tcb_ref, tjb_ref, tfirst_ref, tlast_ref,
         litT_ref, tchain_ref, cchain_ref, votes_ref,
         out_ref, term_ref, ok_ref) = refs
        tmargin_ref = done_ref = None
    t = pl.program_id(1)
    slab = pl.program_id(0)   # hoisted: program_id can't lower inside pl.when

    @pl.when(t == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)
        if early_exit:
            done_ref[0] = 0

    active = jnp.logical_not(done_ref[0]) if early_exit else True

    def _tree_and(g):
        # tree-AND over the chain axis (log2 ops — the chain is associative)
        while g.shape[1] > 1:
            half = g.shape[1] // 2
            lo = g[:, :half, :] & g[:, half:2 * half, :]
            g = (jnp.concatenate([lo, g[:, 2 * half:, :]], axis=1)
                 if g.shape[1] % 2 else lo)
        return g[:, 0, :]

    stage0 = tstage_ref[t] == 0
    if early_exit:   # a certified slab skips every remaining tile
        stage0 = jnp.logical_and(stage0, active)

    @pl.when(stage0)
    def _eval_terms():
        # stage 1: one gather + tree-AND evaluates block_t unique terms for
        # the whole sample slab; sentinel ids land on the all-ones row, so
        # padding terms come out constant 1 (the clause-chain AND identity)
        ids = tchain_ref[...].reshape(-1)
        g = jnp.take(litT_ref[...], ids, axis=0)
        g = g.reshape(block_t, term_w, block_s)
        term_ref[pl.ds(ttb_ref[t] * block_t, block_t), :] = _tree_and(g)

    stage1 = tstage_ref[t] == 1
    if early_exit:
        stage1 = jnp.logical_and(stage1, active)

    @pl.when(stage1)
    def _clause_tile():
        @pl.when(tfirst_ref[t] == 1)
        def _init_ok():   # chain start: every clause alive for every sample
            ok_ref[...] = jnp.full_like(ok_ref, 0xFFFFFFFF)

        ok0 = ok_ref[...]

        def chain(ok):
            # stage 2: one chain step per ACTIVE WORD — a single-row gather
            # of the term's precomputed bitvector instead of its bit chain
            ids = cchain_ref[...].reshape(-1)
            g = jnp.take(term_ref[...], ids, axis=0)
            return ok & _tree_and(g.reshape(block_c, block_j, block_s))

        # early exit: the whole slab of clauses is already dead
        ok = jax.lax.cond(jnp.any(ok0 != 0), chain, lambda o: o, ok0)

        @pl.when(tlast_ref[t] == 0)
        def _carry():   # Clause Out -> next chain tile's Clause In
            ok_ref[...] = ok

        @pl.when(tlast_ref[t] == 1)
        def _fold():    # adder bank: unpack sample bits, fold votes
            shifts = jnp.arange(32, dtype=jnp.uint32)
            fired = ((ok[:, :, None] >> shifts) & jnp.uint32(1)).astype(
                jnp.int32)
            fired = fired.reshape(block_c, block_s * 32)
            out_ref[...] += jax.lax.dot_general(
                fired.T, votes_ref[...], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            if early_exit:
                # certify: every real sample's lead STRICTLY beats the
                # residual swing (padding sample slots stay certified)
                lead = _slab_lead_margin(out_ref[...], n_classes)
                row = (slab * (block_s * 32)
                       + jax.lax.iota(jnp.int32, block_s * 32))
                lead = jnp.where(row < n_samples, lead, jnp.int32(-_NEG_SUM))
                certified = jnp.all(lead > tmargin_ref[t])
                done_ref[0] = jnp.where(certified, 1, done_ref[0])


@functools.partial(
    jax.jit,
    static_argnames=("schedule", "block_s", "interpret"),
)
def factorized_tm_forward(
    lit_words: jax.Array,       # (B, W) uint32 packed literals
    votes: jax.Array,           # (U, K) int32 — rows aligned with schedule
    schedule: FactorizedSchedule,
    *,
    block_s: int = DEFAULT_BLOCK_S,
    interpret: bool = False,
    tile_margin: jax.Array | None = None,   # (T,) residual swing after tile t
) -> jax.Array:
    """Packed literals -> (B, K) int32 class sums via the factorized
    schedule.  Bit-identical to the sparse chain kernel (and the dense
    oracle) for the include rows the schedule was built from.

    With ``tile_margin`` (see :mod:`repro.kernels.anytime`) the kernel
    runs in exact early-exit mode — argmax-identical to the full walk,
    sums possibly truncated once a slab certifies.
    """
    B, W = lit_words.shape
    U, K = votes.shape
    assert U <= schedule.clause_chain.shape[0], (U, schedule.clause_chain.shape)
    assert schedule.n_lit_bits == W * 32, (schedule.n_lit_bits, W)
    if schedule.n_tiles == 0:   # degenerate all-empty schedule: nothing votes
        return jnp.zeros((B, K), jnp.int32)

    Cp = schedule.clause_chain.shape[0]
    vts = jnp.pad(votes.astype(jnp.int32), ((0, Cp - U), (0, 0)))
    tiles = jnp.asarray(np.stack([
        schedule.tile_stage, schedule.tile_tb, schedule.tile_cb,
        schedule.tile_jb, schedule.tile_first, schedule.tile_last,
    ]))   # padded clauses fire vacuously but vote 0
    return factorized_tm_forward_tables(
        lit_words, jnp.asarray(schedule.term_chain),
        jnp.asarray(schedule.clause_chain), vts, tiles,
        block_t=schedule.block_t, block_c=schedule.block_c,
        block_j=schedule.block_j, block_s=block_s, interpret=interpret,
        tile_margin=tile_margin,
    )   # term_w rides on term_chain.shape[1]


def factorized_tm_forward_tables(
    lit_words: jax.Array,       # (B, W) uint32
    term_chain: jax.Array,      # (Tp, term_w) int32
    clause_chain: jax.Array,    # (Cp, Jp) int32
    votes: jax.Array,           # (Cp, K) int32 (already padded rows)
    tiles: jax.Array,           # (6, T) int32 — stage, tb, cb, jb, first, last
    *,
    block_t: int,
    block_c: int,
    block_j: int,
    block_s: int = DEFAULT_BLOCK_S,
    interpret: bool = False,
    tile_margin: jax.Array | None = None,
) -> jax.Array:
    """Traced-table twin of :func:`factorized_tm_forward` for ``shard_map``
    bodies: term/clause/tile tables arrive as (sharded) arrays instead of a
    static schedule, so one jit serves every shard."""
    B, W = lit_words.shape
    Tp, term_w = term_chain.shape
    Cp, Jp = clause_chain.shape
    K = votes.shape[1]
    T = tiles.shape[1]
    Kp = _rup(K, 128)
    Sw = packetizer.n_words(B)
    block_s = max(min(block_s, Sw), 1)
    Swp = _rup(Sw, block_s)

    litT = bit_transpose_literals(lit_words, W * 32)
    litT = jnp.pad(litT, ((0, 0), (0, Swp - litT.shape[1])))
    vts = jnp.pad(votes.astype(jnp.int32), ((0, 0), (0, Kp - K)))

    early_exit = tile_margin is not None
    scratch = [
        pltpu.VMEM((Tp, block_s), jnp.uint32),
        pltpu.VMEM((block_c, block_s), jnp.uint32),
    ]
    if early_exit:
        scratch.append(pltpu.SMEM((1,), jnp.int32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7 if early_exit else 6,
        grid=(Swp // block_s, T),
        in_specs=[
            pl.BlockSpec((W * 32 + 1, block_s), lambda s, t, *refs: (0, s)),
            pl.BlockSpec((block_t, term_w),
                         lambda s, t, stg, tb, cb, jb, *refs: (tb[t], 0)),
            pl.BlockSpec((block_c, block_j),
                         lambda s, t, stg, tb, cb, jb, *refs: (cb[t], jb[t])),
            pl.BlockSpec((block_c, Kp),
                         lambda s, t, stg, tb, cb, jb, *refs: (cb[t], 0)),
        ],
        out_specs=pl.BlockSpec((block_s * 32, Kp), lambda s, t, *refs: (s, 0)),
        scratch_shapes=scratch,
    )
    prefetch = [tiles[0], tiles[1], tiles[2], tiles[3], tiles[4], tiles[5]]
    if early_exit:
        prefetch.append(jnp.asarray(tile_margin, jnp.int32))
    out = pl.pallas_call(
        functools.partial(
            _term_infer_kernel,
            block_t=block_t, block_c=block_c, block_j=block_j,
            block_s=block_s, term_w=term_w,
            n_classes=K, n_samples=B, early_exit=early_exit,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Swp * 32, Kp), jnp.int32),
        interpret=interpret,
    )(*prefetch, litT, term_chain, clause_chain, vts)
    return out[:B, :K]


def factorized_class_sums_ref(
    lit_words: jax.Array,       # (B, W) uint32
    term_chain: jax.Array,      # (Tp, term_w) int32 (sentinel = W * 32)
    clause_chain: jax.Array,    # (Cp, Jp) int32 (sentinel = a padding term)
    votes: jax.Array,           # (Cp, K) int32
) -> jax.Array:
    """jnp oracle over the factorized tables (the non-kernel engine of the
    sharded factorized path): terms fire iff every chain literal is 1
    (sentinel ids read constant 1), clauses fire iff every chained term
    fires.  Bit-identical to the Pallas factorized kernel."""
    B, W = lit_words.shape
    bits = packetizer.unpack_bits(lit_words, W * 32)          # (B, L)
    padded = jnp.concatenate(
        [bits, jnp.ones((B, 1), bits.dtype)], axis=1)         # lit sentinel
    tg = jnp.take(padded, term_chain.reshape(-1), axis=1)
    term_bits = jnp.all(
        tg.reshape(B, *term_chain.shape) != 0, axis=2)        # (B, Tp)
    cg = jnp.take(term_bits, clause_chain.reshape(-1), axis=1)
    fired = jnp.all(cg.reshape(B, *clause_chain.shape), axis=2)
    return fired.astype(jnp.int32) @ votes.astype(jnp.int32)


def stack_shard_factorized(
    include_words: np.ndarray,      # (U, Wa) — compile_tm row order
    votes: np.ndarray,              # (U, K)
    n_shards: int,
    *,
    block_c: int = DEFAULT_BLOCK_C,
    block_j: int = DEFAULT_BLOCK_J,
    block_t: int = DEFAULT_BLOCK_T,
    term_w: int | None = None,
):
    """Clause-shard a factorized schedule: each shard carries its OWN term
    table (terms are extracted from the shard's local rows — cross-shard
    sharing would need a replicated global table, more wire than it saves)
    plus its own tile table, all padded to common shapes so the stacks
    shard over ``model``.  ``term_w`` defaults to the FULL artifact's
    :func:`pick_term_width`, so every shard's term rows share one width.

    Returns ``(schedules, term_stack, chain_stack, votes_stack, tile_stack,
    C_loc)``: per-shard :class:`FactorizedSchedule` objects, the
    ``(n_shards, Tp, term_w)`` term-chain stack, the ``(n_shards, C_loc_p,
    Jp)`` clause-chain stack, the matching vote stack, and the ``(n_shards,
    6, T)`` tile table.  Shards with fewer tiles ride on no-op padding tiles;
    partial class sums compose exactly through one int32 ``psum``.
    """
    iw = np.ascontiguousarray(np.asarray(include_words, dtype=np.uint32))
    U, Wa = iw.shape
    K = votes.shape[1]
    if term_w is None:
        term_w = pick_term_width(iw)
    C_loc = -(-max(U, 1) // n_shards)
    C_loc = _rup(C_loc, 8)
    Up = C_loc * n_shards
    iw = np.pad(iw, ((0, Up - U), (0, 0)))
    vt = np.pad(np.asarray(votes, np.int32), ((0, Up - U), (0, 0)))

    def build_all(bt, pad=None):
        return [
            build_factorized_schedule(iw[s * C_loc:(s + 1) * C_loc],
                                      block_c=block_c, block_j=block_j,
                                      block_t=bt, term_w=term_w,
                                      pad_tiles_to=pad)
            for s in range(n_shards)
        ]

    # one static block_t must serve every shard's term tiles: take the
    # smallest post-clip value (a shard with fewer terms clips harder),
    # then rebuild all shards at it so tile tables stay consistent
    block_t = min(s.block_t for s in build_all(block_t))
    schedules = build_all(block_t)
    T = max(max(s.n_tiles for s in schedules), 1)
    schedules = build_all(block_t, pad=T)
    Tp = max(s.term_chain.shape[0] for s in schedules)
    Jp = max(s.clause_chain.shape[1] for s in schedules)
    Cp = max(s.clause_chain.shape[0] for s in schedules)

    term_stack = np.full((n_shards, Tp, term_w), Wa * 32, np.int32)
    chain_stack = np.zeros((n_shards, Cp, Jp), np.int32)
    votes_stack = np.zeros((n_shards, Cp, K), np.int32)
    tile_stack = np.zeros((n_shards, 6, T), np.int32)
    for s, sched in enumerate(schedules):
        tp = sched.term_chain.shape[0]
        cp, jp = sched.clause_chain.shape
        term_stack[s, :tp] = sched.term_chain
        # padding term rows (>= tp) are all-sentinel: they evaluate to
        # constant 1, so a shorter shard's sentinel ids stay exact
        chain_stack[s] = sched.n_terms   # shard-local sentinel everywhere
        chain_stack[s, :cp, :jp] = sched.clause_chain
        votes_stack[s, :C_loc] = vt[s * C_loc:(s + 1) * C_loc]
        tile_stack[s, 0] = sched.tile_stage
        tile_stack[s, 1] = sched.tile_tb
        tile_stack[s, 2] = sched.tile_cb
        tile_stack[s, 3] = sched.tile_jb
        tile_stack[s, 4] = sched.tile_first
        tile_stack[s, 5] = sched.tile_last
    return schedules, term_stack, chain_stack, votes_stack, tile_stack, C_loc
