"""FINN-style binarized MLP baseline (paper Table I comparison).

Binary {-1,+1} weights/activations at inference via XNOR-popcount
(kernels/xnor_popcount.py); trained with straight-through estimators in
float, exactly the BNN recipe FINN compiles.  Topologies default to the
paper's Table II entries (e.g. MNIST 784-256-256-256-10).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core import packetizer
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class BNNConfig:
    layer_sizes: Tuple[int, ...] = (784, 256, 256, 256, 10)
    lr: float = 1e-3


def bnn_init(cfg: BNNConfig, rng) -> list:
    params = []
    for i, (d_in, d_out) in enumerate(zip(cfg.layer_sizes[:-1], cfg.layer_sizes[1:])):
        rng, r = jax.random.split(rng)
        params.append(jax.random.normal(r, (d_in, d_out)) * (d_in**-0.5))
    return params


def _sign(x):
    return jnp.sign(jnp.where(x == 0, 1.0, x))


def _binarize_ste(w):
    """Straight-through sign with the standard |w|<=1 gradient clip."""
    y = jnp.clip(w, -1.0, 1.0)
    return y + jax.lax.stop_gradient(_sign(w) - y)


def _forward_float(params, x):
    """Training forward: binarized weights/activations, hard-tanh STE
    (gradients flow only where the normalized pre-activation is in [-1, 1] —
    the standard BNN recipe)."""
    h = 2.0 * x.astype(jnp.float32) - 1.0          # {0,1} -> {-1,+1}
    for i, w in enumerate(params):
        wb = _binarize_ste(w)
        h = h @ wb
        if i < len(params) - 1:
            hn = h / float(w.shape[0]) ** 0.5      # normalized pre-activation
            y = jnp.clip(hn, -1.0, 1.0)
            h = y + jax.lax.stop_gradient(_sign(h) - y)
    return h


def bnn_train(cfg: BNNConfig, params, X, y, *, epochs: int, batch_size: int, rng):
    # logits scale: +-1 dot products reach +-d_in, saturating the softmax;
    # dividing by sqrt(d_in) restores gradient flow (argmax-invariant, so
    # the packed inference path is unaffected)
    scale = 1.0 / float(cfg.layer_sizes[-2]) ** 0.5

    @jax.jit
    def step(params, xb, yb):
        def loss_fn(p):
            logits = _forward_float(p, xb) * scale
            return jnp.mean(
                -jax.nn.log_softmax(logits)[jnp.arange(xb.shape[0]), yb]
            )

        loss, grads = jax.value_and_grad(loss_fn)(params)
        return [p - cfg.lr * g for p, g in zip(params, grads)], loss

    n = X.shape[0]
    import numpy as np

    nprng = np.random.default_rng(0)
    for _ in range(epochs):
        perm = nprng.permutation(n)
        for i in range(n // batch_size):
            idx = perm[i * batch_size : (i + 1) * batch_size]
            params, _ = step(params, jnp.asarray(X[idx]), jnp.asarray(y[idx]))
    return params


def bnn_pack(params) -> List[Tuple[jnp.ndarray, int]]:
    """Deployable artifact: per-layer packed sign-bit weight words."""
    packed = []
    for w in params:
        bits = (jnp.sign(w) > 0).astype(jnp.uint8).T        # (out, in) bit rows
        packed.append((packetizer.pack_bits(bits), w.shape[0]))
    return packed


def bnn_predict(packed, x, **kw) -> jnp.ndarray:
    """Bitpacked XNOR-popcount inference over the whole stack."""
    a = x.astype(jnp.uint8)                                  # {0,1} first layer
    for i, (w_words, n_bits) in enumerate(packed):
        a_words = packetizer.pack_bits(a)
        dots = ops.xnor_dot(a_words, w_words, n_bits, **kw)  # (B, out) int32
        if i < len(packed) - 1:
            a = (dots >= 0).astype(jnp.uint8)                # sign activation
    return jnp.argmax(dots, axis=-1)
