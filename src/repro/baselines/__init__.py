from repro.baselines.bnn import BNNConfig, bnn_init, bnn_predict, bnn_train  # noqa: F401
