"""Gradient compression: int8 quantized all-reduce with error feedback.

The distributed-optimization trick for the LM substrate (the TM trainer gets
this for free — its feedback deltas are already bounded small ints).  Used
under ``shard_map`` over the data axes: per-shard grads are quantized to
int8 against a psum'd f32 scale, summed in int32, dequantized, and the
quantization residual is carried to the next step (error feedback), which
keeps convergence unbiased in practice.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro import jax_compat


def quantize_psum(g: jax.Array, err: jax.Array, axes) -> Tuple[jax.Array, jax.Array]:
    """One tensor: returns (all-reduced mean grad, new error residual)."""
    g = g.astype(jnp.float32) + err
    amax = jnp.max(jnp.abs(g))
    amax = jax.lax.pmax(amax, axes)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127)
    new_err = g - q * scale                       # local residual, carried
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n = n * jax_compat.axis_size(a)
    summed = jax.lax.psum(q.astype(jnp.int32), axes)
    return (summed.astype(jnp.float32) * scale) / n, new_err


def compressed_allreduce(grads: Any, err: Any, axes) -> Tuple[Any, Any]:
    """Pytree version; call inside shard_map over the data axes."""
    out = jax.tree.map(lambda g, e: quantize_psum(g, e, axes), grads, err)
    g_new = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    e_new = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return g_new, e_new


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
