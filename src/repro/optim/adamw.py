"""AdamW with f32 moments over bf16 params, global-norm clipping, cosine LR.

Self-contained (no optax in the container).  Moment tensors inherit the
parameter PartitionSpecs, so optimizer state is fully sharded (ZeRO-style —
the FSDP axis shards params AND moments).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10000
    min_lr_ratio: float = 0.1


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    m: Any
    v: Any
    step: jax.Array


def adamw_init(params) -> OptState:
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        m=jax.tree.map(z, params),
        v=jax.tree.map(z, params),
        step=jnp.zeros((), jnp.int32),
    )


def _schedule(cfg: AdamWConfig, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.minimum(warm, cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(
    cfg: AdamWConfig, grads, params, state: OptState
) -> Tuple[Any, OptState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = _schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, p, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return p_new, m_new, v_new

    flat = jax.tree.map(upd, grads, params, state.m, state.v)
    p_new = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    m_new = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    v_new = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return p_new, OptState(m=m_new, v=v_new, step=step), {"grad_norm": gnorm, "lr": lr}
