"""DeepSeek-V2 236B [arXiv:2405.04434]: MLA (kv_lora=512) + MoE 160e top-6,
2 shared experts, first layer dense."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=12288,                       # dense layers (layer 0)
    vocab_size=102400,
    attn_kind="mla", q_lora=1536, kv_lora=512, rope_head_dim=64, v_head_dim=128,
    n_experts=160, top_k=6, n_shared_experts=2, d_ff_expert=1536,
    first_dense_layers=1, rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="deepseek-v2-smoke",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=256, vocab_size=512,
    attn_kind="mla", q_lora=32, kv_lora=24, rope_head_dim=8, v_head_dim=16,
    n_experts=8, top_k=2, n_shared_experts=1, d_ff_expert=48,
    first_dense_layers=1, dtype="float32",
)
