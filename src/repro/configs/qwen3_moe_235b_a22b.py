"""Qwen3-235B-A22B [hf:Qwen/Qwen3-*]: MoE 128 experts top-8, GQA kv=4, qk_norm."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151936, qk_norm=True, rope_theta=1_000_000.0,
    n_experts=128, top_k=8, d_ff_expert=1536,
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=48, vocab_size=512, qk_norm=True,
    n_experts=8, top_k=2, d_ff_expert=48, dtype="float32",
)
