"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409]: mistral-nemo backbone;
the Pixtral-ViT frontend is a stub (precomputed patch embeddings)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072, rope_theta=1_000_000.0,
    frontend="vision_stub",
)

SMOKE = ModelConfig(
    name="pixtral-smoke",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=192, vocab_size=512, frontend="vision_stub", dtype="float32",
)
