"""Config registry: one module per assigned architecture (+ the paper's TM).

``get_config(name)`` returns the full (dry-run) ModelConfig;
``get_smoke_config(name)`` the reduced same-family config used by the CPU
smoke tests (small layers/width/experts, tiny vocab).
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCH_IDS = (
    "tinyllama-1.1b",
    "qwen3-32b",
    "starcoder2-7b",
    "smollm-360m",
    "deepseek-v2-236b",
    "qwen3-moe-235b-a22b",
    "musicgen-large",
    "recurrentgemma-2b",
    "xlstm-1.3b",
    "pixtral-12b",
)

_MODULES = {name: "repro.configs." + name.replace("-", "_").replace(".", "_") for name in ARCH_IDS}


def _load(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name])


def get_config(name: str) -> ModelConfig:
    return _load(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _load(name).SMOKE
