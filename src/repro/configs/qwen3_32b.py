"""Qwen3-32B [hf:Qwen/Qwen3-*]: dense GQA kv=8, qk_norm, head_dim 128."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=25600, vocab_size=151936, qk_norm=True, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen3-smoke",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=192, vocab_size=512, qk_norm=True, rope_theta=1_000_000.0,
    dtype="float32",
)
