"""The paper's own models (Table II) + a pod-scale TM for the dry-run.

Feature counts follow the paper's datasets: MNIST/FMNIST/KMNIST 784-bit
binarized images, KWS6 377-bit MFCC booleans, CIFAR-2 1024-bit.
``clause_pad_multiple`` aligns the flattened clause axis to the model mesh
axis (padded clauses are permanently empty and vote 0 — DESIGN.md §4).
"""

from repro.core.tm import TMConfig

TM_MNIST = TMConfig(n_features=784, n_classes=10, clauses_per_class=200,
                    threshold=50, s=10.0, clause_pad_multiple=256)
TM_KMNIST = TMConfig(n_features=784, n_classes=10, clauses_per_class=500,
                     threshold=100, s=10.0, clause_pad_multiple=256)
TM_FMNIST = TMConfig(n_features=784, n_classes=10, clauses_per_class=500,
                     threshold=100, s=10.0, clause_pad_multiple=256)
TM_CIFAR2 = TMConfig(n_features=1024, n_classes=2, clauses_per_class=1000,
                     threshold=200, s=15.0, clause_pad_multiple=256)
TM_KWS6 = TMConfig(n_features=377, n_classes=6, clauses_per_class=300,
                   threshold=60, s=10.0, clause_pad_multiple=256)

# Pod-scale TM (the "larger edge application datasets" the paper's future
# work targets): 4096 boolean features, 32 classes, 2048 clauses/class.
TM_EDGE_XL = TMConfig(n_features=4096, n_classes=32, clauses_per_class=2048,
                      threshold=400, s=10.0, clause_pad_multiple=256)

# Drill-sized TM for fault-tolerance exercises (tests, CI): synthetic data
# (non-paper name), seconds to train, small enough that every engine on the
# serve ladder traces quickly.
TM_TINY = TMConfig(n_features=32, n_classes=3, clauses_per_class=8,
                   threshold=8, s=4.0)

TM_CONFIGS = {
    "tm-mnist": TM_MNIST, "tm-kmnist": TM_KMNIST, "tm-fmnist": TM_FMNIST,
    "tm-cifar2": TM_CIFAR2, "tm-kws6": TM_KWS6, "tm-edge-xl": TM_EDGE_XL,
    "tm-tiny": TM_TINY,
}
