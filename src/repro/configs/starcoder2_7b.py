"""StarCoder2-7B [arXiv:2402.19173]: dense GQA kv=4, RoPE."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18432, vocab_size=49152, rope_theta=100_000.0, gated_mlp=False,
)

SMOKE = ModelConfig(
    name="starcoder2-smoke",
    n_layers=3, d_model=72, n_heads=6, n_kv_heads=2,
    d_ff=288, vocab_size=512, rope_theta=100_000.0, gated_mlp=False, dtype="float32",
)
