"""TinyLlama-1.1B [arXiv:2401.02385]: llama2-arch small, GQA kv=4."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=5632, vocab_size=32000, rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="tinyllama-smoke",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=176, vocab_size=512, rope_theta=10000.0, dtype="float32",
)
