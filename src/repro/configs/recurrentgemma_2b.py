"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427]: RG-LRU + local attention,
pattern (rec, rec, local), window 2048 — sub-quadratic, runs long_500k."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000, pattern=("rec", "rec", "local"),
    window=2048, rnn_width=2560, subquadratic=True, rope_theta=10000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=192, vocab_size=512, pattern=("rec", "rec", "local"),
    window=16, rnn_width=64, subquadratic=True, tie_embeddings=True, dtype="float32",
)
