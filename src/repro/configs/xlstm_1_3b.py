"""xLSTM-1.3B [arXiv:2405.04517]: mLSTM + sLSTM blocks (7:1) —
sub-quadratic recurrent, runs long_500k."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    pattern=("mlstm",) * 7 + ("slstm",), subquadratic=True,
)

SMOKE = ModelConfig(
    name="xlstm-smoke",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=512, pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    subquadratic=True, dtype="float32",
)
