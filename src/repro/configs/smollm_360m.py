"""SmolLM-360M [hf:HuggingFaceTB/SmolLM]: llama-arch small, GQA kv=5, tied."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
    d_ff=2560, vocab_size=49152, rope_theta=10000.0, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="smollm-smoke",
    n_layers=3, d_model=60, n_heads=3, n_kv_heads=1,
    d_ff=160, vocab_size=512, rope_theta=10000.0, tie_embeddings=True,
    dtype="float32",
)
