"""MusicGen-large [arXiv:2306.05284]: decoder-only over EnCodec tokens,
4 codebook heads; the EnCodec frontend is a stub (precomputed frame
embeddings via input_specs)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048, rope_theta=10000.0,
    frontend="audio_stub", n_codebooks=4,
)

SMOKE = ModelConfig(
    name="musicgen-smoke",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=128, frontend="audio_stub", n_codebooks=4,
    dtype="float32",
)
