from repro.data.synthetic import (  # noqa: F401
    make_boolean_classification,
    make_noisy_xor,
    paper_dataset,
)
from repro.data.booleanize import thermometer_encode, quantile_binarize  # noqa: F401
from repro.data.loader import ShardedBatcher  # noqa: F401
