"""Sharded, prefetching batch loader (straggler-tolerant input pipeline).

Production posture: the loader owns a background prefetch thread (host-side
overlap with device steps), deterministic shuffling keyed by (seed, epoch),
per-host sharding by ``process_index`` for multi-host launches, and a
``state_dict`` so checkpoint/restore resumes mid-epoch without replaying.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


class ShardedBatcher:
    def __init__(
        self,
        arrays,                    # tuple of np arrays with equal leading dim
        batch_size: int,
        *,
        shuffle: bool = True,
        seed: int = 0,
        drop_remainder: bool = True,
        process_index: int = 0,
        process_count: int = 1,
        prefetch: int = 2,
    ):
        n = arrays[0].shape[0]
        assert all(a.shape[0] == n for a in arrays)
        self.arrays = arrays
        self.batch_size = batch_size
        self.shuffle = shuffle
        self.seed = seed
        self.drop_remainder = drop_remainder
        self.process_index = process_index
        self.process_count = process_count
        self.prefetch = prefetch
        self.epoch = 0
        self.step_in_epoch = 0
        self._consumed: Optional[dict] = None

    # -- checkpointable state -------------------------------------------------
    def state_dict(self) -> dict:
        # the prefetch worker advances (epoch, step_in_epoch) up to
        # ``prefetch`` batches AHEAD of the training loop — checkpointing
        # that cursor would skip batches on resume.  The iterator therefore
        # tags every batch with its post-consumption cursor and records it
        # when the batch is actually handed to the caller; state_dict
        # returns that CONSUMED position.
        if self._consumed is not None:
            return dict(self._consumed)
        return {"epoch": self.epoch, "step_in_epoch": self.step_in_epoch,
                "seed": self.seed}

    def load_state_dict(self, st: dict) -> None:
        self.epoch = st["epoch"]
        self.step_in_epoch = st["step_in_epoch"]
        self.seed = st["seed"]
        self._consumed = None

    # -- iteration -------------------------------------------------------------
    def _epoch_order(self, epoch: int) -> np.ndarray:
        n = self.arrays[0].shape[0]
        if not self.shuffle:
            order = np.arange(n)
        else:
            order = np.random.default_rng((self.seed, epoch)).permutation(n)
        return order[self.process_index :: self.process_count]

    def _batches(self) -> Iterator[tuple]:
        # yields (consumed_state, batch): the state a checkpoint must
        # record once this batch has been handed to the training loop
        while True:
            order = self._epoch_order(self.epoch)
            nb = len(order) // self.batch_size
            while self.step_in_epoch < nb:
                i = self.step_in_epoch
                idx = order[i * self.batch_size : (i + 1) * self.batch_size]
                self.step_in_epoch += 1
                state = {"epoch": self.epoch,
                         "step_in_epoch": self.step_in_epoch,
                         "seed": self.seed}
                yield state, tuple(a[idx] for a in self.arrays)
            self.epoch += 1
            self.step_in_epoch = 0

    def __iter__(self):
        if self.prefetch <= 0:
            for state, b in self._batches():
                self._consumed = state
                yield b
            return
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def worker():
            for item in self._batches():
                if stop.is_set():
                    return
                q.put(item)

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                state, b = q.get()
                self._consumed = state
                yield b
        finally:
            stop.set()
