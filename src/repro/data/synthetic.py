"""Synthetic boolean datasets matching the paper's benchmark dimensions.

The container has no MNIST/CIFAR/KWS files (repro band: simulated data
gate), so we generate class-structured Bernoulli data with the same feature
widths as the paper's Table II datasets: each class owns a sparse set of
"prototype" pixels that light with high probability, over a noisy background
— learnable by a TM through the same include/exclude mechanics as the real
images, and producing comparably sparse models.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

PAPER_DATASETS = {
    "mnist": dict(n_features=784, n_classes=10),
    "kmnist": dict(n_features=784, n_classes=10),
    "fmnist": dict(n_features=784, n_classes=10),
    "cifar2": dict(n_features=1024, n_classes=2),
    "kws6": dict(n_features=377, n_classes=6),
}


def make_boolean_classification(
    n_samples: int,
    n_features: int,
    n_classes: int,
    *,
    prototype_density: float = 0.15,
    on_prob: float = 0.9,
    background_prob: float = 0.08,
    label_noise: float = 0.0,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Class-prototype Bernoulli data: X (N, F) uint8, y (N,) int32."""
    rng = np.random.default_rng(seed)
    protos = rng.random((n_classes, n_features)) < prototype_density
    y = rng.integers(0, n_classes, n_samples).astype(np.int32)
    p = np.where(protos[y], on_prob, background_prob)
    X = (rng.random((n_samples, n_features)) < p).astype(np.uint8)
    if label_noise:
        flip = rng.random(n_samples) < label_noise
        y = np.where(flip, rng.integers(0, n_classes, n_samples), y).astype(np.int32)
    return X, y


def make_noisy_xor(
    n_samples: int, n_features: int = 12, noise: float = 0.1, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """The 2D Noisy XOR benchmark (paper refs [22][23])."""
    rng = np.random.default_rng(seed)
    X = rng.integers(0, 2, (n_samples, n_features)).astype(np.uint8)
    y = (X[:, 0] ^ X[:, 1]).astype(np.int32)
    flip = rng.random(n_samples) < noise
    return X, np.where(flip, 1 - y, y).astype(np.int32)


def paper_dataset(
    name: str, n_train: int = 4000, n_test: int = 1000, seed: int = 0
):
    """(X_train, y_train, X_test, y_test) with the paper dataset's dims."""
    spec = PAPER_DATASETS[name]
    X, y = make_boolean_classification(
        n_train + n_test, spec["n_features"], spec["n_classes"], seed=seed
    )
    return X[:n_train], y[:n_train], X[n_train:], y[n_train:]
