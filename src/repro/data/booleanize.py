"""Booleanization front-ends: real-valued features -> TM literals.

The MATADOR GUI booleanizes grayscale/MFCC inputs before training; these are
the two standard encoders from the TM literature (REDRESS, paper ref [5]).
"""

from __future__ import annotations

import numpy as np


def thermometer_encode(x: np.ndarray, n_bits: int = 8) -> np.ndarray:
    """Per-feature thermometer code over [min, max]: (N, F) -> (N, F*n_bits)."""
    lo = x.min(axis=0, keepdims=True)
    hi = x.max(axis=0, keepdims=True)
    span = np.maximum(hi - lo, 1e-9)
    levels = (x - lo) / span * n_bits                     # (N, F) in [0, n_bits]
    th = levels[..., None] > np.arange(n_bits)            # (N, F, n_bits)
    return th.reshape(x.shape[0], -1).astype(np.uint8)


def quantile_binarize(x: np.ndarray, n_bits: int = 4) -> np.ndarray:
    """Quantile-threshold code: bit b set iff x > quantile_(b+1)/(n+1)."""
    qs = np.quantile(x, np.linspace(0, 1, n_bits + 2)[1:-1], axis=0)  # (n, F)
    bits = x[None, ...] > qs[:, None, :]                   # (n, N, F)
    return bits.transpose(1, 2, 0).reshape(x.shape[0], -1).astype(np.uint8)
