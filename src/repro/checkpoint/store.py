"""Fault-tolerant checkpointing: atomic, async, elastic-reshardable.

Design (no orbax in the container, so this is self-contained):
  * flat ``{path: np.ndarray}`` layout in one compressed npz + a JSON
    manifest (step, pytree structure, loader state, mesh signature);
  * **atomic**: written to ``<dir>.tmp`` then os.rename'd — a preempted
    writer never corrupts the latest checkpoint;
  * **async**: ``CheckpointManager.save(..., blocking=False)`` hands the
    host copy to a writer thread so the device step loop continues;
  * **elastic**: restore takes the *current* shardings and uses
    ``jax.make_array_from_callback`` so a checkpoint written on one mesh
    restores onto any other (device-count changes re-shard transparently);
  * retention: keep the newest ``max_to_keep`` steps.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.runtime import faults


def _step_of(name: str) -> Optional[int]:
    """Parse a ``step_<n>`` directory name; None for tmp/malformed entries.

    A killed writer can leave ``step_*.tmp`` debris and a stray file can
    share the prefix — neither may crash ``latest_step``/``_gc`` with an
    ``int()`` ValueError.
    """
    if not name.startswith("step_") or name.endswith(".tmp"):
        return None
    try:
        return int(name.split("_", 1)[1])
    except ValueError:
        return None


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory: str, step: int, tree: Any, extra: Optional[dict] = None) -> str:
    """Atomic synchronous save; returns the final checkpoint path."""
    faults.raise_if("ckpt.write_fail")
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez_compressed(os.path.join(tmp, "arrays.npz"), **flat)
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_arrays": len(flat),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [s for s in map(_step_of, os.listdir(directory)) if s is not None]
    return max(steps) if steps else None


def load_checkpoint(
    directory: str,
    target: Any,
    *,
    step: Optional[int] = None,
    shardings: Any = None,
) -> tuple:
    """Restore into the structure of ``target``; reshard onto ``shardings``.

    ``shardings`` may be a pytree of NamedSharding matching ``target``; when
    given, arrays are placed shard-by-shard (elastic restore onto any mesh).
    Returns (tree, manifest_extra).
    """
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    z = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    flat_target = jax.tree_util.tree_flatten_with_path(target)
    keys = [
        "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path_)
        for path_, _ in flat_target[0]
    ]
    flat_shardings = (
        jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "device_set")
        )
        if shardings is not None
        else [None] * len(keys)
    )
    leaves = []
    for key, (_, ref), shd in zip(keys, flat_target[0], flat_shardings):
        host = z[key]
        if shd is not None:
            arr = jax.make_array_from_callback(
                host.shape, shd, lambda idx, h=host: h[idx]
            )
        else:
            arr = jax.numpy.asarray(host)
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(flat_target[1], leaves)
    return tree, manifest.get("extra", {})


class CheckpointManager:
    """Async writer + retention policy around save/load.

    A failed background write is never swallowed: the exception is captured
    in the writer thread and re-raised on the next ``wait()`` (which
    ``save()`` calls first) — a training loop cannot keep running for hours
    believing its checkpoints are landing when the disk is full.
    """

    def __init__(self, directory: str, max_to_keep: int = 3):
        self.directory = directory
        self.max_to_keep = max_to_keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)
        # a writer killed mid-save leaves a step_*.tmp dir; it is garbage
        # (the atomic rename never happened) and would otherwise accumulate
        for d in os.listdir(directory):
            if d.startswith("step_") and d.endswith(".tmp"):
                shutil.rmtree(os.path.join(directory, d), ignore_errors=True)

    def wait(self) -> None:
        """Join the async writer; re-raise its failure if it died."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             blocking: bool = True) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # device->host

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except BaseException as e:  # surfaced by the next wait()/save()
                self._error = e

        if blocking:
            work()
            self.wait()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def restore(self, target: Any, shardings: Any = None, step=None):
        return load_checkpoint(
            self.directory, target, step=step, shardings=shardings
        )

    def latest_step(self):
        return latest_step(self.directory)

    def _gc(self) -> None:
        steps = sorted(
            s for s in map(_step_of, os.listdir(self.directory))
            if s is not None
        )
        for s in steps[: -self.max_to_keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)
