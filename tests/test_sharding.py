"""Multi-device sharding tests (subprocess: forces 8 host devices so the
main pytest process keeps its single-device view)."""

import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.multidevice

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
           REPRO_DRYRUN_DEVICES="8", JAX_PLATFORMS="cpu")


def _run(code: str, timeout=600):
    return subprocess.run(
        [sys.executable, "-c", code], env=ENV, capture_output=True,
        text=True, timeout=timeout,
    )


def test_tm_sharded_matches_unsharded():
    r = _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import tm, packetizer, sharding
from repro.kernels import ops

cfg = tm.TMConfig(n_features=32, n_classes=4, clauses_per_class=16,
                  clause_pad_multiple=8)
state = tm.init(cfg, jax.random.PRNGKey(0))
mesh = jax.make_mesh((2, 4), ("data", "model"))
X = np.random.default_rng(0).integers(0, 2, (16, 32)).astype(np.uint8)

pred_ref = np.asarray(tm.predict(cfg, state, jnp.asarray(X)))
fn = sharding.sharded_predict_fn(cfg, mesh)
inc = packetizer.pack_include_masks(state.ta_state)
votes = tm.vote_matrix(cfg)
nonempty = jnp.any(state.ta_state >= 0, -1).astype(jnp.uint8)
lits = packetizer.pack_bits(tm.literals(jnp.asarray(X)))
pred_sh = np.asarray(fn(inc, votes, nonempty, lits))
np.testing.assert_array_equal(pred_ref, pred_sh)

# sharded train step == single-device kernel-path step (same hash RNG)
y = np.random.default_rng(1).integers(0, 4, 16).astype(np.int32)
ta_ref, _ = ops.tm_train_step_kernel(cfg, state.ta_state, jnp.asarray(X),
                                     jnp.asarray(y), jnp.uint32(5))
step = sharding.sharded_train_step_fn(cfg, mesh)
ta_sh = step(state.ta_state, jnp.asarray(X), jnp.asarray(y), jnp.uint32(5))
np.testing.assert_array_equal(np.asarray(ta_ref), np.asarray(ta_sh))
print("TM_SHARDED_OK")
""")
    assert "TM_SHARDED_OK" in r.stdout, r.stdout + r.stderr


def test_lm_sharded_loss_matches_unsharded():
    r = _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.models import transformer, sharding as shd
from repro.models.transformer import RunCtx

cfg = get_smoke_config("tinyllama-1.1b")
params = transformer.init_params(cfg, jax.random.PRNGKey(0))
B, S = 4, 32
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
loss_1dev = float(transformer.loss_fn(cfg, params, batch, remat=False))

mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = RunCtx(mesh=mesh)
p_specs = shd.param_specs(cfg, params, mesh, train=True)
p_sh = jax.device_put(params, shd.to_named(p_specs, mesh))
b_specs = shd.batch_specs(cfg, batch, mesh)
b_sh = jax.device_put(batch, shd.to_named(b_specs, mesh))
loss_sh = float(jax.jit(lambda p, b: transformer.loss_fn(cfg, p, b, ctx=ctx, remat=False))(p_sh, b_sh))
assert abs(loss_1dev - loss_sh) < 2e-2, (loss_1dev, loss_sh)
print("LM_SHARDED_OK", loss_1dev, loss_sh)
""")
    assert "LM_SHARDED_OK" in r.stdout, r.stdout + r.stderr


def test_moe_shard_map_matches_local():
    r = _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.models import moe

import dataclasses
cfg = get_smoke_config("qwen3-moe-235b-a22b")
# capacity high enough that no tokens drop -> paths must agree exactly
cfg = dataclasses.replace(cfg, capacity_factor=100.0)
params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 16, cfg.d_model)), jnp.float32)
out_local = moe.moe_ff(cfg, params, x, mesh=None)
mesh = jax.make_mesh((2, 4), ("data", "model"))
out_sh = jax.jit(lambda p, xx: moe.moe_ff(cfg, p, xx, mesh=mesh, dp_axes=("data",)))(params, x)
err = float(jnp.abs(out_local - out_sh).max())
scale = float(jnp.abs(out_local).max())
assert err < 1e-3 * scale + 1e-5, (err, scale)
print("MOE_OK", err, scale)
""")
    assert "MOE_OK" in r.stdout, r.stdout + r.stderr


def test_compressed_allreduce_multidevice():
    r = _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim import compress

mesh = jax.make_mesh((8,), ("data",))
g_all = jnp.asarray(np.random.default_rng(0).normal(size=(8, 64)), jnp.float32)

def f(g, e):
    out, ne = compress.quantize_psum(g[0], e[0], "data")
    return out[None], ne[None]

from repro import jax_compat
out, err = jax.jit(jax_compat.shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                   out_specs=(P("data"), P("data")), check_vma=False))(
    g_all, jnp.zeros_like(g_all))
exact = np.asarray(g_all).mean(0)
got = np.asarray(out)[0]
scale = np.abs(np.asarray(g_all)).max() / 127.0
assert np.abs(got - exact).max() < scale + 1e-5, np.abs(got - exact).max()
print("COMPRESS_OK")
""")
    assert "COMPRESS_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_dryrun_smoke_cells():
    """The dry-run machinery end-to-end on a small mesh with smoke configs."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--smoke",
         "--arch", "recurrentgemma-2b", "--shape", "train_4k", "--mesh", "2x4"],
        env=ENV, capture_output=True, text=True, timeout=600,
    )
    assert '"status": "ok"' in r.stdout, r.stdout + r.stderr
