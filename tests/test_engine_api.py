"""The unified engine/tuning API: EngineSpec dispatch on run_compiled,
deprecation shims for the old boolean kwargs, the `autotune.tune` facade's
three policies, and the zero-timing-run plan_engine cold-start path."""

from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import compiler, packetizer, tm
from repro.kernels import autotune, ops


def _random_tm(n_features, n_classes, cpc, include_density, seed):
    rng = np.random.default_rng(seed)
    C = n_classes * cpc
    ta = np.where(
        rng.random((C, 2 * n_features)) < include_density,
        rng.integers(0, 127, (C, 2 * n_features)),
        rng.integers(-128, 0, (C, 2 * n_features)),
    ).astype(np.int8)
    cfg = tm.TMConfig(n_features=n_features, n_classes=n_classes,
                      clauses_per_class=cpc)
    return cfg, ta


@pytest.fixture(scope="module")
def artifact():
    cfg, ta = _random_tm(48, 3, 8, 0.10, 7)
    comp = compiler.compile_tm(cfg, ta)
    x = jnp.asarray(np.random.default_rng(1).integers(
        0, 2, (11, 48), dtype=np.uint8))
    return comp, packetizer.pack_literals(x)


# ---------------------------------------------------------------------------
# EngineSpec
# ---------------------------------------------------------------------------

def test_engine_spec_validation():
    with pytest.raises(ValueError, match="unknown engine"):
        ops.EngineSpec(name="bogus")
    with pytest.raises(ValueError, match="oracle"):
        ops.EngineSpec(name="oracle", use_kernel=True)
    with pytest.raises(ValueError, match="use_kernel=False"):
        ops.EngineSpec(name="sparse", use_kernel=False)
    with pytest.raises(ValueError, match="unfused"):
        ops.EngineSpec(name="factorized", fuse=False)
    # dense DOES have an unfused (two-kernel pipeline) form
    ops.EngineSpec(name="dense", fuse=False)


def test_engine_spec_coerce():
    assert ops.EngineSpec.coerce(None) == ops.EngineSpec()
    assert ops.EngineSpec.coerce("sparse").name == "sparse"
    spec = ops.EngineSpec(name="dense", interpret=True)
    assert ops.EngineSpec.coerce(spec) is spec
    with pytest.raises(TypeError, match="EngineSpec"):
        ops.EngineSpec.coerce(42)
    with pytest.raises(ValueError, match="unknown engine"):
        ops.EngineSpec.coerce("fastest")


def test_engine_spec_resolve_interpret_precedence():
    spec = ops.EngineSpec(name="sparse", interpret=False)
    # call-site interpret wins over the spec's
    assert spec.resolve(True)[1] is True
    assert spec.resolve(None)[1] is False


# ---------------------------------------------------------------------------
# run_compiled engine dispatch
# ---------------------------------------------------------------------------

def test_all_named_engines_bit_identical(artifact):
    comp, xp = artifact
    oracle = np.asarray(compiler.run_compiled(comp, xp, engine="oracle"))
    for name in ("factorized", "sparse", "dense", "auto"):
        got = compiler.run_compiled(comp, xp, engine=name, interpret=True)
        np.testing.assert_array_equal(oracle, np.asarray(got), err_msg=name)
    spec = compiler.EngineSpec(name="dense", fuse=False, interpret=True)
    np.testing.assert_array_equal(
        oracle, np.asarray(compiler.run_compiled(comp, xp, engine=spec)))


def test_deprecated_kwargs_warn_and_match(artifact):
    """The legacy boolean kwargs still work — behind a DeprecationWarning —
    and agree bit-for-bit with their EngineSpec replacements.  CI reruns
    this test with ``-W error::DeprecationWarning`` to prove the warning
    actually fires."""
    comp, xp = artifact
    with pytest.warns(DeprecationWarning, match="engine="):
        legacy = compiler.run_compiled(
            comp, xp, use_kernel=True, interpret=True,
            sparse=True, factorize=False)
    new = compiler.run_compiled(comp, xp, engine="sparse", interpret=True)
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(new))

    with pytest.warns(DeprecationWarning):
        legacy = compiler.predict_compiled(comp, jnp.asarray(
            np.random.default_rng(0).integers(0, 2, (5, 48), np.uint8)),
            use_kernel=False)
    assert legacy.shape == (5,)


def test_engine_and_legacy_kwargs_conflict(artifact):
    comp, xp = artifact
    with pytest.raises(TypeError, match="deprecated"):
        compiler.run_compiled(comp, xp, engine="sparse", use_kernel=True)


# ---------------------------------------------------------------------------
# sharding builders
# ---------------------------------------------------------------------------

def test_sharding_engine_dispatch_rules():
    from repro.core import sharding

    uk, it, fuse = sharding._engine_dispatch(
        "dense", None, True, allowed=("auto", "dense", "oracle"))
    assert (uk, it, fuse) == (True, True, True)
    uk, it, fuse = sharding._engine_dispatch(
        "oracle", None, None, allowed=("auto", "dense", "oracle"))
    assert uk is False
    with pytest.raises(ValueError, match="sparse"):
        sharding._engine_dispatch(
            "sparse", None, True, allowed=("auto", "dense", "oracle"))
    with pytest.raises(TypeError, match="not both"):
        sharding._engine_dispatch(
            "dense", True, True, allowed=("auto", "dense", "oracle"))
    # engine=None: plain passthrough to ambient kernel dispatch
    uk, it, fuse = sharding._engine_dispatch(
        None, True, True, allowed=("auto", "dense", "oracle"), fuse=False)
    assert (uk, it, fuse) == (True, True, False)


# ---------------------------------------------------------------------------
# autotune.tune facade
# ---------------------------------------------------------------------------

@pytest.fixture()
def tune_env(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    monkeypatch.setenv("REPRO_TUNE_DATA", str(tmp_path / "data.json"))
    from repro.kernels import cost_model
    cost_model._invalidate_model_cache()
    yield tmp_path
    cost_model._invalidate_model_cache()


_CANDS = ((8, 128, 1), (4, 64, 1), (2, 32, 1), (8, 64, 1), (4, 128, 1))


def test_tune_rejects_unknown(tune_env):
    with pytest.raises(ValueError, match="unknown kernel"):
        autotune.tune("warp_drive", B=1, C=1, W=1, K=1, interpret=True)
    with pytest.raises(ValueError, match="unknown policy"):
        autotune.tune("fused_infer", B=1, C=1, W=1, K=1, interpret=True,
                      policy="guess")


def test_predict_policy_zero_timing_runs(tune_env):
    before = autotune.TIMING_RUNS
    blocks = autotune.tune(
        "fused_infer", B=9, C=17, W=1, K=2, interpret=True,
        policy="predict", candidates=_CANDS)
    assert autotune.TIMING_RUNS == before, "predict policy must not time"
    assert set(blocks) == {"block_b", "block_c", "block_w"}
    # memoized: second call (and a fresh process-cache miss) stays free
    again = autotune.tune(
        "fused_infer", B=9, C=17, W=1, K=2, interpret=True,
        policy="predict", candidates=_CANDS)
    assert again == blocks
    assert autotune.TIMING_RUNS == before


def test_verify_policy_times_only_topk(tune_env):
    reps = 1
    before = autotune.TIMING_RUNS
    blocks = autotune.tune(
        "fused_infer", B=9, C=17, W=1, K=2, interpret=True,
        policy="verify", top_k=3, candidates=_CANDS, reps=reps)
    spent = autotune.TIMING_RUNS - before
    # <= top_k shortlisted candidates x (1 warmup + reps) each; the full
    # 5-candidate sweep would have cost 5 x (1 + reps)
    assert 0 < spent <= 3 * (1 + reps)
    assert set(blocks) == {"block_b", "block_c", "block_w"}


def test_sweep_policy_feeds_sidecar_and_shares_legacy_key(tune_env):
    from repro.kernels import cost_model

    cands = ((8, 128, 1), (4, 64, 1))
    blocks = autotune.tune(
        "fused_infer", B=9, C=17, W=1, K=2, interpret=True,
        policy="sweep", candidates=cands, reps=1)
    rows = cost_model.load_observations()
    assert len(rows) == len(cands)
    for row in rows:
        assert row["kernel"] == "fused_infer"
        assert row["measured_us"] > 0
        assert row["basis"]["steps"] > 0
    # the legacy wrapper answers from the SAME cache entry (no re-sweep)
    before = autotune.TIMING_RUNS
    legacy = autotune.autotune_fused_blocks(
        9, 17, 1, 2, interpret=True, candidates=cands, reps=1)
    assert legacy == blocks
    assert autotune.TIMING_RUNS == before


def test_plan_engine_cold_start(tune_env):
    """plan_engine on a freshly loaded artifact: engine by the sharing
    heuristic, tiling by the cost model, ZERO timing runs."""
    cfg, ta = _random_tm(24, 2, 4, 0.08, 0)
    comp = compiler.compile_tm(cfg, ta)
    assert comp.stats.partial_term_sharing \
        < compiler.FACTORIZE_SHARING_THRESHOLD
    before = autotune.TIMING_RUNS
    engine, blocks = autotune.plan_engine(comp, 32, interpret=True)
    assert engine == "sparse"
    assert set(blocks) == {"block_c", "block_j", "block_s"}
    assert autotune.TIMING_RUNS == before

    # high-sharing artifact routes factorized (same bank construction as
    # the run_compiled heuristic test)
    cfg2 = tm.TMConfig(n_features=64, n_classes=2, clauses_per_class=8)
    C, L = 16, 128
    ta2 = np.full((C, L), -5, np.int8)
    ta2[:, 3] = 3
    ta2[:, 40] = 3
    for c in range(C):
        ta2[c, 64 + ((c * 4) % 64)] = 3
    comp2 = compiler.compile_tm(cfg2, ta2)
    engine2, blocks2 = autotune.plan_engine(comp2, 32, interpret=True)
    assert engine2 == "factorized"
    assert "block_t" in blocks2
    assert autotune.TIMING_RUNS == before
