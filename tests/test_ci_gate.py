"""CI machinery: the bench regression gate and the autotune cache under
concurrent writers."""

import importlib.util
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_check_bench():
    spec = importlib.util.spec_from_file_location(
        "check_bench", os.path.join(REPO, "scripts", "check_bench.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _report(us, *, backend="cpu", interpret=True, name="fusedinfer_fused_b1"):
    return dict(
        benchmark="fused_infer", backend=backend, interpret_mode=interpret,
        rows=[
            dict(name=name, us_per_call=us, derived=""),
            dict(name="fusedinfer_unfused_b1", us_per_call=us * 2, derived=""),
        ],
    )


@pytest.fixture
def cb(tmp_path):
    mod = _load_check_bench()

    def write(fname, report):
        p = tmp_path / fname
        p.write_text(json.dumps(report))
        return str(p)

    return mod, write


def test_check_bench_passes_within_factor(cb):
    mod, write = cb
    base = write("base.json", _report(1000.0))
    fresh = write("fresh.json", _report(1800.0))    # 1.8x < 2x: fine
    assert mod.main(["--pair", f"{base}:{fresh}"]) == 0


def test_check_bench_fails_on_injected_regression(cb):
    """The acceptance-criteria case: a synthetic >2x regression of the lead
    fused shape exits non-zero."""
    mod, write = cb
    base = write("base.json", _report(1000.0))
    fresh = write("fresh.json", _report(2500.0))    # 2.5x > 2x: gate trips
    assert mod.main(["--pair", f"{base}:{fresh}"]) == 1
    # tighter factor trips earlier
    fresh_ok = write("fresh2.json", _report(1500.0))
    assert mod.main(["--pair", f"{base}:{fresh_ok}", "--factor", "1.2"]) == 1


def test_check_bench_missing_or_benchless_fresh_fails(cb):
    mod, write = cb
    base = write("base.json", _report(1000.0))
    assert mod.main(["--pair", f"{base}:/nonexistent.json"]) == 1
    # a fresh report with no fused row means the fused bench never ran
    empty = write("empty.json", dict(backend="cpu", interpret_mode=True,
                                     rows=[]))
    assert mod.main(["--pair", f"{base}:{empty}"]) == 1


def test_check_bench_baseline_without_lead_row_fails(cb, tmp_path):
    """A committed BENCH file that parses but lost its lead row must FAIL
    the gate (previously it skipped silently forever); a missing baseline
    FILE still skips (a new benchmark's first PR has no baseline)."""
    mod, write = cb
    fresh = write("fresh.json", _report(1000.0))
    benchless = write("benchless.json",
                      dict(backend="cpu", interpret_mode=True,
                           rows=[dict(name="misc_row", us_per_call=1.0,
                                      derived="")]))
    assert mod.main(["--pair", f"{benchless}:{fresh}"]) == 1
    assert mod.main(["--pair", f"/nonexistent_base.json:{fresh}"]) == 0
    # an EXISTING but unparseable baseline (truncation, conflict markers)
    # also fails — only a missing file is the legitimate first-PR state
    torn = tmp_path / "torn.json"
    torn.write_text('{"rows": [<<<<<<< HEAD')
    assert mod.main(["--pair", f"{torn}:{fresh}"]) == 1


def test_check_bench_gates_sparse_lead_rows(cb):
    """BENCH_sparse_infer.json lead rows (sparseinfer_sparse_*) ride the
    same regression rule; the dense/uncompiled companion rows are not the
    lead."""
    mod, write = cb
    base = write("b.json", _report(1000.0, name="sparseinfer_sparse_b512"))
    ok = write("f_ok.json", _report(1500.0, name="sparseinfer_sparse_b512"))
    bad = write("f_bad.json", _report(2500.0, name="sparseinfer_sparse_b512"))
    assert mod.main(["--pair", f"{base}:{ok}"]) == 0
    assert mod.main(["--pair", f"{base}:{bad}"]) == 1
    # lead-row selection ignores non-lead rows ahead of the sparse row
    report = dict(backend="cpu", interpret_mode=True, rows=[
        dict(name="sparseinfer_oracle_b512", us_per_call=1.0, derived=""),
        dict(name="sparseinfer_sparse_b512", us_per_call=900.0, derived=""),
    ])
    fresh2 = write("f2.json", report)
    assert mod.main(["--pair", f"{base}:{fresh2}"]) == 0


def _serve_report(p99_ms, req_per_s, *, backend="cpu", interpret=True):
    return dict(
        benchmark="serve_gateway", backend=backend,
        interpret_mode=interpret,
        rows=[
            dict(name="serve_openloop_poisson_r1500_t3_b64",
                 us_per_call=p99_ms * 1e3, p99_ms=p99_ms,
                 req_per_s=req_per_s, derived=""),
            dict(name="serve_closedloop_c32_t3_b64",
                 us_per_call=p99_ms * 2e3, p99_ms=p99_ms * 2,
                 req_per_s=req_per_s * 3, derived=""),
        ],
    )


def test_check_bench_gates_serve_lead_row_both_axes(cb):
    """BENCH_serve.json gates on BOTH p99 latency and achieved req/s:
    either axis regressing past the factor fails."""
    mod, write = cb
    base = write("b.json", _serve_report(8.0, 1400.0))
    ok = write("f_ok.json", _serve_report(12.0, 1100.0))     # both < 2x
    slow = write("f_slow.json", _serve_report(20.0, 1400.0))  # p99 2.5x
    starved = write("f_starved.json", _serve_report(8.0, 500.0))  # rps /2.8
    assert mod.main(["--pair", f"{base}:{ok}"]) == 0
    assert mod.main(["--pair", f"{base}:{slow}"]) == 1
    assert mod.main(["--pair", f"{base}:{starved}"]) == 1


def test_check_bench_serve_missing_rows_and_backend_skip(cb):
    """Serve pairs keep the fused-gate file semantics: a leadless fresh
    or baseline fails, a cross-backend comparison skips."""
    mod, write = cb
    base = write("b.json", _serve_report(8.0, 1400.0))
    leadless = write("leadless.json", dict(
        benchmark="serve_gateway", backend="cpu", interpret_mode=True,
        rows=[dict(name="serve_openloop", us_per_call=1.0, derived="")]))
    assert mod.main(["--pair", f"{base}:{leadless}"]) == 1
    assert mod.main(["--pair", f"{leadless}:{base}"]) == 1
    tpu = write("tpu.json", _serve_report(99.0, 10.0, backend="tpu",
                                          interpret=False))
    assert mod.main(["--pair", f"{base}:{tpu}"]) == 0


def _online_report(pause_ms, req_per_s, *, backend="cpu", interpret=True):
    return dict(
        benchmark="online_update", backend=backend,
        interpret_mode=interpret,
        rows=[
            dict(name="online_steady_immediate_r1200_b64",
                 us_per_call=pause_ms * 1e3, swap_pause_p99_ms=pause_ms,
                 p99_ms=pause_ms * 8, req_per_s=req_per_s, derived=""),
            dict(name="online_steady_canary_r1200_b64",
                 us_per_call=pause_ms * 5e2, swap_pause_p99_ms=pause_ms / 2,
                 p99_ms=pause_ms * 4, req_per_s=req_per_s * 2, derived=""),
        ],
    )


def test_check_bench_gates_online_lead_row_both_axes(cb):
    """BENCH_online.json gates on BOTH the hot-swap pause p99 and the
    steady-state req/s under online updating: either axis regressing past
    the factor fails (the injected-regression acceptance case)."""
    mod, write = cb
    base = write("b.json", _online_report(300.0, 400.0))
    ok = write("f_ok.json", _online_report(450.0, 250.0))     # both < 2x
    paused = write("f_paused.json", _online_report(750.0, 400.0))  # 2.5x
    starved = write("f_starved.json", _online_report(300.0, 140.0))  # /2.8
    assert mod.main(["--pair", f"{base}:{ok}"]) == 0
    assert mod.main(["--pair", f"{base}:{paused}"]) == 1
    assert mod.main(["--pair", f"{base}:{starved}"]) == 1


def test_check_bench_online_missing_rows_and_backend_skip(cb):
    """Online pairs keep the file semantics of the other gates: a leadless
    fresh or baseline fails, a cross-backend comparison skips."""
    mod, write = cb
    base = write("b.json", _online_report(300.0, 400.0))
    leadless = write("leadless.json", dict(
        benchmark="online_update", backend="cpu", interpret_mode=True,
        rows=[dict(name="online_steady", us_per_call=1.0, derived="")]))
    assert mod.main(["--pair", f"{base}:{leadless}"]) == 1
    assert mod.main(["--pair", f"{leadless}:{base}"]) == 1
    tpu = write("tpu.json", _online_report(9000.0, 1.0, backend="tpu",
                                           interpret=False))
    assert mod.main(["--pair", f"{base}:{tpu}"]) == 0


def test_check_bench_skips_cross_backend_comparison(cb):
    """TPU fresh numbers never gate against a CPU-interpret baseline."""
    mod, write = cb
    base = write("base.json", _report(1000.0))
    fresh = write("fresh.json", _report(9000.0, backend="tpu",
                                        interpret=False))
    assert mod.main(["--pair", f"{base}:{fresh}"]) == 0


def test_check_bench_gates_sharded_mesh_rows(cb):
    mod, write = cb
    base = write("b.json", _report(1000.0, name="shardedtrain_mesh_b1"))
    fresh = write("f.json", _report(5000.0, name="shardedtrain_mesh_b1"))
    assert mod.main(["--pair", f"{base}:{fresh}"]) == 1


# ---------------------------------------------------------------------------
# Autotune cache: concurrent writers must never corrupt the file
# ---------------------------------------------------------------------------

_TUNE_PROC = """
import os, sys
os.environ["JAX_PLATFORMS"] = "cpu"
from repro.kernels import autotune
i = int(sys.argv[1])
# every process sweeps the SAME shape (the contended entry) plus one
# process-distinct shape (so merges happen against a moving file)
cands = ((8, 128, 1),)
autotune.autotune_fused_blocks(9, 17, 1, 2, interpret=True,
                               candidates=cands, reps=1, refresh=True)
autotune.autotune_fused_blocks(9 + i, 17, 1, 2, interpret=True,
                               candidates=cands, reps=1, refresh=True)
print("TUNED", i)
"""


def test_autotune_cache_concurrent_writers(tmp_path):
    """N processes autotuning into the same $REPRO_AUTOTUNE_CACHE: the file
    must stay whole (valid JSON, current schema) — the atomic os.replace
    save means last-writer-wins per entry, never a torn file."""
    cache = tmp_path / "tune.json"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               REPRO_AUTOTUNE_CACHE=str(cache), JAX_PLATFORMS="cpu")
    procs = [
        subprocess.Popen([sys.executable, "-c", _TUNE_PROC, str(i)],
                         env=env, stdout=subprocess.PIPE,
                         stderr=subprocess.PIPE, text=True)
        for i in range(4)
    ]
    for p in procs:
        out, err = p.communicate(timeout=600)
        assert p.returncode == 0, out + err
        assert "TUNED" in out

    from repro.kernels import autotune

    raw = json.loads(cache.read_text())      # parses: never torn
    assert raw["schema"] == autotune._SCHEMA_VERSION
    entries = raw["entries"]
    assert any("B9:" in k for k in entries)  # the contended entry survived
    for v in entries.values():               # every entry is structurally whole
        assert set(v["blocks"]) == {"block_b", "block_c", "block_w"}
    # no stray temp files left behind
    assert [f.name for f in tmp_path.iterdir()] == ["tune.json"]
