"""Shared-term factorized compiled inference: exactness + schedule shape.

The central property: for ANY automata state, inference through the
two-level factorized schedule (``kernels/term_infer.py`` — unique
(word, include-pattern) AND terms evaluated once per sample slab, clauses
rewritten as term-id chains) produces BIT-identical class sums to dense
``ref``-semantics inference AND to the flat block-sparse chain schedule —
across dedup on/off, zero-sharing artifacts (every term unique),
fully-shared artifacts (one term everywhere), fat-term splitting, ragged
batch tails, save/load round-trips, and a clause-sharded emulated
4-device mesh.

``hypothesis`` is optional (fixed-seed fallbacks keep the checks in
tier-1), matching the repo-wide ``hypothesis_optional`` pattern.
"""

import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import compiler, packetizer, tm
from repro.kernels import ops, term_infer

pytestmark = pytest.mark.schedule

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _random_tm(n_features, n_classes, cpc, include_density, seed):
    rng = np.random.default_rng(seed)
    C = n_classes * cpc
    ta = np.where(
        rng.random((C, 2 * n_features)) < include_density,
        rng.integers(0, 127, (C, 2 * n_features)),
        rng.integers(-128, 0, (C, 2 * n_features)),
    ).astype(np.int8)
    cfg = tm.TMConfig(n_features=n_features, n_classes=n_classes,
                      clauses_per_class=cpc)
    return cfg, ta


def _check_factorized_equals_dense(n_features, n_classes, cpc, density,
                                   seed, batch=16, dedup=True, term_w=None):
    """Factorized-kernel class sums == dense inference == the flat sparse
    schedule, bit for bit."""
    cfg, ta = _random_tm(n_features, n_classes, cpc, density, seed)
    comp = compiler.compile_tm(cfg, ta, dedup=dedup)
    x = jnp.asarray(np.random.default_rng(seed + 1).integers(
        0, 2, (batch, n_features), dtype=np.uint8))
    dense = tm.class_sums(cfg, jnp.asarray(ta), tm.literals(x),
                          training=False)
    xp = packetizer.pack_literals(x)
    fact = compiler.run_compiled(comp, xp, engine="factorized",
                                 interpret=True, term_w=term_w)
    flat = compiler.run_compiled(comp, xp, engine="sparse", interpret=True)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(fact))
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(fact))


if HAVE_HYPOTHESIS:
    @pytest.mark.hypothesis_optional
    @settings(max_examples=20, deadline=None)
    @given(
        n_features=st.integers(3, 80),
        n_classes=st.integers(2, 5),
        cpc=st.integers(2, 12),
        density=st.floats(0.0, 0.3),
        seed=st.integers(0, 10_000),
        batch=st.integers(1, 70),
        dedup=st.booleans(),
    )
    def test_factorized_equals_dense(n_features, n_classes, cpc, density,
                                     seed, batch, dedup):
        _check_factorized_equals_dense(n_features, n_classes, cpc, density,
                                       seed, batch=batch, dedup=dedup)


@pytest.mark.parametrize(
    "n_features,n_classes,cpc,density,seed,batch,dedup,term_w",
    [
        (3, 2, 2, 0.0, 0, 5, True, None),     # empty-clause-only model
        (3, 2, 2, 0.0, 0, 5, False, None),    # ... with dedup off
        (17, 3, 5, 0.05, 11, 7, True, None),  # sparse ragged batch tail
        (80, 5, 12, 0.3, 4242, 33, True, 2),  # dense + forced fat-term split
        (33, 2, 7, 0.15, 977, 64, False, 4),  # no dedup: duplicate rows kept
        (64, 4, 10, 0.02, 5, 40, True, None),  # wide + very sparse chains
    ],
)
def test_factorized_equals_dense_fixed(n_features, n_classes, cpc, density,
                                       seed, batch, dedup, term_w):
    """Fixed-seed fallback for the central property (always runs)."""
    _check_factorized_equals_dense(n_features, n_classes, cpc, density, seed,
                                   batch=batch, dedup=dedup, term_w=term_w)


def test_zero_sharing_artifact():
    """Every clause includes a distinct single word pattern: every term is
    unique (realized sharing 0), the term table is as large as the chain
    reference count, and execution is still exact."""
    cfg = tm.TMConfig(n_features=64, n_classes=2, clauses_per_class=8)
    C, L = 16, 128
    ta = np.full((C, L), -5, np.int8)
    for c in range(C):
        ta[c, (c * 8) % L] = 3              # distinct single-bit words
        ta[c, (c * 8 + 1) % L] = 3
    comp = compiler.compile_tm(cfg, ta)
    sched = comp.default_factorized_schedule
    assert sched.realized_term_sharing == 0.0
    assert sched.n_terms == sched.n_term_refs
    _check_state(cfg, ta, batch=9, seed=0)


def test_fully_shared_artifact():
    """One term everywhere: every clause includes the SAME word pattern
    (plus a per-clause discriminator so dedup keeps them apart) — the
    shared term collapses to one table row referenced by all clauses."""
    cfg = tm.TMConfig(n_features=64, n_classes=2, clauses_per_class=8)
    C, L = 16, 128
    ta = np.full((C, L), -5, np.int8)
    ta[:, 3] = 3                            # the shared term (word 0, bit 3)
    ta[:, 5] = 3                            # ... two bits wide
    comp = compiler.compile_tm(cfg, ta, dedup=False)
    sched = comp.default_factorized_schedule
    assert sched.n_terms == 1
    assert sched.n_term_refs == comp.n_unique
    assert sched.realized_term_sharing == pytest.approx(
        1.0 - 1.0 / comp.n_unique)
    _check_state(cfg, ta, batch=11, seed=1, dedup=False)
    # with a distinct second word per clause the shared term still
    # amortizes: n_terms = 1 shared + C distinct
    ta2 = ta.copy()
    for c in range(C):
        ta2[c, 64 + ((c * 4) % 64)] = 3
    comp2 = compiler.compile_tm(cfg, ta2)
    sched2 = comp2.default_factorized_schedule
    assert sched2.n_terms == 1 + comp2.n_unique
    _check_state(cfg, ta2, batch=11, seed=2)


def _check_state(cfg, ta, batch, seed, dedup=True):
    comp = compiler.compile_tm(cfg, ta, dedup=dedup)
    x = jnp.asarray(np.random.default_rng(seed).integers(
        0, 2, (batch, cfg.n_features), dtype=np.uint8))
    dense = tm.class_sums(cfg, jnp.asarray(ta), tm.literals(x),
                          training=False)
    sp = compiler.run_compiled(comp, packetizer.pack_literals(x),
                               engine="factorized", interpret=True)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(sp))


@pytest.mark.parametrize("batch", [1, 31, 32, 33, 64, 97])
def test_ragged_batch_tails(batch):
    """Sample-word packing (32 samples/word) handles every tail exactly
    through the two-stage kernel."""
    cfg, ta = _random_tm(24, 3, 6, 0.12, 9)
    _check_state(cfg, ta, batch=batch, seed=1)


def test_factorized_schedule_invariants():
    cfg, ta = _random_tm(60, 4, 10, 0.08, 3)
    comp = compiler.compile_tm(cfg, ta)
    for bc, bj, bt, tw in [(8, 2, 8, 2), (32, 4, 16, 4), (512, 8, 64, None)]:
        s = comp.factorized_schedule(bc, bj, bt, tw)
        # CSR over clause tiles; stage-1 tiles precede every clause tile
        assert s.n_tiles >= s.n_term_tiles + int(s.counts.sum())
        np.testing.assert_array_equal(np.diff(s.indptr), s.counts)
        stages = s.tile_stage
        assert (stages[: s.n_term_tiles] == 0).all()
        assert (stages[s.n_term_tiles:] == 1).all()
        # every term row's chain: real ids then sentinels; padding rows all
        # sentinel; every chain id < n_lit_bits + 1
        assert s.term_chain.shape[1] == s.term_w
        assert (s.term_chain[s.n_terms:] == s.n_lit_bits).all()
        assert s.term_chain.max() <= s.n_lit_bits
        # clause chains reference real terms or the sentinel term
        assert s.clause_chain.max() <= s.n_terms
        # reconstruct every clause's include bits from its term chain:
        # the factorization is exact by construction
        bits = packetizer.unpack_bits_np(
            np.ascontiguousarray(comp.include_words), s.n_lit_bits)
        for c in range(comp.n_unique):
            ids = s.clause_chain[c]
            ids = ids[ids < s.n_terms]
            got = np.zeros(s.n_lit_bits, np.uint8)
            for t in ids:
                lids = s.term_chain[t]
                got[lids[lids < s.n_lit_bits]] = 1
            np.testing.assert_array_equal(got, bits[c])


def test_fat_terms_split_into_shared_pieces():
    """A term wider than term_w splits into <= term_w-bit pieces, and two
    fat terms sharing a sub-pattern share its piece."""
    iw = np.zeros((2, 1), np.uint32)
    iw[0, 0] = 0b111101          # bits 0,2,3,4,5
    iw[1, 0] = 0b1101            # bits 0,2,3 — the first piece of row 0
    s = term_infer.build_factorized_schedule(iw, block_c=8, block_j=2,
                                             block_t=8, term_w=3)
    # row 0 -> pieces {0,2,3} + {4,5}; row 1 -> piece {0,2,3} (shared)
    assert s.n_terms == 2
    assert s.n_term_refs == 3
    lit = jnp.asarray(np.array([[0b111101], [0b1101], [0b101]], np.uint32))
    votes = jnp.asarray(np.array([[1, 0], [0, 1]], np.int32))
    out = term_infer.factorized_tm_forward(lit, votes, s, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), [[1, 1], [0, 1], [0, 0]])


def test_ops_dispatch_kernel_equals_oracle():
    """ops.tm_forward_factorized: kernel path == jnp table oracle == the
    flat schedule op, bit-for-bit."""
    cfg, ta = _random_tm(50, 4, 9, 0.07, 21)
    comp = compiler.compile_tm(cfg, ta)
    x = jnp.asarray(np.random.default_rng(2).integers(0, 2, (19, 50),
                                                      dtype=np.uint8))
    xw = packetizer.pack_literals(x)[:, jnp.asarray(comp.word_ids)]
    votes = jnp.asarray(comp.votes)
    kern = ops.tm_forward_factorized(xw, comp.include_words, votes,
                                     use_kernel=True, interpret=True)
    oracle = ops.tm_forward_factorized(xw, comp.include_words, votes,
                                       use_kernel=False)
    flat = ops.tm_forward_schedule(xw, comp.include_words, votes,
                                   use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(oracle))
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(flat))


def test_run_compiled_heuristic_default():
    """factorize=None serves the factorized schedule exactly when the
    artifact's partial_term_sharing clears the threshold (both routes stay
    bit-identical, so the check is on the memoized schedule tables)."""
    # high-sharing artifact: every clause carries the same two-word core
    cfg = tm.TMConfig(n_features=64, n_classes=2, clauses_per_class=8)
    C, L = 16, 128
    ta = np.full((C, L), -5, np.int8)
    ta[:, 3] = 3
    ta[:, 40] = 3
    for c in range(C):
        ta[c, 64 + ((c * 4) % 64)] = 3
    comp = compiler.compile_tm(cfg, ta)
    assert comp.stats.partial_term_sharing \
        >= compiler.FACTORIZE_SHARING_THRESHOLD
    x = jnp.asarray(np.random.default_rng(0).integers(0, 2, (9, 64),
                                                      dtype=np.uint8))
    xp = packetizer.pack_literals(x)
    compiler.run_compiled(comp, xp,
                          engine=compiler.EngineSpec(use_kernel=True),
                          interpret=True)
    assert comp._fschedules, "heuristic should have built the factorized " \
        "schedule"
    # a low-sharing artifact stays on the flat schedule
    cfg2, ta2 = _random_tm(24, 2, 4, 0.08, 0)
    comp2 = compiler.compile_tm(cfg2, ta2)
    assert comp2.stats.partial_term_sharing \
        < compiler.FACTORIZE_SHARING_THRESHOLD
    x2 = jnp.asarray(np.random.default_rng(1).integers(0, 2, (9, 24),
                                                       dtype=np.uint8))
    xp2 = packetizer.pack_literals(x2)
    compiler.run_compiled(comp2, xp2,
                          engine=compiler.EngineSpec(use_kernel=True),
                          interpret=True)
    assert not comp2._fschedules
    assert comp2._schedules
    # a factorized-only tiling key pins the factorized kernel even below
    # the sharing threshold (a tuned config is never silently dropped)...
    compiler.run_compiled(comp2, xp2,
                          engine=compiler.EngineSpec(use_kernel=True),
                          interpret=True, term_w=2)
    assert comp2._fschedules
    # ... and an explicitly non-factorized engine with such a key fails
    # loudly
    with pytest.raises(TypeError, match="factorized-only"):
        compiler.run_compiled(comp2, xp2, engine="sparse", interpret=True,
                              block_t=16)


def test_stacked_shard_factorized_composes_exactly():
    """Per-shard term + tile tables (common-shape padded) sum to the
    unsharded class sums — the single-process version of the mesh
    invariant."""
    cfg, ta = _random_tm(45, 3, 12, 0.09, 13)
    comp = compiler.compile_tm(cfg, ta)
    x = jnp.asarray(np.random.default_rng(3).integers(0, 2, (21, 45),
                                                      dtype=np.uint8))
    xw = packetizer.pack_literals(x)[:, jnp.asarray(comp.word_ids)]
    dense = tm.class_sums(cfg, jnp.asarray(ta), tm.literals(x),
                          training=False)
    for n_shards in (2, 4):
        scheds, terms, chains, votes_st, tiles, C_loc = (
            term_infer.stack_shard_factorized(
                comp.include_words, comp.votes, n_shards,
                block_c=16, block_j=4, block_t=32))
        assert len({s.block_t for s in scheds}) == 1, \
            "shards must share one static block_t"
        total = np.zeros_like(np.asarray(dense))
        for s in range(n_shards):
            part = term_infer.factorized_tm_forward_tables(
                xw, jnp.asarray(terms[s]), jnp.asarray(chains[s]),
                jnp.asarray(votes_st[s]), jnp.asarray(tiles[s]),
                block_t=scheds[s].block_t, block_c=scheds[s].block_c,
                block_j=scheds[s].block_j, interpret=True)
            total += np.asarray(part)
        np.testing.assert_array_equal(np.asarray(dense), total)


def test_save_load_keeps_factorized_schedule_and_tuned():
    cfg, ta = _random_tm(30, 3, 6, 0.1, 7)
    comp = compiler.compile_tm(cfg, ta)
    comp.record_tuned("term_infer", 512,
                      dict(block_c=64, block_j=8, block_t=32, block_s=4,
                           term_w=2))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "model.npz")
        comp.save(path)
        back = compiler.CompiledTM.load(path)
    assert back._fschedules, "artifact should ship its factorized schedule"
    sched = next(iter(back._fschedules.values()))
    ref_sched = comp.default_factorized_schedule
    np.testing.assert_array_equal(ref_sched.term_chain, sched.term_chain)
    np.testing.assert_array_equal(ref_sched.clause_chain, sched.clause_chain)
    np.testing.assert_array_equal(ref_sched.tile_stage, sched.tile_stage)
    np.testing.assert_array_equal(ref_sched.counts, sched.counts)
    assert sched.term_w == ref_sched.term_w
    # the loaded schedule answers the default lookup without a rebuild
    assert back.default_factorized_schedule is sched
    # recorded tilings round-trip for cold-start serving
    assert back.tuned_blocks("term_infer", 512) == dict(
        block_c=64, block_j=8, block_t=32, block_s=4, term_w=2)
    assert back.tuned_blocks("term_infer", 256) is None
    assert back.tuned_blocks("sparse_infer", 512) is None
    # context-keyed recall: a shard-slice sweep or another backend/mode
    # must not answer for the full bank (and vice versa)
    comp.record_tuned("term_infer", 512, dict(block_c=8), rows=10,
                      mode="cpu:interp")
    assert comp.tuned_blocks("term_infer", 512, rows=10,
                             mode="cpu:interp") == dict(block_c=8)
    assert comp.tuned_blocks("term_infer", 512, rows=40,
                             mode="cpu:interp") is None
    assert comp.tuned_blocks("term_infer", 512, rows=10,
                             mode="tpu:compiled") is None


def test_autotune_term_keys(tmp_path, monkeypatch):
    """The factorized sweep caches under artifact-hashed term_infer: keys
    and returns the five-knob tiling dict."""
    import json

    from repro.kernels import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "t.json"))
    cfg, ta = _random_tm(20, 2, 4, 0.1, 0)
    comp = compiler.compile_tm(cfg, ta)
    blocks = autotune.autotune_term_infer_blocks(
        9, 2, comp.include_words, interpret=True,
        candidates=((8, 2, 8, 1, 0), (16, 2, 8, 1, 2)), reps=1)
    assert set(blocks) == {"block_c", "block_j", "block_t", "block_s",
                           "term_w"}
    cache = json.loads((tmp_path / "t.json").read_text())
    keys = [k for k in cache["entries"] if k.startswith("term_infer:")]
    assert len(keys) == 1 and ":sig" in keys[0]
    # a different artifact of the SAME shape must not share the entry
    cfg2, ta2 = _random_tm(20, 2, 4, 0.1, 99)
    comp2 = compiler.compile_tm(cfg2, ta2)
    autotune.autotune_term_infer_blocks(
        9, 2, comp2.include_words, interpret=True,
        candidates=((8, 2, 8, 1, 0), (16, 2, 8, 1, 2)), reps=1)
    cache = json.loads((tmp_path / "t.json").read_text())
    assert len([k for k in cache["entries"]
                if k.startswith("term_infer:")]) == 2


def test_realized_sharing_matches_compile_stat():
    """With no fat-term splits the schedule's realized sharing equals the
    compiler's measured partial_term_sharing opportunity exactly."""
    cfg, ta = _random_tm(40, 3, 10, 0.1, 17)
    comp = compiler.compile_tm(cfg, ta)
    sched = comp.factorized_schedule(term_w=32)   # no splits at full width
    assert sched.realized_term_sharing == pytest.approx(
        comp.stats.partial_term_sharing)
    assert sched.n_terms == comp.stats.n_partial_terms_unique
    assert sched.n_term_refs == comp.stats.n_partial_terms_dense


# ---------------------------------------------------------------------------
# Emulated multi-device: the clause-sharded factorized schedule
# ---------------------------------------------------------------------------

_MESH_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.core import tm, compiler, packetizer, sharding
from repro.kernels import term_infer

rng = np.random.default_rng(0)
cfg = tm.TMConfig(n_features=48, n_classes=4, clauses_per_class=20)
ta = np.where(rng.random((80, 96)) < 0.08,
              rng.integers(0, 127, (80, 96)),
              rng.integers(-128, 0, (80, 96))).astype(np.int8)
comp = compiler.compile_tm(cfg, ta)
X = jnp.asarray(rng.integers(0, 2, (24, 48), dtype=np.uint8))
xw = packetizer.pack_literals(X)[:, jnp.asarray(comp.word_ids)]
dense = tm.class_sums(cfg, jnp.asarray(ta), tm.literals(X), training=False)
for shape, axes in (((4,), ("model",)), ((2, 2), ("data", "model"))):
    mesh = jax.make_mesh(shape, axes)
    n_model = mesh.shape["model"]
    scheds, terms, chains, votes, tiles, C_loc = (
        term_infer.stack_shard_factorized(
            comp.include_words, comp.votes, n_model,
            block_c=32, block_j=4, block_t=32))
    for uk in (True, False):   # Pallas factorized kernel and jnp oracle
        fwd = sharding.sharded_factorized_forward_fn(
            mesh, block_t=scheds[0].block_t, block_c=scheds[0].block_c,
            block_j=scheds[0].block_j, use_kernel=uk, interpret=True)
        out = fwd(jnp.asarray(terms), jnp.asarray(chains),
                  jnp.asarray(votes), jnp.asarray(tiles), xw)
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(out))
print("SHARDED_FACTORIZED_BITEXACT_OK")
"""


@pytest.mark.multidevice
def test_clause_sharded_factorized_bit_identical():
    """The factorized schedule, clause-sharded over an emulated 4-device
    mesh (each shard carrying its own term + tile tables + one int32
    psum), equals dense single-device inference EXACTLY — kernel and
    oracle engines, on a pure-model mesh and a (data x model) mesh."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", _MESH_CODE], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=REPO)
    assert "SHARDED_FACTORIZED_BITEXACT_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.multidevice
def test_serve_mesh_factorized_wiring(tmp_path):
    """`serve --artifact ... --mesh model=2` end-to-end on the FACTORIZED
    path: a saved high-sharing artifact (every clause carries a shared
    two-word core) clears the factorize threshold, so the mesh branch
    must build per-shard term tables and report the factorized path —
    the sparse-schedule fallback would fail the path assert."""
    from repro.configs.matador_tm import TM_CONFIGS

    cfg = TM_CONFIGS["tm-mnist"]
    C, L = cfg.n_clauses_raw, cfg.n_literals
    ta = np.full((C, L), -5, np.int8)
    ta[:, 3] = 3
    ta[:, 40] = 3                     # the shared two-word core
    for c in range(C):
        ta[c, 200 + (c % 600)] = 3    # per-clause discriminator word
    comp = compiler.compile_tm(cfg, ta)
    assert comp.stats.partial_term_sharing \
        >= compiler.FACTORIZE_SHARING_THRESHOLD
    path = os.path.join(str(tmp_path), "artifact.npz")
    comp.save(path)

    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu", REPRO_USE_PALLAS="1",
               XLA_FLAGS="--xla_force_host_platform_device_count=2")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "tm-mnist",
         "--requests", "64", "--bucket", "32", "--mesh", "model=2",
         "--artifact", path],
        env=env, capture_output=True, text=True, timeout=600, cwd=REPO,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert f"loaded artifact {path}" in r.stdout, r.stdout + r.stderr
    assert "clause-sharded factorized-schedule" in r.stdout, \
        r.stdout + r.stderr
    assert "inf/s" in r.stdout, r.stdout + r.stderr
