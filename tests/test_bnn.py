"""FINN-style BNN baseline: trains, and packed XNOR inference matches the
float-binarized network."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import bnn
from repro.data import make_boolean_classification


def test_bnn_learns_and_pack_matches():
    # one generated distribution, split train/test (same class prototypes)
    Xall, yall = make_boolean_classification(1900, 64, 4, seed=0)
    X, y = Xall[:1500], yall[:1500]
    Xte, yte = Xall[1500:], yall[1500:]
    cfg = bnn.BNNConfig(layer_sizes=(64, 128, 4), lr=5e-3)
    params = bnn.bnn_init(cfg, jax.random.PRNGKey(0))
    params = bnn.bnn_train(cfg, params, X, y, epochs=8, batch_size=50,
                           rng=jax.random.PRNGKey(1))

    # float-binarized argmax
    logits = bnn._forward_float(params, jnp.asarray(Xte))
    pred_float = np.asarray(jnp.argmax(logits, -1))
    acc = (pred_float == yte).mean()
    assert acc > 0.6, acc

    # packed XNOR-popcount path agrees exactly
    packed = bnn.bnn_pack(params)
    pred_packed = np.asarray(bnn.bnn_predict(packed, jnp.asarray(Xte)))
    agree = (pred_packed == pred_float).mean()
    assert agree > 0.99, agree


def test_bnn_packed_kernel_path():
    X, _ = make_boolean_classification(64, 32, 2, seed=0)
    cfg = bnn.BNNConfig(layer_sizes=(32, 64, 2))
    params = bnn.bnn_init(cfg, jax.random.PRNGKey(0))
    packed = bnn.bnn_pack(params)
    a = np.asarray(bnn.bnn_predict(packed, jnp.asarray(X)))
    b = np.asarray(bnn.bnn_predict(packed, jnp.asarray(X),
                                   use_kernel=True, interpret=True))
    np.testing.assert_array_equal(a, b)
