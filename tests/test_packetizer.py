"""Property tests for the bandwidth-driven packetizer (paper Fig. 4).

``hypothesis`` is optional: fixed-seed fallbacks cover the same roundtrip
properties when it is not installed.
"""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import packetizer, tm


def _check_pack_unpack_roundtrip(arr):
    words = packetizer.pack_bits(jnp.asarray(arr))
    back = packetizer.unpack_bits(words, arr.shape[-1])
    np.testing.assert_array_equal(np.asarray(back), arr)


def _check_np_and_jnp_twins_agree(arr):
    w_np = packetizer.pack_bits_np(arr)
    w_j = np.asarray(packetizer.pack_bits(jnp.asarray(arr)))
    np.testing.assert_array_equal(w_np, w_j)
    np.testing.assert_array_equal(
        packetizer.unpack_bits_np(w_np, arr.shape[-1]), arr
    )


if HAVE_HYPOTHESIS:
    bits_arrays = st.integers(1, 4).flatmap(
        lambda b: st.integers(1, 200).flatmap(
            lambda l: st.lists(
                st.lists(st.integers(0, 1), min_size=l, max_size=l),
                min_size=b, max_size=b,
            )
        )
    )

    @pytest.mark.hypothesis_optional
    @settings(max_examples=30, deadline=None)
    @given(bits_arrays)
    def test_pack_unpack_roundtrip(bits):
        _check_pack_unpack_roundtrip(np.array(bits, dtype=np.uint8))

    @pytest.mark.hypothesis_optional
    @settings(max_examples=30, deadline=None)
    @given(bits_arrays)
    def test_np_and_jnp_twins_agree(bits):
        _check_np_and_jnp_twins_agree(np.array(bits, dtype=np.uint8))


@pytest.mark.parametrize("b,l,seed", [(1, 1, 0), (3, 31, 1), (4, 32, 2),
                                      (2, 33, 3), (4, 200, 4)])
def test_pack_unpack_roundtrip_fixed(b, l, seed):
    arr = np.random.default_rng(seed).integers(0, 2, (b, l), dtype=np.uint8)
    _check_pack_unpack_roundtrip(arr)


@pytest.mark.parametrize("b,l,seed", [(1, 1, 5), (3, 31, 6), (4, 32, 7),
                                      (2, 33, 8), (4, 200, 9)])
def test_np_and_jnp_twins_agree_fixed(b, l, seed):
    arr = np.random.default_rng(seed).integers(0, 2, (b, l), dtype=np.uint8)
    _check_np_and_jnp_twins_agree(arr)


def test_lsb_first_layout():
    # bit i of word w is literal 32*w + i (paper Fig. 4a LSB-first order)
    bits = np.zeros((1, 40), np.uint8)
    bits[0, 0] = 1   # word 0, bit 0
    bits[0, 33] = 1  # word 1, bit 1
    w = np.asarray(packetizer.pack_bits(jnp.asarray(bits)))
    assert w[0, 0] == 1
    assert w[0, 1] == 2


def test_padding_never_violates():
    """Zero-padding an include mask can never produce a clause violation."""
    ta = np.full((3, 40), -1, np.int8)
    ta[0, :3] = 1
    inc_words = packetizer.pack_include_masks(jnp.asarray(ta))
    # padding bits (40..63) of word 1 must be zero
    assert int(np.asarray(inc_words)[0, 1]) < 2 ** (40 - 32)


def test_pack_literals_shape():
    x = jnp.asarray(np.random.default_rng(0).integers(0, 2, (5, 20), dtype=np.uint8))
    w = packetizer.pack_literals(x)
    assert w.shape == (5, packetizer.n_words(40))
