"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packetizer, tm
from repro.kernels import ops, ref

RNG = np.random.default_rng(42)
KW = dict(use_kernel=True, interpret=True)


def _sparse_includes(C, W, density=0.05):
    m = RNG.random((C, W * 32)) < density
    return packetizer.pack_bits_np(m.astype(np.uint8))


@pytest.mark.parametrize("B,C,W", [(1, 1, 1), (7, 13, 3), (64, 128, 8), (33, 257, 5)])
def test_clause_fire_sweep(B, C, W):
    lit = jnp.asarray(RNG.integers(0, 2**32, (B, W), dtype=np.uint32))
    inc = jnp.asarray(_sparse_includes(C, W))
    r = ref.clause_fire_ref(lit, inc)
    k = ops.clause_fire(lit, inc, **KW)
    np.testing.assert_array_equal(np.asarray(r), np.asarray(k))
    assert int(np.asarray(k).sum()) > 0  # sparse includes -> some clauses fire


@pytest.mark.parametrize("blocks", [dict(), dict(block_b=8, block_c=128, block_w=2)])
def test_clause_fire_blockings(blocks):
    lit = jnp.asarray(RNG.integers(0, 2**32, (17, 5), dtype=np.uint32))
    inc = jnp.asarray(_sparse_includes(39, 5))
    r = ref.clause_fire_ref(lit, inc)
    k = ops.clause_fire(lit, inc, **KW, **blocks)
    np.testing.assert_array_equal(np.asarray(r), np.asarray(k))


@pytest.mark.parametrize("B,C,K", [(3, 7, 2), (65, 300, 10), (128, 512, 32)])
def test_class_sum_sweep(B, C, K):
    fired = jnp.asarray(RNG.integers(0, 2, (B, C), dtype=np.int8))
    votes = jnp.asarray(RNG.integers(-9, 10, (C, K), dtype=np.int32))
    np.testing.assert_array_equal(
        np.asarray(ref.class_sum_ref(fired, votes)),
        np.asarray(ops.class_sums(fired, votes, **KW)),
    )


@pytest.mark.parametrize("C,L,B", [(5, 9, 2), (64, 200, 7), (130, 513, 4)])
@pytest.mark.parametrize("p_act,p_inact", [(1.0, 0.1), (0.9, 0.25)])
def test_ta_delta_sweep(C, L, B, p_act, p_inact):
    ta = jnp.asarray(RNG.integers(-128, 128, (C, L), dtype=np.int8))
    lits = jnp.asarray(RNG.integers(0, 2, (B, L), dtype=np.uint8))
    fire = jnp.asarray(RNG.integers(0, 2, (B, C), dtype=np.uint8))
    ftype = jnp.asarray(RNG.integers(0, 3, (B, C), dtype=np.uint8))
    seed = jnp.uint32(1234)
    r = ref.ta_delta_ref(ta, lits, fire, ftype, seed, p_act=p_act, p_inact=p_inact)
    k = ops.ta_delta(ta, lits, fire, ftype, seed, p_act=p_act, p_inact=p_inact, **KW)
    np.testing.assert_array_equal(np.asarray(r), np.asarray(k))


@pytest.mark.parametrize("B,O,W,pad", [(4, 6, 2, 0), (33, 65, 4, 13), (128, 256, 8, 31)])
def test_xnor_popcount_sweep(B, O, W, pad):
    n_bits = W * 32 - pad
    # real packers zero the padding bits; emulate that
    a_bits = RNG.integers(0, 2, (B, n_bits), dtype=np.uint8)
    w_bits = RNG.integers(0, 2, (O, n_bits), dtype=np.uint8)
    a = jnp.asarray(packetizer.pack_bits_np(a_bits))
    w = jnp.asarray(packetizer.pack_bits_np(w_bits))
    r = ref.xnor_popcount_ref(a, w, n_bits)
    k = ops.xnor_dot(a, w, n_bits, **KW)
    np.testing.assert_array_equal(np.asarray(r), np.asarray(k))
    # oracle-of-oracle: ±1 dot product
    pm_a = 2.0 * a_bits - 1
    pm_w = 2.0 * w_bits - 1
    np.testing.assert_array_equal(np.asarray(r), (pm_a @ pm_w.T).astype(np.int32))


def test_hash_rng_uniformity():
    """The kernel RNG should be close to uniform (coarse sanity)."""
    idx = jnp.arange(100_000, dtype=jnp.uint32)
    r = np.asarray(ref.hash_u32(idx, jnp.uint32(7)))
    frac = (r < ref.prob_to_u32(0.3)).mean()
    assert abs(frac - 0.3) < 0.01


# ---------------------------------------------------------------------------
# fused single-pass inference kernel (fused_infer.py)
# ---------------------------------------------------------------------------

def _fused_expect(lit, inc, votes, nonempty):
    """Oracle composition the fused kernel must match bit-for-bit."""
    fired = ref.clause_fire_ref(lit, inc)
    if nonempty is not None:
        fired = fired * nonempty[None, :].astype(fired.dtype)
    return ref.class_sum_ref(fired, votes)


@pytest.mark.parametrize(
    "B,C,W,K",
    [
        (1, 1, 1, 1),        # single-class, single-clause edge
        (7, 13, 3, 2),       # everything ragged
        (33, 257, 5, 10),    # C not a multiple of 128
        (64, 300, 8, 1),     # single class with a wide bank
        (130, 128, 2, 4),    # B not a multiple of block_b
    ],
)
@pytest.mark.parametrize("masked", [True, False])
def test_fused_infer_sweep(B, C, W, K, masked):
    lit = jnp.asarray(RNG.integers(0, 2**32, (B, W), dtype=np.uint32))
    inc = jnp.asarray(_sparse_includes(C, W))
    votes = jnp.asarray(RNG.integers(-9, 10, (C, K), dtype=np.int32))
    ne = jnp.asarray(RNG.integers(0, 2, (C,), dtype=np.uint8)) if masked else None
    expect = _fused_expect(lit, inc, votes, ne)
    got = ops.tm_forward_packed(lit, inc, votes, ne, fuse=True, **KW)
    np.testing.assert_array_equal(np.asarray(expect), np.asarray(got))


@pytest.mark.parametrize(
    "blocks",
    [dict(), dict(block_b=8, block_c=128, block_w=2),
     dict(block_b=16, block_c=256, block_w=1)],
)
def test_fused_infer_blockings(blocks):
    """Ragged shapes vs every block tiling: B/C/W not multiples of blocks."""
    lit = jnp.asarray(RNG.integers(0, 2**32, (17, 5), dtype=np.uint32))
    inc = jnp.asarray(_sparse_includes(39, 5, density=0.08))
    votes = jnp.asarray(RNG.integers(-3, 4, (39, 3), dtype=np.int32))
    ne = jnp.asarray(RNG.integers(0, 2, (39,), dtype=np.uint8))
    expect = _fused_expect(lit, inc, votes, ne)
    got = ops.tm_forward_packed(lit, inc, votes, ne, fuse=True, **KW, **blocks)
    np.testing.assert_array_equal(np.asarray(expect), np.asarray(got))


def test_fused_infer_all_empty_bank():
    """All-exclude clause bank: every clause fires vacuously but the
    nonempty mask zeroes the sums (inference semantics, paper §III)."""
    B, C, W, K = 9, 40, 3, 4
    lit = jnp.asarray(RNG.integers(0, 2**32, (B, W), dtype=np.uint32))
    inc = jnp.zeros((C, W), jnp.uint32)
    votes = jnp.asarray(RNG.integers(-5, 6, (C, K), dtype=np.int32))
    ne = jnp.zeros((C,), jnp.uint8)
    got = ops.tm_forward_packed(lit, inc, votes, ne, fuse=True, **KW)
    np.testing.assert_array_equal(np.asarray(got), 0)
    # unmasked (training semantics): vacuous fire = 1 -> column sums of votes
    got_unmasked = ops.tm_forward_packed(lit, inc, votes, None, fuse=True, **KW)
    np.testing.assert_array_equal(
        np.asarray(got_unmasked),
        np.broadcast_to(np.asarray(votes).sum(0), (B, K)),
    )


def test_fused_matches_unfused_pipeline():
    """fuse=True and fuse=False kernel paths agree bit-for-bit."""
    lit = jnp.asarray(RNG.integers(0, 2**32, (21, 4), dtype=np.uint32))
    inc = jnp.asarray(_sparse_includes(70, 4))
    votes = jnp.asarray(RNG.integers(-2, 3, (70, 5), dtype=np.int32))
    ne = jnp.asarray(RNG.integers(0, 2, (70,), dtype=np.uint8))
    fused = ops.tm_forward_packed(lit, inc, votes, ne, fuse=True, **KW)
    unfused = ops.tm_forward_packed(lit, inc, votes, ne, fuse=False, **KW)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))


def test_fused_infer_randomized_property():
    """Randomized fixed-seed property sweep: fused == oracle composition."""
    prng = np.random.default_rng(7)
    for _ in range(10):
        B = int(prng.integers(1, 70))
        C = int(prng.integers(1, 400))
        W = int(prng.integers(1, 9))
        K = int(prng.integers(1, 12))
        density = float(prng.uniform(0.0, 0.2))
        lit = jnp.asarray(prng.integers(0, 2**32, (B, W), dtype=np.uint32))
        m = prng.random((C, W * 32)) < density
        inc = jnp.asarray(packetizer.pack_bits_np(m.astype(np.uint8)))
        votes = jnp.asarray(prng.integers(-9, 10, (C, K), dtype=np.int32))
        ne = jnp.asarray(prng.integers(0, 2, (C,), dtype=np.uint8))
        expect = _fused_expect(lit, inc, votes, ne)
        got = ops.tm_forward_packed(lit, inc, votes, ne, fuse=True, **KW)
        np.testing.assert_array_equal(np.asarray(expect), np.asarray(got))


def test_autotuner_cache_roundtrip(tmp_path, monkeypatch):
    """The block autotuner returns a valid clipped tiling and memoizes it."""
    from repro.kernels import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    cands = ((128, 128, 64), (8, 128, 2))
    blocks = autotune.autotune_fused_blocks(
        17, 39, 5, 3, interpret=True, candidates=cands, reps=1
    )
    assert set(blocks) == {"block_b", "block_c", "block_w"}
    assert (tmp_path / "tune.json").exists()
    again = autotune.autotune_fused_blocks(
        17, 39, 5, 3, interpret=True, candidates=cands, reps=1
    )
    assert again == blocks
    # tuned blocks must preserve bit-exactness
    lit = jnp.asarray(RNG.integers(0, 2**32, (17, 5), dtype=np.uint32))
    inc = jnp.asarray(_sparse_includes(39, 5))
    votes = jnp.asarray(RNG.integers(-3, 4, (39, 3), dtype=np.int32))
    expect = _fused_expect(lit, inc, votes, None)
    got = ops.tm_forward_packed(lit, inc, votes, None, fuse=True, **KW, **blocks)
    np.testing.assert_array_equal(np.asarray(expect), np.asarray(got))


def test_predict_kernel_path_matches_dense():
    """tm.predict wired through the fused packed path == dense XLA path."""
    cfg = tm.TMConfig(n_features=37, n_classes=4, clauses_per_class=9)
    state = tm.init(cfg, jax.random.PRNGKey(3))
    x = jnp.asarray(RNG.integers(0, 2, (25, 37), dtype=np.uint8))
    dense = tm.predict(cfg, state, x, use_kernel=False)
    fused = tm.predict(cfg, state, x, use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(fused))


def test_tm_forward_packed_matches_dense():
    cfg = tm.TMConfig(n_features=50, n_classes=3, clauses_per_class=12)
    state = tm.init(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(RNG.integers(0, 2, (20, 50), dtype=np.uint8))
    lits = tm.literals(x)
    dense = tm.class_sums(cfg, state.ta_state, lits, training=False)
    lw = packetizer.pack_bits(lits)
    iw = packetizer.pack_include_masks(state.ta_state)
    nonempty = jnp.any(state.ta_state >= 0, axis=-1).astype(jnp.uint8)
    packed = ops.tm_forward_packed(lw, iw, tm.vote_matrix(cfg), nonempty, **KW)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(packed))


@pytest.mark.parametrize("B,S,H,hd,bq,bkv", [(2, 64, 3, 16, 16, 16), (1, 128, 2, 32, 32, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_kernel(B, S, H, hd, bq, bkv, causal):
    from repro.kernels.flash_attention import flash_forward

    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    out = flash_forward(q, k, v, causal=causal, block_q=bq, block_kv=bkv,
                        interpret=True)
    expect = ref.flash_ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)
