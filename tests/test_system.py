"""End-to-end behaviour of the paper's system: the full MATADOR flow
train -> compile -> verify -> deploy artifact, on paper-shaped datasets."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compiler, packetizer, tm, train
from repro.data import paper_dataset


@pytest.fixture(scope="module")
def trained_mnist_like():
    """A small TM trained on MNIST-dimensioned synthetic data (784 feats,
    10 classes) — module-scoped: several tests share it."""
    X, y, Xte, yte = paper_dataset("mnist", n_train=3000, n_test=600)
    cfg = tm.TMConfig(n_features=784, n_classes=10, clauses_per_class=40,
                      threshold=40, s=8.0)
    state = tm.init(cfg, jax.random.PRNGKey(0))
    state = train.fit(cfg, state, jnp.asarray(X), jnp.asarray(y),
                      epochs=8, batch_size=50, rng=jax.random.PRNGKey(1))
    return cfg, state, Xte, yte


def test_accuracy_on_paper_shaped_data(trained_mnist_like):
    cfg, state, Xte, yte = trained_mnist_like
    acc = float(tm.accuracy(cfg, state, jnp.asarray(Xte), jnp.asarray(yte)))
    assert acc > 0.85, acc  # synthetic prototypes; the claim is learnability


def test_model_exhibits_paper_sparsity(trained_mnist_like):
    """Paper §II: 'extremely high sparsity in the occurrence of includes'."""
    cfg, state, _, _ = trained_mnist_like
    include_frac = float((np.asarray(state.ta_state) >= 0).mean())
    assert include_frac < 0.2, include_frac


def test_boolean_to_silicon_flow(trained_mnist_like):
    """The full automation pipeline with design verification (paper Fig. 6):
    compile -> auto-verify against the dense model -> save -> reload -> run."""
    cfg, state, Xte, yte = trained_mnist_like
    compiled = compiler.compile_tm(cfg, state.ta_state)

    # logic sharing + dead-word elimination actually engaged
    assert compiled.stats.clause_sharing >= 0.0
    assert compiled.stats.n_words_active <= compiled.stats.n_words_dense

    # auto-verification: compiled artifact == dense model on the test set
    xp = packetizer.pack_literals(jnp.asarray(Xte))
    pred_c = np.asarray(jnp.argmax(compiler.run_compiled(compiled, xp), -1))
    pred_d = np.asarray(tm.predict(cfg, state, jnp.asarray(Xte)))
    np.testing.assert_array_equal(pred_c, pred_d)

    # deploy artifact round-trips
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "accelerator.npz")
        compiled.save(path)
        reloaded = compiler.CompiledTM.load(path)
        pred_r = np.asarray(jnp.argmax(compiler.run_compiled(reloaded, xp), -1))
        np.testing.assert_array_equal(pred_c, pred_r)


def test_compiled_beats_random(trained_mnist_like):
    cfg, state, Xte, yte = trained_mnist_like
    compiled = compiler.compile_tm(cfg, state.ta_state)
    pred = np.asarray(compiler.predict_compiled(compiled, jnp.asarray(Xte)))
    assert (pred == yte).mean() > 0.85


def test_all_paper_datasets_train_one_step():
    """Every Table-II dataset shape runs through the training step."""
    from repro.configs.matador_tm import TM_CONFIGS

    for name in ("tm-mnist", "tm-kws6", "tm-cifar2"):
        cfg = TM_CONFIGS[name]
        X, y, _, _ = paper_dataset(name.replace("tm-", ""), n_train=64, n_test=8)
        small = tm.TMConfig(
            n_features=cfg.n_features, n_classes=cfg.n_classes,
            clauses_per_class=4, threshold=10, s=5.0,
        )
        st = tm.init(small, jax.random.PRNGKey(0))
        st2, metrics = train.train_step(small, st, jnp.asarray(X), jnp.asarray(y),
                                        jax.random.PRNGKey(1))
        assert int(metrics["delta_abs_sum"]) > 0
