"""Anytime inference: margin metadata soundness, exact early-exit,
budgeted-mode error bounds, and the brownout controller.

The contract under test (``kernels/anytime.py`` / ISSUE "brownout
serving"):

* ``margin[t]`` — residual vote swing after tile ``t`` — is monotone
  non-increasing, ends at 0, and is consistent with the vote table.
* exact early-exit is BIT-IDENTICAL to the full walk's argmax (property-
  tested against the XLA oracle over random automata).
* budgeted mode's realized error never exceeds its reported bound: every
  pairwise class-sum margin moves by at most ``bound`` votes, so the
  served class trails the true winner by at most ``bound``.
* the ``BrownoutController`` escalates immediately, recovers with
  hysteresis, and its fault-independent watchdog un-wedges a stuck
  step-down path (``gateway.brownout_stuck`` drill).
"""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compiler, packetizer, tm
from repro.kernels import anytime, ops, sparse_infer
from repro.runtime import faults
from repro.runtime.gateway import BrownoutConfig, BrownoutController

pytestmark = pytest.mark.anytime

# small tilings force multi-tile schedules on test-sized banks so prefix
# slicing and early-exit certification actually have tiles to skip
SBLOCKS = dict(block_c=16, block_j=8)
FBLOCKS = dict(block_c=16, block_j=8, block_t=64, term_w=8)


def _random_tm(n_features, n_classes, cpc, include_density, seed):
    rng = np.random.default_rng(seed)
    C = n_classes * cpc
    ta = np.where(
        rng.random((C, 2 * n_features)) < include_density,
        rng.integers(0, 127, (C, 2 * n_features)),
        rng.integers(-128, 0, (C, 2 * n_features)),
    ).astype(np.int8)
    cfg = tm.TMConfig(n_features=n_features, n_classes=n_classes,
                      clauses_per_class=cpc)
    return cfg, ta


def _compiled(seed=0, n_features=48, n_classes=4, cpc=16, density=0.12):
    cfg, ta = _random_tm(n_features, n_classes, cpc, density, seed)
    return compiler.compile_tm(cfg, ta), cfg


def _packed(comp, cfg, B=24, seed=1):
    x = np.random.default_rng(seed).integers(
        0, 2, (B, cfg.n_features), dtype=np.uint8)
    return packetizer.pack_literals(jnp.asarray(x))


# --------------------------------------------------------------------------
# margin tables
# --------------------------------------------------------------------------

def test_row_swing_and_total():
    votes = np.array([[3, -2], [0, 0], [5, 5], [-1, 4]])
    np.testing.assert_array_equal(anytime.row_swing(votes), [5, 0, 0, 5])
    assert anytime.total_swing(votes) == 10


@pytest.mark.parametrize("engine", ["sparse", "factorized"])
def test_margins_monotone_and_terminal(engine):
    comp, _ = _compiled()
    if engine == "sparse":
        margins = comp.tile_margins(**SBLOCKS)
        sched = comp.schedule(**SBLOCKS)
    else:
        margins = comp.factorized_tile_margins(**FBLOCKS)
        sched = comp.factorized_schedule(**FBLOCKS)
    assert margins.shape == (sched.n_tiles,)
    assert sched.n_tiles > 3          # multi-tile, or the test is vacuous
    assert np.all(margins >= 0)
    assert np.all(np.diff(margins) <= 0), "margins must be non-increasing"
    # after the LAST tile every clause block has folded: nothing remains
    assert margins[-1] == 0
    assert margins[0] <= anytime.total_swing(comp.votes)


def test_margin_order_is_mass_banded_permutation():
    comp, _ = _compiled()
    inc, votes = comp.include_words, comp.votes
    order = anytime.margin_order(inc, votes,
                                 cluster_fn=sparse_infer.cluster_order)
    assert sorted(order.tolist()) == list(range(len(votes)))
    mass = np.abs(votes.astype(np.int64)).sum(axis=1)[order]
    # banded descending: every row's band is >= the previous row's band
    top = int(mass.max())
    band = np.where(mass > 0,
                    np.floor(np.log2(top / np.maximum(mass, 1))), 99)
    assert np.all(np.diff(band) >= 0)
    # compile_tm itself applies margin_order: the compiled artifact's
    # first clause row carries top-band vote mass
    first_mass = int(np.abs(comp.votes[0].astype(np.int64)).sum())
    assert first_mass * 2 > int(np.abs(
        comp.votes.astype(np.int64)).sum(axis=1).max())


def test_quality_levels_structure():
    comp, _ = _compiled()
    for engine, tiling in (("sparse", SBLOCKS), ("factorized", FBLOCKS)):
        levels = comp.quality_levels(engine=engine, **tiling)
        assert levels[0] == dict(level=0, n_tiles=levels[0]["n_tiles"],
                                 bound=0, frac=0.0)
        total = anytime.total_swing(comp.votes)
        margins = (comp.tile_margins(**tiling) if engine == "sparse"
                   else comp.factorized_tile_margins(**tiling))
        for q in levels[1:]:
            assert 1 <= q["n_tiles"] <= levels[0]["n_tiles"]
            assert q["bound"] == int(margins[q["n_tiles"] - 1])
        # deeper degradation never runs MORE tiles
        n = [q["n_tiles"] for q in levels]
        assert all(a >= b for a, b in zip(n, n[1:]))


# --------------------------------------------------------------------------
# budgeted mode: realized error <= reported bound
# --------------------------------------------------------------------------

@pytest.mark.parametrize("engine,tiling",
                         [("sparse", SBLOCKS), ("factorized", FBLOCKS)])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_budgeted_error_within_bound(engine, tiling, seed):
    comp, cfg = _compiled(seed=seed)
    xp = _packed(comp, cfg, seed=seed + 10)
    full = np.asarray(compiler.run_compiled(
        comp, xp, engine=engine, interpret=True, **tiling), np.int64)
    for q in comp.quality_levels(engine=engine, **tiling)[1:]:
        got = np.asarray(compiler.run_compiled(
            comp, xp, engine=engine, interpret=True,
            quality=q["level"], **tiling), np.int64)
        # every pairwise class-sum margin within +-bound of the full walk
        d_full = full[:, :, None] - full[:, None, :]
        d_got = got[:, :, None] - got[:, None, :]
        realized = np.abs(d_full - d_got).max()
        assert realized <= q["bound"], (q, realized)
        # served class trails the true winner by at most `bound` votes
        served = got.argmax(axis=1)
        trail = full.max(axis=1) - full[np.arange(len(full)), served]
        assert trail.max() <= q["bound"]


# --------------------------------------------------------------------------
# exact early-exit: bit-identical argmax vs the XLA oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("engine,tiling",
                         [("sparse", SBLOCKS), ("factorized", FBLOCKS)])
@pytest.mark.parametrize("seed,density",
                         [(0, 0.05), (1, 0.12), (2, 0.25), (3, 0.4)])
def test_early_exit_argmax_bit_identical(engine, tiling, seed, density):
    comp, cfg = _compiled(seed=seed, density=density)
    xp = _packed(comp, cfg, B=40, seed=seed + 20)
    oracle = np.asarray(compiler.run_compiled(
        comp, xp, engine="oracle")).argmax(axis=1)
    got = np.asarray(compiler.run_compiled(
        comp, xp, engine=engine, interpret=True,
        early_exit=True, **tiling)).argmax(axis=1)
    np.testing.assert_array_equal(oracle, got)


def _confident_setup():
    """An artifact whose FIRST clause block decides every sample: a
    dominant always-firing clause up front, weak random tail — the
    canonical early-exit shape."""
    cfg, ta = _random_tm(48, 4, 16, 0.1, seed=7)
    comp = compiler.compile_tm(cfg, ta)
    F = cfg.n_features
    inc, wid = comp.include_words, comp.word_ids

    def lits(r):
        return [int(wid[w]) * 32 + b
                for w in range(inc.shape[1]) for b in range(32)
                if int(inc[r, w]) >> b & 1]

    # a clause is satisfiable by a single x iff it never includes both
    # polarities of one feature; find one and pin x to satisfy it
    row = want = None
    for r in range(inc.shape[0]):
        feats, ok = {}, bool(lits(r))
        for j in lits(r):
            f, pos = (j, 1) if j < F else (j - F, 0)
            if feats.setdefault(f, pos) != pos:
                ok = False
                break
        if ok:
            row, want = r, feats
            break
    assert row is not None
    for arr in (comp.include_words, comp.votes):
        arr[[0, row]] = arr[[row, 0]]
    comp.votes[0] = 0
    comp.votes[0, 0], comp.votes[0, 1] = 4000, -4000
    # a TAIL-block clause with the same (always-satisfied) include pattern
    # and a small vote: its fold is observable in the full walk's sums, so
    # a truncated early-exit run provably skipped it
    comp.include_words[20] = comp.include_words[0]
    comp.votes[20] = 0
    comp.votes[20, 2], comp.votes[20, 3] = 5, -5
    for memo in (comp._margins, comp._fmargins, comp._schedules,
                 comp._fschedules, comp._prefix_schedules):
        memo.clear()
    x = np.random.default_rng(3).integers(0, 2, (16, F), dtype=np.uint8)
    for f, pos in want.items():
        x[:, f] = pos                # the dominant clause fires for all
    return comp, packetizer.pack_literals(jnp.asarray(x))


def test_early_exit_truncates_on_confident_artifact():
    # the done flag must fire after the dominant block folds and SKIP the
    # tail folds: raw sums differ from the full walk, the argmax does not
    comp, xp = _confident_setup()
    full = np.asarray(compiler.run_compiled(
        comp, xp, engine="sparse", interpret=True, **SBLOCKS))
    ee = np.asarray(compiler.run_compiled(
        comp, xp, engine="sparse", interpret=True, early_exit=True,
        **SBLOCKS))
    np.testing.assert_array_equal(full.argmax(1), ee.argmax(1))
    assert not np.array_equal(full, ee), \
        "early exit never fired: sums identical to the full walk"


def test_slab_lead_margin_ties_and_padding():
    sums = jnp.asarray(np.array([[10, 10, 0, 99],       # tie -> lead 0
                                 [7, 3, 1, 99]]), jnp.int32)
    lead = np.asarray(sparse_infer._slab_lead_margin(sums, n_classes=3))
    np.testing.assert_array_equal(lead, [0, 4])   # pad col 99 ignored


# --------------------------------------------------------------------------
# artifact persistence + validation + fault drill
# --------------------------------------------------------------------------

def test_artifact_roundtrip_preserves_margins_and_validates():
    comp, _ = _compiled()
    want_s = comp.tile_margins()                 # default tilings persist
    want_f = comp.factorized_tile_margins()
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "a.npz")
        comp.save(path)
        loaded = compiler.CompiledTM.load(path)
    np.testing.assert_array_equal(loaded.tile_margins(), want_s)
    np.testing.assert_array_equal(loaded.factorized_tile_margins(), want_f)
    compiler.validate_artifact(loaded)           # margins checked here


def test_validate_rejects_inconsistent_margins():
    comp, _ = _compiled()
    margins = comp.tile_margins().copy()
    margins[0] += 2                              # no longer matches votes
    key = next(iter(comp._margins))
    comp._margins[key] = margins
    with pytest.raises(compiler.ArtifactError, match="margin"):
        compiler.validate_artifact(comp)


def test_validate_rejects_nonmonotone_margins():
    comp, _ = _compiled()
    margins = comp.tile_margins(**SBLOCKS).copy()
    assert len(margins) >= 2
    margins[-1] = margins[0] + 5                 # increases at the tail
    key = next(iter(comp._margins))
    comp._margins[key] = margins
    with pytest.raises(compiler.ArtifactError, match="margin"):
        compiler.validate_artifact(comp)


@pytest.mark.faults
def test_margin_corrupt_drill_rejected_at_load():
    comp, _ = _compiled()
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "a.npz")
        comp.save(path)
        with faults.injected("anytime.margin_corrupt"):
            with pytest.raises(compiler.ArtifactError, match="margin"):
                compiler.CompiledTM.load(path)
        compiler.CompiledTM.load(path)           # disarmed: loads clean


# --------------------------------------------------------------------------
# engine-ladder quality dispatch
# --------------------------------------------------------------------------

def test_ladder_routes_quality_to_supporting_engines_only():
    served = []

    def quality_fn(x, quality=0):
        served.append(quality)
        return jnp.asarray([quality])

    quality_fn.supports_quality = True
    exact_fn = lambda x: jnp.asarray([0])

    lad = ops.EngineLadder([("q", lambda: quality_fn)])
    out = lad.run(lambda: 0, bucket=0, quality=2)
    assert int(np.asarray(out)[0]) == 2 and lad.last_quality == 2
    lad.run(lambda: 0, bucket=1, quality=0)
    assert lad.last_quality == 0

    # an engine without the capability serves exact and reports exact
    lad2 = ops.EngineLadder([("plain", lambda: exact_fn)])
    lad2.run(lambda: 0, bucket=0, quality=3)
    assert lad2.last_quality == 0


# --------------------------------------------------------------------------
# brownout controller
# --------------------------------------------------------------------------

def test_brownout_escalates_immediately_and_steps_down_one_at_a_time():
    c = BrownoutController(BrownoutConfig(watchdog_evals=100))
    assert c.update(0.9) == 3                    # one eval -> top level
    # 0.6 < exit[2]=0.65 -> steps down exactly one level per evaluation
    assert c.update(0.6) == 2
    assert c.update(0.1) == 1
    assert c.update(0.1) == 0
    assert c.update(0.1) == 0                    # idempotent at exact
    assert c.escalations == 1 and c.stepdowns == 3


def test_brownout_hysteresis_band_holds_level():
    c = BrownoutController(BrownoutConfig(watchdog_evals=100))
    assert c.update(0.55) == 1                   # >= enter[0]=0.5
    # inside the band (exit[0]=0.3 <= p < enter[1]=0.7): holds level 1
    for _ in range(5):
        assert c.update(0.4) == 1
    assert c.update(0.2) == 0


def test_brownout_pressure_terms_and_clipping():
    p = BrownoutController.pressure(pending=10, max_queue=10, oldest_age=0,
                                    max_wait=0.02, deadline_frac=0.0)
    assert p == 1.0
    p = BrownoutController.pressure(pending=0, max_queue=None,
                                    oldest_age=0.04, max_wait=0.02)
    assert p == pytest.approx(0.5)
    p = BrownoutController.pressure(pending=0, max_queue=None, oldest_age=0,
                                    max_wait=0.02, deadline_frac=9.0)
    assert p == 1.0                              # clipped


def test_brownout_stuck_drill_watchdog_forces_recovery():
    c = BrownoutController(BrownoutConfig(watchdog_evals=4))
    assert c.update(0.95) == 3
    with faults.injected("gateway.brownout_stuck"):
        # primary step-down path is pinned: calm pressure leaves the
        # level wedged until the watchdog's consecutive-calm count trips
        levels = [c.update(0.05) for _ in range(3)]
        assert levels == [3, 3, 3], "stuck drill should pin the level"
        assert c.update(0.05) == 0, "watchdog must force exact serving"
    assert c.watchdog_resets == 1
    # watchdog is level-triggered, not a one-shot: a fresh overload still
    # escalates and recovers normally once the fault is disarmed
    assert c.update(0.9) == 3
    assert c.update(0.05) == 2
