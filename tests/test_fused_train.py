"""Fused single-pass training kernel vs the ref.py oracle composition.

The fused kernel (kernels/fused_train.py) must be bit-identical to the
unfused three-dispatch path (clause_fire -> feedback_plan -> ta_delta) and
to the pure-jnp oracle, in every calling mode: unchunked, batch-chunked
(even and ragged tails), and offset (b_offset/c_offset != 0 — the sharded
caller's view of a clause/batch shard).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packetizer, tm, train
from repro.kernels import fused_train, ops, ref

RNG = np.random.default_rng(123)
KW = dict(use_kernel=True, interpret=True)


def _problem(B=13, F=17, K=3, cpc=7, threshold=9, s=4.0, seed=0):
    rng = np.random.default_rng(seed)
    cfg = tm.TMConfig(n_features=F, n_classes=K, clauses_per_class=cpc,
                      threshold=threshold, s=s)
    ta = jnp.asarray(
        rng.integers(-30, 30, (cfg.n_clauses_total, cfg.n_literals),
                     dtype=np.int8))
    x = jnp.asarray(rng.integers(0, 2, (B, F), dtype=np.uint8))
    y = jnp.asarray(rng.integers(0, K, B, dtype=np.int32))
    return cfg, ta, x, y


def _steps(cfg, ta, x, y, seed, **kw):
    new_ta, delta = ops.tm_train_step_kernel(cfg, ta, x, y, seed, **kw)
    return np.asarray(new_ta), np.asarray(delta)


@pytest.mark.parametrize("B,F,K,cpc", [
    (13, 17, 3, 7),      # everything ragged
    (8, 64, 4, 32),      # C = 128 exactly one clause block
    (33, 9, 2, 50),      # binary, wide bank, B ragged vs block_b
])
def test_fused_step_matches_unfused_and_oracle(B, F, K, cpc):
    cfg, ta, x, y = _problem(B=B, F=F, K=K, cpc=cpc, seed=B)
    seed = jnp.uint32(77)
    ta_o, d_o = _steps(cfg, ta, x, y, seed, use_kernel=False)
    ta_u, d_u = _steps(cfg, ta, x, y, seed, fuse=False, **KW)
    ta_f, d_f = _steps(cfg, ta, x, y, seed, fuse=True, **KW)
    np.testing.assert_array_equal(d_o, d_u)
    np.testing.assert_array_equal(d_o, d_f)
    np.testing.assert_array_equal(ta_o, ta_f)
    assert np.abs(d_o).sum() > 0   # the step actually trained something


@pytest.mark.parametrize("blocks", [
    dict(block_b=8, block_c=128, block_w=1),
    dict(block_b=16, block_c=128, block_w=2),
])
def test_fused_step_blockings(blocks):
    """Ragged shapes vs explicit tilings: results must not depend on blocks."""
    cfg, ta, x, y = _problem(B=21, F=19, K=3, cpc=11, seed=5)
    seed = jnp.uint32(9)
    _, d_o = _steps(cfg, ta, x, y, seed, use_kernel=False)
    _, d_f = _steps(cfg, ta, x, y, seed, fuse=True, blocks=blocks, **KW)
    np.testing.assert_array_equal(d_o, d_f)


@pytest.mark.parametrize("B,chunk", [
    (24, 8),    # even split
    (21, 8),    # ragged tail: 2 full chunks + padded 5-sample tail
    (13, 4),    # ragged tail
])
def test_chunked_matches_unchunked_all_engines(B, chunk):
    """batch_chunk must be a pure memory knob: bit-identical results,
    including the padded+masked ragged tail (the old code silently ran
    the full batch when B % chunk != 0)."""
    cfg, ta, x, y = _problem(B=B, seed=B + chunk)
    seed = jnp.uint32(31)
    _, d_ref = _steps(cfg, ta, x, y, seed, use_kernel=False)
    for kw in (dict(use_kernel=False), dict(fuse=False, **KW),
               dict(fuse=True, **KW)):
        _, d_c = _steps(cfg, ta, x, y, seed, batch_chunk=chunk, **kw)
        np.testing.assert_array_equal(d_ref, d_c)


def test_fused_delta_offsets_match_composed_oracle():
    """b_offset/c_offset != 0 (the sharded caller): the fused kernel must
    reproduce feedback_select + ta_delta_ref on the local shard, with the
    selection hash on GLOBAL (sample, clause) ids and the automaton hash
    on (global sample, local clause)."""
    cfg, ta, x, y = _problem(B=11, F=23, K=3, cpc=9, seed=3)
    T = cfg.threshold
    seed = jnp.uint32(55)
    b_off, c_off, n_loc = 37, 10, 11

    lits = tm.literals(x)
    lw = packetizer.pack_bits(lits)
    iw = packetizer.pack_include_masks(ta)
    votes = tm.vote_matrix(cfg)
    cls = jnp.clip(jnp.arange(cfg.n_clauses_total) // cfg.clauses_per_class,
                   0, cfg.n_classes - 1)
    pol = tm.polarity(cfg)

    # per-sample scalars from the FULL clause bank's class sums
    sums = jnp.clip(ref.clause_fire_ref(lw, iw).astype(jnp.int32) @ votes,
                    -T, T)
    kn, p_t, p_n = ops.feedback_probs(sums, y, cfg.n_classes, T, seed,
                                      b_offset=b_off)

    sl = slice(c_off, c_off + n_loc)
    fire_loc = ref.clause_fire_ref(lw, iw[sl]).astype(jnp.uint8)
    ftype_loc = ops.feedback_select(y, kn, p_t, p_n, cls[sl], pol[sl], seed,
                                    b_offset=b_off, c_offset=c_off)
    d_ref = ref.ta_delta_ref(ta[sl], lits, fire_loc, ftype_loc, seed,
                             p_act=1.0, p_inact=0.25, b_offset=b_off)
    d_k = fused_train.fused_tm_train_delta(
        ta[sl], lits, lw, iw[sl], y, kn, p_t, p_n, cls[sl], pol[sl], seed,
        p_act=1.0, p_inact=0.25, b_offset=b_off, c_offset=c_off,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(d_ref), np.asarray(d_k))
    assert int(np.abs(np.asarray(d_ref)).sum()) > 0


def test_fused_clause_shards_reassemble_full_delta():
    """Two clause shards evaluated with c_offset stitch together into the
    full-bank unfused delta (the clause-sharded trainer's invariant)."""
    cfg, ta, x, y = _problem(B=9, F=15, K=2, cpc=12, seed=8)
    T = cfg.threshold
    seed = jnp.uint32(13)
    C = cfg.n_clauses_total
    half = C // 2

    _, d_full = _steps(cfg, ta, x, y, seed, use_kernel=False)

    lits = tm.literals(x)
    lw = packetizer.pack_bits(lits)
    iw = packetizer.pack_include_masks(ta)
    votes = tm.vote_matrix(cfg)
    cls = jnp.clip(jnp.arange(C) // cfg.clauses_per_class, 0,
                   cfg.n_classes - 1)
    pol = tm.polarity(cfg)
    sums = jnp.clip(ref.clause_fire_ref(lw, iw).astype(jnp.int32) @ votes,
                    -T, T)
    kn, p_t, p_n = ops.feedback_probs(sums, y, cfg.n_classes, T, seed)
    p_act = 1.0 if cfg.boost_true_positive else (cfg.s - 1.0) / cfg.s

    parts = []
    for c_off in (0, half):
        sl = slice(c_off, c_off + half)
        # NB the sharded ta_delta hashes (global sample, LOCAL clause):
        # the shard must present the same local clause count as the full
        # bank's ta_delta stream does per shard — here the full-bank
        # oracle is recomputed per shard for the comparison.
        ftype_loc = ops.feedback_select(y, kn, p_t, p_n, cls[sl], pol[sl],
                                        seed, c_offset=c_off)
        fire_loc = ref.clause_fire_ref(lw, iw[sl]).astype(jnp.uint8)
        d_shard = fused_train.fused_tm_train_delta(
            ta[sl], lits, lw, iw[sl], y, kn, p_t, p_n, cls[sl], pol[sl],
            seed, p_act=p_act, p_inact=1.0 / cfg.s, c_offset=c_off,
            interpret=True)
        np.testing.assert_array_equal(
            np.asarray(d_shard),
            np.asarray(ref.ta_delta_ref(ta[sl], lits, fire_loc, ftype_loc,
                                        seed, p_act=p_act,
                                        p_inact=1.0 / cfg.s)))
        parts.append(np.asarray(d_shard))
    # the selection hash is global-id-indexed, so shard 0's ftype equals
    # the full bank's left half: stitching shards reproduces full ftype
    ft_full = ops.feedback_select(y, kn, p_t, p_n, cls, pol, seed)
    ft_stitched = np.concatenate([
        np.asarray(ops.feedback_select(y, kn, p_t, p_n, cls[:half],
                                       pol[:half], seed, c_offset=0)),
        np.asarray(ops.feedback_select(y, kn, p_t, p_n, cls[half:],
                                       pol[half:], seed, c_offset=half)),
    ], axis=1)
    np.testing.assert_array_equal(np.asarray(ft_full), ft_stitched)


def test_feedback_plan_refactor_unchanged():
    """feedback_plan (probs + select split) still returns the original
    (ftype, sums) contract."""
    cfg, ta, x, y = _problem(B=7, seed=2)
    lits = tm.literals(x)
    lw = packetizer.pack_bits(lits)
    iw = packetizer.pack_include_masks(ta)
    votes = tm.vote_matrix(cfg)
    cls = jnp.clip(jnp.arange(cfg.n_clauses_total) // cfg.clauses_per_class,
                   0, cfg.n_classes - 1)
    fire = ref.clause_fire_ref(lw, iw).astype(jnp.uint8)
    seed = jnp.uint32(4)
    ftype, sums = ops.feedback_plan(fire, y, votes, cls, tm.polarity(cfg),
                                    cfg.threshold, seed)
    assert ftype.shape == fire.shape and ftype.dtype == jnp.uint8
    expect_sums = jnp.clip(fire.astype(jnp.int32) @ votes,
                           -cfg.threshold, cfg.threshold)
    np.testing.assert_array_equal(np.asarray(sums), np.asarray(expect_sums))
    assert set(np.unique(np.asarray(ftype))) <= {0, 1, 2}


def test_autotune_train_roundtrip(tmp_path, monkeypatch):
    """The training-shape autotuner memoizes under its own cache key and
    tuned blocks preserve bit-exactness of the fused step."""
    from repro.kernels import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    cands = ((128, 256, 64), (8, 128, 1))
    blocks = autotune.autotune_fused_train_blocks(
        13, 21, 2, 34, 3, interpret=True, candidates=cands, reps=1)
    assert set(blocks) == {"block_b", "block_c", "block_w"}
    again = autotune.autotune_fused_train_blocks(
        13, 21, 2, 34, 3, interpret=True, candidates=cands, reps=1)
    assert again == blocks

    cfg, ta, x, y = _problem()
    seed = jnp.uint32(6)
    _, d_o = _steps(cfg, ta, x, y, seed, use_kernel=False)
    _, d_f = _steps(cfg, ta, x, y, seed, fuse=True, blocks=blocks, **KW)
    np.testing.assert_array_equal(d_o, d_f)


def test_autotune_cache_schema_invalidation(tmp_path, monkeypatch):
    """Pre-schema (v1 flat) or corrupt cache files are invalidated on load
    instead of crashing or silently answering with stale blocks."""
    import json

    from repro.kernels import autotune

    path = tmp_path / "tune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))

    # old flat-format cache (schema 1): must be treated as empty
    path.write_text(json.dumps({
        "fused_infer:v1:cpu:interp:B1:C1:W1:K1:cands[8x128x1]":
            {"blocks": {"block_b": 999, "block_c": 999, "block_w": 999}},
    }))
    assert autotune._load_cache() == {}

    # corrupt file: also empty, no crash
    path.write_text("{not json")
    assert autotune._load_cache() == {}

    # a sweep rewrites the file with the current schema and round-trips
    cands = ((8, 128, 1),)
    blocks = autotune.autotune_fused_blocks(
        9, 17, 1, 2, interpret=True, candidates=cands, reps=1)
    on_disk = json.loads(path.read_text())
    assert on_disk["schema"] == autotune._SCHEMA_VERSION
    assert any(k.startswith("fused_infer:") for k in on_disk["entries"])
    assert autotune.autotune_fused_blocks(
        9, 17, 1, 2, interpret=True, candidates=cands, reps=1) == blocks


def test_fit_kernel_engine_matches_manual_loop():
    """train.fit(engine="kernel") reproduces the manual ops loop bit-for-bit
    (pre-shuffle + donation are pure perf changes)."""
    from repro.data import make_noisy_xor

    X, y = make_noisy_xor(120, noise=0.05, seed=11)
    cfg = tm.TMConfig(n_features=12, n_classes=2, clauses_per_class=10,
                      threshold=15, s=3.9)
    st0 = tm.init(cfg, jax.random.PRNGKey(0))
    ta0 = np.asarray(st0.ta_state)   # snapshot: fit donates st0's buffers
    rng = jax.random.PRNGKey(7)
    bs, epochs = 30, 2

    st = train.fit(cfg, st0, jnp.asarray(X), jnp.asarray(y), epochs=epochs,
                   batch_size=bs, rng=rng, engine="kernel")

    # manual replay: same shuffle stream, same per-step seeds
    ta = jnp.asarray(ta0)
    r = rng
    gstep = 0
    for ep in range(epochs):
        r, rp = jax.random.split(r)
        perm = jax.random.permutation(rp, 120)
        xs, ys = jnp.asarray(X)[perm], jnp.asarray(y)[perm]
        for i in range(120 // bs):
            r, _ = jax.random.split(r)
            ta, _d = ops.tm_train_step_kernel(
                cfg, ta, xs[i * bs:(i + 1) * bs], ys[i * bs:(i + 1) * bs],
                jnp.uint32(gstep))
            gstep += 1
    np.testing.assert_array_equal(np.asarray(st.ta_state), np.asarray(ta))
    assert int(st.steps) == gstep
