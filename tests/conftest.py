"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see 1 device;
multi-device tests spawn subprocesses with REPRO_DRYRUN_DEVICES set."""

import os

import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
