"""Gateway behavior: continuous batching, admission control, typed load
shedding, deadlines, and graceful drain — including the fault-injection
drills for ``gateway.queue_overflow`` and ``gateway.drain_timeout``.

The invariant every test closes with: the final health dict accounts for
100% of offered requests (``unaccounted == 0``) — a request is either
answered (exactly or degraded, under brownout) or shed with a typed
reason, never silently dropped:
``offered == answered_exact + answered_degraded + shed_total``.
"""

import asyncio
import time

import numpy as np
import pytest

from repro.runtime import faults
from repro.runtime.gateway import (
    DEADLINE_EXPIRED, DRAIN_TIMEOUT, ENGINE_FAILED, QUEUE_FULL,
    SHUTTING_DOWN, BrownoutController, Gateway, Response,
)

pytestmark = pytest.mark.gateway


def echo_runner(tenant, rows):
    """Pred = the request's first element (identity routing check)."""
    return np.array([int(r[0]) for r in rows])


def _go(coro):
    return asyncio.run(coro)


def _accounted(h):
    assert h["unaccounted"] == 0, h
    assert h["offered"] == h["answered"] + h["shed_total"], h
    # the brownout refinement of the same invariant: answers split into
    # exact and degraded tiers, and the tier histogram covers them all
    assert h["answered"] == h["answered_exact"] + h["answered_degraded"], h
    assert sum(h["quality_tiers"].values()) == h["answered"], h
    degraded = sum(v for k, v in h["quality_tiers"].items() if k != "0")
    assert degraded == h["answered_degraded"], h


def test_full_buckets_flush_and_route_predictions():
    async def go():
        gw = await Gateway(echo_runner, bucket=4, max_wait=5.0).start()
        futs = [gw.offer("t", np.array([i + 10])) for i in range(8)]
        res = await asyncio.gather(*futs)
        h = await gw.drain()
        return res, h

    res, h = _go(go())
    assert [r.pred for r in res] == [i + 10 for i in range(8)]
    assert all(r.ok for r in res)
    assert h["buckets"] == 2 and h["flushes"]["full"] == 2
    assert h["answered"] == 8
    assert h["latency_ms"]["p50"] is not None
    _accounted(h)


def test_age_based_flush_of_partial_bucket():
    async def go():
        gw = await Gateway(echo_runner, bucket=64, max_wait=0.02).start()
        futs = [gw.offer("t", np.array([i])) for i in range(3)]
        res = await asyncio.gather(*futs)   # resolves via the age flush
        h = await gw.drain()
        return res, h

    res, h = _go(go())
    assert [r.pred for r in res] == [0, 1, 2]
    assert h["flushes"]["age"] >= 1
    _accounted(h)


def test_bounded_queue_sheds_with_typed_reason():
    async def go():
        gw = await Gateway(echo_runner, bucket=2, max_queue=2,
                           max_wait=0.01).start()
        # no await between offers: the dispatcher cannot drain in between,
        # so admission decisions are deterministic
        futs = [gw.offer("t", np.array([i])) for i in range(5)]
        res = await asyncio.gather(*futs)
        h = await gw.drain()
        return res, h

    res, h = _go(go())
    assert [r.ok for r in res] == [True, True, False, False, False]
    assert {r.reason for r in res if not r.ok} == {QUEUE_FULL}
    assert h["shed"][QUEUE_FULL] == 3 and h["answered"] == 2
    _accounted(h)


def test_queue_overflow_fault_drill():
    """gateway.queue_overflow forces admission-time shedding even with
    queue headroom — the degraded path is a typed reject, not a drop."""
    async def go():
        gw = await Gateway(echo_runner, bucket=2, max_wait=0.01).start()
        with faults.injected("gateway.queue_overflow*2"):
            futs = [gw.offer("t", np.array([i])) for i in range(4)]
            res = await asyncio.gather(*futs)
        h = await gw.drain()
        return res, h

    res, h = _go(go())
    assert [r.ok for r in res] == [False, False, True, True]
    assert h["shed"][QUEUE_FULL] == 2
    _accounted(h)


def test_expired_deadline_rejected_never_executed():
    ran_rows = []

    def recording_runner(tenant, rows):
        ran_rows.extend(int(r[0]) for r in rows)
        return echo_runner(tenant, rows)

    async def go():
        gw = await Gateway(recording_runner, bucket=64,
                           max_wait=0.03).start()
        dead = gw.offer("t", np.array([7]), deadline=0.0)
        live = gw.offer("t", np.array([8]), deadline=30.0)
        res = await asyncio.gather(dead, live)
        h = await gw.drain()
        return res, h

    res, h = _go(go())
    assert not res[0].ok and res[0].reason == DEADLINE_EXPIRED
    assert res[1].ok and res[1].pred == 8
    assert ran_rows == [8]          # the expired request never executed
    _accounted(h)


def test_runner_failure_rejects_bucket_typed():
    class Quarantined(RuntimeError):
        shed_reason = "tenant_quarantined"

    def runner(tenant, rows):
        if tenant == "bad":
            raise Quarantined("poisoned")
        if tenant == "ugly":
            raise RuntimeError("untyped crash")
        return echo_runner(tenant, rows)

    async def go():
        gw = await Gateway(runner, bucket=2, max_wait=0.01).start()
        futs = ([gw.offer("bad", np.array([1])) for _ in range(2)]
                + [gw.offer("ugly", np.array([2])) for _ in range(2)]
                + [gw.offer("good", np.array([3])) for _ in range(2)])
        res = await asyncio.gather(*futs)
        h = await gw.drain()
        return res, h

    res, h = _go(go())
    assert {r.reason for r in res[:2]} == {"tenant_quarantined"}
    assert {r.reason for r in res[2:4]} == {ENGINE_FAILED}
    assert all(r.ok and r.pred == 3 for r in res[4:])
    assert h["tenants"]["good"]["answered"] == 2
    assert h["tenants"]["bad"]["shed"]["tenant_quarantined"] == 2
    _accounted(h)


def test_drain_flushes_partial_buckets_then_rejects_offers():
    async def go():
        gw = await Gateway(echo_runner, bucket=64, max_wait=30.0).start()
        futs = [gw.offer("t", np.array([i])) for i in range(3)]
        h = await gw.drain()                 # flush, not abandon
        res = await asyncio.gather(*futs)
        late = await gw.offer("t", np.array([9]))
        return res, h, late

    res, h, late = _go(go())
    assert all(r.ok for r in res)
    assert h["flushes"]["drain"] >= 1 and h["draining"]
    assert not late.ok and late.reason == SHUTTING_DOWN
    _accounted(h)


def test_drain_timeout_fault_drill_sheds_queued_keeps_inflight():
    """gateway.drain_timeout collapses the drain window to zero: queued
    requests shed typed, the in-flight bucket still completes."""
    def slow_runner(tenant, rows):
        time.sleep(0.15)
        return echo_runner(tenant, rows)

    async def go():
        gw = await Gateway(slow_runner, bucket=1, max_wait=0.0).start()
        futs = [gw.offer("t", np.array([i])) for i in range(3)]
        await asyncio.sleep(0.05)            # first bucket is in flight
        with faults.injected("gateway.drain_timeout"):
            h = await gw.drain()
        res = await asyncio.gather(*futs)
        return res, h

    res, h = _go(go())
    assert res[0].ok                          # in-flight bucket completed
    assert {r.reason for r in res if not r.ok} == {DRAIN_TIMEOUT}
    assert h["answered"] >= 1
    assert h["shed"][DRAIN_TIMEOUT] == len(res) - h["answered"]
    _accounted(h)


def test_tenants_batch_independently():
    seen = []

    def runner(tenant, rows):
        seen.append((tenant, len(rows)))
        return echo_runner(tenant, rows)

    async def go():
        gw = await Gateway(runner, bucket=2, max_wait=5.0).start()
        futs = []
        for i in range(2):
            futs.append(gw.offer("a", np.array([i])))
            futs.append(gw.offer("b", np.array([10 + i])))
        res = await asyncio.gather(*futs)
        h = await gw.drain()
        return res, h

    res, h = _go(go())
    assert sorted(seen) == [("a", 2), ("b", 2)]   # never mixed in a bucket
    assert [r.pred for r in res] == [0, 10, 1, 11]
    assert set(h["tenants"]) == {"a", "b"}
    _accounted(h)


# -- brownout / anytime quality tiers (satellite of the anytime PR) ----------


class _ScriptedBrownout(BrownoutController):
    """Controller whose update() replays a fixed level script — makes
    mixed exact/degraded traffic deterministic regardless of timing."""

    def __init__(self, levels):
        super().__init__()
        self._levels = list(levels)

    def update(self, pressure):
        self.evals += 1
        if self._levels:
            self.level = self._levels.pop(0)
        return self.level


def quality_runner(tenant, rows, quality=0):
    """Quality-aware echo runner: degraded buckets report a vote bound."""
    preds = np.array([int(r[0]) for r in rows])
    info = dict(quality=int(quality),
                err_bound=16 * int(quality) if quality else None)
    return preds, info


@pytest.mark.anytime
def test_mixed_exact_degraded_shed_accounting():
    """offered == answered_exact + answered_degraded + shed_total under
    traffic that hits all three outcomes; degraded responses carry the
    served quality level and its concrete err_bound."""
    async def go():
        gw = await Gateway(quality_runner, bucket=2, max_queue=6,
                           max_wait=0.01,
                           brownout=_ScriptedBrownout([0, 2, 1])).start()
        futs = [gw.offer("t", np.array([i])) for i in range(8)]
        res = await asyncio.gather(*futs)
        h = await gw.drain()
        return res, h

    res, h = _go(go())
    shed = [r for r in res if not r.ok]
    assert len(shed) == 2 and {r.reason for r in shed} == {QUEUE_FULL}
    served = [r for r in res if r.ok]
    assert sorted(r.quality for r in served) == [0, 0, 1, 1, 2, 2]
    for r in served:
        if r.quality == 0:
            assert r.err_bound is None
        else:
            assert r.err_bound == 16 * r.quality   # bound travels with it
        assert r.pred is not None                  # degraded != unanswered
    assert h["answered_exact"] == 2 and h["answered_degraded"] == 4
    assert h["quality_tiers"] == {"0": 2, "1": 2, "2": 2}
    assert h["shed"][QUEUE_FULL] == 2
    assert h["brownout"]["evals"] == 3
    _accounted(h)


@pytest.mark.anytime
def test_plain_runner_under_brownout_stays_exact():
    """Degradation is opt-in: a runner without a quality kwarg serves
    exact answers even when the controller demands level 3."""
    async def go():
        gw = await Gateway(echo_runner, bucket=4, max_wait=0.01,
                           brownout=_ScriptedBrownout([3])).start()
        futs = [gw.offer("t", np.array([i])) for i in range(4)]
        res = await asyncio.gather(*futs)
        h = await gw.drain()
        return res, h

    res, h = _go(go())
    assert all(r.ok and r.quality == 0 and r.err_bound is None for r in res)
    assert h["answered_exact"] == 4 and h["answered_degraded"] == 0
    assert h["quality_tiers"] == {"0": 4}
    _accounted(h)


@pytest.mark.anytime
def test_brownout_deadline_attribution_unchanged():
    """An expired request under brownout is still shed deadline_expired —
    never served degraded, never silently dropped."""
    ran_rows = []

    def runner(tenant, rows, quality=0):
        ran_rows.extend(int(r[0]) for r in rows)
        return quality_runner(tenant, rows, quality)

    async def go():
        gw = await Gateway(runner, bucket=64, max_wait=0.03,
                           brownout=_ScriptedBrownout([2])).start()
        dead = gw.offer("t", np.array([7]), deadline=0.0)
        live = gw.offer("t", np.array([8]), deadline=30.0)
        res = await asyncio.gather(dead, live)
        h = await gw.drain()
        return res, h

    res, h = _go(go())
    assert not res[0].ok and res[0].reason == DEADLINE_EXPIRED
    assert res[0].quality == 0 and res[0].err_bound is None
    assert res[1].ok and res[1].pred == 8 and res[1].quality == 2
    assert ran_rows == [8]
    assert h["shed"][DEADLINE_EXPIRED] == 1 and h["answered_degraded"] == 1
    _accounted(h)


@pytest.mark.anytime
def test_brownout_real_controller_escalates_under_queue_pressure():
    """Integration: a real controller sees the backlog of the first flush
    (pending/max_queue = 0.5 -> level 1), then calm (-> back to 0)."""
    async def go():
        gw = await Gateway(quality_runner, bucket=4, max_queue=8,
                           max_wait=5.0,
                           brownout=BrownoutController()).start()
        futs = [gw.offer("t", np.array([i])) for i in range(8)]
        res = await asyncio.gather(*futs)
        h = await gw.drain()
        return res, h

    res, h = _go(go())
    assert all(r.ok for r in res)
    # first bucket flushes with 4 still queued -> pressure 0.5 -> level 1;
    # second bucket flushes an empty queue -> pressure 0 -> step down
    assert h["quality_tiers"] == {"0": 4, "1": 4}
    assert h["brownout"]["escalations"] == 1
    assert h["brownout"]["stepdowns"] == 1
    assert [r.err_bound for r in res[:4]] == [16] * 4
    _accounted(h)


def test_health_mid_stream_counts_queued_as_unaccounted():
    """A non-final health snapshot exposes in-queue work as unaccounted;
    the FINAL (post-drain) health must always read zero."""
    async def go():
        gw = await Gateway(echo_runner, bucket=64, max_wait=30.0).start()
        futs = [gw.offer("t", np.array([i])) for i in range(3)]
        mid = gw.health()
        h = await gw.drain()
        await asyncio.gather(*futs)
        return mid, h

    mid, h = _go(go())
    assert mid["unaccounted"] == 3 and mid["queue_depth"] == 3
    assert h["unaccounted"] == 0 and h["queue_depth"] == 0
