"""The analytical autotuner cost model: feature extraction + artifact
persistence, the observation sidecar (atomicity, cap, concurrency), the
ridge refit, and predictor regret on canned artifacts spanning the
sparsity/sharing range."""

from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import compiler, tm
from repro.kernels import autotune, cost_model

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture()
def tune_env(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    monkeypatch.setenv("REPRO_TUNE_DATA", str(tmp_path / "data.json"))
    cost_model._invalidate_model_cache()
    yield tmp_path
    cost_model._invalidate_model_cache()


def _random_tm(n_features, n_classes, cpc, include_density, seed):
    rng = np.random.default_rng(seed)
    C = n_classes * cpc
    ta = np.where(
        rng.random((C, 2 * n_features)) < include_density,
        rng.integers(0, 127, (C, 2 * n_features)),
        rng.integers(-128, 0, (C, 2 * n_features)),
    ).astype(np.int8)
    cfg = tm.TMConfig(n_features=n_features, n_classes=n_classes,
                      clauses_per_class=cpc)
    return cfg, ta


def _shared_tm():
    """High term-sharing bank: every clause carries the same two-word core."""
    cfg = tm.TMConfig(n_features=64, n_classes=2, clauses_per_class=8)
    C, L = 16, 128
    ta = np.full((C, L), -5, np.int8)
    ta[:, 3] = 3
    ta[:, 40] = 3
    for c in range(C):
        ta[c, 64 + ((c * 4) % 64)] = 3
    return cfg, ta


# ---------------------------------------------------------------------------
# Features
# ---------------------------------------------------------------------------

def test_artifact_features_contents():
    cfg, ta = _random_tm(48, 3, 8, 0.10, 4)
    comp = compiler.compile_tm(cfg, ta)
    feats = comp.extract_features()
    assert feats["schema"] == cost_model.FEATURE_SCHEMA_VERSION
    assert feats["n_rows"] == comp.include_words.shape[0]
    assert 0.0 < feats["include_density"] < 1.0
    assert feats["chain_max"] >= feats["chain_mean"] > 0
    assert feats["hlo_flops_per_sample"] > 0
    assert feats["hlo_bytes_per_sample"] > 0
    assert feats["roofline_t_comp"] >= 0
    # second call answers from the memo, not a re-lowering
    assert comp.extract_features() == feats


def test_features_save_load_roundtrip(tmp_path):
    cfg, ta = _random_tm(32, 2, 6, 0.12, 5)
    comp = compiler.compile_tm(cfg, ta)
    feats = comp.extract_features()
    path = str(tmp_path / "artifact.npz")
    comp.save(path)
    loaded = compiler.CompiledTM.load(path)
    assert set(loaded.features) == set(feats)
    for k, v in feats.items():
        assert loaded.features[k] == pytest.approx(v), k


def test_hlo_and_roofline_smoke():
    """launch/hlo_analysis + launch/roofline drive the feature pipeline on
    the pinned jax — an import-and-run smoke so version drift fails here,
    not deep inside a tuning run."""
    from repro import jax_compat
    from repro.launch import hlo_analysis, roofline  # noqa: F401

    feats = cost_model.hlo_forward_features(16, 2, 3, batch=8)
    assert feats["hlo_flops_per_sample"] > 0
    assert feats["hlo_bytes_per_sample"] > 0
    assert feats["roofline_t_mem"] > 0

    def f(a, b):
        return a @ b

    compiled = jax_compat.lower_compiled(
        f, jnp.ones((4, 4), jnp.float32), jnp.ones((4, 4), jnp.float32))
    cost = hlo_analysis.analyze(compiled.as_text())
    assert cost.flops > 0
    ca = jax_compat.cost_analysis(compiled)
    assert ca is None or isinstance(ca, dict)


# ---------------------------------------------------------------------------
# Sidecar
# ---------------------------------------------------------------------------

def test_sidecar_roundtrip_and_cap(tune_env):
    rows = [cost_model.make_observation(
        "fused_infer", "cpu:interp", {"block_b": 8}, {"steps": float(i)},
        10.0 + i) for i in range(10)]
    cost_model.record_observations(rows)
    back = cost_model.load_observations()
    assert len(back) == 10
    assert back[0]["basis"] == {"steps": 0.0}
    # FIFO cap: a flood keeps only the newest _MAX_OBSERVATIONS
    flood = [cost_model.make_observation(
        "fused_infer", "cpu:interp", {"block_b": 8}, {"steps": 1.0}, 1.0)
        for _ in range(cost_model._MAX_OBSERVATIONS + 50)]
    cost_model.record_observations(flood)
    assert len(cost_model.load_observations()) == cost_model._MAX_OBSERVATIONS


def test_sidecar_corrupt_file_treated_as_empty(tune_env):
    (tune_env / "data.json").write_text("{torn write")
    assert cost_model.load_observations() == []
    cost_model.record_observations([cost_model.make_observation(
        "fused_infer", "cpu:interp", {}, {"steps": 1.0}, 5.0)])
    assert len(cost_model.load_observations()) == 1


_SIDECAR_PROC = """
import os, sys
os.environ["JAX_PLATFORMS"] = "cpu"
from repro.kernels import cost_model
i = int(sys.argv[1])
for j in range(20):
    cost_model.record_observations([cost_model.make_observation(
        "fused_infer", "cpu:interp", {"block_b": i},
        {"steps": float(j)}, 1.0 + j)])
print("WROTE", i)
"""


def test_sidecar_concurrent_writers(tmp_path):
    """N processes appending observations to the same $REPRO_TUNE_DATA:
    the atomic tmp+os.replace write means the file is ALWAYS valid JSON
    with the current schema — interleaved appends may drop rows
    (last-writer-wins per flush) but never tear the file."""
    data = tmp_path / "data.json"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               REPRO_TUNE_DATA=str(data), JAX_PLATFORMS="cpu")
    procs = [
        subprocess.Popen([sys.executable, "-c", _SIDECAR_PROC, str(i)],
                         env=env, stdout=subprocess.PIPE,
                         stderr=subprocess.PIPE, text=True)
        for i in range(4)
    ]
    for p in procs:
        out, err = p.communicate(timeout=600)
        assert p.returncode == 0, out + err
        assert "WROTE" in out

    raw = json.loads(data.read_text())       # parses: never torn
    assert raw["schema"] == cost_model._DATA_SCHEMA
    assert len(raw["observations"]) >= 20    # at least one writer's rows
    for row in raw["observations"]:          # every row structurally whole
        assert row["kernel"] == "fused_infer"
        assert isinstance(row["basis"], dict)
        assert isinstance(row["measured_us"], float)
    assert [f.name for f in tmp_path.iterdir()] == ["data.json"]


# ---------------------------------------------------------------------------
# Fit
# ---------------------------------------------------------------------------

def _obs(kernel, mode, steps, work, us):
    return cost_model.make_observation(
        kernel, mode, {"block_b": 8},
        {"steps": steps, "work_melem": work}, us)


def test_fit_recovers_linear_model():
    rng = np.random.default_rng(0)
    rows = []
    for _ in range(40):
        steps = float(rng.integers(1, 200))
        work = float(rng.random() * 10)
        rows.append(_obs("fused_infer", "cpu:interp", steps, work,
                         100.0 + 5.0 * steps + 30.0 * work))
    model = cost_model.CostModel().fit(rows, "cpu:interp", ridge=1e-6)
    theta = model.coeffs["fused_infer"]
    assert theta["steps"] == pytest.approx(5.0, rel=0.1)
    assert theta["work_melem"] == pytest.approx(30.0, rel=0.1)
    # prediction ranks a cheap tiling above an expensive one
    ranked = model.rank("fused_infer", [
        ((1,), {"steps": 500.0, "work_melem": 1.0}),
        ((2,), {"steps": 5.0, "work_melem": 1.0}),
    ])
    assert ranked[0][0] == (2,)


def test_fit_ignores_other_modes_and_small_samples():
    base = cost_model.CostModel()
    other = [_obs("fused_infer", "tpu:compiled", 10.0, 1.0, 1e9)
             for _ in range(50)]
    refit = base.fit(other, "cpu:interp")
    assert refit.coeffs == base.coeffs      # zero same-mode rows: unchanged
    few = [_obs("fused_infer", "cpu:interp", float(i), 0.0, float(i))
           for i in range(cost_model.MIN_FIT_ROWS - 1)]
    refit = base.fit(few, "cpu:interp")
    assert refit.coeffs == base.coeffs      # below MIN_FIT_ROWS: unchanged


def test_fit_clips_negative_weights():
    # adversarial data where OLS would go negative on `steps`
    rows = [_obs("fused_infer", "cpu:interp", s, w, 1000.0 - s)
            for s, w in [(float(i), float(i * 2)) for i in range(1, 20)]]
    model = cost_model.CostModel().fit(rows, "cpu:interp")
    assert all(v >= 0.0 for v in model.coeffs["fused_infer"].values())


def test_get_model_refits_after_new_observations(tune_env):
    m0 = cost_model.get_model("cpu:interp")
    assert m0.coeffs == cost_model.DEFAULT_COEFFS
    rows = [_obs("fused_infer", "cpu:interp", float(i), float(i % 3),
                 50.0 + 2.0 * i) for i in range(30)]
    cost_model.record_observations(rows)    # invalidates the memo
    m1 = cost_model.get_model("cpu:interp")
    assert m1.coeffs["fused_infer"] != cost_model.DEFAULT_COEFFS["fused_infer"]


# ---------------------------------------------------------------------------
# Predictor regret on canned artifacts (low/high sparsity and sharing)
# ---------------------------------------------------------------------------

_REGRET_CANDS = ((512, 32, 16), (64, 8, 2), (256, 32, 8), (128, 16, 4),
                 (512, 64, 16))


@pytest.mark.parametrize("maker,label", [
    (lambda: _random_tm(48, 3, 12, 0.04, 1), "low_density"),
    (lambda: _random_tm(64, 4, 16, 0.20, 2), "high_density"),
    (_shared_tm, "high_sharing"),
])
def test_predictor_regret_canned_artifact(tune_env, maker, label):
    """Analytical top-1 regret vs a full wall-clock sweep, per artifact.
    Interpret-mode timings on a busy CI box are noisy, so the bound is
    spread-aware: when the candidates genuinely differ (spread > 50%),
    the predicted pick must capture at least half the spread; tighter
    shapes only require staying under 75% regret."""
    cfg, ta = maker()
    comp = compiler.compile_tm(cfg, ta)

    # predict FIRST (defaults only — nothing measured on this shape yet)
    before = autotune.TIMING_RUNS
    ranked = autotune.rank_candidates(
        "sparse_infer", B=64, K=comp.n_classes,
        include_words=comp.include_words, interpret=True,
        candidates=_REGRET_CANDS)
    assert autotune.TIMING_RUNS == before
    pred = tuple(sorted(ranked[0][0].items()))

    # then ground-truth sweep, timings via the sidecar rows it logs
    autotune.tune("sparse_infer", B=64, K=comp.n_classes,
                  include_words=comp.include_words, interpret=True,
                  policy="sweep", candidates=_REGRET_CANDS, reps=3,
                  refresh=True)
    timings = {tuple(sorted(r["blocks"].items())): r["measured_us"]
               for r in cost_model.load_observations()
               if r["kernel"] == "sparse_infer"}
    assert pred in timings
    best, worst = min(timings.values()), max(timings.values())
    regret = timings[pred] / best - 1.0
    spread = worst / best - 1.0
    assert regret <= max(0.75, 0.5 * spread), (
        f"{label}: regret {regret:.2f} spread {spread:.2f} "
        f"pred {pred} timings {timings}")
