"""Substrate tests: optimizer, checkpointing, data pipeline, runtime."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.data import ShardedBatcher, make_boolean_classification, thermometer_encode
from repro.data.booleanize import quantile_binarize
from repro.optim import adamw
from repro.runtime import PreemptionHandler, StragglerMonitor


# -- optimizer ---------------------------------------------------------------

def test_adamw_reduces_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=1, decay_steps=100, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw.adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, opt, info = adamw.adamw_update(cfg, g, params, opt)
    assert float(loss(params)) < 0.05
    assert int(opt.step) == 60


def test_grad_clipping():
    cfg = adamw.AdamWConfig(clip_norm=1.0)
    g = {"w": jnp.asarray([1e6, 1e6])}
    params = {"w": jnp.zeros(2)}
    opt = adamw.adamw_init(params)
    _, _, info = adamw.adamw_update(cfg, g, params, opt)
    assert float(info["grad_norm"]) > 1e5  # reported pre-clip


def test_lr_warmup():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, decay_steps=100)
    assert float(adamw._schedule(cfg, jnp.int32(1))) < 0.2
    assert float(adamw._schedule(cfg, jnp.int32(10))) >= 0.99


# -- gradient compression (single-shard semantics) ---------------------------

def test_compression_error_feedback_roundtrip():
    from repro.optim import compress

    # on one device use shard_map over a 1-device mesh axis
    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)), jnp.float32)}
    err = compress.init_error(g)

    def f(g, e):
        return compress.compressed_allreduce(g, e, "data")

    from jax.sharding import PartitionSpec as P

    from repro import jax_compat

    out, new_err = jax.jit(
        jax_compat.shard_map(f, mesh=mesh, in_specs=(P(), P()),
                             out_specs=(P(), P()), check_vma=False)
    )(g, err)
    # quantized value + residual reconstructs the original exactly
    np.testing.assert_allclose(
        np.asarray(out["w"] + new_err["w"]), np.asarray(g["w"]), atol=1e-6
    )
    # 8-bit quantization error bounded by scale
    scale = float(jnp.abs(g["w"]).max()) / 127.0
    assert float(jnp.abs(new_err["w"]).max()) <= scale * 0.5 + 1e-6


# -- checkpointing ------------------------------------------------------------

def test_checkpoint_roundtrip_and_retention():
    tree = {"a": jnp.arange(5, dtype=jnp.float32), "b": {"c": jnp.ones((2, 3))}}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, max_to_keep=2)
        for step in (1, 2, 3):
            mgr.save(step, tree, extra={"step": step})
        mgr.wait()
        assert mgr.latest_step() == 3
        assert sorted(os.listdir(d)) == ["step_0000000002", "step_0000000003"]
        restored, extra = mgr.restore(tree)
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(5))
        assert extra["step"] == 3


def test_checkpoint_async_and_atomic():
    tree = {"w": jnp.zeros((100, 100))}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(7, tree, blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 7
        assert not any(p.endswith(".tmp") for p in os.listdir(d))


def test_checkpoint_elastic_restore_with_sharding():
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    shardings = {"w": NamedSharding(mesh, P("data"))}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, tree)
        restored, _ = load_checkpoint(d, tree, shardings=shardings)
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(8))
        assert restored["w"].sharding == shardings["w"]


# -- data pipeline ------------------------------------------------------------

def test_loader_deterministic_and_resumable():
    X = np.arange(100)[:, None]
    y = np.arange(100)
    a = ShardedBatcher((X, y), 10, seed=3, prefetch=0)
    it = iter(a)
    seen = [next(it)[1] for _ in range(7)]
    state = a.state_dict()

    b = ShardedBatcher((X, y), 10, seed=3, prefetch=0)
    b.load_state_dict(state)
    nxt_a = next(it)[1]
    nxt_b = next(iter(b))[1]
    np.testing.assert_array_equal(nxt_a, nxt_b)


def test_loader_process_sharding_partitions():
    X = np.arange(64)[:, None]
    y = np.arange(64)
    seen = set()
    for pi in range(4):
        l = ShardedBatcher((X, y), 4, shuffle=False, process_index=pi,
                           process_count=4, prefetch=0)
        it = iter(l)
        for _ in range(4):
            seen.update(next(it)[1].tolist())
    assert seen == set(range(64))


def test_loader_prefetch_thread():
    X = np.arange(32)[:, None]
    y = np.arange(32)
    l = ShardedBatcher((X, y), 8, prefetch=2)
    it = iter(l)
    batches = [next(it) for _ in range(6)]  # crosses an epoch boundary
    assert all(b[0].shape == (8, 1) for b in batches)


def _check_thermometer_monotone(n_bits):
    x = np.random.default_rng(0).normal(size=(20, 3))
    th = thermometer_encode(x, n_bits=n_bits).reshape(20, 3, n_bits)
    # thermometer property: once a bit is 0, all higher bits are 0
    diffs = np.diff(th.astype(int), axis=-1)
    assert (diffs <= 0).all()


if HAVE_HYPOTHESIS:
    @pytest.mark.hypothesis_optional
    @settings(max_examples=15, deadline=None)
    @given(st.integers(2, 6))
    def test_thermometer_monotone(n_bits):
        _check_thermometer_monotone(n_bits)


@pytest.mark.parametrize("n_bits", [2, 3, 6])
def test_thermometer_monotone_fixed(n_bits):
    _check_thermometer_monotone(n_bits)


def test_quantile_binarize_shape():
    x = np.random.default_rng(0).normal(size=(50, 4))
    q = quantile_binarize(x, n_bits=3)
    assert q.shape == (50, 12)
    assert set(np.unique(q)) <= {0, 1}


def test_synthetic_is_learnable_by_construction():
    X, y = make_boolean_classification(500, 64, 4, seed=0)
    # class prototypes make same-class samples more similar
    same = ((X[y == 0][:10, None] == X[y == 0][None, :10]).mean())
    diff = ((X[y == 0][:10, None] == X[y == 1][None, :10]).mean())
    assert same > diff


# -- runtime -------------------------------------------------------------------

def test_straggler_monitor_flags_slow_step():
    import time

    mon = StragglerMonitor(threshold=3.0, warmup=2)
    for s in range(6):
        mon.start_step()
        time.sleep(0.002)
        mon.end_step(s)
    mon.start_step()
    time.sleep(0.05)
    flagged = mon.end_step(6)
    assert flagged is not None and flagged["step"] == 6
    assert mon.events


def test_straggler_monitor_back_to_back_stragglers_both_flag():
    """Flagged outliers must not fold into the EWMA: the second of two
    consecutive stragglers used to compare against a baseline poisoned by
    the first and slip under the threshold."""
    import time

    mon = StragglerMonitor(alpha=0.5, threshold=3.0, warmup=1)

    def step(idx, dt):
        mon.start_step()
        mon._t0 = time.monotonic() - dt      # simulate a dt-second step
        return mon.end_step(idx)

    for s in range(4):
        assert step(s, 0.01) is None         # healthy baseline ~10ms
    ewma_before = mon.ewma
    first = step(4, 0.5)
    second = step(5, 0.5)                    # back-to-back straggler
    assert first is not None and second is not None
    assert [e["step"] for e in mon.events] == [4, 5]
    # the baseline still tracks the healthy distribution
    assert mon.ewma == ewma_before


def test_preemption_handler_flag():
    h = PreemptionHandler()
    assert not h.preempted
    h.trigger()
    assert h.preempted


def test_preemption_nested_install_chains_and_unwinds():
    """install() chains to the previous handler (both flags flip) and
    uninstall() unwinds like a stack, restoring what was there before."""
    import os
    import signal

    before = signal.getsignal(signal.SIGTERM)
    outer = PreemptionHandler(signals=(signal.SIGTERM,)).install()
    inner = PreemptionHandler(signals=(signal.SIGTERM,)).install()
    try:
        os.kill(os.getpid(), signal.SIGTERM)
        # chained delivery: the inner handler ran AND forwarded to outer
        assert inner.preempted and outer.preempted
        # idempotent: re-install without uninstall is a no-op
        handler_now = signal.getsignal(signal.SIGTERM)
        inner.install()
        assert signal.getsignal(signal.SIGTERM) is handler_now
    finally:
        inner.uninstall()
        outer_handler = signal.getsignal(signal.SIGTERM)
        outer.uninstall()
    # after the inner unwind, only the outer flag flips on a new signal
    assert callable(outer_handler)
    # fully unwound: the pre-test handler is back, and a never-installed
    # handler uninstalls as a no-op
    assert signal.getsignal(signal.SIGTERM) is before
    PreemptionHandler().uninstall()
