"""Per-architecture smoke tests: reduced same-family configs, one train step
and two decode steps on CPU, asserting shapes and finiteness (assignment
requirement f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import steps, transformer
from repro.optim import adamw

RNG = np.random.default_rng(0)


def _batch(cfg, B, S):
    tokens = RNG.integers(0, cfg.vocab_size, (B, S + 1))
    if cfg.frontend == "audio_stub":
        return {
            "embeds": jnp.asarray(RNG.normal(size=(B, S, cfg.d_model)), jnp.float32),
            "labels": jnp.asarray(
                RNG.integers(0, cfg.vocab_size, (B, S, cfg.n_codebooks)), jnp.int32
            ),
        }
    if cfg.frontend == "vision_stub":
        si = S // 4
        return {
            "embeds": jnp.asarray(RNG.normal(size=(B, si, cfg.d_model)), jnp.float32),
            "tokens": jnp.asarray(tokens[:, : S - si], jnp.int32),
            "labels": jnp.asarray(tokens[:, 1 : S - si + 1], jnp.int32),
        }
    return {
        "tokens": jnp.asarray(tokens[:, :-1], jnp.int32),
        "labels": jnp.asarray(tokens[:, 1:], jnp.int32),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.adamw_init(params)
    step = jax.jit(steps.make_train_step(cfg))
    B, S = 2, 16
    p2, o2, info = step(params, opt, _batch(cfg, B, S))
    loss = float(info["loss"])
    assert np.isfinite(loss) and loss > 0, loss
    assert int(o2.step) == 1
    # params actually changed
    delta = sum(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).sum())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_steps(arch):
    cfg = get_smoke_config(arch)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    B, S_max = 2, 32
    caches = transformer.init_caches(cfg, B, S_max)
    decode = jax.jit(steps.make_decode_step(cfg))
    if cfg.frontend == "audio_stub":
        inp = {"embeds": jnp.asarray(RNG.normal(size=(B, 1, cfg.d_model)), jnp.float32)}
    else:
        inp = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    logits, caches = decode(params, caches, inp, jnp.int32(0))
    logits2, caches = decode(params, caches, inp, jnp.int32(1))
    assert logits.shape == (B, cfg.vocab_size * cfg.n_codebooks)
    assert np.isfinite(np.asarray(logits2)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """Full configs carry the exact assigned dimensions (never instantiated
    on CPU — the dry-run exercises them via ShapeDtypeStruct)."""
    cfg = get_config(arch)
    assigned = {
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == assigned, (got, assigned)


def test_moe_configs():
    ds = get_config("deepseek-v2-236b")
    assert (ds.n_experts, ds.top_k, ds.n_shared_experts) == (160, 6, 2)
    assert (ds.attn_kind, ds.kv_lora) == ("mla", 512)
    qw = get_config("qwen3-moe-235b-a22b")
    assert (qw.n_experts, qw.top_k) == (128, 8)


def test_subquadratic_flags():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        expect = arch in ("recurrentgemma-2b", "xlstm-1.3b")
        assert cfg.subquadratic == expect, arch
