"""Fault-tolerance drills: every recovery behavior the runtime claims is
exercised by arming a fault site (runtime/faults.py) and asserting the
system degrades the way it promises.

  * artifact integrity — bit-flips, stale schemas, truncation, tampered
    schedules and checksum mismatches are REJECTED at load; an aborted
    save never clobbers the previous artifact;
  * serve degradation ladder — injected kernel failures demote
    factorized -> sparse -> dense -> oracle and the stream completes;
    slow buckets trip the ``--bucket-deadline`` demotion;
  * preemption-safe training — SIGTERM mid-run exits with
    RESUME_EXIT_CODE, restarts resume from the checkpoint, and the final
    model is bit-identical to an uninterrupted run (deterministic
    hash-RNG training + consumed-position loader state);
  * checkpoint substrate — async write failures surface instead of being
    swallowed; stale ``step_*.tmp`` debris is cleaned; malformed entries
    never crash ``latest_step``/gc.

The module is marked ``faults`` so CI's drill job selects it with
``-m faults``; the tests also run (unmarked selection) in tier-1.
"""

import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core import compiler, tm, train
from repro.data import ShardedBatcher, make_boolean_classification
from repro.kernels import ops
from repro.runtime import RESUME_EXIT_CODE, faults

pytestmark = pytest.mark.faults

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"), JAX_PLATFORMS="cpu")
ENV.pop("REPRO_FAULT_INJECT", None)


def _run(code_or_argv, env_extra=None, timeout=600):
    env = dict(ENV, **(env_extra or {}))
    argv = ([sys.executable, "-c", code_or_argv]
            if isinstance(code_or_argv, str) else
            [sys.executable] + code_or_argv)
    return subprocess.run(argv, env=env, capture_output=True, text=True,
                          timeout=timeout)


# --------------------------------------------------------------------------
# kill / resume (the original end-to-end drill, explicit step loop)
# --------------------------------------------------------------------------

def _train(steps, ckpt_dir, out_npy):
    code = f"""
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import CheckpointManager
from repro.configs.matador_tm import TM_CONFIGS
from repro.core import tm
from repro.data import ShardedBatcher, make_boolean_classification
from repro.kernels import ops

config = tm.TMConfig(n_features=32, n_classes=3, clauses_per_class=8)
X, y = make_boolean_classification(512, 32, 3, seed=0)
mgr = CheckpointManager({ckpt_dir!r}, max_to_keep=2)
state = tm.init(config, jax.random.PRNGKey(0))
ta = state.ta_state
loader = ShardedBatcher((X, y), 32, seed=1, prefetch=0)
start = 0
if mgr.latest_step() is not None:
    restored, extra = mgr.restore({{"ta": np.asarray(ta)}})
    ta = jnp.asarray(restored["ta"])
    loader.load_state_dict(extra["loader"])
    start = extra["step"]
it = iter(loader)
for step in range(start, {steps}):
    xb, yb = next(it)
    ta, _ = ops.tm_train_step_kernel(config, ta, jnp.asarray(xb), jnp.asarray(yb), jnp.uint32(step))
    mgr.save(step + 1, {{"ta": np.asarray(ta)}},
             extra={{"step": step + 1, "loader": loader.state_dict()}})
mgr.wait()
np.save({out_npy!r}, np.asarray(ta))
"""
    r = _run(code)
    assert r.returncode == 0, r.stdout + r.stderr


def test_kill_and_resume_is_bit_identical():
    with tempfile.TemporaryDirectory() as d:
        ref = os.path.join(d, "ref.npy")
        _train(12, os.path.join(d, "ckpt_ref"), ref)

        ck = os.path.join(d, "ckpt_resume")
        part = os.path.join(d, "part.npy")
        _train(7, ck, part)              # "preempted" after step 7
        fin = os.path.join(d, "fin.npy")
        _train(12, ck, fin)              # restart resumes from step 7

        np.testing.assert_array_equal(np.load(ref), np.load(fin))


def test_resume_skips_completed_steps():
    with tempfile.TemporaryDirectory() as d:
        ck = os.path.join(d, "ckpt")
        out = os.path.join(d, "a.npy")
        _train(5, ck, out)
        steps = sorted(os.listdir(ck))
        assert steps[-1] == "step_0000000005"


# --------------------------------------------------------------------------
# fault-injection harness itself
# --------------------------------------------------------------------------

def test_fault_spec_grammar():
    specs = faults.parse_spec(
        "train.sigterm@7, serve.slow_bucket@3:0.5, kernel.dense*2")
    assert [s.site for s in specs] == [
        "train.sigterm", "serve.slow_bucket", "kernel.dense"]
    assert specs[0].step == 7 and specs[0].param is None
    assert specs[1].step == 3 and specs[1].param == 0.5
    assert specs[2].count == 2
    with pytest.raises(ValueError, match="unknown fault site"):
        faults.parse_spec("no.such.site")


def test_fault_injector_count_and_step_gating():
    inj = faults.FaultInjector(faults.parse_spec("kernel.dense*2"))
    assert inj.poll("kernel.dense") is not None
    assert inj.poll("kernel.dense") is not None
    assert inj.poll("kernel.dense") is None          # count exhausted
    inj = faults.FaultInjector(faults.parse_spec("train.sigterm@7"))
    assert inj.poll("train.sigterm", step=6) is None
    assert inj.poll("train.sigterm") is None         # no step at call site
    assert inj.poll("train.sigterm", step=7) is not None


def test_injected_context_scopes_arming():
    assert not faults.armed()
    with faults.injected("kernel.dense"):
        assert faults.armed()
        with pytest.raises(faults.InjectedFault):
            faults.raise_if("kernel.dense")
    assert not faults.armed()
    faults.raise_if("kernel.dense")                  # disarmed: no-op


# --------------------------------------------------------------------------
# artifact integrity
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_compiled():
    config = tm.TMConfig(n_features=32, n_classes=3, clauses_per_class=8)
    X, y = make_boolean_classification(256, 32, 3, seed=0)
    state = tm.init(config, jax.random.PRNGKey(0))
    state = train.fit(config, state, jnp.asarray(X), jnp.asarray(y),
                      epochs=1, batch_size=32, rng=jax.random.PRNGKey(1))
    return config, compiler.compile_tm(config, state.ta_state)


def _rewrite(path, mutate, fix_checksum=True):
    """Re-write an artifact with a mutation; optionally re-sign it so the
    mutation exercises the layer BEHIND the checksum (validate_artifact)."""
    z = np.load(path)
    meta = json.loads(bytes(z["meta"]).decode())
    arrays = {k: np.array(z[k]) for k in z.files if k != "meta"}
    mutate(arrays, meta)
    if fix_checksum:
        meta.pop("checksum", None)
        meta["checksum"] = compiler._artifact_checksum(arrays, meta)
    with open(path, "wb") as f:
        np.savez_compressed(
            f, meta=np.frombuffer(json.dumps(meta).encode(), np.uint8),
            **arrays)


def test_artifact_roundtrip_is_verified(tiny_compiled, tmp_path):
    _, compiled = tiny_compiled
    path = compiled.save(str(tmp_path / "art.npz"))
    again = compiler.CompiledTM.load(path)
    np.testing.assert_array_equal(again.votes, compiled.votes)
    np.testing.assert_array_equal(again.include_words, compiled.include_words)


def test_artifact_bitflip_rejected(tiny_compiled, tmp_path):
    _, compiled = tiny_compiled
    with faults.injected("artifact.bitflip"):
        path = compiled.save(str(tmp_path / "art.npz"))
    with pytest.raises(compiler.ArtifactError):
        compiler.CompiledTM.load(path)


def test_artifact_stale_schema_rejected(tiny_compiled, tmp_path):
    _, compiled = tiny_compiled
    path = compiled.save(str(tmp_path / "art.npz"))
    _rewrite(path, lambda arrays, meta: meta.update(schema=0))
    with pytest.raises(compiler.ArtifactError, match="schema version 0"):
        compiler.CompiledTM.load(path)


def test_artifact_checksum_mismatch_rejected(tiny_compiled, tmp_path):
    _, compiled = tiny_compiled
    path = compiled.save(str(tmp_path / "art.npz"))

    def flip_votes(arrays, meta):
        arrays["votes"] = arrays["votes"] + 1

    _rewrite(path, flip_votes, fix_checksum=False)
    with pytest.raises(compiler.ArtifactError, match="checksum"):
        compiler.CompiledTM.load(path)


def test_artifact_truncated_rejected(tiny_compiled, tmp_path):
    _, compiled = tiny_compiled
    path = compiled.save(str(tmp_path / "art.npz"))
    blob = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(blob[: len(blob) // 2])
    with pytest.raises(compiler.ArtifactError, match="unreadable"):
        compiler.CompiledTM.load(path)


def test_artifact_tampered_schedule_rejected(tiny_compiled, tmp_path):
    # a correctly-signed artifact with OUT-OF-RANGE chain ids (a buggy or
    # adversarial producer) must fail structural validation — those ids
    # would gather-clamp into silently wrong class sums
    _, compiled = tiny_compiled
    path = compiled.save(str(tmp_path / "art.npz"))

    def poison(arrays, meta):
        bad = np.array(arrays["sched_chain_ids"])
        bad[0, 0] = meta["schedule"]["n_lit_bits"] + 7
        arrays["sched_chain_ids"] = bad

    _rewrite(path, poison, fix_checksum=True)
    with pytest.raises(compiler.ArtifactError):
        compiler.CompiledTM.load(path)


def test_artifact_unsorted_word_ids_rejected(tiny_compiled, tmp_path):
    _, compiled = tiny_compiled
    if compiled.word_ids.shape[0] < 2:
        pytest.skip("needs >=2 active words")
    path = compiled.save(str(tmp_path / "art.npz"))

    def unsort(arrays, meta):
        arrays["word_ids"] = np.ascontiguousarray(arrays["word_ids"][::-1])

    _rewrite(path, unsort, fix_checksum=True)
    with pytest.raises(compiler.ArtifactError):
        compiler.CompiledTM.load(path)


def test_artifact_save_abort_preserves_previous(tiny_compiled, tmp_path):
    _, compiled = tiny_compiled
    path = compiled.save(str(tmp_path / "art.npz"))
    before = open(path, "rb").read()
    compiled.record_tuned("sparse_infer", 128, {"block_c": 8}, rows=1,
                          mode="drill")
    with faults.injected("artifact.save_abort"):
        with pytest.raises(faults.InjectedFault):
            compiled.save(path)
    # the aborted save left no tmp debris and did not touch the artifact
    assert [p for p in os.listdir(tmp_path) if ".tmp" in p] == []
    assert open(path, "rb").read() == before
    compiler.CompiledTM.load(path)                   # still serves


# --------------------------------------------------------------------------
# checkpoint substrate
# --------------------------------------------------------------------------

def test_ckpt_async_write_failure_surfaces(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"))
    with faults.injected("ckpt.write_fail"):
        mgr.save(1, {"a": np.arange(3)}, blocking=False)
        with pytest.raises(faults.InjectedFault):
            mgr.wait()                               # not swallowed
    # the failure is consumed: the manager keeps working afterwards
    mgr.save(2, {"a": np.arange(3)})
    assert mgr.latest_step() == 2


def test_ckpt_blocking_write_failure_raises_inline(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"))
    with faults.injected("ckpt.write_fail"):
        with pytest.raises(faults.InjectedFault):
            mgr.save(1, {"a": np.arange(3)}, blocking=True)


def test_ckpt_stale_tmp_cleanup_and_malformed_names(tmp_path):
    d = tmp_path / "ck"
    mgr = CheckpointManager(str(d))
    mgr.save(5, {"a": np.arange(3)}, extra={"step": 5})
    # a writer killed mid-save + a stray entry sharing the prefix
    os.makedirs(d / "step_0000000009.tmp")
    (d / "step_0000000009.tmp" / "arrays.npz").write_bytes(b"partial")
    os.makedirs(d / "step_bogus")
    mgr2 = CheckpointManager(str(d))
    assert not (d / "step_0000000009.tmp").exists()  # debris removed
    assert mgr2.latest_step() == 5                   # bogus entry ignored
    for s in (6, 7, 8, 9):
        mgr2.save(s, {"a": np.arange(3)})            # _gc tolerates step_bogus
    assert mgr2.latest_step() == 9


def test_loader_state_dict_is_consumed_position():
    X, y = make_boolean_classification(200, 16, 2, seed=0)
    a = ShardedBatcher((X, y), 10, seed=3, prefetch=2)
    it = iter(a)
    got = [next(it) for _ in range(3)]
    # the prefetch worker runs ahead, but the checkpointable state must be
    # the position the TRAINING LOOP consumed, not the worker's cursor
    st = a.state_dict()
    assert st["step_in_epoch"] == 3
    b = ShardedBatcher((X, y), 10, seed=3, prefetch=0)
    b.load_state_dict(st)
    ref = ShardedBatcher((X, y), 10, seed=3, prefetch=0)
    rit = iter(ref)
    for _ in range(3):
        next(rit)
    np.testing.assert_array_equal(next(iter(b))[0], next(rit)[0])
    del it, got


# --------------------------------------------------------------------------
# engine degradation ladder
# --------------------------------------------------------------------------

def test_engine_ladder_demotes_and_counts():
    def bad_builder():
        def f(x):
            raise RuntimeError("boom")
        return f

    def good_builder():
        return lambda x: x + 1

    lad = ops.EngineLadder([("bad", bad_builder), ("good", good_builder)])
    out = lad.run(lambda: np.int64(1), bucket=0)
    assert out == 2 and lad.engine == "good"
    assert lad.counts == {"bad": 0, "good": 1}
    assert lad.demotions[0]["frm"] == "bad" and lad.demotions[0]["to"] == "good"
    assert lad.exhausted


def test_engine_ladder_exhausted_propagates():
    def bad_builder():
        def f(x):
            raise RuntimeError("boom")
        return f

    lad = ops.EngineLadder([("only", bad_builder)])
    with pytest.raises(RuntimeError, match="boom"):
        lad.run(lambda: np.int64(1))
    assert not lad.demote("manual")                  # nowhere to go


def test_engine_ladder_repromotes_after_healthy_streak():
    """A transient failure demotes; after ``promote_after`` healthy buckets
    the ladder probes one level up and promotes when the probe succeeds —
    the probe bucket itself is served by the higher engine."""
    calls = {"n": 0}

    def flaky_builder():
        def f(x):
            calls["n"] += 1
            if calls["n"] == 1:
                raise RuntimeError("transient")
            return x + 10
        return f

    lad = ops.EngineLadder(
        [("flaky", flaky_builder), ("good", lambda: (lambda x: x + 1))],
        promote_after=2)
    assert lad.run(lambda: np.int64(0), bucket=0) == 1   # demoted to good
    assert lad.engine == "good" and len(lad.demotions) == 1
    assert lad.run(lambda: np.int64(0), bucket=1) == 1   # healthy streak 2
    out = lad.run(lambda: np.int64(0), bucket=2)         # probe bucket
    assert out == 10 and lad.engine == "flaky"
    assert lad.promotions == [dict(to="flaky", frm="good", bucket=2,
                                   after_healthy=2)]
    assert lad.counts == {"flaky": 1, "good": 2}
    assert lad.probe_failures == []


def test_engine_ladder_failed_probe_doubles_cooldown_drill():
    """Fault-injection drill: a kernel engine that keeps faulting makes
    every probe fail — each failed probe falls back to the serving engine
    for the SAME bucket and doubles the healthy-streak cooldown, so the
    fault converges to exponentially-rare probes; once the fault clears,
    the next probe promotes."""
    def kernel_builder():
        def f(x):
            faults.raise_if("kernel.dense")
            return x + 10
        return f

    lad = ops.EngineLadder(
        [("kernel", kernel_builder), ("oracle", lambda: (lambda x: x + 1))],
        promote_after=1)
    with faults.injected("kernel.dense*3"):
        # firing 1: initial demotion; firings 2-3: two failed probes
        assert lad.run(lambda: np.int64(0), bucket=0) == 1   # demote; streak 1
        assert lad.engine == "oracle"
        assert lad.run(lambda: np.int64(0), bucket=1) == 1   # probe fails
        assert len(lad.probe_failures) == 1 and lad._cooldown == 2
        assert lad.run(lambda: np.int64(0), bucket=2) == 1   # streak 2
        assert lad.run(lambda: np.int64(0), bucket=3) == 1   # probe fails
        assert len(lad.probe_failures) == 2 and lad._cooldown == 4
        for b in range(4, 7):                                # streak 2..4
            assert lad.run(lambda: np.int64(0), bucket=b) == 1
        # fault site exhausted: this probe succeeds and promotes
        assert lad.run(lambda: np.int64(0), bucket=7) == 10
    assert lad.engine == "kernel"
    assert lad.promotions[0]["to"] == "kernel"
    # every bucket was answered by SOME engine — probes never drop work
    assert lad.counts["kernel"] + lad.counts["oracle"] == 8


SERVE_ARGV = ["-m", "repro.launch.serve", "--arch", "tm-tiny",
              "--requests", "640", "--bucket", "128",
              "--epochs", "1", "--n-train", "256"]


def _serve_health(r):
    assert r.returncode == 0, r.stdout + r.stderr
    lines = [l for l in r.stdout.splitlines() if l.startswith("SERVE_HEALTH ")]
    assert lines, r.stdout + r.stderr
    return json.loads(lines[0][len("SERVE_HEALTH "):])


def test_serve_ladder_demotes_to_oracle_under_kernel_faults():
    r = _run(SERVE_ARGV + ["--factorize"], env_extra={
        "REPRO_USE_PALLAS": "1",
        "REPRO_FAULT_INJECT": "kernel.factorized,kernel.sparse,kernel.dense",
    })
    h = _serve_health(r)
    assert h["ladder"] == ["factorized", "sparse", "dense", "oracle"]
    assert h["final_engine"] == "oracle"
    assert [d["frm"] for d in h["demotions"]] == [
        "factorized", "sparse", "dense"]
    # every bucket was still served — the run degraded, it did not drop
    assert h["engine_buckets"]["oracle"] == h["buckets"]


def test_serve_healthy_kernel_path_stays_on_top_engine():
    r = _run(SERVE_ARGV + ["--factorize"],
             env_extra={"REPRO_USE_PALLAS": "1"})
    h = _serve_health(r)
    assert h["final_engine"] == "factorized" and h["demotions"] == []
    assert h["engine_buckets"]["factorized"] == h["buckets"]


def test_serve_bucket_deadline_demotes_on_slow_bucket():
    r = _run(SERVE_ARGV + ["--factorize", "--bucket-deadline", "3"],
             env_extra={
                 "REPRO_USE_PALLAS": "1",
                 "REPRO_FAULT_INJECT": "serve.slow_bucket@3:0.3",
             })
    h = _serve_health(r)
    assert h["stragglers"] and h["stragglers"][0]["step"] == 3
    assert h["demotions"] and "deadline" in h["demotions"][0]["reason"]
    assert h["demotions"][0]["frm"] == "factorized"


def test_serve_refuses_corrupt_artifact(tiny_compiled, tmp_path):
    _, compiled = tiny_compiled
    with faults.injected("artifact.bitflip"):
        path = compiled.save(str(tmp_path / "art.npz"))
    r = _run(["-m", "repro.launch.serve", "--arch", "tm-tiny",
              "--requests", "128", "--bucket", "128", "--artifact", path])
    assert r.returncode != 0
    assert "refusing to serve" in (r.stdout + r.stderr)


# --------------------------------------------------------------------------
# preemption-safe training (SIGTERM -> RESUME_EXIT_CODE -> bit-exact resume)
# --------------------------------------------------------------------------

def _fit_code(ckpt, out):
    return f"""
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import CheckpointManager
from repro.core import tm, train
from repro.data import make_boolean_classification
from repro.runtime import PreemptionHandler, StragglerMonitor

config = tm.TMConfig(n_features=32, n_classes=3, clauses_per_class=8)
X, y = make_boolean_classification(256, 32, 3, seed=0)
state = tm.init(config, jax.random.PRNGKey(0))
state = train.fit(config, state, jnp.asarray(X), jnp.asarray(y),
                  epochs=3, batch_size=32, rng=jax.random.PRNGKey(1),
                  engine="kernel", ckpt_manager=CheckpointManager({ckpt!r}),
                  ckpt_every=2, preemption=PreemptionHandler().install(),
                  monitor=StragglerMonitor())
np.save({out!r}, np.asarray(state.ta_state))
"""


def test_fit_sigterm_exits_resume_code_and_resumes_bit_exact():
    with tempfile.TemporaryDirectory() as d:
        ref = os.path.join(d, "ref.npy")
        r = _run(_fit_code(os.path.join(d, "ck_ref"), ref))
        assert r.returncode == 0, r.stdout + r.stderr

        ck = os.path.join(d, "ck")
        out = os.path.join(d, "out.npy")
        # SIGTERM mid-epoch-1 (global step 10 of 24): the handler must
        # checkpoint and exit with the restart-me code, not crash
        r = _run(_fit_code(ck, out),
                 env_extra={"REPRO_FAULT_INJECT": "train.sigterm@9"})
        assert r.returncode == RESUME_EXIT_CODE, r.stdout + r.stderr
        assert not os.path.exists(out)

        r = _run(_fit_code(ck, out))
        assert r.returncode == 0, r.stdout + r.stderr
        assert "fit: resumed" in r.stdout
        np.testing.assert_array_equal(np.load(ref), np.load(out))


def test_launch_train_sigterm_resume_with_prefetch_loader():
    argv = ["-m", "repro.launch.train", "--arch", "tm-tiny",
            "--steps", "12", "--batch-size", "32", "--n-train", "256",
            "--ckpt-every", "3", "--log-every", "100"]
    with tempfile.TemporaryDirectory() as d:
        ck_ref = os.path.join(d, "ck_ref")
        r = _run(argv + ["--ckpt-dir", ck_ref])
        assert r.returncode == 0, r.stdout + r.stderr

        ck = os.path.join(d, "ck")
        r = _run(argv + ["--ckpt-dir", ck],
                 env_extra={"REPRO_FAULT_INJECT": "train.sigterm@5"})
        assert r.returncode == RESUME_EXIT_CODE, r.stdout + r.stderr
        r = _run(argv + ["--ckpt-dir", ck])
        assert r.returncode == 0, r.stdout + r.stderr
        assert "resumed from step 6" in r.stdout

        a = np.load(os.path.join(ck_ref, "step_0000000012", "arrays.npz"))
        b = np.load(os.path.join(ck, "step_0000000012", "arrays.npz"))
        np.testing.assert_array_equal(a["ta"], b["ta"])
