"""End-to-end fault-tolerance drill: the training driver checkpoints, is
killed mid-run, restarts, resumes from the checkpoint, and the final model
is bit-identical to an uninterrupted run (deterministic hash-RNG training +
resumable loader state make this exactly reproducible)."""

import os
import subprocess
import sys
import tempfile

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"), JAX_PLATFORMS="cpu")


def _train(steps, ckpt_dir, out_npy):
    code = f"""
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import CheckpointManager
from repro.configs.matador_tm import TM_CONFIGS
from repro.core import tm
from repro.data import ShardedBatcher, make_boolean_classification
from repro.kernels import ops

config = tm.TMConfig(n_features=32, n_classes=3, clauses_per_class=8)
X, y = make_boolean_classification(512, 32, 3, seed=0)
mgr = CheckpointManager({ckpt_dir!r}, max_to_keep=2)
state = tm.init(config, jax.random.PRNGKey(0))
ta = state.ta_state
loader = ShardedBatcher((X, y), 32, seed=1, prefetch=0)
start = 0
if mgr.latest_step() is not None:
    restored, extra = mgr.restore({{"ta": np.asarray(ta)}})
    ta = jnp.asarray(restored["ta"])
    loader.load_state_dict(extra["loader"])
    start = extra["step"]
it = iter(loader)
for step in range(start, {steps}):
    xb, yb = next(it)
    ta, _ = ops.tm_train_step_kernel(config, ta, jnp.asarray(xb), jnp.asarray(yb), jnp.uint32(step))
    mgr.save(step + 1, {{"ta": np.asarray(ta)}},
             extra={{"step": step + 1, "loader": loader.state_dict()}})
mgr.wait()
np.save({out_npy!r}, np.asarray(ta))
"""
    r = subprocess.run([sys.executable, "-c", code], env=ENV,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr


def test_kill_and_resume_is_bit_identical():
    with tempfile.TemporaryDirectory() as d:
        ref = os.path.join(d, "ref.npy")
        _train(12, os.path.join(d, "ckpt_ref"), ref)

        ck = os.path.join(d, "ckpt_resume")
        part = os.path.join(d, "part.npy")
        _train(7, ck, part)              # "preempted" after step 7
        fin = os.path.join(d, "fin.npy")
        _train(12, ck, fin)              # restart resumes from step 7

        np.testing.assert_array_equal(np.load(ref), np.load(fin))


def test_resume_skips_completed_steps():
    with tempfile.TemporaryDirectory() as d:
        ck = os.path.join(d, "ckpt")
        out = os.path.join(d, "a.npy")
        _train(5, ck, out)
        steps = sorted(os.listdir(ck))
        assert steps[-1] == "step_0000000005"
