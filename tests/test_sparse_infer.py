"""Block-sparse compiled-schedule inference: exactness + schedule shape.

The central property: for ANY automata state, inference through the
compiled chain schedule (``kernels/sparse_infer.py`` — clause clustering,
bit-level chains, scalar-prefetched ragged tile grid, early-exit) produces
BIT-identical class sums to dense ``ref``-semantics inference — across
dedup on/off, empty-clause-only models, single-active-word models, ragged
batch tails, and a clause-sharded emulated 4-device mesh.

``hypothesis`` is optional (fixed-seed fallbacks keep the checks in
tier-1), matching the repo-wide ``hypothesis_optional`` pattern.
"""

import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import compiler, packetizer, tm
from repro.kernels import ops, sparse_infer

pytestmark = pytest.mark.schedule

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _random_tm(n_features, n_classes, cpc, include_density, seed):
    rng = np.random.default_rng(seed)
    C = n_classes * cpc
    ta = np.where(
        rng.random((C, 2 * n_features)) < include_density,
        rng.integers(0, 127, (C, 2 * n_features)),
        rng.integers(-128, 0, (C, 2 * n_features)),
    ).astype(np.int8)
    cfg = tm.TMConfig(n_features=n_features, n_classes=n_classes,
                      clauses_per_class=cpc)
    return cfg, ta


def _check_schedule_equals_dense(n_features, n_classes, cpc, density, seed,
                                 batch=16, dedup=True):
    """Schedule-kernel class sums == dense inference, bit for bit."""
    cfg, ta = _random_tm(n_features, n_classes, cpc, density, seed)
    comp = compiler.compile_tm(cfg, ta, dedup=dedup)
    x = jnp.asarray(np.random.default_rng(seed + 1).integers(
        0, 2, (batch, n_features), dtype=np.uint8))
    dense = tm.class_sums(cfg, jnp.asarray(ta), tm.literals(x),
                          training=False)
    xp = packetizer.pack_literals(x)
    # engine="sparse" (not "auto"): these tests exist to cover the flat
    # bit-chain kernel; the PR-5 heuristic would route high-sharing random
    # banks to the factorized kernel and quietly drop that coverage
    sp = compiler.run_compiled(comp, xp, engine="sparse", interpret=True)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(sp))


if HAVE_HYPOTHESIS:
    @pytest.mark.hypothesis_optional
    @settings(max_examples=20, deadline=None)
    @given(
        n_features=st.integers(3, 80),
        n_classes=st.integers(2, 5),
        cpc=st.integers(2, 12),
        density=st.floats(0.0, 0.3),
        seed=st.integers(0, 10_000),
        batch=st.integers(1, 70),
        dedup=st.booleans(),
    )
    def test_schedule_equals_dense(n_features, n_classes, cpc, density,
                                   seed, batch, dedup):
        _check_schedule_equals_dense(n_features, n_classes, cpc, density,
                                     seed, batch=batch, dedup=dedup)


@pytest.mark.parametrize(
    "n_features,n_classes,cpc,density,seed,batch,dedup",
    [
        (3, 2, 2, 0.0, 0, 5, True),       # empty-clause-only model
        (3, 2, 2, 0.0, 0, 5, False),      # ... with dedup off
        (17, 3, 5, 0.05, 11, 7, True),    # sparse ragged batch tail
        (80, 5, 12, 0.3, 4242, 33, True),  # dense upper corner
        (33, 2, 7, 0.15, 977, 64, False),  # no dedup: duplicate rows kept
        (64, 4, 10, 0.02, 5, 40, True),   # wide + very sparse chains
    ],
)
def test_schedule_equals_dense_fixed(n_features, n_classes, cpc, density,
                                     seed, batch, dedup):
    """Fixed-seed fallback for the central property (always runs)."""
    _check_schedule_equals_dense(n_features, n_classes, cpc, density, seed,
                                 batch=batch, dedup=dedup)


def test_single_active_word_model():
    """Every clause includes exactly one literal: one-step chains, and the
    schedule's tile table collapses to one tile per clause block."""
    cfg = tm.TMConfig(n_features=40, n_classes=2, clauses_per_class=6)
    C, L = 12, 80
    ta = np.full((C, L), -5, np.int8)
    for c in range(C):
        ta[c, (c * 7) % L] = 3              # one include each
    comp = compiler.compile_tm(cfg, ta)
    sched = comp.default_schedule
    assert sched.n_tiles == sched.n_cblocks
    np.testing.assert_array_equal(sched.counts,
                                  np.ones(sched.n_cblocks, np.int32))
    _check_schedule_equals_dense_state(cfg, ta, batch=9, seed=0)


def test_empty_clause_only_model():
    """All-exclude bank: the degenerate artifact has zero chain tiles and
    the schedule path returns all-zero sums without launching a kernel."""
    cfg = tm.TMConfig(n_features=8, n_classes=2, clauses_per_class=2)
    ta = np.full((4, 16), -5, np.int8)
    comp = compiler.compile_tm(cfg, ta)
    assert comp.default_schedule.n_tiles == 0
    x = jnp.asarray(np.random.default_rng(0).integers(0, 2, (3, 8),
                                                      dtype=np.uint8))
    sums = compiler.run_compiled(
        comp, packetizer.pack_literals(x),
        engine=compiler.EngineSpec(use_kernel=True), interpret=True)
    np.testing.assert_array_equal(np.asarray(sums), 0)


def _check_schedule_equals_dense_state(cfg, ta, batch, seed):
    comp = compiler.compile_tm(cfg, ta)
    x = jnp.asarray(np.random.default_rng(seed).integers(
        0, 2, (batch, cfg.n_features), dtype=np.uint8))
    dense = tm.class_sums(cfg, jnp.asarray(ta), tm.literals(x),
                          training=False)
    sp = compiler.run_compiled(comp, packetizer.pack_literals(x),
                               engine="sparse", interpret=True)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(sp))


@pytest.mark.parametrize("batch", [1, 31, 32, 33, 64, 97])
def test_ragged_batch_tails(batch):
    """Sample-word packing (32 samples/word) handles every tail exactly:
    padded sample bits read literal 0, so non-empty clauses report 0 and
    the padded rows are sliced away."""
    cfg, ta = _random_tm(24, 3, 6, 0.12, 9)
    _check_schedule_equals_dense_state(cfg, ta, batch=batch, seed=1)


def test_schedule_csr_invariants():
    cfg, ta = _random_tm(60, 4, 10, 0.08, 3)
    comp = compiler.compile_tm(cfg, ta)
    for bc, bj in [(8, 8), (32, 16), (512, 32)]:
        s = comp.schedule(bc, bj)
        assert s.n_tiles == int(s.counts.sum())
        np.testing.assert_array_equal(np.diff(s.indptr), s.counts)
        # per block: tiles are contiguous, first/last flags bracket them
        for b in range(s.n_cblocks):
            lo, hi = int(s.indptr[b]), int(s.indptr[b + 1])
            if lo == hi:
                continue
            np.testing.assert_array_equal(s.tile_cb[lo:hi], b)
            np.testing.assert_array_equal(s.tile_jb[lo:hi],
                                          np.arange(hi - lo))
            assert s.tile_first[lo] == 1 and s.tile_last[hi - 1] == 1
            assert s.tile_first[lo + 1:hi].sum() == 0
            assert s.tile_last[lo:hi - 1].sum() == 0
        # chain entries beyond each clause's include count are sentinels
        bits = packetizer.unpack_bits_np(
            np.ascontiguousarray(comp.include_words), s.n_lit_bits)
        for c in range(comp.n_unique):
            n = int(bits[c].sum())
            np.testing.assert_array_equal(
                s.chain_ids[c, :n], np.nonzero(bits[c])[0])
            assert (s.chain_ids[c, n:] == s.n_lit_bits).all()
        assert 0.0 <= s.tile_sparsity <= 1.0


def test_pad_tiles_are_noops():
    """pad_tiles_to appends all-sentinel never-first/last tiles that leave
    class sums untouched (the cross-shard tile-count equalizer)."""
    cfg, ta = _random_tm(30, 2, 8, 0.1, 4)
    comp = compiler.compile_tm(cfg, ta)
    base = sparse_infer.build_schedule(comp.include_words,
                                      block_c=8, block_j=8)
    padded = sparse_infer.build_schedule(comp.include_words, block_c=8,
                                        block_j=8,
                                        pad_tiles_to=base.n_tiles + 5)
    assert padded.n_tiles == base.n_tiles + 5
    assert (padded.tile_first[base.n_tiles:] == 0).all()
    assert (padded.tile_last[base.n_tiles:] == 0).all()
    x = jnp.asarray(np.random.default_rng(0).integers(0, 2, (11, 30),
                                                      dtype=np.uint8))
    xp = packetizer.pack_literals(x)[:, jnp.asarray(comp.word_ids)]
    votes = jnp.asarray(comp.votes)
    a = sparse_infer.sparse_tm_forward(xp, votes, base, interpret=True)
    b = sparse_infer.sparse_tm_forward(xp, votes, padded, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cluster_order_preserves_sums():
    """Clustering is a pure permutation: votes travel with their rows.

    compile_tm(cluster=True) lays rows out in anytime.margin_order —
    vote-mass bands descending, density-clustered within each band — so
    the check is against that permutation, and class sums must be
    bit-identical between the plain and reordered banks.
    """
    from repro.kernels import anytime

    cfg, ta = _random_tm(40, 3, 8, 0.1, 7)
    plain = compiler.compile_tm(cfg, ta, cluster=False)
    clustered = compiler.compile_tm(cfg, ta, cluster=True)
    order = anytime.margin_order(plain.include_words, plain.votes,
                                 cluster_fn=sparse_infer.cluster_order)
    np.testing.assert_array_equal(plain.include_words[order],
                                  clustered.include_words)
    np.testing.assert_array_equal(plain.votes[order], clustered.votes)
    # vote mass (the banding key) never climbs back above a prior band
    mass = np.abs(clustered.votes.astype(np.int64)).sum(axis=1)
    top = int(mass.max())
    with np.errstate(divide="ignore"):
        band = np.floor(np.log2(top / np.maximum(mass, 1)))
    band = np.clip(band, 0, 7)
    band[mass == 0] = 8
    assert (np.diff(band) >= 0).all()
    # reordering is sum-preserving: both banks score identically
    x = jnp.asarray(np.random.default_rng(3).integers(0, 2, (9, 40),
                                                      dtype=np.uint8))
    xw = packetizer.pack_literals(x)
    a = ops.tm_forward_schedule(xw[:, jnp.asarray(plain.word_ids)],
                                plain.include_words,
                                jnp.asarray(plain.votes), use_kernel=False)
    b = ops.tm_forward_schedule(xw[:, jnp.asarray(clustered.word_ids)],
                                clustered.include_words,
                                jnp.asarray(clustered.votes),
                                use_kernel=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ops_dispatch_kernel_equals_oracle():
    """ops.tm_forward_schedule: kernel path == jnp oracle (and the traced
    table oracle) bit-for-bit."""
    cfg, ta = _random_tm(50, 4, 9, 0.07, 21)
    comp = compiler.compile_tm(cfg, ta)
    x = jnp.asarray(np.random.default_rng(2).integers(0, 2, (19, 50),
                                                      dtype=np.uint8))
    xw = packetizer.pack_literals(x)[:, jnp.asarray(comp.word_ids)]
    votes = jnp.asarray(comp.votes)
    kern = ops.tm_forward_schedule(xw, comp.include_words, votes,
                                   use_kernel=True, interpret=True)
    oracle = ops.tm_forward_schedule(xw, comp.include_words, votes,
                                     use_kernel=False)
    sched = comp.default_schedule
    table_oracle = sparse_infer.schedule_class_sums_ref(
        xw, jnp.asarray(sched.chain_ids),
        jnp.pad(votes, ((0, sched.chain_ids.shape[0] - comp.n_unique),
                        (0, 0))))
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(oracle))
    np.testing.assert_array_equal(np.asarray(kern),
                                  np.asarray(table_oracle))


def test_stacked_shard_schedules_compose_exactly():
    """Per-shard tile tables (common-shape padded) sum to the unsharded
    class sums — the single-process version of the mesh invariant."""
    cfg, ta = _random_tm(45, 3, 12, 0.09, 13)
    comp = compiler.compile_tm(cfg, ta)
    x = jnp.asarray(np.random.default_rng(3).integers(0, 2, (21, 45),
                                                      dtype=np.uint8))
    xw = packetizer.pack_literals(x)[:, jnp.asarray(comp.word_ids)]
    dense = tm.class_sums(cfg, jnp.asarray(ta), tm.literals(x),
                          training=False)
    for n_shards in (2, 4):
        schedules, chains, votes_st, tiles, C_loc = (
            sparse_infer.stack_shard_schedules(
                comp.include_words, comp.votes, n_shards,
                block_c=16, block_j=8))
        total = np.zeros_like(np.asarray(dense))
        for s in range(n_shards):
            part = sparse_infer.sparse_tm_forward_tables(
                xw, jnp.asarray(chains[s]), jnp.asarray(votes_st[s]),
                jnp.asarray(tiles[s]),
                block_c=schedules[s].block_c,
                block_j=schedules[s].block_j, interpret=True)
            total += np.asarray(part)
        np.testing.assert_array_equal(np.asarray(dense), total)


def test_save_load_keeps_schedule():
    cfg, ta = _random_tm(30, 3, 6, 0.1, 7)
    comp = compiler.compile_tm(cfg, ta)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "model.npz")
        comp.save(path)
        back = compiler.CompiledTM.load(path)
    assert back._schedules, "artifact should ship its default schedule"
    sched = next(iter(back._schedules.values()))
    ref_sched = comp.default_schedule
    np.testing.assert_array_equal(ref_sched.chain_ids, sched.chain_ids)
    np.testing.assert_array_equal(ref_sched.tile_cb, sched.tile_cb)
    np.testing.assert_array_equal(ref_sched.counts, sched.counts)


def test_bit_transpose_roundtrip():
    rng = np.random.default_rng(0)
    for B, W in [(7, 3), (32, 1), (65, 4)]:
        words = jnp.asarray(rng.integers(0, 2**32, (B, W), dtype=np.uint32))
        litT = sparse_infer.bit_transpose_literals(words, W * 32)
        assert litT.shape == (W * 32 + 1, packetizer.n_words(B))
        np.testing.assert_array_equal(np.asarray(litT[-1]), 0xFFFFFFFF)
        bits = packetizer.unpack_bits_np(np.asarray(words), W * 32)
        back = packetizer.unpack_bits_np(np.asarray(litT[:-1]),
                                         packetizer.n_words(B) * 32)
        np.testing.assert_array_equal(bits, back[:, :B].T)


def test_autotune_sparse_keys(tmp_path, monkeypatch):
    """The sparse sweep caches under artifact-hashed sparse_infer: keys and
    returns the schedule-tiling block names."""
    import json

    from repro.kernels import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "t.json"))
    cfg, ta = _random_tm(20, 2, 4, 0.1, 0)
    comp = compiler.compile_tm(cfg, ta)
    blocks = autotune.autotune_sparse_infer_blocks(
        9, 2, comp.include_words, interpret=True,
        candidates=((8, 8, 1), (16, 8, 1)), reps=1)
    assert set(blocks) == {"block_c", "block_j", "block_s"}
    cache = json.loads((tmp_path / "t.json").read_text())
    keys = [k for k in cache["entries"] if k.startswith("sparse_infer:")]
    assert len(keys) == 1 and ":sig" in keys[0]
    # a different artifact of the SAME shape must not share the entry
    cfg2, ta2 = _random_tm(20, 2, 4, 0.1, 99)
    comp2 = compiler.compile_tm(cfg2, ta2)
    autotune.autotune_sparse_infer_blocks(
        9, 2, comp2.include_words, interpret=True,
        candidates=((8, 8, 1), (16, 8, 1)), reps=1)
    cache = json.loads((tmp_path / "t.json").read_text())
    assert len([k for k in cache["entries"]
                if k.startswith("sparse_infer:")]) == 2


# ---------------------------------------------------------------------------
# Emulated multi-device: the clause-sharded compiled schedule
# ---------------------------------------------------------------------------

_MESH_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.core import tm, compiler, packetizer, sharding
from repro.kernels import sparse_infer

rng = np.random.default_rng(0)
cfg = tm.TMConfig(n_features=48, n_classes=4, clauses_per_class=20)
ta = np.where(rng.random((80, 96)) < 0.08,
              rng.integers(0, 127, (80, 96)),
              rng.integers(-128, 0, (80, 96))).astype(np.int8)
comp = compiler.compile_tm(cfg, ta)
X = jnp.asarray(rng.integers(0, 2, (24, 48), dtype=np.uint8))
xw = packetizer.pack_literals(X)[:, jnp.asarray(comp.word_ids)]
dense = tm.class_sums(cfg, jnp.asarray(ta), tm.literals(X), training=False)
for shape, axes in (((4,), ("model",)), ((2, 2), ("data", "model"))):
    mesh = jax.make_mesh(shape, axes)
    n_model = mesh.shape["model"]
    schedules, chains, votes, tiles, C_loc = (
        sparse_infer.stack_shard_schedules(
            comp.include_words, comp.votes, n_model, block_c=32, block_j=8))
    for uk in (True, False):   # Pallas schedule kernel and jnp table oracle
        fwd = sharding.sharded_schedule_forward_fn(
            mesh, block_c=schedules[0].block_c,
            block_j=schedules[0].block_j, use_kernel=uk, interpret=True)
        out = fwd(jnp.asarray(chains), jnp.asarray(votes),
                  jnp.asarray(tiles), xw)
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(out))
print("SHARDED_SCHEDULE_BITEXACT_OK")
"""


@pytest.mark.multidevice
def test_clause_sharded_schedule_bit_identical():
    """The compiled schedule, clause-sharded over an emulated 4-device
    mesh (each shard carrying its own tile table + one int32 psum), equals
    dense single-device inference EXACTLY — kernel and oracle engines, on
    a pure-model mesh and a (data x model) mesh."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", _MESH_CODE], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=REPO)
    assert "SHARDED_SCHEDULE_BITEXACT_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.multidevice
def test_serve_mesh_sparse_schedule_wiring():
    """`serve --mesh model=2` end-to-end on the sparse-schedule path."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu", REPRO_USE_PALLAS="1",
               XLA_FLAGS="--xla_force_host_platform_device_count=2")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "tm-mnist",
         "--requests", "64", "--bucket", "32", "--epochs", "1",
         "--n-train", "128", "--mesh", "model=2"],
        env=env, capture_output=True, text=True, timeout=600, cwd=REPO,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "clause-sharded sparse-schedule" in r.stdout, r.stdout + r.stderr
    assert "inf/s" in r.stdout, r.stdout + r.stderr
