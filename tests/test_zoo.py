"""Artifact-zoo behavior: LRU eviction under a memory cap, circuit-breaker
open/half-open/close transitions with exponential backoff, the
eviction-while-in-flight drill, the ``zoo.load_fail`` drill, and the
end-to-end "corrupt tenant quarantined while healthy tenants keep serving"
scenario through the gateway.
"""

import asyncio

import numpy as np
import pytest

from repro.runtime import faults
from repro.runtime.gateway import Gateway
from repro.runtime.zoo import (
    CLOSED, HALF_OPEN, OPEN, ArtifactLoadError, ArtifactZoo, CircuitBreaker,
    SwapAborted, TenantQuarantined,
)

pytestmark = pytest.mark.gateway


class Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _mk_zoo(**kw):
    loaded = []

    def loader(tenant):
        loaded.append(tenant)
        return f"model:{tenant}", 100      # every artifact "weighs" 100 B

    return ArtifactZoo(loader, **kw), loaded


# -- LRU under the memory cap -------------------------------------------------

def test_lru_eviction_under_byte_cap():
    zoo, loaded = _mk_zoo(capacity_bytes=250)
    for t in ("a", "b", "c"):
        with zoo.lease(t) as obj:
            assert obj == f"model:{t}"
    # 3 x 100 B > 250 B: "a" (least recently used) was evicted
    assert sorted(zoo._entries) == ["b", "c"] and zoo.evictions == 1
    with zoo.lease("b"):                   # touch: "b" is now most recent
        pass
    with zoo.lease("d"):                   # over cap again: "c" goes
        pass
    assert sorted(zoo._entries) == ["b", "d"]
    # evicted tenants reload on demand
    with zoo.lease("a"):
        pass
    assert loaded == ["a", "b", "c", "d", "a"]


def test_eviction_never_targets_pinned_entry():
    zoo, _ = _mk_zoo(max_entries=1)
    with zoo.lease("t0") as obj0:
        # loading t1 pushes over the cap while t0 is LRU — but t0 is
        # pinned, so the scan must pick the next unpinned victim or defer
        with zoo.lease("t1"):
            assert "t0" in zoo._entries     # still loaded mid-flight
            assert obj0 == "model:t0"       # and untouched
    # both leases released: deferred eviction (if any) has drained
    assert len(zoo._entries) <= 1


def test_evict_inflight_drill_defers_until_release():
    zoo, _ = _mk_zoo(max_entries=1)
    with faults.injected("zoo.evict_inflight*1"):
        with zoo.lease("t0"):
            with zoo.lease("t1"):
                # the drill forced the scan to target pinned t0: it must be
                # DEFERRED, not yanked mid-bucket
                assert zoo._entries["t0"].evict_on_release
                assert "t0" in zoo._entries
            assert "t1" in zoo._entries
        # lease released -> the deferred eviction lands
        assert "t0" not in zoo._entries
    assert zoo.deferred_evictions == 1 and zoo.evictions >= 1


# -- circuit breaker ----------------------------------------------------------

def test_breaker_open_half_open_close_transitions():
    clk = Clock()
    br = CircuitBreaker(threshold=2, cooldown=10.0, clock=clk)
    assert br.state == CLOSED and br.allow()
    br.record_failure()
    assert br.state == CLOSED              # one fault is not a pattern
    br.record_failure()
    assert br.state == OPEN and not br.allow()
    clk.advance(9.9)
    assert not br.allow()                  # cooldown not elapsed
    clk.advance(0.2)
    assert br.allow() and br.state == HALF_OPEN
    br.record_success()
    assert br.state == CLOSED and br.trips == 0


def test_breaker_failed_probe_doubles_backoff():
    clk = Clock()
    br = CircuitBreaker(threshold=1, cooldown=10.0, clock=clk)
    br.record_failure()                    # trip 1: retry at t=10
    assert br.state == OPEN and br.retry_at == 10.0
    clk.advance(10.0)
    assert br.allow()                      # half-open probe
    br.record_failure()                    # probe fails -> trip 2
    assert br.state == OPEN and br.retry_at == clk() + 20.0
    clk.advance(20.0)
    assert br.allow()
    br.record_failure()                    # trip 3 -> 40s backoff
    assert br.retry_at == clk() + 40.0


def test_breaker_backoff_is_capped():
    clk = Clock()
    br = CircuitBreaker(threshold=1, cooldown=10.0, max_cooldown=25.0,
                        clock=clk)
    for _ in range(4):
        br.record_failure()
        clk.t = br.retry_at
        assert br.allow()
    assert br.retry_at - clk() <= 25.0


def test_breaker_failed_half_open_probe_retrips_through_lease_path():
    """The backoff-doubling unit test above, drilled through the zoo's
    LEASE path: a half-open probe lease whose load fails must re-trip the
    breaker with a doubled cooldown — not reset it."""
    clk = Clock()
    zoo, loaded = _mk_zoo(breaker_threshold=1, breaker_cooldown=10.0,
                          clock=clk)
    with faults.injected("zoo.load_fail*2"):
        with pytest.raises(ArtifactLoadError):
            with zoo.lease("t0"):
                pass
        br = zoo.breakers["t0"]
        assert br.state == OPEN and br.retry_at == 10.0
        with pytest.raises(TenantQuarantined):     # still cooling down
            with zoo.lease("t0"):
                pass
        clk.advance(10.0)
        with pytest.raises(ArtifactLoadError):     # half-open probe fails
            with zoo.lease("t0"):
                pass
        assert br.state == OPEN
        assert br.retry_at == clk() + 20.0         # doubled, not reset
    clk.advance(20.0)
    with zoo.lease("t0") as obj:                   # next probe heals
        assert obj == "model:t0"
    zoo.record_success("t0")
    assert br.state == CLOSED and br.trips == 0
    assert loaded == ["t0"]                        # only the healthy load ran


def test_breaker_backoff_cap_through_lease_path():
    """max_cooldown bounds the lease-path backoff no matter how many
    consecutive probes fail."""
    clk = Clock()
    zoo, _ = _mk_zoo(breaker_threshold=1, breaker_cooldown=10.0,
                     breaker_max_cooldown=25.0, clock=clk)
    with faults.injected("zoo.load_fail*5"):
        with pytest.raises(ArtifactLoadError):
            with zoo.lease("t0"):
                pass
        br = zoo.breakers["t0"]
        for _ in range(4):                         # keep failing the probe
            clk.t = br.retry_at
            with pytest.raises(ArtifactLoadError):
                with zoo.lease("t0"):
                    pass
            assert br.retry_at - clk() <= 25.0     # capped forever
    clk.t = br.retry_at
    with zoo.lease("t0"):                          # capped != stuck: heals
        pass
    zoo.record_success("t0")
    assert br.state == CLOSED


# -- atomic hot-swap ----------------------------------------------------------

def test_swap_is_atomic_and_inflight_leases_finish_on_old_version():
    zoo, _ = _mk_zoo()
    with zoo.lease("t0") as obj:
        assert obj == "model:t0" and zoo.version("t0") == 1
        assert zoo.swap("t0", "model:t0-v2", 100) == 2
        # the in-flight lease still holds the OLD object — a swap never
        # mutates what a worker is serving from
        assert obj == "model:t0"
        # a lease admitted AFTER the commit gets the new version
        with zoo.lease("t0") as obj2:
            assert obj2 == "model:t0-v2"
    # draining the old lease must not delete the successor entry
    assert zoo.version("t0") == 2 and zoo.swaps == 1
    assert zoo.health()["versions"] == {"t0": 2}


def test_swap_abort_drill_leaves_old_entry_bit_intact():
    zoo, loaded = _mk_zoo()
    with zoo.lease("t0"):
        pass
    with faults.injected("zoo.swap_abort*1"):
        with pytest.raises(SwapAborted):
            zoo.swap("t0", "model:t0-v2", 100)
    # nothing half-promoted: same object, same version, abort counted
    assert zoo.version("t0") == 1
    with zoo.lease("t0") as obj:
        assert obj == "model:t0"
    assert zoo.swap_aborts == 1 and zoo.swaps == 0
    assert loaded == ["t0"]                        # never reloaded either
    # the abort is transient: the retry commits
    assert zoo.swap("t0", "model:t0-v2", 100) == 2


def test_trip_force_opens_breaker_then_half_open_probe_admits():
    clk = Clock()
    zoo, _ = _mk_zoo(breaker_cooldown=10.0, clock=clk)
    with zoo.lease("t0"):
        pass
    zoo.trip("t0")                                 # rollback hook
    with pytest.raises(TenantQuarantined):
        with zoo.lease("t0"):
            pass
    clk.advance(10.0)
    with zoo.lease("t0") as obj:                   # half-open probe admits
        assert obj == "model:t0"
    zoo.record_success("t0")
    assert zoo.breakers["t0"].state == CLOSED


# -- load failures and quarantine --------------------------------------------

def test_load_fail_drill_quarantines_tenant():
    clk = Clock()
    zoo, loaded = _mk_zoo(breaker_threshold=2, breaker_cooldown=10.0,
                          clock=clk)
    with faults.injected("zoo.load_fail*2"):
        for _ in range(2):
            with pytest.raises(ArtifactLoadError) as ei:
                with zoo.lease("t0"):
                    pass
            assert ei.value.shed_reason == "load_failed"
    # threshold reached: the breaker is open, leases refuse typed
    with pytest.raises(TenantQuarantined) as ei:
        with zoo.lease("t0"):
            pass
    assert ei.value.shed_reason == "tenant_quarantined"
    assert zoo.load_failures == 2 and zoo.quarantine_rejections == 1
    assert loaded == []                    # the loader itself never ran
    # backoff elapses -> half-open probe lease succeeds -> breaker closes
    clk.advance(50.0)
    with zoo.lease("t0") as obj:
        assert obj == "model:t0"
    zoo.record_success("t0")
    assert zoo.breakers["t0"].state == CLOSED


def test_load_fail_step_targets_tenant_by_trailing_digit():
    zoo, _ = _mk_zoo()
    with faults.injected("zoo.load_fail@2"):
        with zoo.lease("t1"):              # untargeted tenant loads fine
            pass
        with pytest.raises(ArtifactLoadError):
            with zoo.lease("t2"):
                pass


def test_engine_faults_reported_through_runner_trip_breaker():
    clk = Clock()
    zoo, _ = _mk_zoo(breaker_threshold=2, breaker_cooldown=10.0, clock=clk)

    def serve(obj, rows):
        if obj == "model:bad0":
            raise RuntimeError("engine exhausted")
        return np.zeros(len(rows), np.int64)

    run = zoo.runner(serve)
    for _ in range(2):
        with pytest.raises(RuntimeError):
            run("bad0", [np.zeros(2)])
    with pytest.raises(TenantQuarantined):
        run("bad0", [np.zeros(2)])
    # the healthy tenant is untouched by bad0's quarantine
    assert run("good1", [np.zeros(2)]).shape == (1,)
    assert zoo.breakers["bad0"].state == OPEN
    assert zoo.breakers["good1"].state == CLOSED


# -- end to end through the gateway ------------------------------------------

def test_corrupt_tenant_quarantined_healthy_tenants_keep_serving():
    """The acceptance scenario: one tenant's artifact fails to load (a
    corrupt file in the wild); its requests shed typed and its breaker
    opens, while every other tenant's requests keep being answered."""
    def loader(tenant):
        if tenant == "corrupt0":
            raise RuntimeError("checksum mismatch (simulated bit-rot)")
        return tenant, 64

    zoo = ArtifactZoo(loader, breaker_threshold=2)
    run = zoo.runner(lambda obj, rows: np.array(
        [int(r[0]) for r in rows]))

    async def go():
        gw = await Gateway(run, bucket=2, max_wait=0.01).start()
        futs = []
        for i in range(6):
            futs.append(gw.offer("corrupt0", np.array([i])))
            futs.append(gw.offer("good1", np.array([i])))
        res = await asyncio.gather(*futs)
        h = await gw.drain()
        return res, h

    res, h = asyncio.run(go())
    good = [r for r in res if r.tenant == "good1"]
    bad = [r for r in res if r.tenant == "corrupt0"]
    assert all(r.ok for r in good) and len(good) == 6
    assert not any(r.ok for r in bad)
    assert {r.reason for r in bad} <= {"load_failed", "tenant_quarantined"}
    assert h["tenants"]["good1"]["answered"] == 6
    assert h["unaccounted"] == 0           # zero silent drops
    assert zoo.breakers["corrupt0"].state == OPEN
    assert zoo.health()["breakers"]["corrupt0"]["state"] == OPEN
