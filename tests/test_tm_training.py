"""TM training semantics (Type I/II feedback) + convergence."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import feedback, tm, train
from repro.data import make_noisy_xor
from repro.kernels import ops


def test_type2_only_increments_excluded_zero_literals():
    cfg = tm.TMConfig(n_features=3, n_classes=2, clauses_per_class=2, s=10.0)
    ta = jnp.asarray(np.array([[-3, -3, 5, -3, -3, -3]] * 4, np.int8))
    lits = jnp.asarray(np.array([[1, 0, 1, 0, 1, 0]], np.uint8))
    fire = jnp.ones((1, 4), jnp.uint8)
    ftype = jnp.full((1, 4), 2, jnp.uint8)          # all Type II
    d = np.asarray(
        ops.ta_delta(ta, lits, fire, ftype, jnp.uint32(0), p_act=1.0, p_inact=0.1)
    )
    assert (d >= 0).all()
    # literal=1 positions and included positions unchanged
    assert d[0, 0] == 0 and d[0, 2] == 0 and d[0, 4] == 0
    # literal=0, excluded positions incremented deterministically
    assert d[0, 1] == 1 and d[0, 3] == 1 and d[0, 5] == 1


def test_type1_rewards_matching_literals():
    cfg = tm.TMConfig(n_features=2, n_classes=2, clauses_per_class=2, s=1e9,
                      boost_true_positive=True)
    ta = jnp.zeros((4, 4), jnp.int8)
    lits = jnp.asarray(np.array([[1, 1, 0, 0]], np.uint8))
    fire = jnp.ones((1, 4), jnp.uint8)
    ftype = jnp.full((1, 4), 1, jnp.uint8)
    d = np.asarray(
        ops.ta_delta(ta, lits, fire, ftype, jnp.uint32(3), p_act=1.0, p_inact=0.0)
    )
    np.testing.assert_array_equal(d, np.tile([1, 1, 0, 0], (4, 1)))


def test_states_clamped():
    cfg = tm.TMConfig(n_features=2, n_classes=2, clauses_per_class=2, n_states=128)
    ta = jnp.full((4, 4), 127, jnp.int8)
    new = feedback.apply_delta(cfg, ta, jnp.full((4, 4), 100, jnp.int32))
    assert int(np.asarray(new).max()) == 127
    new = feedback.apply_delta(cfg, jnp.full((4, 4), -128, jnp.int8),
                               jnp.full((4, 4), -100, jnp.int32))
    assert int(np.asarray(new).min()) == -128


def test_padded_clauses_stay_empty():
    cfg = tm.TMConfig(n_features=4, n_classes=3, clauses_per_class=3,
                      clause_pad_multiple=8)
    assert cfg.n_clauses_total == 16 and cfg.n_clauses_raw == 9
    st = tm.init(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(0).integers(0, 2, (16, 4), dtype=np.uint8))
    y = jnp.asarray(np.random.default_rng(1).integers(0, 3, 16, dtype=np.int32))
    st2, _ = train.train_step(cfg, st, x, y, jax.random.PRNGKey(2))
    pad = np.asarray(st2.ta_state)[9:]
    assert (pad < 0).all(), "padded clauses must remain all-exclude"
    assert np.asarray(tm.polarity(cfg))[9:].sum() == 0


def test_xor_convergence_jnp_path():
    X, y = make_noisy_xor(3000, noise=0.05, seed=0)
    Xte, yte = make_noisy_xor(500, noise=0.0, seed=1)
    cfg = tm.TMConfig(n_features=12, n_classes=2, clauses_per_class=20,
                      threshold=15, s=3.9)
    st = tm.init(cfg, jax.random.PRNGKey(0))
    st = train.fit(cfg, st, jnp.asarray(X), jnp.asarray(y), epochs=12,
                   batch_size=50, rng=jax.random.PRNGKey(1))
    acc = float(tm.accuracy(cfg, st, jnp.asarray(Xte), jnp.asarray(yte)))
    assert acc > 0.85, acc


def test_xor_convergence_kernel_path():
    X, y = make_noisy_xor(3000, noise=0.05, seed=2)
    Xte, yte = make_noisy_xor(500, noise=0.0, seed=3)
    cfg = tm.TMConfig(n_features=12, n_classes=2, clauses_per_class=20,
                      threshold=15, s=3.9)
    ta = tm.init(cfg, jax.random.PRNGKey(0)).ta_state
    rng = np.random.default_rng(0)
    for ep in range(12):
        perm = rng.permutation(3000)
        for i in range(3000 // 50):
            idx = perm[i * 50 : (i + 1) * 50]
            ta, _ = ops.tm_train_step_kernel(
                cfg, ta, jnp.asarray(X[idx]), jnp.asarray(y[idx]),
                jnp.uint32(ep * 1000 + i),
            )
    st = tm.TMState(ta_state=ta, steps=jnp.int32(0))
    acc = float(tm.accuracy(cfg, st, jnp.asarray(Xte), jnp.asarray(yte)))
    assert acc > 0.85, acc


def test_trained_model_is_sparse():
    """The paper's central empirical claim: trained TMs are include-sparse."""
    X, y = make_noisy_xor(2000, noise=0.05, seed=4)
    cfg = tm.TMConfig(n_features=12, n_classes=2, clauses_per_class=20,
                      threshold=15, s=3.9)
    st = tm.init(cfg, jax.random.PRNGKey(0))
    st = train.fit(cfg, st, jnp.asarray(X), jnp.asarray(y), epochs=8,
                   batch_size=50, rng=jax.random.PRNGKey(1))
    include_frac = float((np.asarray(st.ta_state) >= 0).mean())
    assert include_frac < 0.35, include_frac
