"""Clause-sharded fused TM paths vs the single-device ref.py oracle.

The PR 3 invariant: ``core/sharding.py``'s explicit ``shard_map`` schedules
(fused Pallas pipeline per ``model`` shard + one int32 class-sum psum) are
BIT-identical to the single-device oracle — exact TA-state and class-sum
equality on an emulated multi-device mesh, for every engine and mesh shape.

Subprocess pattern (like test_sharding.py): each test forces its own host
device count via XLA_FLAGS before jax init, so the main pytest process
keeps its single-device view.
"""

import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.multidevice

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
           JAX_PLATFORMS="cpu")


def _run(code: str, timeout=600):
    return subprocess.run(
        [sys.executable, "-c", code], env=ENV, capture_output=True,
        text=True, timeout=timeout, cwd=REPO,
    )


_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.core import tm, sharding, packetizer
from repro.kernels import ops, ref

cfg = tm.TMConfig(n_features=32, n_classes=4, clauses_per_class=16,
                  clause_pad_multiple=8, threshold=15, s=5.0)
state = tm.init(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
X = jnp.asarray(rng.integers(0, 2, (24, 32), dtype=np.uint8))
y = jnp.asarray(rng.integers(0, 4, 24, dtype=np.int32))
seed = jnp.uint32(5)
"""


def test_clause_sharded_fused_train_bit_identical():
    """The tentpole acceptance test: the clause-sharded fused train step on
    an emulated 4-device mesh reproduces the single-device ``ref.py``
    oracle's TA state EXACTLY (int8 equality, every automaton), on both a
    pure-model mesh and a (data x model) mesh, fused kernel and oracle
    engines, including batch-chunked ragged tails."""
    r = _run(_PRELUDE + """
ta_ref, _ = ops.tm_train_step_kernel(cfg, state.ta_state, X, y, seed,
                                     use_kernel=False)
for shape, axes in (((4,), ("model",)), ((2, 2), ("data", "model"))):
    mesh = jax.make_mesh(shape, axes)
    for kw in (dict(use_kernel=True, interpret=True),       # fused Pallas
               dict(use_kernel=True, interpret=True, fuse=False),
               dict(use_kernel=False,)):                    # oracle engine
        step = sharding.sharded_train_step_fn(cfg, mesh, engine="kernel", **kw)
        ta_sh = np.asarray(step(state.ta_state, X, y, seed))
        np.testing.assert_array_equal(np.asarray(ta_ref), ta_sh)
# chunked with ragged tail (24 local = 12/shard, chunk 5 -> 2 full + tail 2)
mesh = jax.make_mesh((2, 2), ("data", "model"))
step = sharding.sharded_train_step_fn(cfg, mesh, batch_chunk=5,
                                      engine="kernel", use_kernel=True,
                                      interpret=True)
np.testing.assert_array_equal(
    np.asarray(ta_ref), np.asarray(step(state.ta_state, X, y, seed)))
print("SHARDED_TRAIN_BITEXACT_OK")
""")
    assert "SHARDED_TRAIN_BITEXACT_OK" in r.stdout, r.stdout + r.stderr


def test_clause_sharded_fused_forward_sums_exact():
    """Class sums from the clause-sharded fused inference kernel (partial
    per-shard adder banks + psum) equal the oracle's int32 sums exactly,
    and the sharded predict fn matches tm.predict."""
    r = _run(_PRELUDE + """
iw = packetizer.pack_include_masks(state.ta_state)
votes = tm.vote_matrix(cfg)
ne = jnp.any(state.ta_state >= 0, -1).astype(jnp.uint8)
lw = packetizer.pack_bits(tm.literals(X))
sums_ref = (ref.clause_fire_ref(lw, iw).astype(jnp.int32)
            * ne[None, :].astype(jnp.int32)) @ votes
mesh = jax.make_mesh((2, 2), ("data", "model"))
fwd = sharding.sharded_forward_fn(mesh, use_kernel=True, interpret=True)
np.testing.assert_array_equal(np.asarray(sums_ref),
                              np.asarray(fwd(iw, votes, ne, lw)))
pred = sharding.sharded_predict_fn(cfg, mesh, use_kernel=True, interpret=True)
np.testing.assert_array_equal(
    np.asarray(tm.predict(cfg, state, X)),
    np.asarray(pred(iw, votes, ne, lw)))
print("SHARDED_FORWARD_OK")
""")
    assert "SHARDED_FORWARD_OK" in r.stdout, r.stdout + r.stderr


def test_fit_on_mesh_matches_single_device():
    """train.fit(engine='kernel', mesh=...) is a pure layout change: same
    shuffle stream, same seeds, bit-identical final automata."""
    r = _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.core import tm, train
from repro.data import make_noisy_xor

X, y = make_noisy_xor(64, noise=0.05, seed=3)
cfg = tm.TMConfig(n_features=12, n_classes=2, clauses_per_class=8,
                  clause_pad_multiple=4)
st0 = tm.init(cfg, jax.random.PRNGKey(0))
ta0 = np.asarray(st0.ta_state)
st_a = train.fit(cfg, st0, jnp.asarray(X), jnp.asarray(y), epochs=2,
                 batch_size=16, rng=jax.random.PRNGKey(7), engine="kernel")
st0b = tm.TMState(ta_state=jnp.asarray(ta0), steps=jnp.zeros((), jnp.int32))
mesh = jax.make_mesh((2, 2), ("data", "model"))
st_b = train.fit(cfg, st0b, jnp.asarray(X), jnp.asarray(y), epochs=2,
                 batch_size=16, rng=jax.random.PRNGKey(7), engine="kernel",
                 mesh=mesh)
np.testing.assert_array_equal(np.asarray(st_a.ta_state),
                              np.asarray(st_b.ta_state))
print("FIT_MESH_OK")
""")
    assert "FIT_MESH_OK" in r.stdout, r.stdout + r.stderr


def test_launch_train_and_serve_mesh_wiring():
    """`--mesh model=2` end-to-end through the launchers (tiny runs)."""
    env = dict(ENV, XLA_FLAGS="--xla_force_host_platform_device_count=2")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "tm-mnist",
         "--steps", "2", "--batch-size", "32", "--n-train", "128",
         "--mesh", "model=2", "--log-every", "10"],
        env=env, capture_output=True, text=True, timeout=600, cwd=REPO,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "clause axis sharded over model=2" in r.stdout, r.stdout + r.stderr
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "tm-mnist",
         "--requests", "64", "--bucket", "32", "--epochs", "1",
         "--n-train", "128", "--mesh", "model=2"],
        env=env, capture_output=True, text=True, timeout=600, cwd=REPO,
    )
    assert "clause-sharded" in r.stdout, r.stdout + r.stderr
    assert "inf/s" in r.stdout, r.stdout + r.stderr


def test_parse_mesh_spec_validation():
    """Spec parsing + a clear too-few-devices error (single-device proc)."""
    from repro.launch.mesh import parse_mesh_spec

    m = parse_mesh_spec("model=1")
    assert tuple(m.axis_names) == ("model",)
    with pytest.raises(ValueError, match="device_count"):
        parse_mesh_spec("model=64")
    with pytest.raises(ValueError, match="bad --mesh spec"):
        parse_mesh_spec("modl=2")
